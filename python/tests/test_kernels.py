"""Layer-1 correctness: every Pallas kernel against its pure-jnp oracle.

Hypothesis sweeps shapes / chunk sizes / k / dtypes; fixed seeds keep the
suite deterministic. interpret-mode Pallas is slow, so example counts are
deliberately modest — each case still exercises a distinct code path
(padding vs exact grid, ties, extreme magnitudes, non-square batches).
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import cross_entropy as xk
from compile.kernels import dct as dk
from compile.kernels import ref
from compile.kernels import topk as tk

hypothesis.settings.register_profile(
    "gauntlet", deadline=None, max_examples=12, derandomize=True
)
hypothesis.settings.load_profile("gauntlet")


def rng(seed=0):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------- DCT ----


class TestDct:
    def test_basis_orthonormal(self):
        for c in (8, 64):
            d = ref.dct_basis(c)
            np.testing.assert_allclose(d @ d.T, np.eye(c), atol=1e-5)

    def test_matches_ref(self):
        x = jnp.asarray(rng(1).normal(size=(9, 64, 64)).astype(np.float32))
        np.testing.assert_allclose(dk.dct2(x), ref.dct2(x), atol=1e-4)

    def test_roundtrip_identity(self):
        x = jnp.asarray(rng(2).normal(size=(8, 64, 64)).astype(np.float32))
        np.testing.assert_allclose(dk.idct2(dk.dct2(x)), x, atol=1e-4)

    def test_energy_preserved(self):
        """Orthonormal transform: per-chunk L2 norm is invariant."""
        x = jnp.asarray(rng(3).normal(size=(4, 64, 64)).astype(np.float32))
        y = dk.dct2(x)
        np.testing.assert_allclose(
            jnp.linalg.norm(y.reshape(4, -1), axis=1),
            jnp.linalg.norm(x.reshape(4, -1), axis=1),
            rtol=1e-4,
        )

    def test_constant_chunk_concentrates_dc(self):
        """A constant chunk has all energy in the (0, 0) coefficient."""
        x = jnp.ones((1, 64, 64), jnp.float32) * 3.0
        y = np.array(dk.dct2(x))[0]
        assert abs(y[0, 0] - 3.0 * 64) < 1e-3
        y[0, 0] = 0.0
        assert np.abs(y).max() < 1e-4

    @given(
        n=st.integers(1, 17),
        c=st.sampled_from([8, 16, 32]),
        bc=st.sampled_from([1, 3, 8]),
        seed=st.integers(0, 3),
    )
    def test_hypothesis_shapes(self, n, c, bc, seed):
        x = jnp.asarray(rng(seed).normal(size=(n, c, c)).astype(np.float32))
        np.testing.assert_allclose(dk.dct2(x, block_chunks=bc), ref.dct2(x), atol=1e-4)
        np.testing.assert_allclose(dk.idct2(x, block_chunks=bc), ref.idct2(x), atol=1e-4)

    def test_linearity(self):
        a = jnp.asarray(rng(4).normal(size=(3, 16, 16)).astype(np.float32))
        b = jnp.asarray(rng(5).normal(size=(3, 16, 16)).astype(np.float32))
        np.testing.assert_allclose(
            dk.dct2(2.0 * a + b), 2.0 * dk.dct2(a) + dk.dct2(b), atol=1e-4
        )


# --------------------------------------------------------------- top-k ----


class TestTopk:
    def test_matches_ref(self):
        c = jnp.asarray(rng(10).normal(size=(13, 256)).astype(np.float32))
        v, i = tk.topk_compress(c, 16)
        vr, ir = ref.topk_compress(c, 16)
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
        np.testing.assert_allclose(v, vr, atol=0)

    def test_signs_preserved(self):
        c = jnp.asarray(-np.abs(rng(11).normal(size=(2, 64))).astype(np.float32))
        v, _ = tk.topk_compress(c, 4)
        assert np.all(np.asarray(v) < 0)

    def test_k_equals_m_is_sorted_permutation(self):
        c = jnp.asarray(rng(12).normal(size=(3, 32)).astype(np.float32))
        v, i = tk.topk_compress(c, 32)
        for r in range(3):
            assert sorted(np.asarray(i)[r].tolist()) == list(range(32))
            mags = np.abs(np.asarray(v)[r])
            assert np.all(np.diff(mags) <= 1e-7)

    def test_tie_breaks_lower_index(self):
        c = jnp.asarray(np.array([[1.0, -1.0, 1.0, 0.5]], np.float32))
        _, i = tk.topk_compress(c, 3)
        vr, ir = ref.topk_compress(c, 3)
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
        assert np.asarray(i)[0].tolist() == [0, 1, 2]

    @given(
        n=st.integers(1, 10),
        m=st.sampled_from([16, 64, 100]),
        k=st.integers(1, 16),
        seed=st.integers(0, 3),
    )
    def test_hypothesis_matches_ref(self, n, m, k, seed):
        c = jnp.asarray(rng(seed).normal(size=(n, m)).astype(np.float32))
        v, i = tk.topk_compress(c, k)
        vr, ir = ref.topk_compress(c, k)
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
        np.testing.assert_allclose(v, vr, atol=0)

    def test_decompress_roundtrip(self):
        c = jnp.asarray(rng(13).normal(size=(5, 64)).astype(np.float32))
        v, i = tk.topk_compress(c, 64)
        np.testing.assert_allclose(ref.topk_decompress(v, i, 64), c, atol=0)


# ------------------------------------------------------- cross-entropy ----


class TestCrossEntropy:
    def test_matches_ref(self):
        g = rng(20)
        lg = jnp.asarray(g.normal(size=(37, 512)).astype(np.float32))
        lb = jnp.asarray(g.integers(0, 512, size=(37,)).astype(np.int32))
        np.testing.assert_allclose(xk.cross_entropy(lg, lb), ref.cross_entropy(lg, lb), atol=1e-4)

    def test_uniform_logits_give_log_v(self):
        lg = jnp.zeros((8, 1000), jnp.float32)
        lb = jnp.arange(8, dtype=jnp.int32)
        np.testing.assert_allclose(
            xk.cross_entropy(lg, lb), np.full(8, np.log(1000.0), np.float32), rtol=1e-5
        )

    def test_large_logits_stable(self):
        """Flash-style max subtraction keeps huge logits finite."""
        lg = jnp.asarray(rng(21).normal(size=(4, 64)).astype(np.float32)) * 1e4
        lb = jnp.zeros((4,), jnp.int32)
        out = np.asarray(xk.cross_entropy(lg, lb))
        assert np.all(np.isfinite(out))

    def test_grad_matches_analytic(self):
        g = rng(22)
        lg = jnp.asarray(g.normal(size=(16, 128)).astype(np.float32))
        lb = jnp.asarray(g.integers(0, 128, size=(16,)).astype(np.int32))
        got = jax.grad(lambda z: jnp.sum(xk.cross_entropy(z, lb)))(lg)
        want = ref.cross_entropy_grad(lg, lb, jnp.ones((16,)))
        np.testing.assert_allclose(got, want, atol=1e-5)

    def test_grad_matches_finite_difference(self):
        g = rng(23)
        lg = jnp.asarray(g.normal(size=(2, 8)).astype(np.float32))
        lb = jnp.asarray([1, 5], dtype=jnp.int32)
        f = lambda z: float(jnp.sum(xk.cross_entropy(z, lb)))  # noqa: E731
        grad = np.asarray(jax.grad(lambda z: jnp.sum(xk.cross_entropy(z, lb)))(lg))
        eps = 1e-3
        for r, c in [(0, 1), (1, 5), (0, 3)]:
            e = np.zeros_like(np.asarray(lg))
            e[r, c] = eps
            fd = (f(lg + e) - f(lg - e)) / (2 * eps)
            assert abs(fd - grad[r, c]) < 1e-2, (r, c, fd, grad[r, c])

    @given(
        r=st.integers(1, 40),
        v=st.sampled_from([8, 64, 500]),
        br=st.sampled_from([4, 32]),
        seed=st.integers(0, 3),
    )
    def test_hypothesis_shapes(self, r, v, br, seed):
        g = rng(seed)
        lg = jnp.asarray(g.normal(size=(r, v)).astype(np.float32))
        lb = jnp.asarray(g.integers(0, v, size=(r,)).astype(np.int32))
        np.testing.assert_allclose(
            xk.cross_entropy(lg, lb, block_rows=br), ref.cross_entropy(lg, lb), atol=1e-4
        )

    def test_bf16_logits(self):
        g = rng(24)
        lg = jnp.asarray(g.normal(size=(8, 32)).astype(np.float32)).astype(jnp.bfloat16)
        lb = jnp.asarray(g.integers(0, 32, size=(8,)).astype(np.int32))
        got = xk.cross_entropy(lg, lb)
        want = ref.cross_entropy(lg.astype(jnp.float32), lb)
        np.testing.assert_allclose(got, want, atol=5e-2)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])


class TestTopkMethods:
    """Both kernel strategies (itermax sweep / stable argsort) must agree
    with the oracle and with each other — they are perf alternatives, not
    semantic variants."""

    @given(
        n=st.integers(1, 8),
        m=st.sampled_from([32, 100]),
        k=st.integers(1, 12),
        seed=st.integers(0, 2),
    )
    def test_methods_agree(self, n, m, k, seed):
        c = jnp.asarray(rng(seed).normal(size=(n, m)).astype(np.float32))
        vs, is_ = tk.topk_compress(c, k, method="sort")
        vi, ii = tk.topk_compress(c, k, method="itermax")
        vr, ir = ref.topk_compress(c, k)
        np.testing.assert_array_equal(np.asarray(is_), np.asarray(ir))
        np.testing.assert_array_equal(np.asarray(ii), np.asarray(ir))
        np.testing.assert_allclose(vs, vr, atol=0)
        np.testing.assert_allclose(vi, vr, atol=0)

    def test_methods_agree_on_ties(self):
        c = jnp.asarray(np.array([[1.0, -1.0, 1.0, -1.0, 0.5]], np.float32))
        vs, is_ = tk.topk_compress(c, 4, method="sort")
        vi, ii = tk.topk_compress(c, 4, method="itermax")
        np.testing.assert_array_equal(np.asarray(is_), np.asarray(ii))
        np.testing.assert_allclose(vs, vi, atol=0)
