"""AOT pipeline: entry points lower to valid HLO text, meta is consistent."""

import json
import math
import os

import pytest

from compile import aot, configs, model

CFG = configs.NANO


class TestMeta:
    def test_meta_offsets_cover_param_vector(self):
        meta = aot.build_meta(CFG)
        off = 0
        for spec in meta["params"]:
            assert spec["offset"] == off
            assert spec["size"] == math.prod(spec["shape"])
            off += spec["size"]
        assert off == meta["param_count"]

    def test_meta_demo_dims(self):
        meta = aot.build_meta(CFG)
        p, p_pad, n_chunks, c_total = model.demo_dims(CFG)
        assert meta["param_count"] == p
        assert meta["padded_count"] == p_pad
        assert meta["n_chunks"] == n_chunks
        assert meta["coeff_count"] == c_total

    def test_meta_lists_all_artifacts(self):
        meta = aot.build_meta(CFG)
        assert meta["artifacts"] == sorted(
            ["loss", "loss_per_seq", "grad", "demo_compress", "apply_update", "eval_peer", "adamw_step"]
        )

    def test_meta_json_serializable(self):
        json.dumps(aot.build_meta(CFG))


class TestLowering:
    def test_entry_points_have_expected_arity(self):
        eps = aot.entry_points(CFG)
        arity = {name: len(specs) for name, (_, specs) in eps.items()}
        assert arity == {
            "loss": 2,
            "loss_per_seq": 2,
            "grad": 2,
            "demo_compress": 3,
            "apply_update": 3,
            "eval_peer": 5,
            "adamw_step": 6,
        }

    @pytest.mark.parametrize("name", ["loss", "apply_update"])
    def test_lowers_to_hlo_text(self, name):
        import jax

        fn, arg_specs = aot.entry_points(CFG)[name]
        text = aot.to_hlo_text(jax.jit(fn).lower(*arg_specs))
        assert text.startswith("HloModule")
        assert "ENTRY" in text

    def test_artifacts_on_disk_if_built(self):
        """If `make artifacts` ran, the nano directory must be complete."""
        d = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "nano")
        if not os.path.isdir(d):
            pytest.skip("artifacts not built")
        meta = json.load(open(os.path.join(d, "meta.json")))
        for name in meta["artifacts"]:
            path = os.path.join(d, f"{name}.hlo.txt")
            assert os.path.exists(path), path
            with open(path) as f:
                assert f.read(9) == "HloModule"
        init = os.path.join(d, "init_params.bin")
        assert os.path.getsize(init) == 4 * meta["param_count"]
