"""Layer-2 correctness: transformer, DeMo ops, AdamW baseline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model
from compile.kernels import ref

CFG = configs.NANO


def rng(seed=0):
    return np.random.default_rng(seed)


def batch(cfg=CFG, seed=0):
    return jnp.asarray(
        rng(seed).integers(0, cfg.vocab, size=(cfg.batch, cfg.seq + 1)).astype(np.int32)
    )


@pytest.fixture(scope="module")
def flat():
    return jnp.asarray(model.init_params(CFG, seed=0))


class TestParams:
    def test_param_count_matches_specs(self, flat):
        assert flat.size == model.param_count(CFG)

    def test_param_count_formula(self):
        # embed + L * (4 attn + 3 mlp mats + 2 norms) + final norm
        c = CFG
        expected = c.vocab * c.d_model + c.n_layers * (
            4 * c.d_model * c.d_model + 3 * c.d_model * c.d_ff + 2 * c.d_model
        ) + c.d_model
        assert model.param_count(c) == expected

    def test_unflatten_shapes_and_coverage(self, flat):
        p = model.unflatten(flat, CFG)
        specs = dict(model.param_specs(CFG))
        assert set(p) == set(specs)
        total = 0
        for name, arr in p.items():
            assert arr.shape == specs[name], name
            total += arr.size
        assert total == flat.size

    def test_unflatten_is_exact_slicing(self, flat):
        p = model.unflatten(flat, CFG)
        emb = np.asarray(p["embed"]).reshape(-1)
        np.testing.assert_array_equal(emb, np.asarray(flat)[: emb.size])

    def test_init_deterministic(self):
        a = model.init_params(CFG, seed=0)
        b = model.init_params(CFG, seed=0)
        np.testing.assert_array_equal(a, b)
        c = model.init_params(CFG, seed=1)
        assert np.abs(a - c).max() > 0

    def test_norms_init_to_one(self, flat):
        p = model.unflatten(flat, CFG)
        np.testing.assert_array_equal(np.asarray(p["final_norm"]), np.ones(CFG.d_model))


class TestForward:
    def test_logit_shape(self, flat):
        p = model.unflatten(flat, CFG)
        toks = batch()[:, :-1]
        logits = model.forward(p, toks, CFG)
        assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)

    def test_initial_loss_near_log_vocab(self, flat):
        loss = model.loss_fn(flat, batch(), CFG)
        assert abs(float(loss) - np.log(CFG.vocab)) < 0.5

    def test_causality(self, flat):
        """Perturbing a future token must not change earlier logits."""
        p = model.unflatten(flat, CFG)
        toks = np.asarray(batch()[:, :-1]).copy()
        a = np.asarray(model.forward(p, jnp.asarray(toks), CFG))
        toks2 = toks.copy()
        toks2[:, -1] = (toks2[:, -1] + 1) % CFG.vocab
        b = np.asarray(model.forward(p, jnp.asarray(toks2), CFG))
        np.testing.assert_allclose(a[:, :-1], b[:, :-1], atol=1e-5)
        assert np.abs(a[:, -1] - b[:, -1]).max() > 1e-6

    def test_rope_properties(self):
        """RoPE is identity at position 0, norm-preserving, position-mixing."""
        x = jnp.asarray(rng(9).normal(size=(1, 2, CFG.seq, CFG.head_dim)).astype(np.float32))
        y = np.asarray(model._rope(x))
        np.testing.assert_allclose(y[:, :, 0], np.asarray(x)[:, :, 0], atol=1e-6)
        np.testing.assert_allclose(
            np.linalg.norm(y, axis=-1), np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5
        )
        assert np.abs(y[:, :, 1:] - np.asarray(x)[:, :, 1:]).max() > 1e-3

    def test_order_sensitivity(self, flat):
        """Permuting earlier tokens changes the last position's logits."""
        p = model.unflatten(flat, CFG)
        toks = np.asarray(batch()[:, :-1]).copy()
        a = np.asarray(model.forward(p, jnp.asarray(toks), CFG))[:, -1]
        toks2 = toks.copy()
        toks2[:, [0, 1]] = toks2[:, [1, 0]]
        b = np.asarray(model.forward(p, jnp.asarray(toks2), CFG))[:, -1]
        assert np.abs(a - b).max() > 1e-6

    def test_grad_matches_finite_difference(self, flat):
        toks = batch()
        loss0, g = model.grad_fn(flat, toks, CFG)
        g = np.asarray(g)
        eps = 1e-2
        f = lambda th: float(model.loss_fn(th, toks, CFG))  # noqa: E731
        idxs = [0, 17, int(flat.size // 2), int(flat.size - 1)]
        for i in idxs:
            e = np.zeros(flat.size, np.float32)
            e[i] = eps
            fd = (f(flat + jnp.asarray(e)) - f(flat - jnp.asarray(e))) / (2 * eps)
            assert abs(fd - g[i]) < 5e-3, (i, fd, g[i])

    def test_loss_decreases_with_sgd(self, flat):
        toks = batch()
        th = flat
        first = None
        for _ in range(5):
            loss, g = model.grad_fn(th, toks, CFG)
            first = first if first is not None else float(loss)
            th = th - 0.5 * g
        assert float(model.loss_fn(th, toks, CFG)) < first - 0.3


class TestDemo:
    def test_dims(self):
        p, p_pad, n_chunks, c_total = model.demo_dims(CFG)
        m = CFG.chunk * CFG.chunk
        assert p == model.param_count(CFG)
        assert p_pad == n_chunks * m and p_pad >= p and p_pad - p < m
        assert c_total == n_chunks * CFG.topk

    def test_compress_shapes_and_index_layout(self, flat):
        p, p_pad, n_chunks, c_total = model.demo_dims(CFG)
        g = jnp.asarray(rng(3).normal(size=(p,)).astype(np.float32))
        vals, idx, e2 = model.demo_compress(jnp.zeros((p,)), g, jnp.float32(0.999), CFG)
        assert vals.shape == (c_total,) and idx.shape == (c_total,)
        assert e2.shape == (p,)
        idx = np.asarray(idx)
        m = CFG.chunk * CFG.chunk
        # indices are globally unique and each chunk owns its own stripe
        assert len(set(idx.tolist())) == c_total
        chunk_of = idx // m
        np.testing.assert_array_equal(
            chunk_of, np.repeat(np.arange(n_chunks), CFG.topk)
        )

    def test_error_feedback_invariant(self, flat):
        """e' == decay*e + g - IDCT(scatter(vals, idx)) exactly."""
        p, p_pad, n_chunks, _ = model.demo_dims(CFG)
        e = jnp.asarray(rng(4).normal(size=(p,)).astype(np.float32))
        g = jnp.asarray(rng(5).normal(size=(p,)).astype(np.float32))
        decay = jnp.float32(0.9)
        vals, idx, e2 = model.demo_compress(e, g, decay, CFG)
        coeff = np.zeros(p_pad, np.float32)
        coeff[np.asarray(idx)] = np.asarray(vals)
        est = np.asarray(model.coeff_to_delta(jnp.asarray(coeff), CFG))
        want = np.asarray(decay * e + g) - est
        np.testing.assert_allclose(np.asarray(e2), want, atol=1e-4)

    def test_transmitted_energy_dominates(self, flat):
        """Top-k of the DCT should capture the largest coefficients: the
        transmitted estimate's energy >= what any random-k choice gets."""
        p, p_pad, n_chunks, _ = model.demo_dims(CFG)
        g = jnp.asarray(rng(6).normal(size=(p,)).astype(np.float32))
        vals, idx, e2 = model.demo_compress(jnp.zeros((p,)), g, jnp.float32(0), CFG)
        # residual energy strictly less than input energy
        assert float(jnp.linalg.norm(e2)) < float(jnp.linalg.norm(g))

    def test_apply_update_is_signed_step(self, flat):
        p, p_pad, _, _ = model.demo_dims(CFG)
        coeff = jnp.asarray(rng(7).normal(size=(p_pad,)).astype(np.float32))
        lr = jnp.float32(0.01)
        th2 = model.apply_update(flat, coeff, lr, CFG)
        step = np.asarray(th2 - flat)
        nz = step[np.abs(step) > 0]
        np.testing.assert_allclose(np.abs(nz), 0.01, rtol=1e-4)

    def test_eval_peer_consistency(self, flat):
        """eval_peer's four losses match loss_fn on manually stepped params."""
        p, p_pad, _, _ = model.demo_dims(CFG)
        coeff = jnp.asarray(rng(8).normal(size=(p_pad,)).astype(np.float32))
        beta = jnp.float32(0.004)
        ta, trd = batch(seed=1), batch(seed=2)
        la0, la1, lr0, lr1 = model.eval_peer(flat, coeff, beta, ta, trd, CFG)
        thp = flat - beta * jnp.sign(model.coeff_to_delta(coeff, CFG))
        np.testing.assert_allclose(float(la0), float(model.loss_fn(flat, ta, CFG)), rtol=1e-5)
        np.testing.assert_allclose(float(la1), float(model.loss_fn(thp, ta, CFG)), rtol=1e-5)
        np.testing.assert_allclose(float(lr0), float(model.loss_fn(flat, trd, CFG)), rtol=1e-5)
        np.testing.assert_allclose(float(lr1), float(model.loss_fn(thp, trd, CFG)), rtol=1e-5)

    def test_demo_training_reduces_loss(self, flat):
        """A few self-aggregated DeMo steps reduce loss on a fixed batch."""
        p, p_pad, n_chunks, _ = model.demo_dims(CFG)
        toks = batch()
        th, e = flat, jnp.zeros((p,))
        l0 = float(model.loss_fn(th, toks, CFG))
        for _ in range(5):
            _, g = model.grad_fn(th, toks, CFG)
            vals, idx, e = model.demo_compress(e, g, jnp.float32(0.9), CFG)
            coeff = np.zeros(p_pad, np.float32)
            norm = float(np.linalg.norm(np.asarray(vals)))
            coeff[np.asarray(idx)] = np.asarray(vals) / max(norm, 1e-12)
            th = model.apply_update(th, jnp.asarray(coeff), jnp.float32(0.02), CFG)
        assert float(model.loss_fn(th, toks, CFG)) < l0 - 0.5


class TestAdamW:
    def test_matches_manual_adamw(self, flat):
        toks = batch()
        m = jnp.zeros_like(flat)
        v = jnp.zeros_like(flat)
        lr, t = jnp.float32(1e-3), jnp.float32(1)
        loss, th1, m1, v1 = model.adamw_step(flat, m, v, toks, lr, t, CFG)
        _, g = model.grad_fn(flat, toks, CFG)
        g = np.asarray(g, np.float64)
        b1, b2 = CFG.adamw_beta1, CFG.adamw_beta2
        mm = (1 - b1) * g
        vv = (1 - b2) * g * g
        mhat = mm / (1 - b1)
        vhat = vv / (1 - b2)
        upd = mhat / (np.sqrt(vhat) + CFG.adamw_eps) + CFG.adamw_wd * np.asarray(flat, np.float64)
        np.testing.assert_allclose(np.asarray(th1), np.asarray(flat) - 1e-3 * upd, atol=1e-6)

    def test_loss_decreases(self, flat):
        toks = batch()
        th = flat
        m = jnp.zeros_like(flat)
        v = jnp.zeros_like(flat)
        l0 = None
        for t in range(1, 7):
            loss, th, m, v = model.adamw_step(th, m, v, toks, jnp.float32(3e-3), jnp.float32(t), CFG)
            l0 = l0 if l0 is not None else float(loss)
        assert float(model.loss_fn(th, toks, CFG)) < l0 - 0.3


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
