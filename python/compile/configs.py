"""Model / DeMo / training configurations shared by the AOT pipeline.

Every config is lowered into its own ``artifacts/<name>/`` directory; the
Rust coordinator picks a config by name and reads its ``meta.json``.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """A llama-style decoder-only transformer configuration.

    Attributes mirror the 1B-class recipe the paper trains (pre-RMSNorm,
    RoPE attention, SwiGLU MLP, tied embeddings) at reduced width.
    """

    name: str
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    vocab: int
    seq: int  # training sequence length (tokens arrive as [batch, seq+1])
    batch: int  # per-artifact microbatch
    # DeMo compression (chunked 2-D DCT + per-chunk top-k).
    chunk: int = 64
    topk: int = 32
    # Default optimizer hyperparameters baked into meta.json (the runtime
    # still passes lr / beta as runtime scalars; these are the defaults the
    # launcher reads). Signed descent moves EVERY parameter by +-lr each
    # round, so lr must shrink as models grow (swept in the perf pass).
    lr: float = 0.01
    demo_decay: float = 0.999
    adamw_lr: float = 3e-4
    adamw_beta1: float = 0.9
    adamw_beta2: float = 0.95
    adamw_eps: float = 1e-8
    adamw_wd: float = 0.1

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


NANO = ModelConfig(
    name="nano", d_model=64, n_layers=2, n_heads=2, d_ff=256, vocab=512, seq=32, batch=4,
    lr=0.01,
)
TINY = ModelConfig(
    name="tiny", d_model=128, n_layers=4, n_heads=4, d_ff=512, vocab=2048, seq=64, batch=4,
    lr=0.003,
)
SMALL = ModelConfig(
    name="small", d_model=256, n_layers=6, n_heads=8, d_ff=1024, vocab=4096, seq=128, batch=4,
    lr=0.002,
)
BASE = ModelConfig(
    name="base", d_model=512, n_layers=8, n_heads=8, d_ff=2048, vocab=8192, seq=256, batch=2,
    lr=0.0015,
)

CONFIGS: dict[str, ModelConfig] = {c.name: c for c in (NANO, TINY, SMALL, BASE)}

# Configs built by `make artifacts` (BASE is compile-scale-check only; build
# it explicitly with `python -m compile.aot --configs base`).
DEFAULT_BUILD = ("nano", "tiny", "small")


def get(name: str) -> ModelConfig:
    try:
        return CONFIGS[name]
    except KeyError:
        raise KeyError(f"unknown config {name!r}; known: {sorted(CONFIGS)}") from None
