"""AOT pipeline: lower every Layer-2 entry point to HLO-text artifacts.

Run once at build time (``make artifacts``); Python is never on the request
path. For each model config this emits into ``artifacts/<cfg>/``:

  loss.hlo.txt, grad.hlo.txt, demo_compress.hlo.txt, apply_update.hlo.txt,
  eval_peer.hlo.txt, adamw_step.hlo.txt   -- the compiled entry points
  meta.json                               -- shapes/offsets/hyperparams ABI
  init_params.bin                         -- deterministic f32 LE init vector

Interchange format is **HLO text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the Rust ``xla`` crate) rejects; the HLO text parser
reassigns ids so text round-trips cleanly. Everything is lowered with
``return_tuple=True`` and unwrapped with ``to_tuple()`` on the Rust side.
"""

from __future__ import annotations

import argparse
import functools
import json
import math
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs, model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring).

    `print_large_constants=True` is load-bearing: the default printer
    elides big literals as `constant({...})`, which xla_extension 0.5.1's
    text parser silently materializes as **zeros** — RoPE tables, causal
    masks and DCT bases would all vanish. (Found the hard way; see
    DESIGN.md "HLO-text gotchas".)
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def entry_points(cfg: configs.ModelConfig):
    """(name -> (fn, example_arg_specs)) for every artifact of a config."""
    p, p_pad, _, _ = model.demo_dims(cfg)
    tok = _spec((cfg.batch, cfg.seq + 1), jnp.int32)
    vec = _spec((p,))
    coeff = _spec((p_pad,))
    scalar = _spec(())

    return {
        "loss": (lambda th, t: (model.loss_fn(th, t, cfg),), (vec, tok)),
        "loss_per_seq": (lambda th, t: (model.loss_per_seq(th, t, cfg),), (vec, tok)),
        "grad": (lambda th, t: model.grad_fn(th, t, cfg), (vec, tok)),
        "demo_compress": (
            lambda e, g, d: model.demo_compress(e, g, d, cfg),
            (vec, vec, scalar),
        ),
        "apply_update": (
            lambda th, q, lr: (model.apply_update(th, q, lr, cfg),),
            (vec, coeff, scalar),
        ),
        "eval_peer": (
            lambda th, q, b, ta, tr: model.eval_peer(th, q, b, ta, tr, cfg),
            (vec, coeff, scalar, tok, tok),
        ),
        "adamw_step": (
            lambda th, m, v, t, lr, st: model.adamw_step(th, m, v, t, lr, st, cfg),
            (vec, vec, vec, tok, scalar, scalar),
        ),
    }


def build_meta(cfg: configs.ModelConfig) -> dict:
    p, p_pad, n_chunks, c_total = model.demo_dims(cfg)
    specs = []
    off = 0
    for name, shape in model.param_specs(cfg):
        n = math.prod(shape)
        specs.append({"name": name, "shape": list(shape), "offset": off, "size": n})
        off += n
    return {
        "name": cfg.name,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "d_ff": cfg.d_ff,
        "vocab": cfg.vocab,
        "seq": cfg.seq,
        "batch": cfg.batch,
        "chunk": cfg.chunk,
        "topk": cfg.topk,
        "param_count": p,
        "padded_count": p_pad,
        "n_chunks": n_chunks,
        "coeff_count": c_total,
        "hyper": {
            "lr": cfg.lr,
            "demo_decay": cfg.demo_decay,
            "adamw_lr": cfg.adamw_lr,
            "adamw_beta1": cfg.adamw_beta1,
            "adamw_beta2": cfg.adamw_beta2,
            "adamw_eps": cfg.adamw_eps,
            "adamw_wd": cfg.adamw_wd,
        },
        "params": specs,
        "artifacts": sorted(entry_points(cfg)),
    }


def build_config(cfg: configs.ModelConfig, out_dir: str, only: set[str] | None = None) -> None:
    cfg_dir = os.path.join(out_dir, cfg.name)
    os.makedirs(cfg_dir, exist_ok=True)
    eps = entry_points(cfg)
    names = sorted(eps) if only is None else sorted(set(eps) & only)
    for name in names:
        fn, arg_specs = eps[name]
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        path = os.path.join(cfg_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"  {cfg.name}/{name}.hlo.txt  ({len(text) / 1e6:.2f} MB)", flush=True)
    with open(os.path.join(cfg_dir, "meta.json"), "w") as f:
        json.dump(build_meta(cfg), f, indent=1)
    init = model.init_params(cfg, seed=0)
    init.astype("<f4").tofile(os.path.join(cfg_dir, "init_params.bin"))
    print(f"  {cfg.name}/meta.json + init_params.bin (P={init.size})", flush=True)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--configs",
        default=",".join(configs.DEFAULT_BUILD),
        help="comma-separated config names (default: %(default)s)",
    )
    ap.add_argument("--functions", default="", help="subset of entry points (default: all)")
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    args = ap.parse_args()
    only = set(args.functions.split(",")) - {""} or None
    for name in args.configs.split(","):
        cfg = configs.get(name.strip())
        print(f"[aot] lowering config {cfg.name!r}", flush=True)
        build_config(cfg, args.out_dir, only)
    return 0


if __name__ == "__main__":
    sys.exit(main())
