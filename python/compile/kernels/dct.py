"""Chunked 2-D DCT encode/decode as Pallas kernels (DeMo's transform).

DeMo decorrelates pseudo-gradients by applying a 2-D DCT to square chunks of
each tensor before top-k sparsification. On GPU the reference implementation
is a batched GEMM against the DCT basis; here we re-express it for the TPU
memory hierarchy:

  - The (c, c) DCT basis is small (c == 64 or 128) and is pinned in VMEM for
    the whole grid (``BlockSpec`` index map ``lambda i: (0, 0)``), playing
    the role the constant cache plays in the CUDA version.
  - The chunk batch (n_chunks, c, c) streams HBM -> VMEM ``block_chunks``
    chunks per grid step; each step performs two MXU-shaped matmuls
    ``D @ X @ D^T`` (encode) or ``D^T @ Y @ D`` (decode).

Lowered with ``interpret=True`` so the emitted HLO runs on CPU PJRT; real
TPU perf is estimated from the BlockSpec footprint in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import dct_basis

# Chunks per grid step. 32 chunks of 64x64 f32 = 512 KiB in VMEM; with
# double-buffered input+output blocks (~2 MiB) this stays well under the
# ~16 MiB budget while cutting the grid length 4x (the perf pass measured
# the interpret-mode grid loop as the dominant overhead at bc=8).
DEFAULT_BLOCK_CHUNKS = 32


def _encode_kernel(d_ref, x_ref, o_ref):
    d = d_ref[...]
    x = x_ref[...]
    # (c, c) @ (bc, c, c) @ (c, c)^T, batched over bc on the MXU.
    tmp = jnp.einsum("ij,njk->nik", d, x, precision="highest")
    o_ref[...] = jnp.einsum("nik,lk->nil", tmp, d, precision="highest")


def _decode_kernel(d_ref, y_ref, o_ref):
    d = d_ref[...]
    y = y_ref[...]
    tmp = jnp.einsum("ji,njk->nik", d, y, precision="highest")
    o_ref[...] = jnp.einsum("nik,kl->nil", tmp, d, precision="highest")


def _chunk_call(kernel, chunks: jax.Array, block_chunks: int) -> jax.Array:
    n, c, c2 = chunks.shape
    assert c == c2, f"chunks must be square, got {chunks.shape}"
    bc = min(block_chunks, n)
    if n % bc != 0:
        # Pad the chunk batch so the grid divides evenly; padded chunks are
        # all-zero and transform to all-zero, then get sliced away.
        pad = bc - n % bc
        chunks = jnp.concatenate([chunks, jnp.zeros((pad, c, c), chunks.dtype)], axis=0)
    grid = (chunks.shape[0] // bc,)
    d = jnp.asarray(dct_basis(c))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((c, c), lambda i: (0, 0)),  # basis: VMEM-resident
            pl.BlockSpec((bc, c, c), lambda i: (i, 0, 0)),  # chunk stream
        ],
        out_specs=pl.BlockSpec((bc, c, c), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(chunks.shape, jnp.float32),
        interpret=True,
    )(d, chunks.astype(jnp.float32))
    return out[:n]


@functools.partial(jax.jit, static_argnames=("block_chunks",))
def dct2(chunks: jax.Array, block_chunks: int = DEFAULT_BLOCK_CHUNKS) -> jax.Array:
    """2-D DCT-II over a batch of square chunks (n, c, c)."""
    return _chunk_call(_encode_kernel, chunks, block_chunks)


@functools.partial(jax.jit, static_argnames=("block_chunks",))
def idct2(coeffs: jax.Array, block_chunks: int = DEFAULT_BLOCK_CHUNKS) -> jax.Array:
    """Inverse 2-D DCT-II over a batch of square chunks (n, c, c)."""
    return _chunk_call(_decode_kernel, coeffs, block_chunks)
