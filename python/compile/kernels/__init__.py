"""Layer-1 Pallas kernels for the Gauntlet/DeMo compute hot-spots.

All kernels are authored for TPU-style tiling (VMEM blocks, MXU-friendly
matmul shapes) but lowered with ``interpret=True`` so the resulting HLO runs
on any PJRT backend, including the Rust CPU client on the request path.

Kernels:
  - :mod:`.dct`: chunked 2-D DCT encode/decode (DeMo's transform).
  - :mod:`.topk`: per-chunk top-k magnitude compression.
  - :mod:`.cross_entropy`: fused log-softmax cross-entropy.

:mod:`.ref` holds the pure-``jax.numpy`` oracles used by the pytest suite.
"""

from . import cross_entropy, dct, ref, topk  # noqa: F401

__all__ = ["cross_entropy", "dct", "ref", "topk"]
