"""Fused log-softmax cross-entropy as a Pallas kernel with a custom VJP.

The vocabulary projection dominates small-LLM step time, and materializing
the (rows, vocab) softmax in HBM doubles its cost. The kernel streams a
block of rows into VMEM and computes max / sum-exp / gold-logit gather in
one pass (the flash-softmax trick re-tiled for 8x128 VPU lanes), emitting
only the per-row loss.

``jax.grad`` cannot differentiate through ``pallas_call``, so the backward
pass is supplied analytically (``softmax - onehot``) via ``jax.custom_vjp``
— this is also what the fused CUDA kernels in the DeMo reference stack do.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 128 rows x 4096 vocab x 4 B = 2 MiB per block — wider blocks shorten the
# grid loop, the measured bottleneck in interpret mode (perf pass).
DEFAULT_BLOCK_ROWS = 128


def _xent_kernel(logits_ref, labels_ref, loss_ref):
    logits = logits_ref[...].astype(jnp.float32)  # (br, v)
    labels = labels_ref[...]  # (br,)
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[:, 0]
    gold = jnp.take_along_axis(logits, labels[:, None].astype(jnp.int32), axis=-1)[:, 0]
    loss_ref[...] = lse - gold


def _xent_fwd_impl(logits: jax.Array, labels: jax.Array, block_rows: int) -> jax.Array:
    r, v = logits.shape
    br = min(block_rows, r)
    pad = 0
    if r % br != 0:
        pad = br - r % br
        logits = jnp.concatenate([logits, jnp.zeros((pad, v), logits.dtype)], axis=0)
        labels = jnp.concatenate([labels, jnp.zeros((pad,), labels.dtype)], axis=0)
    grid = (logits.shape[0] // br,)
    loss = pl.pallas_call(
        _xent_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, v), lambda i: (i, 0)),
            pl.BlockSpec((br,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((br,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((logits.shape[0],), jnp.float32),
        interpret=True,
    )(logits, labels)
    return loss[:r]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def cross_entropy(logits: jax.Array, labels: jax.Array, block_rows: int = DEFAULT_BLOCK_ROWS):
    """Per-row softmax cross-entropy loss. logits (r, v), labels (r,) i32."""
    return _xent_fwd_impl(logits, labels, block_rows)


def _fwd(logits, labels, block_rows):
    return _xent_fwd_impl(logits, labels, block_rows), (logits, labels)


def _bwd(block_rows, res, g):
    logits, labels = res
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    return ((p - onehot) * g[:, None]).astype(logits.dtype), None


cross_entropy.defvjp(_fwd, _bwd)
