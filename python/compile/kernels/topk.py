"""Per-chunk top-k magnitude compression as a Pallas kernel.

DeMo keeps the k largest-magnitude DCT coefficients of each chunk. The GPU
reference uses ``torch.topk`` (a radix sort in shared memory). Two kernel
strategies are provided, both operating on the VMEM-resident coefficient
block:

  - ``method="itermax"`` (default): k iterative max-reductions, an O(k*m)
    VPU sweep with no sort at all — the natural TPU shape when k << m
    (avoids materializing sort keys), and also what the perf pass measured
    fastest end-to-end on the old-XLA CPU backend (239 ms vs 319 ms for
    the tiny config's full demo_compress; see EXPERIMENTS.md §Perf).
  - ``method="sort"``: one stable argsort of the block by descending
    magnitude, then slice the first k columns; kept for the ablation
    comparison and as the better shape for backends with fused sorts.

Semantics match ``ref.topk_compress`` for either method: values keep their
sign, indices are chunk-local, output ordered by descending magnitude with
ties broken by the lower index (stable sort == lax.top_k order).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_CHUNKS = 32


def _topk_sort_kernel(x_ref, vals_ref, idx_ref, *, k: int):
    x = x_ref[...]  # (bc, m)
    # Stable argsort of descending magnitude reproduces lax.top_k's
    # lower-index tie-break exactly.
    order = jnp.argsort(-jnp.abs(x), axis=-1, stable=True)[:, :k].astype(jnp.int32)
    vals_ref[...] = jnp.take_along_axis(x, order, axis=-1)
    idx_ref[...] = order


def _topk_itermax_kernel(x_ref, vals_ref, idx_ref, *, k: int):
    x = x_ref[...]  # (bc, m)
    bc, m = x.shape
    mag = jnp.abs(x)
    iota = jax.lax.broadcasted_iota(jnp.int32, (bc, m), 1)

    def body(j, carry):
        mag_c, vals, idx = carry
        best = jnp.argmax(mag_c, axis=-1).astype(jnp.int32)  # first max wins ties
        bestv = jnp.take_along_axis(x, best[:, None], axis=-1)[:, 0]
        vals = vals.at[:, j].set(bestv)
        idx = idx.at[:, j].set(best)
        # Knock the selected lane out for subsequent iterations.
        mag_c = jnp.where(iota == best[:, None], -jnp.inf, mag_c)
        return mag_c, vals, idx

    vals0 = jnp.zeros((bc, k), jnp.float32)
    idx0 = jnp.zeros((bc, k), jnp.int32)
    _, vals, idx = jax.lax.fori_loop(0, k, body, (mag, vals0, idx0))
    vals_ref[...] = vals
    idx_ref[...] = idx


_KERNELS = {"sort": _topk_sort_kernel, "itermax": _topk_itermax_kernel}


@functools.partial(jax.jit, static_argnames=("k", "block_chunks", "method"))
def topk_compress(
    coeffs: jax.Array,
    k: int,
    block_chunks: int = DEFAULT_BLOCK_CHUNKS,
    method: str = "itermax",
) -> tuple[jax.Array, jax.Array]:
    """Top-k by magnitude per chunk.

    Args:
      coeffs: (n_chunks, m) flattened per-chunk DCT coefficients, f32.
      k: coefficients kept per chunk (k <= m).
      method: "sort" (default) or "itermax" — see module docstring.

    Returns:
      (values (n_chunks, k) f32, indices (n_chunks, k) i32, chunk-local).
    """
    n, m = coeffs.shape
    assert 0 < k <= m, f"k={k} out of range for m={m}"
    bc = min(block_chunks, n)
    pad = 0
    if n % bc != 0:
        pad = bc - n % bc
        coeffs = jnp.concatenate([coeffs, jnp.zeros((pad, m), coeffs.dtype)], axis=0)
    grid = (coeffs.shape[0] // bc,)
    vals, idx = pl.pallas_call(
        functools.partial(_KERNELS[method], k=k),
        grid=grid,
        in_specs=[pl.BlockSpec((bc, m), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bc, k), lambda i: (i, 0)),
            pl.BlockSpec((bc, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((coeffs.shape[0], k), jnp.float32),
            jax.ShapeDtypeStruct((coeffs.shape[0], k), jnp.int32),
        ],
        interpret=True,
    )(coeffs.astype(jnp.float32))
    return vals[:n], idx[:n]
