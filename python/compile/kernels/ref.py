"""Pure-``jax.numpy`` oracles for every Layer-1 Pallas kernel.

These are the correctness ground truth: the pytest suite asserts each Pallas
kernel (run in interpret mode) matches its oracle to float32 tolerance, and
hypothesis sweeps shapes / chunk sizes / k against them.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.lru_cache(maxsize=8)
def dct_basis(c: int) -> np.ndarray:
    """Orthonormal DCT-II basis ``D`` of size (c, c): rows are frequencies.

    ``D @ D.T == I`` so the inverse transform is ``D.T @ Y @ D``.
    """
    n = np.arange(c, dtype=np.float64)
    j = n[:, None]
    d = np.cos(np.pi * (n[None, :] + 0.5) * j / c)
    d *= np.sqrt(2.0 / c)
    d[0, :] *= np.sqrt(0.5)
    return d.astype(np.float32)


def dct2(chunks: jax.Array) -> jax.Array:
    """2-D DCT-II of a batch of square chunks, shape (n, c, c)."""
    d = jnp.asarray(dct_basis(chunks.shape[-1]))
    return jnp.einsum("ij,njk,lk->nil", d, chunks, d, precision="highest")


def idct2(coeffs: jax.Array) -> jax.Array:
    """Inverse of :func:`dct2` (orthonormal, so the transpose basis)."""
    d = jnp.asarray(dct_basis(coeffs.shape[-1]))
    return jnp.einsum("ji,njk,kl->nil", d, coeffs, d, precision="highest")


def topk_compress(coeffs: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Per-chunk top-k by magnitude.

    Args:
      coeffs: (n_chunks, m) flattened DCT coefficients.
      k: number of coefficients kept per chunk.

    Returns:
      (values (n_chunks, k) f32, indices (n_chunks, k) i32) where indices are
      local to the chunk and values carry their original signs. Ordered by
      descending magnitude; ties broken by lower index (jax.lax.top_k order).
    """
    mag = jnp.abs(coeffs)
    _, idx = jax.lax.top_k(mag, k)
    vals = jnp.take_along_axis(coeffs, idx, axis=-1)
    return vals, idx.astype(jnp.int32)


def topk_decompress(vals: jax.Array, idx: jax.Array, m: int) -> jax.Array:
    """Scatter per-chunk (values, indices) back to dense (n_chunks, m)."""
    n = vals.shape[0]
    dense = jnp.zeros((n, m), dtype=vals.dtype)
    rows = jnp.broadcast_to(jnp.arange(n)[:, None], idx.shape)
    return dense.at[rows, idx].set(vals)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Per-row softmax cross-entropy. logits (r, v) f32, labels (r,) i32."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None].astype(jnp.int32), axis=-1)[:, 0]
    return lse - gold.astype(jnp.float32)


def cross_entropy_grad(logits: jax.Array, labels: jax.Array, g: jax.Array) -> jax.Array:
    """Analytic d(loss)/d(logits): ``g[:,None] * (softmax(logits) - onehot)``."""
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    return (p - onehot) * g[:, None]
