"""Layer-2 JAX model: llama-style transformer + DeMo ops over a flat ABI.

Everything the Rust coordinator executes is defined here as a pure function
over a **flat f32[P] parameter vector** (plus opaque optimizer state). The
flat ABI keeps the Rust <-> XLA boundary a fixed tuple of dense arrays;
unflattening into weight matrices happens inside the jitted function, where
XLA turns the dynamic-slices into zero-copy bitcasts.

Entry points lowered by :mod:`compile.aot` (one HLO artifact each):

  loss, grad           -- forward / forward+backward on one microbatch
  demo_compress        -- DeMo: error-feedback + chunked DCT + top-k
  apply_update         -- IDCT of aggregated coefficients, sign, SGD step
  eval_peer            -- fused Gauntlet primary evaluation (4 losses)
  adamw_step           -- centralized AdamW DDP baseline (Fig. 1 / Table 1)

The vocabulary cross-entropy and the DCT/top-k transform call the Layer-1
Pallas kernels in :mod:`compile.kernels`.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig
from .kernels import cross_entropy as xent_kernel
from .kernels import dct as dct_kernel
from .kernels import topk as topk_kernel

# --------------------------------------------------------------------------
# Parameter layout (the flat ABI)
# --------------------------------------------------------------------------


def param_specs(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Ordered (name, shape) pairs defining the flat parameter layout.

    The order is load-bearing: Rust reads the same list from meta.json to
    locate tensors inside the flat vector (e.g. for SyncScore sampling).
    """
    specs: list[tuple[str, tuple[int, ...]]] = [("embed", (cfg.vocab, cfg.d_model))]
    for l in range(cfg.n_layers):
        d, f = cfg.d_model, cfg.d_ff
        specs += [
            (f"l{l}.attn_norm", (d,)),
            (f"l{l}.wq", (d, d)),
            (f"l{l}.wk", (d, d)),
            (f"l{l}.wv", (d, d)),
            (f"l{l}.wo", (d, d)),
            (f"l{l}.mlp_norm", (d,)),
            (f"l{l}.w_gate", (d, f)),
            (f"l{l}.w_up", (d, f)),
            (f"l{l}.w_down", (f, d)),
        ]
    specs.append(("final_norm", (cfg.d_model,)))
    return specs


def param_count(cfg: ModelConfig) -> int:
    return sum(math.prod(s) for _, s in param_specs(cfg))


def unflatten(flat: jax.Array, cfg: ModelConfig) -> dict[str, jax.Array]:
    """Slice the flat vector into named weight tensors (bitcasts under XLA)."""
    out: dict[str, jax.Array] = {}
    off = 0
    for name, shape in param_specs(cfg):
        n = math.prod(shape)
        out[name] = jax.lax.dynamic_slice_in_dim(flat, off, n).reshape(shape)
        off += n
    return out


def init_params(cfg: ModelConfig, seed: int = 0) -> np.ndarray:
    """Deterministic initialization, returned as the flat f32[P] vector.

    GPT-2-style: N(0, 0.02) with the residual-output projections (wo,
    w_down) scaled down by 1/sqrt(2*n_layers); norms start at 1.
    """
    rng = np.random.default_rng(seed)
    resid_scale = 1.0 / math.sqrt(2 * cfg.n_layers)
    parts = []
    for name, shape in param_specs(cfg):
        if name.endswith("norm"):
            parts.append(np.ones(shape, np.float32))
            continue
        w = rng.normal(0.0, 0.02, size=shape).astype(np.float32)
        if name.endswith(".wo") or name.endswith(".w_down"):
            w *= resid_scale
        parts.append(w)
    return np.concatenate([p.reshape(-1) for p in parts])


# --------------------------------------------------------------------------
# Transformer forward
# --------------------------------------------------------------------------


def _rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


@functools.lru_cache(maxsize=8)
def _rope_tables(seq: int, head_dim: int) -> tuple[np.ndarray, np.ndarray]:
    half = head_dim // 2
    inv_freq = 1.0 / (10000.0 ** (np.arange(half, dtype=np.float64) / half))
    ang = np.arange(seq, dtype=np.float64)[:, None] * inv_freq[None, :]
    return np.cos(ang).astype(np.float32), np.sin(ang).astype(np.float32)


def _rope(x: jax.Array) -> jax.Array:
    """Rotate-half RoPE. x: (B, H, S, hd)."""
    s, hd = x.shape[-2], x.shape[-1]
    cos, sin = _rope_tables(s, hd)
    cos, sin = jnp.asarray(cos), jnp.asarray(sin)  # (S, hd/2)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def forward(params: dict[str, jax.Array], tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Logits (B, S, vocab) for input tokens (B, S) i32."""
    b, s = tokens.shape
    h, hd = cfg.n_heads, cfg.head_dim
    x = params["embed"][tokens]  # (B, S, d)
    mask = jnp.tril(jnp.ones((s, s), jnp.bool_))
    neg = jnp.float32(-1e9)
    for l in range(cfg.n_layers):
        p = lambda k: params[f"l{l}.{k}"]  # noqa: E731
        # --- attention ---
        y = _rmsnorm(x, p("attn_norm"))
        q = (y @ p("wq")).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
        k = (y @ p("wk")).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
        v = (y @ p("wv")).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
        q, k = _rope(q), _rope(k)
        att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(hd)
        att = jnp.where(mask, att, neg)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", att, v).transpose(0, 2, 1, 3).reshape(b, s, -1)
        x = x + o @ p("wo")
        # --- SwiGLU MLP ---
        y = _rmsnorm(x, p("mlp_norm"))
        x = x + (jax.nn.silu(y @ p("w_gate")) * (y @ p("w_up"))) @ p("w_down")
    x = _rmsnorm(x, params["final_norm"])
    return x @ params["embed"].T  # tied embeddings


def loss_fn(flat: jax.Array, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Mean next-token cross-entropy. tokens: (B, S+1) i32."""
    params = unflatten(flat, cfg)
    logits = forward(params, tokens[:, :-1], cfg)
    r = logits.shape[0] * logits.shape[1]
    per_row = xent_kernel.cross_entropy(
        logits.reshape(r, cfg.vocab), tokens[:, 1:].reshape(r).astype(jnp.int32)
    )
    return jnp.mean(per_row)


def loss_per_seq(flat: jax.Array, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Per-sequence mean next-token cross-entropy, f32[B].

    Used by the downstream evaluation harness (Table 1): multiple-choice
    candidates are scored by length-normalized logprob, one candidate per
    batch row.
    """
    params = unflatten(flat, cfg)
    logits = forward(params, tokens[:, :-1], cfg)
    b, s = logits.shape[0], logits.shape[1]
    per_row = xent_kernel.cross_entropy(
        logits.reshape(b * s, cfg.vocab), tokens[:, 1:].reshape(b * s).astype(jnp.int32)
    )
    return jnp.mean(per_row.reshape(b, s), axis=-1)


def grad_fn(flat: jax.Array, tokens: jax.Array, cfg: ModelConfig):
    """(loss, grad f32[P]) on one microbatch."""
    return jax.value_and_grad(loss_fn)(flat, tokens, cfg)


# --------------------------------------------------------------------------
# DeMo compression / decode / update (chunked DCT domain)
# --------------------------------------------------------------------------


def demo_dims(cfg: ModelConfig) -> tuple[int, int, int, int]:
    """(P, P_pad, n_chunks, C): flat length, padded length, chunk count and
    total transmitted coefficient count per pseudo-gradient."""
    p = param_count(cfg)
    m = cfg.chunk * cfg.chunk
    n_chunks = (p + m - 1) // m
    return p, n_chunks * m, n_chunks, n_chunks * cfg.topk


def _to_chunks(flat: jax.Array, cfg: ModelConfig) -> jax.Array:
    p, p_pad, n_chunks, _ = demo_dims(cfg)
    padded = jnp.concatenate([flat, jnp.zeros((p_pad - p,), flat.dtype)])
    return padded.reshape(n_chunks, cfg.chunk, cfg.chunk)


def _from_chunks(chunks: jax.Array, cfg: ModelConfig) -> jax.Array:
    p, _, _, _ = demo_dims(cfg)
    return chunks.reshape(-1)[:p]


def demo_compress(e: jax.Array, g: jax.Array, decay: jax.Array, cfg: ModelConfig):
    """One DeMo encode step (Algorithm 2, lines 2-8).

    e <- decay * e + g; q = DCT(chunk(e)); (vals, idx) = top-k(q);
    e <- e - IDCT(scatter(vals, idx)).

    Returns (vals f32[C], idx i32[C] with *global* coefficient indices
    chunk_id * chunk^2 + local, e' f32[P]).
    """
    _, _, n_chunks, _ = demo_dims(cfg)
    m = cfg.chunk * cfg.chunk
    e1 = decay * e + g
    q = dct_kernel.dct2(_to_chunks(e1, cfg))  # (n, c, c)
    vals, idx_local = topk_kernel.topk_compress(q.reshape(n_chunks, m), cfg.topk)
    idx_global = idx_local + (jnp.arange(n_chunks, dtype=jnp.int32) * m)[:, None]
    # Transmitted estimate, removed from the local error buffer.
    rows = jnp.broadcast_to(jnp.arange(n_chunks)[:, None], idx_local.shape)
    q_hat = jnp.zeros((n_chunks, m), jnp.float32).at[rows, idx_local].set(vals)
    e2 = e1 - _from_chunks(dct_kernel.idct2(q_hat.reshape(n_chunks, cfg.chunk, cfg.chunk)), cfg)
    return vals.reshape(-1), idx_global.reshape(-1), e2


def coeff_to_delta(coeff: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Dense DCT-coefficient vector f32[P_pad] -> parameter-space Delta f32[P]."""
    _, _, n_chunks, _ = demo_dims(cfg)
    return _from_chunks(
        dct_kernel.idct2(coeff.reshape(n_chunks, cfg.chunk, cfg.chunk)), cfg
    )


def apply_update(flat: jax.Array, coeff: jax.Array, lr: jax.Array, cfg: ModelConfig):
    """Signed descent (Algorithm 2 lines 15-16 + eq. 1): theta - lr*sign(IDCT(Q))."""
    delta = coeff_to_delta(coeff, cfg)
    return flat - lr * jnp.sign(delta)


def eval_peer(
    flat: jax.Array,
    coeff: jax.Array,
    beta: jax.Array,
    tok_assigned: jax.Array,
    tok_random: jax.Array,
    cfg: ModelConfig,
):
    """Fused Gauntlet primary evaluation (Algorithm 1, validator loop).

    Applies the peer's *signed* decoded pseudo-gradient with step beta and
    returns (L(theta, D_assigned), L(theta', D_assigned),
             L(theta, D_rand),     L(theta', D_rand)) so the validator can
    form LossScore on both data subsets from one artifact call.
    """
    theta_p = flat - beta * jnp.sign(coeff_to_delta(coeff, cfg))
    la0 = loss_fn(flat, tok_assigned, cfg)
    la1 = loss_fn(theta_p, tok_assigned, cfg)
    lr0 = loss_fn(flat, tok_random, cfg)
    lr1 = loss_fn(theta_p, tok_random, cfg)
    return la0, la1, lr0, lr1


# --------------------------------------------------------------------------
# Centralized AdamW baseline (the paper's Fig. 1 / Table 1 comparison)
# --------------------------------------------------------------------------


def adamw_step(
    flat: jax.Array,
    m: jax.Array,
    v: jax.Array,
    tokens: jax.Array,
    lr: jax.Array,
    t: jax.Array,
    cfg: ModelConfig,
):
    """One fused AdamW step on one (aggregated) batch.

    t is the 1-based step count as f32 (bias correction). Weight decay is
    decoupled. Returns (loss, theta', m', v').
    """
    loss, g = jax.value_and_grad(loss_fn)(flat, tokens, cfg)
    b1, b2 = cfg.adamw_beta1, cfg.adamw_beta2
    m1 = b1 * m + (1.0 - b1) * g
    v1 = b2 * v + (1.0 - b2) * jnp.square(g)
    mhat = m1 / (1.0 - jnp.power(b1, t))
    vhat = v1 / (1.0 - jnp.power(b2, t))
    upd = mhat / (jnp.sqrt(vhat) + cfg.adamw_eps) + cfg.adamw_wd * flat
    return loss, flat - lr * upd, m1, v1
