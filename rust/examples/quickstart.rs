//! Quickstart: the smallest end-to-end Gauntlet run, via the
//! `GauntletBuilder` front door.
//!
//! Registers four honest peers and one poisoner on the simulated chain and
//! runs ten communication rounds of incentivized DeMo training. With the
//! `nano` artifacts built (`python -m compile.aot --configs nano`) and the
//! native xla bindings this executes the compiled transformer (~30 s on
//! one CPU core); otherwise `GauntletBuilder::auto()` falls back to the
//! deterministic pure-Rust `SimExec` backend, so the example always runs
//! (<1 s).
//!
//!     cargo run --release --example quickstart

use gauntlet::bench::Table;
use gauntlet::coordinator::engine::GauntletBuilder;
use gauntlet::peers::Behavior;

fn main() -> anyhow::Result<()> {
    let mut engine = GauntletBuilder::auto()
        .model("nano")
        .rounds(10)
        .peers(vec![
            Behavior::Honest { data_mult: 1.0 },
            Behavior::Honest { data_mult: 1.0 },
            Behavior::Honest { data_mult: 2.0 }, // more data => should earn more
            Behavior::Honest { data_mult: 1.0 },
            Behavior::Poisoner { scale: 100.0 }, // should earn ~nothing
        ])
        .top_g(3)
        .eval_every(2)
        .build()?;

    println!(
        "quickstart: 5 peers, 10 rounds, top-G=3, model=nano, backend={}",
        engine.backend_name()
    );
    for r in 0..10 {
        let rec = engine.run_round()?;
        if let Some(l) = rec.heldout_loss {
            println!(
                "round {r:>2}: heldout loss {l:.4}, {} valid submissions, top-G {:?}",
                rec.n_valid_submissions, rec.top_g
            );
        }
    }

    let mut t = Table::new("who earned what", &["peer", "behaviour", "mu", "score", "TAO"]);
    let book = &engine.validators()[0].book;
    for p in engine.peers() {
        t.row(&[
            p.uid.to_string(),
            p.behavior.label(),
            format!("{:+.2}", book.get(p.uid).map(|s| s.mu.value).unwrap_or(0.0)),
            format!("{:.2}", book.peer_score(p.uid)),
            format!(
                "{:.3}",
                engine.chain().neuron(p.uid).map(|n| n.balance).unwrap_or(0.0)
            ),
        ]);
    }
    t.print();
    println!("\n(the poisoner's mu should be the lowest — Gauntlet at work)");
    Ok(())
}
