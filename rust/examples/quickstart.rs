//! Quickstart: the smallest end-to-end Gauntlet run.
//!
//! Registers four honest peers and one poisoner on the simulated chain and
//! runs ten communication rounds of incentivized DeMo training. With the
//! `nano` artifacts built (`python -m compile.aot --configs nano`) and the
//! native xla bindings this executes the compiled transformer (~30 s on
//! one CPU core); otherwise it falls back to the deterministic pure-Rust
//! `SimExec` backend, so the example always runs (<1 s).
//!
//!     cargo run --release --example quickstart

use gauntlet::bench::Table;
use gauntlet::coordinator::run::{RunConfig, TemplarRun, TemplarRunWith};
use gauntlet::peers::Behavior;
use gauntlet::runtime::ExecBackend;

fn main() -> anyhow::Result<()> {
    let peers = vec![
        Behavior::Honest { data_mult: 1.0 },
        Behavior::Honest { data_mult: 1.0 },
        Behavior::Honest { data_mult: 2.0 }, // more data => should earn more
        Behavior::Honest { data_mult: 1.0 },
        Behavior::Poisoner { scale: 100.0 }, // should earn ~nothing
    ];
    let mut cfg = RunConfig::quick("nano", 10, peers);
    cfg.params.top_g = 3;
    cfg.eval_every = 2;

    println!("quickstart: 5 peers, 10 rounds, top-G=3, model=nano");
    // Try the artifact-backed runtime; fall back to SimExec when artifacts
    // are missing OR the build uses the stub xla crate (see README
    // "Runtime backends").
    match TemplarRun::new(cfg.clone()) {
        Ok(run) => drive(run),
        Err(e) => {
            println!("(artifact backend unavailable — using the pure-Rust SimExec backend)");
            println!("  reason: {e:#}");
            drive(TemplarRunWith::new_sim(cfg)?)
        }
    }
}

fn drive<E: ExecBackend + 'static>(mut run: TemplarRunWith<E>) -> anyhow::Result<()> {
    for r in 0..10 {
        let rec = run.run_round()?;
        if let Some(l) = rec.heldout_loss {
            println!(
                "round {r:>2}: heldout loss {l:.4}, {} valid submissions, top-G {:?}",
                rec.n_valid_submissions, rec.top_g
            );
        }
    }

    let mut t = Table::new("who earned what", &["peer", "behaviour", "mu", "score", "TAO"]);
    let book = &run.validators[0].book;
    for p in &run.peers {
        t.row(&[
            p.uid.to_string(),
            p.behavior.label(),
            format!("{:+.2}", book.get(p.uid).map(|s| s.mu.value).unwrap_or(0.0)),
            format!("{:.2}", book.peer_score(p.uid)),
            format!("{:.3}", run.chain.neuron(p.uid).map(|n| n.balance).unwrap_or(0.0)),
        ]);
    }
    t.print();
    println!("\n(the poisoner's mu should be the lowest — Gauntlet at work)");
    Ok(())
}
