//! §4 reproduction: byzantine fault tolerance under the rescaling attack.
//!
//! Runs the same 5-honest + 1-rescaler(x1000) population twice:
//!   A) with the paper's encoded-domain normalization (Algorithm 2 line 12)
//!   B) with normalization disabled
//! and reports the training-loss damage the attacker causes in each case,
//! plus how quickly the incentive mechanism defunds it.
//!
//! Uses the `nano` artifacts when built, else the pure-Rust SimExec
//! backend (same protocol, synthetic model).
//!
//!     cargo run --release --example byzantine_gauntlet [rounds]

use gauntlet::bench::{sparkline, Table};
use gauntlet::peers::Behavior;

use gauntlet::coordinator::engine::GauntletBuilder;

fn losses(normalize: bool, rounds: u64) -> anyhow::Result<(Vec<f64>, f64, f64)> {
    // Artifact-backed when artifacts + native xla are available, else the
    // deterministic SimExec fallback (`auto`).
    let mut peers = vec![Behavior::Honest { data_mult: 1.0 }; 5];
    peers.push(Behavior::Rescaler { factor: 1000.0 });
    let mut run = GauntletBuilder::auto()
        .model("nano")
        .rounds(rounds)
        .peers(peers)
        .eval_every(2)
        .normalize(normalize)
        .build()?;
    let mut curve = Vec::new();
    let mut attacker_balance = 0.0;
    let mut honest_balance = 0.0;
    for _ in 0..rounds {
        let rec = run.run_round()?;
        if let Some(l) = rec.heldout_loss {
            curve.push(l);
        }
        if let Some(last) = rec.peers.iter().find(|p| p.label.starts_with("rescaler")) {
            attacker_balance = last.balance;
        }
        honest_balance = rec
            .peers
            .iter()
            .filter(|p| p.label == "honest")
            .map(|p| p.balance)
            .fold(0.0, f64::max);
    }
    Ok((curve, attacker_balance, honest_balance))
}

fn main() -> anyhow::Result<()> {
    let rounds: u64 =
        std::env::args().nth(1).map(|s| s.parse()).transpose()?.unwrap_or(16);

    println!("byzantine_gauntlet: 5 honest + 1 rescaler(x1000), {rounds} rounds each\n");

    let (on, att_on, hon_on) = losses(true, rounds)?;
    let (off, att_off, hon_off) = losses(false, rounds)?;

    println!("loss with normalization ON : {}  (end {:.4})", sparkline(&on, 40), on.last().unwrap());
    println!("loss with normalization OFF: {}  (end {:.4})", sparkline(&off, 40), off.last().unwrap());

    let mut t = Table::new(
        "§4 rescaling attack, with vs without encoded-domain normalization",
        &["config", "final heldout loss", "attacker TAO", "best honest TAO"],
    );
    t.row(&[
        "normalize ON (paper)".into(),
        format!("{:.4}", on.last().unwrap()),
        format!("{:.3}", att_on),
        format!("{:.3}", hon_on),
    ]);
    t.row(&[
        "normalize OFF".into(),
        format!("{:.4}", off.last().unwrap()),
        format!("{:.3}", att_off),
        format!("{:.3}", hon_off),
    ]);
    t.print();

    let damage = off.last().unwrap() - on.last().unwrap();
    println!(
        "\nattack damage without the defense: {damage:+.4} nats of final loss \
         (paper §4: normalization \"significantly reduced the impact of byzantine \
         peers while having no impact on convergence\")"
    );
    Ok(())
}
