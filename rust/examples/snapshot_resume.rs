//! Pause and resume a Gauntlet run — bit-identically.
//!
//! Drives the same mixed population twice: once straight through, and
//! once pausing at the halfway round, serializing the full run substrate
//! (chain slot table, validator score books + OpenSkill ratings, peer
//! error-feedback buffers and RNG streams, model parameters, scenario
//! cursor) to a JSON snapshot file, reloading it, and finishing. The two
//! runs must agree bit-for-bit — the engine prints both fingerprints.
//!
//! The same capability backs the CLI:
//!
//!     gauntlet run --rounds 3 --snapshot-out snap.json
//!     gauntlet run --resume snap.json --rounds 6
//!
//!     cargo run --release --example snapshot_resume [rounds]

use gauntlet::coordinator::engine::GauntletBuilder;
use gauntlet::coordinator::snapshot::RunSnapshot;
use gauntlet::peers::Behavior;
use gauntlet::scenario::Scenario;

fn population() -> Vec<Behavior> {
    vec![
        Behavior::Honest { data_mult: 1.0 },
        Behavior::Honest { data_mult: 2.0 },
        Behavior::Desync { at: 2, pause: 2 },
        Behavior::Poisoner { scale: 100.0 },
    ]
}

fn scenario() -> Scenario {
    // Churn on both sides of the pause point, so the resumed run proves
    // the scenario cursor and outage window travel with the snapshot.
    Scenario::parse("@1 join honest\n@2 outage 0.5 3\n@5 join freeloader").expect("scenario")
}

fn main() -> anyhow::Result<()> {
    let rounds: u64 = std::env::args().nth(1).map(|s| s.parse()).transpose()?.unwrap_or(8);
    let pause_at = rounds / 2;

    // ---- run A: straight through ---------------------------------------
    let mut straight = GauntletBuilder::sim()
        .model("nano")
        .rounds(rounds)
        .peers(population())
        .scenario(scenario())
        .seed(17)
        .build()?;
    straight.run()?;
    let fp_straight = straight.fingerprint();

    // ---- run B: pause at the boundary, snapshot to disk, resume --------
    let mut first_half = GauntletBuilder::sim()
        .model("nano")
        .rounds(rounds)
        .peers(population())
        .scenario(scenario())
        .seed(17)
        .build()?;
    for _ in 0..pause_at {
        first_half.run_round()?;
    }
    let path = std::env::temp_dir().join("gauntlet-snapshot-example.json");
    std::fs::write(&path, first_half.snapshot().to_json().write())?;
    drop(first_half); // only the file survives
    println!(
        "paused at round {pause_at}, snapshot written to {} ({} bytes)",
        path.display(),
        std::fs::metadata(&path)?.len()
    );

    let snap = RunSnapshot::parse(&std::fs::read_to_string(&path)?)?;
    let mut resumed = GauntletBuilder::sim().resume(snap).build()?;
    println!("resumed at round {}, continuing to {rounds}", resumed.round());
    resumed.run()?;
    let fp_resumed = resumed.fingerprint();
    std::fs::remove_file(&path).ok();

    // ---- the punchline --------------------------------------------------
    println!("\nstraight-run fingerprint:  {fp_straight:016x}");
    println!("paused+resumed fingerprint: {fp_resumed:016x}");
    anyhow::ensure!(
        fp_straight == fp_resumed,
        "fingerprints diverged — snapshot/resume broke bit-identity!"
    );
    println!("bit-identical: pausing was invisible to the run.");
    Ok(())
}
