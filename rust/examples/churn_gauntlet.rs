//! Permissionless churn: the population as a moving target.
//!
//! The paper's central claim is that Gauntlet needs "no control over the
//! users that can register" — so this example registers, evicts, and
//! re-registers users mid-run and watches the incentive mechanism keep
//! paying honest compute anyway. One validator plus a bounded 6-slot
//! chain (`max_uids`) hosts four honest peers and a poisoner; a scripted
//! scenario then churns it:
//!
//!   round 3  a fifth honest peer registers; the slot table is full, so
//!            the chain evicts the lowest-incentive non-immune neuron —
//!            the defunded poisoner — and recycles its uid,
//!   round 6  an honest peer walks away, freeing its uid,
//!   round 7  the poisoner's operator re-registers under a fresh hotkey
//!            and lands on the freed uid: a byzantine re-registration.
//!            The recycled uid starts from a fresh OpenSkill prior
//!            (no inherited penalty — and no inherited trust),
//!   round 9  a one-round provider outage drops ~30% of PUTs.
//!
//! Expected outcome: every honest hotkey earns TAO (including the round-3
//! joiner), both poisoner identities end with ~zero incentive, and the
//! re-registered poisoner is re-caught by proof-of-computation within a
//! few rounds of its fresh start.
//!
//!     cargo run --release --example churn_gauntlet [rounds]

use gauntlet::bench::Table;
use gauntlet::coordinator::engine::{GauntletBuilder, GauntletEngine};
use gauntlet::peers::Behavior;
use gauntlet::scenario::Scenario;

fn main() -> anyhow::Result<()> {
    let rounds: u64 = std::env::args().nth(1).map(|s| s.parse()).transpose()?.unwrap_or(14);

    let engine = GauntletBuilder::auto()
        .model("nano")
        .rounds(rounds)
        .peers(vec![
            Behavior::Honest { data_mult: 1.0 },
            Behavior::Honest { data_mult: 1.0 },
            Behavior::Honest { data_mult: 2.0 },
            Behavior::Honest { data_mult: 1.0 },
            Behavior::Poisoner { scale: 100.0 },
        ])
        .max_uids(6) // 1 validator + 5 peers: the table starts full
        .immunity_rounds(2)
        .eval_every(2)
        .eval_sample(8) // evaluate everyone: incentives move fast
        .scenario(Scenario::parse(
            "# churn wave (see module docs)\n\
             @3 join honest\n\
             @6 leave 2\n\
             @7 join poisoner\n\
             @9 outage 0.3 1\n",
        )?)
        .build()?;

    println!(
        "churn_gauntlet: 6-slot chain, 4 honest + 1 poisoner, {rounds} rounds of \
         scripted churn (backend={})\n",
        engine.backend_name()
    );
    drive(engine)
}

fn drive(mut run: GauntletEngine) -> anyhow::Result<()> {
    let rounds = run.cfg().rounds;
    for r in 0..rounds {
        let rec = run.run_round()?;
        for e in &rec.events {
            println!("round {r:>3}  ** {e}");
        }
        if let Some(l) = rec.heldout_loss {
            println!(
                "round {r:>3}  heldout={l:.4}  valid={}  population={}",
                rec.n_valid_submissions,
                rec.peers.len()
            );
        }
    }

    let mut t = Table::new(
        "final population (uids recycle; hotkeys are identities)",
        &["uid", "hotkey", "behaviour", "mu", "score", "TAO"],
    );
    let book = &run.validators()[0].book;
    let mut honest_min = f64::INFINITY;
    let mut poisoner_max: f64 = 0.0;
    for p in run.peers() {
        let n = run.chain().neuron(p.uid).expect("active peer is registered");
        if p.behavior.label().starts_with("honest") {
            honest_min = honest_min.min(n.balance);
        } else {
            poisoner_max = poisoner_max.max(n.balance);
        }
        t.row(&[
            p.uid.to_string(),
            n.hotkey.clone(),
            p.behavior.label(),
            book.get(p.uid).map(|s| format!("{:+.2}", s.mu.value)).unwrap_or_default(),
            format!("{:.2}", book.peer_score(p.uid)),
            format!("{:.3}", n.balance),
        ]);
    }
    t.print();

    println!(
        "\nleast-earning honest survivor: {honest_min:.3} TAO; \
         best byzantine identity: {poisoner_max:.3} TAO"
    );
    println!(
        "(the round-3 joiner earned on a recycled uid with a fresh rating, and the \
         re-registered poisoner was re-defunded from its fresh prior — permissionless \
         churn, same incentives)"
    );
    Ok(())
}
