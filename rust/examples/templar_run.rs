//! Flagship end-to-end run — the repo's §6 "Results" reproduction.
//!
//! Trains a transformer with the full permissionless stack (chain, cloud
//! storage, heterogeneous honest + adversarial peers, Gauntlet validator,
//! DeMo aggregation) and, side by side, the centralized AdamW-DDP baseline
//! on the same token budget per round. Ends with the Table-1-style
//! downstream evaluation of both checkpoints.
//!
//!     cargo run --release --example templar_run [model] [rounds]
//!
//! Defaults: model=tiny rounds=60 (~15 min on one CPU core against the
//! compiled artifacts; seconds on the SimExec fallback used when the
//! artifacts are not built). The run used for EXPERIMENTS.md §Fig.1 is
//! `templar_run small 150`.

// An example is edge code (like the bench module): it times whole runs
// for the console report, so the clippy disallowed-methods tier (which
// guards the round path against wall-clock reads) is opted out here.
#![allow(clippy::disallowed_methods)]

use gauntlet::bench::{save_json, series_json, sparkline, Table};
use gauntlet::coordinator::baseline::{AdamWParams, AdamWTrainer};
use gauntlet::coordinator::engine::{GauntletBuilder, GauntletEngine};
use gauntlet::data::Corpus;
use gauntlet::eval::{evaluate_suite, Suite};
use gauntlet::minjson;
use gauntlet::peers::Behavior;
use gauntlet::runtime::ExecBackend;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(String::as_str).unwrap_or("tiny").to_string();
    let rounds: u64 = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(60);

    // The paper's live population in miniature: mostly honest peers with
    // heterogeneous data throughput, plus one of each adversary class.
    let peers = vec![
        Behavior::Honest { data_mult: 1.0 },
        Behavior::Honest { data_mult: 1.0 },
        Behavior::Honest { data_mult: 2.0 },
        Behavior::Honest { data_mult: 1.5 },
        Behavior::Honest { data_mult: 1.0 },
        Behavior::Desync { at: rounds / 4, pause: 3 },
        Behavior::Freeloader,
        Behavior::Poisoner { scale: 100.0 },
    ];

    // Artifact-backed when available, SimExec fallback otherwise (`auto`).
    let mut engine = GauntletBuilder::auto()
        .model(&model)
        .rounds(rounds)
        .peers(peers)
        .top_g(4)
        .eval_sample(3)
        .eval_every(5)
        .build()?;
    let cfg = engine.cfg();
    println!(
        "templar_run: model={model} backend={} rounds={rounds} peers={} (top-G={}, S={}, threads={})",
        engine.backend_name(),
        engine.peers().len(),
        cfg.params.top_g,
        cfg.params.eval_sample,
        cfg.effective_threads(),
    );

    // ---------------- Gauntlet permissionless run -----------------------
    let t0 = std::time::Instant::now();
    let mut gauntlet_curve: Vec<(f64, f64)> = Vec::new();
    for r in 0..rounds {
        let rec = engine.run_round()?;
        if let Some(l) = rec.heldout_loss {
            gauntlet_curve.push((r as f64, l));
            println!(
                "  [gauntlet] round {r:>4}  heldout={l:.4}  local={:.4}  topG={:?}",
                rec.mean_local_loss, rec.top_g
            );
        }
    }
    let gauntlet_time = t0.elapsed();
    let theta_gauntlet = engine.theta().to_vec();

    // The baseline + downstream eval reuse the engine's own backend.
    let (adamw_curve, adamw_time, table1) = match &engine {
        GauntletEngine::Sim(run) => baseline_and_eval(&run.exec, &theta_gauntlet, rounds)?,
        GauntletEngine::Artifact(run) => baseline_and_eval(&run.exec, &theta_gauntlet, rounds)?,
    };

    // ---------------- Fig. 1 style summary ------------------------------
    let gl: Vec<f64> = gauntlet_curve.iter().map(|(_, y)| *y).collect();
    let al: Vec<f64> = adamw_curve.iter().map(|(_, y)| *y).collect();
    println!("\nFig.1 — loss curves ({rounds} rounds)");
    println!("  gauntlet {}  ({:.4} -> {:.4})", sparkline(&gl, 50), gl[0], gl[gl.len() - 1]);
    println!("  adamw    {}  ({:.4} -> {:.4})", sparkline(&al, 50), al[0], al[al.len() - 1]);
    save_json(
        &format!("templar_run_{model}"),
        &minjson::obj(vec![
            ("gauntlet", series_json(&gauntlet_curve)),
            ("adamw", series_json(&adamw_curve)),
        ]),
    );

    // ---------------- final standings ------------------------------------
    let mut t = Table::new(
        "final standings (permissionless run)",
        &["uid", "behaviour", "mu", "rating", "score", "TAO earned"],
    );
    let book = &engine.validators()[0].book;
    for p in engine.peers() {
        let st = book.get(p.uid);
        t.row(&[
            p.uid.to_string(),
            p.behavior.label(),
            st.map(|s| format!("{:+.3}", s.mu.value)).unwrap_or_default(),
            st.map(|s| format!("{:.2}", s.rating.mu)).unwrap_or_default(),
            format!("{:.3}", book.peer_score(p.uid)),
            format!(
                "{:.3}",
                engine.chain().neuron(p.uid).map(|n| n.balance).unwrap_or(0.0)
            ),
        ]);
    }
    t.print();
    table1.print();

    println!(
        "\nwall-clock: gauntlet {:.1}s, adamw {:.1}s; checkpoints: {} full + {} signed updates ({} KiB of signs)",
        gauntlet_time.as_secs_f64(),
        adamw_time.as_secs_f64(),
        engine.checkpoints().n_checkpoints(),
        engine.checkpoints().n_updates(),
        engine.checkpoints().sign_bytes() / 1024,
    );
    Ok(())
}

/// Run the AdamW-DDP baseline on `exec` and evaluate both checkpoints on
/// the Table-1 synthetic suites. Returns the baseline loss curve, its
/// wall-clock, and the print-ready table.
fn baseline_and_eval<E: ExecBackend>(
    exec: &E,
    theta_gauntlet: &[f32],
    rounds: u64,
) -> anyhow::Result<(Vec<(f64, f64)>, std::time::Duration, Table)> {
    let n_honest_equiv = 5; // AdamW baseline worker count (same order of tokens/round)
    let corpus = Corpus::new(exec.meta().vocab as u32, 0);
    let mut trainer =
        AdamWTrainer::new(exec.init_params()?, AdamWParams::default(), n_honest_equiv);
    let mut adamw_curve: Vec<(f64, f64)> = Vec::new();
    let t1 = std::time::Instant::now();
    for r in 0..rounds {
        trainer.step(exec, &corpus, r)?;
        if r % 5 == 0 {
            let toks = corpus.heldout(0, exec.meta().batch, exec.meta().seq + 1);
            let l = exec.loss(&trainer.theta, &toks)? as f64;
            adamw_curve.push((r as f64, l));
            println!("  [adamw]    round {r:>4}  heldout={l:.4}");
        }
    }
    let adamw_time = t1.elapsed();

    let mut t1tab = Table::new(
        "Table 1 — downstream acc_norm (synthetic suites)",
        &["model", "synth-hellaswag", "synth-piqa", "synth-arc-e"],
    );
    for (name, theta) in [("TEMPLAR (gauntlet)", theta_gauntlet), ("AdamW DDP", &trainer.theta)]
    {
        let mut cells = vec![name.to_string()];
        for suite in Suite::all() {
            let r = evaluate_suite(exec, theta, &corpus, suite, 40)?;
            cells.push(format!("{:.3}", r.acc_norm));
        }
        t1tab.row(&cells);
    }
    Ok((adamw_curve, adamw_time, t1tab))
}
