//! Fig. 2 reproduction: "Simulating LossRating".
//!
//! Three peers — one processing 2x data, one desynchronized (pauses for 3
//! rounds, then continues from the stale model), one baseline — are
//! primary-evaluated **every** round (S = K, as in the paper's controlled
//! simulation) and their LossScore / LossRating trajectories printed.
//!
//! Expected shapes (paper Fig. 2): LossScore is noisy round-to-round but
//! the 2x-data peer's rating pulls ahead while the desynchronized peer's
//! rating collapses after its pause.
//!
//! Uses the `nano` artifacts when built, else the pure-Rust SimExec
//! backend.
//!
//!     cargo run --release --example rating_sim [rounds]

use gauntlet::bench::{save_json, sparkline, Table};
use gauntlet::coordinator::engine::{GauntletBuilder, GauntletEngine};
use gauntlet::minjson::{self, Value};
use gauntlet::peers::Behavior;

fn main() -> anyhow::Result<()> {
    let rounds: u64 =
        std::env::args().nth(1).map(|s| s.parse()).transpose()?.unwrap_or(30);
    let desync_at = 5;

    let run = GauntletBuilder::auto()
        .model("nano")
        .rounds(rounds)
        .peers(vec![
            Behavior::Honest { data_mult: 2.0 },          // uid 1: more data
            Behavior::Desync { at: desync_at, pause: 3 }, // uid 2: desynchronized
            Behavior::Honest { data_mult: 1.0 },          // uid 3: baseline
        ])
        .eval_sample(3) // S = K: evaluate everyone, like the paper's sim
        .top_g(3)
        .eval_every(0)
        .build()?;

    println!(
        "rating_sim: 3 peers (2x-data / desync@{desync_at} / baseline), {rounds} rounds \
         (backend={})\n",
        run.backend_name()
    );
    drive(run, rounds)
}

fn drive(mut run: GauntletEngine, rounds: u64) -> anyhow::Result<()> {
    let mut series: Vec<(u64, Vec<(String, Option<f64>, f64, f64)>)> = Vec::new();
    for _ in 0..rounds {
        let rec = run.run_round()?;
        let row: Vec<(String, Option<f64>, f64, f64)> = rec
            .peers
            .iter()
            .map(|p| (p.label.clone(), p.loss_score_rand, p.rating_mu, p.mu))
            .collect();
        series.push((rec.round, row));
    }

    // ---- print the trajectories ----------------------------------------
    let mut t = Table::new(
        "LossScore (rand) and LossRating per round",
        &["round", "2x-data score", "desync score", "base score", "2x rating", "desync rating", "base rating"],
    );
    for (round, row) in &series {
        let f = |o: &Option<f64>| o.map(|v| format!("{v:+.4}")).unwrap_or_else(|| "--".into());
        t.row(&[
            round.to_string(),
            f(&row[0].1),
            f(&row[1].1),
            f(&row[2].1),
            format!("{:.2}", row[0].2),
            format!("{:.2}", row[1].2),
            format!("{:.2}", row[2].2),
        ]);
    }
    t.print();

    let rating_series = |i: usize| -> Vec<f64> { series.iter().map(|(_, r)| r[i].2).collect() };
    println!("\nrating trajectories:");
    println!("  2x-data {}", sparkline(&rating_series(0), 50));
    println!("  desync  {}", sparkline(&rating_series(1), 50));
    println!("  base    {}", sparkline(&rating_series(2), 50));

    let final_row = &series.last().unwrap().1;
    println!(
        "\nfinal ratings: 2x-data={:.2}  desync={:.2}  baseline={:.2}",
        final_row[0].2, final_row[1].2, final_row[2].2
    );
    if final_row[0].2 > final_row[2].2 && final_row[1].2 < final_row[2].2 {
        println!("=> matches the paper's Fig. 2: more data wins, desync collapses");
    } else {
        println!("=> WARNING: ordering deviates from the paper's Fig. 2 shape");
    }

    save_json(
        "rating_sim",
        &minjson::obj(vec![(
            "rounds",
            Value::Arr(
                series
                    .iter()
                    .map(|(round, row)| {
                        minjson::obj(vec![
                            ("round", minjson::num(*round as f64)),
                            (
                                "peers",
                                Value::Arr(
                                    row.iter()
                                        .map(|(label, score, rating, mu)| {
                                            minjson::obj(vec![
                                                ("label", minjson::s(label)),
                                                (
                                                    "loss_score",
                                                    score.map(minjson::num).unwrap_or(Value::Null),
                                                ),
                                                ("rating", minjson::num(*rating)),
                                                ("mu", minjson::num(*mu)),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        )]),
    );
    Ok(())
}
