//! Dependency-free stand-in for the `xla-rs` PJRT bindings.
//!
//! The gauntlet runtime (`gauntlet::runtime::Executor`) drives XLA through
//! exactly the API surface reproduced here: a CPU [`PjRtClient`], HLO-text
//! parsing into an [`XlaComputation`], compilation to a
//! [`PjRtLoadedExecutable`], and host<->device [`Literal`] plumbing.
//!
//! This crate implements the *host* side for real — typed literals,
//! reshapes, tuple unpacking — so everything that doesn't execute HLO
//! compiles and unit-tests without native XLA. The *device* side
//! ([`HloModuleProto::from_text_file`], [`PjRtClient::compile`],
//! [`PjRtLoadedExecutable::execute`]) returns a descriptive [`Error`]:
//! swap this path dependency for the real bindings to run compiled
//! artifacts (the `gauntlet` README's "Runtime backends" section walks
//! through it). Simulation workloads that don't need XLA use
//! `gauntlet::runtime::SimExec` instead and never hit this boundary.

use std::fmt;

/// Error type mirroring the bindings' stringly-typed errors.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what} requires the native XLA/PJRT backend, but this build uses \
         the dependency-free `xla` stub crate; swap rust/xla for the real \
         bindings to execute HLO artifacts, or use the SimExec backend"
    )))
}

/// Element storage for a [`Literal`]: the two dtypes the artifacts use,
/// plus tuples (artifacts are lowered with `return_tuple=True`).
#[derive(Clone, Debug, PartialEq)]
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host-side typed array, the unit of transfer to and from the device.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: LiteralData,
    dims: Vec<i64>,
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
}

/// Scalar types the artifacts' ABI uses (`f32` parameters/losses, `i32`
/// tokens/indices).
pub trait NativeType: sealed::Sealed + Copy {
    fn wrap(v: Vec<Self>) -> LiteralData;
    fn slice(data: &LiteralData) -> Option<&[Self]>;
    const NAME: &'static str;
}

impl NativeType for f32 {
    fn wrap(v: Vec<Self>) -> LiteralData {
        LiteralData::F32(v)
    }
    fn slice(data: &LiteralData) -> Option<&[Self]> {
        match data {
            LiteralData::F32(v) => Some(v),
            _ => None,
        }
    }
    const NAME: &'static str = "f32";
}

impl NativeType for i32 {
    fn wrap(v: Vec<Self>) -> LiteralData {
        LiteralData::I32(v)
    }
    fn slice(data: &LiteralData) -> Option<&[Self]> {
        match data {
            LiteralData::I32(v) => Some(v),
            _ => None,
        }
    }
    const NAME: &'static str = "i32";
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64], data: T::wrap(v.to_vec()) }
    }

    /// Rank-0 f32 literal.
    pub fn scalar(v: f32) -> Literal {
        Literal { dims: vec![], data: LiteralData::F32(vec![v]) }
    }

    /// Tuple literal (what `return_tuple=True` artifacts produce).
    pub fn tuple(items: Vec<Literal>) -> Literal {
        Literal { dims: vec![], data: LiteralData::Tuple(items) }
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
            LiteralData::Tuple(v) => v.len(),
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Reinterpret the buffer with new dimensions (element count must
    /// match, like `Literal::reshape` in the bindings).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        if matches!(self.data, LiteralData::Tuple(_)) {
            return Err(Error("cannot reshape a tuple literal".to_string()));
        }
        let want: i64 = dims.iter().product();
        let have = self.element_count() as i64;
        if want != have {
            return Err(Error(format!(
                "reshape to {dims:?} ({want} elements) from {have} elements"
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Copy out as a host vector of `T`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match T::slice(&self.data) {
            Some(s) => Ok(s.to_vec()),
            None => Err(Error(format!("literal does not hold {}", T::NAME))),
        }
    }

    /// First element (scalar reads).
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        let s = T::slice(&self.data)
            .ok_or_else(|| Error(format!("literal does not hold {}", T::NAME)))?;
        s.first().copied().ok_or_else(|| Error("empty literal".to_string()))
    }

    /// Unpack a tuple literal into its members.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.data {
            LiteralData::Tuple(items) => Ok(items.clone()),
            _ => Err(Error("literal is not a tuple".to_string())),
        }
    }
}

/// Parsed HLO module (opaque; parsing needs the native toolchain).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("parsing HLO text")
    }
}

/// An XLA computation ready for compilation.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client handle. Creation succeeds (it allocates nothing here) so
/// callers fail at the first operation that actually needs the backend,
/// with a message naming that operation.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compiling an XLA computation")
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with one argument list on one device; the bindings return
    /// per-device, per-output buffers.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("executing a compiled artifact")
    }
}

/// Device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("device-to-host transfer")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32_and_i32() {
        let l = Literal::vec1(&[1.0f32, -2.5]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, -2.5]);
        assert_eq!(l.get_first_element::<f32>().unwrap(), 1.0);
        assert!(l.to_vec::<i32>().is_err(), "dtype mismatch must error");

        let t = Literal::vec1(&[7i32, 8, 9]);
        assert_eq!(t.to_vec::<i32>().unwrap(), vec![7, 8, 9]);
        assert_eq!(t.dims(), &[3]);
    }

    #[test]
    fn reshape_checks_element_count() {
        let l = Literal::vec1(&[0i32; 6]);
        assert_eq!(l.reshape(&[2, 3]).unwrap().dims(), &[2, 3]);
        assert!(l.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn tuple_unpacks() {
        let t = Literal::tuple(vec![Literal::scalar(1.0), Literal::vec1(&[2i32])]);
        let items = t.to_tuple().unwrap();
        assert_eq!(items.len(), 2);
        assert_eq!(items[0].get_first_element::<f32>().unwrap(), 1.0);
        assert!(Literal::scalar(0.0).to_tuple().is_err());
    }

    #[test]
    fn device_path_reports_stub() {
        let err = HloModuleProto::from_text_file("x.hlo.txt").err().unwrap();
        assert!(err.to_string().contains("stub"), "{err}");
        let client = PjRtClient::cpu().unwrap();
        assert!(client.compile(&XlaComputation).is_err());
        assert!(PjRtLoadedExecutable.execute::<Literal>(&[]).is_err());
        assert!(PjRtBuffer.to_literal_sync().is_err());
    }
}
