//! Integration tests over the real PJRT runtime and the full system loop.
//!
//! These need `make artifacts` (nano config) and skip gracefully when it
//! hasn't run. Each test creates its own `Executor` (PJRT CPU clients are
//! cheap at this scale).
//!
//! Deliberately uses the legacy `RunConfig::quick` / `TemplarRun::new`
//! shims: during the GauntletBuilder transition these must keep working
//! verbatim, and this file is their coverage.
#![allow(deprecated)]

use gauntlet::coordinator::run::{RunConfig, TemplarRun};
use gauntlet::coordinator::GauntletParams;
use gauntlet::data::Corpus;
use gauntlet::demo::SparseGrad;
use gauntlet::eval::{evaluate_suite, Suite};
use gauntlet::peers::Behavior;
use gauntlet::runtime::{artifact_dir, artifacts_available, Executor};

macro_rules! require_artifacts {
    () => {
        if !artifacts_available("nano") {
            eprintln!("skipping: nano artifacts not built (run `make artifacts`)");
            return;
        }
    };
}

fn exec() -> Executor {
    Executor::load(artifact_dir("nano")).expect("load nano artifacts")
}

fn tokens(exec: &Executor, seed: u64) -> Vec<i32> {
    let corpus = Corpus::new(exec.meta.vocab as u32, seed);
    corpus.assigned_shard(1, 0, 0, exec.meta.batch, exec.meta.seq + 1)
}

// ---------------------------------------------------------------- runtime

#[test]
fn loss_is_deterministic_and_near_log_vocab() {
    require_artifacts!();
    let e = exec();
    let theta = e.init_params().unwrap();
    let toks = tokens(&e, 0);
    let l1 = e.loss(&theta, &toks).unwrap();
    let l2 = e.loss(&theta, &toks).unwrap();
    assert_eq!(l1, l2, "same inputs, same loss");
    let expect = (e.meta.vocab as f32).ln();
    assert!((l1 - expect).abs() < 0.5, "init loss {l1} vs ln(V)={expect}");
}

#[test]
fn grad_decreases_loss_along_negative_direction() {
    require_artifacts!();
    let e = exec();
    let theta = e.init_params().unwrap();
    let toks = tokens(&e, 0);
    let (l0, g) = e.grad(&theta, &toks).unwrap();
    let stepped: Vec<f32> = theta.iter().zip(&g).map(|(t, gi)| t - 0.5 * gi).collect();
    let l1 = e.loss(&stepped, &toks).unwrap();
    assert!(l1 < l0 - 0.05, "sgd step should reduce loss: {l0} -> {l1}");
}

#[test]
fn loss_per_seq_mean_matches_batch_loss() {
    require_artifacts!();
    let e = exec();
    let theta = e.init_params().unwrap();
    let toks = tokens(&e, 3);
    let batch = e.loss(&theta, &toks).unwrap();
    let per_seq = e.loss_per_seq(&theta, &toks).unwrap();
    assert_eq!(per_seq.len(), e.meta.batch);
    let mean: f32 = per_seq.iter().sum::<f32>() / per_seq.len() as f32;
    assert!((mean - batch).abs() < 1e-3, "{mean} vs {batch}");
}

#[test]
fn demo_compress_respects_error_feedback_identity() {
    require_artifacts!();
    let e = exec();
    let meta = &e.meta;
    let theta = e.init_params().unwrap();
    let toks = tokens(&e, 1);
    let (_, g) = e.grad(&theta, &toks).unwrap();
    let err = vec![0.0f32; meta.param_count];
    let (vals, idx, e2) = e.demo_compress(&err, &g, 0.0).unwrap();

    assert_eq!(vals.len(), meta.coeff_count);
    assert_eq!(idx.len(), meta.coeff_count);
    // indices: one stripe of k per chunk
    let m = (meta.chunk * meta.chunk) as i32;
    for (j, &i) in idx.iter().enumerate() {
        let chunk = j / meta.topk;
        assert!(i >= chunk as i32 * m && i < (chunk as i32 + 1) * m, "idx stripe at {j}");
    }
    // residual energy strictly below input energy (top-k removed something)
    let gn: f64 = g.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
    let en: f64 = e2.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
    assert!(en < gn, "residual {en} !< input {gn}");
    assert!(en > 0.0, "compression at this k cannot be lossless");
}

#[test]
fn apply_update_is_exactly_one_signed_step() {
    require_artifacts!();
    let e = exec();
    let meta = &e.meta;
    let theta = e.init_params().unwrap();
    let mut coeff = vec![0.0f32; meta.padded_count];
    // touch only chunk 0: a few coefficients
    coeff[0] = 1.0;
    coeff[5] = -2.0;
    let lr = 0.02f32;
    let theta2 = e.apply_update(&theta, &coeff, lr).unwrap();
    let mut n_moved = 0;
    for (a, b) in theta.iter().zip(&theta2) {
        let d = (a - b).abs();
        assert!(d < 1e-6 || (d - lr).abs() < 1e-6, "step must be 0 or ±lr, got {d}");
        if d > 1e-6 {
            n_moved += 1;
        }
    }
    // IDCT of chunk-0 coefficients moves (at most) the first chunk^2 params
    assert!(n_moved > 0 && n_moved <= meta.chunk * meta.chunk, "moved {n_moved}");
}

#[test]
fn eval_peer_matches_separate_loss_calls() {
    require_artifacts!();
    let e = exec();
    let meta = &e.meta;
    let theta = e.init_params().unwrap();
    let toks_a = tokens(&e, 10);
    let toks_r = tokens(&e, 11);
    // a plausible pseudo-gradient
    let (_, g) = e.grad(&theta, &toks_a).unwrap();
    let err = vec![0.0f32; meta.param_count];
    let (vals, idx, _) = e.demo_compress(&err, &g, 0.999).unwrap();
    let sg = SparseGrad { vals, idx };
    let mut coeff = vec![0.0f32; meta.padded_count];
    let n = sg.l2_norm();
    sg.scatter_into(&mut coeff, (1.0 / n) as f32);

    let beta = 0.01f32;
    let (la0, la1, lr0, lr1) = e.eval_peer(&theta, &coeff, beta, &toks_a, &toks_r).unwrap();
    assert!((la0 - e.loss(&theta, &toks_a).unwrap()).abs() < 1e-4);
    assert!((lr0 - e.loss(&theta, &toks_r).unwrap()).abs() < 1e-4);
    // gradient came from toks_a: the step must reduce loss on both subsets
    // at this (small) beta, and the assigned-data drop should be real
    assert!(la1 < la0, "loss on assigned data must drop: {la0} -> {la1}");
    assert!(lr1.is_finite());
}

#[test]
fn adamw_artifact_matches_host_adamw() {
    require_artifacts!();
    use gauntlet::coordinator::baseline::{AdamWParams, AdamWTrainer};
    let e = exec();
    let theta = e.init_params().unwrap();
    let toks = tokens(&e, 5);
    let z = vec![0.0f32; theta.len()];
    let (_, th_x, _, _) = e.adamw_step(&theta, &z, &z, &toks, 3e-4, 1.0).unwrap();

    let (_, g) = e.grad(&theta, &toks).unwrap();
    let mut host = AdamWTrainer::new(theta.clone(), AdamWParams::default(), 1);
    host.apply(&g);
    let mut max_d = 0.0f32;
    for (a, b) in th_x.iter().zip(&host.theta) {
        max_d = max_d.max((a - b).abs());
    }
    assert!(max_d < 1e-5, "artifact vs host AdamW diverged by {max_d}");
}

// ----------------------------------------------------------- full system

fn quick_cfg(rounds: u64, peers: Vec<Behavior>) -> RunConfig {
    let mut cfg = RunConfig::quick("nano", rounds, peers);
    cfg.eval_every = 0; // keep tests fast
    cfg.params = GauntletParams { top_g: 3, eval_sample: 3, lr: 0.0, ..Default::default() };
    cfg
}

#[test]
fn templar_run_trains_and_is_deterministic_in_structure() {
    require_artifacts!();
    let peers = vec![Behavior::Honest { data_mult: 1.0 }; 4];
    let mut run = TemplarRun::new(quick_cfg(3, peers)).unwrap();
    let t0 = run.theta.clone();
    for _ in 0..3 {
        let rec = run.run_round().unwrap();
        assert_eq!(rec.peers.len(), 4);
        assert!(rec.n_valid_submissions >= 3, "honest peers should submit validly");
    }
    assert_ne!(t0, run.theta, "aggregated updates must move the model");
    // chain emitted 3 epochs of incentives
    let paid: f64 = run.peer_uids().iter().map(|u| run.chain.neuron(*u).unwrap().balance).sum();
    assert!(paid > 0.0, "someone must get paid");
}

#[test]
fn checkpoint_catchup_matches_live_state() {
    require_artifacts!();
    let peers = vec![Behavior::Honest { data_mult: 1.0 }; 3];
    let mut cfg = quick_cfg(5, peers);
    cfg.params.checkpoint_every = 2;
    let mut run = TemplarRun::new(cfg).unwrap();
    let mut states = vec![run.theta.clone()];
    for _ in 0..5 {
        run.run_round().unwrap();
        states.push(run.theta.clone());
    }
    // a late joiner reconstructing the state at the start of each round
    for round in 0..=5u64 {
        let got = run.checkpoints.catchup(round).expect("catchup state");
        let want = &states[round as usize];
        for (g, w) in got.iter().zip(want) {
            assert!((g - w).abs() < 1e-5, "catchup mismatch at round {round}");
        }
    }
}

#[test]
fn format_violator_and_silent_peers_fail_fast_eval() {
    require_artifacts!();
    let peers = vec![
        Behavior::Honest { data_mult: 1.0 },
        Behavior::Honest { data_mult: 1.0 },
        Behavior::FormatViolator,
        Behavior::Silent { prob: 1.0 },
    ];
    let mut run = TemplarRun::new(quick_cfg(2, peers)).unwrap();
    let uids = run.peer_uids();
    for _ in 0..2 {
        let rec = run.run_round().unwrap();
        let by_uid = |u| rec.peers.iter().find(|p| p.uid == u).unwrap();
        assert!(by_uid(uids[0]).fast_pass);
        assert!(!by_uid(uids[2]).fast_pass, "format violator must fail");
        assert!(!by_uid(uids[3]).fast_pass, "silent peer must fail");
    }
    // repeated failures push mu to (or below) zero via phi
    let book = &run.validators[0].book;
    let v = book.get(uids[2]).unwrap();
    assert!(v.fast_fails >= 2);
}

#[test]
fn incentives_favor_honest_over_poisoner_and_copier() {
    require_artifacts!();
    let peers = vec![
        Behavior::Honest { data_mult: 1.0 },
        Behavior::Honest { data_mult: 1.0 },
        Behavior::Honest { data_mult: 1.0 },
        Behavior::Poisoner { scale: 100.0 },
        Behavior::Copier { victim: 1 }, // uid 1 = validator-0? no: peers get uids after the validator; victim set below
    ];
    let mut cfg = quick_cfg(10, peers);
    cfg.params.eval_sample = 4;
    let mut run = TemplarRun::new(cfg).unwrap();
    let uids = run.peer_uids();
    // fix the copier's victim to the first honest peer's actual uid
    if let Behavior::Copier { victim } = &mut run.peers[4].behavior {
        *victim = uids[0];
    }
    for _ in 0..10 {
        run.run_round().unwrap();
    }
    let book = &run.validators[0].book;
    let honest_min =
        uids[..3].iter().map(|u| book.peer_score(*u)).fold(f64::INFINITY, f64::min);
    let poisoner = book.peer_score(uids[3]);
    let copier = book.peer_score(uids[4]);
    assert!(
        honest_min > poisoner,
        "honest ({honest_min:.3}) must outscore poisoner ({poisoner:.3})"
    );
    assert!(honest_min > copier, "honest ({honest_min:.3}) must outscore copier ({copier:.3})");
}

#[test]
fn desync_peer_gets_filtered_or_downrated() {
    require_artifacts!();
    let peers = vec![
        Behavior::Honest { data_mult: 1.0 },
        Behavior::Honest { data_mult: 1.0 },
        Behavior::Desync { at: 2, pause: 4 },
    ];
    let mut cfg = quick_cfg(12, peers);
    cfg.params.eval_sample = 3;
    let mut run = TemplarRun::new(cfg).unwrap();
    let uids = run.peer_uids();
    let mut desync_fast_fails = 0;
    for _ in 0..12 {
        let rec = run.run_round().unwrap();
        let d = rec.peers.iter().find(|p| p.uid == uids[2]).unwrap();
        if d.submitted && !d.fast_pass {
            desync_fast_fails += 1;
        }
    }
    let book = &run.validators[0].book;
    let honest_avg = (book.peer_score(uids[0]) + book.peer_score(uids[1])) / 2.0;
    let desync = book.peer_score(uids[2]);
    assert!(
        desync < honest_avg || desync_fast_fails > 0,
        "desync peer must be downrated ({desync:.3} vs {honest_avg:.3}) or sync-filtered ({desync_fast_fails} fails)"
    );
}

#[test]
fn downstream_eval_runs_and_untrained_is_near_chance() {
    require_artifacts!();
    let e = exec();
    let corpus = Corpus::new(e.meta.vocab as u32, 0);
    let theta = e.init_params().unwrap();
    let r = evaluate_suite(&e, &theta, &corpus, Suite::SynthHellaSwag, 24).unwrap();
    assert_eq!(r.n_items, 24);
    assert!(
        (r.acc_norm - r.chance).abs() < 0.35,
        "untrained model should be near chance: {} vs {}",
        r.acc_norm,
        r.chance
    );
}

#[test]
fn multi_validator_yuma_agrees_with_single_validator_direction() {
    require_artifacts!();
    let peers = vec![
        Behavior::Honest { data_mult: 2.0 },
        Behavior::Honest { data_mult: 1.0 },
        Behavior::Poisoner { scale: 100.0 },
    ];
    let mut cfg = quick_cfg(8, peers);
    cfg.n_validators = 3;
    cfg.params.eval_sample = 3;
    let mut run = TemplarRun::new(cfg).unwrap();
    let uids = run.peer_uids();
    let mut last = Vec::new();
    for _ in 0..8 {
        let rec = run.run_round().unwrap();
        last = rec.peers.iter().map(|p| (p.uid, p.incentive)).collect();
    }
    let inc = |u: u32| last.iter().find(|(x, _)| *x == u).unwrap().1;
    assert!(
        inc(uids[0]) + inc(uids[1]) > inc(uids[2]),
        "consensus incentives must favor honest peers: {last:?}"
    );
}

#[test]
fn lr_schedule_trains_and_keeps_sync_semantics() {
    require_artifacts!();
    use gauntlet::coordinator::schedule::LrSchedule;
    let peers = vec![Behavior::Honest { data_mult: 1.0 }; 3];
    let mut cfg = quick_cfg(6, peers);
    cfg.params.schedule = LrSchedule::WarmupCosine { warmup: 2, total: 6, min_frac: 0.2 };
    let mut run = TemplarRun::new(cfg).unwrap();
    let t0 = run.theta.clone();
    for _ in 0..6 {
        let rec = run.run_round().unwrap();
        // scheduled lr changes the step size but must never trip the
        // SyncScore filter for synchronized honest peers
        for p in &rec.peers {
            assert!(p.fast_pass, "honest peer failed fast eval under schedule");
        }
    }
    assert_ne!(t0, run.theta);
    // checkpoint replay remains exact under a *varying* lr (each update
    // stores its own lr)
    let replay = run.checkpoints.catchup(6).unwrap();
    for (g, w) in replay.iter().zip(&run.theta) {
        assert!((g - w).abs() < 1e-5, "catchup broke under lr schedule");
    }
}

#[test]
fn late_joiner_registers_catches_up_and_earns() {
    require_artifacts!();
    let peers = vec![Behavior::Honest { data_mult: 1.0 }; 3];
    let mut cfg = quick_cfg(10, peers);
    cfg.params.checkpoint_every = 2;
    cfg.params.eval_sample = 4;
    let mut run = TemplarRun::new(cfg).unwrap();
    for _ in 0..5 {
        run.run_round().unwrap();
    }
    // Permissionless join at round 5: the newcomer reconstructs the
    // current model from checkpoint + signed replay...
    let caught_up = run.checkpoints.catchup(5).expect("catchup available");
    for (c, live) in caught_up.iter().zip(&run.theta) {
        assert!((c - live).abs() < 1e-5, "late joiner state mismatch");
    }
    // ...registers, and starts contributing.
    let new_uid = run.register_peer(Behavior::Honest { data_mult: 1.0 }).unwrap();
    let mut earned = 0.0;
    for _ in 0..5 {
        let rec = run.run_round().unwrap();
        let p = rec.peers.iter().find(|p| p.uid == new_uid).unwrap();
        assert!(p.submitted, "new peer must submit");
        assert!(p.fast_pass, "synced newcomer must pass fast eval");
        earned = p.balance;
    }
    assert!(earned > 0.0, "late joiner should start earning: {earned}");
    let mu = run.validators[0].book.get(new_uid).map(|s| s.mu.value).unwrap_or(0.0);
    assert!(mu >= 0.0, "honest newcomer's PoC mu must not be negative: {mu}");
}
