//! VectorLane equivalence pins: the batched kernels (`loss_delta_batch`,
//! `eval_peer_batch`), the fused kernels (`loss_delta`, `grad_into`), and
//! the scratch compressor (`demo_compress_into`) must all be
//! **bit-identical** to their per-call / composed / allocating
//! counterparts — at every parameter-count remainder mod the lane width,
//! so neither the main lane loop nor the remainder tail can drift.
//!
//! These are the tests the `ExecBackend` doc contract points at: a
//! backend overriding a batched default must keep these green.

use gauntlet::runtime::{EvalPeerCase, ExecBackend, SimExec, SimSpec, LANES};

/// A spec with an arbitrary `param_count`; `n_chunks` is sized so the
/// padded coefficient space always covers it.
fn spec_with(param_count: usize) -> SimSpec {
    SimSpec {
        name: format!("lane-{param_count}"),
        chunk: 8,
        n_chunks: param_count.div_ceil(64).max(1),
        topk: 4,
        param_count,
        ..SimSpec::nano()
    }
}

/// Parameter counts covering every residue mod LANES below and above one
/// full lane block, plus a few larger sizes.
fn lane_width_sweep() -> Vec<usize> {
    let mut v: Vec<usize> = (1..=2 * LANES + 3).collect();
    v.extend([31, 64, 65, 200, 333]);
    v
}

fn tokens(exec: &SimExec, tag: i32) -> Vec<i32> {
    let m = exec.meta();
    let n = m.batch * (m.seq + 1);
    (0..n as i32).map(|i| (i * 31 + tag) % m.vocab as i32).collect()
}

/// A deterministic ±1/0 coefficient pattern over the padded space.
fn coeff_pattern(exec: &SimExec, phase: usize) -> Vec<f32> {
    (0..exec.meta().padded_count)
        .map(|i| match (i + phase) % 3 {
            0 => 1.0,
            1 => -1.0,
            _ => 0.0,
        })
        .collect()
}

#[test]
fn loss_delta_batch_is_bit_identical_to_per_call_loss_delta() {
    for len in lane_width_sweep() {
        let exec = SimExec::new(&spec_with(len), 21);
        let theta = exec.init_params().unwrap();
        let toks = tokens(&exec, len as i32);
        let coeffs: Vec<Vec<f32>> = (0..5).map(|p| coeff_pattern(&exec, p)).collect();
        let cands: Vec<(&[f32], f32)> = coeffs
            .iter()
            .enumerate()
            .map(|(i, c)| (c.as_slice(), 0.01 + i as f32 * 1e-3))
            .collect();

        let batched = exec.loss_delta_batch(&theta, &cands, &toks).unwrap();
        assert_eq!(batched.len(), cands.len());
        for (i, &(coeff, step)) in cands.iter().enumerate() {
            let single = exec.loss_delta(&theta, coeff, step, &toks).unwrap();
            assert_eq!(
                (batched[i].0.to_bits(), batched[i].1.to_bits()),
                (single.0.to_bits(), single.1.to_bits()),
                "len {len}, candidate {i}"
            );
        }
    }
}

#[test]
fn eval_peer_batch_is_bit_identical_to_per_call_eval_peer() {
    for len in lane_width_sweep() {
        let exec = SimExec::new(&spec_with(len), 22);
        let theta = exec.init_params().unwrap();
        let coeffs: Vec<Vec<f32>> = (0..4).map(|p| coeff_pattern(&exec, p)).collect();
        let toks: Vec<(Vec<i32>, Vec<i32>)> = (0..4)
            .map(|c| (tokens(&exec, 2 * c), tokens(&exec, 2 * c + 1)))
            .collect();
        let cases: Vec<EvalPeerCase<'_>> = coeffs
            .iter()
            .zip(&toks)
            .map(|(c, (a, r))| EvalPeerCase { coeff: c, tok_assigned: a, tok_rand: r })
            .collect();

        let batched = exec.eval_peer_batch(&theta, 0.013, &cases).unwrap();
        assert_eq!(batched.len(), cases.len());
        for (i, case) in cases.iter().enumerate() {
            let single = exec
                .eval_peer(&theta, case.coeff, 0.013, case.tok_assigned, case.tok_rand)
                .unwrap();
            let b = batched[i];
            assert_eq!(
                [b.0.to_bits(), b.1.to_bits(), b.2.to_bits(), b.3.to_bits()],
                [
                    single.0.to_bits(),
                    single.1.to_bits(),
                    single.2.to_bits(),
                    single.3.to_bits()
                ],
                "len {len}, case {i}"
            );
        }
    }
}

#[test]
fn fused_loss_delta_matches_apply_update_plus_two_losses() {
    for len in lane_width_sweep() {
        let exec = SimExec::new(&spec_with(len), 23);
        let theta = exec.init_params().unwrap();
        let toks = tokens(&exec, 3);
        let coeff = coeff_pattern(&exec, 1);
        let step = 0.02f32;

        let (d0, d1) = exec.loss_delta(&theta, &coeff, step, &toks).unwrap();
        let stepped = exec.apply_update(&theta, &coeff, step).unwrap();
        let c0 = exec.loss(&theta, &toks).unwrap();
        let c1 = exec.loss(&stepped, &toks).unwrap();
        assert_eq!((d0.to_bits(), d1.to_bits()), (c0.to_bits(), c1.to_bits()), "len {len}");
    }
}

#[test]
fn lane_kernel_agrees_with_scalar_reference_to_rounding_error() {
    // The lane scheme is a fixed reassociation of the same f64 terms, so
    // after the final f32 round the two paths may differ by at most a few
    // ulps — pin a tight relative bound at every width.
    for len in lane_width_sweep() {
        let exec = SimExec::new(&spec_with(len), 24);
        let theta = exec.init_params().unwrap();
        let toks = tokens(&exec, 9);
        let coeff = coeff_pattern(&exec, 2);

        let (l0, l1) = exec.loss_delta(&theta, &coeff, 0.01, &toks).unwrap();
        let (s0, s1) = exec.loss_delta_scalar_ref(&theta, &coeff, 0.01, &toks).unwrap();
        for (lane, scalar) in [(l0, s0), (l1, s1)] {
            assert!(
                (lane - scalar).abs() <= 1e-5 * scalar.abs().max(1.0),
                "len {len}: lane {lane} vs scalar {scalar}"
            );
        }
    }
}

#[test]
fn demo_compress_into_is_bit_identical_to_allocating_demo_compress() {
    for len in lane_width_sweep() {
        let exec = SimExec::new(&spec_with(len), 25);
        let theta = exec.init_params().unwrap();
        let toks = tokens(&exec, 4);
        let (_, grad) = exec.grad(&theta, &toks).unwrap();
        let error: Vec<f32> = (0..len).map(|i| (i as f32 * 0.37).sin() * 0.1).collect();
        let decay = 0.999f32;

        let (vals, idx, residual) = exec.demo_compress(&error, &grad, decay).unwrap();

        let mut error2 = error.clone();
        let (mut vals2, mut idx2) = (Vec::new(), Vec::new());
        exec.demo_compress_into(&mut error2, &grad, decay, &mut vals2, &mut idx2).unwrap();

        assert_eq!(idx, idx2, "len {len}");
        assert_eq!(
            vals.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            vals2.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "len {len}"
        );
        assert_eq!(
            residual.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            error2.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "len {len}"
        );
    }
}
