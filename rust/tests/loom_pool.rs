#![cfg(loom)]
//! Loom model checks for the `WorkerPool` dispatch choreography.
//!
//! Under `--cfg loom` the pool's `Mutex`/`Condvar`/`Arc`/threads are
//! loom's instrumented versions, and each `loom::model` below runs its
//! body under **every** schedule the bounded explorer can reach —
//! compile-time lifetime erasure plus run-time latch blocking is exactly
//! the kind of choreography where a one-in-a-million interleaving hides
//! a use-after-free, and these models make that interleaving a
//! deterministic test failure instead.
//!
//! This file compiles to nothing in a normal build (the `#![cfg(loom)]`
//! above): loom is not a dependency of the workspace. CI's `loom` job
//! appends the `[target."cfg(loom)".dependencies]` section to
//! `rust/Cargo.toml` and runs
//! `RUSTFLAGS="--cfg loom" cargo test --release -p gauntlet --test loom_pool`
//! (see README "Correctness tooling" to run it locally).
//!
//! Loom bounds: each model uses a width-2 pool (2 workers + the model's
//! main thread = 3 loom threads, under loom's limit of 4), and CI sets
//! `LOOM_MAX_PREEMPTIONS=2` to keep exploration tractable.

use gauntlet::runtime::WorkerPool;

/// Plain dispatch: a scatter over an even split completes under every
/// schedule, returns chunks in chunk order, and the pool joins cleanly.
#[test]
fn plain_dispatch_completes_in_chunk_order() {
    loom::model(|| {
        let pool = WorkerPool::new(2);
        let mut items: Vec<u32> = vec![1, 2, 3, 4];
        let out =
            pool.scatter(&mut items, 2, |base, ch| (base, ch.iter().copied().sum::<u32>()));
        assert_eq!(out, vec![(0, 3), (2, 7)]);
    });
}

/// Uneven-chunk scatter: 3 items over width 2 must split [2, 1] (the
/// `ceil(len / width)` rule) with per-chunk bases intact, regardless of
/// which thread runs which chunk or how the help-waiting main thread
/// interleaves with the workers.
#[test]
fn uneven_chunk_scatter_keeps_bases_and_sizes() {
    loom::model(|| {
        let pool = WorkerPool::new(2);
        let mut items: Vec<u32> = vec![7, 8, 9];
        let out = pool.scatter(&mut items, 2, |base, ch| (base, ch.len()));
        assert_eq!(out, vec![(0, 2), (2, 1)]);
    });
}

/// Nested dispatch on one pool: outer jobs each scatter inner work on
/// the *same* pool, the validator fan-out shape. The help-while-waiting
/// protocol (waiters drain the shared queue before blocking) is what
/// makes this deadlock-free; loom explores the schedules where both
/// outer jobs wait on inner work simultaneously.
#[test]
fn nested_dispatch_on_one_pool_is_deadlock_free() {
    loom::model(|| {
        let pool = WorkerPool::new(2);
        let mut outer: Vec<u32> = vec![10, 20];
        let pool_ref = &pool;
        let totals = pool.map_indexed(&mut outer, |i, x| {
            let mut inner: Vec<u32> = vec![*x, *x + 1];
            let sums =
                pool_ref.scatter(&mut inner, 2, |_, ch| ch.iter().copied().sum::<u32>());
            (i, sums.into_iter().sum::<u32>())
        });
        assert_eq!(totals, vec![(0, 21), (1, 41)]);
    });
}

/// Worker-panic resume: a panicking job must surface on the waiting
/// thread (same contract as `join().unwrap()` on a scoped spawn), the
/// worker that caught it must survive, and the pool must keep serving —
/// under every schedule, including the one where the *helping waiter*
/// itself runs the panicking job.
#[test]
fn job_panic_resumes_on_waiter_and_pool_survives() {
    loom::model(|| {
        let pool = WorkerPool::new(2);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut items = vec![0u8; 2];
            pool.scatter(&mut items, 2, |base, _| {
                if base == 0 {
                    panic!("deliberate model panic");
                }
                base
            });
        }));
        assert!(caught.is_err(), "the job panic must propagate to the waiter");
        let mut items = vec![0u8; 2];
        let ok = pool.scatter(&mut items, 2, |base, ch| base + ch.len());
        assert_eq!(ok, vec![1, 2], "the pool must keep serving after a job panic");
    });
}
