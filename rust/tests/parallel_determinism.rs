//! The parallel round pipeline must be a pure optimization: for a fixed
//! seed, every observable output — PEERSCOREs, ratings, incentives,
//! balances, fast-eval verdicts, the model parameters themselves, and the
//! typed round-event stream — must be **bit-identical** to the sequential
//! path at any worker-thread count.
//!
//! Runs on the pure-Rust SimExec backend, so this exercises the full
//! pipeline (concurrent peer turns through the exec-service funnel,
//! fan-out fast evaluation, concurrent validators, ordered storage PUTs
//! and chain commits) without compiled artifacts.

use std::sync::{Arc, Mutex};

use gauntlet::coordinator::engine::{GauntletBuilder, GauntletEngine};
use gauntlet::coordinator::events::{observer_fn, replay_trace, JsonlTraceObserver};
use gauntlet::coordinator::run::RunConfig;
use gauntlet::peers::Behavior;
use gauntlet::scenario::Scenario;

/// A population covering every behaviour class, including second-pass
/// peers. With 2 validators registered first (uids 0 and 1), peers get
/// uids 2.. in order, so the copier/duplicator sources below are the two
/// leading honest peers.
fn population() -> Vec<Behavior> {
    vec![
        Behavior::Honest { data_mult: 1.0 },          // uid 2
        Behavior::Honest { data_mult: 2.0 },          // uid 3
        Behavior::Honest { data_mult: 1.0 },          // uid 4
        Behavior::Freeloader,                         // uid 5
        Behavior::Desync { at: 2, pause: 2 },         // uid 6
        Behavior::Late { prob: 0.5 },                 // uid 7
        Behavior::Silent { prob: 0.5 },               // uid 8
        Behavior::FormatViolator,                     // uid 9
        Behavior::Rescaler { factor: 100.0 },         // uid 10
        Behavior::Poisoner { scale: 100.0 },          // uid 11
        Behavior::Copier { victim: 2 },               // uid 12
        Behavior::Duplicator { original: 3 },         // uid 13
    ]
}

fn config(threads: usize) -> RunConfig {
    let mut cfg = RunConfig {
        model: "nano".to_string(),
        rounds: 8,
        peers: population(),
        ..RunConfig::default()
    };
    cfg.seed = 13;
    cfg.eval_every = 2;
    cfg.n_validators = 2;
    cfg.params.top_g = 4;
    cfg.params.eval_sample = 3;
    cfg.threads = threads;
    cfg
}

/// A bounded slot table plus a scripted churn wave covering every event
/// kind: joins that recycle freed uids, a join that forces an eviction on
/// the full table, a leave, a stake move, and a provider outage. The
/// population is different almost every round, which is exactly what the
/// determinism contract must survive.
fn churn_config(threads: usize) -> RunConfig {
    let mut cfg = config(threads);
    cfg.rounds = 10;
    cfg.seed = 29;
    // Primary-evaluate every valid peer each round: honest incumbents hold
    // positive incentive from round 0 on, so slot pressure always lands on
    // a zero/negative-score misbehaver, never on the uid the script churns
    // explicitly.
    cfg.params.eval_sample = 16;
    // 2 validators + 12 peers occupy 14 of 16 slots; the @4 join fills
    // slot 16, so the @5 join must evict.
    cfg.max_uids = 16;
    cfg.immunity_rounds = 1;
    cfg.scenario = Scenario::parse(
        "@2 join honest\n\
         @4 join freeloader\n\
         @5 join honest:2      # table full -> evicts the cheapest slot\n\
         @6 leave 4\n\
         @7 join poisoner      # lands on the uid freed at round 6\n\
         @7 stake 0 750\n\
         @8 outage 0.5 1",
    )
    .expect("valid scenario");
    cfg
}

/// The ChaosPlane acceptance scenario: moderate GET failures, payload
/// corruption caught by the digest verdict, and one peer eclipsed from
/// one validator — a full engine run must complete with no panic, score
/// unreadable submissions as misses, and stay bit-identical across
/// worker-thread counts.
fn chaos_config(threads: usize) -> RunConfig {
    let mut cfg = config(threads);
    cfg.rounds = 10;
    cfg.seed = 37;
    cfg.scenario = Scenario::parse(
        "@1 chaos get-fail 0.2 6\n\
         @2 chaos corrupt 0.05 5\n\
         @3 eclipse 0 4 4      # validator 0 blind to honest peer 4",
    )
    .expect("valid scenario");
    cfg
}

fn engine_for(cfg: RunConfig) -> GauntletEngine {
    GauntletBuilder::sim().config(cfg).build().expect("sim engine")
}

/// Run `rounds` rounds (with a direct permissionless join at round 5 when
/// no scenario is scripted) and collect a structural trace plus a
/// bit-exact numeric fingerprint.
fn fingerprint_cfg(cfg: RunConfig) -> (Vec<String>, Vec<u64>) {
    let rounds = cfg.rounds;
    let scripted = !cfg.scenario.is_empty();
    let mut run = engine_for(cfg);
    let mut structural = Vec::new();
    let mut bits = Vec::new();
    for r in 0..rounds {
        if r == 5 && !scripted {
            run.register_peer(Behavior::Honest { data_mult: 1.0 }).expect("late join");
        }
        let rec = run.run_round().expect("round");
        let flags: String = rec
            .peers
            .iter()
            .map(|p| {
                format!("{}{}{}", p.submitted as u8, p.fast_pass as u8, p.in_top_g as u8)
            })
            .collect();
        structural.push(format!(
            "r{r} valid={} topg={:?} flags={flags} events={:?} uids={:?}",
            rec.n_valid_submissions,
            rec.top_g,
            rec.events,
            rec.peers.iter().map(|p| p.uid).collect::<Vec<_>>()
        ));
        bits.push(rec.heldout_loss.unwrap_or(-1.0).to_bits());
        bits.push(rec.mean_local_loss.to_bits());
        for p in &rec.peers {
            bits.push(p.peer_score.to_bits());
            bits.push(p.rating_mu.to_bits());
            bits.push(p.rating_ordinal.to_bits());
            bits.push(p.mu.to_bits());
            bits.push(p.incentive.to_bits());
            bits.push(p.balance.to_bits());
            bits.push(p.loss_score_rand.unwrap_or(-2.0).to_bits());
            bits.push(p.loss_score_assigned.unwrap_or(-2.0).to_bits());
        }
    }
    // Final model parameters and every validator's full score table.
    for t in run.theta() {
        bits.push(t.to_bits() as u64);
    }
    let uids = run.peer_uids();
    for v in run.validators() {
        for &u in &uids {
            bits.push(v.book.peer_score(u).to_bits());
        }
    }
    (structural, bits)
}

fn fingerprint(threads: usize) -> (Vec<String>, Vec<u64>) {
    fingerprint_cfg(config(threads))
}

#[test]
fn parallel_pipeline_is_bit_identical_to_sequential() {
    let (trace_seq, bits_seq) = fingerprint(1);
    assert!(!bits_seq.is_empty());
    for threads in [2usize, 4, 8] {
        let (trace, bits) = fingerprint(threads);
        assert_eq!(
            trace, trace_seq,
            "structural round trace diverged at {threads} threads"
        );
        assert_eq!(
            bits, bits_seq,
            "numeric fingerprint diverged at {threads} threads"
        );
    }
}

#[test]
fn churn_scenario_is_bit_identical_at_any_thread_count() {
    // The full lifecycle — scripted joins, an eviction on the full slot
    // table, a leave, uid recycling, a stake move, an outage window — must
    // not perturb the determinism contract: PEERSCOREs, incentives,
    // balances, and parameters stay bit-identical at any worker count.
    let (trace_seq, bits_seq) = fingerprint_cfg(churn_config(1));
    assert!(!bits_seq.is_empty());
    // Sanity: the scenario actually fired (joins + eviction + recycling).
    let all = trace_seq.join("\n");
    assert!(all.contains("join honest as uid"), "{all}");
    assert!(all.contains("evicted"), "{all}");
    assert!(all.contains("uid 4 left"), "{all}");
    assert!(all.contains("join poisoner as uid 4 (recycled uid)"), "{all}");
    assert!(all.contains("outage"), "{all}");
    for threads in [2usize, 4, 8] {
        let (trace, bits) = fingerprint_cfg(churn_config(threads));
        assert_eq!(
            trace, trace_seq,
            "churn structural trace diverged at {threads} threads"
        );
        assert_eq!(
            bits, bits_seq,
            "churn numeric fingerprint diverged at {threads} threads"
        );
    }
}

#[test]
fn chaos_scenario_is_bit_identical_at_any_thread_count() {
    // Read-path faults draw from keyed RNG streams (bucket, key, reader,
    // attempt), so the fault pattern — and therefore every retry,
    // rejection, and scored miss — must be independent of how the
    // fast-eval fan-out is scheduled across workers.
    let (trace_seq, bits_seq) = fingerprint_cfg(chaos_config(1));
    assert!(!bits_seq.is_empty());
    let all = trace_seq.join("\n");
    assert!(all.contains("chaos get-fail p=0.2 until round 7"), "{all}");
    assert!(all.contains("chaos corrupt p=0.05 until round 7"), "{all}");
    assert!(all.contains("validator 0 eclipsed from peer 4 until round 7"), "{all}");
    assert!(all.contains("chaos get-fail cleared"), "{all}");
    assert!(all.contains("chaos corrupt cleared"), "{all}");
    assert!(all.contains("validator 0 sees peer 4 again"), "{all}");
    for threads in [2usize, 8] {
        let (trace, bits) = fingerprint_cfg(chaos_config(threads));
        assert_eq!(
            trace, trace_seq,
            "chaos structural trace diverged at {threads} threads"
        );
        assert_eq!(
            bits, bits_seq,
            "chaos numeric fingerprint diverged at {threads} threads"
        );
    }
}

#[test]
fn chaos_event_stream_surfaces_misses_and_retries() {
    // The eclipsed peer's submission must surface as a typed
    // SubmissionUnavailable miss for exactly the blinded validator, and
    // at a 0.2 GET-failure rate the bounded retry path must actually
    // fire — and the whole fault telemetry stream must be identical
    // whether the reads ran sequentially or fanned out.
    let seq = event_stream(chaos_config(1));
    assert!(
        seq.iter().any(|e| e.starts_with("SubmissionUnavailable")
            && e.contains("validator: 0")
            && e.contains("uid: 4")),
        "no SubmissionUnavailable for the eclipsed peer in {} events",
        seq.len()
    );
    assert!(
        seq.iter().any(|e| e.starts_with("StorageRetry")),
        "no StorageRetry at get-fail p=0.2"
    );
    let par = event_stream(chaos_config(8));
    assert_eq!(par, seq, "chaos event stream diverged at 8 threads");
}

#[test]
fn churn_sequential_reruns_are_bit_identical() {
    let a = fingerprint_cfg(churn_config(1));
    let b = fingerprint_cfg(churn_config(1));
    assert_eq!(a, b);
}

#[test]
fn sequential_reruns_are_bit_identical() {
    // Baseline sanity for the test above: the fingerprint itself must be
    // reproducible run-to-run.
    let a = fingerprint(1);
    let b = fingerprint(1);
    assert_eq!(a, b);
}

#[test]
fn explicit_thread_count_is_respected() {
    let cfg = config(7);
    assert_eq!(cfg.effective_threads(), 7);
    let auto = config(0);
    assert!(auto.effective_threads() >= 1);
}

/// Capture the full typed event stream of a run as one string per event.
fn event_stream(cfg: RunConfig) -> Vec<String> {
    let events: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
    let sink = events.clone();
    let mut run = GauntletBuilder::sim()
        .config(cfg)
        .observer(observer_fn(move |ev| {
            sink.lock().unwrap().push(format!("{ev:?}"));
        }))
        .build()
        .expect("sim engine");
    run.run().expect("run");
    let captured = events.lock().unwrap().clone();
    captured
}

#[test]
fn event_stream_is_deterministic_across_thread_counts() {
    // Observers must see the exact same events, in the exact same order,
    // whether the pipeline ran sequentially or fanned out over workers —
    // including under churn, where the population changes mid-run.
    let seq = event_stream(churn_config(1));
    assert!(!seq.is_empty());
    // The stream brackets every round.
    assert!(seq[0].starts_with("RoundStarted"), "{}", seq[0]);
    assert!(seq.last().unwrap().starts_with("RoundCompleted"), "{:?}", seq.last());
    for threads in [2usize, 8] {
        let par = event_stream(churn_config(threads));
        assert_eq!(
            par.len(),
            seq.len(),
            "event count diverged at {threads} threads"
        );
        for (i, (a, b)) in seq.iter().zip(&par).enumerate() {
            assert_eq!(a, b, "event {i} diverged at {threads} threads");
        }
    }
}

#[test]
fn jsonl_trace_replays_to_identical_metrics() {
    // The acceptance contract of the event stream: a JSONL trace of a full
    // run, replayed through a fresh MetricsObserver, reproduces the exact
    // RunMetrics the live run assembled.
    let path = std::env::temp_dir().join(format!(
        "gauntlet-trace-{}-{}.jsonl",
        std::process::id(),
        std::thread::current().name().unwrap_or("t").len()
    ));
    let trace = JsonlTraceObserver::create(&path).expect("trace file");
    let mut run = GauntletBuilder::sim()
        .config(churn_config(2))
        .observer(trace.clone())
        .build()
        .expect("sim engine");
    let live = run.run().expect("run");
    trace.flush().expect("flush");

    let replayed = replay_trace(&path).expect("replay");
    assert_eq!(live.rounds.len(), replayed.rounds.len());
    assert_eq!(
        live.to_json().write(),
        replayed.to_json().write(),
        "replayed metrics diverged from the live run"
    );
    // Typed equality too (no NaNs flow into these records).
    assert_eq!(live, replayed);
    std::fs::remove_file(&path).ok();
}
