//! Adversary-zoo acceptance tests (the PR 6 tentpole):
//!
//! - the deterministic in-tree fuzzer budget: 25 random churn + adversary
//!   scripts through full engine runs via `prop::scenario`, every
//!   incentive-security invariant checked, every failure reproducible from
//!   the printed seed (`gauntlet soak --repro <seed> --size <n>`);
//! - targeted stake-bribery tests pinning both Yuma regimes: a
//!   minority-stake bribe is clipped to the honest consensus, a
//!   majority-stake bribe succeeds (the paper's stake-security assumption);
//! - a 1-vs-N thread fingerprint pin over a population with copy chains
//!   (copier, copycat, duplicator) plus the new zoo classes — the
//!   second-pass copy stage must not depend on thread count;
//! - deterministic relative-earnings checks for the sybil ring and the
//!   stale replayer.

use gauntlet::coordinator::engine::{GauntletBuilder, GauntletEngine};
use gauntlet::peers::Behavior;
use gauntlet::prop;
use gauntlet::scenario::Scenario;

/// The deterministic fuzzer budget that ships inside `cargo test -q`: the
/// CI nightly runs the same generator at much higher case counts through
/// `gauntlet soak --fuzz`.
#[test]
fn scenario_fuzzer_deterministic_budget() {
    prop::check("adversary-zoo-fuzz", 25, prop::scenario::check_case);
}

/// Mixed zoo including every new class plus copy chains, victims pointing
/// at the leading honest uids (validators take uids 0..n_validators).
fn zoo(n_validators: usize) -> Vec<Behavior> {
    let h = n_validators as u32; // first honest peer uid
    vec![
        Behavior::Honest { data_mult: 1.0 },
        Behavior::Honest { data_mult: 2.0 },
        Behavior::Honest { data_mult: 1.0 },
        Behavior::Copier { victim: h },
        Behavior::CopycatNoise { victim: h + 1, noise: 0.1 },
        Behavior::Duplicator { original: h + 2 },
        Behavior::Sybil { ring: 7, eps: 0.05 },
        Behavior::Sybil { ring: 7, eps: 0.05 },
        Behavior::SlowLoris,
        Behavior::StaleReplayer { lag: 2 },
    ]
}

fn build(n_validators: usize, threads: usize, scenario: Scenario) -> GauntletEngine {
    GauntletBuilder::sim()
        .model("nano")
        .rounds(8)
        .peers(zoo(n_validators))
        .scenario(scenario)
        .seed(23)
        .threads(threads)
        .validators(n_validators)
        .eval_every(0)
        .eval_sample(16)
        .build()
        .expect("sim engine builds")
}

fn balance(e: &GauntletEngine, uid: u32) -> f64 {
    e.chain().neuron(uid).map(|n| n.balance).unwrap_or(0.0)
}

/// Satellite 4 pin: the copy stage (copier/copycat/duplicator posting in
/// the same round their victims post) is sequential on the coordinator
/// thread, so the whole zoo must be bit-identical at any thread count.
#[test]
fn zoo_fingerprint_identical_at_any_thread_count() {
    let mut seq = build(2, 1, Scenario::default());
    seq.run().expect("sequential run");
    for threads in [2, 8] {
        let mut par = build(2, threads, Scenario::default());
        par.run().expect("parallel run");
        assert_eq!(
            seq.fingerprint(),
            par.fingerprint(),
            "zoo run diverged at {threads} threads"
        );
    }
}

/// A minority-stake bribe buys one validator's weight row, but Yuma clips
/// values lacking kappa-majority stake support back to the honest
/// consensus: the briber cannot materially out-earn the best honest peer.
/// Validator stakes are 1000 (uid 0) and 500 (uid 1), so uid 1 is the
/// minority target.
#[test]
fn minority_stake_bribe_is_clipped_by_yuma() {
    let peers = vec![
        Behavior::Honest { data_mult: 1.0 },  // uid 2
        Behavior::Honest { data_mult: 1.0 },  // uid 3
        Behavior::Honest { data_mult: 2.0 },  // uid 4
        Behavior::Briber { validator: 1 },    // uid 5
    ];
    let mut engine = GauntletBuilder::sim()
        .model("nano")
        .rounds(8)
        .peers(peers)
        .seed(31)
        .threads(1)
        .validators(2)
        .eval_every(0)
        .eval_sample(16)
        .build()
        .expect("engine builds");
    engine.run().expect("run");
    let best_honest = [2u32, 3, 4].iter().map(|&u| balance(&engine, u)).fold(0.0, f64::max);
    let briber = balance(&engine, 5);
    assert!(best_honest > 0.0, "honest peers earned nothing — degenerate run");
    assert!(
        briber <= best_honest * 1.5 + 1e-6,
        "minority bribe paid off: briber balance {briber} vs best honest {best_honest}"
    );
}

/// Hand the bribed validator the stake majority via a scripted stake move
/// and the same attack succeeds — the incentive guarantee is conditional
/// on honest stake majority, exactly as the paper assumes.
#[test]
fn majority_stake_bribe_succeeds() {
    let peers = vec![
        Behavior::Honest { data_mult: 1.0 },  // uid 2
        Behavior::Honest { data_mult: 1.0 },  // uid 3
        Behavior::Honest { data_mult: 2.0 },  // uid 4
        Behavior::Briber { validator: 1 },    // uid 5
    ];
    // uid 1 starts at stake 500 vs uid 0's 1000; @0 raise it to 3000.
    let scenario = Scenario::parse("@0 stake 1 3000").expect("scenario parses");
    let mut engine = GauntletBuilder::sim()
        .model("nano")
        .rounds(8)
        .peers(peers)
        .scenario(scenario)
        .seed(31)
        .threads(1)
        .validators(2)
        .eval_every(0)
        .eval_sample(16)
        .build()
        .expect("engine builds");
    engine.run().expect("run");
    let honest_mean =
        [2u32, 3, 4].iter().map(|&u| balance(&engine, u)).sum::<f64>() / 3.0;
    let briber = balance(&engine, 5);
    assert!(
        briber > honest_mean,
        "majority bribe should dominate: briber balance {briber} vs honest mean {honest_mean}"
    );
}

/// Sybil ring members share one gradient computation with per-member
/// perturbations; proof-of-computation scores them against their own
/// assigned shards, so each member must earn strictly less than the mean
/// honest peer and end at near-zero incentive.
#[test]
fn sybil_ring_converges_to_near_zero() {
    let mut engine = build(1, 1, Scenario::default());
    engine.run().expect("run");
    let honest_mean =
        [1u32, 2, 3].iter().map(|&u| balance(&engine, u)).sum::<f64>() / 3.0;
    assert!(honest_mean > 0.0, "honest peers earned nothing — degenerate run");
    for uid in [7u32, 8] {
        let b = balance(&engine, uid);
        assert!(
            b < honest_mean,
            "sybil uid {uid} balance {b} not strictly below honest mean {honest_mean}"
        );
    }
    let last = engine.metrics_observer().last_record().expect("final round record");
    let inc = |uid: u32| {
        last.peers.iter().find(|p| p.uid == uid).map(|p| p.incentive).unwrap_or(0.0)
    };
    let honest_inc = ([1u32, 2, 3].iter().map(|&u| inc(u)).sum::<f64>()) / 3.0;
    for uid in [7u32, 8] {
        assert!(
            inc(uid) <= honest_inc * 0.5 + 1e-9,
            "sybil uid {uid} final incentive {} has not converged toward zero \
             (honest mean {honest_inc})",
            inc(uid)
        );
    }
}

/// The stale replayer re-posts its own round-(r-k) submission. It still
/// does real work, so it is *neutralized*, not necessarily starved: it
/// must never materially out-earn the best honest peer.
#[test]
fn stale_replayer_never_out_earns_honest() {
    let mut engine = build(1, 1, Scenario::default());
    engine.run().expect("run");
    let best_honest = [1u32, 2, 3].iter().map(|&u| balance(&engine, u)).fold(0.0, f64::max);
    let stale = balance(&engine, 10);
    assert!(best_honest > 0.0, "honest peers earned nothing — degenerate run");
    assert!(
        stale <= best_honest * 1.5 + 1e-6,
        "stale replayer balance {stale} materially out-earns best honest {best_honest}"
    );
}

/// Mid-run snapshot + resume over the full zoo matches the uninterrupted
/// fingerprint (the fuzzer also samples this; here it is pinned on a
/// population with every copy chain active).
#[test]
fn zoo_snapshot_resume_is_bit_identical() {
    let mut live = build(2, 1, Scenario::default());
    let mut snap = None;
    while live.round() < 8 {
        if live.round() == 4 {
            snap = Some(live.snapshot());
        }
        live.run_round().expect("live round");
    }
    let mut resumed = GauntletBuilder::sim()
        .resume(snap.expect("snapshot taken"))
        .build()
        .expect("resumed engine builds");
    resumed.run().expect("resumed run");
    assert_eq!(resumed.fingerprint(), live.fingerprint(), "resume diverged from live run");
}
