//! Snapshot/resume must be invisible: pausing a run at round k,
//! serializing the snapshot through JSON, and resuming (even at a
//! different worker-thread count) produces **bit-identical** PEERSCOREs,
//! ratings, incentives, balances, and model parameters to the
//! uninterrupted run — including under a churn scenario whose events
//! straddle the snapshot boundary.
//!
//! Runs on the pure-Rust SimExec backend (no artifacts needed).

use gauntlet::coordinator::engine::{GauntletBuilder, GauntletEngine};
use gauntlet::coordinator::run::{RoundRecord, RunConfig};
use gauntlet::coordinator::snapshot::RunSnapshot;
use gauntlet::peers::Behavior;
use gauntlet::scenario::Scenario;

/// A mixed population exercising peer-side persistent state: error-feedback
/// buffers, a divergent Desync model, behaviour RNG streams, and a
/// second-pass copier.
fn population() -> Vec<Behavior> {
    vec![
        Behavior::Honest { data_mult: 1.0 },  // uid 1
        Behavior::Honest { data_mult: 2.0 },  // uid 2
        Behavior::Desync { at: 2, pause: 2 }, // uid 3
        Behavior::Late { prob: 0.5 },         // uid 4
        Behavior::Poisoner { scale: 100.0 },  // uid 5
        Behavior::Copier { victim: 1 },       // uid 6
    ]
}

fn base_cfg(threads: usize) -> RunConfig {
    let mut cfg = RunConfig {
        model: "nano".to_string(),
        rounds: 6,
        peers: population(),
        ..RunConfig::default()
    };
    cfg.seed = 41;
    cfg.eval_every = 2;
    cfg.params.top_g = 3;
    cfg.params.eval_sample = 4;
    cfg.threads = threads;
    cfg
}

/// Churn on both sides of the snapshot boundary (taken at round 3): a
/// pre-snapshot join and an outage window still open at the boundary,
/// plus post-snapshot joins/leaves/stake moves that must fire from the
/// restored scenario cursor.
fn churn_cfg(threads: usize) -> RunConfig {
    let mut cfg = base_cfg(threads);
    cfg.rounds = 7;
    cfg.max_uids = 10;
    cfg.immunity_rounds = 1;
    cfg.scenario = Scenario::parse(
        "@1 join honest\n\
         @2 outage 0.6 3      # still open when the snapshot is taken at 3\n\
         @4 leave 2\n\
         @5 join freeloader   # lands on the uid freed at round 4\n\
         @5 stake 0 900",
    )
    .expect("valid scenario");
    cfg
}

/// Chaos on both sides of the snapshot boundary (taken at round 3): a
/// get-fail window and a corrupt window still open at the boundary, an
/// eclipse that expires only after the resume, and a second get-fail
/// window that must fire from the restored scenario cursor.
fn chaos_cfg(threads: usize) -> RunConfig {
    let mut cfg = base_cfg(threads);
    cfg.rounds = 7;
    cfg.scenario = Scenario::parse(
        "@1 chaos get-fail 0.25 4   # still open when the snapshot is taken at 3\n\
         @2 chaos corrupt 0.125 3\n\
         @2 eclipse 0 4 3           # validator 0 blind to peer 4 through round 4\n\
         @5 chaos get-fail 0.5 1",
    )
    .expect("valid scenario");
    cfg
}

/// Everything the acceptance contract pins, as exact bit patterns.
fn state_bits(run: &GauntletEngine) -> Vec<u64> {
    let mut bits = Vec::new();
    for t in run.theta() {
        bits.push(t.to_bits() as u64);
    }
    let uids = run.peer_uids();
    for v in run.validators() {
        for &u in &uids {
            bits.push(u as u64);
            bits.push(v.book.peer_score(u).to_bits());
        }
    }
    for &u in &uids {
        bits.push(run.chain().neuron(u).map(|n| n.balance).unwrap_or(0.0).to_bits());
        bits.push(
            run.chain().neuron(u).map(|n| n.last_incentive).unwrap_or(0.0).to_bits(),
        );
    }
    bits.push(run.fingerprint());
    bits
}

/// Drive an uninterrupted run, returning per-round records + final state.
fn straight_run(cfg: RunConfig) -> (Vec<RoundRecord>, Vec<u64>) {
    let mut run = GauntletBuilder::sim().config(cfg).build().expect("engine");
    let metrics = run.run().expect("run");
    let bits = state_bits(&run);
    (metrics.rounds, bits)
}

/// Drive k rounds, snapshot, push the snapshot through its JSON text form,
/// resume (possibly at another thread count), and finish. Returns the
/// post-resume records + final state.
fn interrupted_run(
    cfg: RunConfig,
    pause_at: u64,
    resume_threads: usize,
) -> (Vec<RoundRecord>, Vec<u64>) {
    let total = cfg.rounds;
    let mut first = GauntletBuilder::sim().config(cfg).build().expect("engine");
    for _ in 0..pause_at {
        first.run_round().expect("pre-pause round");
    }
    let json = first.snapshot().to_json().write();
    drop(first); // the original engine is gone; only the JSON survives

    let snap = RunSnapshot::parse(&json).expect("snapshot parses");
    assert_eq!(snap.round, pause_at);
    let mut resumed = GauntletBuilder::sim()
        .resume(snap)
        .rounds(total)
        .threads(resume_threads)
        .build()
        .expect("resumed engine");
    assert_eq!(resumed.round(), pause_at, "resume continues at the boundary");
    let metrics = resumed.run().expect("post-resume rounds");
    let bits = state_bits(&resumed);
    (metrics.rounds, bits)
}

#[test]
fn resume_is_bit_identical_to_uninterrupted() {
    let (straight, bits_straight) = straight_run(base_cfg(1));
    let (resumed, bits_resumed) = interrupted_run(base_cfg(1), 3, 1);
    // The resumed engine's records cover rounds 3.. — they must equal the
    // uninterrupted run's tail exactly (scores, ratings, incentives,
    // balances, events, everything).
    assert_eq!(resumed.len(), straight.len() - 3);
    for (a, b) in straight[3..].iter().zip(&resumed) {
        assert_eq!(a, b, "round {} diverged after resume", a.round);
    }
    assert_eq!(bits_straight, bits_resumed, "final state diverged after resume");
}

#[test]
fn resume_is_bit_identical_across_thread_counts() {
    // Pause a sequential run, resume it on 4 workers: still bit-identical
    // (the pipeline's determinism contract composes with resume).
    let (straight, bits_straight) = straight_run(base_cfg(4));
    for resume_threads in [1usize, 4] {
        let (resumed, bits) = interrupted_run(base_cfg(1), 2, resume_threads);
        for (a, b) in straight[2..].iter().zip(&resumed) {
            assert_eq!(
                a, b,
                "round {} diverged (resume at {resume_threads} threads)",
                a.round
            );
        }
        assert_eq!(bits_straight, bits, "state diverged at {resume_threads} threads");
    }
}

#[test]
fn resume_under_churn_scenario_is_bit_identical() {
    // The snapshot boundary sits inside an open outage window, after one
    // scripted join, and before a leave + uid-recycling join + stake move:
    // the restored scenario cursor, outage restore state, chain slot
    // table, and provider RNG must all continue exactly.
    let (straight, bits_straight) = straight_run(churn_cfg(1));
    let all_events: Vec<String> =
        straight.iter().flat_map(|r| r.events.clone()).collect();
    let joined = all_events.join("\n");
    assert!(joined.contains("uid 2 left"), "{joined}");
    assert!(joined.contains("provider recovered"), "{joined}");
    assert!(joined.contains("(recycled uid)"), "{joined}");

    for (pause_at, resume_threads) in [(3u64, 1usize), (3, 4), (5, 2)] {
        let (resumed, bits) = interrupted_run(churn_cfg(1), pause_at, resume_threads);
        for (a, b) in straight[pause_at as usize..].iter().zip(&resumed) {
            assert_eq!(
                a, b,
                "churn round {} diverged (pause {pause_at}, {resume_threads} threads)",
                a.round
            );
        }
        assert_eq!(
            bits_straight, bits,
            "churn state diverged (pause {pause_at}, {resume_threads} threads)"
        );
    }
}

#[test]
fn resume_inside_chaos_window_is_bit_identical() {
    // The snapshot boundary sits inside open get-fail + corrupt chaos
    // windows and an active eclipse: the restored fault probabilities,
    // eclipse set, chaos/eclipse restore cursors, and the keyed fault-RNG
    // draws must all continue exactly — at any resume thread count.
    let (straight, bits_straight) = straight_run(chaos_cfg(1));
    let all_events: Vec<String> =
        straight.iter().flat_map(|r| r.events.clone()).collect();
    let joined = all_events.join("\n");
    assert!(joined.contains("chaos get-fail p=0.25 until round 5"), "{joined}");
    assert!(joined.contains("chaos corrupt p=0.125 until round 5"), "{joined}");
    assert!(joined.contains("chaos get-fail cleared"), "{joined}");
    assert!(joined.contains("chaos corrupt cleared"), "{joined}");
    assert!(
        joined.contains("validator 0 eclipsed from peer 4 until round 5"),
        "{joined}"
    );
    assert!(joined.contains("validator 0 sees peer 4 again"), "{joined}");

    for (pause_at, resume_threads) in [(3u64, 1usize), (3, 4), (4, 2)] {
        let (resumed, bits) = interrupted_run(chaos_cfg(1), pause_at, resume_threads);
        for (a, b) in straight[pause_at as usize..].iter().zip(&resumed) {
            assert_eq!(
                a, b,
                "chaos round {} diverged (pause {pause_at}, {resume_threads} threads)",
                a.round
            );
        }
        assert_eq!(
            bits_straight, bits,
            "chaos state diverged (pause {pause_at}, {resume_threads} threads)"
        );
    }
}

#[test]
fn resume_preserves_direct_midrun_registrations() {
    // A peer registered through the API (not a scenario) immediately
    // before the pause must survive the snapshot: its runner state,
    // bucket read key, and validator score history all travel — and so
    // does its pending "join ..." lifecycle line, which the *next*
    // round's record must still report after the resume.
    let run_with_join = |pause: bool| -> (Vec<RoundRecord>, Vec<u64>) {
        let mut run = GauntletBuilder::sim().config(base_cfg(1)).build().expect("engine");
        run.run_round().expect("round 0");
        run.run_round().expect("round 1");
        // Between rounds, right before the (optional) snapshot.
        run.register_peer(Behavior::Honest { data_mult: 1.0 }).expect("join");
        let mut run = if pause {
            let json = run.snapshot().to_json().write();
            let snap = RunSnapshot::parse(&json).expect("parse");
            GauntletBuilder::sim().resume(snap).build().expect("resumed")
        } else {
            run
        };
        let rest = run.run().expect("rest");
        (rest.rounds, state_bits(&run))
    };
    let (recs_straight, bits_straight) = run_with_join(false);
    let (recs_resumed, bits_resumed) = run_with_join(true);
    assert!(
        recs_straight[0].events.iter().any(|e| e.starts_with("join honest as uid")),
        "{:?}",
        recs_straight[0].events
    );
    assert_eq!(recs_straight, recs_resumed, "post-pause records must match exactly");
    assert_eq!(bits_straight, bits_resumed);
}

#[test]
fn snapshot_json_is_stable_through_a_roundtrip() {
    let mut run = GauntletBuilder::sim().config(churn_cfg(1)).build().expect("engine");
    for _ in 0..3 {
        run.run_round().expect("round");
    }
    let snap = run.snapshot();
    let text = snap.to_json().write();
    let reparsed = RunSnapshot::parse(&text).expect("parse");
    assert_eq!(text, reparsed.to_json().write(), "snapshot JSON must be idempotent");
    // The embedded config survives: same model, rounds, peer specs — and
    // the snapshot remembers which backend produced it.
    assert_eq!(reparsed.backend, "sim");
    assert_eq!(reparsed.cfg.model, "nano");
    assert_eq!(reparsed.cfg.rounds, 7);
    assert_eq!(reparsed.cfg.peers.len(), 6);
    assert_eq!(reparsed.cfg.scenario.len(), 5);

    // The auto backend honors the recorded backend on resume (a sim
    // snapshot resumes on sim without even probing for artifacts).
    let resumed = GauntletBuilder::auto().resume(reparsed).build().expect("auto resume");
    assert_eq!(resumed.backend_name(), "sim");
    assert_eq!(resumed.round(), 3);
}

#[test]
fn resume_rejects_structural_config_changes_and_corrupt_theta() {
    let mut run = GauntletBuilder::sim().config(base_cfg(1)).build().expect("engine");
    run.run_round().expect("round");
    let snap = run.snapshot();

    // Builder setters for snapshot-baked fields are rejected, not ignored.
    let err = GauntletBuilder::sim()
        .resume(snap.clone())
        .model("mid")
        .build()
        .unwrap_err();
    assert!(
        err.to_string().contains("cannot change `model` on resume"),
        "wrong error: {err:#}"
    );
    let err = GauntletBuilder::sim().resume(snap.clone()).seed(999).build().unwrap_err();
    assert!(err.to_string().contains("cannot change `seed`"), "wrong error: {err:#}");
    let err = GauntletBuilder::sim().resume(snap.clone()).validators(3).build().unwrap_err();
    assert!(
        err.to_string().contains("cannot change `n_validators`"),
        "wrong error: {err:#}"
    );

    // A hand-tampered snapshot whose parameters cannot belong to its model
    // is rejected by the parameter-count check.
    let mut bad = snap;
    bad.theta.truncate(10);
    let err = GauntletBuilder::sim().resume(bad).build().unwrap_err();
    assert!(err.to_string().contains("do not fit model"), "wrong error: {err:#}");

    // Runtime-read knobs remain adjustable.
    let mut ok = GauntletBuilder::sim()
        .resume(run.snapshot())
        .rounds(3)
        .threads(2)
        .eval_every(1)
        .build()
        .expect("runtime knobs are resumable");
    assert_eq!(ok.round(), 1);
    ok.run().expect("continue");
}
