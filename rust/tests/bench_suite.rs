//! Acceptance tests for the PerfLab harness (`bench::suite`):
//!
//! - `--quick` runs every registered bench at least once, with sane
//!   statistics (the CI `perf-smoke` job relies on quick results carrying
//!   the same bench names as full results, so baselines stay comparable),
//! - the `BENCH_<suite>.json` schema round-trips through `minjson`,
//! - `--compare` passes an identical baseline and flags a doctored
//!   slowdown (the regression-gate semantics, end to end on real data).

use gauntlet::bench::suite::{self, BenchCtx, SuiteResult};
use gauntlet::minjson::Value;

/// Run the quick hotpath suite once and reuse the result across checks —
/// it is the expensive part of this test file.
fn quick_hotpath() -> (Vec<String>, SuiteResult) {
    let spec = suite::find_suite("hotpath").expect("hotpath suite is registered");
    let registered: Vec<String> = spec.benches.iter().map(|b| b.name.to_string()).collect();
    let result = suite::run_suite(&spec, &BenchCtx { quick: true }).expect("suite run");
    (registered, result)
}

#[test]
fn quick_runs_every_registered_bench_with_sane_stats_and_roundtrips() {
    let (registered, result) = quick_hotpath();

    // Every registered bench ran exactly once, in registration order
    // (nothing in the hotpath suite is environment-gated).
    let ran: Vec<String> = result.benches.iter().map(|b| b.name.clone()).collect();
    assert_eq!(ran, registered, "--quick must run every registered bench");
    assert!(result.quick);
    assert_eq!(result.suite, "hotpath");
    assert!(result.fingerprint.threads >= 1);
    assert!(!result.fingerprint.git_commit.is_empty());

    for b in &result.benches {
        assert!(b.iters >= 1, "{}: no samples", b.name);
        assert!(b.mean_s.is_finite() && b.mean_s >= 0.0, "{}: mean {}", b.name, b.mean_s);
        assert!(b.min_s <= b.mean_s + 1e-12, "{}: min {} > mean {}", b.name, b.min_s, b.mean_s);
        assert!(b.min_s <= b.p50_s + 1e-12, "{}: min {} > p50 {}", b.name, b.min_s, b.p50_s);
        if let Some(t) = b.throughput {
            assert!(t.is_finite() && t > 0.0, "{}: throughput {t}", b.name);
            assert!(b.throughput_unit.is_some(), "{}: rate without a unit", b.name);
        }
    }

    // Schema: serialize -> parse -> typed reload -> identical, and the
    // second serialization is byte-identical (idempotent).
    let text = result.to_json().write();
    let parsed = Value::parse(&text).expect("BENCH json parses");
    let back = SuiteResult::from_json(&parsed).expect("typed reload");
    assert_eq!(result, back, "typed schema round trip");
    assert_eq!(text, back.to_json().write(), "serialization is idempotent");

    // Regression-gate semantics on the real result: identical baseline
    // passes, a doctored 2x-slower current run fails at 1.5x.
    let same = suite::compare(&result, &result, 1.25);
    assert!(same.regressions.is_empty(), "self-compare regressed: {:?}", same.regressions);
    assert_eq!(same.deltas.len(), result.benches.len());

    let mut slowed = result.clone();
    for b in &mut slowed.benches {
        b.mean_s *= 2.0;
    }
    let cmp = suite::compare(&slowed, &result, 1.5);
    // Benches whose quick-mode mean is exactly 0 (sub-resolution timings)
    // yield no verdict; everything measurable must be flagged.
    let measurable =
        result.benches.iter().filter(|b| b.mean_s.is_finite() && b.mean_s > 0.0).count();
    assert!(measurable > 0, "quick suite produced no measurable benches");
    assert_eq!(
        cmp.regressions.len(),
        measurable,
        "every measurable bench must flag a 2x slowdown: {:?}",
        cmp.regressions
    );

    // The mirrored direction — current 2x *faster* than baseline — passes.
    let cmp = suite::compare(&result, &slowed, 1.5);
    assert!(cmp.regressions.is_empty(), "improvements flagged: {:?}", cmp.regressions);
}
