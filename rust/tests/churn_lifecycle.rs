//! End-to-end peer lifecycle on the SimExec backend: the population is
//! chain state, not configuration. Mid-run joiners earn incentive,
//! departures free their slot, a full slot table displaces the
//! lowest-incentive peer, and a recycled uid is a genuinely fresh
//! identity (reset rating/PoC/bucket) — the ISSUE-2 acceptance checks.
//!
//! Populations below include a FormatViolator: eq. 5's normalization
//! subtracts the minimum PEERSCORE, so the worst peer of any round earns
//! exactly zero. The violator pins that floor (its PoC mu never leaves 0),
//! which makes "every honest peer earns" assertable for newcomers too.
//!
//! Deliberately drives the run through the legacy `RunConfig::quick` /
//! `TemplarRunWith::new_sim` shims: during the GauntletBuilder transition
//! these must keep working verbatim, and this file is their coverage.
#![allow(deprecated)]

use gauntlet::chain::ChainError;
use gauntlet::coordinator::run::{RunConfig, TemplarRunWith};
use gauntlet::peers::Behavior;
use gauntlet::scenario::{Event, Scenario};

fn honest() -> Behavior {
    Behavior::Honest { data_mult: 1.0 }
}

fn base_cfg(rounds: u64, peers: Vec<Behavior>) -> RunConfig {
    let mut cfg = RunConfig::quick("nano", rounds, peers);
    cfg.seed = 7;
    cfg.eval_every = 0;
    // Evaluate every valid peer every round so incentives react within a
    // round or two of a population change.
    cfg.params.eval_sample = 16;
    cfg
}

#[test]
fn scenario_join_earns_incentive_and_leave_frees_the_slot() {
    let mut cfg = base_cfg(
        10,
        vec![honest(), honest(), honest(), Behavior::FormatViolator],
    );
    cfg.scenario = Scenario::parse("@3 join honest\n@6 leave 1").unwrap();
    let mut run = TemplarRunWith::new_sim(cfg).expect("sim run");

    // 1 validator (uid 0) + 4 peers (uids 1..=4); the joiner gets uid 5.
    let mut seen_join = false;
    for r in 0..10u64 {
        let rec = run.run_round().expect("round");
        if r == 3 {
            assert!(
                rec.events.iter().any(|e| e.contains("join honest as uid 5")),
                "{:?}",
                rec.events
            );
            seen_join = true;
        }
        if r < 3 {
            assert_eq!(rec.peers.len(), 4);
        }
        if r == 5 {
            assert_eq!(rec.peers.len(), 5);
        }
        if r >= 6 {
            assert_eq!(rec.peers.len(), 4, "uid 1 left at round 6");
            assert!(!rec.peers.iter().any(|p| p.uid == 1));
        }
    }
    assert!(seen_join);

    // The round-3 joiner was paid: permissionless entry is not just
    // tolerated, it earns.
    let joiner = run.chain.neuron(5).expect("joiner registered");
    assert!(joiner.balance > 0.0, "late joiner earned nothing: {}", joiner.balance);

    // uid 1 is gone from the chain, its bucket torn down, and its slot is
    // first in line for reuse.
    assert!(run.chain.neuron(1).is_none());
    assert!(!run.store.bucket_exists("peer-1"));
    let reg = run.register_peer_detailed(honest()).expect("rejoin");
    assert_eq!((reg.uid, reg.recycled), (1, true));
}

#[test]
fn recycled_uid_resets_rating_poc_and_bucket() {
    // Two format violators: uid 3 will deregister and be replaced by an
    // honest operator; uid 4 stays and keeps pinning the incentive floor.
    let cfg = base_cfg(
        12,
        vec![honest(), honest(), Behavior::FormatViolator, Behavior::FormatViolator],
    );
    let mut run = TemplarRunWith::new_sim(cfg).expect("sim run");
    for _ in 0..4 {
        run.run_round().expect("round");
    }
    let bad_uid = 3; // 1 validator + peers at uids 1..=4
    let st = run.validators[0].book.get(bad_uid).expect("tracked");
    assert!(st.fast_fails >= 4, "violator accumulated history: {}", st.fast_fails);
    let old_key = run.chain.neuron(bad_uid).unwrap().bucket_read_key.clone().unwrap();

    // It deregisters and a *new operator* lands on the same uid.
    run.deregister_peer(bad_uid).expect("deregister");
    assert_eq!(
        run.deregister_peer(bad_uid).unwrap_err().downcast::<ChainError>().unwrap(),
        ChainError::UnknownUid(bad_uid)
    );
    let reg = run.register_peer_detailed(honest()).expect("re-register");
    assert_eq!((reg.uid, reg.recycled), (bad_uid, true));

    // Fresh identity: no score-book state survives, the bucket was
    // recreated with a rotated read key, and the chain neuron restarts.
    for v in &run.validators {
        assert!(v.book.get(bad_uid).is_none(), "rating/PoC history must reset");
    }
    let new_key = run.chain.neuron(bad_uid).unwrap().bucket_read_key.clone().unwrap();
    assert_ne!(old_key, new_key, "recycled uid gets a fresh bucket credential");
    assert_eq!(run.chain.neuron(bad_uid).unwrap().balance, 0.0);

    // From its fresh prior the honest re-occupant earns; history of the
    // departed identity neither taxes nor subsidizes it.
    for _ in 0..8 {
        run.run_round().expect("round");
    }
    let st = run.validators[0].book.get(bad_uid).expect("evaluated after rejoin");
    assert_eq!(st.fast_fails, 0, "no inherited fast-fail history");
    assert!(st.evals > 0);
    assert!(
        run.chain.neuron(bad_uid).unwrap().balance > 0.0,
        "honest re-occupant of a recycled uid must earn"
    );
}

#[test]
fn full_slot_table_displaces_the_lowest_incentive_peer() {
    // 1 validator + 4 peers fill a 5-slot table. Both violators earn
    // nothing; the round-4 newcomer displaces the lower-uid one (uid 3),
    // and the other (uid 4) keeps pinning the incentive floor.
    let mut cfg = base_cfg(
        8,
        vec![honest(), honest(), Behavior::FormatViolator, Behavior::FormatViolator],
    );
    cfg.max_uids = 5;
    // 2 rounds of immunity: long enough that the round-4 joiner is still
    // immune when we check after its first round, short enough that the
    // round-0 population is fair game by round 4.
    cfg.immunity_rounds = 2;
    cfg.scenario = Scenario::default()
        .at(4, Event::JoinPeer { behavior: Behavior::Honest { data_mult: 2.0 } });
    let mut run = TemplarRunWith::new_sim(cfg).expect("sim run");

    for r in 0..8u64 {
        let rec = run.run_round().expect("round");
        if r == 4 {
            assert!(
                rec.events
                    .iter()
                    .any(|e| e.contains("join honest-x2 as uid 3") && e.contains("evicted")),
                "lowest-incentive violator (uid 3) should be displaced: {:?}",
                rec.events
            );
            // The newcomer is still inside its immunity window (registered
            // at block 20, immune until block 30; the clock is at 25 now).
            assert!(run.chain.is_immune(3), "newcomer starts immune");
        }
        assert_eq!(rec.peers.len(), 4, "bounded table keeps the population size");
    }
    // The slot now hosts the newcomer (fifth hotkey ever issued), which
    // earned from its fresh prior.
    let n = run.chain.neuron(3).expect("slot occupied");
    assert_eq!(n.hotkey, "peer-hotkey-4");
    assert!(n.balance > 0.0, "displacing newcomer earned: {}", n.balance);
}

#[test]
fn validator_demotion_and_validator_leave_do_not_abort_the_run() {
    // `stake <validator> 0` demotes the (only) validator: it keeps
    // evaluating but can no longer commit, so emission stops — the run
    // itself must carry on. `leave <validator-uid>` is rejected outright.
    let mut cfg = base_cfg(6, vec![honest(), honest()]);
    cfg.scenario = Scenario::parse("@2 leave 0\n@3 stake 0 0").unwrap();
    let mut run = TemplarRunWith::new_sim(cfg).expect("sim run");
    let mut saw_reject = false;
    for r in 0..6u64 {
        let rec = run.run_round().expect("a scripted demotion must not kill the run");
        if r == 2 {
            saw_reject = rec.events.iter().any(|e| e.contains("leave uid 0 rejected"));
            assert!(saw_reject, "{:?}", rec.events);
        }
    }
    assert!(saw_reject);
    let v = run.chain.neuron(0).expect("validator slot survives a scripted leave");
    assert_eq!(v.stake, 0.0, "demotion applied");
    assert!(run.chain.validators().next().is_none(), "no staked validators remain");
    // Demoted at the top of round 3: rounds 3+ paid nothing, so balances
    // froze at their round-2 values.
    let total: f64 = run.chain.neurons().map(|n| n.balance).sum();
    assert!(total > 0.0, "rounds 0-2 paid out before the demotion");
}

#[test]
fn overlapping_outage_windows_extend_rather_than_truncate() {
    // A second outage event landing inside an active window must not cut
    // the first window short: recovery waits for the latest restore round.
    let mut cfg = base_cfg(6, vec![honest(), honest()]);
    cfg.scenario = Scenario::parse("@1 outage 1.0 3\n@2 outage 1.0 1").unwrap();
    let mut run = TemplarRunWith::new_sim(cfg).expect("sim run");
    for r in 0..6u64 {
        let rec = run.run_round().expect("round");
        match r {
            1..=3 => assert_eq!(
                rec.n_valid_submissions, 0,
                "round {r}: the 3-round window from round 1 must hold"
            ),
            4 => {
                assert!(
                    rec.events.iter().any(|e| e.contains("provider recovered")),
                    "{:?}",
                    rec.events
                );
                assert!(rec.n_valid_submissions > 0);
            }
            _ => {}
        }
    }
}

#[test]
fn provider_outage_window_restores_itself() {
    let mut cfg = base_cfg(6, vec![honest(), honest()]);
    cfg.scenario = Scenario::parse("@2 outage 1.0 2").unwrap();
    let mut run = TemplarRunWith::new_sim(cfg).expect("sim run");
    let mut saw_outage = false;
    let mut saw_recovery = false;
    for r in 0..6u64 {
        let rec = run.run_round().expect("round");
        match r {
            2 => {
                assert!(rec.events.iter().any(|e| e.contains("outage")), "{:?}", rec.events);
                saw_outage = true;
                assert_eq!(rec.n_valid_submissions, 0, "total outage drops every PUT");
            }
            3 => assert_eq!(rec.n_valid_submissions, 0, "outage lasts two rounds"),
            4 => {
                assert!(
                    rec.events.iter().any(|e| e.contains("provider recovered")),
                    "{:?}",
                    rec.events
                );
                saw_recovery = true;
                assert!(rec.n_valid_submissions > 0, "submissions flow again");
            }
            _ => {}
        }
    }
    assert!(saw_outage && saw_recovery);
}
