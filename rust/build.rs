fn main() {
    // `--cfg loom` selects the model-checked build of runtime::pool (see
    // rust/tests/loom_pool.rs and the README's "Correctness tooling"
    // section). Declare it so check-cfg-aware toolchains (1.80+) don't
    // flag the cfg as unexpected; older toolchains ignore this directive
    // with a build-script warning, which is harmless.
    println!("cargo:rustc-check-cfg=cfg(loom)");
}
