//! Hot-path microbenchmarks (§Perf): every operation on the validator's
//! and peers' per-round critical path, timed in isolation.
//!
//!   - sparse DeMo aggregation (scatter-add) at several G and C
//!   - wire encode/decode (+ SHA-256 integrity)
//!   - OpenSkill match update
//!   - Yuma consensus epoch at deployed scale (64 validators x 256 peers)
//!   - corpus shard generation
//!   - full-round evaluation pipeline: a 32-peer, 2-validator round on the
//!     SimExec backend swept over worker-thread counts, asserting the
//!     parallel pipeline's PEERSCOREs are bit-identical to the sequential
//!     baseline
//!   - XLA artifact round-trips (grad / demo_compress / eval_peer / apply)
//!
//!     cargo bench --bench hotpath

use gauntlet::bench::{format_speedup, human_duration, save_json, time_it, Table};
use gauntlet::chain::yuma::{yuma_consensus, YumaParams};
use gauntlet::coordinator::engine::GauntletBuilder;
use gauntlet::coordinator::run::RunConfig;
use gauntlet::data::Corpus;
use gauntlet::demo::aggregate::{aggregate_into, AggregateOpts};
use gauntlet::demo::wire::Submission;
use gauntlet::demo::SparseGrad;
use gauntlet::minjson::{self, Value};
use gauntlet::openskill::{PlackettLuce, Rating};
use gauntlet::peers::Behavior;
use gauntlet::runtime::{artifact_dir, artifacts_available, Executor};
use gauntlet::util::Rng;

fn mk_grad(rng: &mut Rng, c: usize, p_pad: usize) -> SparseGrad {
    SparseGrad {
        vals: (0..c).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
        idx: (0..c).map(|_| rng.below(p_pad as u64) as i32).collect(),
    }
}

fn main() -> anyhow::Result<()> {
    let mut results: Vec<(String, f64)> = Vec::new();
    let mut t = Table::new("hot-path microbenchmarks", &["operation", "mean", "throughput"]);
    let mut rng = Rng::new(1);

    // ---- sparse aggregation ------------------------------------------
    for (g, c, p_pad) in [(4usize, 1312usize, 167_936usize), (15, 1312, 167_936), (15, 57_952, 7_372_800)] {
        let grads: Vec<SparseGrad> = (0..g).map(|_| mk_grad(&mut rng, c, p_pad)).collect();
        let refs: Vec<(&SparseGrad, f64)> = grads.iter().map(|gr| (gr, 1.0 / g as f64)).collect();
        let mut dense = vec![0.0f32; p_pad];
        let opts = AggregateOpts::default();
        let timing = time_it(3, 20, || {
            dense.iter_mut().for_each(|x| *x = 0.0);
            aggregate_into(&refs, &mut dense, &opts);
        });
        let vals_per_s = (g * c) as f64 / timing.mean_s;
        t.row(&[
            format!("aggregate G={g} C={c} P'={p_pad}"),
            human_duration(timing.mean_s),
            format!("{:.1} Mcoeff/s", vals_per_s / 1e6),
        ]);
        results.push((format!("aggregate_g{g}_c{c}"), timing.mean_s));
    }

    // ---- wire encode/decode ------------------------------------------
    for c in [1312usize, 57_952] {
        let sub = Submission {
            uid: 3,
            round: 17,
            grad: mk_grad(&mut rng, c, 10_000_000),
            probe: vec![0.5; 150],
        };
        let enc = time_it(3, 30, || {
            let _ = sub.encode();
        });
        let bytes = sub.encode();
        let dec = time_it(3, 30, || {
            let _ = Submission::decode(&bytes).unwrap();
        });
        t.row(&[
            format!("wire encode C={c}"),
            human_duration(enc.mean_s),
            format!("{:.0} MB/s", bytes.len() as f64 / enc.mean_s / 1e6),
        ]);
        t.row(&[
            format!("wire decode C={c}"),
            human_duration(dec.mean_s),
            format!("{:.0} MB/s", bytes.len() as f64 / dec.mean_s / 1e6),
        ]);
        results.push((format!("wire_encode_c{c}"), enc.mean_s));
        results.push((format!("wire_decode_c{c}"), dec.mean_s));
    }

    // ---- openskill ----------------------------------------------------
    let model = PlackettLuce::default();
    let ratings: Vec<Rating> = (0..16).map(|_| model.initial()).collect();
    let scores: Vec<f64> = (0..16).map(|_| rng.next_f64()).collect();
    let os = time_it(5, 200, || {
        let _ = model.rate_by_scores(&ratings, &scores);
    });
    t.row(&["openskill match n=16".into(), human_duration(os.mean_s), String::new()]);
    results.push(("openskill_16".into(), os.mean_s));

    // ---- yuma ----------------------------------------------------------
    let n_val = 64;
    let n_peer = 256;
    let w: Vec<Vec<f64>> =
        (0..n_val).map(|_| (0..n_peer).map(|_| rng.next_f64()).collect()).collect();
    let stake: Vec<f64> = (0..n_val).map(|_| rng.range_f64(1.0, 100.0)).collect();
    let yu = time_it(2, 10, || {
        let _ = yuma_consensus(&w, &stake, &YumaParams::default());
    });
    t.row(&[
        format!("yuma epoch {n_val}x{n_peer}"),
        human_duration(yu.mean_s),
        String::new(),
    ]);
    results.push(("yuma_64x256".into(), yu.mean_s));

    // ---- corpus ---------------------------------------------------------
    let corpus = Corpus::new(4096, 0);
    let cg = time_it(3, 50, || {
        let _ = corpus.assigned_shard(3, 17, 0, 4, 129);
    });
    t.row(&[
        "corpus shard 4x129".into(),
        human_duration(cg.mean_s),
        format!("{:.1} Mtok/s", 4.0 * 129.0 / cg.mean_s / 1e6),
    ]);
    results.push(("corpus_shard".into(), cg.mean_s));

    // ---- parallel round-evaluation pipeline -----------------------------
    // The tentpole path: one full communication round (32 peers taking
    // turns, 2 validators fast-evaluating everyone + primary-evaluating a
    // sample, chain epoch, aggregation) on the SimExec "mid" model, swept
    // over worker-thread counts. PEERSCOREs must be bit-identical at every
    // thread count; the speedup column is the parallelization win.
    {
        const ROUNDS: u64 = 3;
        let mk_run = |threads: usize| {
            let peers: Vec<Behavior> = (0..32)
                .map(|i| match i % 8 {
                    6 => Behavior::Freeloader,
                    7 => Behavior::Poisoner { scale: 100.0 },
                    _ => Behavior::Honest { data_mult: 1.0 },
                })
                .collect();
            let mut cfg = RunConfig {
                model: "mid".to_string(),
                rounds: ROUNDS,
                peers,
                ..RunConfig::default()
            };
            cfg.eval_every = 0;
            cfg.seed = 11;
            cfg.n_validators = 2;
            cfg.params.top_g = 8;
            cfg.params.eval_sample = 4;
            cfg.threads = threads;
            GauntletBuilder::sim().config(cfg).build().expect("sim run")
        };
        let score_bits = |threads: usize| -> Vec<u64> {
            let mut run = mk_run(threads);
            for _ in 0..ROUNDS {
                run.run_round().expect("round");
            }
            let uids = run.peer_uids();
            let mut bits = Vec::with_capacity(run.validators().len() * uids.len());
            for v in run.validators() {
                for &u in &uids {
                    bits.push(v.book.peer_score(u).to_bits());
                }
            }
            bits
        };
        let reference = score_bits(1);
        for threads in [2usize, 4, 8] {
            assert_eq!(
                score_bits(threads),
                reference,
                "PEERSCOREs must be identical at {threads} threads"
            );
        }
        let mut base_mean = 0.0;
        for threads in [1usize, 2, 4, 8] {
            // Pre-build one run per timing iteration so construction cost
            // (init params, peer registration) stays out of the timed
            // region — the sweep measures the round pipeline itself.
            let mut prebuilt: Vec<_> = (0..4).map(|_| mk_run(threads)).collect();
            let timing = time_it(1, 3, || {
                let mut run = prebuilt.pop().expect("prebuilt run");
                for _ in 0..ROUNDS {
                    run.run_round().expect("round");
                }
            });
            if threads == 1 {
                base_mean = timing.mean_s;
            }
            t.row(&[
                format!("round pipeline 32p/2v (threads={threads})"),
                human_duration(timing.mean_s),
                format_speedup(base_mean, timing.mean_s),
            ]);
            results.push((format!("round_pipeline_t{threads}"), timing.mean_s));
        }
    }

    // ---- XLA artifacts --------------------------------------------------
    for cfg in ["nano", "tiny"] {
        if !artifacts_available(cfg) {
            continue;
        }
        // Artifacts exist but may not be executable (stub xla crate);
        // skip rather than fail the whole bench.
        let exec = match Executor::load(artifact_dir(cfg)) {
            Ok(e) => e,
            Err(e) => {
                println!("[skipping xla {cfg} benches: {e:#}]");
                continue;
            }
        };
        let meta = exec.meta.clone();
        let theta = exec.init_params()?;
        let toks = corpus_for(&meta).assigned_shard(1, 0, 0, meta.batch, meta.seq + 1);
        let iters = if cfg == "nano" { 10 } else { 5 };

        let tl = time_it(2, iters, || {
            let _ = exec.loss(&theta, &toks).unwrap();
        });
        let tg = time_it(2, iters, || {
            let _ = exec.grad(&theta, &toks).unwrap();
        });
        let e = vec![0.0f32; meta.param_count];
        let (_, g) = exec.grad(&theta, &toks)?;
        let tc = time_it(2, iters, || {
            let _ = exec.demo_compress(&e, &g, 0.999).unwrap();
        });
        let coeff = vec![0.01f32; meta.padded_count];
        let ta = time_it(2, iters, || {
            let _ = exec.apply_update(&theta, &coeff, 0.02).unwrap();
        });
        let te = time_it(2, iters, || {
            let _ = exec.eval_peer(&theta, &coeff, 0.01, &toks, &toks).unwrap();
        });
        for (name, timing) in
            [("loss", &tl), ("grad", &tg), ("demo_compress", &tc), ("apply_update", &ta), ("eval_peer", &te)]
        {
            let toks_per_s = (meta.batch * meta.seq) as f64 / timing.mean_s;
            t.row(&[
                format!("xla {cfg}/{name}"),
                human_duration(timing.mean_s),
                if name == "loss" || name == "grad" {
                    format!("{:.1} ktok/s", toks_per_s / 1e3)
                } else {
                    String::new()
                },
            ]);
            results.push((format!("xla_{cfg}_{name}"), timing.mean_s));
        }
    }

    t.print();
    save_json(
        "hotpath",
        &Value::Arr(
            results
                .iter()
                .map(|(k, v)| {
                    minjson::obj(vec![("op", minjson::s(k)), ("mean_s", minjson::num(*v))])
                })
                .collect(),
        ),
    );
    Ok(())
}

fn corpus_for(meta: &gauntlet::runtime::ModelMeta) -> Corpus {
    Corpus::new(meta.vocab as u32, 0)
}
