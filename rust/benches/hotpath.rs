//! Thin wrapper over the PerfLab `hotpath` suite (`bench::suite`): every
//! operation on the validator's and peers' per-round critical path, timed
//! in isolation, plus the full-round thread sweep. Results are saved as
//! `bench_results/BENCH_hotpath.json` in the same schema `gauntlet bench`
//! emits, so they diff against `baseline/BENCH_hotpath.json`.
//!
//!     cargo bench --bench hotpath [-- quick]

use gauntlet::bench::suite::{self, BenchCtx};

fn main() -> anyhow::Result<()> {
    // cargo bench passes its own flags (e.g. --bench) to the binary; only
    // bare words select modes.
    let quick = std::env::args().skip(1).any(|a| a == "quick");
    let spec = suite::find_suite("hotpath").expect("hotpath suite is registered");
    let result = suite::run_suite(&spec, &BenchCtx { quick })?;
    suite::save_default(&result)?;
    // Compiled-artifact round-trips are machine/artifact dependent, so they
    // print for humans instead of entering the baseline-diffed schema.
    suite::xla_extras()
}
