//! Thin wrapper over [`gauntlet::bench::figures::fig1`]: Templar
//! permissionless loss curve vs AdamW DDP baseline (the paper's headline
//! figure at `nano` scale). Prints the two series and writes
//! `bench_results/fig1.json`.
//!
//!     cargo bench --bench fig1_training_curve [-- <rounds>]

fn main() -> anyhow::Result<()> {
    let rounds: u64 = std::env::args()
        .skip(1)
        .find(|a| a.chars().all(|c| c.is_ascii_digit()))
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(40);
    gauntlet::bench::figures::fig1(rounds)
}
