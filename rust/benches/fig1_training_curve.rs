//! Fig. 1 bench: Templar permissionless loss curve vs AdamW DDP baseline.
//!
//! Regenerates the paper's headline figure at `nano` scale: a Gauntlet run
//! with heterogeneous permissionless peers against a centralized AdamW
//! baseline with the same worker count and per-worker batch size. Prints
//! the two series and writes them to bench_results/fig1.json.
//!
//! Paper-shape expectations: the Gauntlet run converges (and early on can
//! beat the per-round baseline, since incentives push peers to process
//! more data), while remaining fully permissionless.
//!
//!     cargo bench --bench fig1_training_curve [-- <rounds>]

use gauntlet::bench::{save_json, series_json, sparkline, Table};
use gauntlet::coordinator::baseline::{AdamWParams, AdamWTrainer};
use gauntlet::coordinator::engine::GauntletBuilder;
use gauntlet::coordinator::run::RunConfig;
use gauntlet::data::Corpus;
use gauntlet::minjson;
use gauntlet::peers::Behavior;
use gauntlet::runtime::{artifact_dir, artifacts_available, Executor};

fn main() -> anyhow::Result<()> {
    if !artifacts_available("nano") {
        println!("fig1: artifacts missing; run `make artifacts` first");
        return Ok(());
    }
    let rounds: u64 = std::env::args()
        .skip(1)
        .find(|a| a.chars().all(|c| c.is_ascii_digit()))
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(40);

    // Incentivized population: data multipliers above 1 are what the
    // incentive buys the network (paper §6: "participants were successfully
    // incentivized to process more data").
    let peers = vec![
        Behavior::Honest { data_mult: 2.0 },
        Behavior::Honest { data_mult: 1.5 },
        Behavior::Honest { data_mult: 1.0 },
        Behavior::Honest { data_mult: 1.0 },
        Behavior::Honest { data_mult: 1.0 },
        Behavior::Freeloader,
    ];
    let n_workers = 5;

    let mut cfg = RunConfig {
        model: "nano".to_string(),
        rounds,
        peers,
        ..RunConfig::default()
    };
    cfg.eval_every = 2;
    cfg.params.top_g = 4;
    println!("fig1: gauntlet ({} peers) vs adamw ({} workers), {rounds} rounds", 6, n_workers);

    let mut run = GauntletBuilder::artifact().config(cfg).build()?;
    let mut g_curve = Vec::new();
    let mut tokens_gauntlet: u64 = 0;
    for _ in 0..rounds {
        let rec = run.run_round()?;
        tokens_gauntlet += rec.tokens_processed;
        if let Some(l) = rec.heldout_loss {
            g_curve.push((rec.round as f64, l));
        }
    }

    let exec = Executor::load(artifact_dir("nano"))?;
    let corpus = Corpus::new(exec.meta.vocab as u32, 0);
    let mut trainer = AdamWTrainer::new(exec.init_params()?, AdamWParams::default(), n_workers);
    let mut a_curve = Vec::new();
    let mut tokens_adamw: u64 = 0;
    for r in 0..rounds {
        trainer.step(&exec, &corpus, r)?;
        tokens_adamw += (n_workers * exec.meta.batch * exec.meta.seq) as u64;
        if r % 2 == 0 {
            let toks = corpus.heldout(0, exec.meta.batch, exec.meta.seq + 1);
            a_curve.push((r as f64, exec.loss(&trainer.theta, &toks)? as f64));
        }
    }

    let gl: Vec<f64> = g_curve.iter().map(|(_, y)| *y).collect();
    let al: Vec<f64> = a_curve.iter().map(|(_, y)| *y).collect();
    let mut t = Table::new("Fig. 1 — heldout loss by round", &["round", "templar (gauntlet)", "adamw ddp"]);
    for (i, (r, gy)) in g_curve.iter().enumerate() {
        let ay = a_curve.get(i).map(|(_, y)| format!("{y:.4}")).unwrap_or_default();
        t.row(&[format!("{r}"), format!("{gy:.4}"), ay]);
    }
    t.print();
    println!("  templar {}", sparkline(&gl, 50));
    println!("  adamw   {}", sparkline(&al, 50));
    println!(
        "  tokens: templar={tokens_gauntlet} adamw={tokens_adamw} (incentivized peers processed {:.2}x)",
        tokens_gauntlet as f64 / tokens_adamw as f64
    );
    println!(
        "  final: templar={:.4} adamw={:.4}",
        gl.last().unwrap(),
        al.last().unwrap()
    );

    save_json(
        "fig1",
        &minjson::obj(vec![
            ("gauntlet", series_json(&g_curve)),
            ("adamw", series_json(&a_curve)),
            ("tokens_gauntlet", minjson::num(tokens_gauntlet as f64)),
            ("tokens_adamw", minjson::num(tokens_adamw as f64)),
        ]),
    );
    Ok(())
}
