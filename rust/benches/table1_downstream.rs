//! Thin wrapper over [`gauntlet::bench::figures::table1`]: downstream
//! zero-shot evaluation of the permissionless checkpoint vs the AdamW-DDP
//! checkpoint vs the untrained model.
//!
//!     cargo bench --bench table1_downstream [-- <rounds> <items>]

fn main() -> anyhow::Result<()> {
    let mut tail =
        std::env::args().skip(1).filter(|a| a.chars().all(|c| c.is_ascii_digit()));
    let rounds: u64 = tail.next().map(|s| s.parse()).transpose()?.unwrap_or(30);
    let items: usize = tail.next().map(|s| s.parse()).transpose()?.unwrap_or(60);
    gauntlet::bench::figures::table1(rounds, items)
}
