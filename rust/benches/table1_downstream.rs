//! Table 1 bench: downstream zero-shot evaluation of the permissionless
//! checkpoint vs the AdamW-DDP checkpoint vs the untrained model.
//!
//! Reproduces the paper's protocol (acc_norm = argmin length-normalized
//! loss over candidates) on the synthetic suites. Paper-shape expectation:
//! TEMPLAR ~= AdamW, both >> untrained/chance.
//!
//!     cargo bench --bench table1_downstream [-- <rounds> <items>]

use gauntlet::bench::{save_json, Table};
use gauntlet::coordinator::baseline::{AdamWParams, AdamWTrainer};
use gauntlet::coordinator::engine::GauntletBuilder;
use gauntlet::coordinator::run::RunConfig;
use gauntlet::data::Corpus;
use gauntlet::eval::{evaluate_suite, Suite};
use gauntlet::minjson::{self, Value};
use gauntlet::peers::Behavior;
use gauntlet::runtime::{artifact_dir, artifacts_available, Executor};

fn main() -> anyhow::Result<()> {
    if !artifacts_available("nano") {
        println!("table1: artifacts missing; run `make artifacts` first");
        return Ok(());
    }
    let mut tail =
        std::env::args().skip(1).filter(|a| a.chars().all(|c| c.is_ascii_digit()));
    let rounds: u64 = tail.next().map(|s| s.parse()).transpose()?.unwrap_or(30);
    let items: usize = tail.next().map(|s| s.parse()).transpose()?.unwrap_or(60);

    // Train both systems on the same token budget.
    let peers = vec![Behavior::Honest { data_mult: 1.0 }; 5];
    let mut cfg = RunConfig {
        model: "nano".to_string(),
        rounds,
        peers,
        ..RunConfig::default()
    };
    cfg.eval_every = 0;
    println!("table1: training templar + adamw for {rounds} rounds, then {items} items/suite");
    let mut run = GauntletBuilder::artifact().config(cfg).build()?;
    for _ in 0..rounds {
        run.run_round()?;
    }
    let theta_templar = run.theta().to_vec();

    let exec = Executor::load(artifact_dir("nano"))?;
    let corpus = Corpus::new(exec.meta.vocab as u32, 0);
    let mut trainer = AdamWTrainer::new(exec.init_params()?, AdamWParams::default(), 5);
    for r in 0..rounds {
        trainer.step(&exec, &corpus, r)?;
    }

    let theta_init = exec.init_params()?;
    let rows: Vec<(&str, &Vec<f32>)> = vec![
        ("TEMPLAR (gauntlet)", &theta_templar),
        ("AdamW DDP", &trainer.theta),
        ("untrained", &theta_init),
    ];

    let mut t = Table::new(
        "Table 1 — zero-shot acc_norm (synthetic analogues)",
        &["model", "synth-hellaswag", "synth-piqa", "synth-arc-e"],
    );
    let mut json_rows = Vec::new();
    for (name, theta) in &rows {
        let mut cells = vec![name.to_string()];
        let mut obj = vec![("model", minjson::s(name))];
        for suite in Suite::all() {
            let r = evaluate_suite(&exec, theta, &corpus, suite, items)?;
            cells.push(format!("{:.3}", r.acc_norm));
            obj.push((suite.name(), minjson::num(r.acc_norm)));
        }
        t.row(&cells);
        json_rows.push(minjson::obj(obj));
    }
    t.row(&[
        "chance".into(),
        "0.250".into(),
        "0.500".into(),
        "0.250".into(),
    ]);
    t.print();
    println!("\n(paper Table 1 shape: trained models comparable, both above chance)");
    save_json("table1", &Value::Arr(json_rows));
    Ok(())
}
