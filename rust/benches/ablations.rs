//! Thin wrapper over [`gauntlet::bench::figures::ablations`]: the design
//! choices the paper calls out in prose —
//!
//!   beta      §3.1 — beta = c*alpha with c < 1 reduces LossScore noise and
//!             negative-score rate (run with `-- beta`)
//!   incentive §3.3 — the c=2 power normalization concentrates incentive on
//!             few strong peers (run with `-- incentive`)
//!   sync      §3.2 — SyncScore grows ~linearly with the number of signed
//!             steps a peer lags; threshold 3 separates (run with `-- sync`)
//!   byzantine §4  — encoded-domain normalization neutralizes rescaling
//!             (run with `-- byzantine`)
//!
//! No argument runs all four.

fn main() -> anyhow::Result<()> {
    // cargo bench passes its own flags (e.g. --bench) to the binary;
    // only bare words select sub-studies.
    let which: Vec<String> =
        std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    gauntlet::bench::figures::ablations(&which)
}
