//! Ablation benches for the design choices the paper calls out in prose:
//!
//!   beta      §3.1 — beta = c*alpha with c < 1 reduces LossScore noise and
//!             negative-score rate (run with `-- beta`)
//!   incentive §3.3 — the c=2 power normalization concentrates incentive on
//!             few strong peers (run with `-- incentive`)
//!   sync      §3.2 — SyncScore grows ~linearly with the number of signed
//!             steps a peer lags; threshold 3 separates (run with `-- sync`)
//!   byzantine §4  — encoded-domain normalization neutralizes rescaling
//!             (run with `-- byzantine`)
//!
//! No argument runs all four.

use gauntlet::bench::{save_json, Table};
use gauntlet::coordinator::fast_eval::sync_score;
use gauntlet::coordinator::scoring::normalize_scores;
use gauntlet::data::Corpus;
use gauntlet::demo::aggregate::{aggregate, AggregateOpts};
use gauntlet::demo::SparseGrad;
use gauntlet::minjson::{self, Value};
use gauntlet::runtime::{artifact_dir, artifacts_available, Executor};
use gauntlet::util::{mean, sign, std_dev, Rng};

fn main() -> anyhow::Result<()> {
    // cargo bench passes its own flags (e.g. --bench) to the binary;
    // only bare words select sub-studies.
    let which: Vec<String> =
        std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let all = which.is_empty();
    let has = |n: &str| all || which.iter().any(|w| w == n);

    if has("incentive") {
        ablate_incentive();
    }
    if has("byzantine") {
        ablate_byzantine();
    }
    if !artifacts_available("nano") {
        println!("\n[beta/sync ablations need artifacts; run `make artifacts`]");
        return Ok(());
    }
    let exec = Executor::load(artifact_dir("nano"))?;
    if has("sync") {
        ablate_sync(&exec)?;
    }
    if has("beta") {
        ablate_beta(&exec)?;
    }
    Ok(())
}

/// §3.3: one user with 10 GPUs as ONE strong peer vs TEN weak peers.
fn ablate_incentive() {
    // A network of peers with a spread of PEERSCOREs (weakest at 0 so the
    // eq. 5 min-shift keeps everyone's relative position). The user in
    // question either consolidates its 10 GPUs into ONE strong peer
    // (score 10) or splits them into TEN weak peers (score 1 each).
    let field = [6.0, 5.0, 4.0, 3.0, 0.0];
    let one_strong: Vec<f64> = std::iter::once(10.0).chain(field).collect();
    let ten_weak: Vec<f64> = vec![1.0; 10].into_iter().chain(field).collect();
    let mut t = Table::new(
        "§3.3 incentive concentration: one 10-GPU peer vs ten 1-GPU peers",
        &["norm power c", "share (1 strong peer)", "share (10 weak peers total)", "strong/weak"],
    );
    let mut json = Vec::new();
    for c in [1.0, 2.0, 3.0] {
        let s = normalize_scores(&one_strong, c)[0];
        let w: f64 = normalize_scores(&ten_weak, c)[..10].iter().sum();
        t.row(&[
            format!("{c}"),
            format!("{:.3}", s),
            format!("{:.3}", w),
            format!("{:.2}x", s / w.max(1e-9)),
        ]);
        json.push(minjson::obj(vec![
            ("c", minjson::num(c)),
            ("strong", minjson::num(s)),
            ("weak", minjson::num(w)),
        ]));
    }
    t.print();
    println!("(c=2, the paper's choice, rewards consolidating GPUs into one strong peer)");
    save_json("ablation_incentive", &Value::Arr(json));
}

/// §4: rescaling attack in the encoded domain, with/without normalization.
fn ablate_byzantine() {
    let mut rng = Rng::new(7);
    let p_pad = 4096;
    let c = 256;
    let mk = |rng: &mut Rng, scale: f32| SparseGrad {
        vals: (0..c).map(|_| rng.normal_f32(0.0, scale)).collect(),
        idx: (0..c).map(|_| rng.below(p_pad as u64) as i32).collect(),
    };
    let honest: Vec<SparseGrad> = (0..4).map(|_| mk(&mut rng, 1.0)).collect();
    let attacker = mk(&mut rng, 1000.0);

    let cos = |a: &[f32], b: &[f32]| {
        let dot: f64 = a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64).sum();
        let na: f64 = a.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
        let nb: f64 = b.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
        dot / (na * nb).max(1e-12)
    };

    let mut t = Table::new(
        "§4 rescaling attack (x1000): aggregate fidelity vs honest-only",
        &["normalization", "cosine(honest-only, with-attacker)", "attacker share of L2"],
    );
    let mut json = Vec::new();
    for normalize in [true, false] {
        let opts = AggregateOpts { normalize, ..Default::default() };
        let w = 1.0 / 5.0;
        let honest_refs: Vec<(&SparseGrad, f64)> = honest.iter().map(|g| (g, w)).collect();
        let clean = aggregate(&honest_refs, p_pad, &opts);
        let mut with_att = honest_refs.clone();
        with_att.push((&attacker, w));
        let dirty = aggregate(&with_att, p_pad, &opts);
        let att_only = aggregate(&[(&attacker, w)], p_pad, &opts);
        let att_norm: f64 = att_only.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
        let dirty_norm: f64 = dirty.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
        let fidelity = cos(&clean, &dirty);
        t.row(&[
            if normalize { "ON (paper)" } else { "OFF" }.to_string(),
            format!("{:.4}", fidelity),
            format!("{:.3}", att_norm / dirty_norm.max(1e-12)),
        ]);
        json.push(minjson::obj(vec![
            ("normalize", Value::Bool(normalize)),
            ("fidelity", minjson::num(fidelity)),
        ]));
    }
    t.print();
    println!("(normalization keeps the aggregate pointing where honest peers point)");
    save_json("ablation_byzantine", &Value::Arr(json));
}

/// §3.2: SyncScore vs actual lag in signed steps.
fn ablate_sync(exec: &Executor) -> anyhow::Result<()> {
    let meta = &exec.meta;
    let mut theta = exec.init_params()?;
    let stale = theta.clone();
    let mut rng = Rng::new(3);
    // DeMo updates are momentum-correlated across adjacent rounds (error
    // feedback, decay 0.999), so a stale peer's divergence grows close to
    // linearly in lag — model that with a persistent base direction plus
    // fresh per-round noise.
    let mut base = vec![0.0f32; meta.padded_count];
    for _ in 0..meta.coeff_count {
        let i = rng.below(meta.padded_count as u64) as usize;
        base[i] += rng.normal_f32(0.0, 1.0);
    }
    let mut t = Table::new(
        "§3.2 SyncScore vs true lag (threshold = 3)",
        &["lag (rounds)", "SyncScore", "passes filter"],
    );
    let mut json = Vec::new();
    for lag in 0..=6u32 {
        let probe_peer = meta.sync_probe(&stale);
        let probe_val = meta.sync_probe(&theta);
        let s = sync_score(&probe_val, &probe_peer, 0.02);
        t.row(&[lag.to_string(), format!("{s:.3}"), (s <= 3.0).to_string()]);
        json.push(minjson::obj(vec![
            ("lag", minjson::num(lag as f64)),
            ("sync_score", minjson::num(s)),
        ]));
        // validator takes one more signed, momentum-correlated update step
        let coeff: Vec<f32> =
            base.iter().map(|b| b + 0.3 * rng.normal_f32(0.0, 1.0) * (*b != 0.0) as u8 as f32).collect();
        theta = exec.apply_update(&theta, &coeff, 0.02)?;
    }
    t.print();
    println!("(score grows ~linearly with lag under momentum-correlated updates; the threshold-3 filter rejects ~>=4-step-stale peers)");
    save_json("ablation_sync", &Value::Arr(json));
    Ok(())
}

/// §3.1: beta = c*alpha sweep — negative-LossScore rate and rank stability.
fn ablate_beta(exec: &Executor) -> anyhow::Result<()> {
    let meta = &exec.meta;
    let corpus = Corpus::new(meta.vocab as u32, 0);
    let theta = exec.init_params()?;
    let (b, s1) = (meta.batch, meta.seq + 1);
    let lr = 0.02f32;

    // Four honest peers' pseudo-gradients with different data amounts
    // (1..4 microbatches) — ground-truth quality ranking is 4 > 3 > 2 > 1.
    let mut grads = Vec::new();
    for (uid, n_mb) in [(1u32, 1usize), (2, 2), (3, 3), (4, 4)] {
        let mut acc = vec![0.0f32; meta.param_count];
        for mb in 0..n_mb {
            let toks = corpus.assigned_shard(uid, 0, mb as u32, b, s1);
            let (_, g) = exec.grad(&theta, &toks)?;
            for (a, gi) in acc.iter_mut().zip(&g) {
                *a += gi / n_mb as f32;
            }
        }
        let e = vec![0.0f32; meta.param_count];
        let (vals, idx, _) = exec.demo_compress(&e, &acc, 0.999)?;
        let mut dense = vec![0.0f32; meta.padded_count];
        let g = SparseGrad { vals, idx };
        let n = g.l2_norm();
        g.scatter_into(&mut dense, (1.0 / n) as f32);
        grads.push(dense);
    }

    let mut t = Table::new(
        "§3.1 beta sweep (beta = c * alpha): LossScore quality over 6 data draws",
        &["c", "mean score", "score std", "neg rate", "rank stability"],
    );
    let mut json = Vec::new();
    for c in [0.25f32, 0.5, 1.0, 2.0] {
        let beta = c * lr;
        let mut all_scores: Vec<f64> = Vec::new();
        let mut orderings: Vec<Vec<usize>> = Vec::new();
        for draw in 0..6u32 {
            let tok = corpus.random_eval(1000 + draw as u64, draw, b, s1);
            let mut scores = Vec::new();
            for dense in &grads {
                let (_, _, l0, l1) = exec.eval_peer(&theta, dense, beta, &tok, &tok)?;
                scores.push(l0 as f64 - l1 as f64);
            }
            all_scores.extend(&scores);
            let mut order: Vec<usize> = (0..scores.len()).collect();
            order.sort_by(|&i, &j| scores[j].partial_cmp(&scores[i]).unwrap());
            orderings.push(order);
        }
        // rank stability: mean pairwise agreement of the top choice
        let top_counts = orderings.iter().filter(|o| o[0] == orderings[0][0]).count();
        let stability = top_counts as f64 / orderings.len() as f64;
        let neg_rate =
            all_scores.iter().filter(|s| **s < 0.0).count() as f64 / all_scores.len() as f64;
        t.row(&[
            format!("{c}"),
            format!("{:+.4}", mean(&all_scores)),
            format!("{:.4}", std_dev(&all_scores)),
            format!("{:.2}", neg_rate),
            format!("{:.2}", stability),
        ]);
        json.push(minjson::obj(vec![
            ("c", minjson::num(c as f64)),
            ("mean", minjson::num(mean(&all_scores))),
            ("std", minjson::num(std_dev(&all_scores))),
            ("neg_rate", minjson::num(neg_rate)),
            ("stability", minjson::num(stability)),
        ]));
        let _ = sign(0.0); // keep util::sign linked into the bench build
    }
    t.print();
    println!("(paper: smaller c => fewer negative scores, more consistent rankings)");
    save_json("ablation_beta", &Value::Arr(json));
    Ok(())
}
