//! Fig. 2 bench: LossScore / LossRating evolution for three peer types —
//! 2x-data, desynchronized (3-round pause), and baseline — each evaluated
//! every round (S = K, the paper's controlled simulation).
//!
//! Paper-shape expectations: per-round LossScore is noisy; LossRating
//! separates the 2x-data peer upward and the desynchronized peer downward.
//!
//!     cargo bench --bench fig2_loss_rating [-- <rounds>]

use gauntlet::bench::{save_json, sparkline, Table};
use gauntlet::coordinator::engine::GauntletBuilder;
use gauntlet::coordinator::run::RunConfig;
use gauntlet::minjson::{self, Value};
use gauntlet::peers::Behavior;
use gauntlet::runtime::artifacts_available;
use gauntlet::util::{mean, std_dev};

fn main() -> anyhow::Result<()> {
    if !artifacts_available("nano") {
        println!("fig2: artifacts missing; run `make artifacts` first");
        return Ok(());
    }
    let rounds: u64 = std::env::args()
        .skip(1)
        .find(|a| a.chars().all(|c| c.is_ascii_digit()))
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(30);
    let desync_at = 5;

    let peers = vec![
        Behavior::Honest { data_mult: 2.0 },
        Behavior::Desync { at: desync_at, pause: 3 },
        Behavior::Honest { data_mult: 1.0 },
    ];
    let mut cfg = RunConfig {
        model: "nano".to_string(),
        rounds,
        peers,
        ..RunConfig::default()
    };
    cfg.params.eval_sample = 3;
    cfg.params.top_g = 3;
    cfg.eval_every = 0;

    let mut run = GauntletBuilder::artifact().config(cfg).build()?;
    let labels = ["2x-data", "desync", "baseline"];
    let mut scores: Vec<Vec<Option<f64>>> = vec![Vec::new(); 3];
    let mut ratings: Vec<Vec<f64>> = vec![Vec::new(); 3];
    for _ in 0..rounds {
        let rec = run.run_round()?;
        for (i, p) in rec.peers.iter().enumerate() {
            scores[i].push(p.loss_score_rand);
            ratings[i].push(p.rating_mu);
        }
    }

    let mut t = Table::new(
        "Fig. 2 — per-round LossScore (rand) / LossRating",
        &["peer", "score mean", "score std", "rating start", "rating end", "rating sparkline"],
    );
    for i in 0..3 {
        let s: Vec<f64> = scores[i].iter().flatten().copied().collect();
        t.row(&[
            labels[i].to_string(),
            format!("{:+.4}", mean(&s)),
            format!("{:.4}", std_dev(&s)),
            format!("{:.2}", ratings[i].first().unwrap()),
            format!("{:.2}", ratings[i].last().unwrap()),
            sparkline(&ratings[i], 30),
        ]);
    }
    t.print();

    // Shape assertions (reported, not fatal — this is a bench).
    let end = |i: usize| *ratings[i].last().unwrap();
    println!("\nshape check (paper Fig. 2):");
    println!(
        "  2x-data rating > baseline rating: {} ({:.2} vs {:.2})",
        end(0) > end(2),
        end(0),
        end(2)
    );
    println!(
        "  desync rating < baseline rating:  {} ({:.2} vs {:.2})",
        end(1) < end(2),
        end(1),
        end(2)
    );
    let noisy = {
        let s: Vec<f64> = scores[2].iter().flatten().copied().collect();
        std_dev(&s) > 0.1 * mean(&s).abs()
    };
    println!("  LossScore noisy round-to-round:   {noisy}");

    save_json(
        "fig2",
        &minjson::obj(vec![(
            "peers",
            Value::Arr(
                (0..3)
                    .map(|i| {
                        minjson::obj(vec![
                            ("label", minjson::s(labels[i])),
                            (
                                "scores",
                                Value::Arr(
                                    scores[i]
                                        .iter()
                                        .map(|o| o.map(minjson::num).unwrap_or(Value::Null))
                                        .collect(),
                                ),
                            ),
                            ("ratings", minjson::arr_f64(&ratings[i])),
                        ])
                    })
                    .collect(),
            ),
        )]),
    );
    Ok(())
}
