//! Thin wrapper over [`gauntlet::bench::figures::fig2`]: LossScore /
//! LossRating evolution for three peer types — 2x-data, desynchronized
//! (3-round pause), and baseline — each evaluated every round (S = K, the
//! paper's controlled simulation).
//!
//!     cargo bench --bench fig2_loss_rating [-- <rounds>]

fn main() -> anyhow::Result<()> {
    let rounds: u64 = std::env::args()
        .skip(1)
        .find(|a| a.chars().all(|c| c.is_ascii_digit()))
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(30);
    gauntlet::bench::figures::fig2(rounds)
}
