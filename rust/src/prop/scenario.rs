//! Property-based scenario fuzzer over full engine runs (the adversary-zoo
//! tentpole): generate a random churn + adversary script, run it through a
//! real [`GauntletEngine`](crate::coordinator::engine::GauntletEngine), and
//! assert the paper's incentive-security claims as machine-checked
//! invariants. Every failure is reproducible standalone: the
//! [`crate::prop::check`] harness prints `case`/`seed`/`size`, and
//! `gauntlet soak --repro <seed> --size <n>` re-runs exactly one case.
//!
//! ## Per-round invariants ([`InvariantTracker`], shared with `gauntlet soak`)
//!
//! - every incentive is finite and non-negative, and the per-round sum over
//!   peers never exceeds `1.0 + eps` (Yuma emission is a normalized split);
//! - balances are finite, non-negative, and monotone non-decreasing per uid
//!   (emission only accrues), with the per-uid baseline reset on any
//!   lifecycle event because eviction recycles uids;
//! - PEERSCORE, PoC mu, and OpenSkill ratings stay finite.
//!
//! ## End-of-run invariants
//!
//! Dominance is asserted per *class* ([`crate::peers::Behavior::class`]),
//! over peers registered since round 0 that survived to the end (mid-run
//! joiners haven't had time to be punished; evicted adversaries already
//! lost):
//!
//! - **strictly punished** classes (`copier`, `copycat`, `duplicator`,
//!   `format`, `freeloader`, `poisoner`, `sybil`): mean balance strictly
//!   below the honest mean — these attacks are *detected* (PoC, fast eval)
//!   and driven to near-zero weight, §5 of the paper;
//! - **neutralized** classes (`desync`, `late`, `rescaler`, `silent`,
//!   `slowloris`, `stale`): mean balance bounded by a small multiple of the
//!   best honest balance — the defense (gradient normalization, the put
//!   window, sync scoring) removes the *advantage*, so parity with honest
//!   work is the correct bound, not strict loss;
//! - `briber` is excluded here: its payoff flips on the bribed validator's
//!   stake share (Yuma clips minority bribes, majority bribes succeed — the
//!   paper's stake-security assumption), and the generator caps scripted
//!   stake moves below validator 0's stake precisely so the fuzzer stays in
//!   the clipped regime. Both regimes are pinned by the targeted tests in
//!   `rust/tests/adversary_zoo.rs`;
//! - surviving `copier`/`copycat`/`duplicator`/`sybil` peers end at
//!   near-zero *incentive* (not just balance), i.e. the mechanism converges
//!   to eviction-or-starvation for plagiarists;
//! - on a random subset of cases: a mid-run snapshot, resumed in a fresh
//!   engine, reaches a bit-identical [`fingerprint`]; and
//!   [`replay_trace`] over the emitted JSONL reproduces the live
//!   [`RunMetrics`] exactly.
//!
//! [`fingerprint`]: crate::coordinator::engine::GauntletEngine::fingerprint
//! [`replay_trace`]: crate::coordinator::events::replay_trace
//! [`RunMetrics`]: crate::coordinator::run::RunMetrics

use std::collections::BTreeMap;
use std::fmt;

use crate::chain::Uid;
use crate::coordinator::engine::GauntletBuilder;
use crate::coordinator::events::{replay_trace, JsonlTraceObserver};
use crate::coordinator::run::RoundRecord;
use crate::peers::Behavior;
use crate::scenario::{Event, Scenario};
use crate::util::Rng;

/// Generate any [`Behavior`] variant with random well-formed parameters.
/// All numeric parameters are dyadic rationals so `parse_spec(spec())`
/// round-trips bit-exactly through shortest-roundtrip float formatting.
/// Referenced uids are drawn below `uid_bound`.
pub fn arbitrary_behavior(rng: &mut Rng, uid_bound: u64) -> Behavior {
    let bound = uid_bound.max(1);
    match rng.below(15) {
        0 => Behavior::Honest { data_mult: 1.0 + rng.below(32) as f64 / 16.0 },
        1 => Behavior::Freeloader,
        2 => Behavior::Desync { at: rng.below(10), pause: 1 + rng.below(5) },
        3 => Behavior::Late { prob: rng.below(64) as f64 / 64.0 },
        4 => Behavior::Silent { prob: rng.below(64) as f64 / 64.0 },
        5 => Behavior::FormatViolator,
        6 => Behavior::Rescaler { factor: 1.0 + rng.below(1024) as f32 / 16.0 },
        7 => Behavior::Poisoner { scale: 1.0 + rng.below(1024) as f32 / 16.0 },
        8 => Behavior::Copier { victim: rng.below(bound) as Uid },
        9 => Behavior::Duplicator { original: rng.below(bound) as Uid },
        10 => Behavior::Sybil {
            ring: rng.below(100),
            eps: (1 + rng.below(63)) as f32 / 256.0,
        },
        11 => Behavior::CopycatNoise {
            victim: rng.below(bound) as Uid,
            noise: (1 + rng.below(63)) as f32 / 256.0,
        },
        12 => Behavior::Briber { validator: rng.below(bound) as Uid },
        13 => Behavior::SlowLoris,
        _ => Behavior::StaleReplayer { lag: 1 + rng.below(6) },
    }
}

/// Generate a random [`Scenario`] exercising every event kind, sized by the
/// harness `size` hint. Used by the grammar round-trip property; the engine
/// fuzzer builds its scripts with [`FuzzScript::generate`] instead, which
/// keeps churn inside envelopes the dominance invariants assume.
pub fn arbitrary_scenario(rng: &mut Rng, size: usize) -> Scenario {
    let mut s = Scenario::new();
    for _ in 0..rng.below(size as u64 / 4 + 3) {
        let round = rng.below(16);
        let ev = match rng.below(7) {
            0 => Event::JoinPeer { behavior: arbitrary_behavior(rng, 8) },
            1 => Event::LeavePeer { uid: rng.below(12) as Uid },
            2 => Event::SetStake {
                uid: rng.below(8) as Uid,
                amount: rng.below(2000) as f64 / 4.0,
            },
            3 => Event::ProviderOutage {
                prob: rng.below(32) as f64 / 64.0,
                rounds: 1 + rng.below(3),
            },
            // Chaos probabilities stay dyadic (n/64) so the compact and
            // JSON grammar forms round-trip bit-exactly.
            4 => Event::ChaosGetFail {
                prob: rng.below(32) as f64 / 64.0,
                rounds: 1 + rng.below(3),
            },
            5 => Event::ChaosCorrupt {
                prob: rng.below(32) as f64 / 64.0,
                rounds: 1 + rng.below(3),
            },
            _ => Event::Eclipse {
                validator: rng.below(3) as Uid,
                peer: rng.below(12) as Uid,
                rounds: 1 + rng.below(3),
            },
        };
        s = s.at(round, ev);
    }
    s
}

/// One complete fuzz case: engine seed, population, and churn script.
/// `Display` renders everything needed to rebuild the case by hand.
#[derive(Clone, Debug)]
pub struct FuzzScript {
    /// Engine seed (distinct from the harness seed that generated it).
    pub seed: u64,
    pub rounds: u64,
    pub n_validators: usize,
    /// Round-0 peer population; uid `n_validators + i` gets `peers[i]`.
    pub peers: Vec<Behavior>,
    pub scenario: Scenario,
    /// `Some(cap)` exercises Bittensor-style lowest-incentive eviction by
    /// sizing the uid table one above the initial population.
    pub max_uids: Option<usize>,
}

impl fmt::Display for FuzzScript {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let specs: Vec<String> = self.peers.iter().map(|b| b.spec()).collect();
        write!(
            f,
            "seed={:#x} rounds={} validators={} max_uids={:?} peers=[{}] scenario=\"{}\"",
            self.seed,
            self.rounds,
            self.n_validators,
            self.max_uids,
            specs.join(", "),
            self.scenario.to_compact().replace('\n', "; "),
        )
    }
}

/// Push one adversary pick onto `peers`. Victim-referencing behaviours
/// always target a round-0 honest uid (a copier of garbage tests nothing),
/// and a sybil pick pushes **two** ring members — one "ring" is no ring.
fn push_adversary(rng: &mut Rng, peers: &mut Vec<Behavior>, honest_uids: &[Uid], n_validators: usize) {
    let victim = |rng: &mut Rng| honest_uids[rng.below(honest_uids.len() as u64) as usize];
    match rng.below(14) {
        0 => peers.push(Behavior::Freeloader),
        1 => peers.push(Behavior::Desync { at: 1 + rng.below(4), pause: 1 + rng.below(3) }),
        2 => peers.push(Behavior::Late { prob: rng.below(48) as f64 / 64.0 }),
        3 => peers.push(Behavior::Silent { prob: rng.below(48) as f64 / 64.0 }),
        4 => peers.push(Behavior::FormatViolator),
        5 => peers.push(Behavior::Rescaler { factor: 2.0 + rng.below(64) as f32 / 4.0 }),
        6 => peers.push(Behavior::Poisoner { scale: 10.0 + rng.below(400) as f32 / 4.0 }),
        7 => peers.push(Behavior::Copier { victim: victim(rng) }),
        8 => peers.push(Behavior::Duplicator { original: victim(rng) }),
        9 => {
            let ring = rng.below(100);
            let eps = (1 + rng.below(63)) as f32 / 256.0;
            peers.push(Behavior::Sybil { ring, eps });
            peers.push(Behavior::Sybil { ring, eps });
        }
        10 => peers.push(Behavior::CopycatNoise {
            victim: victim(rng),
            noise: (1 + rng.below(63)) as f32 / 256.0,
        }),
        11 => peers.push(Behavior::Briber { validator: rng.below(n_validators as u64) as Uid }),
        12 => peers.push(Behavior::SlowLoris),
        _ => peers.push(Behavior::StaleReplayer { lag: 1 + rng.below(5) }),
    }
}

impl FuzzScript {
    /// Generate a random script: 2–3 honest peers, 1–3 adversary picks
    /// (every class reachable), 8–12 rounds, and 0–3 churn events kept
    /// inside the envelopes the invariants assume — leaves target round-0
    /// peer uids, and scripted stake never reaches validator 0's 1000.0 so
    /// no bribed validator can be handed the stake majority mid-run.
    pub fn generate(rng: &mut Rng, size: usize) -> FuzzScript {
        let n_validators = 1 + rng.below(2) as usize;
        let n_honest = 2 + rng.below(2) as usize;
        let honest_uids: Vec<Uid> =
            (0..n_honest).map(|i| (n_validators + i) as Uid).collect();

        let mut peers: Vec<Behavior> = (0..n_honest)
            .map(|_| Behavior::Honest { data_mult: 1.0 + rng.below(16) as f64 / 16.0 })
            .collect();
        for _ in 0..1 + rng.below(3) {
            push_adversary(rng, &mut peers, &honest_uids, n_validators);
        }

        let rounds = 8 + rng.below(5);
        let total_initial = n_validators + peers.len();
        let peer_uids: Vec<Uid> =
            (0..peers.len()).map(|i| (n_validators + i) as Uid).collect();

        let mut scenario = Scenario::new();
        for _ in 0..rng.below(1 + size as u64 % 4) {
            let round = 1 + rng.below(rounds - 2);
            let ev = match rng.below(4) {
                0 => Event::JoinPeer {
                    behavior: arbitrary_behavior(rng, total_initial as u64),
                },
                1 => Event::LeavePeer {
                    uid: peer_uids[rng.below(peer_uids.len() as u64) as usize],
                },
                2 => {
                    let uid = if n_validators > 1 && rng.chance(0.5) {
                        1 as Uid
                    } else {
                        peer_uids[rng.below(peer_uids.len() as u64) as usize]
                    };
                    Event::SetStake { uid, amount: rng.below(1600) as f64 / 4.0 }
                }
                _ => Event::ProviderOutage {
                    prob: rng.below(32) as f64 / 64.0,
                    rounds: 1 + rng.below(2),
                },
            };
            scenario = scenario.at(round, ev);
        }

        let max_uids = rng.chance(0.3).then_some(total_initial + 1);
        FuzzScript { seed: rng.next_u64(), rounds, n_validators, peers, scenario, max_uids }
    }

    /// [`FuzzScript::generate`] plus a chaos profile (`gauntlet soak
    /// --chaos <p>`): the script gains 1–2 read-path chaos windows with
    /// probabilities capped at `chaos` (dyadic n/64, for exact grammar
    /// round-trips) and, occasionally, one targeted eclipse. Scripts with
    /// heavy chaos (> 0.3) or any eclipse waive the dominance invariants —
    /// see [`chaos_allows_dominance`] — but every per-round invariant and
    /// the no-panic/no-abort contract still hold.
    pub fn generate_chaos(rng: &mut Rng, size: usize, chaos: f64) -> FuzzScript {
        let mut script = FuzzScript::generate(rng, size);
        if chaos <= 0.0 {
            return script;
        }
        let cap = ((chaos * 64.0) as u64).clamp(1, 64);
        let mut scenario = script.scenario.clone();
        for _ in 0..1 + rng.below(2) {
            let round = 1 + rng.below(script.rounds - 2);
            let prob = (1 + rng.below(cap)) as f64 / 64.0;
            let rounds = 1 + rng.below(3);
            let ev = if rng.chance(0.5) {
                Event::ChaosGetFail { prob, rounds }
            } else {
                Event::ChaosCorrupt { prob, rounds }
            };
            scenario = scenario.at(round, ev);
        }
        if rng.chance(0.15) {
            let validator = rng.below(script.n_validators as u64) as Uid;
            let peer =
                (script.n_validators as u64 + rng.below(script.peers.len() as u64)) as Uid;
            let round = 1 + rng.below(script.rounds - 2);
            scenario =
                scenario.at(round, Event::Eclipse { validator, peer, rounds: 1 + rng.below(2) });
        }
        script.scenario = scenario;
        script
    }

    /// Builder for this script: sim backend, nano model, single-threaded
    /// (1-vs-N determinism is pinned separately), heldout eval off, and an
    /// eval sample large enough that every valid submission is evaluated
    /// every round — adversaries cannot hide from PoC by sampling luck.
    pub fn builder(&self) -> GauntletBuilder {
        let mut b = GauntletBuilder::sim()
            .model("nano")
            .rounds(self.rounds)
            .peers(self.peers.clone())
            .scenario(self.scenario.clone())
            .seed(self.seed)
            .threads(1)
            .validators(self.n_validators)
            .eval_every(0)
            .eval_sample(32);
        if let Some(m) = self.max_uids {
            b = b.max_uids(m);
        }
        b
    }
}

/// Rolling per-round invariant checks over [`RoundRecord`]s, shared between
/// the fuzzer and `gauntlet soak` (see the module docs for the list).
#[derive(Default)]
pub struct InvariantTracker {
    /// Last observed balance per uid; cleared on lifecycle events because
    /// eviction recycles uids with fresh balances.
    balances: BTreeMap<Uid, f64>,
}

impl InvariantTracker {
    pub fn observe(&mut self, rec: &RoundRecord) -> Result<(), String> {
        let mut sum = 0.0;
        for p in &rec.peers {
            crate::prop_assert!(
                p.incentive.is_finite() && p.incentive >= -1e-12,
                "round {}: uid {} incentive {} is not finite and non-negative",
                rec.round,
                p.uid,
                p.incentive
            );
            crate::prop_assert!(
                p.balance.is_finite() && p.balance >= -1e-9,
                "round {}: uid {} balance {} is not finite and non-negative",
                rec.round,
                p.uid,
                p.balance
            );
            crate::prop_assert!(
                p.peer_score.is_finite()
                    && p.mu.is_finite()
                    && p.rating_mu.is_finite()
                    && p.rating_ordinal.is_finite(),
                "round {}: uid {} has a non-finite score \
                 (peer_score={} mu={} rating_mu={} ordinal={})",
                rec.round,
                p.uid,
                p.peer_score,
                p.mu,
                p.rating_mu,
                p.rating_ordinal
            );
            sum += p.incentive;
        }
        crate::prop_assert!(
            sum <= 1.0 + 1e-6,
            "round {}: incentives sum to {sum} > 1",
            rec.round
        );
        if !rec.events.is_empty() {
            self.balances.clear();
        }
        for p in &rec.peers {
            if let Some(prev) = self.balances.get(&p.uid) {
                crate::prop_assert!(
                    p.balance + 1e-9 >= *prev,
                    "round {}: uid {} balance shrank from {prev} to {}",
                    rec.round,
                    p.uid,
                    p.balance
                );
            }
            self.balances.insert(p.uid, p.balance);
        }
        Ok(())
    }
}

/// Adversary classes the mechanism actively detects and starves — honest
/// mean balance must strictly dominate theirs.
pub const STRICT_CLASSES: [&str; 7] =
    ["copier", "copycat", "duplicator", "format", "freeloader", "poisoner", "sybil"];

/// Adversary classes the mechanism *neutralizes* rather than punishes
/// (normalization, put window, sync probes): bounded by honest parity.
pub const PARITY_CLASSES: [&str; 6] =
    ["desync", "late", "rescaler", "silent", "slowloris", "stale"];

/// Assert class dominance over final balances grouped by
/// [`Behavior::class`]. `honest` holds honest balances; skipped entirely
/// when the run is degenerate (no honest survivors or zero honest mean).
pub fn check_class_dominance(
    honest: &[f64],
    groups: &BTreeMap<&'static str, Vec<f64>>,
) -> Result<(), String> {
    if honest.is_empty() {
        return Ok(());
    }
    let h_mean = honest.iter().sum::<f64>() / honest.len() as f64;
    let h_max = honest.iter().fold(0.0_f64, |a, &b| a.max(b));
    if h_mean <= 1e-9 {
        return Ok(());
    }
    for (class, bals) in groups {
        if bals.is_empty() {
            continue;
        }
        let mean = bals.iter().sum::<f64>() / bals.len() as f64;
        if STRICT_CLASSES.contains(class) {
            crate::prop_assert!(
                mean < h_mean,
                "class {class}: mean balance {mean} does not strictly trail honest mean {h_mean}"
            );
        } else if PARITY_CLASSES.contains(class) {
            crate::prop_assert!(
                mean <= h_max * 1.5 + 1e-6,
                "class {class}: mean balance {mean} materially out-earns best honest {h_max}"
            );
        }
    }
    Ok(())
}

/// Whether the end-of-run dominance invariants apply under this script's
/// chaos profile. Mild read-path chaos (every window's probability at most
/// 0.3) keeps the honest-vs-adversary earnings ordering intact — misses
/// hit all readers uniformly in expectation — but heavier chaos, or a
/// *targeted* eclipse, can starve an honest peer through no fault of the
/// incentive mechanism, so those scripts only assert the per-round
/// invariants and the no-panic contract.
pub fn chaos_allows_dominance(scenario: &Scenario) -> bool {
    for (_, ev) in scenario.events() {
        match ev {
            Event::ChaosGetFail { prob, .. } | Event::ChaosCorrupt { prob, .. } => {
                if *prob > 0.3 {
                    return false;
                }
            }
            Event::Eclipse { .. } => return false,
            _ => {}
        }
    }
    true
}

/// Run one fuzz case end to end: generate a script, run it, check every
/// invariant. The rng also decides whether this case additionally performs
/// the snapshot/resume and trace-replay self-tests. Designed as the body
/// of a [`crate::prop::check`] property; failures embed the full script.
pub fn check_case(rng: &mut Rng, size: usize) -> Result<(), String> {
    check_case_chaos(rng, size, 0.0)
}

/// [`check_case`] with a chaos profile: `chaos > 0` injects read-path
/// fault windows via [`FuzzScript::generate_chaos`] (the `soak --chaos`
/// path). `chaos = 0` draws identically to [`check_case`].
pub fn check_case_chaos(rng: &mut Rng, size: usize, chaos: f64) -> Result<(), String> {
    let script = FuzzScript::generate_chaos(rng, size, chaos);
    let do_snapshot = rng.chance(0.5);
    let do_replay = rng.chance(0.35);
    let tag = rng.next_u64();
    run_script(&script, do_snapshot, do_replay, tag)
        .map_err(|e| format!("{e}\n  failing script: {script}"))
}

/// Standalone re-run of one fuzz case from a harness seed, for
/// `gauntlet soak --repro <seed> --size <n>` and CI triage.
pub fn check_seed(seed: u64, size: usize) -> Result<(), String> {
    check_case(&mut Rng::new(seed), size)
}

/// [`check_seed`] under a chaos profile — the repro path for failures out
/// of `soak --chaos <p>` (the chaos knob is part of the case identity:
/// reproducing a chaos failure requires the same `--chaos` value).
pub fn check_seed_chaos(seed: u64, size: usize, chaos: f64) -> Result<(), String> {
    check_case_chaos(&mut Rng::new(seed), size, chaos)
}

fn run_script(
    script: &FuzzScript,
    do_snapshot: bool,
    do_replay: bool,
    tag: u64,
) -> Result<(), String> {
    let trace_path = std::env::temp_dir()
        .join(format!("gauntlet-fuzz-{tag:016x}-{}.jsonl", std::process::id()));
    let trace = if do_replay {
        Some(
            JsonlTraceObserver::create(&trace_path)
                .map_err(|e| format!("trace create: {e:#}"))?,
        )
    } else {
        None
    };

    let mut b = script.builder();
    if let Some(t) = &trace {
        b = b.observer(t.clone());
    }
    let mut engine = b.build().map_err(|e| format!("build: {e:#}"))?;

    let snap_at = script.rounds / 2;
    let mut mid = None;
    let mut tracker = InvariantTracker::default();
    while engine.round() < script.rounds {
        if do_snapshot && engine.round() == snap_at {
            mid = Some(engine.snapshot());
        }
        let r = engine.round();
        let rec = engine.run_round().map_err(|e| format!("round {r}: {e:#}"))?;
        tracker.observe(&rec)?;
    }

    // Under heavy chaos or a targeted eclipse the earnings ordering is not
    // the mechanism's to guarantee; per-round invariants above still ran.
    let dominance = chaos_allows_dominance(&script.scenario);

    // Class dominance over round-0 peers that survived to the end. A slot
    // is "original" only if its uid maps back into the initial population
    // AND the behavior still matches — eviction recycles uids, and a
    // recycled slot says nothing about the original occupant's earnings.
    let mut honest = Vec::new();
    let mut honest_uids = Vec::new();
    let mut groups: BTreeMap<&'static str, Vec<f64>> = BTreeMap::new();
    let mut plagiarists: Vec<Uid> = Vec::new();
    for p in engine.peers() {
        let Some(idx) = (p.uid as usize).checked_sub(script.n_validators) else { continue };
        if idx >= script.peers.len() || script.peers[idx] != p.behavior {
            continue;
        }
        let bal = engine.chain().neuron(p.uid).map(|n| n.balance).unwrap_or(0.0);
        let class = p.behavior.class();
        if class == "honest" {
            honest.push(bal);
            honest_uids.push(p.uid);
        } else {
            groups.entry(class).or_default().push(bal);
            if matches!(class, "copier" | "copycat" | "duplicator" | "sybil") {
                plagiarists.push(p.uid);
            }
        }
    }
    if dominance {
        check_class_dominance(&honest, &groups)?;
    }

    // Plagiarist classes must *converge* to near-zero weight, not merely
    // trail on cumulative balance: final-round incentive at most half the
    // honest mean.
    if let Some(last) = engine.metrics_observer().last_record() {
        if !dominance {
            // An eclipsed or chaos-starved honest peer can drag the honest
            // mean to a level plagiarists legitimately match.
            honest_uids.clear();
        }
        let inc = |uid: Uid| last.peers.iter().find(|p| p.uid == uid).map(|p| p.incentive);
        let h_inc: Vec<f64> = honest_uids.iter().filter_map(|&u| inc(u)).collect();
        if !h_inc.is_empty() {
            let h_mean = h_inc.iter().sum::<f64>() / h_inc.len() as f64;
            if h_mean > 1e-9 {
                for &uid in &plagiarists {
                    if let Some(i) = inc(uid) {
                        crate::prop_assert!(
                            i <= h_mean * 0.5 + 1e-9,
                            "plagiarist uid {uid} final incentive {i} has not \
                             converged to near-zero (honest mean {h_mean})"
                        );
                    }
                }
            }
        }
    }

    if let Some(snap) = mid {
        let mut resumed = GauntletBuilder::sim()
            .resume(snap)
            .build()
            .map_err(|e| format!("resume build: {e:#}"))?;
        resumed.run().map_err(|e| format!("resumed run: {e:#}"))?;
        crate::prop_assert!(
            resumed.fingerprint() == engine.fingerprint(),
            "snapshot/resume fingerprint {:#x} diverged from uninterrupted run {:#x}",
            resumed.fingerprint(),
            engine.fingerprint()
        );
    }

    if let Some(t) = trace {
        t.flush().map_err(|e| format!("trace flush: {e:#}"))?;
        let replayed =
            replay_trace(&trace_path).map_err(|e| format!("replay_trace: {e:#}"))?;
        let live = engine.metrics_observer().metrics();
        // Compare through JSON so NaN diagnostics (heldout loss is off
        // here) compare by bit pattern rather than poisoning PartialEq.
        crate::prop_assert!(
            replayed.to_json().write() == live.to_json().write(),
            "replay_trace metrics diverged from the live run (trace kept at {})",
            trace_path.display()
        );
        let _ = std::fs::remove_file(&trace_path);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scripts_are_deterministic_per_seed() {
        let a = FuzzScript::generate(&mut Rng::new(42), 17);
        let b = FuzzScript::generate(&mut Rng::new(42), 17);
        assert_eq!(a.to_string(), b.to_string());
        let c = FuzzScript::generate(&mut Rng::new(43), 17);
        assert_ne!(a.seed, c.seed, "different harness seeds give different engine seeds");
    }

    #[test]
    fn generated_sybil_rings_have_at_least_two_members() {
        for seed in 0..200 {
            let s = FuzzScript::generate(&mut Rng::new(seed), 11);
            let mut rings: BTreeMap<u64, usize> = BTreeMap::new();
            for b in &s.peers {
                if let Behavior::Sybil { ring, .. } = b {
                    *rings.entry(*ring).or_default() += 1;
                }
            }
            for (ring, n) in rings {
                assert!(n >= 2, "seed {seed}: ring {ring} has a lone member");
            }
        }
    }

    #[test]
    fn generated_scripts_stay_inside_safe_envelopes() {
        for seed in 0..200 {
            let s = FuzzScript::generate(&mut Rng::new(seed), 23);
            assert!((8..=12).contains(&s.rounds));
            assert!((1..=2).contains(&s.n_validators));
            let honest =
                s.peers.iter().filter(|b| b.class() == "honest").count();
            assert!((2..=3).contains(&honest), "seed {seed}: {honest} honest peers");
            for (round, ev) in s.scenario.events() {
                assert!(*round >= 1 && *round < s.rounds);
                if let Event::SetStake { amount, .. } = ev {
                    assert!(
                        *amount < 1000.0,
                        "seed {seed}: scripted stake {amount} could flip the majority"
                    );
                }
            }
        }
    }

    #[test]
    fn chaos_scripts_cap_probabilities_and_gate_dominance() {
        for seed in 0..200 {
            let s = FuzzScript::generate_chaos(&mut Rng::new(seed), 13, 0.2);
            let mut chaos_events = 0;
            for (round, ev) in s.scenario.events() {
                assert!(*round >= 1 && *round < s.rounds);
                match ev {
                    Event::ChaosGetFail { prob, .. } | Event::ChaosCorrupt { prob, .. } => {
                        chaos_events += 1;
                        assert!(
                            *prob > 0.0 && *prob <= 0.2,
                            "seed {seed}: chaos prob {prob} outside (0, 0.2]"
                        );
                    }
                    _ => {}
                }
            }
            assert!(chaos_events >= 1, "seed {seed}: no chaos window injected");
        }
        // chaos = 0 draws identically to the plain generator.
        let plain = FuzzScript::generate(&mut Rng::new(7), 13);
        let zero = FuzzScript::generate_chaos(&mut Rng::new(7), 13, 0.0);
        assert_eq!(plain.to_string(), zero.to_string());
    }

    #[test]
    fn dominance_gate_trips_on_heavy_chaos_or_eclipse() {
        let mild = Scenario::new()
            .at(2, Event::ChaosGetFail { prob: 0.25, rounds: 2 })
            .at(3, Event::ChaosCorrupt { prob: 0.05, rounds: 1 });
        assert!(chaos_allows_dominance(&mild));
        let heavy = Scenario::new().at(2, Event::ChaosGetFail { prob: 0.5, rounds: 1 });
        assert!(!chaos_allows_dominance(&heavy));
        let eclipsed =
            Scenario::new().at(2, Event::Eclipse { validator: 0, peer: 3, rounds: 1 });
        assert!(!chaos_allows_dominance(&eclipsed));
        assert!(chaos_allows_dominance(&Scenario::new()));
    }

    #[test]
    fn class_dominance_rejects_out_earning_plagiarist() {
        let mut groups = BTreeMap::new();
        groups.insert("copier", vec![2.0]);
        assert!(check_class_dominance(&[1.0, 1.2], &groups).is_err());
        groups.insert("copier", vec![0.01]);
        assert!(check_class_dominance(&[1.0, 1.2], &groups).is_ok());
        // parity classes tolerate honest-level earnings but not multiples
        let mut parity = BTreeMap::new();
        parity.insert("slowloris", vec![1.1]);
        assert!(check_class_dominance(&[1.0, 1.2], &parity).is_ok());
        parity.insert("slowloris", vec![5.0]);
        assert!(check_class_dominance(&[1.0, 1.2], &parity).is_err());
        // degenerate runs are skipped, not failed
        assert!(check_class_dominance(&[], &groups).is_ok());
        assert!(check_class_dominance(&[0.0], &groups).is_ok());
    }
}
