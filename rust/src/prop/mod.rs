//! Miniature property-based testing harness (proptest is not available in
//! this vendored environment — see DESIGN.md §4 substitutions).
//!
//! A property runs against `cases` deterministic pseudo-random inputs; on
//! failure it reports the case index and seed so the exact input can be
//! reproduced with `Rng::new(seed)`. A greedy "shrink by retrying smaller
//! size hints" pass is intentionally omitted: generators take a `size`
//! parameter and the harness retries failing properties at smaller sizes to
//! report the smallest size class that still fails.

pub mod scenario;

use crate::util::Rng;

/// Run `prop(rng, size)` for `cases` seeds. Panics with a reproducible
/// report on the first failure, after probing smaller sizes.
pub fn check<F>(name: &str, cases: u64, prop: F)
where
    F: Fn(&mut Rng, usize) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0x9A0C_u64 << 32 | case;
        let size = 1 + (case as usize * 7) % 64;
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng, size) {
            // Probe smaller size classes with the same seed for a more
            // readable failure report.
            let mut min_fail = (size, msg.clone());
            for s in (1..size).rev() {
                let mut r2 = Rng::new(seed);
                if let Err(m) = prop(&mut r2, s) {
                    min_fail = (s, m);
                }
            }
            panic!(
                "property {name:?} failed: case={case} seed={seed:#x} size={} \
                 (first failure at size={size})\n  {}",
                min_fail.0, min_fail.1
            );
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("sum-commutes", 32, |rng, size| {
            let a: Vec<f64> = (0..size).map(|_| rng.next_f64()).collect();
            let s1: f64 = a.iter().sum();
            let s2: f64 = a.iter().rev().sum();
            prop_assert!((s1 - s2).abs() < 1e-9, "sums differ: {s1} vs {s2}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_seed() {
        check("always-fails-at-size-10", 64, |_rng, size| {
            prop_assert!(size < 10, "failed as designed at size {size}");
            Ok(())
        });
    }
}
