//! Minimal JSON parser/writer (no serde in this vendored environment).
//!
//! Used for `artifacts/<cfg>/meta.json` (the Rust<->Python ABI contract),
//! run configuration files, and metrics/series output consumed by the bench
//! harness. Supports the full JSON grammar except that numbers are kept as
//! f64 (adequate: the ABI's largest integers are parameter counts < 2^53).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl Value {
    // ---------------------------- accessors ----------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["a"]["b"]` style access; returns Null for missing paths.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    // ----------------------------- parsing -----------------------------

    pub fn parse(text: &str) -> Result<Value, ParseError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ----------------------------- writing -----------------------------

    pub fn write(&self) -> String {
        let mut s = String::new();
        self.write_into(&mut s);
        s
    }

    fn write_into(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    fmt::Write::write_fmt(out, format_args!("{}", *n as i64)).unwrap()
                } else {
                    fmt::Write::write_fmt(out, format_args!("{n}")).unwrap()
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_into(out);
                }
                out.push(']');
            }
            Value::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write_into(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors.
pub fn obj(entries: Vec<(&str, Value)>) -> Value {
    Value::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(n: f64) -> Value {
    Value::Num(n)
}
pub fn arr_f64(xs: &[f64]) -> Value {
    Value::Arr(xs.iter().map(|x| Value::Num(*x)).collect())
}
pub fn s(x: &str) -> Value {
    Value::Str(x.to_string())
}

/// Bit-faithful f64 serialization for run snapshots and event traces.
///
/// JSON has no NaN/±inf, and the plain writer normalizes `-0.0` to `0`;
/// all four would silently change bits across a write/parse round trip —
/// fatal for the bit-identical snapshot/resume contract. This encodes them
/// as sentinel strings; every other finite value goes through [`Value::Num`],
/// whose shortest-roundtrip `Display` parses back to the identical bits.
pub fn fnum(x: f64) -> Value {
    if x.is_nan() {
        Value::Str("nan".to_string())
    } else if x == f64::INFINITY {
        Value::Str("inf".to_string())
    } else if x == f64::NEG_INFINITY {
        Value::Str("-inf".to_string())
    } else if x == 0.0 && x.is_sign_negative() {
        Value::Str("-0".to_string())
    } else {
        Value::Num(x)
    }
}

/// Inverse of [`fnum`]: reads a number or one of its sentinel strings.
pub fn read_f64(v: &Value) -> Option<f64> {
    match v {
        Value::Num(n) => Some(*n),
        Value::Str(s) => match s.as_str() {
            "nan" => Some(f64::NAN),
            "inf" => Some(f64::INFINITY),
            "-inf" => Some(f64::NEG_INFINITY),
            "-0" => Some(-0.0),
            _ => None,
        },
        _ => None,
    }
}

/// Typed object-field readers with contextual errors — the shared
/// accessors behind the snapshot, event, and metrics codecs (one place to
/// fix range checks or error wording, not three).
pub mod field {
    use anyhow::{Context, Result};

    use super::{read_f64, Value};

    fn missing(key: &str) -> String {
        format!("missing or bad field {key:?}")
    }

    /// An `f64` written via [`super::fnum`] (NaN/±inf/-0.0 sentinels ok).
    pub fn f64(v: &Value, key: &str) -> Result<f64> {
        read_f64(v.get(key)).with_context(|| missing(key))
    }

    /// An `f32` stored exactly as its `f64` widening.
    pub fn f32(v: &Value, key: &str) -> Result<f32> {
        Ok(f64(v, key)? as f32)
    }

    pub fn boolean(v: &Value, key: &str) -> Result<bool> {
        v.get(key).as_bool().with_context(|| missing(key))
    }

    pub fn string(v: &Value, key: &str) -> Result<String> {
        v.get(key).as_str().map(str::to_string).with_context(|| missing(key))
    }

    pub fn size(v: &Value, key: &str) -> Result<usize> {
        v.get(key).as_usize().with_context(|| missing(key))
    }

    /// A non-negative integer-valued number as `u64`.
    pub fn unsigned(v: &Value, key: &str) -> Result<u64> {
        v.get(key)
            .as_f64()
            .filter(|n| *n >= 0.0 && n.fract() == 0.0)
            .map(|n| n as u64)
            .with_context(|| missing(key))
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32)).unwrap()
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Value::Null),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            out.insert(key, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // Handle surrogate pairs.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let combined =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(ch.ok_or_else(|| self.err("bad \\u escape"))?);
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // Re-decode UTF-8 from the raw bytes.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    self.pos = start + len;
                    if self.pos > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.b[start..self.pos])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        if self.pos + 4 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>().map(Value::Num).map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(text: &str) -> Value {
        let v = Value::parse(text).unwrap();
        let v2 = Value::parse(&v.write()).unwrap();
        assert_eq!(v, v2, "write/parse roundtrip for {text}");
        v
    }

    #[test]
    fn scalars() {
        assert_eq!(roundtrip("null"), Value::Null);
        assert_eq!(roundtrip("true"), Value::Bool(true));
        assert_eq!(roundtrip("false"), Value::Bool(false));
        assert_eq!(roundtrip("3.5"), Value::Num(3.5));
        assert_eq!(roundtrip("-17"), Value::Num(-17.0));
        assert_eq!(roundtrip("1e-3"), Value::Num(0.001));
        assert_eq!(roundtrip("2.5E2"), Value::Num(250.0));
    }

    #[test]
    fn strings_with_escapes() {
        let v = roundtrip(r#""a\"b\\c\ndA\t""#);
        assert_eq!(v.as_str().unwrap(), "a\"b\\c\ndA\t");
    }

    #[test]
    fn unicode_and_surrogates() {
        assert_eq!(roundtrip(r#""héllo 世界""#).as_str().unwrap(), "héllo 世界");
        assert_eq!(roundtrip(r#""😀""#).as_str().unwrap(), "😀");
    }

    #[test]
    fn nested_structures() {
        let v = roundtrip(r#"{"a":[1,2,{"b":null}],"c":{"d":[true,false]},"e":""}"#);
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").get("d").as_arr().unwrap()[0], Value::Bool(true));
        assert_eq!(v.get("missing"), &Value::Null);
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Value::parse(" {\n \"k\" :\t[ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("k").as_arr().unwrap().len(), 2);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(roundtrip("[]"), Value::Arr(vec![]));
        assert_eq!(roundtrip("{}"), Value::Obj(BTreeMap::new()));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "nul", "\"abc", "{\"a\" 1}", "01x", "[1 2]", "{}extra"] {
            assert!(Value::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn as_usize_guards() {
        assert_eq!(Value::Num(5.0).as_usize(), Some(5));
        assert_eq!(Value::Num(5.5).as_usize(), None);
        assert_eq!(Value::Num(-1.0).as_usize(), None);
        assert_eq!(Value::Str("5".into()).as_usize(), None);
    }

    #[test]
    fn parses_real_meta_json() {
        // The actual ABI file, if artifacts are built.
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/nano/meta.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let v = Value::parse(&text).unwrap();
            assert_eq!(v.get("name").as_str(), Some("nano"));
            assert!(v.get("param_count").as_usize().unwrap() > 0);
        }
    }

    #[test]
    fn fnum_preserves_every_f64_bit_pattern() {
        let specials = [
            f64::NAN,
            f64::INFINITY,
            f64::NEG_INFINITY,
            -0.0,
            0.0,
            1.0,
            -17.25,
            1e300,
            5e-324, // smallest subnormal
            std::f64::consts::PI,
        ];
        for x in specials {
            let text = fnum(x).write();
            let back = read_f64(&Value::parse(&text).unwrap()).unwrap();
            assert_eq!(x.to_bits(), back.to_bits(), "{x} via {text}");
        }
        assert_eq!(read_f64(&Value::Bool(true)), None);
        assert_eq!(read_f64(&Value::Str("bogus".into())), None);
    }

    #[test]
    fn integer_formatting_has_no_decimal_point() {
        assert_eq!(Value::Num(42.0).write(), "42");
        assert_eq!(Value::Num(42.5).write(), "42.5");
    }
}
