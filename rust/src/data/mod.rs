//! Synthetic training corpus + deterministic shard assignment.
//!
//! Substitutes FineWebEdu (paper §6): the incentive mechanics only require
//! (a) a corpus with learnable structure so losses fall and LossScores are
//! informative, and (b) the `SelectData(seed, p, t)` contract — the
//! validator and an honest peer must derive the *identical* unique data
//! subset for peer p at round t from public information, while random
//! evaluation subsets come from a disjoint namespace.
//!
//! The corpus is a mixture of `n_patterns` affine token processes: within a
//! document, `next = (a_p * cur + b_p) mod V` for a per-document pattern p,
//! with occasional random "switch" tokens. Two consecutive tokens identify
//! the pattern, so a small transformer can drive next-token loss from
//! ln(V) down toward the switch-noise floor — fast enough convergence to
//! reproduce the paper's loss-curve shapes at hundreds of rounds.

use crate::util::Rng;

/// Token type matching the artifacts' i32 ABI.
pub type Token = i32;

#[derive(Clone, Debug)]
pub struct Corpus {
    pub vocab: u32,
    pub n_patterns: u32,
    /// Probability of an entropy-injecting random token at each position.
    pub switch_prob: f64,
    /// Global run seed: all shards derive from it.
    pub seed: u64,
}

impl Corpus {
    pub fn new(vocab: u32, seed: u64) -> Self {
        Corpus { vocab, n_patterns: 4, switch_prob: 0.02, seed }
    }

    /// Pattern p's affine map (odd multiplier => bijective mod 2^k vocab).
    fn pattern(&self, p: u32) -> (u64, u64) {
        let mut r = Rng::from_parts(&["pattern", &self.seed.to_string(), &p.to_string()]);
        let a = 2 * r.below(self.vocab as u64 / 2) + 1;
        let b = r.below(self.vocab as u64);
        (a, b)
    }

    /// One document of `len` tokens driven by `rng`.
    fn document(&self, rng: &mut Rng, len: usize) -> Vec<Token> {
        let p = rng.below(self.n_patterns as u64) as u32;
        let (a, b) = self.pattern(p);
        let v = self.vocab as u64;
        let mut cur = rng.below(v);
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            out.push(cur as Token);
            if rng.chance(self.switch_prob) {
                cur = rng.below(v);
            } else {
                cur = (a * cur + b) % v;
            }
        }
        out
    }

    /// Deterministic batch: `parts` name the shard (namespace + ids); the
    /// same parts always yield the same tokens. Shape: batch * (seq+1),
    /// row-major, matching the artifacts' `tokens i32[B, S+1]` input.
    pub fn batch(&self, parts: &[&str], batch: usize, seq_plus1: usize) -> Vec<Token> {
        let seed_s = self.seed.to_string();
        let mut all_parts = vec!["corpus", seed_s.as_str()];
        all_parts.extend_from_slice(parts);
        let mut rng = Rng::from_parts(&all_parts);
        let mut out = Vec::with_capacity(batch * seq_plus1);
        for _ in 0..batch {
            out.extend(self.document(&mut rng, seq_plus1));
        }
        out
    }

    /// Peer p's **assigned** unique shard for round t, microbatch `mb`
    /// (paper: D_t^p). Honest peers train on these; the validator
    /// re-derives them for the proof-of-computation check.
    pub fn assigned_shard(
        &self,
        uid: u32,
        round: u64,
        mb: u32,
        batch: usize,
        seq_plus1: usize,
    ) -> Vec<Token> {
        self.batch(
            &["assigned", &uid.to_string(), &round.to_string(), &mb.to_string()],
            batch,
            seq_plus1,
        )
    }

    /// A random evaluation subset for round t (paper: D_t^rand). The
    /// namespace is disjoint from every assigned shard by construction.
    pub fn random_eval(&self, round: u64, draw: u32, batch: usize, seq_plus1: usize) -> Vec<Token> {
        self.batch(&["rand", &round.to_string(), &draw.to_string()], batch, seq_plus1)
    }

    /// A fixed held-out batch for loss-curve tracking (never trained on).
    pub fn heldout(&self, draw: u32, batch: usize, seq_plus1: usize) -> Vec<Token> {
        self.batch(&["heldout", &draw.to_string()], batch, seq_plus1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use crate::prop_assert;

    fn corpus() -> Corpus {
        Corpus::new(512, 7)
    }

    #[test]
    fn batches_are_deterministic() {
        let c = corpus();
        assert_eq!(c.assigned_shard(3, 17, 0, 4, 33), c.assigned_shard(3, 17, 0, 4, 33));
        assert_eq!(c.random_eval(17, 1, 4, 33), c.random_eval(17, 1, 4, 33));
    }

    #[test]
    fn shards_differ_across_peers_rounds_and_namespaces() {
        let c = corpus();
        let base = c.assigned_shard(0, 0, 0, 2, 33);
        assert_ne!(base, c.assigned_shard(1, 0, 0, 2, 33), "peer disjoint");
        assert_ne!(base, c.assigned_shard(0, 1, 0, 2, 33), "round disjoint");
        assert_ne!(base, c.assigned_shard(0, 0, 1, 2, 33), "microbatch disjoint");
        assert_ne!(base, c.random_eval(0, 0, 2, 33), "namespace disjoint");
        assert_ne!(base, c.heldout(0, 2, 33), "heldout disjoint");
    }

    #[test]
    fn tokens_in_vocab_range() {
        let c = corpus();
        for t in c.assigned_shard(5, 9, 0, 8, 65) {
            assert!((0..512).contains(&t));
        }
    }

    #[test]
    fn corpus_seed_changes_data() {
        let a = Corpus::new(512, 1).assigned_shard(0, 0, 0, 2, 33);
        let b = Corpus::new(512, 2).assigned_shard(0, 0, 0, 2, 33);
        assert_ne!(a, b);
    }

    #[test]
    fn documents_follow_affine_pattern_mostly() {
        // Within a document, consecutive pairs should usually satisfy one
        // of the n_patterns affine maps.
        let c = corpus();
        let doc = c.batch(&["probe"], 1, 257);
        let maps: Vec<(u64, u64)> = (0..c.n_patterns).map(|p| c.pattern(p)).collect();
        let v = c.vocab as u64;
        let mut hits = 0;
        for w in doc.windows(2) {
            let (x, y) = (w[0] as u64, w[1] as u64);
            if maps.iter().any(|(a, b)| (a * x + b) % v == y) {
                hits += 1;
            }
        }
        let frac = hits as f64 / (doc.len() - 1) as f64;
        assert!(frac > 0.9, "pattern hit rate too low: {frac}");
    }

    #[test]
    fn pattern_multiplier_is_odd() {
        let c = corpus();
        for p in 0..c.n_patterns {
            assert_eq!(c.pattern(p).0 % 2, 1);
        }
    }

    #[test]
    fn prop_batch_shape_and_determinism() {
        prop::check("corpus-batch", 30, |rng, size| {
            let c = Corpus::new(256, rng.next_u64());
            let b = 1 + size % 5;
            let s = 2 + size % 40;
            let uid = rng.below(100) as u32;
            let round = rng.below(1000);
            let x = c.assigned_shard(uid, round, 0, b, s);
            prop_assert!(x.len() == b * s, "len {} != {}", x.len(), b * s);
            prop_assert!(
                x.iter().all(|&t| (0..256).contains(&t)),
                "token out of range"
            );
            let y = c.assigned_shard(uid, round, 0, b, s);
            prop_assert!(x == y, "not deterministic");
            Ok(())
        });
    }
}
