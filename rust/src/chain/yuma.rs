//! Yuma consensus (Steeves et al. [18]; docs.bittensor.com/yuma-consensus).
//!
//! Given each validator's weight vector over peers and each validator's
//! stake, Yuma computes, per peer, a *consensus weight*: the largest value
//! `w` such that validators holding at least a `kappa` fraction of total
//! stake assign the peer at least `w`. Every validator's weight is then
//! clipped to the consensus (punishing out-of-consensus inflation), and
//! incentives are the stake-weighted sum of clipped weights, normalized to
//! sum to 1. A dishonest minority validator therefore cannot pump a peer's
//! incentive above what the stake majority supports.
//!
//! # Sparse rows
//!
//! The registered uid table is permissionless and can be orders of
//! magnitude larger than the set of uids any validator actually weights
//! (the paper's "no control over the users that can register"). The
//! primary entry point is therefore [`yuma_consensus_sparse`] over
//! [`WeightRows`] — per-validator `(uid, weight)` rows — which computes
//! consensus only over the *union of touched uids*, so an epoch costs
//! O(active), not O(table). A uid absent from every row holds weight 0
//! with every validator: it can never raise the consensus above 0 and
//! contributes exactly 0 to each clipped stake-weighted rank, so skipping
//! it is not an approximation (the dense equivalence is pinned to 1e-12 by
//! `prop_sparse_equals_dense`). The dense [`yuma_consensus`] survives as a
//! deprecated forwarding shim.

use std::collections::BTreeMap;

use crate::chain::Uid;
use crate::util::det_sum;

#[derive(Clone, Copy, Debug)]
pub struct YumaParams {
    /// Stake-majority threshold (mainnet default 0.5).
    pub kappa: f64,
}

impl Default for YumaParams {
    fn default() -> Self {
        YumaParams { kappa: 0.5 }
    }
}

/// Borrowed view of per-validator sparse weight rows for
/// [`yuma_consensus_sparse`]: each entry is one validator's stake and its
/// committed `(target uid, weight)` row, sorted by ascending uid — exactly
/// the shape the chain stores (`BTreeMap` iteration order). Rows may be
/// empty (a committed-then-scrubbed validator still contributes its stake
/// to the consensus denominator, as in the dense formulation).
#[derive(Default)]
pub struct WeightRows<'a> {
    rows: Vec<(f64, &'a [(Uid, f64)])>,
}

impl<'a> WeightRows<'a> {
    pub fn new() -> Self {
        WeightRows { rows: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> Self {
        WeightRows { rows: Vec::with_capacity(n) }
    }

    /// Add one validator's stake and sparse weight row. The row must be
    /// sorted by ascending uid with no duplicates (debug-asserted inside
    /// the consensus): normalization and rank folds run in uid order, the
    /// order that makes the sparse epoch bit-compatible with the dense one.
    pub fn push(&mut self, stake: f64, row: &'a [(Uid, f64)]) {
        self.rows.push((stake, row));
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

/// Yuma consensus over sparse per-validator weight rows: returns
/// `(uid, incentive)` pairs in ascending uid order for every uid touched
/// by at least one row (untouched uids have incentive exactly 0 and are
/// not materialized). Incentives sum to 1 (or all zeros if every weight —
/// or all stake — is zero), matching [`yuma_consensus`] on the densified
/// matrix to 1e-12.
pub fn yuma_consensus_sparse(rows: &WeightRows<'_>, params: &YumaParams) -> Vec<(Uid, f64)> {
    if rows.rows.is_empty() {
        return vec![];
    }
    let total_stake = det_sum(rows.rows.iter().map(|(s, _)| *s));

    // One pass over the rows builds, per touched uid, the (normalized
    // weight, stake) column restricted to the validators that committed a
    // weight for it — in validator order, which the rank fold below
    // preserves. Row normalization divides by the row's det_sum, exactly
    // as the dense path does (zeros interleave as exact no-ops).
    let mut cols: BTreeMap<Uid, Vec<(f64, f64)>> = BTreeMap::new();
    for (stake, row) in &rows.rows {
        debug_assert!(
            row.windows(2).all(|p| p[0].0 < p[1].0),
            "weight row must be sorted by ascending uid without duplicates"
        );
        let scale = det_sum(row.iter().map(|(_, w)| *w));
        for &(uid, w) in *row {
            let nw = if scale > 0.0 { w / scale } else { w };
            cols.entry(uid).or_default().push((nw, *stake));
        }
    }
    if total_stake <= 0.0 {
        return cols.keys().map(|&u| (u, 0.0)).collect();
    }

    // Per touched uid: the kappa-stake-weighted consensus quantile over
    // its column, then the stake-weighted sum of clipped weights. Absent
    // validators hold weight 0 here — below any positive candidate
    // threshold, and a +0.0 term in the rank fold — so the column scan
    // over touching validators is equivalent to the dense column scan.
    let mut rank: Vec<(Uid, f64)> = Vec::with_capacity(cols.len());
    for (&uid, col) in &cols {
        let mut sorted = col.clone();
        sorted.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        // largest w s.t. stake of validators with weight >= w is
        // >= kappa * total
        let mut best = 0.0;
        for &(w, _) in &sorted {
            let supporting =
                det_sum(sorted.iter().filter(|(wi, _)| *wi >= w).map(|(_, s)| *s));
            if supporting >= params.kappa * total_stake {
                best = w;
            }
        }
        // Clip and combine by stake, in validator order (`col`, not
        // `sorted` — the fold order is part of the determinism contract).
        let r = det_sum(col.iter().map(|&(w, s)| s * w.min(best)));
        rank.push((uid, r));
    }

    let total = det_sum(rank.iter().map(|(_, r)| *r));
    if total > 0.0 {
        for (_, r) in &mut rank {
            *r /= total;
        }
    }
    rank
}

/// `weights[v][j]` = validator v's (non-negative) weight on peer j.
/// `stake[v]` = validator v's stake. Returns per-peer incentives summing to
/// 1 (all zeros if every weight is zero).
///
/// Dense shim over [`yuma_consensus_sparse`]: it materializes every
/// `(column index, weight)` pair — zeros included — so it costs
/// O(validators × peers) regardless of sparsity.
#[deprecated(
    note = "use `yuma_consensus_sparse` over `WeightRows`; the dense matrix \
            costs O(validators × table) per epoch"
)]
pub fn yuma_consensus(weights: &[Vec<f64>], stake: &[f64], params: &YumaParams) -> Vec<f64> {
    assert_eq!(weights.len(), stake.len());
    if weights.is_empty() {
        return vec![];
    }
    let n_peers = weights[0].len();
    for row in weights {
        assert_eq!(row.len(), n_peers, "ragged weight matrix");
    }
    let owned: Vec<Vec<(Uid, f64)>> = weights
        .iter()
        .map(|row| row.iter().enumerate().map(|(j, &w)| (j as Uid, w)).collect())
        .collect();
    let mut rows = WeightRows::with_capacity(owned.len());
    for (row, &s) in owned.iter().zip(stake) {
        rows.push(s, row);
    }
    let mut out = vec![0.0; n_peers];
    for (uid, inc) in yuma_consensus_sparse(&rows, params) {
        out[uid as usize] = inc;
    }
    out
}

#[cfg(test)]
#[allow(deprecated)] // the dense shim is exercised deliberately throughout
mod tests {
    use super::*;
    use crate::prop;
    use crate::prop_assert;

    fn p() -> YumaParams {
        YumaParams::default()
    }

    #[test]
    fn single_validator_passthrough() {
        let inc = yuma_consensus(&[vec![0.75, 0.25]], &[100.0], &p());
        assert!((inc[0] - 0.75).abs() < 1e-12);
        assert!((inc[1] - 0.25).abs() < 1e-12);
    }

    #[test]
    fn sparse_single_validator_passthrough() {
        let row = vec![(7 as Uid, 0.75), (900_000 as Uid, 0.25)];
        let mut rows = WeightRows::new();
        rows.push(100.0, &row);
        let inc = yuma_consensus_sparse(&rows, &p());
        assert_eq!(inc.len(), 2, "only touched uids materialize: {inc:?}");
        assert_eq!(inc[0].0, 7);
        assert_eq!(inc[1].0, 900_000);
        assert!((inc[0].1 - 0.75).abs() < 1e-12);
        assert!((inc[1].1 - 0.25).abs() < 1e-12);
    }

    #[test]
    fn sparse_zero_everything_is_safe() {
        assert_eq!(yuma_consensus_sparse(&WeightRows::new(), &p()), vec![]);
        let row = vec![(3 as Uid, 1.0)];
        let mut rows = WeightRows::new();
        rows.push(0.0, &row);
        assert_eq!(yuma_consensus_sparse(&rows, &p()), vec![(3, 0.0)], "no stake, no payout");
        let zero_row = vec![(3 as Uid, 0.0)];
        let mut rows = WeightRows::new();
        rows.push(5.0, &zero_row);
        assert_eq!(yuma_consensus_sparse(&rows, &p()), vec![(3, 0.0)]);
    }

    #[test]
    fn sparse_minority_validator_cannot_pump_a_peer() {
        // Same economics as the dense test below, but over a huge uid
        // space: the touched union is {10, 999_999} and nothing else is
        // ever visited.
        let honest = vec![(10 as Uid, 1.0)];
        let dishonest = vec![(999_999 as Uid, 1.0)];
        let mut rows = WeightRows::new();
        rows.push(45.0, &honest);
        rows.push(45.0, &honest);
        rows.push(10.0, &dishonest);
        let inc = yuma_consensus_sparse(&rows, &p());
        let get = |u: Uid| inc.iter().find(|(x, _)| *x == u).map(|(_, i)| *i).unwrap();
        assert!(get(999_999) < 1e-9, "pumped peer got {}", get(999_999));
        assert!((get(10) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn agreement_is_preserved() {
        let w = vec![vec![0.6, 0.4], vec![0.6, 0.4], vec![0.6, 0.4]];
        let inc = yuma_consensus(&w, &[10.0, 20.0, 30.0], &p());
        assert!((inc[0] - 0.6).abs() < 1e-9);
    }

    #[test]
    fn minority_validator_cannot_pump_a_peer() {
        // Two honest validators (90% of stake) give peer 1 nothing; a
        // dishonest 10% validator gives it everything. Consensus clips the
        // dishonest weight to the majority's (0), so peer 1 earns ~0.
        let w = vec![vec![1.0, 0.0], vec![1.0, 0.0], vec![0.0, 1.0]];
        let inc = yuma_consensus(&w, &[45.0, 45.0, 10.0], &p());
        assert!(inc[1] < 1e-9, "pumped peer got {}", inc[1]);
        assert!((inc[0] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn majority_attacker_does_control() {
        // Flip the stake: the "attacker" holds the majority, so its view IS
        // the consensus — stake is the security assumption.
        let w = vec![vec![1.0, 0.0], vec![0.0, 1.0]];
        let inc = yuma_consensus(&w, &[10.0, 90.0], &p());
        assert!(inc[1] > 0.85, "majority view should dominate: {inc:?}");
    }

    #[test]
    fn zero_everything_is_safe() {
        assert_eq!(yuma_consensus(&[], &[], &p()), Vec::<f64>::new());
        assert_eq!(yuma_consensus(&[vec![0.0, 0.0]], &[5.0], &p()), vec![0.0, 0.0]);
        assert_eq!(yuma_consensus(&[vec![1.0]], &[0.0], &p()), vec![0.0]);
    }

    #[test]
    fn unnormalized_rows_are_renormalized() {
        let inc = yuma_consensus(&[vec![30.0, 10.0]], &[1.0], &p());
        assert!((inc[0] - 0.75).abs() < 1e-12);
    }

    #[test]
    fn prop_incentives_normalized_and_bounded_by_majority_max() {
        prop::check("yuma-invariants", 50, |rng, size| {
            let n_val = 1 + size % 5;
            let n_peer = 1 + size % 7;
            let weights: Vec<Vec<f64>> = (0..n_val)
                .map(|_| (0..n_peer).map(|_| rng.range_f64(0.0, 1.0)).collect())
                .collect();
            let stake: Vec<f64> = (0..n_val).map(|_| rng.range_f64(1.0, 100.0)).collect();
            let inc = yuma_consensus(&weights, &stake, &p());
            prop_assert!(inc.len() == n_peer, "length mismatch");
            let total: f64 = inc.iter().sum();
            prop_assert!(
                inc.iter().all(|x| (0.0..=1.0 + 1e-9).contains(x)),
                "incentive out of range: {inc:?}"
            );
            prop_assert!(
                total < 1e-9 || (total - 1.0).abs() < 1e-9,
                "not normalized: {total}"
            );
            Ok(())
        });
    }

    #[test]
    fn prop_sparse_rows_and_zero_stakes_stay_sane() {
        // Committed weight rows are sparse in practice (top-G of a large
        // uid table) and stakes can be zero (scripted demotion): incentives
        // must stay finite, non-negative, and sum to at most 1 + eps.
        prop::check("yuma-sparse", 50, |rng, size| {
            let n_val = 1 + size % 6;
            let n_peer = 1 + size % 9;
            let weights: Vec<Vec<f64>> = (0..n_val)
                .map(|_| {
                    (0..n_peer)
                        .map(|_| if rng.chance(0.6) { 0.0 } else { rng.range_f64(0.0, 1.0) })
                        .collect()
                })
                .collect();
            let stake: Vec<f64> = (0..n_val)
                .map(|_| if rng.chance(0.25) { 0.0 } else { rng.range_f64(1.0, 100.0) })
                .collect();
            let inc = yuma_consensus(&weights, &stake, &p());
            prop_assert!(inc.len() == n_peer, "length mismatch");
            let total: f64 = inc.iter().sum();
            prop_assert!(
                inc.iter().all(|x| x.is_finite() && *x >= 0.0),
                "non-finite or negative incentive: {inc:?}"
            );
            prop_assert!(total <= 1.0 + 1e-9, "incentives sum {total} > 1");
            Ok(())
        });
    }

    #[test]
    fn prop_sparse_equals_dense() {
        // The API-redesign pin: consensus over sparse rows holding only the
        // nonzero entries must match the dense matrix — zeros and all — to
        // 1e-12, including columns nobody touches (implicitly zero) and
        // zero-stake validators. Uids are spread over a range far larger
        // than the active count so the sparse path cannot secretly
        // densify.
        prop::check("yuma-sparse-vs-dense", 60, |rng, size| {
            let n_val = 1 + size % 6;
            let n_peer = 2 + size % 12;
            let stride = 1 + (size as u32 % 1000) * 97; // uid gaps up to ~100k
            let uids: Vec<Uid> = (0..n_peer as u32).map(|j| j * stride).collect();
            let weights: Vec<Vec<f64>> = (0..n_val)
                .map(|_| {
                    (0..n_peer)
                        .map(|_| if rng.chance(0.6) { 0.0 } else { rng.range_f64(0.0, 1.0) })
                        .collect()
                })
                .collect();
            let stake: Vec<f64> = (0..n_val)
                .map(|_| if rng.chance(0.2) { 0.0 } else { rng.range_f64(1.0, 100.0) })
                .collect();

            let dense = yuma_consensus(&weights, &stake, &p());

            let sparse_rows: Vec<Vec<(Uid, f64)>> = weights
                .iter()
                .map(|row| {
                    row.iter()
                        .enumerate()
                        .filter(|(_, w)| **w != 0.0)
                        .map(|(j, &w)| (uids[j], w))
                        .collect()
                })
                .collect();
            let mut rows = WeightRows::with_capacity(n_val);
            for (row, &s) in sparse_rows.iter().zip(&stake) {
                rows.push(s, row);
            }
            let sparse = yuma_consensus_sparse(&rows, &p());

            prop_assert!(
                sparse.windows(2).all(|p| p[0].0 < p[1].0),
                "sparse output not ascending-uid: {sparse:?}"
            );
            for (j, &uid) in uids.iter().enumerate() {
                let s = sparse
                    .iter()
                    .find(|(u, _)| *u == uid)
                    .map(|(_, i)| *i)
                    .unwrap_or(0.0);
                prop_assert!(
                    (s - dense[j]).abs() < 1e-12,
                    "uid {uid} (col {j}): sparse {s} vs dense {}",
                    dense[j]
                );
            }
            for (u, _) in &sparse {
                prop_assert!(uids.contains(u), "sparse invented uid {u}");
            }
            Ok(())
        });
    }

    #[test]
    fn prop_permuting_uid_order_never_changes_results() {
        // Consensus must be a per-column computation: permuting the peer
        // (column) order permutes the incentives and nothing else, and
        // permuting the validator (row) order together with stakes changes
        // nothing at all. A violation would mean registration order leaks
        // into payouts.
        prop::check("yuma-permutation", 40, |rng, size| {
            let n_val = 2 + size % 4;
            let n_peer = 2 + size % 6;
            let weights: Vec<Vec<f64>> = (0..n_val)
                .map(|_| {
                    (0..n_peer)
                        .map(|_| if rng.chance(0.5) { 0.0 } else { rng.range_f64(0.0, 1.0) })
                        .collect()
                })
                .collect();
            let stake: Vec<f64> = (0..n_val).map(|_| rng.range_f64(1.0, 100.0)).collect();
            let base = yuma_consensus(&weights, &stake, &p());

            let mut cols: Vec<usize> = (0..n_peer).collect();
            rng.shuffle(&mut cols);
            let permuted_w: Vec<Vec<f64>> = weights
                .iter()
                .map(|row| cols.iter().map(|&j| row[j]).collect())
                .collect();
            let permuted = yuma_consensus(&permuted_w, &stake, &p());
            for (i, &j) in cols.iter().enumerate() {
                prop_assert!(
                    (permuted[i] - base[j]).abs() < 1e-12,
                    "column permutation changed peer {j}: {} vs {}",
                    permuted[i],
                    base[j]
                );
            }

            let mut rows: Vec<usize> = (0..n_val).collect();
            rng.shuffle(&mut rows);
            let rw: Vec<Vec<f64>> = rows.iter().map(|&v| weights[v].clone()).collect();
            let rs: Vec<f64> = rows.iter().map(|&v| stake[v]).collect();
            let row_permuted = yuma_consensus(&rw, &rs, &p());
            for j in 0..n_peer {
                prop_assert!(
                    (row_permuted[j] - base[j]).abs() < 1e-12,
                    "validator order changed peer {j}: {} vs {}",
                    row_permuted[j],
                    base[j]
                );
            }
            Ok(())
        });
    }

    #[test]
    fn prop_stake_scaling_invariance() {
        prop::check("yuma-stake-scale", 30, |rng, size| {
            let n_val = 2 + size % 3;
            let n_peer = 2 + size % 4;
            let weights: Vec<Vec<f64>> = (0..n_val)
                .map(|_| (0..n_peer).map(|_| rng.range_f64(0.0, 1.0)).collect())
                .collect();
            let stake: Vec<f64> = (0..n_val).map(|_| rng.range_f64(1.0, 10.0)).collect();
            let scaled: Vec<f64> = stake.iter().map(|s| s * 7.0).collect();
            let a = yuma_consensus(&weights, &stake, &p());
            let b = yuma_consensus(&weights, &scaled, &p());
            for (x, y) in a.iter().zip(&b) {
                prop_assert!((x - y).abs() < 1e-9, "stake scale changed outcome");
            }
            Ok(())
        });
    }
}
