//! Yuma consensus (Steeves et al. [18]; docs.bittensor.com/yuma-consensus).
//!
//! Given each validator's weight vector over peers and each validator's
//! stake, Yuma computes, per peer, a *consensus weight*: the largest value
//! `w` such that validators holding at least a `kappa` fraction of total
//! stake assign the peer at least `w`. Every validator's weight is then
//! clipped to the consensus (punishing out-of-consensus inflation), and
//! incentives are the stake-weighted sum of clipped weights, normalized to
//! sum to 1. A dishonest minority validator therefore cannot pump a peer's
//! incentive above what the stake majority supports.

use crate::util::det_sum;

#[derive(Clone, Copy, Debug)]
pub struct YumaParams {
    /// Stake-majority threshold (mainnet default 0.5).
    pub kappa: f64,
}

impl Default for YumaParams {
    fn default() -> Self {
        YumaParams { kappa: 0.5 }
    }
}

/// `weights[v][j]` = validator v's (non-negative) weight on peer j.
/// `stake[v]` = validator v's stake. Returns per-peer incentives summing to
/// 1 (all zeros if every weight is zero).
pub fn yuma_consensus(weights: &[Vec<f64>], stake: &[f64], params: &YumaParams) -> Vec<f64> {
    assert_eq!(weights.len(), stake.len());
    if weights.is_empty() {
        return vec![];
    }
    let n_peers = weights[0].len();
    for row in weights {
        assert_eq!(row.len(), n_peers, "ragged weight matrix");
    }
    let total_stake = det_sum(stake.iter().copied());
    if total_stake <= 0.0 {
        return vec![0.0; n_peers];
    }

    // Row-normalize each validator's weights (the chain stores weights
    // already normalized; we re-normalize defensively).
    let norm: Vec<Vec<f64>> = weights
        .iter()
        .map(|row| {
            let s = det_sum(row.iter().copied());
            if s > 0.0 {
                row.iter().map(|w| w / s).collect()
            } else {
                row.clone()
            }
        })
        .collect();

    // Consensus per peer: kappa-stake-weighted quantile of the column.
    let consensus: Vec<f64> = (0..n_peers)
        .map(|j| {
            // candidate thresholds are the committed weights themselves
            let mut col: Vec<(f64, f64)> =
                norm.iter().zip(stake).map(|(row, &s)| (row[j], s)).collect();
            col.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            // largest w s.t. stake of validators with weight >= w is
            // >= kappa * total
            let mut best = 0.0;
            for &(w, _) in &col {
                let supporting =
                    det_sum(col.iter().filter(|(wi, _)| *wi >= w).map(|(_, s)| *s));
                if supporting >= params.kappa * total_stake {
                    best = w;
                }
            }
            best
        })
        .collect();

    // Clip and combine by stake.
    let mut rank = vec![0.0; n_peers];
    for (row, &s) in norm.iter().zip(stake) {
        for j in 0..n_peers {
            rank[j] += s * row[j].min(consensus[j]);
        }
    }
    let total = det_sum(rank.iter().copied());
    if total > 0.0 {
        for r in &mut rank {
            *r /= total;
        }
    }
    rank
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use crate::prop_assert;

    fn p() -> YumaParams {
        YumaParams::default()
    }

    #[test]
    fn single_validator_passthrough() {
        let inc = yuma_consensus(&[vec![0.75, 0.25]], &[100.0], &p());
        assert!((inc[0] - 0.75).abs() < 1e-12);
        assert!((inc[1] - 0.25).abs() < 1e-12);
    }

    #[test]
    fn agreement_is_preserved() {
        let w = vec![vec![0.6, 0.4], vec![0.6, 0.4], vec![0.6, 0.4]];
        let inc = yuma_consensus(&w, &[10.0, 20.0, 30.0], &p());
        assert!((inc[0] - 0.6).abs() < 1e-9);
    }

    #[test]
    fn minority_validator_cannot_pump_a_peer() {
        // Two honest validators (90% of stake) give peer 1 nothing; a
        // dishonest 10% validator gives it everything. Consensus clips the
        // dishonest weight to the majority's (0), so peer 1 earns ~0.
        let w = vec![vec![1.0, 0.0], vec![1.0, 0.0], vec![0.0, 1.0]];
        let inc = yuma_consensus(&w, &[45.0, 45.0, 10.0], &p());
        assert!(inc[1] < 1e-9, "pumped peer got {}", inc[1]);
        assert!((inc[0] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn majority_attacker_does_control() {
        // Flip the stake: the "attacker" holds the majority, so its view IS
        // the consensus — stake is the security assumption.
        let w = vec![vec![1.0, 0.0], vec![0.0, 1.0]];
        let inc = yuma_consensus(&w, &[10.0, 90.0], &p());
        assert!(inc[1] > 0.85, "majority view should dominate: {inc:?}");
    }

    #[test]
    fn zero_everything_is_safe() {
        assert_eq!(yuma_consensus(&[], &[], &p()), Vec::<f64>::new());
        assert_eq!(yuma_consensus(&[vec![0.0, 0.0]], &[5.0], &p()), vec![0.0, 0.0]);
        assert_eq!(yuma_consensus(&[vec![1.0]], &[0.0], &p()), vec![0.0]);
    }

    #[test]
    fn unnormalized_rows_are_renormalized() {
        let inc = yuma_consensus(&[vec![30.0, 10.0]], &[1.0], &p());
        assert!((inc[0] - 0.75).abs() < 1e-12);
    }

    #[test]
    fn prop_incentives_normalized_and_bounded_by_majority_max() {
        prop::check("yuma-invariants", 50, |rng, size| {
            let n_val = 1 + size % 5;
            let n_peer = 1 + size % 7;
            let weights: Vec<Vec<f64>> = (0..n_val)
                .map(|_| (0..n_peer).map(|_| rng.range_f64(0.0, 1.0)).collect())
                .collect();
            let stake: Vec<f64> = (0..n_val).map(|_| rng.range_f64(1.0, 100.0)).collect();
            let inc = yuma_consensus(&weights, &stake, &p());
            prop_assert!(inc.len() == n_peer, "length mismatch");
            let total: f64 = inc.iter().sum();
            prop_assert!(
                inc.iter().all(|x| (0.0..=1.0 + 1e-9).contains(x)),
                "incentive out of range: {inc:?}"
            );
            prop_assert!(
                total < 1e-9 || (total - 1.0).abs() < 1e-9,
                "not normalized: {total}"
            );
            Ok(())
        });
    }

    #[test]
    fn prop_sparse_rows_and_zero_stakes_stay_sane() {
        // Committed weight rows are sparse in practice (top-G of a large
        // uid table) and stakes can be zero (scripted demotion): incentives
        // must stay finite, non-negative, and sum to at most 1 + eps.
        prop::check("yuma-sparse", 50, |rng, size| {
            let n_val = 1 + size % 6;
            let n_peer = 1 + size % 9;
            let weights: Vec<Vec<f64>> = (0..n_val)
                .map(|_| {
                    (0..n_peer)
                        .map(|_| if rng.chance(0.6) { 0.0 } else { rng.range_f64(0.0, 1.0) })
                        .collect()
                })
                .collect();
            let stake: Vec<f64> = (0..n_val)
                .map(|_| if rng.chance(0.25) { 0.0 } else { rng.range_f64(1.0, 100.0) })
                .collect();
            let inc = yuma_consensus(&weights, &stake, &p());
            prop_assert!(inc.len() == n_peer, "length mismatch");
            let total: f64 = inc.iter().sum();
            prop_assert!(
                inc.iter().all(|x| x.is_finite() && *x >= 0.0),
                "non-finite or negative incentive: {inc:?}"
            );
            prop_assert!(total <= 1.0 + 1e-9, "incentives sum {total} > 1");
            Ok(())
        });
    }

    #[test]
    fn prop_permuting_uid_order_never_changes_results() {
        // Consensus must be a per-column computation: permuting the peer
        // (column) order permutes the incentives and nothing else, and
        // permuting the validator (row) order together with stakes changes
        // nothing at all. A violation would mean registration order leaks
        // into payouts.
        prop::check("yuma-permutation", 40, |rng, size| {
            let n_val = 2 + size % 4;
            let n_peer = 2 + size % 6;
            let weights: Vec<Vec<f64>> = (0..n_val)
                .map(|_| {
                    (0..n_peer)
                        .map(|_| if rng.chance(0.5) { 0.0 } else { rng.range_f64(0.0, 1.0) })
                        .collect()
                })
                .collect();
            let stake: Vec<f64> = (0..n_val).map(|_| rng.range_f64(1.0, 100.0)).collect();
            let base = yuma_consensus(&weights, &stake, &p());

            let mut cols: Vec<usize> = (0..n_peer).collect();
            rng.shuffle(&mut cols);
            let permuted_w: Vec<Vec<f64>> = weights
                .iter()
                .map(|row| cols.iter().map(|&j| row[j]).collect())
                .collect();
            let permuted = yuma_consensus(&permuted_w, &stake, &p());
            for (i, &j) in cols.iter().enumerate() {
                prop_assert!(
                    (permuted[i] - base[j]).abs() < 1e-12,
                    "column permutation changed peer {j}: {} vs {}",
                    permuted[i],
                    base[j]
                );
            }

            let mut rows: Vec<usize> = (0..n_val).collect();
            rng.shuffle(&mut rows);
            let rw: Vec<Vec<f64>> = rows.iter().map(|&v| weights[v].clone()).collect();
            let rs: Vec<f64> = rows.iter().map(|&v| stake[v]).collect();
            let row_permuted = yuma_consensus(&rw, &rs, &p());
            for j in 0..n_peer {
                prop_assert!(
                    (row_permuted[j] - base[j]).abs() < 1e-12,
                    "validator order changed peer {j}: {} vs {}",
                    row_permuted[j],
                    base[j]
                );
            }
            Ok(())
        });
    }

    #[test]
    fn prop_stake_scaling_invariance() {
        prop::check("yuma-stake-scale", 30, |rng, size| {
            let n_val = 2 + size % 3;
            let n_peer = 2 + size % 4;
            let weights: Vec<Vec<f64>> = (0..n_val)
                .map(|_| (0..n_peer).map(|_| rng.range_f64(0.0, 1.0)).collect())
                .collect();
            let stake: Vec<f64> = (0..n_val).map(|_| rng.range_f64(1.0, 10.0)).collect();
            let scaled: Vec<f64> = stake.iter().map(|s| s * 7.0).collect();
            let a = yuma_consensus(&weights, &stake, &p());
            let b = yuma_consensus(&weights, &scaled, &p());
            for (x, y) in a.iter().zip(&b) {
                prop_assert!((x - y).abs() < 1e-9, "stake scale changed outcome");
            }
            Ok(())
        });
    }
}
