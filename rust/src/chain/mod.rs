//! Simulated Bittensor substrate: block clock, permissionless registration,
//! a bounded neuron-slot table with churn, stake, weight commits, Yuma
//! consensus, and token emission.
//!
//! Gauntlet's scores only become money once a validator posts them to the
//! chain and the chain combines (possibly several) validators' weight
//! vectors under the Yuma consensus protocol [18], weighting each validator
//! by its stake and clipping outliers to the stake-majority consensus.
//! This module provides exactly that substrate, plus the two pieces of
//! chain state the paper leans on elsewhere: a global block clock used to
//! timestamp put windows (§5) and the read-key registry for peers' buckets.
//!
//! # Peer lifecycle and uid recycling
//!
//! The paper's "completely permissionless" population is dynamic: peers
//! join, leave, and get displaced mid-run. Like the live subnet, the uid
//! space is a bounded slot table ([`Chain::max_uids`]; 0 = unbounded):
//!
//! - [`Chain::deregister`] frees a neuron's slot. Its committed weight row
//!   and any weights other validators committed *for* it are scrubbed, so
//!   a later occupant of the uid inherits nothing.
//! - Registration reuses the **lowest freed uid** before allocating a new
//!   one; when every slot is occupied, the newcomer **evicts** the
//!   lowest-incentive, zero-stake, non-permit neuron outside its immunity
//!   period (ties broken by ascending uid), exactly Bittensor's
//!   replacement rule. Validator identities hold a
//!   [`Neuron::validator_permit`] and are never replacement victims, even
//!   while demoted to zero stake. If every occupant is immune, staked, or
//!   permit-holding, registration fails with [`ChainError::NoSlots`].
//! - A neuron is immune for [`Chain::immunity_blocks`] blocks after
//!   registration, giving newcomers time to earn their first incentive
//!   before they can be displaced.
//!
//! **Recycled uids are new identities.** [`Registration::recycled`] tells
//! the coordinator the uid had a previous occupant; everything keyed by
//! uid off-chain — OpenSkill rating, proof-of-computation EMA, phi/sync
//! history, the storage bucket — must be reset to a fresh prior, which is
//! exactly what `coordinator::run` does on a recycled registration.

use std::collections::{BTreeMap, BTreeSet};

pub mod yuma;

#[allow(deprecated)] // the dense shim stays re-exported for downstream callers
pub use yuma::yuma_consensus;
pub use yuma::{yuma_consensus_sparse, WeightRows, YumaParams};

use crate::storage::ReadKey;

/// A network participant id (paper: "uid" on the subnet).
pub type Uid = u32;

/// Milliseconds per block (Bittensor mainnet: 12 s).
pub const BLOCK_MS: u64 = 12_000;

#[derive(Clone, Debug, PartialEq)]
pub struct Neuron {
    pub uid: Uid,
    pub hotkey: String,
    /// Stake in TAO; > 0 effectively makes the neuron a validator.
    pub stake: f64,
    /// Read credential for the neuron's bucket (posted at registration).
    pub bucket_read_key: Option<ReadKey>,
    pub registered_at_block: u64,
    /// Cumulative emission received.
    pub balance: f64,
    /// Incentive from the most recent Yuma epoch (the eviction/pruning
    /// score: full slots displace the lowest-incentive non-immune neuron).
    pub last_incentive: f64,
    /// Validator permit: the slot belongs to a validator identity and is
    /// never a replacement victim, even while its stake is (temporarily)
    /// zero — a demoted validator keeps its uid until it deregisters.
    pub validator_permit: bool,
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum ChainError {
    #[error("hotkey {0:?} already registered")]
    DuplicateHotkey(String),
    #[error("unknown uid {0}")]
    UnknownUid(Uid),
    #[error("weights must be finite and non-negative")]
    BadWeights,
    #[error("uid {0} has no stake; only validators may set weights")]
    NotValidator(Uid),
    #[error("all {0} neuron slots are occupied by immune, staked, or permit-holding neurons")]
    NoSlots(usize),
}

/// What [`Chain::register_replacing`] did.
#[derive(Clone, Debug, PartialEq)]
pub struct Registration {
    pub uid: Uid,
    /// The uid had a previous occupant (freed by deregistration or evicted
    /// just now) — off-chain state keyed by this uid must be reset.
    pub recycled: bool,
    /// Hotkey of the neuron evicted to make room, if slot pressure forced
    /// a replacement.
    pub evicted_hotkey: Option<String>,
}

/// Every field of the simulated subnet, exported as plain data so run
/// snapshots (`coordinator::snapshot`) can serialize and rebuild the chain
/// exactly — including committed weight rows, the freed-uid pool, and the
/// monotone uid counter, all of which feed future epochs and registrations.
#[derive(Clone, Debug)]
pub struct ChainState {
    pub block: u64,
    pub neurons: Vec<Neuron>,
    pub next_uid: Uid,
    pub free_uids: Vec<Uid>,
    /// `(validator uid, [(target uid, weight)])`, sorted by validator uid.
    pub weights: Vec<(Uid, Vec<(Uid, f64)>)>,
    pub yuma: YumaParams,
    pub emission_per_epoch: f64,
    pub max_uids: usize,
    pub immunity_blocks: u64,
}

/// Total-order key for the stake index: orders stakes *descending* via
/// `total_cmp`, so `(StakeOrd, Uid)` tuples iterate best-first with an
/// ascending-uid tiebreak and never panic, whatever the float.
#[derive(Clone, Copy, Debug, PartialEq)]
struct StakeOrd(f64);

impl Eq for StakeOrd {}

impl Ord for StakeOrd {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other.0.total_cmp(&self.0)
    }
}

impl PartialOrd for StakeOrd {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// The simulated subnet.
///
/// All per-round queries are served from incrementally maintained indexes
/// — `hotkeys` (registration duplicate check), `staked` (validator order),
/// `paid` (uids holding a nonzero `last_incentive`) — so registration,
/// validator resolution, and the Yuma epoch cost O(active · log table)
/// rather than O(table). The indexes are derived state: [`ChainState`]
/// does not carry them and [`Chain::from_state`] rebuilds them.
pub struct Chain {
    pub block: u64,
    neurons: BTreeMap<Uid, Neuron>,
    next_uid: Uid,
    /// Uids freed by deregistration, reused lowest-first.
    free_uids: BTreeSet<Uid>,
    /// Latest committed weight vector per validator uid: target uid -> w.
    weights: BTreeMap<Uid, BTreeMap<Uid, f64>>,
    /// Registered hotkey -> uid (duplicate check without a table scan).
    hotkeys: BTreeMap<String, Uid>,
    /// Staked neurons keyed best-first: stake descending, uid ascending.
    staked: BTreeSet<(StakeOrd, Uid)>,
    /// Uids whose `last_incentive` is nonzero — exactly the entries the
    /// next epoch must clear, replacing the old full-table sweep.
    paid: BTreeSet<Uid>,
    pub yuma: YumaParams,
    /// TAO emitted to contributors per epoch (paper: real-valued payouts).
    pub emission_per_epoch: f64,
    /// Neuron-slot capacity (0 = unbounded). When full, a new registration
    /// evicts the lowest-incentive non-immune zero-stake neuron.
    pub max_uids: usize,
    /// Blocks after registration during which a neuron cannot be evicted.
    pub immunity_blocks: u64,
}

impl Chain {
    pub fn new() -> Self {
        Chain {
            block: 0,
            neurons: BTreeMap::new(),
            next_uid: 0,
            free_uids: BTreeSet::new(),
            weights: BTreeMap::new(),
            hotkeys: BTreeMap::new(),
            staked: BTreeSet::new(),
            paid: BTreeSet::new(),
            yuma: YumaParams::default(),
            emission_per_epoch: 1.0,
            max_uids: 0,
            immunity_blocks: 0,
        }
    }

    /// Export the full chain state for a run snapshot (see [`ChainState`]).
    pub fn to_state(&self) -> ChainState {
        ChainState {
            block: self.block,
            neurons: self.neurons.values().cloned().collect(),
            next_uid: self.next_uid,
            free_uids: self.free_uids.iter().copied().collect(),
            weights: self
                .weights
                .iter()
                .map(|(v, row)| (*v, row.iter().map(|(u, w)| (*u, *w)).collect()))
                .collect(),
            yuma: self.yuma,
            emission_per_epoch: self.emission_per_epoch,
            max_uids: self.max_uids,
            immunity_blocks: self.immunity_blocks,
        }
    }

    /// Rebuild a chain from an exported [`ChainState`] — the exact inverse
    /// of [`Chain::to_state`], so a resumed run's registrations, epochs,
    /// and evictions continue bit-identically. The hotkey / stake / paid
    /// indexes are derived from the neuron table here rather than carried
    /// in the state.
    pub fn from_state(state: ChainState) -> Chain {
        let neurons: BTreeMap<Uid, Neuron> =
            state.neurons.into_iter().map(|n| (n.uid, n)).collect();
        let hotkeys = neurons.values().map(|n| (n.hotkey.clone(), n.uid)).collect();
        let staked = neurons
            .values()
            .filter(|n| n.stake > 0.0)
            .map(|n| (StakeOrd(n.stake), n.uid))
            .collect();
        let paid = neurons
            .values()
            .filter(|n| n.last_incentive != 0.0)
            .map(|n| n.uid)
            .collect();
        Chain {
            block: state.block,
            neurons,
            next_uid: state.next_uid,
            free_uids: state.free_uids.into_iter().collect(),
            weights: state
                .weights
                .into_iter()
                .map(|(v, row)| (v, row.into_iter().collect()))
                .collect(),
            hotkeys,
            staked,
            paid,
            yuma: state.yuma,
            emission_per_epoch: state.emission_per_epoch,
            max_uids: state.max_uids,
            immunity_blocks: state.immunity_blocks,
        }
    }

    /// Advance the global clock.
    pub fn advance_blocks(&mut self, n: u64) {
        self.block += n;
    }

    /// Current chain time in ms (the "consistent global clock" of §3.2).
    pub fn now_ms(&self) -> u64 {
        self.block * BLOCK_MS
    }

    /// Permissionless registration: anyone with a fresh hotkey gets a uid.
    /// (The live chain charges a registration fee / PoW; economically that
    /// is folded into the incentive analysis, not modelled here.)
    ///
    /// Convenience wrapper over [`Chain::register_replacing`] for callers
    /// that only need the uid.
    pub fn register(&mut self, hotkey: &str) -> Result<Uid, ChainError> {
        self.register_replacing(hotkey).map(|r| r.uid)
    }

    /// Permissionless registration with full slot-table semantics (see the
    /// module docs): freed uids are reused lowest-first, and when every
    /// slot is occupied the lowest-incentive non-immune zero-stake neuron
    /// is evicted to make room. The caller learns via
    /// [`Registration::recycled`] whether off-chain per-uid state must be
    /// reset.
    pub fn register_replacing(&mut self, hotkey: &str) -> Result<Registration, ChainError> {
        // Indexed duplicate check: a table scan here would make bulk
        // registration O(n^2) — at the 1M-uid scale the sparse epoch
        // targets, registration itself must stay O(log table).
        if self.hotkeys.contains_key(hotkey) {
            return Err(ChainError::DuplicateHotkey(hotkey.to_string()));
        }
        let lowest_free = self.free_uids.iter().next().copied();
        let (uid, recycled, evicted_hotkey) = if let Some(uid) = lowest_free {
            self.free_uids.remove(&uid);
            (uid, true, None)
        } else if self.max_uids == 0 || self.neurons.len() < self.max_uids {
            let uid = self.next_uid;
            self.next_uid += 1;
            (uid, false, None)
        } else {
            let victim = self.eviction_candidate().ok_or(ChainError::NoSlots(self.max_uids))?;
            let hk = self.neurons[&victim].hotkey.clone();
            self.deregister(victim)?;
            self.free_uids.remove(&victim);
            (victim, true, Some(hk))
        };
        self.neurons.insert(
            uid,
            Neuron {
                uid,
                hotkey: hotkey.to_string(),
                stake: 0.0,
                bucket_read_key: None,
                registered_at_block: self.block,
                balance: 0.0,
                last_incentive: 0.0,
                validator_permit: false,
            },
        );
        self.hotkeys.insert(hotkey.to_string(), uid);
        Ok(Registration { uid, recycled, evicted_hotkey })
    }

    /// Free a neuron's slot (a peer leaving, or the replacement rule).
    /// Scrubs the neuron's committed weight row and every weight other
    /// validators committed *for* it, so a future occupant of the uid
    /// inherits nothing.
    pub fn deregister(&mut self, uid: Uid) -> Result<(), ChainError> {
        let Some(n) = self.neurons.remove(&uid) else {
            return Err(ChainError::UnknownUid(uid));
        };
        self.hotkeys.remove(&n.hotkey);
        if n.stake > 0.0 {
            self.staked.remove(&(StakeOrd(n.stake), uid));
        }
        self.paid.remove(&uid);
        self.weights.remove(&uid);
        for row in self.weights.values_mut() {
            row.remove(&uid);
        }
        self.free_uids.insert(uid);
        Ok(())
    }

    /// Whether `uid` is inside its post-registration immunity period.
    pub fn is_immune(&self, uid: Uid) -> bool {
        self.neurons.get(&uid).is_some_and(|n| {
            self.block < n.registered_at_block.saturating_add(self.immunity_blocks)
        })
    }

    /// Grant or revoke a validator permit (see [`Neuron::validator_permit`]).
    pub fn set_validator_permit(&mut self, uid: Uid, permit: bool) -> Result<(), ChainError> {
        let n = self.neurons.get_mut(&uid).ok_or(ChainError::UnknownUid(uid))?;
        n.validator_permit = permit;
        Ok(())
    }

    /// The neuron a full slot table would evict: lowest `last_incentive`
    /// among non-immune, zero-stake, non-permit neurons, ties broken by
    /// ascending uid. Staked neurons and validator-permit holders (even
    /// temporarily demoted ones) are never evicted.
    pub fn eviction_candidate(&self) -> Option<Uid> {
        self.neurons
            .values()
            .filter(|n| n.stake <= 0.0 && !n.validator_permit && !self.is_immune(n.uid))
            .min_by(|a, b| a.last_incentive.total_cmp(&b.last_incentive).then(a.uid.cmp(&b.uid)))
            .map(|n| n.uid)
    }

    /// Keep the best-first stake index in step with a stake change: only
    /// strictly positive stakes are indexed (NaN compares `> 0.0` false on
    /// both sides, so a NaN-staked neuron simply never enters the index).
    fn reindex_stake(&mut self, uid: Uid, old: f64, new: f64) {
        if old > 0.0 {
            self.staked.remove(&(StakeOrd(old), uid));
        }
        if new > 0.0 {
            self.staked.insert((StakeOrd(new), uid));
        }
    }

    pub fn add_stake(&mut self, uid: Uid, amount: f64) -> Result<(), ChainError> {
        let (old, new) = {
            let n = self.neurons.get_mut(&uid).ok_or(ChainError::UnknownUid(uid))?;
            let old = n.stake;
            n.stake += amount;
            (old, n.stake)
        };
        self.reindex_stake(uid, old, new);
        Ok(())
    }

    /// Set a neuron's stake to an absolute amount (scenario scripting).
    pub fn set_stake(&mut self, uid: Uid, amount: f64) -> Result<(), ChainError> {
        let old = {
            let n = self.neurons.get_mut(&uid).ok_or(ChainError::UnknownUid(uid))?;
            let old = n.stake;
            n.stake = amount;
            old
        };
        self.reindex_stake(uid, old, amount);
        Ok(())
    }

    /// Publish the read key for the neuron's bucket (paper §5).
    pub fn post_read_key(&mut self, uid: Uid, key: ReadKey) -> Result<(), ChainError> {
        let n = self.neurons.get_mut(&uid).ok_or(ChainError::UnknownUid(uid))?;
        n.bucket_read_key = Some(key);
        Ok(())
    }

    pub fn neuron(&self, uid: Uid) -> Option<&Neuron> {
        self.neurons.get(&uid)
    }

    pub fn neurons(&self) -> impl Iterator<Item = &Neuron> {
        self.neurons.values()
    }

    /// Registered uids in ascending order, borrowed — collect only if a
    /// materialized set is really needed.
    pub fn uids(&self) -> impl Iterator<Item = Uid> + '_ {
        self.neurons.keys().copied()
    }

    /// Number of registered neurons.
    pub fn n_registered(&self) -> usize {
        self.neurons.len()
    }

    /// Validators = staked neurons, ordered by stake descending with an
    /// ascending-uid tiebreak, served as a borrowed iterator over the
    /// incrementally maintained stake index — O(#validators), not an
    /// O(table) filter-and-sort-and-clone. `total_cmp` keeps the index
    /// order total (and panic-free) even for NaN stakes, so the lead
    /// validator — and thus which weight vector drives aggregation — is
    /// always deterministic.
    pub fn validators(&self) -> impl Iterator<Item = Uid> + '_ {
        self.staked.iter().map(|(_, u)| *u)
    }

    /// The highest-staked validator provides checkpoint locations and the
    /// top-G peer list in the current protocol (paper §3.3). O(1) off the
    /// stake index.
    pub fn lead_validator(&self) -> Option<Uid> {
        self.staked.iter().next().map(|(_, u)| *u)
    }

    /// A validator commits its (pre-normalized, non-negative) weights.
    pub fn set_weights(&mut self, validator: Uid, w: &[(Uid, f64)]) -> Result<(), ChainError> {
        let v = self.neurons.get(&validator).ok_or(ChainError::UnknownUid(validator))?;
        if v.stake <= 0.0 {
            return Err(ChainError::NotValidator(validator));
        }
        if w.iter().any(|(_, x)| !x.is_finite() || *x < 0.0) {
            return Err(ChainError::BadWeights);
        }
        for (uid, _) in w {
            if !self.neurons.contains_key(uid) {
                return Err(ChainError::UnknownUid(*uid));
            }
        }
        self.weights.insert(validator, w.iter().copied().collect());
        Ok(())
    }

    pub fn committed_weights(&self, validator: Uid) -> Option<&BTreeMap<Uid, f64>> {
        self.weights.get(&validator)
    }

    /// Run one Yuma epoch: combine all committed validator weights into
    /// consensus incentives and pay emission. Returns (uid, incentive)
    /// with incentives summing to 1 over peers with any weight (or empty
    /// if no validator has committed anything).
    ///
    /// The epoch is *incremental*: consensus runs over the sparse union of
    /// uids carrying committed weight ([`yuma_consensus_sparse`]), and
    /// stale eviction scores are cleared through the `paid` index rather
    /// than a table sweep, so the whole epoch costs
    /// O(active · validators), independent of how many uids are
    /// registered.
    pub fn run_epoch(&mut self) -> Vec<(Uid, f64)> {
        // Every epoch resets the eviction scores first — including epochs
        // that pay nobody (no staked committer left): `last_incentive`
        // must reflect the *current* epoch, or eviction would rank peers
        // by a consensus that no longer exists. Only uids in `paid` can
        // hold a nonzero score, so clearing them is O(previously paid).
        for uid in std::mem::take(&mut self.paid) {
            if let Some(n) = self.neurons.get_mut(&uid) {
                n.last_incentive = 0.0;
            }
        }
        // Defensive re-check: a committer may have lost its stake (or its
        // slot) since it set weights. Row order is ascending validator
        // uid (BTreeMap), the same order the dense path used.
        let rows_owned: Vec<(f64, Vec<(Uid, f64)>)> = self
            .weights
            .iter()
            .filter_map(|(v, row)| {
                let n = self.neurons.get(v)?;
                (n.stake > 0.0)
                    .then(|| (n.stake, row.iter().map(|(u, w)| (*u, *w)).collect()))
            })
            .collect();
        if rows_owned.is_empty() {
            return vec![];
        }
        let mut rows = WeightRows::with_capacity(rows_owned.len());
        for (stake, row) in &rows_owned {
            rows.push(*stake, row);
        }
        let out: Vec<(Uid, f64)> = yuma_consensus_sparse(&rows, &self.yuma)
            .into_iter()
            .filter(|(_, inc)| *inc > 0.0)
            .collect();
        for (uid, inc) in &out {
            let n = self.neurons.get_mut(uid).unwrap();
            n.balance += inc * self.emission_per_epoch;
            n.last_incentive = *inc;
            self.paid.insert(*uid);
        }
        out
    }
}

impl Default for Chain {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain_with_validator() -> (Chain, Uid) {
        let mut c = Chain::new();
        let v = c.register("validator").unwrap();
        c.add_stake(v, 1000.0).unwrap();
        (c, v)
    }

    #[test]
    fn registration_is_permissionless_and_uids_increment() {
        let mut c = Chain::new();
        let a = c.register("alice").unwrap();
        let b = c.register("bob").unwrap();
        assert_eq!((a, b), (0, 1));
        assert_eq!(c.neuron(a).unwrap().hotkey, "alice");
    }

    #[test]
    fn duplicate_hotkey_rejected_but_sybils_allowed() {
        // The paper's "Duplicating Contributions" attack registers many
        // hotkeys; the chain allows that — Gauntlet's PoC catches it.
        let mut c = Chain::new();
        c.register("eve-1").unwrap();
        assert_eq!(c.register("eve-1").unwrap_err(), ChainError::DuplicateHotkey("eve-1".into()));
        c.register("eve-2").unwrap(); // sybil under a fresh hotkey: allowed
    }

    #[test]
    fn block_clock_advances() {
        let mut c = Chain::new();
        assert_eq!(c.now_ms(), 0);
        c.advance_blocks(5);
        assert_eq!(c.now_ms(), 5 * BLOCK_MS);
    }

    #[test]
    fn only_staked_neurons_set_weights() {
        let (mut c, v) = chain_with_validator();
        let p = c.register("peer").unwrap();
        assert_eq!(c.set_weights(p, &[(v, 1.0)]).unwrap_err(), ChainError::NotValidator(p));
        c.set_weights(v, &[(p, 1.0)]).unwrap();
        assert_eq!(c.committed_weights(v).unwrap()[&p], 1.0);
    }

    #[test]
    fn weights_validated() {
        let (mut c, v) = chain_with_validator();
        let p = c.register("peer").unwrap();
        assert_eq!(c.set_weights(v, &[(p, -0.5)]).unwrap_err(), ChainError::BadWeights);
        assert_eq!(c.set_weights(v, &[(p, f64::NAN)]).unwrap_err(), ChainError::BadWeights);
        assert_eq!(c.set_weights(v, &[(99, 0.5)]).unwrap_err(), ChainError::UnknownUid(99));
    }

    #[test]
    fn single_validator_epoch_normalizes_and_pays() {
        let (mut c, v) = chain_with_validator();
        let p0 = c.register("p0").unwrap();
        let p1 = c.register("p1").unwrap();
        c.set_weights(v, &[(p0, 3.0), (p1, 1.0)]).unwrap();
        c.emission_per_epoch = 10.0;
        let inc = c.run_epoch();
        let total: f64 = inc.iter().map(|(_, x)| x).sum();
        assert!((total - 1.0).abs() < 1e-9);
        let i0 = inc.iter().find(|(u, _)| *u == p0).unwrap().1;
        assert!((i0 - 0.75).abs() < 1e-9);
        assert!((c.neuron(p0).unwrap().balance - 7.5).abs() < 1e-9);
    }

    #[test]
    fn lead_validator_is_highest_staked() {
        let mut c = Chain::new();
        let a = c.register("a").unwrap();
        let b = c.register("b").unwrap();
        c.add_stake(a, 10.0).unwrap();
        c.add_stake(b, 50.0).unwrap();
        assert_eq!(c.lead_validator(), Some(b));
    }

    #[test]
    fn validators_tied_stakes_break_by_uid() {
        let mut c = Chain::new();
        let a = c.register("a").unwrap();
        let b = c.register("b").unwrap();
        let d = c.register("d").unwrap();
        c.add_stake(b, 50.0).unwrap();
        c.add_stake(a, 50.0).unwrap();
        c.add_stake(d, 50.0).unwrap();
        assert_eq!(c.validators().collect::<Vec<_>>(), vec![a, b, d], "ties break by uid");
        assert_eq!(c.lead_validator(), Some(a));
    }

    #[test]
    fn validators_nan_stake_does_not_panic() {
        let mut c = Chain::new();
        let a = c.register("a").unwrap();
        let b = c.register("b").unwrap();
        c.add_stake(a, f64::NAN).unwrap();
        c.add_stake(b, 10.0).unwrap();
        // NaN > 0.0 is false, so the NaN-staked neuron is not a validator;
        // the point is the sort is total and the outcome deterministic.
        assert_eq!(c.validators().collect::<Vec<_>>(), vec![b]);
        assert_eq!(c.lead_validator(), Some(b));
    }

    #[test]
    fn deregister_frees_slot_and_scrubs_weights() {
        let (mut c, v) = chain_with_validator();
        let p0 = c.register("p0").unwrap();
        let p1 = c.register("p1").unwrap();
        c.set_weights(v, &[(p0, 0.5), (p1, 0.5)]).unwrap();
        c.deregister(p0).unwrap();
        assert!(c.neuron(p0).is_none());
        assert!(!c.committed_weights(v).unwrap().contains_key(&p0), "weights for it scrubbed");
        assert_eq!(c.deregister(p0).unwrap_err(), ChainError::UnknownUid(p0));
        // freed uid is reused by the next registration, flagged recycled
        let r = c.register_replacing("p2").unwrap();
        assert_eq!((r.uid, r.recycled, r.evicted_hotkey), (p0, true, None));
    }

    #[test]
    fn full_slot_table_evicts_lowest_incentive_non_immune() {
        let mut c = Chain::new();
        c.max_uids = 3;
        let v = c.register("validator").unwrap();
        c.add_stake(v, 100.0).unwrap();
        let p0 = c.register("p0").unwrap();
        let p1 = c.register("p1").unwrap();
        c.set_weights(v, &[(p0, 0.2), (p1, 0.8)]).unwrap();
        c.run_epoch();
        assert!(c.neuron(p0).unwrap().last_incentive < c.neuron(p1).unwrap().last_incentive);
        // Table full: the newcomer displaces p0 (lowest incentive); the
        // staked validator is never a candidate.
        let r = c.register_replacing("newcomer").unwrap();
        assert_eq!(r.uid, p0);
        assert!(r.recycled);
        assert_eq!(r.evicted_hotkey.as_deref(), Some("p0"));
        assert_eq!(c.neuron(p0).unwrap().hotkey, "newcomer");
        assert_eq!(c.neuron(p0).unwrap().last_incentive, 0.0, "fresh occupant, fresh score");
    }

    #[test]
    fn validator_permit_protects_demoted_validators_from_eviction() {
        // A validator demoted to zero stake must keep its slot: its uid
        // being recycled to a peer while the coordinator still runs a
        // Validator under it would collide two identities.
        let mut c = Chain::new();
        c.max_uids = 2;
        let v = c.register("validator").unwrap();
        c.add_stake(v, 100.0).unwrap();
        c.set_validator_permit(v, true).unwrap();
        let p = c.register("peer").unwrap();
        c.set_stake(v, 0.0).unwrap(); // demoted, still permit-holding
        let r = c.register_replacing("newcomer").unwrap();
        assert_eq!(r.uid, p, "the peer, not the demoted validator, is displaced");
        assert_eq!(c.neuron(v).unwrap().hotkey, "validator");
        // With every slot immune or permit-holding, registration fails
        // cleanly instead of touching the demoted validator.
        c.immunity_blocks = 10; // newcomer (registered this block) is immune
        assert_eq!(c.register_replacing("late").unwrap_err(), ChainError::NoSlots(2));
        assert_eq!(
            c.set_validator_permit(99, true).unwrap_err(),
            ChainError::UnknownUid(99)
        );
    }

    #[test]
    fn immunity_protects_newcomers_from_eviction() {
        let mut c = Chain::new();
        c.max_uids = 2;
        c.immunity_blocks = 10;
        let p0 = c.register("p0").unwrap();
        let p1 = c.register("p1").unwrap();
        assert!(c.is_immune(p0) && c.is_immune(p1));
        // Everyone immune: registration must fail, not evict.
        assert_eq!(c.register_replacing("late").unwrap_err(), ChainError::NoSlots(2));
        c.advance_blocks(10);
        assert!(!c.is_immune(p0));
        // Immunity over: lowest-incentive (tie -> lowest uid) is displaced.
        let r = c.register_replacing("late").unwrap();
        assert_eq!(r.uid, p0);
        assert!(c.is_immune(p0), "the new occupant starts its own immunity window");
        assert_eq!(c.neuron(p1).unwrap().hotkey, "p1");
    }

    #[test]
    fn epoch_with_zero_stake_network_pays_nothing() {
        // Weights were committed, then the validator lost its stake: the
        // epoch must degrade to "no consensus" instead of panicking — and
        // it must still clear eviction scores, which would otherwise rank
        // peers by a consensus that no longer exists.
        let (mut c, v) = chain_with_validator();
        let p = c.register("p").unwrap();
        c.set_weights(v, &[(p, 1.0)]).unwrap();
        c.run_epoch();
        assert!(c.neuron(p).unwrap().last_incentive > 0.9);
        c.set_stake(v, 0.0).unwrap();
        assert_eq!(c.run_epoch(), vec![]);
        assert!((c.neuron(p).unwrap().balance - 1.0).abs() < 1e-12, "paid only while staked");
        assert_eq!(c.neuron(p).unwrap().last_incentive, 0.0, "stale eviction score cleared");
    }

    #[test]
    fn epoch_with_deregistered_committer_ignores_its_weights() {
        let mut c = Chain::new();
        let v0 = c.register("v0").unwrap();
        let v1 = c.register("v1").unwrap();
        c.add_stake(v0, 100.0).unwrap();
        c.add_stake(v1, 100.0).unwrap();
        let p0 = c.register("p0").unwrap();
        let p1 = c.register("p1").unwrap();
        c.set_weights(v0, &[(p0, 1.0)]).unwrap();
        c.set_weights(v1, &[(p1, 1.0)]).unwrap();
        c.deregister(v1).unwrap();
        let inc = c.run_epoch();
        assert!(inc.iter().any(|(u, x)| *u == p0 && *x > 0.9), "{inc:?}");
        assert!(!inc.iter().any(|(u, _)| *u == p1), "dead validator's view dropped: {inc:?}");
    }

    #[test]
    fn epoch_with_weights_for_deregistered_target() {
        // v committed weights for p0 and p1, then p1 deregistered before
        // the epoch: p1's weights are scrubbed, p0 absorbs the emission,
        // and a fresh occupant of p1's uid does NOT inherit the old weight.
        let (mut c, v) = chain_with_validator();
        let p0 = c.register("p0").unwrap();
        let p1 = c.register("p1").unwrap();
        c.set_weights(v, &[(p0, 0.5), (p1, 0.5)]).unwrap();
        c.deregister(p1).unwrap();
        let fresh = c.register("fresh").unwrap();
        assert_eq!(fresh, p1, "uid recycled");
        let inc = c.run_epoch();
        assert_eq!(inc, vec![(p0, 1.0)]);
        assert_eq!(c.neuron(fresh).unwrap().balance, 0.0);
    }

    #[test]
    fn epoch_with_tied_validator_stakes_is_deterministic() {
        let mut c = Chain::new();
        let v0 = c.register("v0").unwrap();
        let v1 = c.register("v1").unwrap();
        c.add_stake(v0, 50.0).unwrap();
        c.add_stake(v1, 50.0).unwrap();
        let p0 = c.register("p0").unwrap();
        let p1 = c.register("p1").unwrap();
        c.set_weights(v0, &[(p0, 1.0)]).unwrap();
        c.set_weights(v1, &[(p1, 1.0)]).unwrap();
        let a = c.run_epoch();
        let b = c.run_epoch();
        assert_eq!(a, b, "tied stakes must not make the epoch flap");
        let total: f64 = a.iter().map(|(_, x)| x).sum();
        assert!(total.abs() < 1e-9 || (total - 1.0).abs() < 1e-9, "{a:?}");
    }

    #[test]
    fn single_validator_epoch_is_passthrough() {
        let (mut c, v) = chain_with_validator();
        let p0 = c.register("p0").unwrap();
        let p1 = c.register("p1").unwrap();
        c.set_weights(v, &[(p0, 1.0), (p1, 3.0)]).unwrap();
        let inc = c.run_epoch();
        let get = |u: Uid| inc.iter().find(|(x, _)| *x == u).map(|(_, i)| *i).unwrap_or(0.0);
        assert!((get(p0) - 0.25).abs() < 1e-9 && (get(p1) - 0.75).abs() < 1e-9, "{inc:?}");
    }

    #[test]
    fn state_export_rebuilds_an_identical_chain() {
        let (mut c, v) = chain_with_validator();
        c.max_uids = 8;
        c.immunity_blocks = 3;
        let p0 = c.register("p0").unwrap();
        let p1 = c.register("p1").unwrap();
        c.post_read_key(p0, ReadKey("rk-p0".into())).unwrap();
        c.set_weights(v, &[(p0, 0.7), (p1, 0.3)]).unwrap();
        c.run_epoch();
        c.deregister(p1).unwrap(); // leaves a freed uid + scrubbed weights
        c.advance_blocks(4);

        let mut rebuilt = Chain::from_state(c.to_state());
        assert_eq!(rebuilt.block, c.block);
        assert_eq!(rebuilt.uids().collect::<Vec<_>>(), c.uids().collect::<Vec<_>>());
        assert_eq!(rebuilt.neuron(p0), c.neuron(p0));
        assert_eq!(rebuilt.committed_weights(v), c.committed_weights(v));
        assert_eq!(rebuilt.validators().collect::<Vec<_>>(), c.validators().collect::<Vec<_>>());
        // The freed uid is recycled identically on both chains…
        let a = rebuilt.register_replacing("next").unwrap();
        let b = c.register_replacing("next").unwrap();
        assert_eq!(a, b);
        assert_eq!(a.uid, p1);
        // …and the next epoch pays identically.
        assert_eq!(rebuilt.run_epoch(), c.run_epoch());
    }

    #[test]
    fn stale_eviction_scores_clear_without_full_sweep() {
        // Round 1 pays p0; round 2's weights drop p0 entirely. The sparse
        // epoch never visits p0's column, so its stale `last_incentive`
        // must be cleared through the paid index — a leak here would let a
        // once-paid peer dodge eviction forever.
        let (mut c, v) = chain_with_validator();
        let p0 = c.register("p0").unwrap();
        let p1 = c.register("p1").unwrap();
        c.set_weights(v, &[(p0, 1.0)]).unwrap();
        c.run_epoch();
        assert!(c.neuron(p0).unwrap().last_incentive > 0.9);
        c.set_weights(v, &[(p1, 1.0)]).unwrap();
        c.run_epoch();
        assert_eq!(c.neuron(p0).unwrap().last_incentive, 0.0, "stale score cleared");
        assert!(c.neuron(p1).unwrap().last_incentive > 0.9);
    }

    #[test]
    fn hotkey_index_released_on_deregistration() {
        let mut c = Chain::new();
        let a = c.register("alice").unwrap();
        assert_eq!(c.register("alice").unwrap_err(), ChainError::DuplicateHotkey("alice".into()));
        c.deregister(a).unwrap();
        // The name is free again (and takes the recycled uid).
        assert_eq!(c.register("alice").unwrap(), a);
    }

    #[test]
    fn stake_index_tracks_add_set_and_deregister() {
        let mut c = Chain::new();
        let a = c.register("a").unwrap();
        let b = c.register("b").unwrap();
        c.add_stake(a, 10.0).unwrap();
        c.add_stake(b, 5.0).unwrap();
        c.add_stake(b, 10.0).unwrap(); // 15 total: b overtakes a
        assert_eq!(c.validators().collect::<Vec<_>>(), vec![b, a]);
        c.set_stake(b, 0.0).unwrap(); // demotion leaves the index
        assert_eq!(c.validators().collect::<Vec<_>>(), vec![a]);
        assert_eq!(c.lead_validator(), Some(a));
        c.deregister(a).unwrap();
        assert_eq!(c.validators().next(), None);
        assert_eq!(c.lead_validator(), None);
    }

    #[test]
    fn epoch_cost_tracks_active_set_not_table() {
        // 50k registered uids, 32 active: the epoch output and payouts are
        // exactly those of a 32-uid chain — the other 49,968 slots are
        // never part of the consensus. (The hotpath suite's
        // `chain_epoch_1m_sparse` pins the timing claim; this pins the
        // semantics at a size a unit test can afford.)
        let mut big = Chain::new();
        let mut small = Chain::new();
        let v_big = big.register("v").unwrap();
        let v_small = small.register("v").unwrap();
        big.add_stake(v_big, 100.0).unwrap();
        small.add_stake(v_small, 100.0).unwrap();
        for i in 0..50_000u32 {
            big.register(&format!("n{i}")).unwrap();
        }
        let mut w_big = Vec::new();
        let mut w_small = Vec::new();
        for i in 0..32u32 {
            // Spread the active uids across the big table.
            let uid_big = 1 + i * 1_500;
            let uid_small = small.register(&format!("n{i}")).unwrap();
            w_big.push((uid_big, (i + 1) as f64));
            w_small.push((uid_small, (i + 1) as f64));
        }
        big.set_weights(v_big, &w_big).unwrap();
        small.set_weights(v_small, &w_small).unwrap();
        let inc_big = big.run_epoch();
        let inc_small = small.run_epoch();
        assert_eq!(inc_big.len(), 32);
        let a: Vec<f64> = inc_big.iter().map(|(_, x)| *x).collect();
        let b: Vec<f64> = inc_small.iter().map(|(_, x)| *x).collect();
        assert_eq!(a, b, "table size must not leak into the consensus values");
    }

    #[test]
    fn read_key_registry() {
        let mut c = Chain::new();
        let p = c.register("p").unwrap();
        c.post_read_key(p, ReadKey("rk-x".into())).unwrap();
        assert_eq!(c.neuron(p).unwrap().bucket_read_key, Some(ReadKey("rk-x".into())));
        assert_eq!(
            c.post_read_key(99, ReadKey("rk".into())).unwrap_err(),
            ChainError::UnknownUid(99)
        );
    }
}
