//! Simulated Bittensor substrate: block clock, permissionless registration,
//! stake, weight commits, Yuma consensus, and token emission.
//!
//! Gauntlet's scores only become money once a validator posts them to the
//! chain and the chain combines (possibly several) validators' weight
//! vectors under the Yuma consensus protocol [18], weighting each validator
//! by its stake and clipping outliers to the stake-majority consensus.
//! This module provides exactly that substrate, plus the two pieces of
//! chain state the paper leans on elsewhere: a global block clock used to
//! timestamp put windows (§5) and the read-key registry for peers' buckets.

use std::collections::BTreeMap;

pub mod yuma;

pub use yuma::{yuma_consensus, YumaParams};

use crate::storage::ReadKey;

/// A network participant id (paper: "uid" on the subnet).
pub type Uid = u32;

/// Milliseconds per block (Bittensor mainnet: 12 s).
pub const BLOCK_MS: u64 = 12_000;

#[derive(Clone, Debug, PartialEq)]
pub struct Neuron {
    pub uid: Uid,
    pub hotkey: String,
    /// Stake in TAO; > 0 effectively makes the neuron a validator.
    pub stake: f64,
    /// Read credential for the neuron's bucket (posted at registration).
    pub bucket_read_key: Option<ReadKey>,
    pub registered_at_block: u64,
    /// Cumulative emission received.
    pub balance: f64,
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum ChainError {
    #[error("hotkey {0:?} already registered")]
    DuplicateHotkey(String),
    #[error("unknown uid {0}")]
    UnknownUid(Uid),
    #[error("weights must be finite and non-negative")]
    BadWeights,
    #[error("uid {0} has no stake; only validators may set weights")]
    NotValidator(Uid),
}

/// The simulated subnet.
pub struct Chain {
    pub block: u64,
    neurons: BTreeMap<Uid, Neuron>,
    next_uid: Uid,
    /// Latest committed weight vector per validator uid: target uid -> w.
    weights: BTreeMap<Uid, BTreeMap<Uid, f64>>,
    pub yuma: YumaParams,
    /// TAO emitted to contributors per epoch (paper: real-valued payouts).
    pub emission_per_epoch: f64,
}

impl Chain {
    pub fn new() -> Self {
        Chain {
            block: 0,
            neurons: BTreeMap::new(),
            next_uid: 0,
            weights: BTreeMap::new(),
            yuma: YumaParams::default(),
            emission_per_epoch: 1.0,
        }
    }

    /// Advance the global clock.
    pub fn advance_blocks(&mut self, n: u64) {
        self.block += n;
    }

    /// Current chain time in ms (the "consistent global clock" of §3.2).
    pub fn now_ms(&self) -> u64 {
        self.block * BLOCK_MS
    }

    /// Permissionless registration: anyone with a fresh hotkey gets a uid.
    /// (The live chain charges a registration fee / PoW; economically that
    /// is folded into the incentive analysis, not modelled here.)
    pub fn register(&mut self, hotkey: &str) -> Result<Uid, ChainError> {
        if self.neurons.values().any(|n| n.hotkey == hotkey) {
            return Err(ChainError::DuplicateHotkey(hotkey.to_string()));
        }
        let uid = self.next_uid;
        self.next_uid += 1;
        self.neurons.insert(
            uid,
            Neuron {
                uid,
                hotkey: hotkey.to_string(),
                stake: 0.0,
                bucket_read_key: None,
                registered_at_block: self.block,
                balance: 0.0,
            },
        );
        Ok(uid)
    }

    pub fn add_stake(&mut self, uid: Uid, amount: f64) -> Result<(), ChainError> {
        let n = self.neurons.get_mut(&uid).ok_or(ChainError::UnknownUid(uid))?;
        n.stake += amount;
        Ok(())
    }

    /// Publish the read key for the neuron's bucket (paper §5).
    pub fn post_read_key(&mut self, uid: Uid, key: ReadKey) -> Result<(), ChainError> {
        let n = self.neurons.get_mut(&uid).ok_or(ChainError::UnknownUid(uid))?;
        n.bucket_read_key = Some(key);
        Ok(())
    }

    pub fn neuron(&self, uid: Uid) -> Option<&Neuron> {
        self.neurons.get(&uid)
    }

    pub fn neurons(&self) -> impl Iterator<Item = &Neuron> {
        self.neurons.values()
    }

    pub fn uids(&self) -> Vec<Uid> {
        self.neurons.keys().copied().collect()
    }

    /// Validators = staked neurons, ordered by stake descending.
    pub fn validators(&self) -> Vec<Uid> {
        let mut v: Vec<&Neuron> = self.neurons.values().filter(|n| n.stake > 0.0).collect();
        v.sort_by(|a, b| b.stake.partial_cmp(&a.stake).unwrap());
        v.into_iter().map(|n| n.uid).collect()
    }

    /// The highest-staked validator provides checkpoint locations and the
    /// top-G peer list in the current protocol (paper §3.3).
    pub fn lead_validator(&self) -> Option<Uid> {
        self.validators().first().copied()
    }

    /// A validator commits its (pre-normalized, non-negative) weights.
    pub fn set_weights(&mut self, validator: Uid, w: &[(Uid, f64)]) -> Result<(), ChainError> {
        let v = self.neurons.get(&validator).ok_or(ChainError::UnknownUid(validator))?;
        if v.stake <= 0.0 {
            return Err(ChainError::NotValidator(validator));
        }
        if w.iter().any(|(_, x)| !x.is_finite() || *x < 0.0) {
            return Err(ChainError::BadWeights);
        }
        for (uid, _) in w {
            if !self.neurons.contains_key(uid) {
                return Err(ChainError::UnknownUid(*uid));
            }
        }
        self.weights.insert(validator, w.iter().copied().collect());
        Ok(())
    }

    pub fn committed_weights(&self, validator: Uid) -> Option<&BTreeMap<Uid, f64>> {
        self.weights.get(&validator)
    }

    /// Run one Yuma epoch: combine all committed validator weights into
    /// consensus incentives and pay emission. Returns (uid, incentive)
    /// with incentives summing to 1 over peers with any weight (or empty
    /// if no validator has committed anything).
    pub fn run_epoch(&mut self) -> Vec<(Uid, f64)> {
        let validators: Vec<Uid> =
            self.weights.keys().copied().filter(|v| self.neurons[v].stake > 0.0).collect();
        if validators.is_empty() {
            return vec![];
        }
        let stakes: Vec<f64> = validators.iter().map(|v| self.neurons[v].stake).collect();
        let all_uids = self.uids();
        let wmat: Vec<Vec<f64>> = validators
            .iter()
            .map(|v| {
                let row = &self.weights[v];
                all_uids.iter().map(|u| row.get(u).copied().unwrap_or(0.0)).collect()
            })
            .collect();
        let incentives = yuma_consensus(&wmat, &stakes, &self.yuma);
        let out: Vec<(Uid, f64)> = all_uids
            .iter()
            .copied()
            .zip(incentives.iter().copied())
            .filter(|(_, inc)| *inc > 0.0)
            .collect();
        for (uid, inc) in &out {
            self.neurons.get_mut(uid).unwrap().balance += inc * self.emission_per_epoch;
        }
        out
    }
}

impl Default for Chain {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain_with_validator() -> (Chain, Uid) {
        let mut c = Chain::new();
        let v = c.register("validator").unwrap();
        c.add_stake(v, 1000.0).unwrap();
        (c, v)
    }

    #[test]
    fn registration_is_permissionless_and_uids_increment() {
        let mut c = Chain::new();
        let a = c.register("alice").unwrap();
        let b = c.register("bob").unwrap();
        assert_eq!((a, b), (0, 1));
        assert_eq!(c.neuron(a).unwrap().hotkey, "alice");
    }

    #[test]
    fn duplicate_hotkey_rejected_but_sybils_allowed() {
        // The paper's "Duplicating Contributions" attack registers many
        // hotkeys; the chain allows that — Gauntlet's PoC catches it.
        let mut c = Chain::new();
        c.register("eve-1").unwrap();
        assert_eq!(c.register("eve-1").unwrap_err(), ChainError::DuplicateHotkey("eve-1".into()));
        c.register("eve-2").unwrap(); // sybil under a fresh hotkey: allowed
    }

    #[test]
    fn block_clock_advances() {
        let mut c = Chain::new();
        assert_eq!(c.now_ms(), 0);
        c.advance_blocks(5);
        assert_eq!(c.now_ms(), 5 * BLOCK_MS);
    }

    #[test]
    fn only_staked_neurons_set_weights() {
        let (mut c, v) = chain_with_validator();
        let p = c.register("peer").unwrap();
        assert_eq!(c.set_weights(p, &[(v, 1.0)]).unwrap_err(), ChainError::NotValidator(p));
        c.set_weights(v, &[(p, 1.0)]).unwrap();
        assert_eq!(c.committed_weights(v).unwrap()[&p], 1.0);
    }

    #[test]
    fn weights_validated() {
        let (mut c, v) = chain_with_validator();
        let p = c.register("peer").unwrap();
        assert_eq!(c.set_weights(v, &[(p, -0.5)]).unwrap_err(), ChainError::BadWeights);
        assert_eq!(c.set_weights(v, &[(p, f64::NAN)]).unwrap_err(), ChainError::BadWeights);
        assert_eq!(c.set_weights(v, &[(99, 0.5)]).unwrap_err(), ChainError::UnknownUid(99));
    }

    #[test]
    fn single_validator_epoch_normalizes_and_pays() {
        let (mut c, v) = chain_with_validator();
        let p0 = c.register("p0").unwrap();
        let p1 = c.register("p1").unwrap();
        c.set_weights(v, &[(p0, 3.0), (p1, 1.0)]).unwrap();
        c.emission_per_epoch = 10.0;
        let inc = c.run_epoch();
        let total: f64 = inc.iter().map(|(_, x)| x).sum();
        assert!((total - 1.0).abs() < 1e-9);
        let i0 = inc.iter().find(|(u, _)| *u == p0).unwrap().1;
        assert!((i0 - 0.75).abs() < 1e-9);
        assert!((c.neuron(p0).unwrap().balance - 7.5).abs() < 1e-9);
    }

    #[test]
    fn lead_validator_is_highest_staked() {
        let mut c = Chain::new();
        let a = c.register("a").unwrap();
        let b = c.register("b").unwrap();
        c.add_stake(a, 10.0).unwrap();
        c.add_stake(b, 50.0).unwrap();
        assert_eq!(c.lead_validator(), Some(b));
    }

    #[test]
    fn read_key_registry() {
        let mut c = Chain::new();
        let p = c.register("p").unwrap();
        c.post_read_key(p, ReadKey("rk-x".into())).unwrap();
        assert_eq!(c.neuron(p).unwrap().bucket_read_key, Some(ReadKey("rk-x".into())));
        assert_eq!(
            c.post_read_key(99, ReadKey("rk".into())).unwrap_err(),
            ChainError::UnknownUid(99)
        );
    }
}
