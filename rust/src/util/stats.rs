//! Streaming statistics used by the scoring, metrics, and bench code.

/// Deterministic scalar reduction: a strictly in-order left fold,
/// `((0 + x0) + x1) + ...`, so the association order is pinned by the
/// iterator's order rather than left to the `Sum` impl. This is the
/// blessed spelling for round-path float totals (detlint rule D003);
/// bulk hot-path reductions should use the fixed-lane `lane_reduce`
/// kernels instead.
pub fn det_sum<I: IntoIterator<Item = f64>>(xs: I) -> f64 {
    let mut acc = 0.0;
    for x in xs {
        acc += x;
    }
    acc
}

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    det_sum(xs.iter().copied()) / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator); 0.0 below two samples.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (det_sum(xs.iter().map(|x| (x - m) * (x - m))) / (xs.len() - 1) as f64).sqrt()
}

/// Linear-interpolated percentile, q in [0, 100]. Sorts a copy.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

/// Exponential moving average with the paper's `gamma` semantics:
/// `mu <- gamma * mu + (1 - gamma) * x` (eq. 3).
#[derive(Clone, Copy, Debug)]
pub struct Ema {
    pub gamma: f64,
    pub value: f64,
}

impl Ema {
    pub fn new(gamma: f64) -> Self {
        assert!((0.0..=1.0).contains(&gamma), "gamma out of [0,1]");
        Ema { gamma, value: 0.0 }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        self.value = self.gamma * self.value + (1.0 - self.gamma) * x;
        self.value
    }

    /// Multiplicative penalty (the fast-evaluation phi in §3.2).
    pub fn scale(&mut self, phi: f64) -> f64 {
        self.value *= phi;
        self.value
    }
}

/// Welford online mean/variance/min/max.
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn empty_slices_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn ema_matches_paper_recurrence() {
        let mut e = Ema::new(0.75);
        e.update(1.0); // 0.25
        e.update(1.0); // 0.4375
        assert!((e.value - 0.4375).abs() < 1e-12);
        e.scale(0.75);
        assert!((e.value - 0.328125).abs() < 1e-12);
    }

    #[test]
    fn ema_converges_to_constant_input() {
        let mut e = Ema::new(0.9);
        for _ in 0..500 {
            e.update(3.0);
        }
        assert!((e.value - 3.0).abs() < 1e-9);
    }

    #[test]
    fn online_stats_match_batch() {
        let xs = [1.5, -2.0, 0.25, 9.0, 3.5];
        let mut s = OnlineStats::new();
        for &x in &xs {
            s.push(x);
        }
        assert!((s.mean() - mean(&xs)).abs() < 1e-12);
        assert!((s.std() - std_dev(&xs)).abs() < 1e-12);
        assert_eq!(s.min(), -2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.count(), 5);
    }
}
