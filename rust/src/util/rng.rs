//! Deterministic SplitMix64 RNG with the distribution helpers the
//! simulation needs (uniform, normal, shuffling, subset sampling).
//!
//! Determinism matters twice here: (a) experiments are reproducible from a
//! single seed, and (b) the paper's `SelectData(seed, p, t)` contract
//! requires the validator and an honest peer to derive the *same* data
//! shard from public inputs — see [`Rng::from_parts`], which mixes the
//! parts through SHA-256 so shard seeds cannot collide by accident.

use sha2::{Digest, Sha256};

/// SplitMix64: tiny, fast, passes BigCrush for this mixing constant.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    /// Derive a generator from a structured seed, e.g.
    /// `Rng::from_parts(&["shard", "42", "peer=3", "round=17"])`.
    pub fn from_parts(parts: &[&str]) -> Self {
        let mut h = Sha256::new();
        for p in parts {
            h.update(p.as_bytes());
            h.update([0u8]); // unambiguous separator
        }
        let d = h.finalize();
        Rng::new(u64::from_le_bytes(d[..8].try_into().unwrap()))
    }

    /// The raw SplitMix64 state, for durable run snapshots: a generator
    /// restored with [`Rng::from_state`] continues the exact draw sequence.
    pub fn state(&self) -> u64 {
        self.state
    }

    /// Rebuild a generator mid-stream from a captured [`Rng::state`].
    pub fn from_state(state: u64) -> Self {
        Rng { state }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n). Uses rejection sampling to stay unbiased.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let zone = u64::MAX - u64::MAX % n;
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Bernoulli with probability p.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// k distinct elements sampled uniformly from `xs` (order random).
    pub fn choose_k<T: Clone>(&mut self, xs: &[T], k: usize) -> Vec<T> {
        let mut idx: Vec<usize> = (0..xs.len()).collect();
        self.shuffle(&mut idx);
        idx.truncate(k.min(xs.len()));
        idx.into_iter().map(|i| xs[i].clone()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_capture_resumes_the_exact_stream() {
        let mut a = Rng::new(99);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn from_parts_separator_is_unambiguous() {
        // ("ab", "c") must differ from ("a", "bc").
        let a = Rng::from_parts(&["ab", "c"]).state;
        let b = Rng::from_parts(&["a", "bc"]).state;
        assert_ne!(a, b);
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let s: f64 = (0..n).map(|_| r.next_f64()).sum();
        assert!((s / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(2);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // overwhelmingly likely
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::new(5);
        let xs: Vec<u32> = (0..20).collect();
        let picked = r.choose_k(&xs, 8);
        assert_eq!(picked.len(), 8);
        let mut s = picked.clone();
        s.sort();
        s.dedup();
        assert_eq!(s.len(), 8);
    }

    #[test]
    fn choose_k_larger_than_len_returns_all() {
        let mut r = Rng::new(6);
        let xs = vec![1, 2, 3];
        let mut picked = r.choose_k(&xs, 10);
        picked.sort();
        assert_eq!(picked, xs);
    }
}
