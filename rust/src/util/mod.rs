//! Small shared utilities: deterministic RNG, statistics, timing tables.
//!
//! The environment has no `rand` crate, so [`Rng`] is a hand-rolled
//! SplitMix64 (Steele et al., "Fast splittable pseudorandom number
//! generators") — more than adequate for simulation workloads and fully
//! deterministic across platforms, which the seeded data-assignment scheme
//! (`SelectData(seed, p, t)` in the paper's Algorithm 1) relies on.

pub mod rng;
pub mod stats;

pub use rng::Rng;
pub use stats::{mean, percentile, std_dev, Ema, OnlineStats};

/// Mathematical sign with sign(0) = 0 (Rust's `f64::signum` maps +0.0 to
/// +1.0, which would bias the paper's eq. 3 EMA on exact ties).
pub fn sign(x: f64) -> f64 {
    if x > 0.0 {
        1.0
    } else if x < 0.0 {
        -1.0
    } else {
        0.0
    }
}

/// Convert a byte slice (little-endian f32) into a vector of f32.
pub fn f32_from_le_bytes(bytes: &[u8]) -> Vec<f32> {
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// Serialize a f32 slice as little-endian bytes.
pub fn f32_to_le_bytes(vals: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(vals.len() * 4);
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let xs = vec![0.0f32, -1.5, 3.25e7, f32::MIN_POSITIVE];
        assert_eq!(f32_from_le_bytes(&f32_to_le_bytes(&xs)), xs);
    }

    #[test]
    fn f32_from_le_ignores_trailing_partial() {
        let mut b = f32_to_le_bytes(&[1.0, 2.0]);
        b.push(0xff);
        assert_eq!(f32_from_le_bytes(&b), vec![1.0, 2.0]);
    }
}
