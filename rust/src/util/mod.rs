//! Small shared utilities: deterministic RNG, statistics, timing tables.
//!
//! The environment has no `rand` crate, so [`Rng`] is a hand-rolled
//! SplitMix64 (Steele et al., "Fast splittable pseudorandom number
//! generators") — more than adequate for simulation workloads and fully
//! deterministic across platforms, which the seeded data-assignment scheme
//! (`SelectData(seed, p, t)` in the paper's Algorithm 1) relies on.

pub mod rng;
pub mod stats;

pub use rng::Rng;
pub use stats::{det_sum, mean, percentile, std_dev, Ema, OnlineStats};

/// Mathematical sign with sign(0) = 0 (Rust's `f64::signum` maps +0.0 to
/// +1.0, which would bias the paper's eq. 3 EMA on exact ties).
pub fn sign(x: f64) -> f64 {
    if x > 0.0 {
        1.0
    } else if x < 0.0 {
        -1.0
    } else {
        0.0
    }
}

// ---------------------------------------------------------------------
// Bulk little-endian numeric codecs.
//
// The wire path (pseudo-gradient submissions, `demo::wire`) and artifact
// loading move tens of thousands of f32/i32 values per object. On
// little-endian targets — every platform this runs on in practice — the
// in-memory representation of `[f32]`/`[i32]` *is* the wire
// representation, so the hot path is a single `memcpy` instead of a
// per-element `to_le_bytes`/`from_le_bytes` loop with its bounds checks.
// Big-endian targets keep the byte-wise loop; `bulk_le_matches_bytewise`
// below pins the two paths to identical bytes, so the fast path can
// never silently fork the format.
// ---------------------------------------------------------------------

macro_rules! le_codec {
    ($extend:ident, $from:ident, $ty:ty, $doc_ty:literal) => {
        #[doc = concat!("Append a `", $doc_ty, "` slice to `out` as little-endian bytes ")]
        /// (bulk memcpy on little-endian targets, byte-wise elsewhere).
        pub fn $extend(out: &mut Vec<u8>, vals: &[$ty]) {
            #[cfg(target_endian = "little")]
            {
                // SAFETY: the element type has size 4, no padding, and no
                // invalid byte patterns; on a little-endian target its
                // in-memory bytes are exactly its little-endian encoding.
                // The slice covers `vals.len() * 4` initialized bytes.
                let bytes = unsafe {
                    std::slice::from_raw_parts(vals.as_ptr().cast::<u8>(), vals.len() * 4)
                };
                out.extend_from_slice(bytes);
            }
            #[cfg(not(target_endian = "little"))]
            {
                for v in vals {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
        }

        #[doc = concat!("Decode little-endian bytes into a `", $doc_ty, "` vector ")]
        /// (inverse of the extend form; a trailing partial element is
        /// ignored, matching `chunks_exact`).
        pub fn $from(bytes: &[u8]) -> Vec<$ty> {
            let n = bytes.len() / 4;
            #[cfg(target_endian = "little")]
            {
                let mut out = vec![<$ty>::default(); n];
                // SAFETY: `out` owns `n * 4` writable bytes; any byte
                // pattern is a valid value of the element type; the copy
                // stays within both buffers.
                unsafe {
                    std::ptr::copy_nonoverlapping(
                        bytes.as_ptr(),
                        out.as_mut_ptr().cast::<u8>(),
                        n * 4,
                    );
                }
                out
            }
            #[cfg(not(target_endian = "little"))]
            {
                bytes[..n * 4]
                    .chunks_exact(4)
                    .map(|c| <$ty>::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect()
            }
        }
    };
}

le_codec!(extend_f32_le, f32_from_le_bytes, f32, "f32");
le_codec!(extend_i32_le, i32_from_le_bytes, i32, "i32");

/// Serialize a f32 slice as little-endian bytes.
pub fn f32_to_le_bytes(vals: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(vals.len() * 4);
    extend_f32_le(&mut out, vals);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let xs = vec![0.0f32, -1.5, 3.25e7, f32::MIN_POSITIVE];
        assert_eq!(f32_from_le_bytes(&f32_to_le_bytes(&xs)), xs);
    }

    #[test]
    fn f32_from_le_ignores_trailing_partial() {
        let mut b = f32_to_le_bytes(&[1.0, 2.0]);
        b.push(0xff);
        assert_eq!(f32_from_le_bytes(&b), vec![1.0, 2.0]);
    }

    #[test]
    fn bulk_le_matches_bytewise_reference() {
        // The endianness contract: whatever path the target compiles
        // (memcpy or byte-wise), the emitted bytes must equal the
        // canonical per-element `to_le_bytes` encoding — including for
        // NaN, infinities, and -0.0, whose bit patterns must survive.
        let f = [
            0.0f32,
            -0.0,
            1.5,
            -2.25e-7,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::NAN,
            f32::MIN_POSITIVE,
        ];
        let mut bulk = Vec::new();
        extend_f32_le(&mut bulk, &f);
        let mut reference = Vec::new();
        for v in &f {
            reference.extend_from_slice(&v.to_le_bytes());
        }
        assert_eq!(bulk, reference);
        let back = f32_from_le_bytes(&bulk);
        assert_eq!(back.len(), f.len());
        for (a, b) in back.iter().zip(&f) {
            assert_eq!(a.to_bits(), b.to_bits(), "f32 bits must survive the round trip");
        }

        let i = [0i32, 1, -1, i32::MAX, i32::MIN, 0x0102_0304];
        let mut bulk = Vec::new();
        extend_i32_le(&mut bulk, &i);
        let mut reference = Vec::new();
        for v in &i {
            reference.extend_from_slice(&v.to_le_bytes());
        }
        assert_eq!(bulk, reference);
        assert_eq!(i32_from_le_bytes(&bulk), i);
    }

    #[test]
    fn bulk_le_empty_and_partial_inputs() {
        let mut out = Vec::new();
        extend_f32_le(&mut out, &[]);
        extend_i32_le(&mut out, &[]);
        assert!(out.is_empty());
        assert!(f32_from_le_bytes(&[]).is_empty());
        assert_eq!(i32_from_le_bytes(&[1, 0, 0, 0, 9]), vec![1]);
    }
}
