//! Peer score bookkeeping: PEERSCORE (eq. 4), the power normalization
//! (eq. 5) and top-G aggregation weights (eq. 6).

use std::collections::BTreeMap;

use crate::chain::Uid;
use crate::openskill::{PlackettLuce, Rating};
use crate::util::Ema;

/// Validator-local state for one peer.
#[derive(Clone, Debug)]
pub struct PeerState {
    /// OpenSkill LossRating (updated by ranked primary evaluations).
    pub rating: Rating,
    /// Proof-of-computation EMA mu_p (eq. 3), also the phi penalty target.
    pub mu: Ema,
    /// Diagnostics: last primary-eval loss scores.
    pub last_loss_score_rand: f64,
    pub last_loss_score_assigned: f64,
    pub evals: u64,
    pub fast_fails: u64,
}

/// The validator's score table.
#[derive(Clone, Debug)]
pub struct ScoreBook {
    pub model: PlackettLuce,
    pub gamma: f64,
    states: BTreeMap<Uid, PeerState>,
}

impl ScoreBook {
    pub fn new(gamma: f64) -> Self {
        ScoreBook { model: PlackettLuce::default(), gamma, states: BTreeMap::new() }
    }

    pub fn ensure(&mut self, uid: Uid) -> &mut PeerState {
        let model = self.model;
        let gamma = self.gamma;
        self.states.entry(uid).or_insert_with(|| PeerState {
            rating: model.initial(),
            mu: Ema::new(gamma),
            last_loss_score_rand: 0.0,
            last_loss_score_assigned: 0.0,
            evals: 0,
            fast_fails: 0,
        })
    }

    pub fn get(&self, uid: Uid) -> Option<&PeerState> {
        self.states.get(&uid)
    }

    /// Drop all state for `uid`. Called when a chain uid is recycled to a
    /// new occupant: the next [`ScoreBook::ensure`] starts from the fresh
    /// OpenSkill prior with cleared PoC EMA and phi/fast-fail history —
    /// the newcomer inherits nothing from the evicted identity.
    pub fn remove(&mut self, uid: Uid) -> Option<PeerState> {
        self.states.remove(&uid)
    }

    /// Known peer uids in ascending order, borrowed. The book only ever
    /// holds active peers (states are created by `ensure` and removed on
    /// uid recycling), so iteration here is O(active) by construction.
    pub fn uids(&self) -> impl Iterator<Item = Uid> + '_ {
        self.states.keys().copied()
    }

    /// Iterate every `(uid, state)` pair in uid order (snapshot export).
    pub fn iter(&self) -> impl Iterator<Item = (&Uid, &PeerState)> {
        self.states.iter()
    }

    /// Install a peer's state wholesale (snapshot restore — bypasses the
    /// fresh-prior path of [`ScoreBook::ensure`]).
    pub fn insert_state(&mut self, uid: Uid, state: PeerState) {
        self.states.insert(uid, state);
    }

    /// Apply the fast-evaluation outcome: phi < 1 on failure (§3.2).
    pub fn apply_fast_penalty(&mut self, uid: Uid, phi: f64) {
        let s = self.ensure(uid);
        if phi < 1.0 {
            s.fast_fails += 1;
        }
        s.mu.scale(phi);
    }

    /// Record one primary evaluation for `uid` (eq. 3 EMA update).
    pub fn record_primary(&mut self, uid: Uid, score_assigned: f64, score_rand: f64) {
        let s = self.ensure(uid);
        s.last_loss_score_assigned = score_assigned;
        s.last_loss_score_rand = score_rand;
        s.evals += 1;
        s.mu.update(crate::util::sign(score_assigned - score_rand));
    }

    /// Rank an evaluated subset by their random-data LossScores and update
    /// OpenSkill ratings (the `OpenSkillMatch` step of Algorithm 1).
    pub fn rate_match(&mut self, uids: &[Uid], loss_scores_rand: &[f64]) {
        assert_eq!(uids.len(), loss_scores_rand.len());
        if uids.len() < 2 {
            return;
        }
        let ratings: Vec<Rating> = uids.iter().map(|u| self.ensure(*u).rating).collect();
        let updated = self.model.rate_by_scores(&ratings, loss_scores_rand);
        for (u, r) in uids.iter().zip(updated) {
            self.ensure(*u).rating = r;
        }
    }

    /// PEERSCORE_p = mu_p * LossRating_p (eq. 4). We use the OpenSkill mu
    /// as the rating magnitude (clamped at zero): early in a run the
    /// conservative ordinal (mu - 3 sigma) is ~0 for everyone, which would
    /// leave the incentive signal flat for many rounds; mu separates peers
    /// as soon as the first matches are played, while the mu_p factor
    /// already gates unevaluated peers at zero.
    pub fn peer_score(&self, uid: Uid) -> f64 {
        match self.states.get(&uid) {
            Some(s) => s.mu.value * s.rating.mu.max(0.0),
            None => 0.0,
        }
    }

    pub fn all_peer_scores(&self) -> Vec<(Uid, f64)> {
        self.states.keys().map(|&u| (u, self.peer_score(u))).collect()
    }
}

/// Incentive normalization (eq. 5):
/// `x_p = (s_p - min s)^c / sum_k (s_k - min s)^c`.
/// Returns zeros when all scores are equal (no signal yet).
///
/// Degenerate inputs are handled deterministically rather than propagated:
/// a non-finite score (NaN, ±inf — e.g. a poisoned rating that slipped
/// through) contributes zero incentive and is excluded from the min-shift,
/// so one corrupt entry cannot NaN-poison every peer's weight.
pub fn normalize_scores(scores: &[f64], power: f64) -> Vec<f64> {
    if scores.is_empty() {
        return vec![];
    }
    let min = scores
        .iter()
        .copied()
        .filter(|s| s.is_finite())
        .fold(f64::INFINITY, f64::min);
    if !min.is_finite() {
        // No finite score at all: no signal.
        return vec![0.0; scores.len()];
    }
    let shifted: Vec<f64> = scores
        .iter()
        .map(|s| if s.is_finite() { (s - min).max(0.0).powf(power) } else { 0.0 })
        .collect();
    let total = crate::util::det_sum(shifted.iter().copied());
    if total <= 0.0 || !total.is_finite() {
        return vec![0.0; scores.len()];
    }
    shifted.into_iter().map(|x| x / total).collect()
}

/// Top-G selection + aggregation weights (eq. 6): 1/G for the top G peers
/// by normalized incentive, 0 otherwise. Ties are broken by ascending uid
/// for determinism (`total_cmp` keeps the sort total even if a non-finite
/// incentive slips in). Peers with zero, negative, or non-finite incentive
/// are never selected; `g = 0` selects nobody.
pub fn top_g_weights(incentives: &[(Uid, f64)], g: usize) -> Vec<(Uid, f64)> {
    let mut ranked: Vec<(Uid, f64)> = incentives
        .iter()
        .copied()
        .filter(|(_, x)| x.is_finite() && *x > 0.0)
        .collect();
    ranked.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
    ranked.truncate(g);
    if ranked.is_empty() {
        return vec![];
    }
    let w = 1.0 / ranked.len() as f64;
    ranked.into_iter().map(|(u, _)| (u, w)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use crate::prop_assert;

    #[test]
    fn peer_score_combines_mu_and_rating() {
        let mut b = ScoreBook::new(0.0); // gamma 0: mu = latest sign
        assert_eq!(b.peer_score(1), 0.0, "unknown peer scores 0");
        b.record_primary(1, 0.5, 0.3); // assigned > rand -> mu = +1
        let s = b.peer_score(1);
        assert!(s > 0.0, "compliant evaluated peer scores positive: {s}");
        b.record_primary(2, 0.1, 0.3); // assigned < rand -> mu = -1
        assert!(b.peer_score(2) < 0.0);
    }

    #[test]
    fn fast_penalty_decays_mu_geometrically() {
        let mut b = ScoreBook::new(0.0);
        b.record_primary(1, 1.0, 0.5);
        let before = b.get(1).unwrap().mu.value;
        b.apply_fast_penalty(1, 0.75);
        b.apply_fast_penalty(1, 0.75);
        let after = b.get(1).unwrap().mu.value;
        assert!((after - before * 0.5625).abs() < 1e-12);
        assert_eq!(b.get(1).unwrap().fast_fails, 2);
    }

    #[test]
    fn passing_fast_eval_is_noop() {
        let mut b = ScoreBook::new(0.0);
        b.record_primary(1, 1.0, 0.5);
        let before = b.get(1).unwrap().mu.value;
        b.apply_fast_penalty(1, 1.0);
        assert_eq!(b.get(1).unwrap().mu.value, before);
        assert_eq!(b.get(1).unwrap().fast_fails, 0);
    }

    #[test]
    fn rate_match_orders_ratings_by_score() {
        let mut b = ScoreBook::new(0.9);
        for _ in 0..20 {
            b.rate_match(&[1, 2, 3], &[0.9, 0.5, 0.1]);
        }
        let r1 = b.get(1).unwrap().rating.ordinal();
        let r2 = b.get(2).unwrap().rating.ordinal();
        let r3 = b.get(3).unwrap().rating.ordinal();
        assert!(r1 > r2 && r2 > r3, "{r1} {r2} {r3}");
    }

    #[test]
    fn normalize_matches_paper_example() {
        // two peers, c=2: scores (3, 1) -> shifted (2, 0) -> (1, 0)
        let x = normalize_scores(&[3.0, 1.0], 2.0);
        assert_eq!(x, vec![1.0, 0.0]);
        // c=2 concentrates: (2,1,0) -> (4,1,0)/5
        let x = normalize_scores(&[2.0, 1.0, 0.0], 2.0);
        assert!((x[0] - 0.8).abs() < 1e-12 && (x[1] - 0.2).abs() < 1e-12);
    }

    #[test]
    fn normalize_degenerate_cases() {
        assert_eq!(normalize_scores(&[], 2.0), Vec::<f64>::new());
        assert_eq!(normalize_scores(&[5.0, 5.0], 2.0), vec![0.0, 0.0]);
        assert_eq!(normalize_scores(&[1.0], 2.0), vec![0.0]);
        // All-zero scores: no signal, all-zero incentives.
        assert_eq!(normalize_scores(&[0.0, 0.0, 0.0], 2.0), vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn normalize_non_finite_inputs_are_quarantined() {
        // A NaN score earns nothing and cannot poison the others.
        let x = normalize_scores(&[3.0, f64::NAN, 1.0], 2.0);
        assert!(x.iter().all(|v| v.is_finite()), "{x:?}");
        assert_eq!(x[1], 0.0, "NaN peer gets zero incentive");
        assert_eq!(x, normalize_scores(&[3.0, f64::NEG_INFINITY, 1.0], 2.0));
        // ±inf likewise: +inf must not absorb the whole distribution via
        // inf/inf = NaN.
        let y = normalize_scores(&[f64::INFINITY, 2.0, 1.0], 2.0);
        assert!(y.iter().all(|v| v.is_finite()), "{y:?}");
        assert_eq!(y[0], 0.0);
        assert!((y[1] - 1.0).abs() < 1e-12, "finite winner takes all: {y:?}");
        // Nothing finite at all: zeros, not NaNs.
        assert_eq!(
            normalize_scores(&[f64::NAN, f64::INFINITY], 2.0),
            vec![0.0, 0.0]
        );
        // The min-shift ignores -inf, so finite scores keep their relative
        // shares.
        let clean = normalize_scores(&[2.0, 1.0, 0.0], 2.0);
        let with_nan = normalize_scores(&[2.0, 1.0, 0.0, f64::NAN], 2.0);
        for (a, b) in clean.iter().zip(&with_nan) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn higher_power_concentrates_incentive() {
        // The design rationale in §3.3: one strong peer should out-earn
        // many weak peers more at c=2 than c=1.
        let scores = [10.0, 6.0, 5.0, 4.0, 0.0];
        let c1 = normalize_scores(&scores, 1.0);
        let c2 = normalize_scores(&scores, 2.0);
        assert!(c2[0] > c1[0], "top share should grow with c: {} vs {}", c2[0], c1[0]);
    }

    #[test]
    fn top_g_weights_are_uniform_and_exclude_zero() {
        let inc = vec![(0, 0.5), (1, 0.3), (2, 0.2), (3, 0.0)];
        let w = top_g_weights(&inc, 2);
        assert_eq!(w, vec![(0, 0.5), (1, 0.5)]);
        let w = top_g_weights(&inc, 10);
        assert_eq!(w.len(), 3, "zero-incentive peer excluded");
        assert!((w[0].1 - 1.0 / 3.0).abs() < 1e-12);
        assert!(top_g_weights(&[(0, 0.0)], 3).is_empty());
    }

    #[test]
    fn top_g_ties_break_by_uid() {
        let inc = vec![(5, 0.4), (2, 0.4), (9, 0.2)];
        let w = top_g_weights(&inc, 2);
        assert_eq!(w.iter().map(|(u, _)| *u).collect::<Vec<_>>(), vec![2, 5]);
        // Fully tied field, g smaller than the tie: selection is the g
        // lowest uids, pinned (input order must not matter).
        let tied = vec![(7, 0.25), (1, 0.25), (4, 0.25), (3, 0.25)];
        let w = top_g_weights(&tied, 2);
        assert_eq!(w, vec![(1, 0.5), (3, 0.5)]);
        let mut reversed = tied.clone();
        reversed.reverse();
        assert_eq!(top_g_weights(&reversed, 2), w, "order-independent tie-break");
    }

    #[test]
    fn top_g_degenerate_sizes_and_non_finite_incentives() {
        let inc = vec![(0, 0.5), (1, 0.3), (2, 0.2)];
        // g larger than the candidate set: everyone in, uniform weights.
        let w = top_g_weights(&inc, 100);
        assert_eq!(w.len(), 3);
        assert!(w.iter().all(|(_, x)| (*x - 1.0 / 3.0).abs() < 1e-12));
        // g = 0 selects nobody (and must not divide by zero).
        assert_eq!(top_g_weights(&inc, 0), vec![]);
        assert_eq!(top_g_weights(&[], 4), vec![]);
        // NaN / inf incentives are never selected and never panic the sort.
        let dirty = vec![(0, f64::NAN), (1, 0.4), (2, f64::INFINITY), (3, 0.1)];
        let w = top_g_weights(&dirty, 4);
        assert_eq!(w.iter().map(|(u, _)| *u).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(top_g_weights(&[(0, f64::NAN)], 2), vec![]);
    }

    #[test]
    fn prop_normalized_scores_sum_to_one_and_are_monotone() {
        prop::check("normalize-eq5", 50, |rng, size| {
            let n = 2 + size % 10;
            let scores: Vec<f64> = (0..n).map(|_| rng.range_f64(-5.0, 5.0)).collect();
            let x = normalize_scores(&scores, 2.0);
            let total: f64 = x.iter().sum();
            prop_assert!(
                total.abs() < 1e-12 || (total - 1.0).abs() < 1e-9,
                "sum {total}"
            );
            // monotone: higher raw score never yields lower incentive
            for i in 0..n {
                for j in 0..n {
                    if scores[i] > scores[j] {
                        prop_assert!(
                            x[i] >= x[j] - 1e-12,
                            "monotonicity broken at {i},{j}"
                        );
                    }
                }
            }
            // shift invariance: adding a constant changes nothing
            let shifted: Vec<f64> = scores.iter().map(|s| s + 3.7).collect();
            let y = normalize_scores(&shifted, 2.0);
            for (a, b) in x.iter().zip(&y) {
                prop_assert!((a - b).abs() < 1e-9, "shift invariance broken");
            }
            Ok(())
        });
    }
}
