//! Centralized AdamW DDP baseline (Fig. 1 / Table 1 comparison).
//!
//! The paper compares the permissionless run against "a controlled AdamW
//! baseline with the same number of peers and the default per worker batch
//! size" — i.e. classic synchronous data-parallel training, which is *not*
//! deployable over the internet (full-gradient all-reduce) but anchors the
//! convergence comparison.
//!
//! Two modes:
//!  - [`AdamWTrainer`]: gradient averaging over `n_workers` simulated
//!    workers' shards per step (DDP semantics), AdamW moments kept in Rust.
//!  - the fused single-batch `adamw_step` artifact (used by the hot-path
//!    bench) — same math, one XLA call, for B = one microbatch.

use anyhow::Result;

use crate::data::Corpus;
use crate::runtime::ExecBackend;

/// AdamW hyperparameters (defaults mirror meta.json / DeMo's paper).
#[derive(Clone, Copy, Debug)]
pub struct AdamWParams {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Default for AdamWParams {
    fn default() -> Self {
        AdamWParams { lr: 3e-4, beta1: 0.9, beta2: 0.95, eps: 1e-8, weight_decay: 0.1 }
    }
}

/// DDP-style trainer: per step, average gradients over `n_workers` disjoint
/// shards, then take one AdamW step (moments live host-side).
pub struct AdamWTrainer {
    pub p: AdamWParams,
    pub n_workers: usize,
    pub theta: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    step: u64,
}

impl AdamWTrainer {
    pub fn new(theta: Vec<f32>, p: AdamWParams, n_workers: usize) -> Self {
        let n = theta.len();
        AdamWTrainer { p, n_workers, theta, m: vec![0.0; n], v: vec![0.0; n], step: 0 }
    }

    /// One synchronous DDP step at `round`; returns the mean worker loss.
    pub fn step<E: ExecBackend>(&mut self, exec: &E, corpus: &Corpus, round: u64) -> Result<f64> {
        let meta = exec.meta();
        let (b, s1) = (meta.batch, meta.seq + 1);
        let mut acc = vec![0.0f32; meta.param_count];
        let mut loss_sum = 0.0f64;
        for w in 0..self.n_workers {
            // Same shard namespace the Gauntlet peers use, different stream
            // per worker — equal tokens per step at equal worker counts.
            let toks = corpus.assigned_shard(w as u32, round, 0, b, s1);
            let (loss, g) = exec.grad(&self.theta, &toks)?;
            loss_sum += loss as f64;
            for (a, gi) in acc.iter_mut().zip(&g) {
                *a += gi / self.n_workers as f32;
            }
        }
        self.apply(&acc);
        Ok(loss_sum / self.n_workers as f64)
    }

    /// The AdamW update on an externally computed (averaged) gradient.
    pub fn apply(&mut self, grad: &[f32]) {
        assert_eq!(grad.len(), self.theta.len());
        self.step += 1;
        let t = self.step as f32;
        let (b1, b2) = (self.p.beta1, self.p.beta2);
        let bc1 = 1.0 - b1.powf(t);
        let bc2 = 1.0 - b2.powf(t);
        for i in 0..grad.len() {
            let g = grad[i];
            self.m[i] = b1 * self.m[i] + (1.0 - b1) * g;
            self.v[i] = b2 * self.v[i] + (1.0 - b2) * g * g;
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            self.theta[i] -=
                self.p.lr * (mhat / (vhat.sqrt() + self.p.eps) + self.p.weight_decay * self.theta[i]);
        }
    }

    pub fn steps_taken(&self) -> u64 {
        self.step
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Quadratic sanity check: AdamW on f(x) = 0.5 * x^2 (grad = x)
    /// converges toward 0 from any start.
    #[test]
    fn adamw_minimizes_quadratic() {
        let p = AdamWParams { lr: 0.05, weight_decay: 0.0, ..Default::default() };
        let mut t = AdamWTrainer::new(vec![3.0, -2.0, 0.5], p, 1);
        for _ in 0..500 {
            let g = t.theta.clone();
            t.apply(&g);
        }
        for x in &t.theta {
            assert!(x.abs() < 0.05, "did not converge: {:?}", t.theta);
        }
        assert_eq!(t.steps_taken(), 500);
    }

    #[test]
    fn bias_correction_makes_first_step_lr_sized() {
        // With m=v=0, the first AdamW step is ~lr * sign(g) regardless of
        // gradient magnitude (the classic bias-correction property).
        let p = AdamWParams { lr: 0.01, weight_decay: 0.0, ..Default::default() };
        for g0 in [1e-3f32, 1.0, 1e3] {
            let mut t = AdamWTrainer::new(vec![0.0], p, 1);
            t.apply(&[g0]);
            assert!(
                (t.theta[0] + 0.01).abs() < 1e-3,
                "g0={g0}: step was {}",
                t.theta[0]
            );
        }
    }

    #[test]
    fn weight_decay_is_decoupled() {
        // With zero gradient, parameters decay multiplicatively.
        let p = AdamWParams { lr: 0.1, weight_decay: 0.5, ..Default::default() };
        let mut t = AdamWTrainer::new(vec![1.0], p, 1);
        t.apply(&[0.0]);
        assert!((t.theta[0] - 0.95).abs() < 1e-6, "{}", t.theta[0]);
    }
}
