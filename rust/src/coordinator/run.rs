//! The end-to-end Templar system: chain + cloud storage + peers +
//! validator(s) + DeMo aggregation, driven round by round (§2, §3.3, §6).
//!
//! This is what `rust/examples/templar_run.rs` and the Fig. 1 / Fig. 2
//! benches execute, normally assembled through the
//! [`GauntletBuilder`](super::engine::GauntletBuilder) front door. One
//! [`TemplarRun`] owns every substrate; `run_round()` performs a staged
//! pipeline, publishing every decision to the typed round-event stream
//! (`coordinator::events`) — metrics are assembled by the built-in
//! [`MetricsObserver`], never inline — and the whole run can be paused
//! and resumed bit-identically via [`RunSnapshot`]:
//!
//!   0. the population resolves: scripted [`Scenario`] churn events fire
//!      (joins, leaves, stake moves, provider outages) and the peer set is
//!      re-read from the chain registry — `RunConfig::peers` only seeds
//!      round 0; after that the chain's bounded slot table (eviction,
//!      immunity, uid recycling — see the `chain` module docs) is the
//!      source of truth, and recycled uids have their ratings, phi/sync
//!      history, and bucket reset,
//!   1. peers take their turns — first pass (independent behaviours)
//!      produced **concurrently** across a worker pool, with storage PUTs
//!      applied in peer order; second pass (copiers/duplicators, who need
//!      a victim's public object) afterwards,
//!   2. every validator fast-evaluates all peers (each validator's checks
//!      fanned out over workers), primary-evaluates a random subset, and
//!      updates its scores — **validators run concurrently**, then commit
//!      weights to the chain in validator order,
//!   3. the chain runs a Yuma epoch, combining validators into incentives
//!      and paying emission,
//!   4. the lead validator's top-G weights drive the DeMo aggregation
//!      (encoded-domain normalization + weighted sparse sum -> IDCT ->
//!      sign -> `theta -= lr * sign`), with checkpoint bookkeeping,
//!   5. peers synchronize to the new model (or diverge, per behaviour).
//!
//! # Parallelism and determinism
//!
//! The worker count comes from [`RunConfig::threads`] (0 = auto: the
//! `GAUNTLET_THREADS` environment variable, else the machine's available
//! parallelism; 1 = fully sequential), resolved **once** at construction
//! into a persistent [`WorkerPool`](crate::runtime::WorkerPool) — every
//! parallel stage of every round dispatches onto the same long-lived
//! workers instead of spawning scoped threads. Model execution is generic over
//! [`ExecBackend`]. `Sync` backends (the pure-Rust `SimExec`) advertise
//! themselves via `ExecBackend::as_shared` and are called by every worker
//! directly; the PJRT [`Executor`] is not `Send`, so its workers instead
//! hold [`ExecClient`](crate::runtime::ExecClient) handles and the
//! coordinator thread serves their requests ([`exec_service`]) — every
//! XLA call still runs on the owning thread (the constraint documented in
//! `runtime`). All order-sensitive state — storage PUT latency draws, phi
//! penalties, rating matches, sampling RNGs, chain commits — is applied in
//! deterministic peer/validator order on stable threads, so a run's
//! PEERSCOREs, weights, and parameters are bit-identical at any thread
//! count (pinned by `tests/parallel_determinism.rs`).

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use super::checkpoint::CheckpointStore;
use super::events::{MetricsObserver, Observer, RoundEvent};
use super::round::RoundClock;
use super::snapshot::RunSnapshot;
use super::validator::{chain_read_keys, RoundOutcome, Validator};
use super::GauntletParams;
use crate::chain::{Chain, Uid, BLOCK_MS};
use crate::data::Corpus;
use crate::demo::aggregate::{aggregate_into, AggregateOpts};
use crate::demo::wire::Submission;
use crate::minjson::{self, fnum, read_f64, Value};
use crate::peers::{Behavior, PeerCtx, PeerOutput, PeerRunner};
use crate::runtime::pool::Job;
use crate::runtime::{
    artifact_dir, exec_service, ExecBackend, Executor, SimExec, ThetaShared, WorkerPool,
};
use crate::scenario::{Event, Scenario};
use crate::storage::{ObjectStore, ProviderModel};

/// Configuration for a full run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Artifact config name (nano / tiny / small / base).
    pub model: String,
    pub rounds: u64,
    /// Behaviours of the peers registered at round 0 (uids assigned in
    /// order). The population is *not* frozen to this: a [`Scenario`] (or
    /// direct [`TemplarRunWith::register_peer`] /
    /// [`TemplarRunWith::deregister_peer`] calls) churns it mid-run, and
    /// the round pipeline re-resolves the peer set from the chain registry
    /// at the top of every round.
    pub peers: Vec<Behavior>,
    /// Scripted churn: joins, leaves, stake moves, provider outages, fired
    /// at the top of their round (`gauntlet run --scenario ...`).
    pub scenario: Scenario,
    /// Chain neuron-slot capacity, *including* validators (0 = unbounded).
    /// When the table is full a new registration evicts the
    /// lowest-incentive non-immune peer. Must admit the initial
    /// population (`n_validators + peers.len()`).
    pub max_uids: usize,
    /// Rounds of post-registration immunity from slot eviction.
    pub immunity_rounds: u64,
    pub params: GauntletParams,
    pub clock: RoundClock,
    pub provider: ProviderModel,
    pub seed: u64,
    /// Evaluate held-out loss every this many rounds (0 = never).
    pub eval_every: u64,
    /// Number of staked validators (>=1; all run the same protocol and
    /// are combined by Yuma consensus).
    pub n_validators: usize,
    /// Aggregation options (normalization on/off for the §4 ablation).
    pub agg: AggregateOpts,
    /// Worker threads for the round pipeline: 0 = auto (`GAUNTLET_THREADS`
    /// env var, else available parallelism), 1 = sequential.
    pub threads: usize,
}

impl Default for RunConfig {
    /// The baseline configuration every entry point starts from: `nano`
    /// model, 20 rounds, no peers, one validator, auto threads.
    fn default() -> Self {
        RunConfig {
            model: "nano".to_string(),
            rounds: 20,
            peers: Vec::new(),
            scenario: Scenario::default(),
            max_uids: 0,
            immunity_rounds: 2,
            // lr = 0 means "resolve from the config's meta.json default"
            // (signed-descent lr scales with model size; see configs.py).
            params: GauntletParams { lr: 0.0, ..GauntletParams::default() },
            clock: RoundClock::default(),
            provider: ProviderModel::default(),
            seed: 0,
            eval_every: 5,
            n_validators: 1,
            agg: AggregateOpts::default(),
            threads: 0,
        }
    }
}

impl RunConfig {
    #[deprecated(note = "use GauntletBuilder (coordinator::engine) or \
                         `RunConfig { .., ..Default::default() }`")]
    pub fn quick(model: &str, rounds: u64, peers: Vec<Behavior>) -> Self {
        RunConfig { model: model.to_string(), rounds, peers, ..RunConfig::default() }
    }

    /// Resolve [`RunConfig::threads`]: explicit value, else the
    /// `GAUNTLET_THREADS` environment variable, else available parallelism
    /// (capped at 16 — the round pipeline's widest useful fan-out at
    /// simulated scale).
    ///
    /// The run resolves this **once**, when it is assembled: the result
    /// sizes the persistent `runtime::pool::WorkerPool` the round
    /// pipeline dispatches onto, so the env lookup and CPU probe never
    /// happen per round.
    // This is THE blessed env-read site: detlint rule D002 exempts the
    // body of `effective_threads` by name, and the clippy disallowed-
    // methods tier is opted out here for the same reason — resolution
    // happens once at assembly, and the fingerprint tests prove round
    // results are invariant to the resolved width anyway.
    #[allow(clippy::disallowed_methods)]
    pub fn effective_threads(&self) -> usize {
        if self.threads > 0 {
            return self.threads;
        }
        if let Ok(v) = std::env::var("GAUNTLET_THREADS") {
            match v.parse::<usize>() {
                Ok(n) if n > 0 => return n,
                _ => {
                    // A typo'd knob silently falling back to auto-detection
                    // is a debugging trap; say so, but only once per process.
                    static WARN_ONCE: std::sync::Once = std::sync::Once::new();
                    WARN_ONCE.call_once(|| {
                        eprintln!(
                            "warning: GAUNTLET_THREADS={v:?} is not a positive \
                             integer; falling back to auto-detected parallelism"
                        );
                    });
                }
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(16)
    }
}

/// Per-peer metrics for one round.
#[derive(Clone, Debug, PartialEq)]
pub struct PeerRoundStats {
    pub uid: Uid,
    pub label: String,
    pub submitted: bool,
    pub fast_pass: bool,
    pub peer_score: f64,
    pub rating_mu: f64,
    pub rating_ordinal: f64,
    pub mu: f64,
    pub incentive: f64,
    pub in_top_g: bool,
    pub loss_score_rand: Option<f64>,
    pub loss_score_assigned: Option<f64>,
    pub balance: f64,
}

/// Everything recorded about one round. Assembled exclusively by
/// [`MetricsObserver`] from the round-event stream (see
/// `coordinator::events`); `run_round()` returns the engine's built-in
/// observer's record rather than building one inline.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundRecord {
    pub round: u64,
    pub heldout_loss: Option<f64>,
    /// Mean local training loss over honest submitting peers.
    pub mean_local_loss: f64,
    pub n_valid_submissions: usize,
    pub top_g: Vec<Uid>,
    pub peers: Vec<PeerRoundStats>,
    /// Estimated tokens processed across peers this round.
    pub tokens_processed: u64,
    /// Population/lifecycle events applied at the top of this round
    /// (scenario joins/leaves/evictions, stake moves, outages).
    pub events: Vec<String>,
}

/// Full-run metrics, serializable for the bench harness / plots
/// (`gauntlet run --metrics-out <file>` writes [`RunMetrics::to_json`]).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RunMetrics {
    pub rounds: Vec<RoundRecord>,
}

impl PeerRoundStats {
    /// Full-fidelity JSON (every field; NaN-safe via [`minjson::fnum`]).
    pub fn to_json(&self) -> Value {
        let opt = |x: Option<f64>| x.map(fnum).unwrap_or(Value::Null);
        minjson::obj(vec![
            ("uid", minjson::num(self.uid as f64)),
            ("label", minjson::s(&self.label)),
            ("submitted", Value::Bool(self.submitted)),
            ("fast_pass", Value::Bool(self.fast_pass)),
            ("peer_score", fnum(self.peer_score)),
            ("rating_mu", fnum(self.rating_mu)),
            ("rating_ordinal", fnum(self.rating_ordinal)),
            ("mu", fnum(self.mu)),
            ("incentive", fnum(self.incentive)),
            ("in_top_g", Value::Bool(self.in_top_g)),
            ("loss_score_rand", opt(self.loss_score_rand)),
            ("loss_score_assigned", opt(self.loss_score_assigned)),
            ("balance", fnum(self.balance)),
        ])
    }

    /// Inverse of [`PeerRoundStats::to_json`].
    pub fn from_json(v: &Value) -> Result<PeerRoundStats> {
        use crate::minjson::field;
        let opt = |key: &str| match v.get(key) {
            Value::Null => Ok(None),
            other => read_f64(other)
                .map(Some)
                .with_context(|| format!("peer stats bad {key:?}")),
        };
        Ok(PeerRoundStats {
            uid: field::size(v, "uid")? as Uid,
            label: field::string(v, "label")?,
            submitted: field::boolean(v, "submitted")?,
            fast_pass: field::boolean(v, "fast_pass")?,
            peer_score: field::f64(v, "peer_score")?,
            rating_mu: field::f64(v, "rating_mu")?,
            rating_ordinal: field::f64(v, "rating_ordinal")?,
            mu: field::f64(v, "mu")?,
            incentive: field::f64(v, "incentive")?,
            in_top_g: field::boolean(v, "in_top_g")?,
            loss_score_rand: opt("loss_score_rand")?,
            loss_score_assigned: opt("loss_score_assigned")?,
            balance: field::f64(v, "balance")?,
        })
    }
}

impl RunMetrics {
    /// Held-out loss series as (round, loss).
    pub fn loss_curve(&self) -> Vec<(u64, f64)> {
        self.rounds
            .iter()
            .filter_map(|r| r.heldout_loss.map(|l| (r.round, l)))
            .collect()
    }

    /// Final cumulative balance per uid (the "real-valued tokens paid").
    pub fn final_balances(&self) -> Vec<(Uid, f64)> {
        match self.rounds.last() {
            Some(r) => r.peers.iter().map(|p| (p.uid, p.balance)).collect(),
            None => vec![],
        }
    }

    /// Per-peer series of a metric, keyed by uid.
    pub fn series<F: Fn(&PeerRoundStats) -> f64>(&self, f: F) -> BTreeMap<Uid, Vec<f64>> {
        let mut out: BTreeMap<Uid, Vec<f64>> = BTreeMap::new();
        for r in &self.rounds {
            for p in &r.peers {
                out.entry(p.uid).or_default().push(f(p));
            }
        }
        out
    }

    /// Full-fidelity JSON: every [`RoundRecord`] field, round-trippable
    /// through [`RunMetrics::from_json`] (`--metrics-out` writes this).
    pub fn to_json(&self) -> Value {
        let rounds: Vec<Value> = self
            .rounds
            .iter()
            .map(|r| {
                minjson::obj(vec![
                    ("round", minjson::num(r.round as f64)),
                    (
                        "heldout_loss",
                        r.heldout_loss.map(fnum).unwrap_or(Value::Null),
                    ),
                    (
                        "events",
                        Value::Arr(r.events.iter().map(|e| minjson::s(e)).collect()),
                    ),
                    ("mean_local_loss", fnum(r.mean_local_loss)),
                    ("n_valid", minjson::num(r.n_valid_submissions as f64)),
                    ("tokens", minjson::num(r.tokens_processed as f64)),
                    (
                        "top_g",
                        Value::Arr(
                            r.top_g.iter().map(|u| minjson::num(*u as f64)).collect(),
                        ),
                    ),
                    (
                        "peers",
                        Value::Arr(r.peers.iter().map(|p| p.to_json()).collect()),
                    ),
                ])
            })
            .collect();
        minjson::obj(vec![("rounds", Value::Arr(rounds))])
    }

    /// Inverse of [`RunMetrics::to_json`] — lets downstream tooling (and
    /// the round-trip test) reload a metrics file into typed records.
    pub fn from_json(v: &Value) -> Result<RunMetrics> {
        let rounds = v
            .get("rounds")
            .as_arr()
            .context("metrics missing \"rounds\"")?
            .iter()
            .map(|r| {
                Ok(RoundRecord {
                    round: r.get("round").as_f64().context("round")? as u64,
                    heldout_loss: match r.get("heldout_loss") {
                        Value::Null => None,
                        other => Some(read_f64(other).context("heldout_loss")?),
                    },
                    mean_local_loss: read_f64(r.get("mean_local_loss"))
                        .context("mean_local_loss")?,
                    n_valid_submissions: r.get("n_valid").as_usize().context("n_valid")?,
                    top_g: r
                        .get("top_g")
                        .as_arr()
                        .context("top_g")?
                        .iter()
                        .map(|u| u.as_usize().map(|u| u as Uid).context("top_g uid"))
                        .collect::<Result<_>>()?,
                    peers: r
                        .get("peers")
                        .as_arr()
                        .context("peers")?
                        .iter()
                        .map(PeerRoundStats::from_json)
                        .collect::<Result<_>>()?,
                    tokens_processed: r.get("tokens").as_f64().context("tokens")? as u64,
                    events: r
                        .get("events")
                        .as_arr()
                        .context("events")?
                        .iter()
                        .map(|e| {
                            e.as_str().map(str::to_string).context("event string")
                        })
                        .collect::<Result<_>>()?,
                })
            })
            .collect::<Result<_>>()?;
        Ok(RunMetrics { rounds })
    }
}

/// The live system, generic over the execution backend. Use the
/// [`TemplarRun`] alias for the PJRT-artifact-backed system, or
/// [`TemplarRunWith::new_sim`] for the pure-Rust [`SimExec`] backend (no
/// artifacts required).
pub struct TemplarRunWith<E: ExecBackend + 'static> {
    pub cfg: RunConfig,
    pub exec: E,
    pub chain: Chain,
    pub store: ObjectStore,
    pub corpus: Corpus,
    pub clock: RoundClock,
    pub validators: Vec<Validator>,
    pub peers: Vec<PeerRunner>,
    pub theta: Vec<f32>,
    pub checkpoints: CheckpointStore,
    pub round: u64,
    /// The persistent worker pool every parallel stage dispatches onto:
    /// created once per run from the resolved thread count (so
    /// `GAUNTLET_THREADS` / CPU probing happen exactly once, not per
    /// round) and reused for peer turns, fast-eval fan-out, and the
    /// per-validator eval loop. See `runtime::pool` for the determinism
    /// contract.
    pool: WorkerPool,
    /// Scratch dense coefficient buffer (perf: reused across rounds).
    dense: Vec<f32>,
    /// Scratch for the post-aggregation parameters: `apply_update_into`
    /// writes here and the buffer is swapped with `theta`, so an
    /// updating round allocates nothing theta-sized.
    theta_next: Vec<f32>,
    /// Last round's aggregated coefficients (for divergent peers). After
    /// an updating round this buffer and `dense` are *swapped*, not
    /// cloned — the round hot path never reallocates the coefficient
    /// space. Meaningful only while `last_coeff_valid`.
    last_coeff: Vec<f32>,
    /// Whether `last_coeff` holds the previous round's aggregate (false
    /// after a no-update round or a snapshot resume).
    last_coeff_valid: bool,
    /// Monotonic hotkey counter: uids are recycled, hotkeys never are.
    next_hotkey: u64,
    /// Active provider-outage window: restore `outage_prob` to `.1` at the
    /// top of round `.0`.
    outage_restore: Option<(u64, f64)>,
    /// Active read-path chaos windows, keyed by kind (`"get-fail"` |
    /// `"corrupt"`): restore the provider probability to `.1` at the top
    /// of round `.0`. Same overlap semantics as `outage_restore`.
    chaos_restore: BTreeMap<String, (u64, f64)>,
    /// Active targeted eclipses: `(validator, peer)` → the round at which
    /// the validator's view of the peer's bucket is restored.
    eclipse_restore: BTreeMap<(Uid, Uid), u64>,
    /// The built-in metrics observer: the only producer of
    /// [`RoundRecord`]/[`RunMetrics`] (what `run_round()` returns).
    metrics: Arc<MetricsObserver>,
    /// External subscribers to the round-event stream.
    observers: Vec<Arc<dyn Observer>>,
    /// Suppressed during construction so the round-0 population's
    /// registrations (which pre-date every possible subscriber) don't
    /// leave the built-in observer ahead of later-attached ones.
    emit_enabled: bool,
}

/// The artifact-backed system (what the paper deploys).
pub type TemplarRun = TemplarRunWith<Executor>;

impl TemplarRunWith<Executor> {
    /// Load the config's compiled artifacts and assemble the system.
    #[deprecated(note = "use GauntletBuilder::artifact() (coordinator::engine)")]
    pub fn new(cfg: RunConfig) -> Result<TemplarRun> {
        Self::new_artifact(cfg)
    }

    /// Non-deprecated core of [`TemplarRunWith::new`], used by
    /// `GauntletBuilder::build`.
    pub(crate) fn new_artifact(cfg: RunConfig) -> Result<TemplarRun> {
        let exec = Executor::load(artifact_dir(&cfg.model))
            .with_context(|| format!("loading artifacts for {:?}", cfg.model))?;
        Self::assemble(exec, cfg)
    }
}

impl TemplarRunWith<SimExec> {
    /// Assemble the system on the deterministic pure-Rust backend — same
    /// protocol end to end, no artifacts or native XLA needed.
    #[deprecated(note = "use GauntletBuilder::sim() (coordinator::engine)")]
    pub fn new_sim(cfg: RunConfig) -> Result<TemplarRunWith<SimExec>> {
        Self::new_sim_inner(cfg)
    }

    pub(crate) fn new_sim_inner(cfg: RunConfig) -> Result<TemplarRunWith<SimExec>> {
        let exec = SimExec::from_model_name(&cfg.model, cfg.seed);
        Self::assemble(exec, cfg)
    }
}

impl<E: ExecBackend + 'static> TemplarRunWith<E> {
    /// Assemble the system over an already-constructed backend.
    #[deprecated(note = "use GauntletBuilder (coordinator::engine); direct \
                         backend injection remains available via this shim")]
    pub fn with_backend(exec: E, cfg: RunConfig) -> Result<TemplarRunWith<E>> {
        Self::assemble(exec, cfg)
    }

    /// Core constructor: assemble every substrate over `exec` and register
    /// the round-0 population through the permissionless path.
    pub(crate) fn assemble(exec: E, mut cfg: RunConfig) -> Result<TemplarRunWith<E>> {
        let theta = exec.init_params()?;
        let meta = exec.meta();
        if cfg.params.lr <= 0.0 {
            cfg.params.lr = meta.hyper.lr;
        }

        if cfg.max_uids > 0 {
            let need = cfg.n_validators.max(1) + cfg.peers.len();
            anyhow::ensure!(
                cfg.max_uids >= need,
                "max_uids = {} cannot admit the initial population \
                 ({need} neurons: {} validators + {} peers)",
                cfg.max_uids,
                cfg.n_validators.max(1),
                cfg.peers.len()
            );
        }
        let mut chain = Chain::new();
        chain.max_uids = cfg.max_uids;
        let blocks_per_round = (cfg.clock.round_ms / BLOCK_MS).max(1);
        chain.immunity_blocks = cfg.immunity_rounds * blocks_per_round;
        let store = ObjectStore::new(cfg.provider.clone(), cfg.seed ^ 0x5702);
        // The shared bucket the lead validator publishes each updating
        // round's aggregate header into (peer buckets are created at
        // registration). The minted read key is not posted on-chain —
        // monitors read it through the store's snapshot accessors.
        let _ = store.create_bucket("aggregate", "aggregate");
        let corpus = Corpus::new(meta.vocab as u32, cfg.seed);

        // Validators register and stake first (peers then get the next
        // dense uids in order).
        let mut validators = Vec::new();
        for v in 0..cfg.n_validators.max(1) {
            let uid = chain.register(&format!("validator-{v}"))?;
            chain.add_stake(uid, 1_000.0 / (v as f64 + 1.0))?;
            // Permit: even if a scenario later demotes this validator to
            // zero stake, its slot is never an eviction victim — the
            // Validator object and its chain uid stay in sync for life.
            chain.set_validator_permit(uid, true)?;
            validators.push(Validator::new(uid, cfg.params.clone(), meta.padded_count, cfg.seed));
        }

        let checkpoints = CheckpointStore::new(cfg.params.checkpoint_every);
        let dense = vec![0.0; meta.padded_count];
        let last_coeff = vec![0.0; meta.padded_count];
        let clock = cfg.clock;
        let initial_peers = cfg.peers.clone();
        // Resolve the thread knob exactly once: the pool (and the warn-once
        // on an unparsable GAUNTLET_THREADS) happen here, never per round.
        let pool = WorkerPool::new(cfg.effective_threads());
        let mut run = TemplarRunWith {
            cfg,
            exec,
            chain,
            store,
            corpus,
            clock,
            validators,
            peers: Vec::new(),
            theta,
            checkpoints,
            round: 0,
            pool,
            dense,
            theta_next: Vec::new(),
            last_coeff,
            last_coeff_valid: false,
            next_hotkey: 0,
            outage_restore: None,
            chaos_restore: BTreeMap::new(),
            eclipse_restore: BTreeMap::new(),
            metrics: Arc::new(MetricsObserver::new()),
            observers: Vec::new(),
            emit_enabled: false,
        };
        // Round-0 peers go through the same registration path as mid-run
        // joiners: the population is chain state from the very start.
        // Emission stays disabled: these registrations pre-date every
        // possible subscriber, so no observer should see them.
        for behavior in initial_peers {
            run.register_peer(behavior)
                .context("registering the initial peer population")?;
        }
        run.emit_enabled = true;
        Ok(run)
    }

    /// Subscribe an observer to this run's round-event stream. Attach
    /// before the first `run_round()` call for a complete stream (the
    /// JSONL-trace replay contract assumes this).
    pub fn add_observer(&mut self, obs: Arc<dyn Observer>) {
        self.observers.push(obs);
    }

    /// The built-in metrics observer (every record since construction).
    pub fn metrics_observer(&self) -> &Arc<MetricsObserver> {
        &self.metrics
    }

    /// Publish one event to the built-in metrics observer and every
    /// subscriber, on the calling (coordinator) thread.
    fn emit(&self, event: RoundEvent) {
        if !self.emit_enabled {
            return;
        }
        self.metrics.on_event(&event);
        for obs in &self.observers {
            obs.on_event(&event);
        }
    }

    pub fn peer_uids(&self) -> Vec<Uid> {
        self.peers.iter().map(|p| p.uid).collect()
    }

    /// Permissionless mid-run registration (§6: "peers joining later or
    /// restarting"): the newcomer registers a fresh hotkey, creates its
    /// bucket, posts the read key, and starts contributing the next time
    /// the round pipeline resolves the peer set. It obtains the current
    /// model via checkpoint + signed-update replay (the same state the
    /// network holds, verified by `checkpoints.catchup`).
    ///
    /// Slot rules apply (see the `chain` module docs): freed uids are
    /// reused, and on a full table the chain evicts the lowest-incentive
    /// non-immune peer. When the assigned uid is recycled, every validator
    /// forgets the previous occupant (fresh OpenSkill prior, cleared
    /// phi/sync history) and the old storage bucket is torn down — the
    /// newcomer shares nothing with the evicted identity but the number.
    pub fn register_peer(&mut self, behavior: Behavior) -> Result<Uid> {
        self.register_peer_detailed(behavior).map(|r| r.uid)
    }

    /// [`Self::register_peer`], exposing the chain's [`Registration`]
    /// (recycled flag + evicted hotkey) for lifecycle diagnostics.
    pub fn register_peer_detailed(
        &mut self,
        behavior: Behavior,
    ) -> Result<crate::chain::Registration> {
        let hotkey = format!("peer-hotkey-{}", self.next_hotkey);
        self.next_hotkey += 1;
        let reg = self.chain.register_replacing(&hotkey)?;
        let uid = reg.uid;
        if reg.recycled {
            self.recycle_uid(uid);
        }
        let bucket = format!("peer-{uid}");
        let rk = self.store.create_bucket(&bucket, &bucket);
        self.chain.post_read_key(uid, rk)?;
        let label = behavior.label();
        self.peers.push(PeerRunner::new(
            uid,
            behavior,
            self.exec.meta().param_count,
            self.cfg.seed,
        ));
        self.emit(RoundEvent::PeerRegistered {
            round: self.round,
            uid,
            label,
            recycled: reg.recycled,
            evicted_hotkey: reg.evicted_hotkey.clone(),
        });
        Ok(reg)
    }

    /// A peer leaves the network: its slot is freed on-chain (weights for
    /// it are scrubbed), its bucket is deleted, and its runner is torn
    /// down. Validator score state lingers harmlessly until the uid is
    /// recycled, at which point [`Self::recycle_uid`] clears it.
    pub fn deregister_peer(&mut self, uid: Uid) -> Result<()> {
        // Validators are not peers: deregistering one on-chain while its
        // Validator object keeps evaluating would crash the commit step
        // and hand its uid to a peer runner. Reject up front (a scenario
        // `leave <validator-uid>` logs as rejected and the run continues).
        if self.validators.iter().any(|v| v.uid == uid) {
            anyhow::bail!("uid {uid} is a validator; only peers can deregister");
        }
        self.chain.deregister(uid)?;
        self.store.delete_bucket(&format!("peer-{uid}"));
        self.peers.retain(|p| p.uid != uid);
        self.emit(RoundEvent::PeerDeregistered { round: self.round, uid });
        Ok(())
    }

    /// Reset every per-uid substrate for a recycled chain uid: validators
    /// drop their score state (fresh rating prior on next contact), the
    /// old bucket (and any stale objects) disappears, and any leftover
    /// runner is torn down.
    fn recycle_uid(&mut self, uid: Uid) {
        for v in &mut self.validators {
            v.forget_peer(uid);
        }
        self.store.delete_bucket(&format!("peer-{uid}"));
        self.peers.retain(|p| p.uid != uid);
    }

    /// Fire the scripted events for `round` (top-of-round, coordinator
    /// thread — see `scenario` module docs), then reconcile the runner set
    /// against the chain registry. Everything that happened is published
    /// as typed lifecycle [`RoundEvent`]s; [`MetricsObserver`] renders
    /// them into [`RoundRecord::events`].
    fn apply_scenario(&mut self, round: u64) -> Result<()> {
        // A previously scripted outage window may end this round.
        if let Some((until, orig)) = self.outage_restore {
            if round >= until {
                self.store.model.outage_prob = orig;
                self.outage_restore = None;
                self.emit(RoundEvent::OutageEnded { round });
            }
        }
        // Chaos windows (read-path faults) expire the same way: restore
        // the original probability and announce the all-clear, in BTreeMap
        // (kind) order so the event stream is deterministic.
        let expired: Vec<String> = self
            .chaos_restore
            .iter()
            .filter(|(_, &(until, _))| round >= until)
            .map(|(kind, _)| kind.clone())
            .collect();
        for kind in expired {
            let (_, orig) = self.chaos_restore.remove(&kind).expect("expired window exists");
            match kind.as_str() {
                "get-fail" => self.store.model.get_fail_prob = orig,
                "corrupt" => self.store.model.corrupt_prob = orig,
                other => unreachable!("unknown chaos window kind {other:?}"),
            }
            self.emit(RoundEvent::ChaosEnded { round, kind });
        }
        // Targeted eclipses lift at their scheduled round, in (validator,
        // peer) order.
        let lifted: Vec<(Uid, Uid)> = self
            .eclipse_restore
            .iter()
            .filter(|(_, &until)| round >= until)
            .map(|(&pair, _)| pair)
            .collect();
        for (validator, peer) in lifted {
            self.eclipse_restore.remove(&(validator, peer));
            self.store.clear_eclipse(u64::from(validator), &format!("peer-{peer}"));
            self.emit(RoundEvent::EclipseEnded { round, validator, peer });
        }

        for event in self.cfg.scenario.events_at(round) {
            match event {
                Event::JoinPeer { behavior } => {
                    let label = behavior.label();
                    // Success emits `PeerRegistered` from inside
                    // `register_peer_detailed`.
                    if let Err(e) = self.register_peer_detailed(behavior) {
                        self.emit(RoundEvent::ScenarioRejected {
                            round,
                            description: format!("join {label} rejected: {e:#}"),
                        });
                    }
                }
                Event::LeavePeer { uid } => {
                    // Success emits `PeerDeregistered` from inside
                    // `deregister_peer`.
                    if let Err(e) = self.deregister_peer(uid) {
                        self.emit(RoundEvent::ScenarioRejected {
                            round,
                            description: format!("leave uid {uid} rejected: {e:#}"),
                        });
                    }
                }
                Event::SetStake { uid, amount } => match self.chain.set_stake(uid, amount) {
                    Ok(()) => self.emit(RoundEvent::StakeSet { round, uid, amount }),
                    Err(e) => self.emit(RoundEvent::ScenarioRejected {
                        round,
                        description: format!("stake uid {uid} rejected: {e:#}"),
                    }),
                },
                Event::ProviderOutage { prob, rounds } => {
                    // Overlapping windows: the new event takes over the
                    // probability, but recovery waits for the *latest*
                    // scheduled restore — an overlap must never truncate
                    // an earlier scripted window.
                    let (prev_until, orig) = self
                        .outage_restore
                        .unwrap_or((0, self.store.model.outage_prob));
                    self.store.model.outage_prob = prob;
                    let until = (round + rounds.max(1)).max(prev_until);
                    self.outage_restore = Some((until, orig));
                    self.emit(RoundEvent::OutageStarted { round, prob, until_round: until });
                }
                Event::ChaosGetFail { prob, rounds } => {
                    // Same overlap contract as outages: the new probability
                    // takes over, recovery waits for the latest scheduled
                    // restore, and the *original* (pre-chaos) probability
                    // is what eventually comes back.
                    let (prev_until, orig) = self
                        .chaos_restore
                        .get("get-fail")
                        .copied()
                        .unwrap_or((0, self.store.model.get_fail_prob));
                    self.store.model.get_fail_prob = prob;
                    let until = (round + rounds.max(1)).max(prev_until);
                    self.chaos_restore.insert("get-fail".to_string(), (until, orig));
                    self.emit(RoundEvent::ChaosStarted {
                        round,
                        kind: "get-fail".to_string(),
                        prob,
                        until_round: until,
                    });
                }
                Event::ChaosCorrupt { prob, rounds } => {
                    let (prev_until, orig) = self
                        .chaos_restore
                        .get("corrupt")
                        .copied()
                        .unwrap_or((0, self.store.model.corrupt_prob));
                    self.store.model.corrupt_prob = prob;
                    let until = (round + rounds.max(1)).max(prev_until);
                    self.chaos_restore.insert("corrupt".to_string(), (until, orig));
                    self.emit(RoundEvent::ChaosStarted {
                        round,
                        kind: "corrupt".to_string(),
                        prob,
                        until_round: until,
                    });
                }
                Event::Eclipse { validator, peer, rounds } => {
                    let until = (round + rounds.max(1))
                        .max(self.eclipse_restore.get(&(validator, peer)).copied().unwrap_or(0));
                    self.eclipse_restore.insert((validator, peer), until);
                    self.store.set_eclipse(u64::from(validator), &format!("peer-{peer}"));
                    self.emit(RoundEvent::EclipseStarted {
                        round,
                        validator,
                        peer,
                        until_round: until,
                    });
                }
            }
        }

        // Resolve the peer set from the chain registry: a runner whose uid
        // is gone (scripted leave above, or an eviction by any
        // registration path) no longer takes turns. Membership is probed
        // per runner — O(active · log table) — rather than materializing
        // the registered set, which at 1M uids would cost more than the
        // round itself.
        let before = self.peers.len();
        let chain = &self.chain;
        self.peers.retain(|p| chain.neuron(p.uid).is_some());
        if self.peers.len() != before {
            let count = before - self.peers.len();
            self.emit(RoundEvent::RunnersDropped { round, count });
        }
        Ok(())
    }

    /// Drive the run to completion: rounds advance until the engine's
    /// round counter reaches [`RunConfig::rounds`], so a resumed engine
    /// runs exactly the rounds an uninterrupted run still had left.
    /// Returns the metrics of the rounds driven by *this* call (assembled
    /// by the built-in [`MetricsObserver`]).
    pub fn run(&mut self) -> Result<RunMetrics> {
        let already = self.metrics.n_rounds();
        while self.round < self.cfg.rounds {
            self.run_round()?;
        }
        Ok(RunMetrics { rounds: self.metrics.records_since(already) })
    }

    /// One synchronous communication round (see module docs for the staged
    /// pipeline and its determinism contract). Every decision is published
    /// to the round-event stream; the returned [`RoundRecord`] is the
    /// built-in [`MetricsObserver`]'s assembly of those events (a clone of
    /// the record the observer retains — drivers that don't want the
    /// per-round records at all can ignore the return value and drain the
    /// observer with [`MetricsObserver::take`] as needed).
    pub fn run_round(&mut self) -> Result<RoundRecord> {
        let round = self.round;
        self.emit(RoundEvent::RoundStarted { round });
        // Population lifecycle first: fire scripted churn events and
        // re-resolve the peer set from the chain registry, so everything
        // below sees this round's population.
        self.apply_scenario(round)?;
        let meta_batch = self.exec.meta().batch;
        let meta_seq = self.exec.meta().seq;
        // alpha_t from the schedule (§3.1); everything downstream — signed
        // step, SyncScore units, beta_t — uses this round's value.
        let lr_t = self.cfg.params.schedule.lr_at(round, self.cfg.params.lr);
        if self.checkpoints.is_checkpoint_round(round) {
            self.emit(RoundEvent::Checkpointed { round });
        }
        self.checkpoints.maybe_checkpoint(round, &self.theta);
        // Resolved once at construction; reading it off the pool is a
        // field load, not an env-var lookup + CPU probe per round.
        let threads = self.pool.threads();

        // ------------------------- peers act -----------------------------
        // First pass: independent behaviours, produced concurrently on the
        // persistent pool. PUTs are applied afterwards in peer order so
        // the provider's latency/outage draws don't depend on worker
        // timing.
        let outputs = {
            let exec = &self.exec;
            let corpus = &self.corpus;
            let theta = &self.theta;
            let clock = &self.clock;
            let params = &self.cfg.params;
            let pool = &self.pool;
            if threads <= 1 || self.peers.len() <= 1 {
                step_peer_chunk(exec, &mut self.peers, 0, corpus, theta, round, clock, params)?
            } else if let Some(shared) = exec.as_shared() {
                // Sync backend: workers call the model directly.
                step_first_pass_shared(
                    shared,
                    &mut self.peers,
                    corpus,
                    theta,
                    round,
                    clock,
                    params,
                    pool,
                )?
            } else {
                // Thread-affine backend: workers go through the funnel.
                step_first_pass_funneled(
                    exec,
                    &mut self.peers,
                    corpus,
                    theta,
                    round,
                    clock,
                    params,
                    pool,
                )?
            }
        };
        // PUTs, turn diagnostics, and events in peer order, identical to
        // the sequential sweep.
        let mut submitted: BTreeMap<Uid, bool> = BTreeMap::new();
        for (i, out) in outputs {
            let (uid, label, local_loss, tokens) = {
                let p = &self.peers[i];
                (
                    p.uid,
                    p.behavior.label(),
                    p.last_local_loss,
                    (p.last_microbatches * meta_batch * meta_seq) as u64,
                )
            };
            let ok = self.emit_turn_and_put(round, uid, label, false, local_loss, tokens, out);
            submitted.insert(uid, ok);
        }
        // Second pass: copiers / duplicators read their source's public
        // object and re-post it (cheap; stays sequential).
        for i in 0..self.peers.len() {
            if !self.peers[i].behavior.is_second_pass() {
                continue;
            }
            let uid = self.peers[i].uid;
            let src_uid = self.peers[i].behavior.source_uid().unwrap();
            let src_obj = self.read_public(src_uid, round);
            let ctx = PeerCtx {
                exec: &self.exec,
                corpus: &self.corpus,
                global_theta: &self.theta,
                round,
                clock: &self.clock,
                params: &self.cfg.params,
            };
            let out =
                self.peers[i].step_copy(&ctx, src_obj.as_deref().map(|o| o.bytes.as_slice()))?;
            let (label, local_loss) =
                (self.peers[i].behavior.label(), self.peers[i].last_local_loss);
            let ok = self.emit_turn_and_put(round, uid, label, true, local_loss, 0, out);
            submitted.insert(uid, ok);
        }

        // ---------------------- validators evaluate ----------------------
        let peer_uids = self.peer_uids();
        let read_keys = chain_read_keys(&self.chain, &peer_uids)?;
        let mut outcomes: Vec<RoundOutcome> = {
            let exec = &self.exec;
            let corpus = &self.corpus;
            // Freeze theta once per round: every validator's evaluation
            // requests clone this handle, so the funnel ships pointers,
            // not per-call copies of the parameter vector.
            let theta_shared: ThetaShared = ThetaShared::from(self.theta.as_slice());
            let theta = &theta_shared;
            let clock = &self.clock;
            let store = &self.store;
            let pool = &self.pool;
            let validators = &mut self.validators;
            if threads <= 1 || validators.is_empty() {
                let mut out = Vec::with_capacity(validators.len());
                for v in validators.iter_mut() {
                    out.push(v.evaluate_round(
                        exec, corpus, theta, round, clock, store, &read_keys, &peer_uids,
                        lr_t, pool, 1,
                    )?);
                }
                out
            } else {
                // Validators run concurrently on the pool; each fans its
                // fast checks out over its share of the worker budget
                // (nested dispatch on the same pool — waiters help, see
                // `runtime::pool`).
                let fanout = (threads / validators.len()).max(1);
                let results: Vec<Result<RoundOutcome>> = if let Some(shared) = exec.as_shared()
                {
                    // Sync backend: validator workers call it directly.
                    let read_keys = &read_keys;
                    let peer_uids = &peer_uids;
                    pool.map_indexed(validators, |_, v| {
                        v.evaluate_round(
                            shared, corpus, theta, round, clock, store, read_keys, peer_uids,
                            lr_t, pool, fanout,
                        )
                    })
                } else {
                    // Thread-affine backend: it stays on this thread,
                    // serving the validator workers' ExecClient requests
                    // while the pool runs the evaluations.
                    let (client, host) = exec_service(exec);
                    let mut slots: Vec<Option<Result<RoundOutcome>>> =
                        Vec::with_capacity(validators.len());
                    slots.resize_with(validators.len(), || None);
                    let jobs: Vec<Job<'_>> = validators
                        .iter_mut()
                        .zip(slots.iter_mut())
                        .map(|(v, slot)| {
                            let client = client.clone();
                            let read_keys = &read_keys;
                            let peer_uids = &peer_uids;
                            Box::new(move || {
                                *slot = Some(v.evaluate_round(
                                    &client, corpus, theta, round, clock, store, read_keys,
                                    peer_uids, lr_t, pool, fanout,
                                ));
                            }) as Job<'_>
                        })
                        .collect();
                    pool.run_with(jobs, move || {
                        drop(client);
                        host.serve();
                    });
                    slots.into_iter().map(|s| s.expect("pool job completed")).collect()
                };
                let mut out = Vec::with_capacity(results.len());
                for r in results {
                    out.push(r?);
                }
                out
            }
        };
        // Publish each validator's verdicts in validator order (the
        // parallel fan-out above already returned them ordered).
        for (v, o) in self.validators.iter().zip(&outcomes) {
            // Storage friction first (retries spent, unreadable peers),
            // then the verdicts those reads produced.
            for (&uid, &retries) in &o.fast_retries {
                self.emit(RoundEvent::StorageRetry { round, actor: v.uid, uid, retries });
            }
            for &uid in &o.unavailable {
                self.emit(RoundEvent::SubmissionUnavailable { round, validator: v.uid, uid });
            }
            for (&uid, &passed) in &o.fast_pass {
                let phi = o.fast_phi.get(&uid).copied().unwrap_or(1.0);
                self.emit(RoundEvent::FastEval { round, validator: v.uid, uid, passed, phi });
            }
            for (uid, ev) in &o.evaluated {
                self.emit(RoundEvent::PrimaryEval {
                    round,
                    validator: v.uid,
                    uid: *uid,
                    score_assigned: ev.score_assigned,
                    score_rand: ev.score_rand,
                });
            }
            if o.evaluated.len() >= 2 {
                self.emit(RoundEvent::RatingMatch {
                    round,
                    validator: v.uid,
                    uids: o.evaluated.iter().map(|(u, _)| *u).collect(),
                });
            }
        }
        // Bribery stage: a Briber peer pays its target validator to commit
        // an inflated weight for the briber's uid. Applied here, at the
        // weight-commit boundary, so the bribed validator's own score book,
        // aggregation weights, and event stream stay honest — the only
        // corrupted artifact is the on-chain weight row, exactly what Yuma
        // consensus (stake-weighted clipping at kappa) exists to bound. A
        // minority-stake target gets clipped to the honest consensus; the
        // attack only pays once the bribed validator holds a stake
        // majority (the paper's stake-security assumption).
        for i in 0..self.peers.len() {
            let Behavior::Briber { validator } = self.peers[i].behavior else { continue };
            let briber_uid = self.peers[i].uid;
            let Some(vi) = self.validators.iter().position(|v| v.uid == validator) else {
                continue;
            };
            let row = &mut outcomes[vi].incentives;
            let top = row.iter().map(|(_, w)| *w).fold(0.0_f64, f64::max).max(1.0);
            match row.iter_mut().find(|(u, _)| *u == briber_uid) {
                Some(entry) => entry.1 = top,
                None => row.push((briber_uid, top)),
            }
        }
        // Commit weight vectors in validator order (determinism + the
        // chain is single-writer). A validator demoted mid-run (scenario
        // `stake <uid> 0`) still evaluates locally but may no longer
        // commit — the chain would reject it, and killing the run over a
        // scripted demotion would make `SetStake` unusable.
        for i in 0..self.validators.len() {
            let v_uid = self.validators[i].uid;
            let staked = self.chain.neuron(v_uid).is_some_and(|n| n.stake > 0.0);
            if staked {
                self.chain.set_weights(v_uid, &outcomes[i].incentives)?;
            }
            self.emit(RoundEvent::WeightsCommitted {
                round,
                validator: v_uid,
                committed: staked,
            });
        }
        // The lead validator — highest on-chain stake, deterministic after
        // the total_cmp/uid ordering — provides the aggregation weights
        // (§3.3). Resolved from the chain every round so a scripted
        // demotion (`stake <uid> 0`) moves emission *and* aggregation to
        // the new lead together. `chain.validators()` is sorted best-first
        // and may contain scripted-staked peers; the lead is the best
        // staked uid that *is* one of ours. Falls back to the first
        // validator when none of ours holds stake.
        let lead_idx = self
            .chain
            .validators()
            .find_map(|u| self.validators.iter().position(|v| v.uid == u))
            .unwrap_or(0);
        let outcome = outcomes
            .into_iter()
            .nth(lead_idx)
            .expect("at least one validator");

        // ------------------------ chain epoch ----------------------------
        let chain_incentives = self.chain.run_epoch();
        self.emit(RoundEvent::YumaEpoch { round, incentives: chain_incentives.clone() });
        let incentive_of = |uid: Uid| {
            chain_incentives.iter().find(|(u, _)| *u == uid).map(|(_, x)| *x).unwrap_or(0.0)
        };

        // ------------------------- aggregation ---------------------------
        // Lead validator's top-G weights drive aggregation (§3.3
        // "Coordinated Aggregation" / "Validator Consensus and Stake").
        let weights = if outcome.agg_weights.is_empty() {
            // Bootstrap: before any primary evaluations have separated the
            // peers, aggregate every fast-valid submission equally.
            let n = outcome.valid_submissions.len().max(1);
            outcome
                .valid_submissions
                .keys()
                .map(|&u| (u, 1.0 / n as f64))
                .collect::<Vec<_>>()
        } else {
            outcome
                .agg_weights
                .iter()
                .filter(|(u, _)| outcome.valid_submissions.contains_key(u))
                .copied()
                .collect()
        };
        let top_g: Vec<Uid> = weights.iter().map(|(u, _)| *u).collect();

        // Allocation-free aggregation step (perf): when nothing aggregates,
        // theta stays in place untouched (this used to clone the whole
        // parameter vector just to reassign it); when something does, the
        // aggregate is scattered into the reusable `dense` scratch, and
        // `dense`/`last_coeff` are swapped instead of cloned. `top_g` is
        // moved into the event rather than copied — the scoreboard below
        // reads membership from `weights`.
        let had_update = !weights.is_empty();
        if had_update {
            self.dense.iter_mut().for_each(|x| *x = 0.0);
            let contributions: Vec<(&crate::demo::SparseGrad, f64)> = weights
                .iter()
                .map(|(u, w)| (&outcome.valid_submissions[u].grad, *w))
                .collect();
            aggregate_into(&contributions, &mut self.dense, &self.cfg.agg);
            // In-place kernel + buffer swap: the new parameters land in
            // the reusable `theta_next` scratch and become `theta` by
            // exchange, so the update step allocates nothing.
            self.exec.apply_update_into(&self.theta, &self.dense, lr_t, &mut self.theta_next)?;
            self.checkpoints.record_update(round, &self.theta, &self.theta_next, lr_t)?;
            std::mem::swap(&mut self.theta, &mut self.theta_next);
            std::mem::swap(&mut self.dense, &mut self.last_coeff);
        }
        self.last_coeff_valid = had_update;
        self.emit(RoundEvent::Aggregated {
            round,
            top_g,
            n_valid: outcome.valid_submissions.len(),
            had_update,
        });

        // -------------------- aggregate publication ----------------------
        // The lead validator publishes a compact aggregate header (round,
        // lr, theta digest) to the shared bucket so late joiners and
        // monitors can verify which parameters this round produced. The
        // write runs through the same retry policy as peer PUTs; if the
        // budget is exhausted the round *degrades* instead of aborting:
        // a pointer at the latest durable checkpoint is posted best-effort
        // and the run continues on the already-applied update.
        if had_update {
            let lead_uid = self.validators[lead_idx].uid;
            let key = format!("agg-{round}");
            let send = self.clock.put_window(round).1;
            let payload = aggregate_payload(round, lr_t, &self.theta);
            let policy = &self.cfg.params.retry;
            match self.store.put_with_retry("aggregate", "aggregate", &key, payload, send, policy)
            {
                Ok((_, attempts)) => {
                    if attempts > 1 {
                        self.emit(RoundEvent::StorageRetry {
                            round,
                            actor: lead_uid,
                            uid: lead_uid,
                            retries: attempts - 1,
                        });
                    }
                }
                Err(_) => {
                    let attempts = policy.max_attempts.max(1);
                    self.emit(RoundEvent::AggregationDegraded { round, attempts });
                    let every = self.cfg.params.checkpoint_every.max(1);
                    let ckpt_round = round - round % every;
                    let fallback = degraded_payload(round, ckpt_round);
                    // Best-effort: under a total outage this fails too, and
                    // that is fine — the degradation event already tells the
                    // story, and readers fall back to the checkpoint anyway.
                    let _ = self.store.put("aggregate", "aggregate", &key, fallback, send);
                }
            }
        }

        // -------------------- peers synchronize --------------------------
        let agg_coeff: Option<&[f32]> =
            if self.last_coeff_valid { Some(&self.last_coeff) } else { None };
        for p in &mut self.peers {
            p.on_round_end(round, &self.theta, &self.exec, agg_coeff, lr_t)?;
        }

        // --------------------- end-of-round events -----------------------
        if self.cfg.eval_every > 0 && round % self.cfg.eval_every == 0 {
            let toks = self.corpus.heldout(0, meta_batch, meta_seq + 1);
            let loss = self.exec.loss(&self.theta, &toks)? as f64;
            self.emit(RoundEvent::HeldoutEval { round, loss });
        }

        // Per-peer scoreboard: the lead validator's view, matching the
        // outcome that drove aggregation above.
        let book = &self.validators[lead_idx].book;
        for p in &self.peers {
            let st = book.get(p.uid);
            let ev = outcome.evaluated.iter().find(|(u, _)| *u == p.uid).map(|(_, e)| e);
            let stats = PeerRoundStats {
                uid: p.uid,
                label: p.behavior.label(),
                submitted: *submitted.get(&p.uid).unwrap_or(&false),
                fast_pass: *outcome.fast_pass.get(&p.uid).unwrap_or(&false),
                peer_score: book.peer_score(p.uid),
                rating_mu: st.map(|s| s.rating.mu).unwrap_or(0.0),
                rating_ordinal: st.map(|s| s.rating.ordinal()).unwrap_or(0.0),
                mu: st.map(|s| s.mu.value).unwrap_or(0.0),
                incentive: incentive_of(p.uid),
                in_top_g: weights.iter().any(|(u, _)| *u == p.uid),
                loss_score_rand: ev.map(|e| e.score_rand),
                loss_score_assigned: ev.map(|e| e.score_assigned),
                balance: self.chain.neuron(p.uid).map(|n| n.balance).unwrap_or(0.0),
            };
            self.emit(RoundEvent::PeerScoreboard { round, stats });
        }

        // Advance chain time to the start of the next round.
        let blocks_per_round = self.clock.round_ms / crate::chain::BLOCK_MS;
        self.chain.advance_blocks(blocks_per_round.max(1));
        self.round += 1;
        self.emit(RoundEvent::RoundCompleted { round });

        self.metrics
            .last_record()
            .context("the built-in metrics observer must have recorded this round")
    }

    /// Capture the full run substrate at the current round boundary (call
    /// between `run_round()` calls). The snapshot is self-contained: it
    /// embeds the [`RunConfig`], so `GauntletBuilder::resume` needs
    /// nothing else, and resuming is bit-identical to not having paused
    /// (`tests/snapshot_resume.rs`).
    pub fn snapshot(&self) -> RunSnapshot {
        let (checkpoints, updates) = self.checkpoints.export();
        RunSnapshot {
            round: self.round,
            // Filled in by `GauntletEngine::snapshot`, which knows which
            // backend variant it wraps.
            backend: String::new(),
            cfg: self.cfg.clone(),
            theta: self.theta.clone(),
            next_hotkey: self.next_hotkey,
            outage_restore: self.outage_restore,
            chaos_restore: self.chaos_restore.clone(),
            eclipse_restore: self.eclipse_restore.clone(),
            chain: self.chain.to_state(),
            validators: self
                .validators
                .iter()
                .map(|v| super::snapshot::ValidatorState {
                    uid: v.uid,
                    rng_state: v.rng_state(),
                    book: v.book.iter().map(|(u, s)| (*u, s.clone())).collect(),
                })
                .collect(),
            peers: self.peers.iter().map(|p| p.to_state()).collect(),
            store: super::snapshot::StoreState {
                rng_state: self.store.rng_state(),
                next_key_id: self.store.next_key_id(),
                outage_prob: self.store.model.outage_prob,
                get_fail_prob: self.store.model.get_fail_prob,
                corrupt_prob: self.store.model.corrupt_prob,
                buckets: self.store.export_buckets(),
            },
            // Lifecycle lines from direct register/deregister calls since
            // the last round must still land in the next round's record.
            pending_events: self.metrics.pending_events(),
            checkpoint_rounds: checkpoints.to_vec(),
            checkpoint_updates: updates.to_vec(),
        }
    }

    /// Reassemble a run mid-stream from a [`RunSnapshot`] over an
    /// already-constructed backend (the `GauntletBuilder::resume` path).
    pub(crate) fn from_snapshot(exec: E, snap: RunSnapshot) -> Result<TemplarRunWith<E>> {
        let cfg = snap.cfg;
        let meta = exec.meta();
        anyhow::ensure!(
            snap.theta.len() == meta.param_count,
            "snapshot parameters ({}) do not fit model {:?} ({} parameters) — \
             was the snapshot taken with a different --model?",
            snap.theta.len(),
            cfg.model,
            meta.param_count
        );
        let chain = Chain::from_state(snap.chain);
        // The store restarts from the captured control state: RNG stream,
        // read-key mint, bucket registry, live (possibly mid-outage /
        // mid-chaos) failure probabilities. Object payloads never cross a
        // round boundary, so none are carried. The fault seed is derived
        // from the config seed exactly as `assemble` derives it, so the
        // keyed read-path draws continue bit-identically across the
        // snapshot boundary.
        let mut provider = cfg.provider.clone();
        provider.outage_prob = snap.store.outage_prob;
        provider.get_fail_prob = snap.store.get_fail_prob;
        provider.corrupt_prob = snap.store.corrupt_prob;
        let store = ObjectStore::new(provider, cfg.seed ^ 0x5702);
        store.set_rng_state(snap.store.rng_state);
        store.set_next_key_id(snap.store.next_key_id);
        for (name, owner, key) in snap.store.buckets {
            store.restore_bucket(&name, &owner, key);
        }
        // Re-arm the targeted faults that were live at the boundary.
        for &(validator, peer) in snap.eclipse_restore.keys() {
            store.set_eclipse(u64::from(validator), &format!("peer-{peer}"));
        }
        let corpus = Corpus::new(meta.vocab as u32, cfg.seed);
        let mut validators = Vec::with_capacity(snap.validators.len());
        for vs in snap.validators {
            let mut v = Validator::new(vs.uid, cfg.params.clone(), meta.padded_count, cfg.seed);
            v.set_rng_state(vs.rng_state);
            for (uid, state) in vs.book {
                v.book.insert_state(uid, state);
            }
            validators.push(v);
        }
        let peers = snap.peers.into_iter().map(PeerRunner::from_state).collect();
        let checkpoints = CheckpointStore::restore(
            cfg.params.checkpoint_every,
            snap.checkpoint_rounds,
            snap.checkpoint_updates,
        );
        let dense = vec![0.0; meta.padded_count];
        let last_coeff = vec![0.0; meta.padded_count];
        let clock = cfg.clock;
        let metrics = Arc::new(MetricsObserver::new());
        metrics.push_pending(snap.pending_events);
        let pool = WorkerPool::new(cfg.effective_threads());
        Ok(TemplarRunWith {
            cfg,
            exec,
            chain,
            store,
            corpus,
            clock,
            validators,
            peers,
            theta: snap.theta,
            checkpoints,
            round: snap.round,
            pool,
            dense,
            theta_next: Vec::new(),
            last_coeff,
            last_coeff_valid: false,
            next_hotkey: snap.next_hotkey,
            outage_restore: snap.outage_restore,
            chaos_restore: snap.chaos_restore,
            eclipse_restore: snap.eclipse_restore,
            metrics,
            observers: Vec::new(),
            emit_enabled: true,
        })
    }

    /// Publish one peer's `PeerTurn` (+ `PutApplied`, if it submitted) and
    /// apply the PUT — shared by the first- and second-pass loops so their
    /// event payloads cannot drift apart. Returns whether the submission
    /// landed.
    #[allow(clippy::too_many_arguments)]
    fn emit_turn_and_put(
        &self,
        round: u64,
        uid: Uid,
        label: String,
        second_pass: bool,
        local_loss: f64,
        tokens: u64,
        out: PeerOutput,
    ) -> bool {
        self.emit(RoundEvent::PeerTurn { round, uid, label, second_pass, local_loss, tokens });
        let attempted = matches!(out, PeerOutput::Submit { .. });
        let (ok, retries) = self.put_output(uid, out);
        if retries > 0 {
            // The peer is both the actor (it ran the retry loop) and the
            // bucket owner.
            self.emit(RoundEvent::StorageRetry { round, actor: uid, uid, retries });
        }
        if attempted {
            self.emit(RoundEvent::PutApplied { round, uid, accepted: ok });
        }
        ok
    }

    /// Apply one peer's submission PUT through the retry policy. Returns
    /// `(landed, retries_spent)` — a PUT that exhausts its budget on
    /// transient outages reports the full spend; a definitive rejection
    /// reports none (no attempt would have helped).
    fn put_output(&self, uid: Uid, out: PeerOutput) -> (bool, u32) {
        match out {
            PeerOutput::Submit { time, bytes } => {
                let bucket = format!("peer-{uid}");
                let key = Submission::object_key(uid, self.round);
                let policy = &self.cfg.params.retry;
                match self.store.put_with_retry(&bucket, &bucket, &key, bytes, time, policy) {
                    Ok((_, attempts)) => (true, attempts - 1),
                    Err(e) if e.is_transient() => (false, policy.max_attempts.max(1) - 1),
                    Err(_) => (false, 0),
                }
            }
            PeerOutput::Skip => (false, 0),
        }
    }

    /// Read another peer's public object (pseudo-gradients are broadcast:
    /// every peer's read key is on the chain). Hands back the store's
    /// shared `Arc<Object>` — no byte copy, and the copier's decode hits
    /// the same digest memo the validators warmed.
    fn read_public(&self, uid: Uid, round: u64) -> Option<Arc<crate::storage::Object>> {
        let rk = self.chain.neuron(uid)?.bucket_read_key.clone()?;
        let bucket = format!("peer-{uid}");
        let key = Submission::object_key(uid, round);
        self.store.get(&bucket, &rk, &key).ok()?
    }
}

/// The aggregate header published each updating round: magic, round,
/// this round's lr, and an FNV-1a digest over the post-update parameter
/// bits — enough for a reader to verify which theta the round produced
/// without shipping theta itself.
fn aggregate_payload(round: u64, lr_t: f32, theta: &[f32]) -> Vec<u8> {
    let mut digest = 0xcbf2_9ce4_8422_2325_u64;
    for x in theta {
        for b in x.to_le_bytes() {
            digest ^= u64::from(b);
            digest = digest.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    let mut out = Vec::with_capacity(24);
    out.extend_from_slice(b"AGG1");
    out.extend_from_slice(&round.to_le_bytes());
    out.extend_from_slice(&lr_t.to_le_bytes());
    out.extend_from_slice(&digest.to_le_bytes());
    out
}

/// The degraded header posted when the aggregate publication exhausts its
/// retry budget: points readers at the latest durable checkpoint round
/// instead of this round's (unpublishable) aggregate.
fn degraded_payload(round: u64, checkpoint_round: u64) -> Vec<u8> {
    let mut out = Vec::with_capacity(20);
    out.extend_from_slice(b"AGG0");
    out.extend_from_slice(&round.to_le_bytes());
    out.extend_from_slice(&checkpoint_round.to_le_bytes());
    out
}

/// What one first-pass pool job produces: the chunk's `(peer_index,
/// output)` pairs in ascending index order, or the first error.
type PeerChunkOut = Result<Vec<(usize, PeerOutput)>>;

/// Step a contiguous chunk of peers sequentially (first pass only).
/// `base` is the chunk's offset in the full peer list, so results come
/// back as `(peer_index, output)` in ascending index order. Shared by the
/// sequential path and both parallel fan-outs — per-peer RNG draw
/// sequences are identical everywhere.
#[allow(clippy::too_many_arguments)]
fn step_peer_chunk<B: ExecBackend + ?Sized>(
    exec: &B,
    chunk: &mut [PeerRunner],
    base: usize,
    corpus: &Corpus,
    theta: &[f32],
    round: u64,
    clock: &RoundClock,
    params: &GauntletParams,
) -> PeerChunkOut {
    let mut out = Vec::with_capacity(chunk.len());
    for (j, p) in chunk.iter_mut().enumerate() {
        if p.behavior.is_second_pass() {
            continue;
        }
        let ctx = PeerCtx { exec, corpus, global_theta: theta, round, clock, params };
        out.push((base + j, p.step(&ctx)?));
    }
    Ok(out)
}

/// First-pass peer turns on the run's persistent worker pool, calling a
/// `Sync` backend directly from every worker. Chunking and result order
/// match the sequential sweep exactly (see `runtime::pool`).
#[allow(clippy::too_many_arguments)]
fn step_first_pass_shared(
    exec: &(dyn ExecBackend + Sync),
    peers: &mut [PeerRunner],
    corpus: &Corpus,
    theta: &[f32],
    round: u64,
    clock: &RoundClock,
    params: &GauntletParams,
    pool: &WorkerPool,
) -> Result<Vec<(usize, PeerOutput)>> {
    let n = peers.len();
    let per_chunk: Vec<PeerChunkOut> = pool.scatter(peers, pool.threads(), |base, chunk| {
        step_peer_chunk(exec, chunk, base, corpus, theta, round, clock, params)
    });
    let mut out = Vec::with_capacity(n);
    for r in per_chunk {
        out.extend(r?);
    }
    Ok(out)
}

/// First-pass peer turns on the persistent pool for a thread-affine
/// backend: model execution goes through an [`exec_service`] funnel so
/// the backend never leaves the calling thread (which serves requests
/// until every dispatched chunk finishes).
#[allow(clippy::too_many_arguments)]
fn step_first_pass_funneled<E: ExecBackend + 'static>(
    exec: &E,
    peers: &mut [PeerRunner],
    corpus: &Corpus,
    theta: &[f32],
    round: u64,
    clock: &RoundClock,
    params: &GauntletParams,
    pool: &WorkerPool,
) -> Result<Vec<(usize, PeerOutput)>> {
    let n = peers.len();
    let chunk_size = WorkerPool::chunk_len(n, pool.threads());
    let n_chunks = n.div_ceil(chunk_size);
    let (client, host) = exec_service(exec);
    let mut slots: Vec<Option<PeerChunkOut>> = Vec::with_capacity(n_chunks);
    slots.resize_with(n_chunks, || None);
    let jobs: Vec<Job<'_>> = peers
        .chunks_mut(chunk_size)
        .zip(slots.iter_mut())
        .enumerate()
        .map(|(ci, (chunk, slot))| {
            let client = client.clone();
            Box::new(move || {
                *slot = Some(step_peer_chunk(
                    &client,
                    chunk,
                    ci * chunk_size,
                    corpus,
                    theta,
                    round,
                    clock,
                    params,
                ));
            }) as Job<'_>
        })
        .collect();
    pool.run_with(jobs, move || {
        drop(client);
        host.serve();
    });
    let mut out = Vec::with_capacity(n);
    for r in slots {
        out.extend(r.expect("pool job completed")?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_metrics() -> RunMetrics {
        let peer = |uid: Uid, in_top_g: bool| PeerRoundStats {
            uid,
            label: format!("honest-{uid}"),
            submitted: true,
            fast_pass: uid % 2 == 0,
            peer_score: 0.25 * uid as f64,
            rating_mu: 25.0 + uid as f64,
            rating_ordinal: 1.5 - uid as f64,
            mu: -0.0, // negative zero must survive the round trip
            incentive: 1.0 / 3.0,
            in_top_g,
            loss_score_rand: if uid == 1 { Some(0.125) } else { None },
            loss_score_assigned: None,
            balance: 7.75,
        };
        RunMetrics {
            rounds: vec![
                RoundRecord {
                    round: 0,
                    heldout_loss: Some(4.15625),
                    mean_local_loss: 3.0625,
                    n_valid_submissions: 2,
                    top_g: vec![1, 2],
                    peers: vec![peer(1, true), peer(2, true)],
                    tokens_processed: 128,
                    events: vec!["join honest as uid 2".to_string()],
                },
                RoundRecord {
                    round: 1,
                    heldout_loss: None,
                    mean_local_loss: 0.0,
                    n_valid_submissions: 0,
                    top_g: vec![],
                    peers: vec![peer(1, false)],
                    tokens_processed: 0,
                    events: vec![],
                },
            ],
        }
    }

    #[test]
    fn run_metrics_roundtrip_through_minjson() {
        let m = sample_metrics();
        let text = m.to_json().write();
        let parsed = Value::parse(&text).expect("metrics JSON parses");
        let back = RunMetrics::from_json(&parsed).expect("typed reload");
        assert_eq!(m, back, "typed round trip");
        // Bit-exactness of the awkward values survives a second pass too.
        assert_eq!(text, back.to_json().write(), "serialization is idempotent");
        assert_eq!(back.rounds[0].peers[0].mu.to_bits(), (-0.0f64).to_bits());
    }

    #[test]
    fn run_metrics_from_json_rejects_malformed_input() {
        for bad in [
            r#"{}"#,
            r#"{"rounds":[{"round":0}]}"#,
            r#"{"rounds":[{"round":0,"heldout_loss":null,"mean_local_loss":"bogus","n_valid":0,"tokens":0,"top_g":[],"peers":[],"events":[]}]}"#,
        ] {
            let v = Value::parse(bad).unwrap();
            assert!(RunMetrics::from_json(&v).is_err(), "{bad}");
        }
    }

    #[test]
    fn default_config_seeds_no_peers_and_quick_shim_matches() {
        let d = RunConfig::default();
        assert!(d.peers.is_empty());
        assert_eq!(d.rounds, 20);
        #[allow(deprecated)]
        let q = RunConfig::quick("tiny", 7, vec![Behavior::Freeloader]);
        assert_eq!(q.model, "tiny");
        assert_eq!(q.rounds, 7);
        assert_eq!(q.peers, vec![Behavior::Freeloader]);
        assert_eq!(q.n_validators, d.n_validators);
    }
}
