//! The end-to-end Templar system: chain + cloud storage + peers +
//! validator(s) + DeMo aggregation, driven round by round (§2, §3.3, §6).
//!
//! This is what `examples/templar_run.rs` and the Fig. 1 / Fig. 2 benches
//! execute. One `TemplarRun` owns every substrate; `run_round()` performs:
//!
//!   1. peers take their turns (first pass: independent behaviours; second
//!      pass: copiers/duplicators, who need a victim's public object),
//!   2. each validator fast-evaluates everyone, primary-evaluates a random
//!      subset, updates its scores, and commits weights to the chain,
//!   3. the chain runs a Yuma epoch, combining validators into incentives
//!      and paying emission,
//!   4. the lead validator's top-G weights drive the DeMo aggregation
//!      (encoded-domain normalization + weighted sparse sum -> IDCT ->
//!      sign -> `theta -= lr * sign`), with checkpoint bookkeeping,
//!   5. peers synchronize to the new model (or diverge, per behaviour).

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use super::checkpoint::CheckpointStore;
use super::round::RoundClock;
use super::validator::Validator;
use super::GauntletParams;
use crate::chain::{Chain, Uid};
use crate::data::Corpus;
use crate::demo::aggregate::{aggregate_into, AggregateOpts};
use crate::demo::wire::Submission;
use crate::minjson::{self, Value};
use crate::peers::{Behavior, PeerCtx, PeerOutput, PeerRunner};
use crate::runtime::{artifact_dir, Executor};
use crate::storage::{ObjectStore, ProviderModel};

/// Configuration for a full run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Artifact config name (nano / tiny / small / base).
    pub model: String,
    pub rounds: u64,
    /// One behaviour per registered peer (uids assigned in order).
    pub peers: Vec<Behavior>,
    pub params: GauntletParams,
    pub clock: RoundClock,
    pub provider: ProviderModel,
    pub seed: u64,
    /// Evaluate held-out loss every this many rounds (0 = never).
    pub eval_every: u64,
    /// Number of staked validators (>=1; all run the same protocol and
    /// are combined by Yuma consensus).
    pub n_validators: usize,
    /// Aggregation options (normalization on/off for the §4 ablation).
    pub agg: AggregateOpts,
}

impl RunConfig {
    pub fn quick(model: &str, rounds: u64, peers: Vec<Behavior>) -> Self {
        RunConfig {
            model: model.to_string(),
            rounds,
            peers,
            // lr = 0 means "resolve from the config's meta.json default"
            // (signed-descent lr scales with model size; see configs.py).
            params: GauntletParams { lr: 0.0, ..GauntletParams::default() },
            clock: RoundClock::default(),
            provider: ProviderModel::default(),
            seed: 0,
            eval_every: 5,
            n_validators: 1,
            agg: AggregateOpts::default(),
        }
    }
}

/// Per-peer metrics for one round.
#[derive(Clone, Debug)]
pub struct PeerRoundStats {
    pub uid: Uid,
    pub label: String,
    pub submitted: bool,
    pub fast_pass: bool,
    pub peer_score: f64,
    pub rating_mu: f64,
    pub rating_ordinal: f64,
    pub mu: f64,
    pub incentive: f64,
    pub in_top_g: bool,
    pub loss_score_rand: Option<f64>,
    pub loss_score_assigned: Option<f64>,
    pub balance: f64,
}

/// Everything recorded about one round.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: u64,
    pub heldout_loss: Option<f64>,
    /// Mean local training loss over honest submitting peers.
    pub mean_local_loss: f64,
    pub n_valid_submissions: usize,
    pub top_g: Vec<Uid>,
    pub peers: Vec<PeerRoundStats>,
    /// Estimated tokens processed across peers this round.
    pub tokens_processed: u64,
}

/// Full-run metrics, serializable for the bench harness / plots.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub rounds: Vec<RoundRecord>,
}

impl RunMetrics {
    /// Held-out loss series as (round, loss).
    pub fn loss_curve(&self) -> Vec<(u64, f64)> {
        self.rounds
            .iter()
            .filter_map(|r| r.heldout_loss.map(|l| (r.round, l)))
            .collect()
    }

    /// Final cumulative balance per uid (the "real-valued tokens paid").
    pub fn final_balances(&self) -> Vec<(Uid, f64)> {
        match self.rounds.last() {
            Some(r) => r.peers.iter().map(|p| (p.uid, p.balance)).collect(),
            None => vec![],
        }
    }

    /// Per-peer series of a metric, keyed by uid.
    pub fn series<F: Fn(&PeerRoundStats) -> f64>(&self, f: F) -> BTreeMap<Uid, Vec<f64>> {
        let mut out: BTreeMap<Uid, Vec<f64>> = BTreeMap::new();
        for r in &self.rounds {
            for p in &r.peers {
                out.entry(p.uid).or_default().push(f(p));
            }
        }
        out
    }

    pub fn to_json(&self) -> Value {
        let rounds: Vec<Value> = self
            .rounds
            .iter()
            .map(|r| {
                minjson::obj(vec![
                    ("round", minjson::num(r.round as f64)),
                    (
                        "heldout_loss",
                        r.heldout_loss.map(minjson::num).unwrap_or(Value::Null),
                    ),
                    ("mean_local_loss", minjson::num(r.mean_local_loss)),
                    ("n_valid", minjson::num(r.n_valid_submissions as f64)),
                    ("tokens", minjson::num(r.tokens_processed as f64)),
                    (
                        "peers",
                        Value::Arr(
                            r.peers
                                .iter()
                                .map(|p| {
                                    minjson::obj(vec![
                                        ("uid", minjson::num(p.uid as f64)),
                                        ("label", minjson::s(&p.label)),
                                        ("score", minjson::num(p.peer_score)),
                                        ("rating_mu", minjson::num(p.rating_mu)),
                                        ("mu", minjson::num(p.mu)),
                                        ("incentive", minjson::num(p.incentive)),
                                        ("balance", minjson::num(p.balance)),
                                        ("fast_pass", Value::Bool(p.fast_pass)),
                                        ("top_g", Value::Bool(p.in_top_g)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect();
        minjson::obj(vec![("rounds", Value::Arr(rounds))])
    }
}

/// The live system.
pub struct TemplarRun {
    pub cfg: RunConfig,
    pub exec: Executor,
    pub chain: Chain,
    pub store: ObjectStore,
    pub corpus: Corpus,
    pub clock: RoundClock,
    pub validators: Vec<Validator>,
    pub peers: Vec<PeerRunner>,
    pub theta: Vec<f32>,
    pub checkpoints: CheckpointStore,
    pub round: u64,
    /// Scratch dense coefficient buffer (perf: reused across rounds).
    dense: Vec<f32>,
    /// Last round's aggregated coefficients (for divergent peers).
    last_coeff: Option<Vec<f32>>,
}

impl TemplarRun {
    pub fn new(mut cfg: RunConfig) -> Result<TemplarRun> {
        let exec = Executor::load(artifact_dir(&cfg.model))
            .with_context(|| format!("loading artifacts for {:?}", cfg.model))?;
        let theta = exec.init_params()?;
        let meta = &exec.meta;
        if cfg.params.lr <= 0.0 {
            cfg.params.lr = meta.hyper.lr;
        }

        let mut chain = Chain::new();
        let mut store = ObjectStore::new(cfg.provider.clone(), cfg.seed ^ 0x5702);
        let corpus = Corpus::new(meta.vocab as u32, cfg.seed);

        // Validators register and stake first (uids 1000+ keep peer uids
        // dense from 0).
        let mut validators = Vec::new();
        for v in 0..cfg.n_validators.max(1) {
            let uid = chain.register(&format!("validator-{v}"))?;
            chain.add_stake(uid, 1_000.0 / (v as f64 + 1.0))?;
            validators.push(Validator::new(uid, cfg.params.clone(), meta.padded_count, cfg.seed));
        }

        // Permissionless peer registration: each creates a bucket and posts
        // its read key (§5).
        let mut peers = Vec::new();
        for (i, behavior) in cfg.peers.iter().enumerate() {
            let uid = chain.register(&format!("peer-hotkey-{i}"))?;
            let bucket = format!("peer-{uid}");
            let rk = store.create_bucket(&bucket, &bucket);
            chain.post_read_key(uid, rk)?;
            peers.push(PeerRunner::new(uid, behavior.clone(), meta.param_count, cfg.seed));
        }

        let checkpoints = CheckpointStore::new(cfg.params.checkpoint_every);
        let dense = vec![0.0; meta.padded_count];
        let clock = cfg.clock;
        Ok(TemplarRun {
            cfg,
            exec,
            chain,
            store,
            corpus,
            clock,
            validators,
            peers,
            theta,
            checkpoints,
            round: 0,
            dense,
            last_coeff: None,
        })
    }

    pub fn peer_uids(&self) -> Vec<Uid> {
        self.peers.iter().map(|p| p.uid).collect()
    }

    /// Permissionless mid-run registration (§6: "peers joining later or
    /// restarting"): the newcomer registers a hotkey, creates its bucket,
    /// posts the read key, and starts contributing next round. It obtains
    /// the current model via checkpoint + signed-update replay (the same
    /// state the network holds, verified by `checkpoints.catchup`).
    pub fn register_peer(&mut self, behavior: Behavior) -> Result<Uid> {
        let i = self.peers.len();
        let uid = self.chain.register(&format!("peer-hotkey-{i}"))?;
        let bucket = format!("peer-{uid}");
        let rk = self.store.create_bucket(&bucket, &bucket);
        self.chain.post_read_key(uid, rk)?;
        self.peers.push(PeerRunner::new(
            uid,
            behavior,
            self.exec.meta.param_count,
            self.cfg.seed,
        ));
        Ok(uid)
    }

    /// Drive the whole run.
    pub fn run(&mut self) -> Result<RunMetrics> {
        let mut metrics = RunMetrics::default();
        for _ in 0..self.cfg.rounds {
            metrics.rounds.push(self.run_round()?);
        }
        Ok(metrics)
    }

    /// One synchronous communication round.
    pub fn run_round(&mut self) -> Result<RoundRecord> {
        let round = self.round;
        let meta_batch = self.exec.meta.batch;
        let meta_seq = self.exec.meta.seq;
        // alpha_t from the schedule (§3.1); everything downstream — signed
        // step, SyncScore units, beta_t — uses this round's value.
        let lr_t = self.cfg.params.schedule.lr_at(round, self.cfg.params.lr);
        self.checkpoints.maybe_checkpoint(round, &self.theta);

        // ------------------------- peers act -----------------------------
        let mut local_losses = Vec::new();
        let mut tokens: u64 = 0;
        let mut submitted: BTreeMap<Uid, bool> = BTreeMap::new();
        // First pass: independent behaviours.
        for i in 0..self.peers.len() {
            if self.peers[i].behavior.is_second_pass() {
                continue;
            }
            let ctx = PeerCtx {
                exec: &self.exec,
                corpus: &self.corpus,
                global_theta: &self.theta,
                round,
                clock: &self.clock,
                params: &self.cfg.params,
            };
            let out = self.peers[i].step(&ctx)?;
            let uid = self.peers[i].uid;
            if self.peers[i].last_local_loss.is_finite() {
                local_losses.push(self.peers[i].last_local_loss);
            }
            tokens +=
                (self.peers[i].last_microbatches * meta_batch * meta_seq) as u64;
            submitted.insert(uid, self.put_output(uid, out));
        }
        // Second pass: copiers / duplicators read their source's public
        // object and re-post it.
        for i in 0..self.peers.len() {
            if !self.peers[i].behavior.is_second_pass() {
                continue;
            }
            let uid = self.peers[i].uid;
            let src_uid = self.peers[i].behavior.source_uid().unwrap();
            let src_bytes = self.read_public(src_uid, round);
            let ctx = PeerCtx {
                exec: &self.exec,
                corpus: &self.corpus,
                global_theta: &self.theta,
                round,
                clock: &self.clock,
                params: &self.cfg.params,
            };
            let out = self.peers[i].step_copy(&ctx, src_bytes.as_deref())?;
            submitted.insert(uid, self.put_output(uid, out));
        }

        // ---------------------- validators evaluate ----------------------
        let peer_uids = self.peer_uids();
        let mut lead_outcome = None;
        for v in 0..self.validators.len() {
            let outcome = self.validators[v].process_round(
                &self.exec,
                &self.corpus,
                &self.theta,
                round,
                &self.clock,
                &self.store,
                &mut self.chain,
                &peer_uids,
                lr_t,
            )?;
            if v == 0 {
                lead_outcome = Some(outcome);
            }
        }
        let outcome = lead_outcome.expect("at least one validator");

        // ------------------------ chain epoch ----------------------------
        let chain_incentives = self.chain.run_epoch();
        let incentive_of = |uid: Uid| {
            chain_incentives.iter().find(|(u, _)| *u == uid).map(|(_, x)| *x).unwrap_or(0.0)
        };

        // ------------------------- aggregation ---------------------------
        // Lead validator's top-G weights drive aggregation (§3.3
        // "Coordinated Aggregation" / "Validator Consensus and Stake").
        let weights = if outcome.agg_weights.is_empty() {
            // Bootstrap: before any primary evaluations have separated the
            // peers, aggregate every fast-valid submission equally.
            let n = outcome.valid_submissions.len().max(1);
            outcome
                .valid_submissions
                .keys()
                .map(|&u| (u, 1.0 / n as f64))
                .collect::<Vec<_>>()
        } else {
            outcome
                .agg_weights
                .iter()
                .filter(|(u, _)| outcome.valid_submissions.contains_key(u))
                .copied()
                .collect()
        };
        let top_g: Vec<Uid> = weights.iter().map(|(u, _)| *u).collect();

        let theta_before = std::mem::take(&mut self.theta);
        let (theta_after, had_update) = if weights.is_empty() {
            (theta_before.clone(), false)
        } else {
            self.dense.iter_mut().for_each(|x| *x = 0.0);
            let contributions: Vec<(&crate::demo::SparseGrad, f64)> = weights
                .iter()
                .map(|(u, w)| (&outcome.valid_submissions[u].grad, *w))
                .collect();
            aggregate_into(&contributions, &mut self.dense, &self.cfg.agg);
            let new_theta = self.exec.apply_update(&theta_before, &self.dense, lr_t)?;
            (new_theta, true)
        };
        if had_update {
            self.checkpoints.record_update(round, &theta_before, &theta_after, lr_t)?;
            self.last_coeff = Some(self.dense.clone());
        } else {
            self.last_coeff = None;
        }
        self.theta = theta_after;

        // -------------------- peers synchronize --------------------------
        for p in &mut self.peers {
            p.on_round_end(
                round,
                &self.theta,
                &self.exec,
                self.last_coeff.as_deref(),
                lr_t,
            )?;
        }

        // ------------------------- metrics -------------------------------
        let heldout_loss = if self.cfg.eval_every > 0 && round % self.cfg.eval_every == 0 {
            let toks = self.corpus.heldout(0, meta_batch, meta_seq + 1);
            Some(self.exec.loss(&self.theta, &toks)? as f64)
        } else {
            None
        };

        let book = &self.validators[0].book;
        let peers_stats: Vec<PeerRoundStats> = self
            .peers
            .iter()
            .map(|p| {
                let st = book.get(p.uid);
                let ev = outcome.evaluated.iter().find(|(u, _)| *u == p.uid).map(|(_, e)| e);
                PeerRoundStats {
                    uid: p.uid,
                    label: p.behavior.label(),
                    submitted: *submitted.get(&p.uid).unwrap_or(&false),
                    fast_pass: *outcome.fast_pass.get(&p.uid).unwrap_or(&false),
                    peer_score: book.peer_score(p.uid),
                    rating_mu: st.map(|s| s.rating.mu).unwrap_or(0.0),
                    rating_ordinal: st.map(|s| s.rating.ordinal()).unwrap_or(0.0),
                    mu: st.map(|s| s.mu.value).unwrap_or(0.0),
                    incentive: incentive_of(p.uid),
                    in_top_g: top_g.contains(&p.uid),
                    loss_score_rand: ev.map(|e| e.score_rand),
                    loss_score_assigned: ev.map(|e| e.score_assigned),
                    balance: self.chain.neuron(p.uid).map(|n| n.balance).unwrap_or(0.0),
                }
            })
            .collect();

        // Advance chain time to the start of the next round.
        let blocks_per_round = self.clock.round_ms / crate::chain::BLOCK_MS;
        self.chain.advance_blocks(blocks_per_round.max(1));
        self.round += 1;

        Ok(RoundRecord {
            round,
            heldout_loss,
            mean_local_loss: crate::util::mean(&local_losses),
            n_valid_submissions: outcome.valid_submissions.len(),
            top_g,
            peers: peers_stats,
            tokens_processed: tokens,
        })
    }

    fn put_output(&mut self, uid: Uid, out: PeerOutput) -> bool {
        match out {
            PeerOutput::Submit { time, bytes } => {
                let bucket = format!("peer-{uid}");
                let key = Submission::object_key(uid, self.round);
                self.store.put(&bucket, &bucket, &key, bytes, time).is_ok()
            }
            PeerOutput::Skip => false,
        }
    }

    /// Read another peer's public object (pseudo-gradients are broadcast:
    /// every peer's read key is on the chain).
    fn read_public(&self, uid: Uid, round: u64) -> Option<Vec<u8>> {
        let rk = self.chain.neuron(uid)?.bucket_read_key.clone()?;
        let bucket = format!("peer-{uid}");
        let key = Submission::object_key(uid, round);
        self.store.get(&bucket, &rk, &key).ok()?.map(|o| o.bytes.clone())
    }
}
