//! Checkpointing and signed-update catchup (§3.1, "Signed Descent").
//!
//! Because the aggregated update is `theta' = theta - alpha * sign(Delta)`,
//! each round's update is fully described by one ternary digit per
//! parameter. The coordinator therefore checkpoints the full parameter
//! vector only every `checkpoint_every` rounds and stores the per-round
//! sign vectors bit-packed (2 bits/param, 16x smaller than f32); a peer
//! joining late (or restarting) downloads the latest checkpoint and
//! replays the signs — the paper's "fast checkpoint catchup".

use anyhow::{bail, Result};

/// A bit-packed ternary sign vector: 2 bits per parameter.
/// Encoding: 0b00 = 0, 0b01 = +1, 0b10 = -1.
#[derive(Clone, Debug, PartialEq)]
pub struct SignVector {
    packed: Vec<u8>,
    len: usize,
}

impl SignVector {
    /// Extract signs from a pre/post parameter pair:
    /// `sign_i = round((theta_i - theta_i') / lr)` which is exact for the
    /// signed-descent update.
    pub fn from_update(theta_before: &[f32], theta_after: &[f32], lr: f32) -> Result<SignVector> {
        if theta_before.len() != theta_after.len() {
            bail!("length mismatch");
        }
        let mut packed = vec![0u8; theta_before.len().div_ceil(4)];
        for (i, (b, a)) in theta_before.iter().zip(theta_after).enumerate() {
            let step = ((*b as f64 - *a as f64) / lr as f64).round();
            let code: u8 = match step as i64 {
                0 => 0b00,
                1 => 0b01,
                -1 => 0b10,
                s => bail!("update at {i} is {s} steps, not a single signed step"),
            };
            packed[i / 4] |= code << ((i % 4) * 2);
        }
        Ok(SignVector { packed, len: theta_before.len() })
    }

    pub fn len(&self) -> usize {
        self.len
    }

    /// The raw `(packed bytes, logical length)` pair, for serialization.
    pub fn to_parts(&self) -> (&[u8], usize) {
        (&self.packed, self.len)
    }

    /// Rebuild from [`SignVector::to_parts`] output.
    pub fn from_parts(packed: Vec<u8>, len: usize) -> Result<SignVector> {
        if packed.len() != len.div_ceil(4) {
            bail!("sign vector: {} packed bytes cannot hold {len} entries", packed.len());
        }
        Ok(SignVector { packed, len })
    }
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
    pub fn byte_size(&self) -> usize {
        self.packed.len()
    }

    pub fn get(&self, i: usize) -> i8 {
        let code = (self.packed[i / 4] >> ((i % 4) * 2)) & 0b11;
        match code {
            0b01 => 1,
            0b10 => -1,
            _ => 0,
        }
    }

    /// Apply this signed update in place: `theta -= lr * sign`.
    pub fn apply(&self, theta: &mut [f32], lr: f32) {
        assert_eq!(theta.len(), self.len);
        for i in 0..self.len {
            match self.get(i) {
                1 => theta[i] -= lr,
                -1 => theta[i] += lr,
                _ => {}
            }
        }
    }
}

/// In-memory checkpoint store (the deployed system keeps these in the lead
/// validator's bucket; the storage layer is orthogonal to the replay
/// logic tested here).
pub struct CheckpointStore {
    pub every: u64,
    /// (round, full params) — "params as of the *start* of round".
    checkpoints: Vec<(u64, Vec<f32>)>,
    /// sign vector applied at the *end* of round r, with the lr used.
    updates: Vec<(u64, f32, SignVector)>,
}

impl CheckpointStore {
    pub fn new(every: u64) -> Self {
        CheckpointStore { every, checkpoints: Vec::new(), updates: Vec::new() }
    }

    /// Whether `round` starts with a full-parameter checkpoint.
    pub fn is_checkpoint_round(&self, round: u64) -> bool {
        round % self.every == 0
    }

    /// Record state at the start of `round` if it's a checkpoint round.
    pub fn maybe_checkpoint(&mut self, round: u64, theta: &[f32]) {
        if self.is_checkpoint_round(round) {
            self.checkpoints.push((round, theta.to_vec()));
        }
    }

    /// Record the signed update that advanced round `round`.
    pub fn record_update(
        &mut self,
        round: u64,
        theta_before: &[f32],
        theta_after: &[f32],
        lr: f32,
    ) -> Result<()> {
        let sv = SignVector::from_update(theta_before, theta_after, lr)?;
        self.updates.push((round, lr, sv));
        Ok(())
    }

    /// Reconstruct the parameters at the **start** of `round` from the
    /// nearest earlier checkpoint plus sign replay — what a late-joining
    /// peer does.
    pub fn catchup(&self, round: u64) -> Option<Vec<f32>> {
        let (ckpt_round, base) =
            self.checkpoints.iter().rev().find(|(r, _)| *r <= round)?;
        let mut theta = base.clone();
        for (r, lr, sv) in &self.updates {
            if *r >= *ckpt_round && *r < round {
                sv.apply(&mut theta, *lr);
            }
        }
        Some(theta)
    }

    /// Export everything for a run snapshot: the stored full-parameter
    /// checkpoints and the per-round `(round, lr, signs)` updates.
    #[allow(clippy::type_complexity)]
    pub fn export(&self) -> (&[(u64, Vec<f32>)], &[(u64, f32, SignVector)]) {
        (&self.checkpoints, &self.updates)
    }

    /// Rebuild a store mid-run from exported state, so `catchup` keeps
    /// answering for pre-snapshot rounds after a resume.
    pub fn restore(
        every: u64,
        checkpoints: Vec<(u64, Vec<f32>)>,
        updates: Vec<(u64, f32, SignVector)>,
    ) -> Self {
        CheckpointStore { every, checkpoints, updates }
    }

    pub fn n_checkpoints(&self) -> usize {
        self.checkpoints.len()
    }
    pub fn n_updates(&self) -> usize {
        self.updates.len()
    }
    /// Total bytes of sign storage (compression accounting).
    pub fn sign_bytes(&self) -> usize {
        self.updates.iter().map(|(_, _, sv)| sv.byte_size()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use crate::prop_assert;

    #[test]
    fn sign_vector_roundtrip() {
        let lr = 0.02f32;
        let before = vec![1.0f32, -0.5, 0.25, 0.0, 2.0];
        let signs: [i8; 5] = [1, -1, 0, 1, -1];
        let after: Vec<f32> =
            before.iter().zip(signs).map(|(b, s)| b - lr * s as f32).collect();
        let sv = SignVector::from_update(&before, &after, lr).unwrap();
        for (i, s) in signs.iter().enumerate() {
            assert_eq!(sv.get(i), *s, "index {i}");
        }
        let mut replay = before.clone();
        sv.apply(&mut replay, lr);
        for (r, a) in replay.iter().zip(&after) {
            assert!((r - a).abs() < 1e-6);
        }
    }

    #[test]
    fn rejects_non_signed_updates() {
        let before = vec![1.0f32];
        let after = vec![0.9f32]; // 5 steps at lr=0.02
        assert!(SignVector::from_update(&before, &after, 0.02).is_err());
    }

    #[test]
    fn packing_is_16x_smaller_than_f32() {
        let n = 1024;
        let before = vec![0.0f32; n];
        let after = vec![-0.02f32; n];
        let sv = SignVector::from_update(&before, &after, 0.02).unwrap();
        assert_eq!(sv.byte_size(), n / 4);
        assert_eq!(sv.byte_size() * 16, n * 4);
    }

    #[test]
    fn catchup_replays_to_exact_state() {
        let lr = 0.1f32;
        let mut store = CheckpointStore::new(4);
        let mut theta = vec![0.0f32; 9];
        let mut rng = crate::util::Rng::new(3);
        let mut states = vec![theta.clone()];
        for round in 0..10u64 {
            store.maybe_checkpoint(round, &theta);
            let before = theta.clone();
            for t in theta.iter_mut() {
                let s = (rng.below(3) as i64) - 1;
                *t -= lr * s as f32;
            }
            store.record_update(round, &before, &theta, lr).unwrap();
            states.push(theta.clone());
        }
        assert_eq!(store.n_checkpoints(), 3); // rounds 0, 4, 8
        for round in 0..=10u64 {
            let got = store.catchup(round).unwrap();
            let want = &states[round as usize];
            for (g, w) in got.iter().zip(want) {
                assert!((g - w).abs() < 1e-5, "round {round}");
            }
        }
    }

    #[test]
    fn prop_signvector_roundtrips_arbitrary_ternary() {
        prop::check("signvector-roundtrip", 40, |rng, size| {
            let n = 1 + size * 3;
            let lr = 0.05f32;
            let before: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let signs: Vec<i8> = (0..n).map(|_| (rng.below(3) as i8) - 1).collect();
            let after: Vec<f32> =
                before.iter().zip(&signs).map(|(b, s)| b - lr * *s as f32).collect();
            let sv = SignVector::from_update(&before, &after, lr).map_err(|e| e.to_string())?;
            for i in 0..n {
                prop_assert!(sv.get(i) == signs[i], "sign mismatch at {i}");
            }
            Ok(())
        });
    }
}
