//! Learning-rate schedules (§3.1: "In practice, when using a learning rate
//! scheduler, we found it was sufficient to set beta_t = c * alpha_t").
//!
//! The round loop evaluates alpha_t = schedule(round) each communication
//! round; the validator's evaluation step size follows automatically as
//! beta_t = beta_frac * alpha_t, and the SyncScore denominator uses the
//! same alpha_t so "one unit" always means "one current signed step".

/// Per-round learning-rate schedule.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LrSchedule {
    /// alpha_t = base for all t.
    Constant,
    /// Linear warmup over `warmup` rounds from base/10, then cosine decay
    /// to `min_frac * base` at round `total` (clamped afterwards).
    WarmupCosine { warmup: u64, total: u64, min_frac: f64 },
    /// Step decay: alpha halves every `every` rounds.
    StepHalving { every: u64 },
}

impl Default for LrSchedule {
    fn default() -> Self {
        LrSchedule::Constant
    }
}

impl LrSchedule {
    /// The learning rate for communication round `round`.
    pub fn lr_at(&self, round: u64, base: f32) -> f32 {
        match *self {
            LrSchedule::Constant => base,
            LrSchedule::WarmupCosine { warmup, total, min_frac } => {
                let base = base as f64;
                let lr = if warmup > 0 && round < warmup {
                    // from 10% to 100% of base across the warmup
                    base * (0.1 + 0.9 * (round as f64 + 1.0) / warmup as f64)
                } else {
                    let t0 = warmup.min(total);
                    let span = total.saturating_sub(t0).max(1) as f64;
                    let p = ((round.saturating_sub(t0)) as f64 / span).min(1.0);
                    let floor = base * min_frac;
                    floor + 0.5 * (base - floor) * (1.0 + (std::f64::consts::PI * p).cos())
                };
                lr as f32
            }
            LrSchedule::StepHalving { every } => {
                let k = if every == 0 { 0 } else { round / every };
                base / 2f32.powi(k.min(30) as i32)
            }
        }
    }

    /// Parse a CLI spec: "constant", "cosine:<warmup>:<total>[:<min_frac>]",
    /// "halve:<every>".
    pub fn parse(spec: &str) -> Result<LrSchedule, String> {
        let parts: Vec<&str> = spec.split(':').collect();
        match parts[0] {
            "constant" => Ok(LrSchedule::Constant),
            "cosine" => {
                let warmup = parts.get(1).ok_or("cosine needs :<warmup>")?.parse()
                    .map_err(|e| format!("warmup: {e}"))?;
                let total = parts.get(2).ok_or("cosine needs :<total>")?.parse()
                    .map_err(|e| format!("total: {e}"))?;
                let min_frac = match parts.get(3) {
                    Some(f) => f.parse().map_err(|e| format!("min_frac: {e}"))?,
                    None => 0.1,
                };
                Ok(LrSchedule::WarmupCosine { warmup, total, min_frac })
            }
            "halve" => {
                let every = parts.get(1).ok_or("halve needs :<every>")?.parse()
                    .map_err(|e| format!("every: {e}"))?;
                Ok(LrSchedule::StepHalving { every })
            }
            other => Err(format!("unknown schedule {other:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use crate::prop_assert;

    #[test]
    fn constant_is_constant() {
        let s = LrSchedule::Constant;
        assert_eq!(s.lr_at(0, 0.02), 0.02);
        assert_eq!(s.lr_at(10_000, 0.02), 0.02);
    }

    #[test]
    fn warmup_rises_then_cosine_falls() {
        let s = LrSchedule::WarmupCosine { warmup: 10, total: 100, min_frac: 0.1 };
        let base = 0.01f32;
        // warmup monotone rising
        for r in 1..10 {
            assert!(s.lr_at(r, base) >= s.lr_at(r - 1, base), "warmup at {r}");
        }
        // peak at end of warmup equals base
        assert!((s.lr_at(9, base) - base).abs() < 1e-6);
        // decay monotone falling
        for r in 11..100 {
            assert!(s.lr_at(r, base) <= s.lr_at(r - 1, base) + 1e-9, "decay at {r}");
        }
        // floor respected and held after `total`
        let floor = base * 0.1;
        assert!((s.lr_at(100, base) - floor).abs() < 1e-6);
        assert!((s.lr_at(5000, base) - floor).abs() < 1e-6);
    }

    #[test]
    fn step_halving() {
        let s = LrSchedule::StepHalving { every: 5 };
        assert_eq!(s.lr_at(0, 0.04), 0.04);
        assert_eq!(s.lr_at(4, 0.04), 0.04);
        assert_eq!(s.lr_at(5, 0.04), 0.02);
        assert_eq!(s.lr_at(14, 0.04), 0.01);
    }

    #[test]
    fn parse_roundtrips() {
        assert_eq!(LrSchedule::parse("constant").unwrap(), LrSchedule::Constant);
        assert_eq!(
            LrSchedule::parse("cosine:5:50").unwrap(),
            LrSchedule::WarmupCosine { warmup: 5, total: 50, min_frac: 0.1 }
        );
        assert_eq!(
            LrSchedule::parse("cosine:5:50:0.25").unwrap(),
            LrSchedule::WarmupCosine { warmup: 5, total: 50, min_frac: 0.25 }
        );
        assert_eq!(LrSchedule::parse("halve:7").unwrap(), LrSchedule::StepHalving { every: 7 });
        assert!(LrSchedule::parse("exponential").is_err());
        assert!(LrSchedule::parse("cosine").is_err());
        assert!(LrSchedule::parse("cosine:x:50").is_err());
    }

    #[test]
    fn prop_lr_always_positive_and_bounded_by_base() {
        prop::check("schedule-bounds", 40, |rng, size| {
            let base = rng.range_f64(1e-4, 0.1) as f32;
            let s = match size % 3 {
                0 => LrSchedule::Constant,
                1 => LrSchedule::WarmupCosine {
                    warmup: rng.below(20),
                    total: 20 + rng.below(200),
                    min_frac: rng.range_f64(0.0, 1.0),
                },
                _ => LrSchedule::StepHalving { every: 1 + rng.below(50) },
            };
            for _ in 0..30 {
                let r = rng.below(5000);
                let lr = s.lr_at(r, base);
                prop_assert!(lr > 0.0, "non-positive lr {lr} at {r} for {s:?}");
                prop_assert!(lr <= base * 1.0001, "lr {lr} exceeds base {base} for {s:?}");
            }
            Ok(())
        });
    }
}
