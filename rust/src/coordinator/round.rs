//! Communication-round clock and put windows (§2).
//!
//! Training proceeds in fixed-duration communication rounds anchored to
//! blockchain time (§5 gives the network a consistent global clock). At
//! the end of each round there is a short **put window** during which
//! pseudo-gradients must land in the peer's bucket; submissions stored
//! outside the window are ignored by the validator (§3.2 basic check (a)).

use crate::storage::SimTime;

#[derive(Clone, Copy, Debug)]
pub struct RoundClock {
    /// Full round duration (compute + communication), ms.
    pub round_ms: u64,
    /// Length of the put window at the end of the round, ms.
    pub put_window_ms: u64,
}

impl Default for RoundClock {
    fn default() -> Self {
        // 60 s rounds with a 20 s put window — scaled-down from the live
        // run's multi-minute windows, same structure.
        RoundClock { round_ms: 60_000, put_window_ms: 20_000 }
    }
}

impl RoundClock {
    pub fn round_start(&self, round: u64) -> SimTime {
        round * self.round_ms
    }

    /// [open, close] of the put window for `round`.
    pub fn put_window(&self, round: u64) -> (SimTime, SimTime) {
        let end = (round + 1) * self.round_ms;
        (end - self.put_window_ms, end)
    }

    /// The round a given timestamp falls in.
    pub fn round_of(&self, t: SimTime) -> u64 {
        t / self.round_ms
    }

    /// A compliant upload time for a peer that spent `compute_ms` working:
    /// it posts as soon as its work is done, but never before the window
    /// opens (early submissions are ignored too).
    pub fn compliant_upload_time(&self, round: u64, compute_ms: u64) -> SimTime {
        let (open, _) = self.put_window(round);
        (self.round_start(round) + compute_ms).max(open)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windows_tile_the_timeline() {
        let c = RoundClock { round_ms: 1000, put_window_ms: 300 };
        assert_eq!(c.round_start(0), 0);
        assert_eq!(c.put_window(0), (700, 1000));
        assert_eq!(c.put_window(3), (3700, 4000));
        assert_eq!(c.round_of(0), 0);
        assert_eq!(c.round_of(999), 0);
        assert_eq!(c.round_of(1000), 1);
    }

    #[test]
    fn compliant_upload_waits_for_window() {
        let c = RoundClock { round_ms: 1000, put_window_ms: 300 };
        // fast peer: done at t=200, must hold until window opens at 700
        assert_eq!(c.compliant_upload_time(0, 200), 700);
        // slow peer: done at 900, posts immediately
        assert_eq!(c.compliant_upload_time(0, 900), 900);
    }
}
