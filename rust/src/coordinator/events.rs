//! The typed round-event stream: everything the round pipeline decides,
//! published as [`RoundEvent`]s to [`Observer`]s instead of being scraped
//! out of return values.
//!
//! The Gauntlet mechanism "can be applied to any synchronous distributed
//! training scheme" (§1); what varies per deployment is who watches —
//! metrics collection, tracing, benches, dashboards. This module makes
//! watching composable: the engine emits one deterministic stream of
//! events per round (always from the coordinator thread, in a fixed
//! order, regardless of worker-thread count), and observers subscribe via
//! [`GauntletBuilder::observer`](super::engine::GauntletBuilder::observer).
//!
//! Two built-in observers cover the previously hard-wired consumers:
//!
//! - [`MetricsObserver`] assembles the per-round [`RoundRecord`]s and the
//!   full-run [`RunMetrics`] — the engine itself carries one, which is how
//!   `run_round()` still returns a record without assembling it inline.
//! - [`JsonlTraceObserver`] writes every event as one JSON line to a trace
//!   file; [`replay_trace`] re-reads such a file through a fresh
//!   `MetricsObserver` and reproduces the identical `RunMetrics`
//!   (pinned by `tests/parallel_determinism.rs`).
//!
//! # Event order
//!
//! Within one round, events always arrive in pipeline-stage order:
//! `RoundStarted`, lifecycle events (registrations, departures, stake
//! moves, outage/chaos/eclipse window boundaries), `Checkpointed`,
//! per-peer `PeerTurn`/`StorageRetry`/`PutApplied` in peer order (first
//! pass, then second pass), per-validator `StorageRetry` /
//! `SubmissionUnavailable` / `FastEval` (uid order) / `PrimaryEval`
//! (sample order) / `RatingMatch` / `WeightsCommitted` in validator
//! order, `YumaEpoch`, `Aggregated` (preceded by `AggregationDegraded`
//! when the publication write failed), `HeldoutEval`, per-peer
//! `PeerScoreboard`, `RoundCompleted`. The stream is bit-identical at any
//! worker-thread count.
//!
//! ```
//! use std::sync::{Arc, Mutex};
//! use gauntlet::coordinator::engine::GauntletBuilder;
//! use gauntlet::coordinator::events::{observer_fn, RoundEvent};
//! use gauntlet::peers::Behavior;
//!
//! // Count fast-eval failures with a closure observer.
//! let fails = Arc::new(Mutex::new(0u32));
//! let sink = fails.clone();
//! let mut engine = GauntletBuilder::sim()
//!     .model("nano")
//!     .rounds(2)
//!     .peers(vec![Behavior::Honest { data_mult: 1.0 }, Behavior::FormatViolator])
//!     .observer(observer_fn(move |ev| {
//!         if let RoundEvent::FastEval { passed: false, .. } = ev {
//!             *sink.lock().unwrap() += 1;
//!         }
//!     }))
//!     .build()?;
//! engine.run()?;
//! assert!(*fails.lock().unwrap() > 0, "the format violator must fail");
//! # anyhow::Ok(())
//! ```

use std::fmt;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use super::run::{PeerRoundStats, RoundRecord, RunMetrics};
use crate::chain::Uid;
use crate::minjson::{self, fnum, read_f64, Value};

/// One thing the round pipeline decided, timestamped with its round.
///
/// Every variant carries `round` so observers can stay stateless; the
/// engine brackets each round with [`RoundEvent::RoundStarted`] /
/// [`RoundEvent::RoundCompleted`]. Lifecycle events triggered *between*
/// rounds (a direct `register_peer` call from driver code) are emitted
/// immediately, stamped with the round that will consume them.
#[derive(Clone, Debug, PartialEq)]
pub enum RoundEvent {
    /// Top of the round, before any scenario event fires.
    RoundStarted { round: u64 },
    /// A peer registered (round-0 population, scenario join, or a direct
    /// `register_peer` call): slot semantics included.
    PeerRegistered {
        round: u64,
        uid: Uid,
        label: String,
        recycled: bool,
        evicted_hotkey: Option<String>,
    },
    /// A peer deregistered, freeing its slot.
    PeerDeregistered { round: u64, uid: Uid },
    /// A scenario stake move landed.
    StakeSet { round: u64, uid: Uid, amount: f64 },
    /// A scripted provider outage began (PUTs fail with `prob`).
    OutageStarted { round: u64, prob: f64, until_round: u64 },
    /// The provider recovered from a scripted outage.
    OutageEnded { round: u64 },
    /// A scripted chaos window opened: read-path faults of `kind`
    /// (`"get-fail"` or `"corrupt"`) fire with probability `prob`.
    ChaosStarted { round: u64, kind: String, prob: f64, until_round: u64 },
    /// A chaos window closed; the read path is clean again for `kind`.
    ChaosEnded { round: u64, kind: String },
    /// A scripted eclipse began: `validator` cannot read `peer`'s bucket.
    EclipseStarted { round: u64, validator: Uid, peer: Uid, until_round: u64 },
    /// An eclipse lifted: `validator` sees `peer`'s bucket again.
    EclipseEnded { round: u64, validator: Uid, peer: Uid },
    /// A scripted event was rejected (e.g. `leave` on a validator uid);
    /// the run continues.
    ScenarioRejected { round: u64, description: String },
    /// Runners dropped because their uids vanished from the chain registry
    /// (evictions by registration pressure).
    RunnersDropped { round: u64, count: usize },
    /// The round began with a full-parameter checkpoint.
    Checkpointed { round: u64 },
    /// A peer took its turn: local training diagnostics.
    PeerTurn {
        round: u64,
        uid: Uid,
        label: String,
        second_pass: bool,
        local_loss: f64,
        tokens: u64,
    },
    /// A peer's submission PUT resolved against the storage provider.
    PutApplied { round: u64, uid: Uid, accepted: bool },
    /// A storage operation on peer `uid`'s bucket spent bounded retries on
    /// transient faults before resolving. `actor` is the party driving the
    /// operation: a validator for submission GETs, the peer itself for its
    /// submission PUT. Emitted by the coordinator in deterministic
    /// peer/validator order — never from worker threads.
    StorageRetry { round: u64, actor: Uid, uid: Uid, retries: u32 },
    /// A validator could not read peer `uid`'s submission at all (retry
    /// budget exhausted, or an eclipsed view): the submission is scored as
    /// a miss instead of aborting the round.
    SubmissionUnavailable { round: u64, validator: Uid, uid: Uid },
    /// The lead validator's aggregate publication write failed even after
    /// retries; the round degraded to re-publishing the previous
    /// checkpoint instead of the fresh aggregate.
    AggregationDegraded { round: u64, attempts: u32 },
    /// One validator's fast-evaluation verdict for one peer (§3.2), with
    /// the phi multiplier applied to the peer's PoC EMA.
    FastEval { round: u64, validator: Uid, uid: Uid, passed: bool, phi: f64 },
    /// One primary evaluation (§3.1): LossScores on assigned + random data.
    PrimaryEval {
        round: u64,
        validator: Uid,
        uid: Uid,
        score_assigned: f64,
        score_rand: f64,
    },
    /// The validator ranked this round's sampled peers and updated their
    /// OpenSkill ratings (the `OpenSkillMatch` step of Algorithm 1).
    RatingMatch { round: u64, validator: Uid, uids: Vec<Uid> },
    /// The validator committed (or was barred from committing) its weight
    /// vector to the chain.
    WeightsCommitted { round: u64, validator: Uid, committed: bool },
    /// The chain ran a Yuma epoch; `incentives` is the consensus payout.
    YumaEpoch { round: u64, incentives: Vec<(Uid, f64)> },
    /// The lead validator's top-G weights drove DeMo aggregation.
    Aggregated { round: u64, top_g: Vec<Uid>, n_valid: usize, had_update: bool },
    /// Held-out loss was evaluated on the post-aggregation model.
    HeldoutEval { round: u64, loss: f64 },
    /// End-of-round scoreboard entry for one peer (lead validator's view).
    PeerScoreboard { round: u64, stats: PeerRoundStats },
    /// The round finished; all of its events have been published.
    RoundCompleted { round: u64 },
}

impl RoundEvent {
    /// The round this event belongs to.
    pub fn round(&self) -> u64 {
        match self {
            RoundEvent::RoundStarted { round }
            | RoundEvent::PeerRegistered { round, .. }
            | RoundEvent::PeerDeregistered { round, .. }
            | RoundEvent::StakeSet { round, .. }
            | RoundEvent::OutageStarted { round, .. }
            | RoundEvent::OutageEnded { round }
            | RoundEvent::ChaosStarted { round, .. }
            | RoundEvent::ChaosEnded { round, .. }
            | RoundEvent::EclipseStarted { round, .. }
            | RoundEvent::EclipseEnded { round, .. }
            | RoundEvent::ScenarioRejected { round, .. }
            | RoundEvent::RunnersDropped { round, .. }
            | RoundEvent::Checkpointed { round }
            | RoundEvent::PeerTurn { round, .. }
            | RoundEvent::PutApplied { round, .. }
            | RoundEvent::StorageRetry { round, .. }
            | RoundEvent::SubmissionUnavailable { round, .. }
            | RoundEvent::AggregationDegraded { round, .. }
            | RoundEvent::FastEval { round, .. }
            | RoundEvent::PrimaryEval { round, .. }
            | RoundEvent::RatingMatch { round, .. }
            | RoundEvent::WeightsCommitted { round, .. }
            | RoundEvent::YumaEpoch { round, .. }
            | RoundEvent::Aggregated { round, .. }
            | RoundEvent::HeldoutEval { round, .. }
            | RoundEvent::PeerScoreboard { round, .. }
            | RoundEvent::RoundCompleted { round } => *round,
        }
    }

    /// Whether this is a population/lifecycle event — the subset that
    /// [`RoundRecord::events`] records as human-readable lines. Chaos and
    /// eclipse *window boundaries* qualify (they fire once per window);
    /// the high-frequency fault telemetry (`StorageRetry`,
    /// `SubmissionUnavailable`) deliberately does not — a chaos-window
    /// interior must not flood every round's record.
    pub fn is_lifecycle(&self) -> bool {
        matches!(
            self,
            RoundEvent::PeerRegistered { .. }
                | RoundEvent::PeerDeregistered { .. }
                | RoundEvent::StakeSet { .. }
                | RoundEvent::OutageStarted { .. }
                | RoundEvent::OutageEnded { .. }
                | RoundEvent::ChaosStarted { .. }
                | RoundEvent::ChaosEnded { .. }
                | RoundEvent::EclipseStarted { .. }
                | RoundEvent::EclipseEnded { .. }
                | RoundEvent::ScenarioRejected { .. }
                | RoundEvent::RunnersDropped { .. }
                | RoundEvent::AggregationDegraded { .. }
        )
    }

    /// Serialize as one JSON value (the [`JsonlTraceObserver`] line
    /// format). Round-trips bit-exactly through [`RoundEvent::from_json`],
    /// including NaN diagnostics (see [`minjson::fnum`]).
    pub fn to_json(&self) -> Value {
        let uid_pairs = |xs: &[(Uid, f64)]| {
            Value::Arr(
                xs.iter()
                    .map(|(u, x)| Value::Arr(vec![minjson::num(*u as f64), fnum(*x)]))
                    .collect(),
            )
        };
        let uids = |xs: &[Uid]| {
            Value::Arr(xs.iter().map(|u| minjson::num(*u as f64)).collect())
        };
        match self {
            RoundEvent::RoundStarted { round } => minjson::obj(vec![
                ("ev", minjson::s("round_started")),
                ("round", minjson::num(*round as f64)),
            ]),
            RoundEvent::PeerRegistered { round, uid, label, recycled, evicted_hotkey } => {
                minjson::obj(vec![
                    ("ev", minjson::s("peer_registered")),
                    ("round", minjson::num(*round as f64)),
                    ("uid", minjson::num(*uid as f64)),
                    ("label", minjson::s(label)),
                    ("recycled", Value::Bool(*recycled)),
                    (
                        "evicted_hotkey",
                        evicted_hotkey.as_deref().map(minjson::s).unwrap_or(Value::Null),
                    ),
                ])
            }
            RoundEvent::PeerDeregistered { round, uid } => minjson::obj(vec![
                ("ev", minjson::s("peer_deregistered")),
                ("round", minjson::num(*round as f64)),
                ("uid", minjson::num(*uid as f64)),
            ]),
            RoundEvent::StakeSet { round, uid, amount } => minjson::obj(vec![
                ("ev", minjson::s("stake_set")),
                ("round", minjson::num(*round as f64)),
                ("uid", minjson::num(*uid as f64)),
                ("amount", fnum(*amount)),
            ]),
            RoundEvent::OutageStarted { round, prob, until_round } => minjson::obj(vec![
                ("ev", minjson::s("outage_started")),
                ("round", minjson::num(*round as f64)),
                ("prob", fnum(*prob)),
                ("until_round", minjson::num(*until_round as f64)),
            ]),
            RoundEvent::OutageEnded { round } => minjson::obj(vec![
                ("ev", minjson::s("outage_ended")),
                ("round", minjson::num(*round as f64)),
            ]),
            RoundEvent::ChaosStarted { round, kind, prob, until_round } => minjson::obj(vec![
                ("ev", minjson::s("chaos_started")),
                ("round", minjson::num(*round as f64)),
                ("kind", minjson::s(kind)),
                ("prob", fnum(*prob)),
                ("until_round", minjson::num(*until_round as f64)),
            ]),
            RoundEvent::ChaosEnded { round, kind } => minjson::obj(vec![
                ("ev", minjson::s("chaos_ended")),
                ("round", minjson::num(*round as f64)),
                ("kind", minjson::s(kind)),
            ]),
            RoundEvent::EclipseStarted { round, validator, peer, until_round } => {
                minjson::obj(vec![
                    ("ev", minjson::s("eclipse_started")),
                    ("round", minjson::num(*round as f64)),
                    ("validator", minjson::num(*validator as f64)),
                    ("peer", minjson::num(*peer as f64)),
                    ("until_round", minjson::num(*until_round as f64)),
                ])
            }
            RoundEvent::EclipseEnded { round, validator, peer } => minjson::obj(vec![
                ("ev", minjson::s("eclipse_ended")),
                ("round", minjson::num(*round as f64)),
                ("validator", minjson::num(*validator as f64)),
                ("peer", minjson::num(*peer as f64)),
            ]),
            RoundEvent::ScenarioRejected { round, description } => minjson::obj(vec![
                ("ev", minjson::s("scenario_rejected")),
                ("round", minjson::num(*round as f64)),
                ("description", minjson::s(description)),
            ]),
            RoundEvent::RunnersDropped { round, count } => minjson::obj(vec![
                ("ev", minjson::s("runners_dropped")),
                ("round", minjson::num(*round as f64)),
                ("count", minjson::num(*count as f64)),
            ]),
            RoundEvent::Checkpointed { round } => minjson::obj(vec![
                ("ev", minjson::s("checkpointed")),
                ("round", minjson::num(*round as f64)),
            ]),
            RoundEvent::PeerTurn { round, uid, label, second_pass, local_loss, tokens } => {
                minjson::obj(vec![
                    ("ev", minjson::s("peer_turn")),
                    ("round", minjson::num(*round as f64)),
                    ("uid", minjson::num(*uid as f64)),
                    ("label", minjson::s(label)),
                    ("second_pass", Value::Bool(*second_pass)),
                    ("local_loss", fnum(*local_loss)),
                    ("tokens", minjson::num(*tokens as f64)),
                ])
            }
            RoundEvent::PutApplied { round, uid, accepted } => minjson::obj(vec![
                ("ev", minjson::s("put_applied")),
                ("round", minjson::num(*round as f64)),
                ("uid", minjson::num(*uid as f64)),
                ("accepted", Value::Bool(*accepted)),
            ]),
            RoundEvent::StorageRetry { round, actor, uid, retries } => minjson::obj(vec![
                ("ev", minjson::s("storage_retry")),
                ("round", minjson::num(*round as f64)),
                ("actor", minjson::num(*actor as f64)),
                ("uid", minjson::num(*uid as f64)),
                ("retries", minjson::num(*retries as f64)),
            ]),
            RoundEvent::SubmissionUnavailable { round, validator, uid } => minjson::obj(vec![
                ("ev", minjson::s("submission_unavailable")),
                ("round", minjson::num(*round as f64)),
                ("validator", minjson::num(*validator as f64)),
                ("uid", minjson::num(*uid as f64)),
            ]),
            RoundEvent::AggregationDegraded { round, attempts } => minjson::obj(vec![
                ("ev", minjson::s("aggregation_degraded")),
                ("round", minjson::num(*round as f64)),
                ("attempts", minjson::num(*attempts as f64)),
            ]),
            RoundEvent::FastEval { round, validator, uid, passed, phi } => minjson::obj(vec![
                ("ev", minjson::s("fast_eval")),
                ("round", minjson::num(*round as f64)),
                ("validator", minjson::num(*validator as f64)),
                ("uid", minjson::num(*uid as f64)),
                ("passed", Value::Bool(*passed)),
                ("phi", fnum(*phi)),
            ]),
            RoundEvent::PrimaryEval { round, validator, uid, score_assigned, score_rand } => {
                minjson::obj(vec![
                    ("ev", minjson::s("primary_eval")),
                    ("round", minjson::num(*round as f64)),
                    ("validator", minjson::num(*validator as f64)),
                    ("uid", minjson::num(*uid as f64)),
                    ("score_assigned", fnum(*score_assigned)),
                    ("score_rand", fnum(*score_rand)),
                ])
            }
            RoundEvent::RatingMatch { round, validator, uids: us } => minjson::obj(vec![
                ("ev", minjson::s("rating_match")),
                ("round", minjson::num(*round as f64)),
                ("validator", minjson::num(*validator as f64)),
                ("uids", uids(us)),
            ]),
            RoundEvent::WeightsCommitted { round, validator, committed } => minjson::obj(vec![
                ("ev", minjson::s("weights_committed")),
                ("round", minjson::num(*round as f64)),
                ("validator", minjson::num(*validator as f64)),
                ("committed", Value::Bool(*committed)),
            ]),
            RoundEvent::YumaEpoch { round, incentives } => minjson::obj(vec![
                ("ev", minjson::s("yuma_epoch")),
                ("round", minjson::num(*round as f64)),
                ("incentives", uid_pairs(incentives)),
            ]),
            RoundEvent::Aggregated { round, top_g, n_valid, had_update } => minjson::obj(vec![
                ("ev", minjson::s("aggregated")),
                ("round", minjson::num(*round as f64)),
                ("top_g", uids(top_g)),
                ("n_valid", minjson::num(*n_valid as f64)),
                ("had_update", Value::Bool(*had_update)),
            ]),
            RoundEvent::HeldoutEval { round, loss } => minjson::obj(vec![
                ("ev", minjson::s("heldout_eval")),
                ("round", minjson::num(*round as f64)),
                ("loss", fnum(*loss)),
            ]),
            RoundEvent::PeerScoreboard { round, stats } => minjson::obj(vec![
                ("ev", minjson::s("peer_scoreboard")),
                ("round", minjson::num(*round as f64)),
                ("stats", stats.to_json()),
            ]),
            RoundEvent::RoundCompleted { round } => minjson::obj(vec![
                ("ev", minjson::s("round_completed")),
                ("round", minjson::num(*round as f64)),
            ]),
        }
    }

    /// Parse one trace line back into an event (see [`RoundEvent::to_json`]).
    pub fn from_json(v: &Value) -> Result<RoundEvent> {
        use crate::minjson::field;
        fn round(v: &Value) -> Result<u64> {
            v.get("round")
                .as_f64()
                .map(|r| r as u64)
                .context("event missing \"round\"")
        }
        fn uid_of(v: &Value, key: &str) -> Result<Uid> {
            v.get(key)
                .as_usize()
                .map(|u| u as Uid)
                .with_context(|| format!("event missing {key:?}"))
        }
        fn uids_of(v: &Value, key: &str) -> Result<Vec<Uid>> {
            v.get(key)
                .as_arr()
                .with_context(|| format!("event missing {key:?}"))?
                .iter()
                .map(|u| u.as_usize().map(|u| u as Uid).context("bad uid"))
                .collect()
        }
        fn uid_pairs_of(v: &Value, key: &str) -> Result<Vec<(Uid, f64)>> {
            v.get(key)
                .as_arr()
                .with_context(|| format!("event missing {key:?}"))?
                .iter()
                .map(|p| {
                    let pair = p.as_arr().context("expected [uid, value]")?;
                    let u = pair
                        .first()
                        .and_then(|u| u.as_usize())
                        .context("bad uid in pair")?;
                    let x = pair.get(1).and_then(read_f64).context("bad value in pair")?;
                    Ok((u as Uid, x))
                })
                .collect()
        }

        let kind = v.get("ev").as_str().context("event missing \"ev\" kind")?;
        Ok(match kind {
            "round_started" => RoundEvent::RoundStarted { round: round(v)? },
            "peer_registered" => RoundEvent::PeerRegistered {
                round: round(v)?,
                uid: uid_of(v, "uid")?,
                label: field::string(v, "label")?,
                recycled: field::boolean(v, "recycled")?,
                evicted_hotkey: v.get("evicted_hotkey").as_str().map(str::to_string),
            },
            "peer_deregistered" => RoundEvent::PeerDeregistered {
                round: round(v)?,
                uid: uid_of(v, "uid")?,
            },
            "stake_set" => RoundEvent::StakeSet {
                round: round(v)?,
                uid: uid_of(v, "uid")?,
                amount: field::f64(v, "amount")?,
            },
            "outage_started" => RoundEvent::OutageStarted {
                round: round(v)?,
                prob: field::f64(v, "prob")?,
                until_round: v.get("until_round").as_f64().context("until_round")? as u64,
            },
            "outage_ended" => RoundEvent::OutageEnded { round: round(v)? },
            "chaos_started" => RoundEvent::ChaosStarted {
                round: round(v)?,
                kind: field::string(v, "kind")?,
                prob: field::f64(v, "prob")?,
                until_round: v.get("until_round").as_f64().context("until_round")? as u64,
            },
            "chaos_ended" => RoundEvent::ChaosEnded {
                round: round(v)?,
                kind: field::string(v, "kind")?,
            },
            "eclipse_started" => RoundEvent::EclipseStarted {
                round: round(v)?,
                validator: uid_of(v, "validator")?,
                peer: uid_of(v, "peer")?,
                until_round: v.get("until_round").as_f64().context("until_round")? as u64,
            },
            "eclipse_ended" => RoundEvent::EclipseEnded {
                round: round(v)?,
                validator: uid_of(v, "validator")?,
                peer: uid_of(v, "peer")?,
            },
            "scenario_rejected" => RoundEvent::ScenarioRejected {
                round: round(v)?,
                description: field::string(v, "description")?,
            },
            "runners_dropped" => RoundEvent::RunnersDropped {
                round: round(v)?,
                count: v.get("count").as_usize().context("count")?,
            },
            "checkpointed" => RoundEvent::Checkpointed { round: round(v)? },
            "peer_turn" => RoundEvent::PeerTurn {
                round: round(v)?,
                uid: uid_of(v, "uid")?,
                label: field::string(v, "label")?,
                second_pass: field::boolean(v, "second_pass")?,
                local_loss: field::f64(v, "local_loss")?,
                tokens: v.get("tokens").as_f64().context("tokens")? as u64,
            },
            "put_applied" => RoundEvent::PutApplied {
                round: round(v)?,
                uid: uid_of(v, "uid")?,
                accepted: field::boolean(v, "accepted")?,
            },
            "storage_retry" => RoundEvent::StorageRetry {
                round: round(v)?,
                actor: uid_of(v, "actor")?,
                uid: uid_of(v, "uid")?,
                retries: v.get("retries").as_usize().context("retries")? as u32,
            },
            "submission_unavailable" => RoundEvent::SubmissionUnavailable {
                round: round(v)?,
                validator: uid_of(v, "validator")?,
                uid: uid_of(v, "uid")?,
            },
            "aggregation_degraded" => RoundEvent::AggregationDegraded {
                round: round(v)?,
                attempts: v.get("attempts").as_usize().context("attempts")? as u32,
            },
            "fast_eval" => RoundEvent::FastEval {
                round: round(v)?,
                validator: uid_of(v, "validator")?,
                uid: uid_of(v, "uid")?,
                passed: field::boolean(v, "passed")?,
                phi: field::f64(v, "phi")?,
            },
            "primary_eval" => RoundEvent::PrimaryEval {
                round: round(v)?,
                validator: uid_of(v, "validator")?,
                uid: uid_of(v, "uid")?,
                score_assigned: field::f64(v, "score_assigned")?,
                score_rand: field::f64(v, "score_rand")?,
            },
            "rating_match" => RoundEvent::RatingMatch {
                round: round(v)?,
                validator: uid_of(v, "validator")?,
                uids: uids_of(v, "uids")?,
            },
            "weights_committed" => RoundEvent::WeightsCommitted {
                round: round(v)?,
                validator: uid_of(v, "validator")?,
                committed: field::boolean(v, "committed")?,
            },
            "yuma_epoch" => RoundEvent::YumaEpoch {
                round: round(v)?,
                incentives: uid_pairs_of(v, "incentives")?,
            },
            "aggregated" => RoundEvent::Aggregated {
                round: round(v)?,
                top_g: uids_of(v, "top_g")?,
                n_valid: v.get("n_valid").as_usize().context("n_valid")?,
                had_update: field::boolean(v, "had_update")?,
            },
            "heldout_eval" => RoundEvent::HeldoutEval {
                round: round(v)?,
                loss: field::f64(v, "loss")?,
            },
            "peer_scoreboard" => RoundEvent::PeerScoreboard {
                round: round(v)?,
                stats: PeerRoundStats::from_json(v.get("stats"))?,
            },
            "round_completed" => RoundEvent::RoundCompleted { round: round(v)? },
            other => anyhow::bail!("unknown event kind {other:?}"),
        })
    }
}

/// Lifecycle events render as the human-readable lines that
/// [`RoundRecord::events`] has always carried (CLI output and the churn
/// tests pin these exact strings). Non-lifecycle events render as a terse
/// diagnostic form.
impl fmt::Display for RoundEvent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RoundEvent::PeerRegistered { uid, label, recycled, evicted_hotkey, .. } => {
                write!(f, "join {label} as uid {uid}")?;
                if let Some(hk) = evicted_hotkey {
                    write!(f, " (evicted {hk})")?;
                } else if *recycled {
                    write!(f, " (recycled uid)")?;
                }
                Ok(())
            }
            RoundEvent::PeerDeregistered { uid, .. } => write!(f, "uid {uid} left"),
            RoundEvent::StakeSet { uid, amount, .. } => {
                write!(f, "stake of uid {uid} set to {amount}")
            }
            RoundEvent::OutageStarted { prob, until_round, .. } => {
                write!(f, "provider outage p={prob} until round {until_round}")
            }
            RoundEvent::OutageEnded { .. } => write!(f, "provider recovered"),
            RoundEvent::ChaosStarted { kind, prob, until_round, .. } => {
                write!(f, "chaos {kind} p={prob} until round {until_round}")
            }
            RoundEvent::ChaosEnded { kind, .. } => write!(f, "chaos {kind} cleared"),
            RoundEvent::EclipseStarted { validator, peer, until_round, .. } => {
                write!(f, "validator {validator} eclipsed from peer {peer} until round {until_round}")
            }
            RoundEvent::EclipseEnded { validator, peer, .. } => {
                write!(f, "validator {validator} sees peer {peer} again")
            }
            RoundEvent::AggregationDegraded { attempts, .. } => {
                write!(f, "aggregate publication failed after {attempts} attempt(s); republished previous checkpoint")
            }
            RoundEvent::ScenarioRejected { description, .. } => write!(f, "{description}"),
            RoundEvent::RunnersDropped { count, .. } => {
                write!(f, "{count} runner(s) dropped by registry resolution")
            }
            other => write!(f, "{other:?}"),
        }
    }
}

/// A subscriber to the round-event stream.
///
/// Events arrive on the coordinator thread, one at a time, in the
/// deterministic order documented on [the module](self). `on_event` takes
/// `&self` so observers can be shared (`Arc`) between the engine and the
/// driver that later reads them — use interior mutability for state, as
/// [`MetricsObserver`] does.
pub trait Observer: Send + Sync {
    fn on_event(&self, event: &RoundEvent);
}

struct FnObserver<F: Fn(&RoundEvent) + Send + Sync>(F);

impl<F: Fn(&RoundEvent) + Send + Sync> Observer for FnObserver<F> {
    fn on_event(&self, event: &RoundEvent) {
        (self.0)(event)
    }
}

/// Wrap a closure as an [`Observer`] (see the module example).
pub fn observer_fn<F: Fn(&RoundEvent) + Send + Sync + 'static>(f: F) -> Arc<dyn Observer> {
    Arc::new(FnObserver(f))
}

/// In-flight accumulation for the round currently being observed.
#[derive(Default)]
struct PartialRound {
    round: u64,
    events: Vec<String>,
    local_losses: Vec<f64>,
    tokens: u64,
    n_valid: usize,
    top_g: Vec<Uid>,
    heldout: Option<f64>,
    peers: Vec<PeerRoundStats>,
}

#[derive(Default)]
struct MetricsState {
    metrics: RunMetrics,
    cur: Option<PartialRound>,
    /// Lifecycle events emitted between rounds (direct `register_peer` /
    /// `deregister_peer` calls) — folded into the next round's record.
    pending_events: Vec<String>,
}

/// The built-in observer that assembles [`RoundRecord`] / [`RunMetrics`]
/// from the event stream — the only place in the crate that does.
#[derive(Default)]
pub struct MetricsObserver {
    state: Mutex<MetricsState>,
}

impl MetricsObserver {
    pub fn new() -> Self {
        Self::default()
    }

    /// A shareable handle: hand one clone to
    /// [`GauntletBuilder::observer`](super::engine::GauntletBuilder::observer)
    /// and keep the other to read the metrics afterwards.
    pub fn shared() -> Arc<Self> {
        Arc::new(Self::new())
    }

    /// A clone of everything recorded so far.
    pub fn metrics(&self) -> RunMetrics {
        self.state.lock().unwrap().metrics.clone()
    }

    /// The most recently completed round's record.
    pub fn last_record(&self) -> Option<RoundRecord> {
        self.state.lock().unwrap().metrics.rounds.last().cloned()
    }

    /// Number of completed rounds recorded.
    pub fn n_rounds(&self) -> usize {
        self.state.lock().unwrap().metrics.rounds.len()
    }

    /// Clone only the records from index `start` on (what a `run()` call
    /// uses to report its own rounds without copying the whole history).
    pub fn records_since(&self, start: usize) -> Vec<RoundRecord> {
        let st = self.state.lock().unwrap();
        st.metrics.rounds.get(start..).unwrap_or(&[]).to_vec()
    }

    /// Lifecycle event lines received outside a round bracket, waiting to
    /// be folded into the next round's record (snapshot capture).
    pub fn pending_events(&self) -> Vec<String> {
        self.state.lock().unwrap().pending_events.clone()
    }

    /// Seed pending lifecycle lines (snapshot restore), so a resumed run's
    /// next [`RoundRecord::events`] matches the uninterrupted run even
    /// when a direct `register_peer`/`deregister_peer` immediately
    /// preceded the snapshot.
    pub fn push_pending(&self, lines: Vec<String>) {
        self.state.lock().unwrap().pending_events.extend(lines);
    }

    /// Move the accumulated metrics out, leaving an empty record.
    ///
    /// The observer otherwise accumulates one [`RoundRecord`] (with full
    /// per-peer stats) per round for the life of the run — for very long
    /// runs, drain it periodically with this (the engine's own
    /// `run_round()` only ever reads the latest record).
    pub fn take(&self) -> RunMetrics {
        std::mem::take(&mut self.state.lock().unwrap().metrics)
    }
}

impl Observer for MetricsObserver {
    fn on_event(&self, event: &RoundEvent) {
        let mut guard = self.state.lock().unwrap();
        // One reborrow up front so the borrow checker sees plain disjoint
        // field accesses instead of repeated MutexGuard derefs.
        let st: &mut MetricsState = &mut guard;
        match event {
            RoundEvent::RoundStarted { round } => {
                let events = std::mem::take(&mut st.pending_events);
                st.cur = Some(PartialRound { round: *round, events, ..Default::default() });
            }
            ev if ev.is_lifecycle() => {
                let line = ev.to_string();
                match st.cur.as_mut() {
                    Some(cur) => cur.events.push(line),
                    None => st.pending_events.push(line),
                }
            }
            RoundEvent::PeerTurn { second_pass, local_loss, tokens, .. } => {
                if let Some(cur) = st.cur.as_mut() {
                    if !second_pass {
                        if local_loss.is_finite() {
                            cur.local_losses.push(*local_loss);
                        }
                        cur.tokens += tokens;
                    }
                }
            }
            RoundEvent::Aggregated { top_g, n_valid, .. } => {
                if let Some(cur) = st.cur.as_mut() {
                    cur.top_g = top_g.clone();
                    cur.n_valid = *n_valid;
                }
            }
            RoundEvent::HeldoutEval { loss, .. } => {
                if let Some(cur) = st.cur.as_mut() {
                    cur.heldout = Some(*loss);
                }
            }
            RoundEvent::PeerScoreboard { stats, .. } => {
                if let Some(cur) = st.cur.as_mut() {
                    cur.peers.push(stats.clone());
                }
            }
            RoundEvent::RoundCompleted { .. } => {
                if let Some(cur) = st.cur.take() {
                    st.metrics.rounds.push(RoundRecord {
                        round: cur.round,
                        heldout_loss: cur.heldout,
                        mean_local_loss: crate::util::mean(&cur.local_losses),
                        n_valid_submissions: cur.n_valid,
                        top_g: cur.top_g,
                        peers: cur.peers,
                        tokens_processed: cur.tokens,
                        events: cur.events,
                    });
                }
            }
            _ => {}
        }
    }
}

struct TraceSink {
    writer: BufWriter<std::fs::File>,
    failed: bool,
}

/// Writes every event as one JSON line (JSONL) to a trace file — a
/// replayable record of the whole run. [`replay_trace`] feeds such a file
/// back through a [`MetricsObserver`] and reproduces the identical
/// [`RunMetrics`].
///
/// I/O errors cannot propagate through the observer interface; the first
/// failure is reported to stderr and the trace disabled (the run itself is
/// never interrupted by a full disk).
pub struct JsonlTraceObserver {
    sink: Mutex<TraceSink>,
}

impl JsonlTraceObserver {
    /// Create (truncate) `path` and stream events into it.
    pub fn create(path: impl AsRef<Path>) -> Result<Arc<Self>> {
        let file = std::fs::File::create(path.as_ref())
            .with_context(|| format!("creating trace file {:?}", path.as_ref()))?;
        Ok(Arc::new(JsonlTraceObserver {
            sink: Mutex::new(TraceSink { writer: BufWriter::new(file), failed: false }),
        }))
    }

    /// Flush buffered lines to disk.
    pub fn flush(&self) -> Result<()> {
        self.sink.lock().unwrap().writer.flush().context("flushing trace file")
    }
}

impl Observer for JsonlTraceObserver {
    fn on_event(&self, event: &RoundEvent) {
        let mut sink = self.sink.lock().unwrap();
        if sink.failed {
            return;
        }
        let line = event.to_json().write();
        let res = writeln!(sink.writer, "{line}").and_then(|_| {
            if matches!(event, RoundEvent::RoundCompleted { .. }) {
                sink.writer.flush()
            } else {
                Ok(())
            }
        });
        if let Err(e) = res {
            sink.failed = true;
            eprintln!("warning: trace file write failed ({e}); tracing disabled");
        }
    }
}

/// Parse a JSONL trace file back into its event stream.
pub fn read_trace(path: impl AsRef<Path>) -> Result<Vec<RoundEvent>> {
    let text = std::fs::read_to_string(path.as_ref())
        .with_context(|| format!("reading trace file {:?}", path.as_ref()))?;
    text.lines()
        .enumerate()
        .filter(|(_, l)| !l.trim().is_empty())
        .map(|(i, l)| {
            let v = Value::parse(l).map_err(|e| anyhow::anyhow!("trace line {}: {e}", i + 1))?;
            RoundEvent::from_json(&v).with_context(|| format!("trace line {}", i + 1))
        })
        .collect()
}

/// Replay a JSONL trace through a fresh [`MetricsObserver`]: the returned
/// metrics are identical to what the original run's metrics observer
/// produced (the acceptance contract of the event stream).
pub fn replay_trace(path: impl AsRef<Path>) -> Result<RunMetrics> {
    let obs = MetricsObserver::new();
    for ev in read_trace(path)? {
        obs.on_event(&ev);
    }
    Ok(obs.take())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_events() -> Vec<RoundEvent> {
        vec![
            RoundEvent::RoundStarted { round: 3 },
            RoundEvent::PeerRegistered {
                round: 3,
                uid: 7,
                label: "honest".into(),
                recycled: true,
                evicted_hotkey: Some("peer-hotkey-2".into()),
            },
            RoundEvent::PeerDeregistered { round: 3, uid: 4 },
            RoundEvent::StakeSet { round: 3, uid: 0, amount: 500.0 },
            RoundEvent::OutageStarted { round: 3, prob: 0.5, until_round: 5 },
            RoundEvent::OutageEnded { round: 3 },
            RoundEvent::ChaosStarted {
                round: 3,
                kind: "get-fail".into(),
                prob: 0.25,
                until_round: 6,
            },
            RoundEvent::ChaosEnded { round: 3, kind: "corrupt".into() },
            RoundEvent::EclipseStarted { round: 3, validator: 0, peer: 7, until_round: 5 },
            RoundEvent::EclipseEnded { round: 3, validator: 0, peer: 7 },
            RoundEvent::StorageRetry { round: 3, actor: 0, uid: 7, retries: 2 },
            RoundEvent::SubmissionUnavailable { round: 3, validator: 0, uid: 7 },
            RoundEvent::AggregationDegraded { round: 3, attempts: 3 },
            RoundEvent::ScenarioRejected { round: 3, description: "leave uid 0 rejected".into() },
            RoundEvent::RunnersDropped { round: 3, count: 2 },
            RoundEvent::Checkpointed { round: 3 },
            RoundEvent::PeerTurn {
                round: 3,
                uid: 7,
                label: "honest".into(),
                second_pass: false,
                local_loss: f64::NAN,
                tokens: 64,
            },
            RoundEvent::PutApplied { round: 3, uid: 7, accepted: true },
            RoundEvent::FastEval { round: 3, validator: 0, uid: 7, passed: false, phi: 0.75 },
            RoundEvent::PrimaryEval {
                round: 3,
                validator: 0,
                uid: 7,
                score_assigned: 0.25,
                score_rand: -0.0,
            },
            RoundEvent::RatingMatch { round: 3, validator: 0, uids: vec![7, 8] },
            RoundEvent::WeightsCommitted { round: 3, validator: 0, committed: true },
            RoundEvent::YumaEpoch { round: 3, incentives: vec![(7, 0.75), (8, 0.25)] },
            RoundEvent::Aggregated { round: 3, top_g: vec![7], n_valid: 2, had_update: true },
            RoundEvent::HeldoutEval { round: 3, loss: 4.125 },
            RoundEvent::RoundCompleted { round: 3 },
        ]
    }

    #[test]
    fn every_event_kind_roundtrips_through_json() {
        for ev in sample_events() {
            let text = ev.to_json().write();
            let back = RoundEvent::from_json(&Value::parse(&text).unwrap()).unwrap();
            // NaN != NaN breaks derived PartialEq; compare re-serialized.
            assert_eq!(text, back.to_json().write(), "{ev:?}");
            assert_eq!(ev.round(), back.round());
        }
    }

    #[test]
    fn lifecycle_display_matches_the_pinned_strings() {
        let evs = sample_events();
        assert_eq!(evs[1].to_string(), "join honest as uid 7 (evicted peer-hotkey-2)");
        assert_eq!(evs[2].to_string(), "uid 4 left");
        assert_eq!(evs[3].to_string(), "stake of uid 0 set to 500");
        assert_eq!(evs[4].to_string(), "provider outage p=0.5 until round 5");
        assert_eq!(evs[5].to_string(), "provider recovered");
        assert_eq!(evs[6].to_string(), "chaos get-fail p=0.25 until round 6");
        assert_eq!(evs[7].to_string(), "chaos corrupt cleared");
        assert_eq!(evs[8].to_string(), "validator 0 eclipsed from peer 7 until round 5");
        assert_eq!(evs[9].to_string(), "validator 0 sees peer 7 again");
        assert_eq!(
            evs[12].to_string(),
            "aggregate publication failed after 3 attempt(s); republished previous checkpoint"
        );
        assert_eq!(evs[14].to_string(), "2 runner(s) dropped by registry resolution");
        let plain = RoundEvent::PeerRegistered {
            round: 0,
            uid: 2,
            label: "poisoner".into(),
            recycled: true,
            evicted_hotkey: None,
        };
        assert_eq!(plain.to_string(), "join poisoner as uid 2 (recycled uid)");
    }

    #[test]
    fn metrics_observer_assembles_a_round_record() {
        let obs = MetricsObserver::new();
        // A lifecycle event before the bracket lands in the next record.
        obs.on_event(&RoundEvent::PeerDeregistered { round: 3, uid: 9 });
        for ev in sample_events() {
            obs.on_event(&ev);
        }
        let m = obs.metrics();
        assert_eq!(m.rounds.len(), 1);
        let r = &m.rounds[0];
        assert_eq!(r.round, 3);
        assert_eq!(r.events[0], "uid 9 left", "pending event folded in first");
        assert_eq!(r.n_valid_submissions, 2);
        assert_eq!(r.top_g, vec![7]);
        assert_eq!(r.heldout_loss, Some(4.125));
        assert_eq!(r.tokens_processed, 64);
        assert_eq!(r.mean_local_loss, 0.0, "NaN local loss excluded from the mean");
        assert_eq!(obs.last_record().unwrap().round, 3);
        assert_eq!(obs.n_rounds(), 1);
    }

    #[test]
    fn unknown_event_kind_is_rejected() {
        let v = Value::parse(r#"{"ev":"warp_drive","round":1}"#).unwrap();
        assert!(RoundEvent::from_json(&v).is_err());
    }
}
