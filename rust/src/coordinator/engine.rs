//! The library's front door: a fluent [`GauntletBuilder`] that assembles a
//! [`GauntletEngine`] — the backend-agnostic facade over the full Templar
//! system (chain + storage + peers + validators + DeMo aggregation).
//!
//! The Gauntlet mechanism is pluggable ("can be applied to any synchronous
//! distributed training scheme", §1); this module is the stable surface
//! new workloads grow against, replacing the old
//! `RunConfig::quick` / `TemplarRunWith::{new,new_sim,with_backend}`
//! constructor tangle (kept as deprecated shims during the transition):
//!
//! ```
//! use gauntlet::coordinator::engine::GauntletBuilder;
//! use gauntlet::coordinator::events::MetricsObserver;
//! use gauntlet::peers::Behavior;
//!
//! let metrics = MetricsObserver::shared();
//! let mut engine = GauntletBuilder::sim()
//!     .model("nano")
//!     .rounds(3)
//!     .peers(vec![
//!         Behavior::Honest { data_mult: 1.0 },
//!         Behavior::Honest { data_mult: 2.0 },
//!         Behavior::Poisoner { scale: 100.0 },
//!     ])
//!     .top_g(2)
//!     .seed(7)
//!     .observer(metrics.clone())
//!     .build()?;
//! let run_metrics = engine.run()?;
//! assert_eq!(run_metrics.rounds.len(), 3);
//! assert_eq!(metrics.n_rounds(), 3, "observers see every round");
//! # anyhow::Ok(())
//! ```
//!
//! Three backend modes: [`GauntletBuilder::sim`] (deterministic pure-Rust
//! `SimExec`, always available), [`GauntletBuilder::artifact`] (compiled
//! PJRT artifacts, errors if missing), and [`GauntletBuilder::auto`]
//! (artifacts if present, else the sim fallback — what the CLI uses).
//! [`GauntletBuilder::resume`] rebuilds an engine from a
//! [`RunSnapshot`](super::snapshot::RunSnapshot) and continues
//! bit-identically.

use std::sync::Arc;

use anyhow::{Context, Result};

use super::events::{MetricsObserver, Observer};
use super::run::{RoundRecord, RunConfig, RunMetrics, TemplarRunWith};
use super::snapshot::RunSnapshot;
use super::GauntletParams;
use crate::chain::{Chain, Registration, Uid};
use crate::coordinator::validator::Validator;
use crate::peers::{Behavior, PeerRunner};
use crate::runtime::{ExecStats, Executor, SimExec};
use crate::scenario::Scenario;
use crate::storage::ProviderModel;

/// Which execution backend [`GauntletBuilder::build`] assembles over.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum BackendKind {
    /// Deterministic pure-Rust `SimExec` (no artifacts needed).
    Sim,
    /// Compiled PJRT artifacts; build fails if they are missing.
    Artifact,
    /// Artifacts when available, sim fallback otherwise.
    Auto,
}

/// Fluent constructor for a [`GauntletEngine`] (see the module docs).
///
/// Every setter overrides one [`RunConfig`] field; [`GauntletBuilder::config`]
/// swaps in a whole config for full control. Setters applied after
/// [`GauntletBuilder::resume`] override the snapshot's embedded config —
/// `rounds` is the usual one (the run target is a *total* round count, so
/// `.resume(snap).rounds(10)` continues a paused run out to round 10).
pub struct GauntletBuilder {
    cfg: RunConfig,
    backend: BackendKind,
    observers: Vec<Arc<dyn Observer>>,
    snapshot: Option<RunSnapshot>,
}

impl GauntletBuilder {
    fn with_backend_kind(backend: BackendKind) -> Self {
        GauntletBuilder {
            cfg: RunConfig::default(),
            backend,
            observers: Vec::new(),
            snapshot: None,
        }
    }

    /// Build on the deterministic pure-Rust backend (always available).
    pub fn sim() -> Self {
        Self::with_backend_kind(BackendKind::Sim)
    }

    /// Build on compiled PJRT artifacts (fails if they are missing).
    pub fn artifact() -> Self {
        Self::with_backend_kind(BackendKind::Artifact)
    }

    /// Prefer artifacts, fall back to the sim backend (the CLI default).
    pub fn auto() -> Self {
        Self::with_backend_kind(BackendKind::Auto)
    }

    /// Continue a paused run from a [`RunSnapshot`]: the snapshot's
    /// embedded config becomes the builder's config.
    ///
    /// Setters applied afterwards fall in two classes. Runtime-read fields
    /// take effect on the resumed run: `rounds`, `threads`, `eval_every`,
    /// `scenario`, `params`. Structural fields are *baked into the
    /// snapshot state* (the chain slot table, registered runners, RNG
    /// streams, the backend's data geometry) — changing `model`, `seed`,
    /// `peers`, `validators`, `max_uids`, or `immunity_rounds` after
    /// `resume` is rejected by [`GauntletBuilder::build`] rather than
    /// silently ignored.
    pub fn resume(mut self, snapshot: RunSnapshot) -> Self {
        self.cfg = snapshot.cfg.clone();
        self.snapshot = Some(snapshot);
        self
    }

    /// Artifact config name (nano / tiny / small / base).
    pub fn model(mut self, model: &str) -> Self {
        self.cfg.model = model.to_string();
        self
    }

    /// Total communication rounds ([`GauntletEngine::run`] drives until the
    /// round counter reaches this, so it composes with `resume`).
    pub fn rounds(mut self, rounds: u64) -> Self {
        self.cfg.rounds = rounds;
        self
    }

    /// The round-0 peer population (replaces any previous list).
    pub fn peers(mut self, peers: Vec<Behavior>) -> Self {
        self.cfg.peers = peers;
        self
    }

    /// Append one peer to the round-0 population.
    pub fn peer(mut self, behavior: Behavior) -> Self {
        self.cfg.peers.push(behavior);
        self
    }

    /// Scripted churn schedule (`scenario` module).
    pub fn scenario(mut self, scenario: Scenario) -> Self {
        self.cfg.scenario = scenario;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Worker threads (0 = auto via `GAUNTLET_THREADS`, 1 = sequential).
    pub fn threads(mut self, threads: usize) -> Self {
        self.cfg.threads = threads;
        self
    }

    /// Number of staked validators (>= 1).
    pub fn validators(mut self, n: usize) -> Self {
        self.cfg.n_validators = n;
        self
    }

    /// Evaluate held-out loss every `n` rounds (0 = never).
    pub fn eval_every(mut self, n: u64) -> Self {
        self.cfg.eval_every = n;
        self
    }

    /// Chain neuron-slot capacity including validators (0 = unbounded).
    pub fn max_uids(mut self, n: usize) -> Self {
        self.cfg.max_uids = n;
        self
    }

    /// Rounds of post-registration eviction immunity.
    pub fn immunity_rounds(mut self, rounds: u64) -> Self {
        self.cfg.immunity_rounds = rounds;
        self
    }

    /// Aggregation size G (eq. 6).
    pub fn top_g(mut self, g: usize) -> Self {
        self.cfg.params.top_g = g;
        self
    }

    /// |S_t|: peers primary-evaluated per round.
    pub fn eval_sample(mut self, s: usize) -> Self {
        self.cfg.params.eval_sample = s;
        self
    }

    /// Override any [`GauntletParams`] field in place.
    pub fn params(mut self, f: impl FnOnce(&mut GauntletParams)) -> Self {
        f(&mut self.cfg.params);
        self
    }

    /// Storage-provider latency/reliability model.
    pub fn provider(mut self, provider: ProviderModel) -> Self {
        self.cfg.provider = provider;
        self
    }

    /// Toggle encoded-domain normalization (the §4 ablation).
    pub fn normalize(mut self, on: bool) -> Self {
        self.cfg.agg.normalize = on;
        self
    }

    /// Swap in a complete [`RunConfig`] (escape hatch for tests/benches
    /// that build configs programmatically).
    pub fn config(mut self, cfg: RunConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Subscribe an observer to the engine's round-event stream (attached
    /// before the first round, so it sees the complete stream).
    pub fn observer(mut self, obs: Arc<dyn Observer>) -> Self {
        self.observers.push(obs);
        self
    }

    /// Assemble the engine. Fresh builds register the round-0 population
    /// through the permissionless path; `resume` builds restore every
    /// substrate from the snapshot instead.
    pub fn build(self) -> Result<GauntletEngine> {
        let GauntletBuilder { cfg, backend, observers, snapshot } = self;
        let mut engine = match snapshot {
            Some(mut snap) => {
                // Runtime-read setters applied after `resume` win over the
                // snapshot's embedded config; structural ones cannot
                // (their state is already baked into the snapshot), so a
                // changed value is an error, not a silent no-op.
                ensure_resume_compatible(&snap.cfg, &cfg)?;
                snap.cfg = cfg;
                Self::build_resumed(backend, snap)?
            }
            None => Self::build_fresh(backend, cfg)?,
        };
        for obs in observers {
            engine.add_observer(obs);
        }
        Ok(engine)
    }

    fn build_fresh(backend: BackendKind, cfg: RunConfig) -> Result<GauntletEngine> {
        match backend {
            BackendKind::Sim => {
                Ok(GauntletEngine::Sim(TemplarRunWith::<SimExec>::new_sim_inner(cfg)?))
            }
            BackendKind::Artifact => {
                Ok(GauntletEngine::Artifact(TemplarRunWith::<Executor>::new_artifact(cfg)?))
            }
            BackendKind::Auto => match TemplarRunWith::<Executor>::new_artifact(cfg.clone()) {
                Ok(run) => Ok(GauntletEngine::Artifact(run)),
                Err(e) => {
                    // Don't swallow *why* artifacts were rejected — a
                    // corrupted/ABI-mismatched build would otherwise run
                    // silently (and wrongly) on the toy model.
                    eprintln!(
                        "note: artifact backend unavailable ({e:#}); \
                         falling back to the pure-Rust SimExec backend"
                    );
                    Ok(GauntletEngine::Sim(TemplarRunWith::<SimExec>::new_sim_inner(cfg)?))
                }
            },
        }
    }

    fn build_resumed(backend: BackendKind, snap: RunSnapshot) -> Result<GauntletEngine> {
        match backend {
            BackendKind::Sim => {
                let exec = SimExec::from_model_name(&snap.cfg.model, snap.cfg.seed);
                Ok(GauntletEngine::Sim(TemplarRunWith::from_snapshot(exec, snap)?))
            }
            BackendKind::Artifact => {
                let exec =
                    Executor::load(crate::runtime::artifact_dir(&snap.cfg.model))?;
                Ok(GauntletEngine::Artifact(TemplarRunWith::from_snapshot(exec, snap)?))
            }
            // Auto resume follows the backend the snapshot records: a
            // bit-identical continuation is only possible on the backend
            // that produced the state, so a recorded backend is honored
            // (and its absence — artifacts gone, say — is an error, not a
            // silent switch to a different model implementation).
            BackendKind::Auto => match snap.backend.as_str() {
                "sim" => {
                    let exec = SimExec::from_model_name(&snap.cfg.model, snap.cfg.seed);
                    Ok(GauntletEngine::Sim(TemplarRunWith::from_snapshot(exec, snap)?))
                }
                "artifact" => {
                    let exec = Executor::load(crate::runtime::artifact_dir(&snap.cfg.model))
                        .context(
                            "this snapshot was taken on the artifact backend; resuming it \
                             on the sim backend would silently change the model — rebuild \
                             the artifacts or pass GauntletBuilder::sim() explicitly",
                        )?;
                    Ok(GauntletEngine::Artifact(TemplarRunWith::from_snapshot(exec, snap)?))
                }
                // Snapshot predates the backend stamp (or was captured
                // below the engine facade): keep the old try-then-fall-back
                // behavior, but say which way it went.
                _ => match Executor::load(crate::runtime::artifact_dir(&snap.cfg.model)) {
                    Ok(exec) => {
                        Ok(GauntletEngine::Artifact(TemplarRunWith::from_snapshot(exec, snap)?))
                    }
                    Err(e) => {
                        eprintln!(
                            "note: artifact backend unavailable ({e:#}); resuming on \
                             the pure-Rust SimExec backend"
                        );
                        let exec = SimExec::from_model_name(&snap.cfg.model, snap.cfg.seed);
                        Ok(GauntletEngine::Sim(TemplarRunWith::from_snapshot(exec, snap)?))
                    }
                },
            },
        }
    }
}

/// Reject post-`resume` changes to config fields whose state is baked into
/// the snapshot (see [`GauntletBuilder::resume`]); a silent no-op would
/// leave `engine.cfg()` describing a different experiment than the one
/// actually running.
fn ensure_resume_compatible(snapshot: &RunConfig, requested: &RunConfig) -> Result<()> {
    fn check<T: PartialEq + std::fmt::Debug>(field: &str, old: &T, new: &T) -> Result<()> {
        anyhow::ensure!(
            old == new,
            "cannot change `{field}` on resume ({old:?} -> {new:?}): that state is \
             baked into the snapshot; start a fresh run instead"
        );
        Ok(())
    }
    check("model", &snapshot.model, &requested.model)?;
    check("seed", &snapshot.seed, &requested.seed)?;
    check("peers", &snapshot.peers, &requested.peers)?;
    check("n_validators", &snapshot.n_validators, &requested.n_validators)?;
    check("max_uids", &snapshot.max_uids, &requested.max_uids)?;
    check("immunity_rounds", &snapshot.immunity_rounds, &requested.immunity_rounds)?;
    Ok(())
}

/// The assembled system behind one stable facade, whichever backend won:
/// drive it with [`GauntletEngine::run_round`] / [`GauntletEngine::run`],
/// snapshot it, churn its population, or inspect its substrates.
pub enum GauntletEngine {
    /// Pure-Rust deterministic backend.
    Sim(TemplarRunWith<SimExec>),
    /// Compiled-artifact PJRT backend.
    Artifact(TemplarRunWith<Executor>),
}

macro_rules! delegate {
    ($self:ident, $run:ident => $body:expr) => {
        match $self {
            GauntletEngine::Sim($run) => $body,
            GauntletEngine::Artifact($run) => $body,
        }
    };
}

impl GauntletEngine {
    /// One synchronous communication round.
    pub fn run_round(&mut self) -> Result<RoundRecord> {
        delegate!(self, run => run.run_round())
    }

    /// Drive rounds until the round counter reaches the configured total;
    /// returns the metrics of the rounds this call drove.
    pub fn run(&mut self) -> Result<RunMetrics> {
        delegate!(self, run => run.run())
    }

    /// Capture a [`RunSnapshot`] at the current round boundary, stamped
    /// with this engine's backend so `resume` can refuse a silent
    /// backend switch.
    pub fn snapshot(&self) -> RunSnapshot {
        let mut snap = delegate!(self, run => run.snapshot());
        snap.backend = self.backend_name().to_string();
        snap
    }

    /// Subscribe an observer to the round-event stream.
    pub fn add_observer(&mut self, obs: Arc<dyn Observer>) {
        delegate!(self, run => run.add_observer(obs))
    }

    /// The engine's built-in metrics observer.
    pub fn metrics_observer(&self) -> &Arc<MetricsObserver> {
        delegate!(self, run => run.metrics_observer())
    }

    /// Permissionless mid-run registration (slot rules apply).
    pub fn register_peer(&mut self, behavior: Behavior) -> Result<Uid> {
        delegate!(self, run => run.register_peer(behavior))
    }

    /// Mid-run registration exposing the chain's [`Registration`].
    pub fn register_peer_detailed(&mut self, behavior: Behavior) -> Result<Registration> {
        delegate!(self, run => run.register_peer_detailed(behavior))
    }

    /// A peer leaves the network, freeing its slot.
    pub fn deregister_peer(&mut self, uid: Uid) -> Result<()> {
        delegate!(self, run => run.deregister_peer(uid))
    }

    /// The next round to execute (also how many rounds have run).
    pub fn round(&self) -> u64 {
        delegate!(self, run => run.round)
    }

    pub fn cfg(&self) -> &RunConfig {
        delegate!(self, run => &run.cfg)
    }

    pub fn chain(&self) -> &Chain {
        delegate!(self, run => &run.chain)
    }

    pub fn validators(&self) -> &[Validator] {
        delegate!(self, run => &run.validators)
    }

    pub fn peers(&self) -> &[PeerRunner] {
        delegate!(self, run => &run.peers)
    }

    pub fn peer_uids(&self) -> Vec<Uid> {
        delegate!(self, run => run.peer_uids())
    }

    /// The current global model parameters.
    pub fn theta(&self) -> &[f32] {
        delegate!(self, run => &run.theta)
    }

    /// The checkpoint store (full checkpoints + signed-update replay log).
    pub fn checkpoints(&self) -> &super::checkpoint::CheckpointStore {
        delegate!(self, run => &run.checkpoints)
    }

    /// Which backend this engine runs on ("sim" / "artifact").
    pub fn backend_name(&self) -> &'static str {
        match self {
            GauntletEngine::Sim(_) => "sim",
            GauntletEngine::Artifact(_) => "artifact",
        }
    }

    /// Per-artifact executor timings (artifact backend only).
    pub fn exec_stats(&self) -> Option<std::collections::BTreeMap<String, ExecStats>> {
        match self {
            GauntletEngine::Sim(_) => None,
            GauntletEngine::Artifact(run) => Some(run.exec.stats()),
        }
    }

    /// A 64-bit digest of the run's observable state, mixed in a fixed
    /// deterministic order: model parameters, every validator's
    /// PEERSCOREs, and on-chain balances. Two runs (or a
    /// paused-and-resumed pair) that agree here agree bit-for-bit on
    /// everything the snapshot/resume contract pins — the CLI prints it
    /// and CI diffs it.
    pub fn fingerprint(&self) -> u64 {
        // FNV-1a over the state in a deterministic order.
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut mix = |x: u64| {
            for b in x.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01B3);
            }
        };
        for t in self.theta() {
            mix(t.to_bits() as u64);
        }
        let uids = self.peer_uids();
        for v in self.validators() {
            for &u in &uids {
                mix(u as u64);
                mix(v.book.peer_score(u).to_bits());
            }
        }
        for &u in &uids {
            let bal = self.chain().neuron(u).map(|n| n.balance).unwrap_or(0.0);
            mix(bal.to_bits());
        }
        h
    }
}
