//! Durable run snapshots: pause a Gauntlet run at any round boundary,
//! serialize the *entire* run substrate to JSON, and resume later (in a
//! different process, at a different worker-thread count) **bit-identically**
//! to the uninterrupted run.
//!
//! A [`RunSnapshot`] captures everything the next round's computation can
//! observe:
//!
//! - the chain slot table: neurons, stakes, balances, committed weight
//!   rows, freed uids, the monotone uid counter, the block clock;
//! - every validator's [`ScoreBook`](super::scoring::ScoreBook) — OpenSkill
//!   ratings, proof-of-computation EMAs, phi/fast-fail history — plus its
//!   sampling-RNG stream;
//! - every peer runner's DeMo error-feedback buffer, divergent local model
//!   (if any), and behaviour-RNG stream;
//! - the model parameters, the round counter (which doubles as the
//!   scenario cursor: scripted events fire by round index), the active
//!   provider-outage window, and the storage provider's RNG stream,
//!   read-key mint, and bucket registry;
//! - the checkpoint store (full checkpoints + packed signed updates), so
//!   catchup keeps answering for pre-snapshot rounds;
//! - the full [`RunConfig`], making a snapshot self-contained: resume
//!   needs nothing but the file.
//!
//! Floating-point state is encoded bit-faithfully: `f32` vectors as raw
//! bit patterns, `f64`s through [`minjson::fnum`] (shortest-roundtrip
//! `Display` plus sentinels for NaN/±inf/-0.0), and RNG states as decimal
//! strings (u64 does not fit in a JSON double). See
//! `tests/snapshot_resume.rs` for the bit-identity pin.
//!
//! ```
//! use gauntlet::coordinator::engine::GauntletBuilder;
//! use gauntlet::coordinator::snapshot::RunSnapshot;
//! use gauntlet::peers::Behavior;
//!
//! let peers = vec![Behavior::Honest { data_mult: 1.0 }; 3];
//! let mut engine = GauntletBuilder::sim().model("nano").rounds(4).peers(peers).build()?;
//! engine.run_round()?;
//!
//! // Serialize at the round boundary, reload, and continue elsewhere.
//! let json = engine.snapshot().to_json().write();
//! let snap = RunSnapshot::parse(&json)?;
//! let mut resumed = GauntletBuilder::sim().resume(snap).build()?;
//! assert_eq!(resumed.round(), 1);
//! resumed.run()?; // rounds 1..4, bit-identical to never having paused
//! assert_eq!(resumed.round(), 4);
//! # anyhow::Ok(())
//! ```

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use super::checkpoint::SignVector;
use super::run::RunConfig;
use super::schedule::LrSchedule;
use super::scoring::PeerState;
use super::GauntletParams;
use crate::chain::{ChainState, Neuron, Uid};
use crate::chain::yuma::YumaParams;
use crate::minjson::{self, field, fnum, read_f64, Value};
use crate::openskill::Rating;
use crate::peers::{Behavior, PeerRunnerState};
use crate::scenario::Scenario;
use crate::storage::{ProviderModel, ReadKey};
use crate::util::Ema;

/// Format marker written into every snapshot.
pub const SNAPSHOT_VERSION: &str = "gauntlet-snapshot-v1";

/// One validator's serializable state.
#[derive(Clone, Debug)]
pub struct ValidatorState {
    pub uid: Uid,
    pub rng_state: u64,
    /// `(uid, score-book entry)` in uid order.
    pub book: Vec<(Uid, PeerState)>,
}

/// The storage provider's serializable state (objects are per-round and
/// never read across a round boundary, so only the control state travels).
#[derive(Clone, Debug)]
pub struct StoreState {
    pub rng_state: u64,
    pub next_key_id: u64,
    /// The *live* outage probability (a scripted outage may be active).
    pub outage_prob: f64,
    /// The *live* transient-GET-failure probability (a scripted chaos
    /// window may be active).
    pub get_fail_prob: f64,
    /// The *live* payload-corruption probability (ditto).
    pub corrupt_prob: f64,
    /// `(bucket name, owner, read key)`, sorted by name.
    pub buckets: Vec<(String, String, ReadKey)>,
}

/// A full run snapshot at a round boundary (see the module docs).
#[derive(Clone, Debug)]
pub struct RunSnapshot {
    pub round: u64,
    /// Which backend produced this snapshot ("sim" / "artifact"), recorded
    /// by `GauntletEngine::snapshot` — the auto backend refuses to resume
    /// an artifact-backed run on the sim backend (and vice versa), since a
    /// silent switch would continue real-transformer parameters on the toy
    /// model while still printing plausible fingerprints. Empty when the
    /// snapshot was captured below the engine facade.
    pub backend: String,
    pub cfg: RunConfig,
    pub theta: Vec<f32>,
    pub next_hotkey: u64,
    /// Active provider-outage window: `(restore round, original prob)`.
    pub outage_restore: Option<(u64, f64)>,
    /// Active chaos windows: kind → `(restore round, original prob)`.
    pub chaos_restore: BTreeMap<String, (u64, f64)>,
    /// Active targeted eclipses: `(validator, peer)` → restore round.
    pub eclipse_restore: BTreeMap<(Uid, Uid), u64>,
    pub chain: ChainState,
    pub validators: Vec<ValidatorState>,
    pub peers: Vec<PeerRunnerState>,
    pub store: StoreState,
    /// Lifecycle event lines emitted between rounds (a direct
    /// `register_peer` just before the snapshot) that the next round's
    /// [`RoundRecord`](super::run::RoundRecord) must still report.
    pub pending_events: Vec<String>,
    /// `(round, full parameter vector)` checkpoints.
    pub checkpoint_rounds: Vec<(u64, Vec<f32>)>,
    /// `(round, lr, packed signs)` per recorded update.
    pub checkpoint_updates: Vec<(u64, f32, SignVector)>,
}

// --------------------------- helpers ------------------------------------

fn u64s(x: u64) -> Value {
    Value::Str(x.to_string())
}

fn read_u64(v: &Value) -> Result<u64> {
    match v {
        Value::Str(s) => s.parse().with_context(|| format!("bad u64 {s:?}")),
        Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n < 9e15 => Ok(*n as u64),
        other => bail!("expected u64, got {other:?}"),
    }
}

/// f32 slice -> raw bit patterns (exact u32 integers survive JSON doubles).
fn arr_f32_bits(xs: &[f32]) -> Value {
    Value::Arr(xs.iter().map(|x| Value::Num(x.to_bits() as f64)).collect())
}

fn read_f32_bits(v: &Value) -> Result<Vec<f32>> {
    v.as_arr()
        .context("expected an f32-bits array")?
        .iter()
        .map(|x| {
            let n = x.as_f64().context("bad f32 bits")?;
            if n < 0.0 || n.fract() != 0.0 || n > u32::MAX as f64 {
                bail!("f32 bit pattern out of range: {n}");
            }
            Ok(f32::from_bits(n as u32))
        })
        .collect()
}

/// i32 slice (sparse-gradient indices) as exact JSON integers.
fn arr_i32(xs: &[i32]) -> Value {
    Value::Arr(xs.iter().map(|x| Value::Num(*x as f64)).collect())
}

fn read_i32(v: &Value) -> Result<Vec<i32>> {
    v.as_arr()
        .context("expected an i32 array")?
        .iter()
        .map(|x| {
            let n = x.as_f64().context("bad i32")?;
            if n.fract() != 0.0 || n < i32::MIN as f64 || n > i32::MAX as f64 {
                bail!("i32 out of range: {n}");
            }
            Ok(n as i32)
        })
        .collect()
}

fn arr_bytes(xs: &[u8]) -> Value {
    Value::Arr(xs.iter().map(|b| Value::Num(*b as f64)).collect())
}

fn read_bytes(v: &Value) -> Result<Vec<u8>> {
    v.as_arr()
        .context("expected a byte array")?
        .iter()
        .map(|x| {
            x.as_usize()
                .filter(|n| *n <= 255)
                .map(|n| n as u8)
                .context("bad byte")
        })
        .collect()
}

// ------------------------- config codec ----------------------------------

impl LrSchedule {
    /// Canonical spec string — the inverse of [`LrSchedule::parse`].
    pub fn spec(&self) -> String {
        match self {
            LrSchedule::Constant => "constant".to_string(),
            LrSchedule::WarmupCosine { warmup, total, min_frac } => {
                format!("cosine:{warmup}:{total}:{min_frac}")
            }
            LrSchedule::StepHalving { every } => format!("halve:{every}"),
        }
    }
}

fn cfg_to_json(cfg: &RunConfig) -> Value {
    let p = &cfg.params;
    minjson::obj(vec![
        ("model", minjson::s(&cfg.model)),
        ("rounds", minjson::num(cfg.rounds as f64)),
        (
            "peers",
            Value::Arr(cfg.peers.iter().map(|b| minjson::s(&b.spec())).collect()),
        ),
        ("scenario", cfg.scenario.to_json()),
        ("max_uids", minjson::num(cfg.max_uids as f64)),
        ("immunity_rounds", minjson::num(cfg.immunity_rounds as f64)),
        ("seed", u64s(cfg.seed)),
        ("eval_every", minjson::num(cfg.eval_every as f64)),
        ("n_validators", minjson::num(cfg.n_validators as f64)),
        ("threads", minjson::num(cfg.threads as f64)),
        (
            "params",
            minjson::obj(vec![
                ("gamma", fnum(p.gamma)),
                ("phi_penalty", fnum(p.phi_penalty)),
                ("sync_threshold", fnum(p.sync_threshold)),
                ("beta_frac", fnum(p.beta_frac as f64)),
                ("norm_power", fnum(p.norm_power)),
                ("top_g", minjson::num(p.top_g as f64)),
                ("eval_sample", minjson::num(p.eval_sample as f64)),
                ("lr", fnum(p.lr as f64)),
                ("schedule", minjson::s(&p.schedule.spec())),
                ("demo_decay", fnum(p.demo_decay as f64)),
                ("base_microbatches", minjson::num(p.base_microbatches as f64)),
                ("checkpoint_every", minjson::num(p.checkpoint_every as f64)),
                (
                    "retry",
                    minjson::obj(vec![
                        ("max_attempts", minjson::num(p.retry.max_attempts as f64)),
                        ("base_backoff_ms", minjson::num(p.retry.base_backoff_ms as f64)),
                        ("max_backoff_ms", minjson::num(p.retry.max_backoff_ms as f64)),
                    ]),
                ),
            ]),
        ),
        (
            "clock",
            minjson::obj(vec![
                ("round_ms", minjson::num(cfg.clock.round_ms as f64)),
                ("put_window_ms", minjson::num(cfg.clock.put_window_ms as f64)),
            ]),
        ),
        (
            "provider",
            minjson::obj(vec![
                ("mean_upload_ms", fnum(cfg.provider.mean_upload_ms)),
                ("jitter_ms", fnum(cfg.provider.jitter_ms)),
                ("outage_prob", fnum(cfg.provider.outage_prob)),
                ("get_fail_prob", fnum(cfg.provider.get_fail_prob)),
                ("corrupt_prob", fnum(cfg.provider.corrupt_prob)),
                ("truncate_prob", fnum(cfg.provider.truncate_prob)),
                ("spike_prob", fnum(cfg.provider.spike_prob)),
                ("spike_ms", minjson::num(cfg.provider.spike_ms as f64)),
                ("max_object_bytes", minjson::num(cfg.provider.max_object_bytes as f64)),
            ]),
        ),
        (
            "agg",
            minjson::obj(vec![
                ("normalize", Value::Bool(cfg.agg.normalize)),
                ("min_norm", fnum(cfg.agg.min_norm)),
            ]),
        ),
    ])
}

fn cfg_from_json(v: &Value) -> Result<RunConfig> {
    let peers = v
        .get("peers")
        .as_arr()
        .context("cfg missing \"peers\"")?
        .iter()
        .map(|b| {
            let spec = b.as_str().context("peer spec must be a string")?;
            Behavior::parse_spec(spec).map_err(|e| anyhow::anyhow!("peer spec {spec:?}: {e}"))
        })
        .collect::<Result<Vec<_>>>()?;
    let p = v.get("params");
    let params = GauntletParams {
        gamma: field::f64(p, "gamma")?,
        phi_penalty: field::f64(p, "phi_penalty")?,
        sync_threshold: field::f64(p, "sync_threshold")?,
        beta_frac: field::f32(p, "beta_frac")?,
        norm_power: field::f64(p, "norm_power")?,
        top_g: p.get("top_g").as_usize().context("top_g")?,
        eval_sample: p.get("eval_sample").as_usize().context("eval_sample")?,
        lr: field::f32(p, "lr")?,
        schedule: LrSchedule::parse(&field::string(p, "schedule")?)
            .map_err(|e| anyhow::anyhow!("schedule: {e}"))?,
        demo_decay: field::f32(p, "demo_decay")?,
        base_microbatches: p
            .get("base_microbatches")
            .as_usize()
            .context("base_microbatches")?,
        checkpoint_every: field::unsigned(p, "checkpoint_every")?,
        // Tolerant: snapshots written before the retry policy existed
        // resume on the defaults (which is what those runs effectively
        // used — a single attempt per transient failure class was the
        // old behaviour only for p = 0 providers, where it is identical).
        retry: {
            let r = p.get("retry");
            let d = crate::storage::RetryPolicy::default();
            crate::storage::RetryPolicy {
                max_attempts: r
                    .get("max_attempts")
                    .as_usize()
                    .map(|n| n as u32)
                    .unwrap_or(d.max_attempts),
                base_backoff_ms: field::unsigned(r, "base_backoff_ms")
                    .unwrap_or(d.base_backoff_ms),
                max_backoff_ms: field::unsigned(r, "max_backoff_ms")
                    .unwrap_or(d.max_backoff_ms),
            }
        },
    };
    let clock = crate::coordinator::round::RoundClock {
        round_ms: field::unsigned(v.get("clock"), "round_ms")?,
        put_window_ms: field::unsigned(v.get("clock"), "put_window_ms")?,
    };
    let pr = v.get("provider");
    let provider = ProviderModel {
        mean_upload_ms: field::f64(pr, "mean_upload_ms")?,
        jitter_ms: field::f64(pr, "jitter_ms")?,
        outage_prob: field::f64(pr, "outage_prob")?,
        // Tolerant: pre-chaos snapshots default every fault knob to off.
        get_fail_prob: read_f64(pr.get("get_fail_prob")).unwrap_or(0.0),
        corrupt_prob: read_f64(pr.get("corrupt_prob")).unwrap_or(0.0),
        truncate_prob: read_f64(pr.get("truncate_prob")).unwrap_or(0.0),
        spike_prob: read_f64(pr.get("spike_prob")).unwrap_or(0.0),
        spike_ms: field::unsigned(pr, "spike_ms").unwrap_or(0),
        max_object_bytes: pr.get("max_object_bytes").as_usize().context("max_object_bytes")?,
    };
    let agg = crate::demo::aggregate::AggregateOpts {
        normalize: v.get("agg").get("normalize").as_bool().context("agg.normalize")?,
        min_norm: field::f64(v.get("agg"), "min_norm")?,
    };
    Ok(RunConfig {
        model: field::string(v, "model")?,
        rounds: field::unsigned(v, "rounds")?,
        peers,
        scenario: Scenario::parse(&v.get("scenario").write())
            .map_err(|e| anyhow::anyhow!("scenario: {e}"))?,
        max_uids: v.get("max_uids").as_usize().context("max_uids")?,
        immunity_rounds: field::unsigned(v, "immunity_rounds")?,
        params,
        clock,
        provider,
        seed: read_u64(v.get("seed")).context("seed")?,
        eval_every: field::unsigned(v, "eval_every")?,
        n_validators: v.get("n_validators").as_usize().context("n_validators")?,
        agg,
        threads: v.get("threads").as_usize().context("threads")?,
    })
}

// ------------------------- chain codec -----------------------------------

/// Schema note — sparse weight books: `"weights"` serializes each
/// validator's committed row as `[validator_uid, [[uid, w], ...]]`, i.e.
/// only the uids the validator actually weighted. This is the same sparse
/// shape `Chain::run_epoch` consumes, so a snapshot of a 1M-uid table
/// costs O(active) weight entries, not O(validators × table). The chain's
/// derived indexes (hotkey map, stake order, the `paid` set of uids
/// holding a nonzero `last_incentive`) are deliberately NOT serialized:
/// `Chain::from_state` rebuilds all three from the neuron records, so the
/// snapshot format did not change when the indexes were introduced.
fn chain_to_json(c: &ChainState) -> Value {
    minjson::obj(vec![
        ("block", minjson::num(c.block as f64)),
        (
            "neurons",
            Value::Arr(
                c.neurons
                    .iter()
                    .map(|n| {
                        minjson::obj(vec![
                            ("uid", minjson::num(n.uid as f64)),
                            ("hotkey", minjson::s(&n.hotkey)),
                            ("stake", fnum(n.stake)),
                            (
                                "read_key",
                                n.bucket_read_key
                                    .as_ref()
                                    .map(|k| minjson::s(&k.0))
                                    .unwrap_or(Value::Null),
                            ),
                            ("registered_at_block", minjson::num(n.registered_at_block as f64)),
                            ("balance", fnum(n.balance)),
                            ("last_incentive", fnum(n.last_incentive)),
                            ("validator_permit", Value::Bool(n.validator_permit)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("next_uid", minjson::num(c.next_uid as f64)),
        (
            "free_uids",
            Value::Arr(c.free_uids.iter().map(|u| minjson::num(*u as f64)).collect()),
        ),
        (
            "weights",
            Value::Arr(
                c.weights
                    .iter()
                    .map(|(v, row)| {
                        Value::Arr(vec![
                            minjson::num(*v as f64),
                            Value::Arr(
                                row.iter()
                                    .map(|(u, w)| {
                                        Value::Arr(vec![minjson::num(*u as f64), fnum(*w)])
                                    })
                                    .collect(),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("yuma_kappa", fnum(c.yuma.kappa)),
        ("emission_per_epoch", fnum(c.emission_per_epoch)),
        ("max_uids", minjson::num(c.max_uids as f64)),
        ("immunity_blocks", minjson::num(c.immunity_blocks as f64)),
    ])
}

fn chain_from_json(v: &Value) -> Result<ChainState> {
    let neurons = v
        .get("neurons")
        .as_arr()
        .context("chain missing \"neurons\"")?
        .iter()
        .map(|n| {
            Ok(Neuron {
                uid: n.get("uid").as_usize().context("neuron uid")? as Uid,
                hotkey: field::string(n, "hotkey")?,
                stake: field::f64(n, "stake")?,
                bucket_read_key: n.get("read_key").as_str().map(|k| ReadKey(k.to_string())),
                registered_at_block: field::unsigned(n, "registered_at_block")?,
                balance: field::f64(n, "balance")?,
                last_incentive: field::f64(n, "last_incentive")?,
                validator_permit: n
                    .get("validator_permit")
                    .as_bool()
                    .context("validator_permit")?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let free_uids = v
        .get("free_uids")
        .as_arr()
        .context("free_uids")?
        .iter()
        .map(|u| u.as_usize().map(|u| u as Uid).context("free uid"))
        .collect::<Result<Vec<_>>>()?;
    let weights = v
        .get("weights")
        .as_arr()
        .context("weights")?
        .iter()
        .map(|entry| {
            let pair = entry.as_arr().context("weights entry")?;
            let vu = pair
                .first()
                .and_then(|x| x.as_usize())
                .context("weights validator uid")? as Uid;
            let row = pair
                .get(1)
                .and_then(|x| x.as_arr())
                .context("weights row")?
                .iter()
                .map(|w| {
                    let p = w.as_arr().context("weight pair")?;
                    let u = p.first().and_then(|x| x.as_usize()).context("weight uid")?;
                    let x = p.get(1).and_then(read_f64).context("weight value")?;
                    Ok((u as Uid, x))
                })
                .collect::<Result<Vec<_>>>()?;
            Ok((vu, row))
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(ChainState {
        block: field::unsigned(v, "block")?,
        neurons,
        next_uid: v.get("next_uid").as_usize().context("next_uid")? as Uid,
        free_uids,
        weights,
        yuma: YumaParams { kappa: field::f64(v, "yuma_kappa")? },
        emission_per_epoch: field::f64(v, "emission_per_epoch")?,
        max_uids: v.get("max_uids").as_usize().context("max_uids")?,
        immunity_blocks: field::unsigned(v, "immunity_blocks")?,
    })
}

// ----------------------- snapshot codec ----------------------------------

impl RunSnapshot {
    /// Serialize the snapshot to a JSON value (write with `.write()`).
    pub fn to_json(&self) -> Value {
        let validators = self
            .validators
            .iter()
            .map(|vs| {
                minjson::obj(vec![
                    ("uid", minjson::num(vs.uid as f64)),
                    ("rng_state", u64s(vs.rng_state)),
                    (
                        "book",
                        Value::Arr(
                            vs.book
                                .iter()
                                .map(|(u, s)| {
                                    Value::Arr(vec![
                                        minjson::num(*u as f64),
                                        minjson::obj(vec![
                                            ("rating_mu", fnum(s.rating.mu)),
                                            ("rating_sigma", fnum(s.rating.sigma)),
                                            ("mu_gamma", fnum(s.mu.gamma)),
                                            ("mu_value", fnum(s.mu.value)),
                                            (
                                                "last_loss_score_rand",
                                                fnum(s.last_loss_score_rand),
                                            ),
                                            (
                                                "last_loss_score_assigned",
                                                fnum(s.last_loss_score_assigned),
                                            ),
                                            ("evals", minjson::num(s.evals as f64)),
                                            ("fast_fails", minjson::num(s.fast_fails as f64)),
                                        ]),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect();
        let peers = self
            .peers
            .iter()
            .map(|p| {
                minjson::obj(vec![
                    ("uid", minjson::num(p.uid as f64)),
                    ("behavior", minjson::s(&p.behavior.spec())),
                    ("error", arr_f32_bits(&p.error)),
                    (
                        "theta_local",
                        p.theta_local
                            .as_ref()
                            .map(|t| arr_f32_bits(t))
                            .unwrap_or(Value::Null),
                    ),
                    ("rng_state", u64s(p.rng_state)),
                    ("compute_ms_per_mb", minjson::num(p.compute_ms_per_mb as f64)),
                    ("last_microbatches", minjson::num(p.last_microbatches as f64)),
                    ("last_local_loss", fnum(p.last_local_loss)),
                    (
                        // StaleReplayer's gradient archive: [round, vals
                        // (f32 bits), idx] triples. Empty for every other
                        // behaviour.
                        "replay",
                        Value::Arr(
                            p.replay_log
                                .iter()
                                .map(|(r, g)| {
                                    Value::Arr(vec![
                                        minjson::num(*r as f64),
                                        arr_f32_bits(&g.vals),
                                        arr_i32(&g.idx),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect();
        let buckets = self
            .store
            .buckets
            .iter()
            .map(|(name, owner, key)| {
                Value::Arr(vec![minjson::s(name), minjson::s(owner), minjson::s(&key.0)])
            })
            .collect();
        let checkpoints = self
            .checkpoint_rounds
            .iter()
            .map(|(r, theta)| {
                Value::Arr(vec![minjson::num(*r as f64), arr_f32_bits(theta)])
            })
            .collect();
        let updates = self
            .checkpoint_updates
            .iter()
            .map(|(r, lr, sv)| {
                let (packed, len) = sv.to_parts();
                Value::Arr(vec![
                    minjson::num(*r as f64),
                    Value::Num(lr.to_bits() as f64),
                    minjson::num(len as f64),
                    arr_bytes(packed),
                ])
            })
            .collect();
        minjson::obj(vec![
            ("version", minjson::s(SNAPSHOT_VERSION)),
            ("round", minjson::num(self.round as f64)),
            ("backend", minjson::s(&self.backend)),
            ("cfg", cfg_to_json(&self.cfg)),
            ("theta", arr_f32_bits(&self.theta)),
            ("next_hotkey", u64s(self.next_hotkey)),
            (
                "outage_restore",
                self.outage_restore
                    .map(|(until, orig)| {
                        Value::Arr(vec![minjson::num(until as f64), fnum(orig)])
                    })
                    .unwrap_or(Value::Null),
            ),
            (
                "chaos_restore",
                Value::Arr(
                    self.chaos_restore
                        .iter()
                        .map(|(kind, &(until, orig))| {
                            Value::Arr(vec![
                                minjson::s(kind),
                                minjson::num(until as f64),
                                fnum(orig),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "eclipse_restore",
                Value::Arr(
                    self.eclipse_restore
                        .iter()
                        .map(|(&(validator, peer), &until)| {
                            Value::Arr(vec![
                                minjson::num(validator as f64),
                                minjson::num(peer as f64),
                                minjson::num(until as f64),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("chain", chain_to_json(&self.chain)),
            ("validators", Value::Arr(validators)),
            ("peers", Value::Arr(peers)),
            (
                "store",
                minjson::obj(vec![
                    ("rng_state", u64s(self.store.rng_state)),
                    ("next_key_id", u64s(self.store.next_key_id)),
                    ("outage_prob", fnum(self.store.outage_prob)),
                    ("get_fail_prob", fnum(self.store.get_fail_prob)),
                    ("corrupt_prob", fnum(self.store.corrupt_prob)),
                    ("buckets", Value::Arr(buckets)),
                ]),
            ),
            (
                "pending_events",
                Value::Arr(self.pending_events.iter().map(|e| minjson::s(e)).collect()),
            ),
            ("checkpoints", Value::Arr(checkpoints)),
            ("updates", Value::Arr(updates)),
        ])
    }

    /// Parse a snapshot from JSON text (the inverse of
    /// `snapshot.to_json().write()`).
    pub fn parse(text: &str) -> Result<RunSnapshot> {
        let v = Value::parse(text).map_err(|e| anyhow::anyhow!("snapshot JSON: {e}"))?;
        Self::from_json(&v)
    }

    /// Reconstruct a snapshot from its JSON value.
    pub fn from_json(v: &Value) -> Result<RunSnapshot> {
        let version = field::string(v, "version")?;
        if version != SNAPSHOT_VERSION {
            bail!("unsupported snapshot version {version:?} (expected {SNAPSHOT_VERSION:?})");
        }
        let validators = v
            .get("validators")
            .as_arr()
            .context("validators")?
            .iter()
            .map(|vs| {
                let book = vs
                    .get("book")
                    .as_arr()
                    .context("book")?
                    .iter()
                    .map(|entry| {
                        let pair = entry.as_arr().context("book entry")?;
                        let uid = pair
                            .first()
                            .and_then(|x| x.as_usize())
                            .context("book uid")? as Uid;
                        let s = pair.get(1).context("book state")?;
                        Ok((
                            uid,
                            PeerState {
                                rating: Rating {
                                    mu: field::f64(s, "rating_mu")?,
                                    sigma: field::f64(s, "rating_sigma")?,
                                },
                                mu: Ema {
                                    gamma: field::f64(s, "mu_gamma")?,
                                    value: field::f64(s, "mu_value")?,
                                },
                                last_loss_score_rand: field::f64(s, "last_loss_score_rand")?,
                                last_loss_score_assigned: field::f64(
                                    s,
                                    "last_loss_score_assigned",
                                )?,
                                evals: field::unsigned(s, "evals")?,
                                fast_fails: field::unsigned(s, "fast_fails")?,
                            },
                        ))
                    })
                    .collect::<Result<Vec<_>>>()?;
                Ok(ValidatorState {
                    uid: vs.get("uid").as_usize().context("validator uid")? as Uid,
                    rng_state: read_u64(vs.get("rng_state")).context("validator rng")?,
                    book,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let peers = v
            .get("peers")
            .as_arr()
            .context("peers")?
            .iter()
            .map(|p| {
                let spec = field::string(p, "behavior")?;
                Ok(PeerRunnerState {
                    uid: p.get("uid").as_usize().context("peer uid")? as Uid,
                    behavior: Behavior::parse_spec(&spec)
                        .map_err(|e| anyhow::anyhow!("behavior {spec:?}: {e}"))?,
                    error: read_f32_bits(p.get("error")).context("peer error buffer")?,
                    theta_local: match p.get("theta_local") {
                        Value::Null => None,
                        other => Some(read_f32_bits(other).context("peer theta_local")?),
                    },
                    rng_state: read_u64(p.get("rng_state")).context("peer rng")?,
                    compute_ms_per_mb: field::unsigned(p, "compute_ms_per_mb")?,
                    last_microbatches: p
                        .get("last_microbatches")
                        .as_usize()
                        .context("last_microbatches")?,
                    last_local_loss: field::f64(p, "last_local_loss")?,
                    replay_log: p
                        .get("replay")
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(|entry| {
                            let t = entry.as_arr().context("replay entry")?;
                            let r = t
                                .first()
                                .and_then(|x| x.as_f64())
                                .context("replay round")? as u64;
                            let vals = read_f32_bits(t.get(1).context("replay vals")?)?;
                            let idx = read_i32(t.get(2).context("replay idx")?)?;
                            Ok((r, crate::demo::SparseGrad { vals, idx }))
                        })
                        .collect::<Result<Vec<_>>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let st = v.get("store");
        let buckets = st
            .get("buckets")
            .as_arr()
            .context("buckets")?
            .iter()
            .map(|b| {
                let t = b.as_arr().context("bucket triple")?;
                let get = |i: usize| {
                    t.get(i)
                        .and_then(|x| x.as_str())
                        .map(str::to_string)
                        .context("bucket field")
                };
                Ok((get(0)?, get(1)?, ReadKey(get(2)?)))
            })
            .collect::<Result<Vec<_>>>()?;
        let checkpoint_rounds = v
            .get("checkpoints")
            .as_arr()
            .context("checkpoints")?
            .iter()
            .map(|c| {
                let pair = c.as_arr().context("checkpoint pair")?;
                let r = pair
                    .first()
                    .and_then(|x| x.as_f64())
                    .context("checkpoint round")? as u64;
                let theta = read_f32_bits(pair.get(1).context("checkpoint theta")?)?;
                Ok((r, theta))
            })
            .collect::<Result<Vec<_>>>()?;
        let checkpoint_updates = v
            .get("updates")
            .as_arr()
            .context("updates")?
            .iter()
            .map(|u| {
                let parts = u.as_arr().context("update parts")?;
                let r = parts
                    .first()
                    .and_then(|x| x.as_f64())
                    .context("update round")? as u64;
                let lr_bits = parts
                    .get(1)
                    .and_then(|x| x.as_f64())
                    .context("update lr bits")?;
                let len = parts.get(2).and_then(|x| x.as_usize()).context("update len")?;
                let packed = read_bytes(parts.get(3).context("update signs")?)?;
                Ok((r, f32::from_bits(lr_bits as u32), SignVector::from_parts(packed, len)?))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(RunSnapshot {
            round: field::unsigned(v, "round")?,
            backend: v.get("backend").as_str().unwrap_or("").to_string(),
            cfg: cfg_from_json(v.get("cfg")).context("snapshot cfg")?,
            theta: read_f32_bits(v.get("theta")).context("snapshot theta")?,
            next_hotkey: read_u64(v.get("next_hotkey")).context("next_hotkey")?,
            outage_restore: match v.get("outage_restore") {
                Value::Null => None,
                other => {
                    let pair = other.as_arr().context("outage_restore")?;
                    let until = pair
                        .first()
                        .and_then(|x| x.as_f64())
                        .context("outage_restore round")? as u64;
                    let orig = pair.get(1).and_then(read_f64).context("outage_restore prob")?;
                    Some((until, orig))
                }
            },
            // Tolerant: absent in pre-chaos snapshots → no live windows.
            chaos_restore: v
                .get("chaos_restore")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|e| {
                    let t = e.as_arr().context("chaos_restore entry")?;
                    let kind = t
                        .first()
                        .and_then(|x| x.as_str())
                        .context("chaos_restore kind")?
                        .to_string();
                    let until = t
                        .get(1)
                        .and_then(|x| x.as_f64())
                        .context("chaos_restore round")? as u64;
                    let orig = t.get(2).and_then(read_f64).context("chaos_restore prob")?;
                    Ok((kind, (until, orig)))
                })
                .collect::<Result<_>>()?,
            eclipse_restore: v
                .get("eclipse_restore")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|e| {
                    let t = e.as_arr().context("eclipse_restore entry")?;
                    let validator = t
                        .first()
                        .and_then(|x| x.as_usize())
                        .context("eclipse_restore validator")? as Uid;
                    let peer = t
                        .get(1)
                        .and_then(|x| x.as_usize())
                        .context("eclipse_restore peer")? as Uid;
                    let until = t
                        .get(2)
                        .and_then(|x| x.as_f64())
                        .context("eclipse_restore round")? as u64;
                    Ok(((validator, peer), until))
                })
                .collect::<Result<_>>()?,
            chain: chain_from_json(v.get("chain")).context("snapshot chain")?,
            validators,
            peers,
            store: StoreState {
                rng_state: read_u64(st.get("rng_state")).context("store rng")?,
                next_key_id: read_u64(st.get("next_key_id")).context("next_key_id")?,
                outage_prob: field::f64(st, "outage_prob")?,
                get_fail_prob: read_f64(st.get("get_fail_prob")).unwrap_or(0.0),
                corrupt_prob: read_f64(st.get("corrupt_prob")).unwrap_or(0.0),
                buckets,
            },
            pending_events: v
                .get("pending_events")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|e| e.as_str().map(str::to_string).context("pending event line"))
                .collect::<Result<_>>()?,
            checkpoint_rounds,
            checkpoint_updates,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_spec_roundtrips() {
        for s in [
            LrSchedule::Constant,
            LrSchedule::WarmupCosine { warmup: 5, total: 50, min_frac: 0.25 },
            LrSchedule::StepHalving { every: 7 },
        ] {
            assert_eq!(LrSchedule::parse(&s.spec()).unwrap(), s, "{}", s.spec());
        }
    }

    #[test]
    fn u64_codec_handles_full_range() {
        for x in [0u64, 1, u64::MAX, 0xDEAD_BEEF_CAFE_F00D] {
            assert_eq!(read_u64(&u64s(x)).unwrap(), x);
        }
        assert!(read_u64(&Value::Str("not a number".into())).is_err());
    }

    #[test]
    fn f32_bits_codec_is_exact() {
        let xs = vec![0.0f32, -0.0, 1.5, f32::NAN, f32::INFINITY, f32::MIN_POSITIVE];
        let back = read_f32_bits(&arr_f32_bits(&xs)).unwrap();
        for (a, b) in xs.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn chain_codec_roundtrips_sparse_weight_books() {
        use crate::chain::Chain;
        let mut chain = Chain::new();
        let v = chain.register("val").unwrap();
        chain.add_stake(v, 10.0).unwrap();
        let mut far = 0;
        for i in 0..500 {
            far = chain.register(&format!("peer-{i}")).unwrap();
        }
        chain.set_weights(v, &[(1, 0.25), (far, 0.75)]).unwrap();
        let paid = chain.run_epoch(); // populate last_incentive / paid index
        assert_eq!(paid.len(), 2);

        let state = chain.to_state();
        let back = chain_from_json(&chain_to_json(&state)).unwrap();
        // The book stays sparse on the wire: two entries for the one
        // committed row, however many uids the table holds.
        assert_eq!(back.weights, state.weights);
        assert_eq!(back.weights[0].1.len(), 2);
        assert_eq!(back.neurons, state.neurons);
        assert_eq!(back.next_uid, state.next_uid);
        assert_eq!(back.free_uids, state.free_uids);
        // And the rebuilt chain re-derives the indexes: a second epoch on
        // the restored chain pays the same uids the same incentives.
        let mut restored = Chain::from_state(back);
        assert_eq!(restored.run_epoch(), chain.run_epoch());
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let v = Value::parse(r#"{"version":"gauntlet-snapshot-v99"}"#).unwrap();
        let err = RunSnapshot::from_json(&v).unwrap_err();
        assert!(err.to_string().contains("unsupported snapshot version"), "{err}");
    }
}
