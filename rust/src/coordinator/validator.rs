//! One Gauntlet validator: fast eval over all peers, primary eval over a
//! random subset, score maintenance, and the weight vector it commits to
//! the chain each round (Algorithm 1, validator loop).
//!
//! [`Validator::evaluate_round`] is chain-free so several validators can be
//! evaluated concurrently by `coordinator::run`: the coordinator snapshots
//! the on-chain read keys once, hands each validator an [`ExecBackend`]
//! handle (an `ExecClient` when running parallel), and commits the
//! returned weight vectors to the chain afterwards, in validator order.

use std::collections::BTreeMap;

use anyhow::Result;

use super::fast_eval::{fast_evaluate_all, FastViolation, RoundChecks};
use super::primary_eval::{PrimaryEval, PrimaryEvaluator};
use super::round::RoundClock;
use super::scoring::{normalize_scores, top_g_weights, ScoreBook};
use super::GauntletParams;
use crate::chain::{Chain, Uid};
use crate::data::Corpus;
use crate::demo::wire::Submission;
use crate::runtime::{ExecBackend, ThetaShared, WorkerPool};
use crate::storage::{ObjectStore, ReadKey};
use crate::util::Rng;

/// Everything a validator decided in one round.
#[derive(Debug, Default)]
pub struct RoundOutcome {
    /// Fast-evaluation pass/fail per peer.
    pub fast_pass: BTreeMap<Uid, bool>,
    /// The phi multiplier applied to each peer's PoC EMA this round
    /// (1.0 = compliant, `phi_penalty` on any fast-check violation) —
    /// surfaced so the round-event stream can report verdict + phi.
    pub fast_phi: BTreeMap<Uid, f64>,
    /// Primary evaluations performed this round (the sampled S_t).
    pub evaluated: Vec<(Uid, PrimaryEval)>,
    /// Normalized incentives x^norm (eq. 5) over all known peers.
    pub incentives: Vec<(Uid, f64)>,
    /// Aggregation weights w_p (eq. 6) — peers in the top G.
    pub agg_weights: Vec<(Uid, f64)>,
    /// Submissions that passed every fast check (aggregation candidates).
    pub valid_submissions: BTreeMap<Uid, Submission>,
    /// Peers whose submission GET spent retries on transient storage
    /// faults (uid → retries). The coordinator turns these into
    /// `StorageRetry` events in deterministic validator/peer order.
    pub fast_retries: BTreeMap<Uid, u32>,
    /// Peers whose submission could not be read at all (retry budget
    /// exhausted or eclipsed view), in peer order — surfaced as
    /// `SubmissionUnavailable` events and scored as misses.
    pub unavailable: Vec<Uid>,
}

pub struct Validator {
    /// Chain identity (a staked neuron).
    pub uid: Uid,
    pub book: ScoreBook,
    pub params: GauntletParams,
    evaluator: PrimaryEvaluator,
    rng: Rng,
    /// Reusable SyncScore probe scratch: the fast-eval probe is
    /// re-gathered from theta every round, and reusing this buffer keeps
    /// the per-round validator loop allocation-free.
    probe: Vec<f32>,
}

impl Validator {
    pub fn new(uid: Uid, params: GauntletParams, padded_count: usize, seed: u64) -> Self {
        Validator {
            uid,
            book: ScoreBook::new(params.gamma),
            rng: Rng::from_parts(&["validator", &uid.to_string(), &seed.to_string()]),
            evaluator: PrimaryEvaluator::new(padded_count),
            params,
            probe: Vec::new(),
        }
    }

    /// Evaluate one communication round: fast checks over all peers
    /// (fanned out over at most `fanout` workers of the run's persistent
    /// `pool` — safe even when this call itself runs on a pool worker),
    /// primary evaluation of the sampled subset, and the resulting
    /// incentive / aggregation weights. Pure with respect to the chain —
    /// the caller commits `RoundOutcome::incentives` via
    /// [`Chain::set_weights`].
    ///
    /// Every stateful step (phi penalties, EMA updates, rating matches,
    /// the sampling RNG) runs in peer order on this thread, so the outcome
    /// is independent of `fanout` — the determinism the parallel pipeline
    /// relies on.
    /// `theta` is the round's frozen parameter snapshot as a shared
    /// handle: evaluation requests clone the `Arc`, so a funneled backend
    /// (`ExecClient`) ships a pointer per sweep, not a theta-sized copy.
    #[allow(clippy::too_many_arguments)]
    pub fn evaluate_round<E: ExecBackend + ?Sized>(
        &mut self,
        exec: &E,
        corpus: &Corpus,
        theta: &ThetaShared,
        round: u64,
        clock: &RoundClock,
        store: &ObjectStore,
        read_keys: &BTreeMap<Uid, ReadKey>,
        peer_uids: &[Uid],
        lr_t: f32,
        pool: &WorkerPool,
        fanout: usize,
    ) -> Result<RoundOutcome> {
        let meta = exec.meta();
        meta.sync_probe_into(theta, &mut self.probe);
        let mut out = RoundOutcome::default();

        // ---- fast evaluation over ALL peers (F_t; §3.2 — this always
        // includes the current top-G so bad actors are evicted quickly) ---
        let keyed: Vec<(Uid, ReadKey)> = peer_uids
            .iter()
            .map(|&uid| {
                let rk = read_keys
                    .get(&uid)
                    .ok_or_else(|| anyhow::anyhow!("peer {uid} has no read key on chain"))?;
                Ok((uid, rk.clone()))
            })
            .collect::<Result<_>>()?;
        let checks = RoundChecks {
            round,
            coeff_count: meta.coeff_count,
            padded_count: meta.padded_count,
            probe_len: self.probe.len(),
            validator_probe: &self.probe,
            lr: lr_t,
            sync_threshold: self.params.sync_threshold,
            window: clock.put_window(round),
            reader: self.uid,
            retry: self.params.retry.clone(),
        };
        let fast = fast_evaluate_all(store, &keyed, &checks, pool, fanout)?;
        for (uid, outcome) in fast {
            let passed = outcome.passed();
            let phi = outcome.phi(self.params.phi_penalty);
            if outcome.retries > 0 {
                out.fast_retries.insert(uid, outcome.retries);
            }
            if outcome.violations.contains(&FastViolation::Unavailable) {
                out.unavailable.push(uid);
            }
            self.book.ensure(uid);
            self.book.apply_fast_penalty(uid, phi);
            out.fast_pass.insert(uid, passed);
            out.fast_phi.insert(uid, phi);
            if passed {
                if let Some(sub) = outcome.submission {
                    out.valid_submissions.insert(uid, sub);
                }
            }
        }

        // ---- primary evaluation on a random subset S_t of valid peers ---
        let candidates: Vec<Uid> = out.valid_submissions.keys().copied().collect();
        let sample = self.rng.choose_k(&candidates, self.params.eval_sample);
        let beta = self.params.beta_frac * lr_t; // beta_t = c * alpha_t
        // One batched sweep for the whole sample: a native backend
        // (SimExec) pays one token-direction derivation and one theta
        // pass, and the exec-service funnel carries one request instead
        // of |S_t|. Bit-identical to the old per-peer evaluate loop.
        let peers: Vec<(Uid, &crate::demo::SparseGrad)> =
            sample.iter().map(|&uid| (uid, &out.valid_submissions[&uid].grad)).collect();
        let evals =
            self.evaluator.evaluate_batch(exec, theta, &peers, round, corpus, beta)?;
        let mut scores_rand = Vec::with_capacity(sample.len());
        for (&uid, ev) in sample.iter().zip(evals) {
            self.book.record_primary(uid, ev.score_assigned, ev.score_rand);
            scores_rand.push(ev.score_rand);
            out.evaluated.push((uid, ev));
        }
        self.book.rate_match(&sample, &scores_rand);

        // ---- PEERSCORE -> eq.5 normalization -> eq.6 top-G weights ------
        let raw: Vec<(Uid, f64)> =
            peer_uids.iter().map(|&u| (u, self.book.peer_score(u))).collect();
        let normed = normalize_scores(
            &raw.iter().map(|(_, s)| *s).collect::<Vec<_>>(),
            self.params.norm_power,
        );
        out.incentives = raw.iter().map(|(u, _)| *u).zip(normed).collect();
        out.agg_weights = top_g_weights(&out.incentives, self.params.top_g);
        Ok(out)
    }

    /// Forget everything about a peer: called when the chain recycles its
    /// uid to a new occupant, so the newcomer starts from the fresh
    /// OpenSkill prior with no PoC / phi / fast-fail history.
    pub fn forget_peer(&mut self, uid: Uid) {
        self.book.remove(uid);
    }

    /// The sampling RNG's raw state (run snapshots: `choose_k` draws must
    /// continue mid-stream on resume).
    pub fn rng_state(&self) -> u64 {
        self.rng.state()
    }

    /// Restore the sampling RNG mid-stream (snapshot resume).
    pub fn set_rng_state(&mut self, state: u64) {
        self.rng = Rng::from_state(state);
    }

    /// Sequential convenience kept for tests and small tools: evaluate the
    /// round on this thread and commit the weights to the chain, like the
    /// original single-threaded validator loop did.
    #[allow(clippy::too_many_arguments)]
    pub fn process_round<E: ExecBackend + ?Sized>(
        &mut self,
        exec: &E,
        corpus: &Corpus,
        theta: &[f32],
        round: u64,
        clock: &RoundClock,
        store: &ObjectStore,
        chain: &mut Chain,
        peer_uids: &[Uid],
        lr_t: f32,
    ) -> Result<RoundOutcome> {
        let read_keys = chain_read_keys(chain, peer_uids)?;
        let pool = WorkerPool::inline();
        let theta: ThetaShared = theta.into(); // one copy; callers stay slice-based
        let out = self.evaluate_round(
            exec, corpus, &theta, round, clock, store, &read_keys, peer_uids, lr_t, &pool, 1,
        )?;
        chain.set_weights(self.uid, &out.incentives)?;
        Ok(out)
    }
}

/// Snapshot the on-chain bucket read keys for `peer_uids` (§5: readers use
/// the keys peers posted at registration). Done once per round by the
/// coordinator so validator workers don't contend on the chain.
pub fn chain_read_keys(chain: &Chain, peer_uids: &[Uid]) -> Result<BTreeMap<Uid, ReadKey>> {
    peer_uids
        .iter()
        .map(|&uid| {
            let rk = chain
                .neuron(uid)
                .and_then(|n| n.bucket_read_key.clone())
                .ok_or_else(|| anyhow::anyhow!("peer {uid} has no read key on chain"))?;
            Ok((uid, rk))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    //! Validator round-loop integration tests (needing artifacts) live in
    //! `rust/tests/integration.rs` and the SimExec-backed pipeline tests in
    //! `rust/tests/parallel_determinism.rs`; scoring/fast-eval units are
    //! tested in their own modules.

    use super::*;

    #[test]
    fn round_outcome_default_is_empty() {
        let o = RoundOutcome::default();
        assert!(o.fast_pass.is_empty() && o.evaluated.is_empty());
        assert!(o.incentives.is_empty() && o.agg_weights.is_empty());
    }

    #[test]
    fn validator_rng_is_deterministic_per_uid() {
        let a = Validator::new(7, GauntletParams::default(), 16, 1);
        let b = Validator::new(7, GauntletParams::default(), 16, 1);
        let mut ra = a.rng.clone();
        let mut rb = b.rng.clone();
        assert_eq!(ra.next_u64(), rb.next_u64());
    }

    #[test]
    fn chain_read_keys_requires_registration() {
        let mut chain = Chain::new();
        let uid = chain.register("p0").unwrap();
        assert!(chain_read_keys(&chain, &[uid]).is_err(), "no key posted yet");
        chain.post_read_key(uid, ReadKey("rk-test".into())).unwrap();
        let keys = chain_read_keys(&chain, &[uid]).unwrap();
        assert_eq!(keys[&uid], ReadKey("rk-test".into()));
    }
}
