//! One Gauntlet validator: fast eval over all peers, primary eval over a
//! random subset, score maintenance, and the weight vector it commits to
//! the chain each round (Algorithm 1, validator loop).

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use super::fast_eval::{fast_evaluate, FastEvalCtx, FastEvalOutcome};
use super::primary_eval::{PrimaryEval, PrimaryEvaluator};
use super::round::RoundClock;
use super::scoring::{normalize_scores, top_g_weights, ScoreBook};
use super::GauntletParams;
use crate::chain::{Chain, Uid};
use crate::data::Corpus;
use crate::demo::wire::Submission;
use crate::runtime::Executor;
use crate::storage::ObjectStore;
use crate::util::Rng;

/// Everything a validator decided in one round.
#[derive(Debug, Default)]
pub struct RoundOutcome {
    /// Fast-evaluation pass/fail per peer.
    pub fast_pass: BTreeMap<Uid, bool>,
    /// Primary evaluations performed this round (the sampled S_t).
    pub evaluated: Vec<(Uid, PrimaryEval)>,
    /// Normalized incentives x^norm (eq. 5) over all known peers.
    pub incentives: Vec<(Uid, f64)>,
    /// Aggregation weights w_p (eq. 6) — peers in the top G.
    pub agg_weights: Vec<(Uid, f64)>,
    /// Submissions that passed every fast check (aggregation candidates).
    pub valid_submissions: BTreeMap<Uid, Submission>,
}

pub struct Validator {
    /// Chain identity (a staked neuron).
    pub uid: Uid,
    pub book: ScoreBook,
    pub params: GauntletParams,
    evaluator: PrimaryEvaluator,
    rng: Rng,
}

impl Validator {
    pub fn new(uid: Uid, params: GauntletParams, padded_count: usize, seed: u64) -> Self {
        Validator {
            uid,
            book: ScoreBook::new(params.gamma),
            rng: Rng::from_parts(&["validator", &uid.to_string(), &seed.to_string()]),
            evaluator: PrimaryEvaluator::new(padded_count),
            params,
        }
    }

    /// Process one communication round end-to-end for this validator and
    /// commit the resulting weights to the chain.
    #[allow(clippy::too_many_arguments)]
    pub fn process_round(
        &mut self,
        exec: &Executor,
        corpus: &Corpus,
        theta: &[f32],
        round: u64,
        clock: &RoundClock,
        store: &ObjectStore,
        chain: &mut Chain,
        peer_uids: &[Uid],
        lr_t: f32,
    ) -> Result<RoundOutcome> {
        let meta = &exec.meta;
        let probe = meta.sync_probe(theta);
        let (w_open, w_close) = clock.put_window(round);
        let mut out = RoundOutcome::default();

        // ---- fast evaluation over ALL peers (F_t; §3.2 — this always
        // includes the current top-G so bad actors are evicted quickly) ---
        for &uid in peer_uids {
            let bucket = format!("peer-{uid}");
            let rk = chain
                .neuron(uid)
                .and_then(|n| n.bucket_read_key.clone())
                .with_context(|| format!("peer {uid} has no read key on chain"))?;
            let key = Submission::object_key(uid, round);
            let get = store
                .get_within_window(&bucket, &rk, &key, w_open, w_close)
                .with_context(|| format!("reading {bucket}/{key}"))?;
            let ctx = FastEvalCtx {
                uid,
                round,
                coeff_count: meta.coeff_count,
                padded_count: meta.padded_count,
                probe_len: probe.len(),
                validator_probe: &probe,
                lr: lr_t,
                sync_threshold: self.params.sync_threshold,
            };
            let outcome: FastEvalOutcome = fast_evaluate(&get, &ctx);
            let passed = outcome.passed();
            self.book.ensure(uid);
            self.book.apply_fast_penalty(uid, outcome.phi(self.params.phi_penalty));
            out.fast_pass.insert(uid, passed);
            if passed {
                if let Some(sub) = outcome.submission {
                    out.valid_submissions.insert(uid, sub);
                }
            }
        }

        // ---- primary evaluation on a random subset S_t of valid peers ---
        let candidates: Vec<Uid> = out.valid_submissions.keys().copied().collect();
        let sample = self.rng.choose_k(&candidates, self.params.eval_sample);
        let beta = self.params.beta_frac * lr_t; // beta_t = c * alpha_t
        let mut scores_rand = Vec::with_capacity(sample.len());
        for &uid in &sample {
            let sub = &out.valid_submissions[&uid];
            let ev = self.evaluator.evaluate(
                exec, theta, uid, round, &sub.grad, corpus, beta,
            )?;
            self.book.record_primary(uid, ev.score_assigned, ev.score_rand);
            scores_rand.push(ev.score_rand);
            out.evaluated.push((uid, ev));
        }
        self.book.rate_match(&sample, &scores_rand);

        // ---- PEERSCORE -> eq.5 normalization -> eq.6 top-G weights ------
        let raw: Vec<(Uid, f64)> =
            peer_uids.iter().map(|&u| (u, self.book.peer_score(u))).collect();
        let normed = normalize_scores(
            &raw.iter().map(|(_, s)| *s).collect::<Vec<_>>(),
            self.params.norm_power,
        );
        out.incentives = raw.iter().map(|(u, _)| *u).zip(normed).collect();
        out.agg_weights = top_g_weights(&out.incentives, self.params.top_g);

        // ---- commit to chain --------------------------------------------
        chain.set_weights(self.uid, &out.incentives)?;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    //! Validator round-loop integration tests (needing artifacts) live in
    //! `rust/tests/integration.rs`; scoring/fast-eval units are tested in
    //! their own modules.

    use super::*;

    #[test]
    fn round_outcome_default_is_empty() {
        let o = RoundOutcome::default();
        assert!(o.fast_pass.is_empty() && o.evaluated.is_empty());
        assert!(o.incentives.is_empty() && o.agg_weights.is_empty());
    }

    #[test]
    fn validator_rng_is_deterministic_per_uid() {
        let a = Validator::new(7, GauntletParams::default(), 16, 1);
        let b = Validator::new(7, GauntletParams::default(), 16, 1);
        let mut ra = a.rng.clone();
        let mut rb = b.rng.clone();
        assert_eq!(ra.next_u64(), rb.next_u64());
    }
}
