//! Primary evaluation (§3.1): the compute-heavy heart of Gauntlet.
//!
//! For each sampled peer p the validator decodes the peer's pseudo-gradient
//! into the dense DCT-coefficient space, applies a scaled **signed** step
//! `theta - beta * sign(IDCT(q_p))` inside the fused `eval_peer` artifact,
//! and measures the loss drop (eq. 2) on two data subsets:
//!
//! - the peer's **assigned** shard D_t^p (re-derived from public seeds),
//! - a fresh **random** shard D_t^rand.
//!
//! The random-shard LossScore feeds the OpenSkill ranking; the sign of the
//! assigned-minus-random difference feeds the proof-of-computation EMA
//! (eq. 3), catching copiers and duplicators who did not actually train on
//! their assigned data.

use anyhow::Result;

use crate::data::Corpus;
use crate::demo::SparseGrad;
use crate::runtime::{EvalPeerCase, ExecBackend, ThetaShared};

/// Result of one primary evaluation.
#[derive(Clone, Copy, Debug)]
pub struct PrimaryEval {
    /// LossScore on the assigned shard: L(theta, D^p) - L(theta', D^p).
    pub score_assigned: f64,
    /// LossScore on the random shard: L(theta, D^rand) - L(theta', D^rand).
    pub score_rand: f64,
    /// Raw losses (diagnostics / Fig. 2 series).
    pub loss_before_assigned: f64,
    pub loss_before_rand: f64,
}

/// Scratch buffer reuse across evaluations (the dense coefficient vector is
/// the largest allocation on the validator's hot path).
pub struct PrimaryEvaluator {
    dense: Vec<f32>,
}

impl PrimaryEvaluator {
    pub fn new(padded_count: usize) -> Self {
        PrimaryEvaluator { dense: vec![0.0; padded_count] }
    }

    /// Evaluate one peer's pseudo-gradient at round `round`.
    ///
    /// `beta` is the scaled evaluation step size (beta = beta_frac * lr,
    /// with beta_frac < 1 — §3.1 explains why stepping with the full lr
    /// over-penalizes individual contributions).
    ///
    /// `exec` is any [`ExecBackend`]; in the parallel pipeline this is an
    /// `ExecClient` whose calls are served on the backend's owning thread.
    #[allow(clippy::too_many_arguments)]
    pub fn evaluate<E: ExecBackend + ?Sized>(
        &mut self,
        exec: &E,
        theta: &[f32],
        uid: u32,
        round: u64,
        grad: &SparseGrad,
        corpus: &Corpus,
        beta: f32,
    ) -> Result<PrimaryEval> {
        let meta = exec.meta();
        let padded = meta.padded_count;
        // Validator-side decode: scatter the sparse submission into the
        // dense coefficient space (normalized exactly like aggregation
        // normalizes, so scale games don't help here either).
        self.dense.clear();
        self.dense.resize(padded, 0.0);
        let norm = grad.l2_norm();
        if norm > 1e-12 {
            grad.scatter_into(&mut self.dense, (1.0 / norm) as f32);
        }

        let (b, s1) = (meta.batch, meta.seq + 1);
        // The peer's assigned shard, microbatch 0 — the subset the PoC
        // contract requires it to have trained on.
        let tok_assigned = corpus.assigned_shard(uid, round, 0, b, s1);
        let tok_rand = corpus.random_eval(round, uid, b, s1);

        let (la0, la1, lr0, lr1) =
            exec.eval_peer(theta, &self.dense, beta, &tok_assigned, &tok_rand)?;
        Ok(PrimaryEval {
            score_assigned: la0 as f64 - la1 as f64,
            score_rand: lr0 as f64 - lr1 as f64,
            loss_before_assigned: la0 as f64,
            loss_before_rand: lr0 as f64,
        })
    }

    /// Evaluate a whole sampled subset S_t in one backend call.
    ///
    /// The dense scratch becomes a flat `peers × padded_count` coefficient
    /// matrix (reused across rounds), each peer's shards are derived
    /// exactly as [`PrimaryEvaluator::evaluate`] derives them, and one
    /// [`ExecBackend::eval_peer_batch`] sweep scores everything — so a
    /// native batched backend pays one theta pass for the whole sample.
    /// Results are in `peers` order and bit-identical to calling
    /// `evaluate` per peer.
    pub fn evaluate_batch<E: ExecBackend + ?Sized>(
        &mut self,
        exec: &E,
        theta: &ThetaShared,
        peers: &[(u32, &SparseGrad)],
        round: u64,
        corpus: &Corpus,
        beta: f32,
    ) -> Result<Vec<PrimaryEval>> {
        let meta = exec.meta();
        let padded = meta.padded_count;
        self.dense.clear();
        self.dense.resize(peers.len() * padded, 0.0);
        for ((_, grad), row) in peers.iter().zip(self.dense.chunks_mut(padded.max(1))) {
            let norm = grad.l2_norm();
            if norm > 1e-12 {
                grad.scatter_into(row, (1.0 / norm) as f32);
            }
        }

        let (b, s1) = (meta.batch, meta.seq + 1);
        let toks: Vec<(Vec<i32>, Vec<i32>)> = peers
            .iter()
            .map(|&(uid, _)| {
                (
                    corpus.assigned_shard(uid, round, 0, b, s1),
                    corpus.random_eval(round, uid, b, s1),
                )
            })
            .collect();
        let cases: Vec<EvalPeerCase<'_>> = self
            .dense
            .chunks(padded.max(1))
            .zip(&toks)
            .map(|(coeff, (tok_assigned, tok_rand))| EvalPeerCase {
                coeff,
                tok_assigned,
                tok_rand,
            })
            .collect();
        let raw = exec.eval_peer_batch_shared(theta, beta, &cases)?;
        Ok(raw
            .into_iter()
            .map(|(la0, la1, lr0, lr1)| PrimaryEval {
                score_assigned: la0 as f64 - la1 as f64,
                score_rand: lr0 as f64 - lr1 as f64,
                loss_before_assigned: la0 as f64,
                loss_before_rand: lr0 as f64,
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    //! Integration tests for primary evaluation live in
    //! `rust/tests/integration.rs` (they need compiled artifacts); the unit
    //! tests here cover the pure parts.

    use super::*;

    #[test]
    fn evaluator_scratch_is_reused_and_zeroed() {
        let mut ev = PrimaryEvaluator::new(8);
        let g = SparseGrad { vals: vec![3.0], idx: vec![2] };
        g.scatter_into(&mut ev.dense, 1.0);
        assert_eq!(ev.dense[2], 3.0);
        // a second evaluate() call zeroes first — simulate the zeroing step
        ev.dense.iter_mut().for_each(|x| *x = 0.0);
        assert!(ev.dense.iter().all(|&x| x == 0.0));
    }
}
