//! The Gauntlet coordinator — the paper's contribution (§3).
//!
//! Two-phase incentive evaluation over a synchronous DeMo training run:
//!
//! - [`fast_eval`]: cheap per-round checks over a large peer subset — put
//!   window, presence, wire format, SyncScore — applying the multiplicative
//!   `phi` penalty to the proof-of-computation EMA (§3.2).
//! - [`primary_eval`]: the compute-heavy LossScore (eq. 2) on a small
//!   random subset, on both the peer's **assigned** data shard and a fresh
//!   **random** shard, feeding the OpenSkill LossRating and the
//!   proof-of-computation EMA mu_p (eq. 3).
//! - [`scoring`]: PEERSCORE = mu * LossRating (eq. 4), the power
//!   normalization (eq. 5) and top-G aggregation weights (eq. 6).
//! - [`validator`]: glues the phases together for one validator identity.
//! - [`round`]: the communication-round clock and put windows.
//! - [`checkpoint`]: infrequent checkpoints + signed-update replay catchup.
//! - [`baseline`]: the centralized AdamW-DDP comparison run (Fig. 1).
//! - [`run`]: the full system — chain + storage + peers + validators —
//!   driving a live training run end to end.

pub mod baseline;
pub mod checkpoint;
pub mod engine;
pub mod events;
pub mod fast_eval;
pub mod primary_eval;
pub mod round;
pub mod run;
pub mod schedule;
pub mod scoring;
pub mod snapshot;
pub mod validator;

pub use engine::{GauntletBuilder, GauntletEngine};

/// All Gauntlet hyperparameters in one place (defaults follow the paper
/// where it states values: phi = 0.75, sync threshold = 3, c = 2, beta =
/// c_beta * lr with c_beta < 1).
///
/// ```
/// use gauntlet::coordinator::GauntletParams;
///
/// // Paper defaults out of the box…
/// let p = GauntletParams::default();
/// assert_eq!(p.phi_penalty, 0.75);
/// assert_eq!(p.sync_threshold, 3.0);
/// assert_eq!(p.norm_power, 2.0);
///
/// // …and the §3.1 schedule contract: the evaluation step size follows
/// // the round's learning rate as beta_t = beta_frac * alpha_t, always
/// // smaller than a full signed step.
/// let alpha_t = p.schedule.lr_at(0, p.lr);
/// let beta_t = p.beta_frac * alpha_t;
/// assert!(beta_t < alpha_t);
///
/// // Tighter eviction for a small-population run:
/// let strict = GauntletParams { phi_penalty: 0.5, top_g: 3, ..p };
/// assert!(strict.phi_penalty < strict.sync_threshold);
/// ```
#[derive(Clone, Debug)]
pub struct GauntletParams {
    /// EMA decay gamma for the proof-of-computation score mu_p (eq. 3).
    pub gamma: f64,
    /// Multiplicative penalty on mu_p for failing any fast check (§3.2).
    pub phi_penalty: f64,
    /// SyncScore filter threshold ("in practice, setting the threshold to 3").
    pub sync_threshold: f64,
    /// beta = beta_frac * lr for the primary-evaluation step (beta_frac < 1).
    pub beta_frac: f32,
    /// Exponent c of the incentive normalization (eq. 5); paper uses 2.
    pub norm_power: f64,
    /// Number of top peers aggregated each round (eq. 6; paper: G = 15).
    pub top_g: usize,
    /// |S_t|: peers primary-evaluated per round (paper: 5).
    pub eval_sample: usize,
    /// Outer (base) learning rate alpha for the signed update (eq. 1).
    pub lr: f32,
    /// Per-round schedule: alpha_t = schedule.lr_at(t, lr); the evaluation
    /// step follows as beta_t = beta_frac * alpha_t (§3.1).
    pub schedule: schedule::LrSchedule,
    /// DeMo error-feedback momentum decay.
    pub demo_decay: f32,
    /// Number of grad microbatches an honest peer runs per round at
    /// data multiplier 1.0 (the "baseline training script").
    pub base_microbatches: usize,
    /// Checkpoint every this many rounds (catchup replays signed updates).
    pub checkpoint_every: u64,
    /// Storage retry budget + backoff for peer PUTs and validator GETs
    /// (transient faults only; definitive errors degrade immediately).
    pub retry: crate::storage::RetryPolicy,
}

impl Default for GauntletParams {
    fn default() -> Self {
        GauntletParams {
            gamma: 0.9,
            phi_penalty: 0.75,
            sync_threshold: 3.0,
            beta_frac: 0.5,
            norm_power: 2.0,
            top_g: 4,
            eval_sample: 3,
            lr: 0.02,
            schedule: schedule::LrSchedule::Constant,
            demo_decay: 0.999,
            base_microbatches: 1,
            checkpoint_every: 25,
            retry: crate::storage::RetryPolicy::default(),
        }
    }
}
