//! Fast evaluation (§3.2): low-cost checks over a large peer subset.
//!
//! (a) put-window timing, (b) presence, (c) wire format + declared tensor
//! dimensions, plus the SyncScore heuristic estimating how many signed
//! update steps a peer's model has diverged from the validator's. Any
//! violation yields phi = `phi_penalty` (< 1), applied multiplicatively to
//! the peer's mu — repeated failures crash the peer's PEERSCORE and evict
//! it from the top-G aggregation within a few rounds.
//!
//! Fast evaluation is the widest stage of the per-round pipeline — every
//! validator runs it over *every* registered peer — and each peer's checks
//! are independent, so [`fast_evaluate_all`] fans them out across a worker
//! pool (see the README's "Scaling the round pipeline" section). Results
//! come back in peer order, which keeps the validator's bookkeeping, and
//! therefore PEERSCORE, bit-identical to a sequential sweep.

use crate::chain::Uid;
use crate::demo::wire::{Submission, WireError};
use crate::demo::SparseGrad;
use crate::runtime::WorkerPool;
use crate::storage::{ObjectStore, ReadKey, RetryPolicy, SimTime, WindowedGet};

/// Why fast evaluation failed (diagnostics + tests).
#[derive(Clone, Debug, PartialEq)]
pub enum FastViolation {
    Missing,
    TooEarly,
    TooLate,
    BadFormat(String),
    WrongRound { declared: u64, expected: u64 },
    WrongUid { declared: u32, expected: u32 },
    Desynchronized { sync_score: f64 },
    /// The submission could not be *read at all*: the GET retry budget
    /// exhausted on transient failures, or the reader is eclipsed from the
    /// peer's bucket. Scored as a miss — the run never aborts for it.
    Unavailable,
}

/// Outcome of fast evaluation for one peer.
#[derive(Clone, Debug)]
pub struct FastEvalOutcome {
    pub violations: Vec<FastViolation>,
    /// A validated submission, if one was decodable (kept even when the
    /// peer failed SyncScore, so diagnostics can inspect it; the validator
    /// only *aggregates* submissions from peers that passed everything).
    pub submission: Option<Submission>,
    /// GET retries spent reading this peer's submission (0 on a clean
    /// first read). Surfaced so the coordinator can emit `StorageRetry`
    /// events in deterministic order — workers must not emit themselves.
    pub retries: u32,
}

impl FastEvalOutcome {
    pub fn passed(&self) -> bool {
        self.violations.is_empty()
    }

    /// phi multiplier (§3.2): `penalty` on any failure, 1 otherwise. The
    /// validator applies it multiplicatively to the peer's
    /// proof-of-computation EMA mu, so repeated failures decay the peer's
    /// PEERSCORE geometrically.
    ///
    /// ```
    /// use gauntlet::coordinator::fast_eval::{FastEvalOutcome, FastViolation};
    ///
    /// let clean = FastEvalOutcome { violations: vec![], submission: None, retries: 0 };
    /// assert_eq!(clean.phi(0.75), 1.0); // compliant: mu untouched
    ///
    /// let late = FastEvalOutcome {
    ///     violations: vec![FastViolation::TooLate],
    ///     submission: None,
    ///     retries: 0,
    /// };
    /// assert_eq!(late.phi(0.75), 0.75); // any violation: mu *= phi_penalty
    /// ```
    pub fn phi(&self, penalty: f64) -> f64 {
        if self.passed() {
            1.0
        } else {
            penalty
        }
    }
}

/// SyncScore (§3.2): mean absolute difference between the validator's and
/// the peer's sampled parameters, in units of the signed step size alpha —
/// a heuristic count of divergent update steps. Degenerate inputs (empty
/// probe, or a paused schedule with `lr == 0`) score 0: with no step size
/// there is no unit of divergence, and the check abstains rather than
/// dividing by zero.
pub fn sync_score(validator_probe: &[f32], peer_probe: &[f32], lr: f32) -> f64 {
    assert_eq!(validator_probe.len(), peer_probe.len());
    if validator_probe.is_empty() || lr == 0.0 {
        return 0.0;
    }
    let n = validator_probe.len() as f64;
    let sum = crate::util::det_sum(
        validator_probe
            .iter()
            .zip(peer_probe)
            .map(|(a, b)| (*a as f64 - *b as f64).abs()),
    );
    sum / (lr as f64 * n)
}

/// Structural expectations for a submission in this round.
pub struct FastEvalCtx<'a> {
    pub uid: u32,
    pub round: u64,
    /// Expected coefficient count C (meta.coeff_count).
    pub coeff_count: usize,
    /// Dense coefficient space size (meta.padded_count).
    pub padded_count: usize,
    /// Expected probe length (2 per tensor).
    pub probe_len: usize,
    /// The validator's own probe of theta_t.
    pub validator_probe: &'a [f32],
    pub lr: f32,
    pub sync_threshold: f64,
}

/// Run every fast check against a windowed GET result.
pub fn fast_evaluate(get: &WindowedGet, ctx: &FastEvalCtx<'_>) -> FastEvalOutcome {
    let mut violations = Vec::new();
    let miss = |v: FastViolation| FastEvalOutcome {
        violations: vec![v],
        submission: None,
        retries: 0,
    };
    let obj = match get {
        WindowedGet::InWindow(obj) => obj,
        WindowedGet::Missing => return miss(FastViolation::Missing),
        WindowedGet::TooEarly(_) => return miss(FastViolation::TooEarly),
        WindowedGet::TooLate(_) => return miss(FastViolation::TooLate),
    };

    // `decode_object` memoizes the SHA-256 integrity verdict on the
    // shared `Arc<Object>`: one stored submission is read by every
    // validator each round, and only the first pays the hash.
    let sub = match Submission::decode_object(obj) {
        Ok(s) => s,
        Err(e @ (WireError::Truncated(_)
        | WireError::BadMagic(_)
        | WireError::BadVersion(_)
        | WireError::LengthMismatch { .. }
        | WireError::BadDigest)) => {
            return FastEvalOutcome {
                violations: vec![FastViolation::BadFormat(e.to_string())],
                submission: None,
                retries: 0,
            }
        }
    };

    if sub.round != ctx.round {
        violations.push(FastViolation::WrongRound { declared: sub.round, expected: ctx.round });
    }
    if sub.uid != ctx.uid {
        violations.push(FastViolation::WrongUid { declared: sub.uid, expected: ctx.uid });
    }
    if let Err(msg) = sub.grad.validate(ctx.coeff_count, ctx.padded_count) {
        violations.push(FastViolation::BadFormat(msg));
    }
    if sub.probe.len() != ctx.probe_len {
        violations.push(FastViolation::BadFormat(format!(
            "probe has {} values, expected {}",
            sub.probe.len(),
            ctx.probe_len
        )));
    } else {
        let s = sync_score(ctx.validator_probe, &sub.probe, ctx.lr);
        if s > ctx.sync_threshold {
            violations.push(FastViolation::Desynchronized { sync_score: s });
        }
    }
    FastEvalOutcome { violations, submission: Some(sub), retries: 0 }
}

/// The per-round inputs shared by every peer's fast checks (everything in
/// [`FastEvalCtx`] except the peer identity).
pub struct RoundChecks<'a> {
    pub round: u64,
    pub coeff_count: usize,
    pub padded_count: usize,
    pub probe_len: usize,
    pub validator_probe: &'a [f32],
    pub lr: f32,
    pub sync_threshold: f64,
    /// Inclusive `[open, close]` put window for this round.
    pub window: (SimTime, SimTime),
    /// The reading validator's uid — the *named reader* for the store's
    /// keyed fault draws and targeted eclipse faults.
    pub reader: Uid,
    /// Retry budget for transient GET failures. A retry salts the keyed
    /// fault draw with a higher attempt number (a genuinely fresh draw);
    /// an exhausted budget degrades the peer to
    /// [`FastViolation::Unavailable`] instead of aborting the round.
    pub retry: RetryPolicy,
}

impl RoundChecks<'_> {
    fn ctx_for(&self, uid: Uid) -> FastEvalCtx<'_> {
        FastEvalCtx {
            uid,
            round: self.round,
            coeff_count: self.coeff_count,
            padded_count: self.padded_count,
            probe_len: self.probe_len,
            validator_probe: self.validator_probe,
            lr: self.lr,
            sync_threshold: self.sync_threshold,
        }
    }
}

fn fast_evaluate_chunk(
    store: &ObjectStore,
    peers: &[(Uid, ReadKey)],
    checks: &RoundChecks<'_>,
) -> anyhow::Result<Vec<(Uid, FastEvalOutcome)>> {
    use anyhow::Context as _;
    use std::fmt::Write as _;
    let (open, close) = checks.window;
    let mut out = Vec::with_capacity(peers.len());
    // One bucket-name and one object-key buffer per worker, reused across
    // the whole sweep (fast eval runs per peer per validator per round —
    // the widest stage of the pipeline, so per-peer string allocations
    // multiply fastest here).
    let mut bucket = String::new();
    let mut key = String::new();
    let budget = checks.retry.max_attempts.max(1);
    for (uid, rk) in peers {
        bucket.clear();
        let _ = write!(bucket, "peer-{uid}");
        key.clear();
        Submission::write_object_key(&mut key, *uid, checks.round);
        // Bounded retry on *transient* GET failures only. Draws are keyed
        // on (bucket, key, reader, attempt), so the loop is deterministic
        // on any worker thread; definitive errors (eclipse → NotFound)
        // skip the budget and degrade immediately.
        let mut attempt: u32 = 0;
        let got = loop {
            match store.get_within_window_as(
                u64::from(checks.reader),
                attempt,
                &bucket,
                rk,
                &key,
                open,
                close,
            ) {
                Ok(g) => break Some(g),
                Err(e) if e.is_transient() && attempt + 1 < budget => attempt += 1,
                Err(e) if e.is_transient() => break None, // budget exhausted
                Err(crate::storage::StorageError::NotFound(_)) => break None,
                Err(e) => return Err(e).with_context(|| format!("reading {bucket}/{key}")),
            }
        };
        let outcome = match got {
            Some(g) => fast_evaluate(&g, &checks.ctx_for(*uid)),
            None => FastEvalOutcome {
                violations: vec![FastViolation::Unavailable],
                submission: None,
                retries: 0,
            },
        };
        out.push((*uid, FastEvalOutcome { retries: attempt, ..outcome }));
    }
    Ok(out)
}

/// Fast-evaluate every peer, fanning the independent per-peer checks out
/// over at most `fanout` workers of the run's persistent [`WorkerPool`]
/// (1 = sequential, on the calling thread). The result order is the input
/// peer order regardless of `fanout` or pool width, so downstream score
/// bookkeeping is deterministic. Safe to call from a pool worker (the
/// per-validator eval loop does): waiters help drain the shared queue, so
/// nested fan-out cannot deadlock.
pub fn fast_evaluate_all(
    store: &ObjectStore,
    peers: &[(Uid, ReadKey)],
    checks: &RoundChecks<'_>,
    pool: &WorkerPool,
    fanout: usize,
) -> anyhow::Result<Vec<(Uid, FastEvalOutcome)>> {
    if fanout <= 1 || peers.len() <= 1 {
        return fast_evaluate_chunk(store, peers, checks);
    }
    let per_chunk: Vec<anyhow::Result<Vec<(Uid, FastEvalOutcome)>>> =
        pool.scatter_ref(peers, fanout, |_base, ch| fast_evaluate_chunk(store, ch, checks));
    let mut out = Vec::with_capacity(peers.len());
    for r in per_chunk {
        out.extend(r?);
    }
    Ok(out)
}

/// Convenience for tests/benches: fast-evaluate an in-memory submission.
pub fn fast_evaluate_decoded(sub: &Submission, ctx: &FastEvalCtx<'_>) -> FastEvalOutcome {
    let obj = crate::storage::Object::new(String::new(), sub.encode(), 0);
    fast_evaluate(&WindowedGet::InWindow(std::sync::Arc::new(obj)), ctx)
}

/// Sanity helper used by both validator and peers: a well-formed empty
/// gradient placeholder (peers that have nothing still probe for sync).
pub fn empty_grad() -> SparseGrad {
    SparseGrad { vals: vec![], idx: vec![] }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::{Object, ProviderModel};
    use std::sync::Arc;

    fn ctx(probe: &[f32]) -> FastEvalCtx<'_> {
        FastEvalCtx {
            uid: 1,
            round: 10,
            coeff_count: 3,
            padded_count: 100,
            probe_len: probe.len(),
            validator_probe: probe,
            lr: 0.02,
            sync_threshold: 3.0,
        }
    }

    fn good_sub(probe: Vec<f32>) -> Submission {
        Submission {
            uid: 1,
            round: 10,
            grad: SparseGrad { vals: vec![1.0, -1.0, 0.5], idx: vec![0, 5, 99] },
            probe,
        }
    }

    #[test]
    fn compliant_submission_passes() {
        let vp = vec![0.5, -0.5];
        let out = fast_evaluate_decoded(&good_sub(vp.clone()), &ctx(&vp));
        assert!(out.passed(), "{:?}", out.violations);
        assert_eq!(out.phi(0.75), 1.0);
        assert!(out.submission.is_some());
    }

    #[test]
    fn missing_early_late_fail() {
        let vp = vec![0.0];
        let c = ctx(&vp);
        for (get, want) in [
            (WindowedGet::Missing, FastViolation::Missing),
            (WindowedGet::TooEarly(1), FastViolation::TooEarly),
            (WindowedGet::TooLate(2), FastViolation::TooLate),
        ] {
            let out = fast_evaluate(&get, &c);
            assert_eq!(out.violations, vec![want.clone()]);
            assert_eq!(out.phi(0.75), 0.75);
        }
    }

    #[test]
    fn corrupt_bytes_fail_format() {
        let vp = vec![0.0];
        let obj = Object::new("k".into(), vec![1, 2, 3], 0);
        let out = fast_evaluate(&WindowedGet::InWindow(Arc::new(obj)), &ctx(&vp));
        assert!(matches!(out.violations[0], FastViolation::BadFormat(_)));
    }

    #[test]
    fn wrong_dims_fail_format() {
        let vp = vec![0.0, 0.0];
        let mut sub = good_sub(vp.clone());
        sub.grad.vals.push(9.0); // now 4 vals vs declared layout of 3
        sub.grad.idx.push(1);
        let out = fast_evaluate_decoded(&sub, &ctx(&vp));
        assert!(out.violations.iter().any(|v| matches!(v, FastViolation::BadFormat(_))));
    }

    #[test]
    fn wrong_round_or_uid_detected() {
        let vp = vec![0.0, 0.0];
        let mut sub = good_sub(vp.clone());
        sub.round = 9;
        sub.uid = 7;
        let out = fast_evaluate_decoded(&sub, &ctx(&vp));
        assert!(out
            .violations
            .contains(&FastViolation::WrongRound { declared: 9, expected: 10 }));
        assert!(out.violations.contains(&FastViolation::WrongUid { declared: 7, expected: 1 }));
    }

    #[test]
    fn sync_score_counts_divergent_steps() {
        // peer diverged by exactly k signed steps on every sampled param:
        // SyncScore == k.
        let lr = 0.02f32;
        let vp = vec![1.0, -1.0, 0.5, 0.0];
        for k in 0..5 {
            let pp: Vec<f32> = vp.iter().map(|v| v + k as f32 * lr).collect();
            let s = sync_score(&vp, &pp, lr);
            assert!((s - k as f64).abs() < 1e-4, "k={k} s={s}");
        }
    }

    #[test]
    fn desync_beyond_threshold_fails() {
        let lr = 0.02f32;
        let vp = vec![1.0, -1.0];
        let pp: Vec<f32> = vp.iter().map(|v| v + 5.0 * lr).collect(); // 5 steps off
        let sub = good_sub(pp);
        let out = fast_evaluate_decoded(&sub, &ctx(&vp));
        assert!(matches!(
            out.violations[0],
            FastViolation::Desynchronized { sync_score } if sync_score > 3.0
        ));
        // 2 steps off passes the threshold-3 filter
        let pp2: Vec<f32> = vp.iter().map(|v| v + 2.0 * lr).collect();
        let out2 = fast_evaluate_decoded(&good_sub(pp2), &ctx(&vp));
        assert!(out2.passed(), "{:?}", out2.violations);
    }

    #[test]
    fn sync_score_empty_or_zero_lr_is_zero() {
        assert_eq!(sync_score(&[], &[], 0.02), 0.0, "empty probes abstain");
        assert_eq!(sync_score(&[1.0], &[2.0], 0.0), 0.0, "lr = 0 abstains");
        assert_eq!(sync_score(&[], &[], 0.0), 0.0, "both degenerate cases at once");
    }

    #[test]
    fn zero_lr_never_flags_desync() {
        // A paused schedule (alpha_t = 0) must not mass-flag honest peers:
        // with no step unit the SyncScore check abstains entirely.
        let vp = vec![1.0, -1.0];
        let pp = vec![9.0, 9.0]; // wildly different parameters
        let mut c = ctx(&vp);
        c.lr = 0.0;
        let out = fast_evaluate_decoded(&good_sub(pp), &c);
        assert!(
            !out.violations.iter().any(|v| matches!(v, FastViolation::Desynchronized { .. })),
            "{:?}",
            out.violations
        );
    }

    fn seeded_store_with_peers(n: usize, round: u64) -> (ObjectStore, Vec<(Uid, ReadKey)>, Vec<f32>) {
        let model = ProviderModel { mean_upload_ms: 100.0, jitter_ms: 0.0, ..Default::default() };
        let store = ObjectStore::new(model, 9);
        let probe = vec![0.25f32, -0.75];
        let mut peers = Vec::new();
        for uid in 0..n as u32 {
            let bucket = format!("peer-{uid}");
            let rk = store.create_bucket(&bucket, &bucket);
            // Peers 0, 3, 6, ... submit well-formed objects; 1 mod 3 are
            // late; 2 mod 3 stay silent.
            if uid % 3 == 0 {
                let sub = Submission {
                    uid,
                    round,
                    grad: SparseGrad { vals: vec![1.0, -1.0, 0.5], idx: vec![0, 5, 99] },
                    probe: probe.clone(),
                };
                store
                    .put(&bucket, &bucket, &Submission::object_key(uid, round), sub.encode(), 400)
                    .unwrap();
            } else if uid % 3 == 1 {
                store
                    .put(&bucket, &bucket, &Submission::object_key(uid, round), vec![0; 8], 9_999)
                    .unwrap();
            }
            peers.push((uid, rk));
        }
        (store, peers, probe)
    }

    fn checks<'a>(round: u64, probe: &'a [f32]) -> RoundChecks<'a> {
        RoundChecks {
            round,
            coeff_count: 3,
            padded_count: 100,
            probe_len: probe.len(),
            validator_probe: probe,
            lr: 0.02,
            sync_threshold: 3.0,
            window: (200, 2_000),
            reader: 99,
            retry: RetryPolicy::default(),
        }
    }

    #[test]
    fn fast_evaluate_all_parallel_matches_sequential() {
        let round = 4;
        let (store, peers, probe) = seeded_store_with_peers(13, round);
        let checks = checks(round, &probe);
        let pool = WorkerPool::new(4);
        let seq = fast_evaluate_all(&store, &peers, &checks, &pool, 1).unwrap();
        for fanout in [2, 4, 8, 32] {
            let par = fast_evaluate_all(&store, &peers, &checks, &pool, fanout).unwrap();
            assert_eq!(par.len(), seq.len());
            for ((ua, a), (ub, b)) in seq.iter().zip(&par) {
                assert_eq!(ua, ub, "peer order must be preserved at fanout {fanout}");
                assert_eq!(a.violations, b.violations);
                assert_eq!(a.submission, b.submission);
            }
        }
        // sanity: the three behaviour classes are classified as expected
        assert!(seq[0].1.passed());
        assert!(seq[1].1.violations.contains(&FastViolation::TooLate));
        assert!(seq[2].1.violations.contains(&FastViolation::Missing));
    }

    #[test]
    fn transient_get_failures_retry_then_degrade_to_unavailable() {
        let round = 4;
        let (mut store, peers, probe) = seeded_store_with_peers(6, round);
        store.model.get_fail_prob = 1.0;
        let c = checks(round, &probe);
        let pool = WorkerPool::new(2);
        let seq = fast_evaluate_all(&store, &peers, &c, &pool, 1).unwrap();
        for (uid, o) in &seq {
            assert_eq!(o.violations, vec![FastViolation::Unavailable], "uid {uid}");
            assert_eq!(o.retries, c.retry.max_attempts - 1, "budget fully spent");
            assert!(o.submission.is_none());
        }
        // Sequential and parallel degrade identically — keyed draws.
        let par = fast_evaluate_all(&store, &peers, &c, &pool, 4).unwrap();
        for ((ua, a), (ub, b)) in seq.iter().zip(&par) {
            assert_eq!(ua, ub);
            assert_eq!(a.violations, b.violations);
            assert_eq!(a.retries, b.retries);
        }
    }

    #[test]
    fn eclipsed_reader_degrades_immediately_without_spending_budget() {
        let round = 4;
        let (store, peers, probe) = seeded_store_with_peers(6, round);
        store.set_eclipse(99, "peer-0");
        let c = checks(round, &probe);
        let pool = WorkerPool::new(2);
        let out = fast_evaluate_all(&store, &peers, &c, &pool, 1).unwrap();
        assert_eq!(out[0].1.violations, vec![FastViolation::Unavailable]);
        assert_eq!(out[0].1.retries, 0, "NotFound is definitive: no retries");
        assert!(out[3].1.passed(), "other peers unaffected: {:?}", out[3].1.violations);
        // A different reader's view of peer-0 is intact.
        let mut c2 = checks(round, &probe);
        c2.reader = 98;
        let out2 = fast_evaluate_all(&store, &peers, &c2, &pool, 1).unwrap();
        assert!(out2[0].1.passed(), "{:?}", out2[0].1.violations);
    }

    #[test]
    fn corrupted_payloads_are_rejected_by_the_digest_verdict() {
        let round = 4;
        let (mut store, peers, probe) = seeded_store_with_peers(6, round);
        store.model.corrupt_prob = 1.0;
        let c = checks(round, &probe);
        let pool = WorkerPool::new(2);
        let out = fast_evaluate_all(&store, &peers, &c, &pool, 1).unwrap();
        // uids 0 and 3 submitted well-formed objects; every read is
        // damaged in transit, so the digest/frame verdict must reject them
        // as format failures — never a panic, never an abort.
        for i in [0usize, 3] {
            assert!(
                matches!(out[i].1.violations[0], FastViolation::BadFormat(_)),
                "uid {i}: {:?}",
                out[i].1.violations
            );
        }
        // Missing/late classifications are untouched by payload damage.
        assert!(out[1].1.violations.contains(&FastViolation::TooLate));
        assert!(out[2].1.violations.contains(&FastViolation::Missing));
    }
}
