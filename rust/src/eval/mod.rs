//! Downstream zero-shot evaluation harness (Table 1).
//!
//! The paper reports HellaSwag / PIQA / ARC-E `acc_norm`. Models at this
//! scale trained on a synthetic corpus cannot read English, so the harness
//! reproduces the *protocol* on synthetic analogues: multiple-choice tasks
//! where the correct continuation follows the corpus's generative pattern
//! and distractors do not. Scoring is identical to lm-eval-harness
//! `acc_norm`: pick the candidate with the highest length-normalized
//! logprob (here: lowest per-token loss from the `loss_per_seq` artifact).
//!
//! Suites (all chance-level 1/n_choices for an untrained model):
//!  - `synth-hellaswag`: 4 choices; distractors are uniform-random tails.
//!  - `synth-piqa`: 2 choices; distractor is the right tail with two
//!    tokens swapped (harder, tests local consistency).
//!  - `synth-arc-e`: 4 choices; distractors follow *other* patterns of the
//!    same corpus (hardest: requires inferring the active pattern).

use anyhow::Result;

use crate::data::{Corpus, Token};
use crate::runtime::ExecBackend;
use crate::util::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Suite {
    SynthHellaSwag,
    SynthPiqa,
    SynthArcE,
}

impl Suite {
    pub fn name(&self) -> &'static str {
        match self {
            Suite::SynthHellaSwag => "synth-hellaswag",
            Suite::SynthPiqa => "synth-piqa",
            Suite::SynthArcE => "synth-arc-e",
        }
    }
    pub fn n_choices(&self) -> usize {
        match self {
            Suite::SynthPiqa => 2,
            _ => 4,
        }
    }
    pub fn all() -> [Suite; 3] {
        [Suite::SynthHellaSwag, Suite::SynthPiqa, Suite::SynthArcE]
    }
}

/// One multiple-choice item: full candidate sequences (context + tail).
#[derive(Clone, Debug)]
pub struct Item {
    pub candidates: Vec<Vec<Token>>,
    pub correct: usize,
}

/// Deterministically generate `n` items for a suite.
pub fn generate_items(corpus: &Corpus, suite: Suite, n: usize, seq_plus1: usize) -> Vec<Item> {
    let mut items = Vec::with_capacity(n);
    let tail_len = seq_plus1 / 2;
    for i in 0..n {
        let mut rng = Rng::from_parts(&["eval", suite.name(), &corpus.seed.to_string(), &i.to_string()]);
        // The true sequence: one corpus document.
        let truth = corpus.batch(&["evaldoc", suite.name(), &i.to_string()], 1, seq_plus1);
        let ctx_len = seq_plus1 - tail_len;
        let mut candidates = Vec::with_capacity(suite.n_choices());
        let correct = rng.below(suite.n_choices() as u64) as usize;
        for c in 0..suite.n_choices() {
            if c == correct {
                candidates.push(truth.clone());
                continue;
            }
            let mut cand = truth.clone();
            match suite {
                Suite::SynthHellaSwag => {
                    // uniform-random tail
                    for t in cand[ctx_len..].iter_mut() {
                        *t = rng.below(corpus.vocab as u64) as Token;
                    }
                }
                Suite::SynthPiqa => {
                    // right tail with two positions swapped
                    let a = ctx_len + rng.below(tail_len as u64 / 2) as usize;
                    let b = ctx_len + tail_len / 2
                        + rng.below((tail_len - tail_len / 2) as u64) as usize;
                    cand.swap(a, b.min(seq_plus1 - 1));
                    if cand == truth {
                        // degenerate swap; force a change
                        cand[ctx_len] = (cand[ctx_len] + 1) % corpus.vocab as Token;
                    }
                }
                Suite::SynthArcE => {
                    // tail continued with a different pattern: take the
                    // tail of another document
                    let other = corpus.batch(
                        &["evaldoc-alt", suite.name(), &i.to_string(), &c.to_string()],
                        1,
                        seq_plus1,
                    );
                    cand[ctx_len..].copy_from_slice(&other[ctx_len..]);
                }
            }
            candidates.push(cand);
        }
        items.push(Item { candidates, correct });
    }
    items
}

/// Result of one suite evaluation.
#[derive(Clone, Debug)]
pub struct SuiteResult {
    pub suite: Suite,
    pub n_items: usize,
    pub acc_norm: f64,
    pub chance: f64,
}

/// Evaluate a model (flat params) on a suite. Candidates are scored in
/// batches through the fixed-shape `loss_per_seq` artifact; rows beyond the
/// candidate count are padding.
pub fn evaluate_suite<E: ExecBackend>(
    exec: &E,
    theta: &[f32],
    corpus: &Corpus,
    suite: Suite,
    n_items: usize,
) -> Result<SuiteResult> {
    let meta = exec.meta();
    let (b, s1) = (meta.batch, meta.seq + 1);
    let items = generate_items(corpus, suite, n_items, s1);
    let mut correct = 0usize;
    for item in &items {
        let k = item.candidates.len();
        let mut scores = vec![f64::INFINITY; k];
        // pack candidates into batches of B rows
        let mut row = 0usize;
        while row < k {
            let take = (k - row).min(b);
            let mut toks: Vec<Token> = Vec::with_capacity(b * s1);
            for r in 0..b {
                if r < take {
                    toks.extend_from_slice(&item.candidates[row + r]);
                } else {
                    toks.extend(std::iter::repeat(0).take(s1)); // padding row
                }
            }
            let losses = exec.loss_per_seq(theta, &toks)?;
            for r in 0..take {
                scores[row + r] = losses[r] as f64;
            }
            row += take;
        }
        let best = scores
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if best == item.correct {
            correct += 1;
        }
    }
    Ok(SuiteResult {
        suite,
        n_items: items.len(),
        acc_norm: correct as f64 / items.len().max(1) as f64,
        chance: 1.0 / suite.n_choices() as f64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> Corpus {
        Corpus::new(512, 11)
    }

    #[test]
    fn items_are_deterministic_and_well_formed() {
        let c = corpus();
        for suite in Suite::all() {
            let a = generate_items(&c, suite, 8, 33);
            let b = generate_items(&c, suite, 8, 33);
            assert_eq!(a.len(), 8);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.correct, y.correct);
                assert_eq!(x.candidates, y.candidates);
            }
            for item in &a {
                assert_eq!(item.candidates.len(), suite.n_choices());
                assert!(item.correct < suite.n_choices());
                for cand in &item.candidates {
                    assert_eq!(cand.len(), 33);
                    assert!(cand.iter().all(|&t| (0..512).contains(&t)));
                }
            }
        }
    }

    #[test]
    fn distractors_differ_from_truth() {
        let c = corpus();
        for suite in Suite::all() {
            for item in generate_items(&c, suite, 10, 33) {
                let truth = &item.candidates[item.correct];
                for (i, cand) in item.candidates.iter().enumerate() {
                    if i != item.correct {
                        assert_ne!(cand, truth, "{suite:?} item has duplicate candidate");
                    }
                }
            }
        }
    }

    #[test]
    fn distractors_share_the_context_prefix() {
        let c = corpus();
        let items = generate_items(&c, Suite::SynthHellaSwag, 5, 33);
        let ctx = 33 - 16;
        for item in &items {
            let truth = &item.candidates[item.correct];
            for cand in &item.candidates {
                assert_eq!(&cand[..ctx], &truth[..ctx], "context must be shared");
            }
        }
    }

    #[test]
    fn suite_metadata() {
        assert_eq!(Suite::SynthPiqa.n_choices(), 2);
        assert_eq!(Suite::SynthHellaSwag.n_choices(), 4);
        let names: Vec<&str> = Suite::all().iter().map(|s| s.name()).collect();
        assert_eq!(names.len(), 3);
    }
}
