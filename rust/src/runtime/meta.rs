//! `meta.json` — the Rust<->Python ABI contract for one model config.
//!
//! Produced by `python -m compile.aot` alongside the HLO artifacts; parsed
//! here with `minjson`. Everything the coordinator needs to know about a
//! config (shapes, DCT dimensions, default hyperparameters, the flat
//! parameter layout used for SyncScore probes) lives in this file, so the
//! two languages can never drift silently: any mismatch fails loudly at
//! load time.

use anyhow::{bail, Context, Result};

use crate::minjson::Value;

/// One tensor inside the flat parameter vector.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

/// Default optimizer hyperparameters chosen at AOT time.
#[derive(Clone, Debug)]
pub struct Hyper {
    pub lr: f32,
    pub demo_decay: f32,
    pub adamw_lr: f32,
}

#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
    pub chunk: usize,
    pub topk: usize,
    pub param_count: usize,
    pub padded_count: usize,
    pub n_chunks: usize,
    pub coeff_count: usize,
    pub hyper: Hyper,
    pub params: Vec<ParamSpec>,
    pub artifacts: Vec<String>,
}

impl ModelMeta {
    pub fn parse(text: &str) -> Result<ModelMeta> {
        let v = Value::parse(text).context("parsing meta.json")?;
        let need = |key: &str| -> Result<usize> {
            v.get(key).as_usize().with_context(|| format!("meta.json missing {key}"))
        };
        let hyper = Hyper {
            lr: v.get("hyper").get("lr").as_f64().context("hyper.lr")? as f32,
            demo_decay: v.get("hyper").get("demo_decay").as_f64().context("hyper.demo_decay")?
                as f32,
            adamw_lr: v.get("hyper").get("adamw_lr").as_f64().context("hyper.adamw_lr")? as f32,
        };
        let mut params = Vec::new();
        let mut expected_offset = 0usize;
        for p in v.get("params").as_arr().context("meta.json params")? {
            let spec = ParamSpec {
                name: p.get("name").as_str().context("param name")?.to_string(),
                shape: p
                    .get("shape")
                    .as_arr()
                    .context("param shape")?
                    .iter()
                    .map(|d| d.as_usize().context("dim"))
                    .collect::<Result<_>>()?,
                offset: p.get("offset").as_usize().context("param offset")?,
                size: p.get("size").as_usize().context("param size")?,
            };
            if spec.offset != expected_offset {
                bail!("param {} offset {} != expected {}", spec.name, spec.offset, expected_offset);
            }
            if spec.size != spec.shape.iter().product::<usize>() {
                bail!("param {} size/shape mismatch", spec.name);
            }
            expected_offset += spec.size;
            params.push(spec);
        }
        let meta = ModelMeta {
            name: v.get("name").as_str().context("name")?.to_string(),
            d_model: need("d_model")?,
            n_layers: need("n_layers")?,
            vocab: need("vocab")?,
            seq: need("seq")?,
            batch: need("batch")?,
            chunk: need("chunk")?,
            topk: need("topk")?,
            param_count: need("param_count")?,
            padded_count: need("padded_count")?,
            n_chunks: need("n_chunks")?,
            coeff_count: need("coeff_count")?,
            hyper,
            params,
            artifacts: v
                .get("artifacts")
                .as_arr()
                .context("artifacts")?
                .iter()
                .map(|a| a.as_str().map(String::from).context("artifact name"))
                .collect::<Result<_>>()?,
        };
        if expected_offset != meta.param_count {
            bail!("param specs cover {expected_offset}, expected {}", meta.param_count);
        }
        let m = meta.chunk * meta.chunk;
        if meta.padded_count != meta.n_chunks * m {
            bail!("padded_count inconsistent with chunk layout");
        }
        if meta.coeff_count != meta.n_chunks * meta.topk {
            bail!("coeff_count inconsistent with topk layout");
        }
        Ok(meta)
    }

    /// The single source of truth for the SyncScore probe layout: the
    /// first and last element of every tensor (2 values per tensor,
    /// §3.2). Deterministic, so peer and validator agree without
    /// communication — every probe accessor below consumes this iterator,
    /// so the contract cannot fork.
    fn probe_index_iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.params.iter().flat_map(|p| [p.offset, p.offset + p.size - 1])
    }

    /// Flat indices sampled for the SyncScore probe.
    pub fn sync_probe_indices(&self) -> Vec<usize> {
        self.probe_index_iter().collect()
    }

    /// Gather a probe vector from a flat parameter vector.
    pub fn sync_probe(&self, theta: &[f32]) -> Vec<f32> {
        let mut out = Vec::new();
        self.sync_probe_into(theta, &mut out);
        out
    }

    /// Gather a probe into a reusable buffer (cleared first) — the
    /// allocation-free form of [`ModelMeta::sync_probe`] for the
    /// validator's per-round fast-eval hot path, which re-gathers the
    /// probe every round and previously reallocated both the index list
    /// and the probe vector each time.
    pub fn sync_probe_into(&self, theta: &[f32], out: &mut Vec<f32>) {
        out.clear();
        out.reserve(self.params.len() * 2);
        out.extend(self.probe_index_iter().map(|i| theta[i]));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "name": "test", "d_model": 8, "n_layers": 1, "n_heads": 2, "d_ff": 16,
      "vocab": 32, "seq": 4, "batch": 2, "chunk": 4, "topk": 2,
      "param_count": 20, "padded_count": 32, "n_chunks": 2, "coeff_count": 4,
      "hyper": {"lr": 0.02, "demo_decay": 0.999, "adamw_lr": 0.0003,
                "adamw_beta1": 0.9, "adamw_beta2": 0.95, "adamw_eps": 1e-8,
                "adamw_wd": 0.1},
      "params": [
        {"name": "a", "shape": [4, 4], "offset": 0, "size": 16},
        {"name": "b", "shape": [4], "offset": 16, "size": 4}
      ],
      "artifacts": ["loss"]
    }"#;

    #[test]
    fn parses_sample() {
        let m = ModelMeta::parse(SAMPLE).unwrap();
        assert_eq!(m.name, "test");
        assert_eq!(m.param_count, 20);
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[1].offset, 16);
        assert_eq!(m.artifacts, vec!["loss"]);
        assert!((m.hyper.lr - 0.02).abs() < 1e-9);
    }

    #[test]
    fn sync_probe_takes_first_and_last_of_each_tensor() {
        let m = ModelMeta::parse(SAMPLE).unwrap();
        assert_eq!(m.sync_probe_indices(), vec![0, 15, 16, 19]);
        let theta: Vec<f32> = (0..20).map(|i| i as f32).collect();
        assert_eq!(m.sync_probe(&theta), vec![0.0, 15.0, 16.0, 19.0]);
        // The buffer-reusing form clears stale contents and agrees.
        let mut buf = vec![9.0f32; 7];
        m.sync_probe_into(&theta, &mut buf);
        assert_eq!(buf, vec![0.0, 15.0, 16.0, 19.0]);
    }

    #[test]
    fn rejects_gapped_offsets() {
        let bad = SAMPLE.replace(r#""offset": 16"#, r#""offset": 17"#);
        assert!(ModelMeta::parse(&bad).is_err());
    }

    #[test]
    fn rejects_size_shape_mismatch() {
        let bad = SAMPLE.replace(r#""shape": [4], "offset": 16, "size": 4"#,
                                 r#""shape": [4], "offset": 16, "size": 5"#);
        assert!(ModelMeta::parse(&bad).is_err());
    }

    #[test]
    fn rejects_inconsistent_chunk_layout() {
        let bad = SAMPLE.replace(r#""padded_count": 32"#, r#""padded_count": 33"#);
        assert!(ModelMeta::parse(&bad).is_err());
    }

    #[test]
    fn parses_real_artifact_meta_when_built() {
        let path = super::super::artifact_dir("nano").join("meta.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = ModelMeta::parse(&text).unwrap();
            assert_eq!(m.name, "nano");
            assert_eq!(m.artifacts.len(), 7);
            assert_eq!(m.padded_count, m.n_chunks * m.chunk * m.chunk);
        }
    }
}
