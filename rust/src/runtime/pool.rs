//! A persistent, deterministic worker pool for the round pipeline.
//!
//! Before this module, every parallel stage of every round — peer turns,
//! each validator's fast-eval fan-out, the per-validator eval loop —
//! tore down and respawned scoped OS threads (`std::thread::scope`).
//! Thread spawn/join is pure orchestration overhead on the hottest path
//! in the system, and the paper's own scaling argument (and IOTA's) is
//! that orchestration, not model math, caps permissionless-swarm
//! throughput. [`WorkerPool`] is created **once per run**, sized by the
//! resolved [`RunConfig::threads`](crate::coordinator::run::RunConfig),
//! and reused by every stage of every round.
//!
//! # Determinism contract
//!
//! The pool adds no ordering freedom the scoped spawns didn't have:
//!
//! - [`WorkerPool::scatter`] / [`WorkerPool::scatter_ref`] split the input
//!   into the same contiguous `ceil(len / width)`-sized chunks the old
//!   code built, and return per-chunk results **in chunk order** no
//!   matter which worker ran which chunk (each job writes its own
//!   pre-allocated slot).
//! - [`WorkerPool::map_indexed`] is the one-job-per-element form
//!   (validators), results in element order.
//! - A pool built with `threads <= 1` spawns no workers at all and runs
//!   every job inline on the caller, in order — the sequential path is
//!   the same code, not a parallel code path with one worker.
//!
//! All *stateful* ordering (storage PUT draws, phi penalties, chain
//! commits) stays on the coordinator thread exactly as before; workers
//! only ever run pure-per-chunk work, so results are bit-identical at
//! any thread count (pinned by `tests/parallel_determinism.rs`).
//!
//! # Nesting and deadlock freedom
//!
//! Validator jobs dispatched on the pool themselves fan their fast-eval
//! chunks out on the *same* pool. Waiting threads therefore **help**:
//! while a scope is incomplete, the waiter drains the shared queue and
//! runs whatever it pops. A thread only blocks after observing an empty
//! queue, and every thread that enqueues jobs subsequently help-waits
//! (draining before blocking), so a queued job always has a thread that
//! will run it — nesting cannot strand work.
//!
//! # Panics and shutdown
//!
//! A panicking job is caught on the worker (the worker survives), the
//! first payload is stored on the scope's latch, and the panic resumes
//! on the waiting thread — the same observable behaviour as the old
//! `handle.join().expect(..)` pattern. Dropping the pool wakes and joins
//! every worker.

use std::collections::VecDeque;
use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};

// Under `--cfg loom` the pool's synchronization primitives are swapped
// for loom's model-checked equivalents, and `rust/tests/loom_pool.rs`
// exhaustively explores the dispatch/help-wait/panic interleavings (see
// README "Correctness tooling" for how to run it — loom is a CI-side
// dev-dependency only, the normal build stays dependency-free).
#[cfg(loom)]
use loom::sync::{Arc, Condvar, Mutex};
#[cfg(loom)]
use loom::thread::JoinHandle;
#[cfg(not(loom))]
use std::sync::{Arc, Condvar, Mutex};
#[cfg(not(loom))]
use std::thread::JoinHandle;

/// A unit of work handed to [`WorkerPool::dispatch`]. The borrow lifetime
/// is erased internally and re-anchored by the returned [`ScopeHandle`],
/// which refuses to release the borrows before every job has finished.
pub(crate) type Job<'env> = Box<dyn FnOnce() + Send + 'env>;
type StaticJob = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    jobs: VecDeque<StaticJob>,
    shutdown: bool,
}

struct PoolShared {
    queue: Mutex<Queue>,
    /// Signaled on enqueue and shutdown.
    available: Condvar,
}

impl PoolShared {
    fn try_pop(&self) -> Option<StaticJob> {
        self.queue.lock().unwrap().jobs.pop_front()
    }
}

struct LatchState {
    remaining: usize,
    /// First panic payload from any job in this scope.
    panic: Option<Box<dyn std::any::Any + Send>>,
}

/// Completion latch for one dispatched scope.
struct Latch {
    state: Mutex<LatchState>,
    done: Condvar,
}

impl Latch {
    fn new(count: usize) -> Latch {
        Latch {
            state: Mutex::new(LatchState { remaining: count, panic: None }),
            done: Condvar::new(),
        }
    }

    fn complete(&self, panic: Option<Box<dyn std::any::Any + Send>>) {
        let mut st = self.state.lock().unwrap();
        st.remaining -= 1;
        if st.panic.is_none() {
            st.panic = panic;
        }
        if st.remaining == 0 {
            self.done.notify_all();
        }
    }

    /// Wait for every job in this scope, helping with queued work while
    /// waiting (see module docs: this is what makes nested dispatch from
    /// a pool worker deadlock-free). Returns the first panic payload.
    fn wait(&self, shared: &PoolShared) -> Option<Box<dyn std::any::Any + Send>> {
        loop {
            // Drain the queue first: jobs of *this* scope were all
            // enqueued before wait() started, so once the queue reads
            // empty they are running (or done) on some thread.
            while let Some(job) = shared.try_pop() {
                job();
            }
            let mut st = self.state.lock().unwrap();
            if st.remaining == 0 {
                return st.panic.take();
            }
            let mut st = self.done.wait(st).unwrap();
            if st.remaining == 0 {
                return st.panic.take();
            }
            // Spurious wakeup or partial completion: drop the guard,
            // loop, and re-help.
        }
    }
}

/// Spawn one persistent worker. Loom's scheduler owns thread identity, so
/// the model-checked build uses its plain `spawn`; the real build names
/// the thread for debuggers and profilers.
#[cfg(not(loom))]
fn spawn_worker(i: usize, shared: Arc<PoolShared>) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("gauntlet-pool-{i}"))
        .spawn(move || worker_loop(&shared))
        .expect("spawning pool worker")
}

#[cfg(loom)]
fn spawn_worker(_i: usize, shared: Arc<PoolShared>) -> JoinHandle<()> {
    loom::thread::spawn(move || worker_loop(&shared))
}

fn worker_loop(shared: &PoolShared) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.jobs.pop_front() {
                    break Some(job);
                }
                if q.shutdown {
                    break None;
                }
                q = shared.available.wait(q).unwrap();
            }
        };
        match job {
            // Dispatched jobs are wrapped in catch_unwind, so a panicking
            // user closure never kills the worker.
            Some(job) => job(),
            None => return,
        }
    }
}

/// The persistent worker pool (see module docs).
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
}

/// Borrow anchor for one [`WorkerPool::dispatch`] call: dropping (or
/// [`ScopeHandle::wait`]ing) blocks until every job in the scope has run,
/// then propagates the first panic. `dispatch` is `unsafe` precisely
/// because this anchor is load-bearing: leaking it (`mem::forget`) would
/// let the lifetime-erased jobs outlive their borrows. Every caller in
/// this module waits before returning, which is what discharges the
/// safety obligation — the public surface (`scatter`/`scatter_ref`/
/// `map_indexed`/`run_with`) is safe.
struct ScopeHandle<'pool, 'env> {
    pool: &'pool WorkerPool,
    latch: Arc<Latch>,
    _env: PhantomData<&'env mut &'env ()>,
}

impl ScopeHandle<'_, '_> {
    /// Block until every job in this scope has completed, propagating the
    /// first panic (equivalent to dropping the handle, but explicit at
    /// call sites that sequence work after the scope).
    fn wait(self) {
        drop(self);
    }
}

impl Drop for ScopeHandle<'_, '_> {
    fn drop(&mut self) {
        let payload = self.latch.wait(&self.pool.shared);
        if let Some(p) = payload {
            if !std::thread::panicking() {
                resume_unwind(p);
            }
        }
    }
}

/// The chunked-scatter body, written once for both slice mutabilities
/// (`&mut [T]`/`chunks_mut` and `&[T]`/`chunks`): the chunking rule, the
/// inline fallback, and the slot-per-chunk result ordering must never
/// diverge between the two.
macro_rules! scatter_method {
    ($(#[$attr:meta])* $name:ident, $slice:ty, $bound:ident, $chunks:ident) => {
        $(#[$attr])*
        pub fn $name<T, R, F>(&self, items: $slice, width: usize, f: F) -> Vec<R>
        where
            T: $bound,
            R: Send,
            F: Fn(usize, $slice) -> R + Sync,
        {
            let len = items.len();
            if len == 0 {
                return Vec::new();
            }
            let width = width.max(1);
            let chunk = WorkerPool::chunk_len(len, width);
            if self.workers.is_empty() || width <= 1 || len <= 1 {
                return items
                    .$chunks(chunk)
                    .enumerate()
                    .map(|(ci, ch)| f(ci * chunk, ch))
                    .collect();
            }
            let n_chunks = len.div_ceil(chunk);
            let mut slots: Vec<Option<R>> = Vec::with_capacity(n_chunks);
            slots.resize_with(n_chunks, || None);
            let f = &f;
            let jobs: Vec<Job<'_>> = items
                .$chunks(chunk)
                .zip(slots.iter_mut())
                .enumerate()
                .map(|(ci, (ch, slot))| {
                    Box::new(move || {
                        *slot = Some(f(ci * chunk, ch));
                    }) as Job<'_>
                })
                .collect();
            // SAFETY: the handle is waited on this line, before `items`,
            // `slots`, or `f` can go out of scope.
            unsafe { self.dispatch(jobs) }.wait();
            slots.into_iter().map(|s| s.expect("pool job completed")).collect()
        }
    };
}

impl WorkerPool {
    /// Build a pool of `threads` persistent workers. `threads <= 1`
    /// spawns **no** workers: every scatter/map runs inline on the
    /// caller, which *is* the deterministic sequential path.
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(Queue { jobs: VecDeque::new(), shutdown: false }),
            available: Condvar::new(),
        });
        let workers = if threads > 1 {
            (0..threads).map(|i| spawn_worker(i, Arc::clone(&shared))).collect()
        } else {
            Vec::new()
        };
        WorkerPool { shared, workers, threads }
    }

    /// A zero-worker pool that runs everything inline on the caller —
    /// the sequential convenience for tests and single-threaded tools.
    pub fn inline() -> WorkerPool {
        WorkerPool::new(1)
    }

    /// The pool's configured width (>= 1). This is the resolved
    /// `RunConfig::threads`, fixed at construction — nothing re-reads
    /// `GAUNTLET_THREADS` per round.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Whether this pool runs everything inline (no spawned workers).
    pub fn is_inline(&self) -> bool {
        self.workers.is_empty()
    }

    /// The single source of truth for the scatter chunking rule:
    /// contiguous `ceil(len / width)`-sized chunks, never empty. Both
    /// `scatter`/`scatter_ref` and the funneled call sites that build
    /// their own jobs (to pack an `ExecClient` clone per chunk) derive
    /// their chunk size here, so the rule cannot fork between the
    /// shared-backend and thread-affine paths.
    pub fn chunk_len(len: usize, width: usize) -> usize {
        len.div_ceil(width.max(1)).max(1)
    }

    /// Enqueue `jobs` and return the scope's borrow anchor. The caller
    /// may do other work (e.g. serve an [`exec_service`] funnel) before
    /// waiting. On an inline pool the jobs run here, immediately.
    ///
    /// # Safety
    ///
    /// The returned [`ScopeHandle`] must be dropped (or `wait`ed) before
    /// any borrow captured by `jobs` ends — in practice: wait on it in
    /// the same scope, and never `mem::forget` it. Leaking the handle
    /// lets workers run the lifetime-erased jobs after their borrows are
    /// gone (use-after-free). Every caller below waits before returning.
    ///
    /// [`exec_service`]: crate::runtime::exec_service
    unsafe fn dispatch<'pool, 'env>(
        &'pool self,
        jobs: Vec<Job<'env>>,
    ) -> ScopeHandle<'pool, 'env> {
        if self.workers.is_empty() {
            for job in jobs {
                job();
            }
            return ScopeHandle { pool: self, latch: Arc::new(Latch::new(0)), _env: PhantomData };
        }
        let latch = Arc::new(Latch::new(jobs.len()));
        {
            let mut q = self.shared.queue.lock().unwrap();
            for job in jobs {
                let job_latch = Arc::clone(&latch);
                let wrapped: Job<'env> = Box::new(move || {
                    let result = catch_unwind(AssertUnwindSafe(job));
                    job_latch.complete(result.err());
                });
                // SAFETY: the wrapped job may borrow data with lifetime
                // 'env. The only way it reaches a worker is through this
                // queue, and the returned ScopeHandle's Drop blocks until
                // the latch counts every job complete — so the job cannot
                // outlive 'env unless the handle is leaked, which the
                // crate-private API contract forbids.
                let wrapped: StaticJob =
                    unsafe { std::mem::transmute::<Job<'env>, StaticJob>(wrapped) };
                q.jobs.push_back(wrapped);
            }
        }
        self.shared.available.notify_all();
        ScopeHandle { pool: self, latch, _env: PhantomData }
    }

    scatter_method! {
        /// Deterministic chunked map over a mutable slice: `items` is
        /// split into contiguous `ceil(len / width)`-sized chunks (the
        /// exact chunking the old scoped-thread fan-outs used),
        /// `f(base, chunk)` runs once per chunk (`base` = the chunk's
        /// offset in `items`), and the per-chunk results come back **in
        /// chunk order** regardless of which worker ran what.
        scatter, &mut [T], Send, chunks_mut
    }

    scatter_method! {
        /// [`WorkerPool::scatter`] over a shared slice (read-only
        /// chunks) — the fast-eval sweep's shape.
        scatter_ref, &[T], Sync, chunks
    }

    /// Dispatch pre-built jobs, run `on_caller` on this thread while
    /// they execute, then wait for the scope (propagating job panics).
    /// This is the one place the funneled-backend choreography lives:
    /// the caller packs its [`ExecClient`] clones into the jobs and its
    /// `drop(client); host.serve()` into `on_caller`, and the
    /// dispatch → caller-work → wait ordering cannot be gotten wrong at
    /// the call sites. Must not be used on an inline pool (jobs would
    /// run before `on_caller`, deadlocking a funnel); the round pipeline
    /// only funnels when `threads > 1`.
    ///
    /// [`ExecClient`]: crate::runtime::ExecClient
    pub(crate) fn run_with<'env>(&self, jobs: Vec<Job<'env>>, on_caller: impl FnOnce()) {
        // Hard assert, not debug_assert: on an inline pool the jobs
        // would run synchronously before `on_caller`, and a funneled job
        // would then block forever on a host nobody is serving — a
        // release-mode hang. This runs once per round; the check is free.
        assert!(
            !self.is_inline(),
            "run_with on an inline pool would run jobs before on_caller"
        );
        // SAFETY: the scope is waited before this function returns, so
        // the jobs cannot outlive the borrows they capture (`on_caller`
        // panicking still waits, via the handle's Drop during unwind).
        let scope = unsafe { self.dispatch(jobs) };
        on_caller();
        scope.wait();
    }

    /// One job per element, results in element order — the per-validator
    /// eval loop's shape (each element is a whole unit of work).
    pub fn map_indexed<T, R, F>(&self, items: &mut [T], f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, &mut T) -> R + Sync,
    {
        if self.workers.is_empty() || items.len() <= 1 {
            return items.iter_mut().enumerate().map(|(i, t)| f(i, t)).collect();
        }
        let mut slots: Vec<Option<R>> = Vec::with_capacity(items.len());
        slots.resize_with(items.len(), || None);
        let f = &f;
        let jobs: Vec<Job<'_>> = items
            .iter_mut()
            .zip(slots.iter_mut())
            .enumerate()
            .map(|(i, (item, slot))| {
                Box::new(move || {
                    *slot = Some(f(i, item));
                }) as Job<'_>
            })
            .collect();
        // SAFETY: waited immediately — no borrow outlives this call.
        unsafe { self.dispatch(jobs) }.wait();
        slots.into_iter().map(|s| s.expect("pool job completed")).collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.queue.lock().unwrap().shutdown = true;
        self.shared.available.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("threads", &self.threads)
            .field("workers", &self.workers.len())
            .finish()
    }
}

// Not compiled under loom: these tests exercise real OS threads and
// timing-dependent shapes; the loom build has its own model-checked
// suite in `rust/tests/loom_pool.rs`.
#[cfg(all(test, not(loom)))]
// detlint is silent in cfg(test) code, but clippy's disallowed-types
// tier needs an explicit opt-out: ThreadId implements Hash, not Ord, so
// HashSet is the only std container that can hold it — and the test
// only asks set-membership questions, never iterates.
#[allow(clippy::disallowed_types)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::thread::ThreadId;

    #[test]
    fn scatter_matches_inline_at_every_width_and_uneven_chunks() {
        // 13 items never divide evenly into 2/4/5/8 chunks — the shapes
        // the round pipeline sees whenever peers % threads != 0.
        let base: Vec<u64> = (0..13).collect();
        let expect: Vec<(usize, u64)> = {
            let mut items = base.clone();
            WorkerPool::inline().scatter(&mut items, 1, |b, ch| (b, ch.iter().sum::<u64>()))
        };
        // The per-chunk sums differ by width (different chunk shapes),
        // but the *flattened per-item transformation* must not: verify by
        // mapping each item and concatenating in order.
        for width in [2usize, 4, 5, 8, 13, 64] {
            let pool = WorkerPool::new(4);
            let mut items = base.clone();
            let per_chunk =
                pool.scatter(&mut items, width, |b, ch| {
                    ch.iter_mut().for_each(|x| *x *= 3);
                    (b, ch.to_vec())
                });
            // Chunks come back in order and cover the slice exactly once.
            let mut flat = Vec::new();
            let mut next_base = 0;
            for (b, ch) in per_chunk {
                assert_eq!(b, next_base, "chunk base out of order at width {width}");
                next_base += ch.len();
                flat.extend(ch);
            }
            assert_eq!(flat, base.iter().map(|x| x * 3).collect::<Vec<_>>());
            assert_eq!(items, flat, "in-place mutation must match returned chunks");
        }
        // Width 1 on a parallel pool is the inline path.
        let pool = WorkerPool::new(4);
        let mut items = base.clone();
        let seq = pool.scatter(&mut items, 1, |b, ch| (b, ch.iter().sum::<u64>()));
        assert_eq!(seq, expect);
    }

    #[test]
    fn scatter_ref_and_map_indexed_preserve_order() {
        let pool = WorkerPool::new(3);
        let items: Vec<u32> = (0..17).collect();
        let chunks = pool.scatter_ref(&items, 3, |b, ch| (b, ch.len()));
        assert_eq!(chunks.iter().map(|(_, n)| n).sum::<usize>(), 17);
        assert_eq!(chunks[0].0, 0);
        let mut items: Vec<u32> = (0..9).collect();
        let mapped = pool.map_indexed(&mut items, |i, x| (i as u32) * 100 + *x);
        assert_eq!(mapped, (0..9).map(|i| i * 101).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single_inputs_short_circuit() {
        let pool = WorkerPool::new(4);
        let mut none: Vec<u8> = vec![];
        assert!(pool.scatter(&mut none, 4, |_, _| 0).is_empty());
        let mut one = vec![7u8];
        assert_eq!(pool.scatter(&mut one, 4, |b, ch| (b, ch[0])), vec![(0, 7)]);
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let mut items = vec![0u8; 8];
            pool.scatter(&mut items, 2, |base, _| {
                if base == 0 {
                    panic!("deliberate test panic");
                }
                base
            });
        }));
        assert!(caught.is_err(), "the job panic must surface on the waiter");
        // The workers caught the panic and are still serving: the pool
        // remains usable.
        let mut items: Vec<u32> = (0..8).collect();
        let ok = pool.scatter(&mut items, 2, |b, ch| b + ch.len());
        assert_eq!(ok.len(), 2);
    }

    #[test]
    fn pool_reuses_threads_across_dispatches() {
        // The point of the pool: no per-round thread creation. Across
        // many dispatch "rounds", the set of non-caller thread ids must
        // stay bounded by the pool width — scoped spawns would mint
        // fresh ids every round.
        let caller = std::thread::current().id();
        let pool = WorkerPool::new(4);
        // HashSet, not BTreeSet: ThreadId implements Hash but not Ord.
        let mut seen: HashSet<ThreadId> = HashSet::new();
        for _ in 0..50 {
            let mut items = vec![0u8; 8];
            for id in pool.scatter(&mut items, 4, |_, _| std::thread::current().id()) {
                if id != caller {
                    seen.insert(id);
                }
            }
        }
        // Which threads ran chunks is scheduling-dependent (the waiting
        // caller helps, and on a starved runner may run everything
        // itself), so only the *bound* is asserted: scoped spawns would
        // mint ~200 distinct ids here, a persistent 4-wide pool never
        // more than 4.
        assert!(
            seen.len() <= 4,
            "50 dispatch rounds used {} distinct worker threads; a persistent \
             4-wide pool must never exceed 4",
            seen.len()
        );
    }

    #[test]
    fn nested_dispatch_from_workers_does_not_deadlock() {
        // The validator shape: outer jobs on the pool each scatter their
        // own inner work on the same pool. With more outer jobs than
        // workers this deadlocks unless waiters help (see module docs).
        let pool = WorkerPool::new(2);
        let mut outer: Vec<u64> = (0..6).collect();
        let pool_ref = &pool;
        let totals = pool.map_indexed(&mut outer, |i, x| {
            let mut inner: Vec<u64> = (0..8).map(|j| *x * 10 + j).collect();
            let sums = pool_ref.scatter(&mut inner, 4, |_, ch| ch.iter().sum::<u64>());
            (i, sums.into_iter().sum::<u64>())
        });
        for (i, (idx, total)) in totals.iter().enumerate() {
            assert_eq!(i, *idx);
            let expect: u64 = (0..8).map(|j| (i as u64) * 10 + j).sum();
            assert_eq!(*total, expect, "nested sum wrong for outer job {i}");
        }
    }

    #[test]
    fn inline_pool_runs_on_the_caller() {
        let pool = WorkerPool::inline();
        assert!(pool.is_inline());
        assert_eq!(pool.threads(), 1);
        let caller = std::thread::current().id();
        let mut items = vec![0u8; 4];
        for id in pool.scatter(&mut items, 4, |_, _| std::thread::current().id()) {
            assert_eq!(id, caller);
        }
    }
}
