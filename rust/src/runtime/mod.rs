//! PJRT runtime: loads the AOT HLO-text artifacts and executes them.
//!
//! This is the only place the coordinator touches XLA. Each model config's
//! `artifacts/<cfg>/` directory (produced by `python -m compile.aot`)
//! contains HLO-text entry points plus the `meta.json` ABI contract;
//! [`Executor`] compiles each entry point once at startup and exposes typed
//! wrappers. Python is never on this path. See the repository README
//! ("Layer map" and "Runtime backends") for how this layer fits the stack.
//!
//! The model-execution surface the rest of the system consumes is the
//! [`ExecBackend`] trait, with two implementations:
//!
//! - [`Executor`] — the real thing: compiled HLO via PJRT.
//! - [`SimExec`] (in [`sim`]) — a deterministic pure-Rust stand-in with the
//!   same ABI semantics (signed updates, top-k compression, data-aligned
//!   LossScores), used by tests, benches, and artifact-less quickstarts.
//!
//! Note on threading: the `xla` crate's handles wrap raw PJRT pointers and
//! are not `Send`; all XLA execution must stay on the thread that created
//! the [`Executor`]. The parallel round pipeline honors this via
//! [`service::exec_service`]: worker threads hold cloneable
//! [`service::ExecClient`] handles and the owning thread drains their
//! requests, so every PJRT call still executes on the owner thread. The
//! workers themselves come from [`pool::WorkerPool`] — one persistent,
//! deterministic pool per run, not per-round scoped threads.

pub mod meta;
pub mod pool;
pub mod service;
pub mod sim;

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

pub use meta::{ModelMeta, ParamSpec};
pub use pool::WorkerPool;
pub use service::{exec_service, ExecClient, ExecHost};
pub use sim::{SimExec, SimSpec, LANES};

/// Shared theta snapshot handle: the round pipeline freezes theta into one
/// `Arc<[f32]>` per round and every evaluation request clones the handle,
/// so calls crossing the exec-service funnel carry a pointer instead of a
/// fresh copy of the parameter vector (ROADMAP: "zero-copy data plane,
/// remaining surface").
pub type ThetaShared = std::sync::Arc<[f32]>;

/// One case of a batched [`ExecBackend::eval_peer_batch`] sweep: a dense
/// coefficient vector plus the two token batches it is scored on (the
/// peer's assigned shard and the validator's random-eval shard).
#[derive(Clone, Copy)]
pub struct EvalPeerCase<'a> {
    pub coeff: &'a [f32],
    pub tok_assigned: &'a [i32],
    pub tok_rand: &'a [i32],
}

/// The model-execution ABI every backend provides: exactly the typed entry
/// points the AOT artifacts export (`meta.json` `artifacts` list), plus the
/// ABI contract itself via [`ExecBackend::meta`].
///
/// Implementations: [`Executor`] (PJRT), [`SimExec`] (pure Rust), and
/// [`service::ExecClient`] (a channel proxy that forwards to whichever
/// backend owns the service — how worker threads reach a non-`Send`
/// `Executor`).
pub trait ExecBackend {
    /// The `meta.json` ABI contract (shapes, DCT layout, hyperparameters).
    fn meta(&self) -> &ModelMeta;
    /// Deterministic initial parameter vector.
    fn init_params(&self) -> Result<Vec<f32>>;
    /// `loss(theta, tokens) -> loss`
    fn loss(&self, theta: &[f32], tokens: &[i32]) -> Result<f32>;
    /// `loss_per_seq(theta, tokens) -> f32[B]`
    fn loss_per_seq(&self, theta: &[f32], tokens: &[i32]) -> Result<Vec<f32>>;
    /// `grad(theta, tokens) -> (loss, grad)`
    fn grad(&self, theta: &[f32], tokens: &[i32]) -> Result<(f32, Vec<f32>)>;
    /// `demo_compress(e, g, decay) -> (vals, idx, e')`
    fn demo_compress(
        &self,
        error: &[f32],
        grad: &[f32],
        decay: f32,
    ) -> Result<(Vec<f32>, Vec<i32>, Vec<f32>)>;
    /// `apply_update(theta, coeff, lr) -> theta'` (IDCT + sign + step)
    fn apply_update(&self, theta: &[f32], coeff: &[f32], lr: f32) -> Result<Vec<f32>>;
    /// `eval_peer(theta, coeff, beta, tok_assigned, tok_rand)
    ///    -> (L_assigned_before, L_assigned_after, L_rand_before, L_rand_after)`
    fn eval_peer(
        &self,
        theta: &[f32],
        coeff: &[f32],
        beta: f32,
        tok_assigned: &[i32],
        tok_rand: &[i32],
    ) -> Result<(f32, f32, f32, f32)>;
    /// `adamw_step(theta, m, v, tokens, lr, t) -> (loss, theta', m', v')`
    #[allow(clippy::too_many_arguments)]
    fn adamw_step(
        &self,
        theta: &[f32],
        m: &[f32],
        v: &[f32],
        tokens: &[i32],
        lr: f32,
        t: f32,
    ) -> Result<(f32, Vec<f32>, Vec<f32>, Vec<f32>)>;

    // ------------------------------------------------------------------
    // scratch-based in-place kernels
    //
    // The allocating entry points above return fresh theta-sized `Vec`s
    // on every call — fine for the PJRT artifact path (the copy out of
    // device literals dominates) but the last big per-round allocation
    // class on the pure-Rust hot path. These variants write into
    // caller-owned scratch instead; the defaults fall back to the
    // allocating versions so every backend (including `ExecClient`
    // proxies) keeps working unchanged, and `SimExec` overrides them
    // with genuinely allocation-free implementations. All overrides must
    // stay **value-identical** to the defaults — the determinism
    // fingerprints in `tests/parallel_determinism.rs` pin this.
    // ------------------------------------------------------------------

    /// `grad` into a reusable buffer: writes the gradient into
    /// `grad_out` (cleared first) and returns the loss.
    fn grad_into(&self, theta: &[f32], tokens: &[i32], grad_out: &mut Vec<f32>) -> Result<f32> {
        let (loss, g) = self.grad(theta, tokens)?;
        *grad_out = g;
        Ok(loss)
    }

    /// `apply_update` into a reusable buffer: writes `theta'` into `out`
    /// (cleared first). `out` must not alias `theta`.
    fn apply_update_into(
        &self,
        theta: &[f32],
        coeff: &[f32],
        lr: f32,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        *out = self.apply_update(theta, coeff, lr)?;
        Ok(())
    }

    /// Loss before and after one signed evaluation step
    /// `theta - step * sign(coeff)` on the same token batch, without the
    /// caller ever materializing the stepped parameters. This is one
    /// half of `eval_peer` (which measures the delta on two batches).
    fn loss_delta(
        &self,
        theta: &[f32],
        coeff: &[f32],
        step: f32,
        tokens: &[i32],
    ) -> Result<(f32, f32)> {
        let before = self.loss(theta, tokens)?;
        let stepped = self.apply_update(theta, coeff, step)?;
        let after = self.loss(&stepped, tokens)?;
        Ok((before, after))
    }

    /// `demo_compress` into caller-owned buffers: folds `grad` into the
    /// error-feedback buffer **in place** (`e <- decay*e + g` minus the
    /// extracted coefficients) and writes the top-k values and indices
    /// into `vals_out`/`idx_out` (both cleared first). Finishes the
    /// allocation purge on the peer step path: the theta-sized residual
    /// stops being reallocated per peer per round.
    fn demo_compress_into(
        &self,
        error: &mut [f32],
        grad: &[f32],
        decay: f32,
        vals_out: &mut Vec<f32>,
        idx_out: &mut Vec<i32>,
    ) -> Result<()> {
        let (vals, idx, e2) = self.demo_compress(error, grad, decay)?;
        error.copy_from_slice(&e2);
        *vals_out = vals;
        *idx_out = idx;
        Ok(())
    }

    // ------------------------------------------------------------------
    // batched kernels
    //
    // A validator scores many candidates against the same theta every
    // round; calling the single-candidate kernels in a loop re-derives
    // the token direction and re-walks theta once per candidate. These
    // batched entry points let a backend amortize that: the defaults
    // fall back to per-candidate calls (so `Executor` and other thin
    // backends keep working unchanged), `SimExec` implements them
    // natively (one direction derivation + one theta pass per sweep),
    // and `ExecClient` forwards a whole batch as a single funnel
    // round-trip. Overrides must stay **bit-identical** to the
    // per-call defaults — `tests/kernel_equivalence.rs` pins this.
    // ------------------------------------------------------------------

    /// [`ExecBackend::loss_delta`] for many `(coeff, step)` candidates
    /// on one token batch. Returns one `(before, after)` pair per
    /// candidate, in input order; the `before` loss is shared.
    fn loss_delta_batch(
        &self,
        theta: &[f32],
        candidates: &[(&[f32], f32)],
        tokens: &[i32],
    ) -> Result<Vec<(f32, f32)>> {
        candidates
            .iter()
            .map(|&(coeff, step)| self.loss_delta(theta, coeff, step, tokens))
            .collect()
    }

    /// [`ExecBackend::eval_peer`] for many cases, each with its own
    /// token pair — the multi-token-set variant that serves a
    /// validator's whole sampled peer sweep. Results in case order.
    fn eval_peer_batch(
        &self,
        theta: &[f32],
        beta: f32,
        cases: &[EvalPeerCase<'_>],
    ) -> Result<Vec<(f32, f32, f32, f32)>> {
        cases
            .iter()
            .map(|c| self.eval_peer(theta, c.coeff, beta, c.tok_assigned, c.tok_rand))
            .collect()
    }

    // ------------------------------------------------------------------
    // shared-theta batched kernels (zero-copy funnel surface)
    //
    // The validator stage evaluates every peer against the *same* theta
    // snapshot; taking it as a [`ThetaShared`] handle lets a proxying
    // backend ship an `Arc` clone across the exec-service funnel instead
    // of copying the full parameter vector per request. The defaults
    // deref to the slice kernels, so in-process backends are untouched
    // and bit-transparency is structural.
    // ------------------------------------------------------------------

    /// [`ExecBackend::loss_delta_batch`] over a shared theta handle.
    fn loss_delta_batch_shared(
        &self,
        theta: &ThetaShared,
        candidates: &[(&[f32], f32)],
        tokens: &[i32],
    ) -> Result<Vec<(f32, f32)>> {
        self.loss_delta_batch(theta, candidates, tokens)
    }

    /// [`ExecBackend::eval_peer_batch`] over a shared theta handle — the
    /// entry point the validator's sampled peer sweep uses.
    fn eval_peer_batch_shared(
        &self,
        theta: &ThetaShared,
        beta: f32,
        cases: &[EvalPeerCase<'_>],
    ) -> Result<Vec<(f32, f32, f32, f32)>> {
        self.eval_peer_batch(theta, beta, cases)
    }

    /// A `Sync` view of this backend, if its entry points may be called
    /// from any thread directly. Thread-affine backends (the PJRT
    /// [`Executor`], whose handles are not `Send`) return `None` — the
    /// parallel pipeline then routes workers' calls through the
    /// [`service`] funnel to the owning thread. Pure-Rust backends like
    /// [`SimExec`] return `Some(self)`, and workers call them in place.
    fn as_shared(&self) -> Option<&(dyn ExecBackend + Sync)> {
        None
    }
}

/// Per-entry-point execution statistics (perf accounting, §Perf).
#[derive(Clone, Debug, Default)]
pub struct ExecStats {
    pub calls: u64,
    pub total: Duration,
}

/// Compiled artifacts for one model config.
pub struct Executor {
    pub meta: ModelMeta,
    pub dir: PathBuf,
    client: xla::PjRtClient,
    exes: BTreeMap<String, xla::PjRtLoadedExecutable>,
    stats: RefCell<BTreeMap<String, ExecStats>>,
}

impl Executor {
    /// Load and compile every artifact listed in `<dir>/meta.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Executor> {
        let dir = dir.as_ref().to_path_buf();
        let meta_path = dir.join("meta.json");
        let meta_text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {meta_path:?} (run `make artifacts`?)"))?;
        let meta = ModelMeta::parse(&meta_text)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut exes = BTreeMap::new();
        for name in &meta.artifacts {
            let path = dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).with_context(|| format!("compiling {name}"))?;
            exes.insert(name.clone(), exe);
        }
        Ok(Executor { meta, dir, client, exes, stats: RefCell::new(BTreeMap::new()) })
    }

    /// Deterministic initial parameter vector produced at AOT time.
    pub fn init_params(&self) -> Result<Vec<f32>> {
        let path = self.dir.join("init_params.bin");
        let bytes = std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
        let v = crate::util::f32_from_le_bytes(&bytes);
        if v.len() != self.meta.param_count {
            bail!("init_params has {} values, expected {}", v.len(), self.meta.param_count);
        }
        Ok(v)
    }

    pub fn stats(&self) -> BTreeMap<String, ExecStats> {
        self.stats.borrow().clone()
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Raw tuple-call on an artifact with literal arguments.
    fn call(&self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.exes.get(name).with_context(|| format!("no artifact {name:?}"))?;
        // detlint: allow(D002, observability only — the duration feeds ExecStats and is never branched on, so round results cannot depend on it)
        #[allow(clippy::disallowed_methods)]
        let t0 = Instant::now();
        let out = exe.execute::<xla::Literal>(args).with_context(|| format!("executing {name}"))?;
        let lit = out[0][0].to_literal_sync()?;
        // Lowered with return_tuple=True: the single output is a tuple.
        let items = lit.to_tuple()?;
        let mut st = self.stats.borrow_mut();
        let e = st.entry(name.to_string()).or_default();
        e.calls += 1;
        e.total += t0.elapsed();
        Ok(items)
    }

    // ------------------------------------------------------------------
    // typed entry points (shapes per meta.json)
    // ------------------------------------------------------------------

    fn theta_lit(&self, theta: &[f32]) -> Result<xla::Literal> {
        if theta.len() != self.meta.param_count {
            bail!("theta has {} values, expected {}", theta.len(), self.meta.param_count);
        }
        Ok(xla::Literal::vec1(theta))
    }

    fn tokens_lit(&self, tokens: &[i32]) -> Result<xla::Literal> {
        let (b, s1) = (self.meta.batch, self.meta.seq + 1);
        if tokens.len() != b * s1 {
            bail!("tokens has {} values, expected {}x{}", tokens.len(), b, s1);
        }
        Ok(xla::Literal::vec1(tokens).reshape(&[b as i64, s1 as i64])?)
    }

    fn coeff_lit(&self, coeff: &[f32]) -> Result<xla::Literal> {
        if coeff.len() != self.meta.padded_count {
            bail!("coeff has {} values, expected {}", coeff.len(), self.meta.padded_count);
        }
        Ok(xla::Literal::vec1(coeff))
    }

    fn scalar(v: f32) -> xla::Literal {
        xla::Literal::scalar(v)
    }

    /// `loss(theta, tokens) -> loss`
    pub fn loss(&self, theta: &[f32], tokens: &[i32]) -> Result<f32> {
        let out = self.call("loss", &[self.theta_lit(theta)?, self.tokens_lit(tokens)?])?;
        Ok(out[0].get_first_element::<f32>()?)
    }

    /// `loss_per_seq(theta, tokens) -> f32[B]` — per-sequence mean loss
    /// (length-normalized logprob scoring for the downstream eval harness).
    pub fn loss_per_seq(&self, theta: &[f32], tokens: &[i32]) -> Result<Vec<f32>> {
        let out =
            self.call("loss_per_seq", &[self.theta_lit(theta)?, self.tokens_lit(tokens)?])?;
        Ok(out[0].to_vec::<f32>()?)
    }

    /// `grad(theta, tokens) -> (loss, grad)`
    pub fn grad(&self, theta: &[f32], tokens: &[i32]) -> Result<(f32, Vec<f32>)> {
        let out = self.call("grad", &[self.theta_lit(theta)?, self.tokens_lit(tokens)?])?;
        Ok((out[0].get_first_element::<f32>()?, out[1].to_vec::<f32>()?))
    }

    /// `demo_compress(e, g, decay) -> (vals, idx, e')`
    pub fn demo_compress(
        &self,
        error: &[f32],
        grad: &[f32],
        decay: f32,
    ) -> Result<(Vec<f32>, Vec<i32>, Vec<f32>)> {
        let out = self.call(
            "demo_compress",
            &[self.theta_lit(error)?, self.theta_lit(grad)?, Self::scalar(decay)],
        )?;
        Ok((out[0].to_vec::<f32>()?, out[1].to_vec::<i32>()?, out[2].to_vec::<f32>()?))
    }

    /// `apply_update(theta, coeff, lr) -> theta'` (IDCT + sign + step)
    pub fn apply_update(&self, theta: &[f32], coeff: &[f32], lr: f32) -> Result<Vec<f32>> {
        let out = self.call(
            "apply_update",
            &[self.theta_lit(theta)?, self.coeff_lit(coeff)?, Self::scalar(lr)],
        )?;
        Ok(out[0].to_vec::<f32>()?)
    }

    /// `eval_peer(theta, coeff, beta, tok_assigned, tok_rand)
    ///    -> (L_assigned_before, L_assigned_after, L_rand_before, L_rand_after)`
    pub fn eval_peer(
        &self,
        theta: &[f32],
        coeff: &[f32],
        beta: f32,
        tok_assigned: &[i32],
        tok_rand: &[i32],
    ) -> Result<(f32, f32, f32, f32)> {
        let out = self.call(
            "eval_peer",
            &[
                self.theta_lit(theta)?,
                self.coeff_lit(coeff)?,
                Self::scalar(beta),
                self.tokens_lit(tok_assigned)?,
                self.tokens_lit(tok_rand)?,
            ],
        )?;
        Ok((
            out[0].get_first_element::<f32>()?,
            out[1].get_first_element::<f32>()?,
            out[2].get_first_element::<f32>()?,
            out[3].get_first_element::<f32>()?,
        ))
    }

    /// `adamw_step(theta, m, v, tokens, lr, t) -> (loss, theta', m', v')`
    pub fn adamw_step(
        &self,
        theta: &[f32],
        m: &[f32],
        v: &[f32],
        tokens: &[i32],
        lr: f32,
        t: f32,
    ) -> Result<(f32, Vec<f32>, Vec<f32>, Vec<f32>)> {
        let out = self.call(
            "adamw_step",
            &[
                self.theta_lit(theta)?,
                self.theta_lit(m)?,
                self.theta_lit(v)?,
                self.tokens_lit(tokens)?,
                Self::scalar(lr),
                Self::scalar(t),
            ],
        )?;
        Ok((
            out[0].get_first_element::<f32>()?,
            out[1].to_vec::<f32>()?,
            out[2].to_vec::<f32>()?,
            out[3].to_vec::<f32>()?,
        ))
    }
}

impl ExecBackend for Executor {
    fn meta(&self) -> &ModelMeta {
        &self.meta
    }
    fn init_params(&self) -> Result<Vec<f32>> {
        Executor::init_params(self)
    }
    fn loss(&self, theta: &[f32], tokens: &[i32]) -> Result<f32> {
        Executor::loss(self, theta, tokens)
    }
    fn loss_per_seq(&self, theta: &[f32], tokens: &[i32]) -> Result<Vec<f32>> {
        Executor::loss_per_seq(self, theta, tokens)
    }
    fn grad(&self, theta: &[f32], tokens: &[i32]) -> Result<(f32, Vec<f32>)> {
        Executor::grad(self, theta, tokens)
    }
    fn demo_compress(
        &self,
        error: &[f32],
        grad: &[f32],
        decay: f32,
    ) -> Result<(Vec<f32>, Vec<i32>, Vec<f32>)> {
        Executor::demo_compress(self, error, grad, decay)
    }
    fn apply_update(&self, theta: &[f32], coeff: &[f32], lr: f32) -> Result<Vec<f32>> {
        Executor::apply_update(self, theta, coeff, lr)
    }
    fn eval_peer(
        &self,
        theta: &[f32],
        coeff: &[f32],
        beta: f32,
        tok_assigned: &[i32],
        tok_rand: &[i32],
    ) -> Result<(f32, f32, f32, f32)> {
        Executor::eval_peer(self, theta, coeff, beta, tok_assigned, tok_rand)
    }
    fn adamw_step(
        &self,
        theta: &[f32],
        m: &[f32],
        v: &[f32],
        tokens: &[i32],
        lr: f32,
        t: f32,
    ) -> Result<(f32, Vec<f32>, Vec<f32>, Vec<f32>)> {
        Executor::adamw_step(self, theta, m, v, tokens, lr, t)
    }
}

/// Locate `artifacts/<cfg>` relative to the crate root (works from
/// examples, tests, and benches). Override the artifacts root with the
/// `GAUNTLET_ARTIFACT_DIR` environment variable (see README).
#[allow(clippy::disallowed_methods)]
pub fn artifact_dir(cfg: &str) -> PathBuf {
    // detlint: allow(D002, artifact location is resolved once when a backend is constructed, before any round runs; it selects which bytes to load, never how they are scored)
    match std::env::var_os("GAUNTLET_ARTIFACT_DIR") {
        Some(dir) => PathBuf::from(dir).join(cfg),
        None => PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(cfg),
    }
}

/// True if a config's artifacts are present (used by tests to skip
/// gracefully when `make artifacts` has not run).
pub fn artifacts_available(cfg: &str) -> bool {
    artifact_dir(cfg).join("meta.json").exists()
}
