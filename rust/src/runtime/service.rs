//! Executor service: funnels model execution from worker threads to the
//! backend's owning thread.
//!
//! The PJRT handles inside [`Executor`](super::Executor) are not `Send`, so
//! the parallel round pipeline (`coordinator::run`) cannot hand `&Executor`
//! to its worker threads. Instead the owning thread opens a service with
//! [`exec_service`]; workers receive cloneable [`ExecClient`] handles (an
//! [`ExecBackend`] themselves, so all peer/validator code is backend
//! generic), and the owner drains requests with [`ExecHost::serve`] until
//! every client is dropped:
//!
//! ```text
//! worker 1 ──┐  ExecClient::grad(..)            ┌───────────────────┐
//! worker 2 ──┼────────── mpsc ─────────────────▶│ ExecHost::serve   │
//! worker 3 ──┘  (inputs copied into the job)    │ &E on owner thread│
//!      ▲                                        └─────────┬─────────┘
//!      └───────────── per-call reply channel ─────────────┘
//! ```
//!
//! Requests are closures over owned inputs, so no borrow crosses the
//! channel; replies come back over a per-call channel. Because every
//! backend entry point is a pure function of its inputs, the interleaving
//! of requests from different workers cannot change any result — this is
//! what keeps the parallel pipeline bit-identical to the sequential one.
//!
//! **Deadlock rule:** the thread that holds the [`ExecHost`] must call
//! [`ExecHost::serve`] *before* joining the workers, and must never call an
//! [`ExecClient`] method itself (it would wait on a request only it can
//! serve).

use std::sync::mpsc::{channel, Receiver, Sender};

use anyhow::{anyhow, Result};

use super::{EvalPeerCase, ExecBackend, ModelMeta, ThetaShared};

/// A boxed request: runs against the backend on the owner thread.
type Job<E> = Box<dyn FnOnce(&E) + Send>;

/// Worker-side handle: a cheap, cloneable [`ExecBackend`] proxy.
///
/// Each call copies its input slices into the request (the owner thread
/// cannot borrow worker stacks), sends it, and blocks on the reply.
pub struct ExecClient<E: 'static> {
    tx: Sender<Job<E>>,
    meta: ModelMeta,
}

// Manual impl: `E` itself need not be `Clone` (it never leaves the owner).
impl<E: 'static> Clone for ExecClient<E> {
    fn clone(&self) -> Self {
        ExecClient { tx: self.tx.clone(), meta: self.meta.clone() }
    }
}

/// Owner-side handle: holds the backend borrow and the request queue.
pub struct ExecHost<'e, E: 'static> {
    exec: &'e E,
    rx: Receiver<Job<E>>,
}

/// Open an execution service over `exec`. Returns the client to clone into
/// workers and the host the owning thread drives with [`ExecHost::serve`].
pub fn exec_service<E: ExecBackend + 'static>(exec: &E) -> (ExecClient<E>, ExecHost<'_, E>) {
    let (tx, rx) = channel();
    (ExecClient { tx, meta: exec.meta().clone() }, ExecHost { exec, rx })
}

impl<E: 'static> ExecHost<'_, E> {
    /// Serve requests until every [`ExecClient`] clone has been dropped.
    /// Call this on the owning thread after spawning the workers (and after
    /// dropping the original client).
    pub fn serve(self) {
        while let Ok(job) = self.rx.recv() {
            job(self.exec);
        }
    }
}

impl<E: ExecBackend + 'static> ExecClient<E> {
    fn call<T, F>(&self, f: F) -> Result<T>
    where
        T: Send + 'static,
        F: FnOnce(&E) -> Result<T> + Send + 'static,
    {
        let (rtx, rrx) = channel();
        self.tx
            .send(Box::new(move |e: &E| {
                let _ = rtx.send(f(e));
            }))
            .map_err(|_| anyhow!("exec service closed before the request was sent"))?;
        rrx.recv().map_err(|_| anyhow!("exec service dropped the request reply"))?
    }
}

impl<E: ExecBackend + 'static> ExecBackend for ExecClient<E> {
    fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    fn init_params(&self) -> Result<Vec<f32>> {
        self.call(move |e| e.init_params())
    }

    fn loss(&self, theta: &[f32], tokens: &[i32]) -> Result<f32> {
        let (theta, tokens) = (theta.to_vec(), tokens.to_vec());
        self.call(move |e| e.loss(&theta, &tokens))
    }

    fn loss_per_seq(&self, theta: &[f32], tokens: &[i32]) -> Result<Vec<f32>> {
        let (theta, tokens) = (theta.to_vec(), tokens.to_vec());
        self.call(move |e| e.loss_per_seq(&theta, &tokens))
    }

    fn grad(&self, theta: &[f32], tokens: &[i32]) -> Result<(f32, Vec<f32>)> {
        let (theta, tokens) = (theta.to_vec(), tokens.to_vec());
        self.call(move |e| e.grad(&theta, &tokens))
    }

    fn demo_compress(
        &self,
        error: &[f32],
        grad: &[f32],
        decay: f32,
    ) -> Result<(Vec<f32>, Vec<i32>, Vec<f32>)> {
        let (error, grad) = (error.to_vec(), grad.to_vec());
        self.call(move |e| e.demo_compress(&error, &grad, decay))
    }

    fn apply_update(&self, theta: &[f32], coeff: &[f32], lr: f32) -> Result<Vec<f32>> {
        let (theta, coeff) = (theta.to_vec(), coeff.to_vec());
        self.call(move |e| e.apply_update(&theta, &coeff, lr))
    }

    fn eval_peer(
        &self,
        theta: &[f32],
        coeff: &[f32],
        beta: f32,
        tok_assigned: &[i32],
        tok_rand: &[i32],
    ) -> Result<(f32, f32, f32, f32)> {
        let (theta, coeff) = (theta.to_vec(), coeff.to_vec());
        let (tok_assigned, tok_rand) = (tok_assigned.to_vec(), tok_rand.to_vec());
        self.call(move |e| e.eval_peer(&theta, &coeff, beta, &tok_assigned, &tok_rand))
    }

    fn adamw_step(
        &self,
        theta: &[f32],
        m: &[f32],
        v: &[f32],
        tokens: &[i32],
        lr: f32,
        t: f32,
    ) -> Result<(f32, Vec<f32>, Vec<f32>, Vec<f32>)> {
        let (theta, m, v) = (theta.to_vec(), m.to_vec(), v.to_vec());
        let tokens = tokens.to_vec();
        self.call(move |e| e.adamw_step(&theta, &m, &v, &tokens, lr, t))
    }

    // The trait defaults for the kernels below would decompose into
    // several base calls — several funnel round-trips each. Forwarding
    // them whole keeps one validator sweep (or one fused delta) at one
    // request, and lets the owner-side backend use its native batched
    // implementations.

    fn loss_delta(
        &self,
        theta: &[f32],
        coeff: &[f32],
        step: f32,
        tokens: &[i32],
    ) -> Result<(f32, f32)> {
        let (theta, coeff, tokens) = (theta.to_vec(), coeff.to_vec(), tokens.to_vec());
        self.call(move |e| e.loss_delta(&theta, &coeff, step, &tokens))
    }

    fn loss_delta_batch(
        &self,
        theta: &[f32],
        candidates: &[(&[f32], f32)],
        tokens: &[i32],
    ) -> Result<Vec<(f32, f32)>> {
        let (theta, tokens) = (theta.to_vec(), tokens.to_vec());
        let owned: Vec<(Vec<f32>, f32)> =
            candidates.iter().map(|&(c, s)| (c.to_vec(), s)).collect();
        self.call(move |e| {
            let views: Vec<(&[f32], f32)> =
                owned.iter().map(|(c, s)| (c.as_slice(), *s)).collect();
            e.loss_delta_batch(&theta, &views, &tokens)
        })
    }

    fn eval_peer_batch(
        &self,
        theta: &[f32],
        beta: f32,
        cases: &[EvalPeerCase<'_>],
    ) -> Result<Vec<(f32, f32, f32, f32)>> {
        let theta = theta.to_vec();
        let owned: Vec<(Vec<f32>, Vec<i32>, Vec<i32>)> = cases
            .iter()
            .map(|c| (c.coeff.to_vec(), c.tok_assigned.to_vec(), c.tok_rand.to_vec()))
            .collect();
        self.call(move |e| {
            let views: Vec<EvalPeerCase<'_>> = owned
                .iter()
                .map(|(coeff, tok_assigned, tok_rand)| EvalPeerCase {
                    coeff,
                    tok_assigned,
                    tok_rand,
                })
                .collect();
            e.eval_peer_batch(&theta, beta, &views)
        })
    }

    fn demo_compress_into(
        &self,
        error: &mut [f32],
        grad: &[f32],
        decay: f32,
        vals_out: &mut Vec<f32>,
        idx_out: &mut Vec<i32>,
    ) -> Result<()> {
        let (e0, g) = (error.to_vec(), grad.to_vec());
        let (vals, idx, e2) = self.call(move |e| e.demo_compress(&e0, &g, decay))?;
        error.copy_from_slice(&e2);
        *vals_out = vals;
        *idx_out = idx;
        Ok(())
    }

    // Shared-theta kernels: the whole point of the handle is that these
    // overrides move an `Arc` clone into the request instead of
    // `theta.to_vec()` — the one theta-sized copy left on the validator
    // path. The owner-side backend still sees a plain `&[f32]`.

    fn loss_delta_batch_shared(
        &self,
        theta: &ThetaShared,
        candidates: &[(&[f32], f32)],
        tokens: &[i32],
    ) -> Result<Vec<(f32, f32)>> {
        let theta = ThetaShared::clone(theta);
        let tokens = tokens.to_vec();
        let owned: Vec<(Vec<f32>, f32)> =
            candidates.iter().map(|&(c, s)| (c.to_vec(), s)).collect();
        self.call(move |e| {
            let views: Vec<(&[f32], f32)> =
                owned.iter().map(|(c, s)| (c.as_slice(), *s)).collect();
            e.loss_delta_batch(&theta, &views, &tokens)
        })
    }

    fn eval_peer_batch_shared(
        &self,
        theta: &ThetaShared,
        beta: f32,
        cases: &[EvalPeerCase<'_>],
    ) -> Result<Vec<(f32, f32, f32, f32)>> {
        let theta = ThetaShared::clone(theta);
        let owned: Vec<(Vec<f32>, Vec<i32>, Vec<i32>)> = cases
            .iter()
            .map(|c| (c.coeff.to_vec(), c.tok_assigned.to_vec(), c.tok_rand.to_vec()))
            .collect();
        self.call(move |e| {
            let views: Vec<EvalPeerCase<'_>> = owned
                .iter()
                .map(|(coeff, tok_assigned, tok_rand)| EvalPeerCase {
                    coeff,
                    tok_assigned,
                    tok_rand,
                })
                .collect();
            e.eval_peer_batch(&theta, beta, &views)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::{SimExec, SimSpec};
    use super::*;

    #[test]
    fn workers_reach_the_backend_through_the_funnel() {
        let sim = SimExec::new(&SimSpec::nano(), 3);
        let theta = ExecBackend::init_params(&sim).unwrap();
        let tokens = vec![1i32; sim.meta().batch * (sim.meta().seq + 1)];
        let direct = ExecBackend::loss(&sim, &theta, &tokens).unwrap();

        let (client, host) = exec_service(&sim);
        let losses: Vec<f32> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|_| {
                    let c = client.clone();
                    let (theta, tokens) = (&theta, &tokens);
                    s.spawn(move || c.loss(theta, tokens).unwrap())
                })
                .collect();
            drop(client);
            host.serve();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for l in losses {
            assert_eq!(l.to_bits(), direct.to_bits(), "funnel must be bit-transparent");
        }
    }

    #[test]
    fn batched_kernels_cross_the_funnel_bit_transparently() {
        let sim = SimExec::new(&SimSpec::nano(), 5);
        let theta = ExecBackend::init_params(&sim).unwrap();
        let n_tok = sim.meta().batch * (sim.meta().seq + 1);
        let toks: Vec<i32> = (0..n_tok as i32).collect();
        let mut coeff = vec![0.0f32; sim.meta().padded_count];
        for (i, c) in coeff.iter_mut().enumerate() {
            *c = if i % 3 == 0 { 1.0 } else { -1.0 };
        }
        let cands: Vec<(&[f32], f32)> = vec![(&coeff, 0.01), (&coeff, 0.02)];
        let direct = sim.loss_delta_batch(&theta, &cands, &toks).unwrap();

        let (client, host) = exec_service(&sim);
        let via_funnel = std::thread::scope(|s| {
            let c = client.clone();
            let (theta, coeff, toks) = (&theta, &coeff, &toks);
            let h = s.spawn(move || {
                let cands: Vec<(&[f32], f32)> = vec![(coeff, 0.01), (coeff, 0.02)];
                c.loss_delta_batch(theta, &cands, toks).unwrap()
            });
            drop(client);
            host.serve();
            h.join().unwrap()
        });
        for (a, b) in direct.iter().zip(&via_funnel) {
            assert_eq!((a.0.to_bits(), a.1.to_bits()), (b.0.to_bits(), b.1.to_bits()));
        }
    }

    #[test]
    fn shared_theta_handle_crosses_the_funnel_bit_transparently() {
        // The Arc-handle path must be indistinguishable from the slice
        // path: same bits out of eval_peer_batch whether theta crosses the
        // funnel as a per-call copy or as a shared handle — and the handle
        // itself must not be copied (same allocation before/after).
        let sim = SimExec::new(&SimSpec::nano(), 9);
        let theta: ThetaShared = ExecBackend::init_params(&sim).unwrap().into();
        let n_tok = sim.meta().batch * (sim.meta().seq + 1);
        let tok_a: Vec<i32> = (0..n_tok as i32).collect();
        let tok_r: Vec<i32> = (0..n_tok as i32).rev().collect();
        let coeff = vec![0.5f32; sim.meta().padded_count];
        let cases =
            vec![EvalPeerCase { coeff: &coeff, tok_assigned: &tok_a, tok_rand: &tok_r }];
        let direct = sim.eval_peer_batch(&theta, 0.01, &cases).unwrap();

        let (client, host) = exec_service(&sim);
        let via_funnel = std::thread::scope(|s| {
            let c = client.clone();
            let (theta, coeff, tok_a, tok_r) = (&theta, &coeff, &tok_a, &tok_r);
            let h = s.spawn(move || {
                let cases = vec![EvalPeerCase {
                    coeff,
                    tok_assigned: tok_a,
                    tok_rand: tok_r,
                }];
                c.eval_peer_batch_shared(theta, 0.01, &cases).unwrap()
            });
            drop(client);
            host.serve();
            h.join().unwrap()
        });
        for (a, b) in direct.iter().zip(&via_funnel) {
            assert_eq!(
                (a.0.to_bits(), a.1.to_bits(), a.2.to_bits(), a.3.to_bits()),
                (b.0.to_bits(), b.1.to_bits(), b.2.to_bits(), b.3.to_bits()),
                "shared-theta funnel must be bit-transparent"
            );
        }
        // Zero-copy: the client round-trips cloned the handle, never the
        // buffer — ours is still the only named owner plus none in flight.
        assert_eq!(std::sync::Arc::strong_count(&theta), 1);

        let (client2, host2) = exec_service(&sim);
        let direct_ld = sim.loss_delta_batch(&theta, &[(&coeff[..], 0.01)], &tok_a).unwrap();
        let via2 = std::thread::scope(|s| {
            let c = client2.clone();
            let (theta, coeff, tok_a) = (&theta, &coeff, &tok_a);
            let h = s.spawn(move || {
                c.loss_delta_batch_shared(theta, &[(&coeff[..], 0.01)], tok_a).unwrap()
            });
            drop(client2);
            host2.serve();
            h.join().unwrap()
        });
        for (a, b) in direct_ld.iter().zip(&via2) {
            assert_eq!((a.0.to_bits(), a.1.to_bits()), (b.0.to_bits(), b.1.to_bits()));
        }
    }

    #[test]
    fn client_meta_matches_backend_meta() {
        let sim = SimExec::new(&SimSpec::nano(), 0);
        let (client, _host) = exec_service(&sim);
        assert_eq!(client.meta().param_count, sim.meta().param_count);
        assert_eq!(client.meta().coeff_count, sim.meta().coeff_count);
    }
}
