//! `SimExec` — a deterministic, pure-Rust [`ExecBackend`].
//!
//! The PJRT [`Executor`](super::Executor) needs compiled HLO artifacts and
//! native XLA. Neither is required to exercise the *incentive* mechanics,
//! which only assume the ABI's semantics:
//!
//! - losses fall along the negative gradient (so LossScores are
//!   informative),
//! - gradients computed on a data shard drop the loss on *that* shard a
//!   little more than on a fresh one (so proof-of-computation separates
//!   honest peers from freeloaders/copiers, eq. 3),
//! - `demo_compress` is error-feedback + per-chunk top-k in a coefficient
//!   space, and `apply_update` is exactly one signed step per parameter
//!   (so SyncScore units and checkpoint sign-replay hold).
//!
//! `SimExec` implements those semantics on a synthetic quadratic model:
//! for token batch `T`, `L(theta, T) = floor + qscale * mean((theta -
//! theta* - delta * u_T)^2)` where `theta*` is a seed-derived target and
//! `u_T` a direction hashed from the tokens. The `u_T` shift is what makes
//! training data *identifiable*: a step from a gradient computed on `T`
//! aligns with `u_T` and drops the loss on `T` slightly more than on an
//! unrelated batch — exactly the paper's LossScore-difference signal.
//!
//! Every method is a pure function of its inputs (no interior state), so
//! results are bit-identical regardless of call order or thread count —
//! the property the parallel-pipeline determinism tests pin down.
//!
//! The "DCT" is the identity chunking: coefficient `i` is parameter `i`
//! (indices past `param_count` are padding). That keeps compression,
//! scatter, and signed updates consistent with the validator's native-Rust
//! bookkeeping without a transform library.

use std::cell::RefCell;

use anyhow::{bail, Result};
use sha2::{Digest, Sha256};

use super::meta::{Hyper, ModelMeta, ParamSpec};
use super::ExecBackend;
use crate::util::Rng;

thread_local! {
    /// Per-worker scratch for the token direction `u_T`. Every loss /
    /// grad / eval call derives a fresh direction; before this scratch,
    /// each derivation allocated a theta-sized `Vec` — per peer, per
    /// microbatch, per validator eval, every round. The round pipeline's
    /// workers are persistent (`runtime::pool`), so one buffer per
    /// worker thread lives for the whole run.
    static DIRECTION_SCRATCH: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// Shape of a synthetic model config (everything `ModelMeta` derives from).
#[derive(Clone, Debug)]
pub struct SimSpec {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
    /// DCT chunk side; a chunk holds `chunk * chunk` coefficients.
    pub chunk: usize,
    pub n_chunks: usize,
    /// Coefficients kept per chunk.
    pub topk: usize,
    pub param_count: usize,
}

impl SimSpec {
    /// Smallest config; mirrors the artifact `nano` in spirit.
    pub fn nano() -> SimSpec {
        SimSpec {
            name: "nano".into(),
            d_model: 8,
            n_layers: 1,
            vocab: 64,
            seq: 16,
            batch: 2,
            chunk: 8,
            n_chunks: 4,
            topk: 4,
            param_count: 200,
        }
    }

    /// Mid-size config for multi-threaded benchmarks: enough parameters
    /// that per-peer gradient/compression work dominates thread overhead.
    pub fn mid() -> SimSpec {
        SimSpec {
            name: "mid".into(),
            d_model: 64,
            n_layers: 4,
            vocab: 256,
            seq: 32,
            batch: 2,
            chunk: 32,
            n_chunks: 64,
            topk: 16,
            param_count: 60_000,
        }
    }

    /// Map an artifact config name onto a simulation spec of comparable
    /// intent (unknown names get `nano`).
    pub fn for_model_name(name: &str) -> SimSpec {
        match name {
            "mid" => SimSpec::mid(),
            "tiny" | "small" | "base" => SimSpec {
                name: name.into(),
                d_model: 16,
                n_layers: 2,
                vocab: 128,
                seq: 24,
                batch: 2,
                chunk: 16,
                n_chunks: 16,
                topk: 8,
                param_count: 3_500,
            },
            _ => SimSpec { name: name.into(), ..SimSpec::nano() },
        }
    }

    /// Materialize the ABI contract. Tensor boundaries are synthetic but
    /// satisfy every invariant `ModelMeta::parse` enforces, so SyncScore
    /// probes (first + last element per tensor) work unchanged.
    pub fn build_meta(&self) -> ModelMeta {
        assert!(self.param_count <= self.n_chunks * self.chunk * self.chunk);
        let sizes = [
            self.param_count / 2,
            self.param_count / 4,
            self.param_count / 8,
            self.param_count - self.param_count / 2 - self.param_count / 4
                - self.param_count / 8,
        ];
        let names = ["tok_embed", "blocks", "norm", "head"];
        let mut params = Vec::new();
        let mut offset = 0;
        for (name, &size) in names.iter().zip(&sizes) {
            if size == 0 {
                continue;
            }
            params.push(ParamSpec {
                name: (*name).to_string(),
                shape: vec![size],
                offset,
                size,
            });
            offset += size;
        }
        ModelMeta {
            name: self.name.clone(),
            d_model: self.d_model,
            n_layers: self.n_layers,
            vocab: self.vocab,
            seq: self.seq,
            batch: self.batch,
            chunk: self.chunk,
            topk: self.topk,
            param_count: self.param_count,
            padded_count: self.n_chunks * self.chunk * self.chunk,
            n_chunks: self.n_chunks,
            coeff_count: self.n_chunks * self.topk,
            hyper: Hyper { lr: 0.02, demo_decay: 0.999, adamw_lr: 3e-4 },
            params,
            artifacts: vec![],
        }
    }
}

/// Deterministic pure-Rust execution backend (see module docs).
#[derive(Clone)]
pub struct SimExec {
    meta: ModelMeta,
    seed: u64,
    /// The quadratic's optimum.
    theta_star: Vec<f32>,
    /// Curvature scale: init loss lands near `ln(vocab)`.
    qscale: f64,
    /// Data-alignment shift applied to the optimum per token batch.
    delta: f64,
    /// Irreducible loss floor (the corpus's switch-noise analogue).
    floor: f64,
}

impl SimExec {
    pub fn new(spec: &SimSpec, seed: u64) -> SimExec {
        let meta = spec.build_meta();
        let mut rng = Rng::from_parts(&["sim-target", &spec.name, &seed.to_string()]);
        let theta_star: Vec<f32> = (0..meta.param_count).map(|_| rng.normal_f32(0.0, 0.1)).collect();
        SimExec { meta, seed, theta_star, qscale: 150.0, delta: 0.05, floor: 1.0 }
    }

    /// Spec-by-model-name convenience used by the artifact-less fallbacks.
    pub fn from_model_name(name: &str, seed: u64) -> SimExec {
        SimExec::new(&SimSpec::for_model_name(name), seed)
    }

    fn check_theta(&self, theta: &[f32]) -> Result<()> {
        if theta.len() != self.meta.param_count {
            bail!("theta has {} values, expected {}", theta.len(), self.meta.param_count);
        }
        Ok(())
    }

    fn check_tokens(&self, tokens: &[i32]) -> Result<()> {
        let want = self.meta.batch * (self.meta.seq + 1);
        if tokens.len() != want {
            bail!("tokens has {} values, expected {}", tokens.len(), want);
        }
        Ok(())
    }

    /// Per-batch direction `u_T`: i.i.d. standard normals seeded by a hash
    /// of the tokens (and the run seed, so different runs see different
    /// data geometry), written into a reusable buffer (cleared first).
    fn token_direction_into(&self, tokens: &[i32], out: &mut Vec<f32>) {
        let mut h = Sha256::new();
        h.update(self.seed.to_le_bytes());
        for t in tokens {
            h.update(t.to_le_bytes());
        }
        let digest = h.finalize();
        let mut rng = Rng::new(u64::from_le_bytes(digest[..8].try_into().unwrap()));
        out.clear();
        out.reserve(self.meta.param_count);
        out.extend((0..self.meta.param_count).map(|_| rng.normal_f32(0.0, 1.0)));
    }

    /// Derive `u_T` into this worker's thread-local scratch and hand it
    /// to `f`. Calls must not nest (each would need its own buffer) —
    /// every consumer below uses one direction at a time, sequentially.
    fn with_token_direction<R>(&self, tokens: &[i32], f: impl FnOnce(&[f32]) -> R) -> R {
        DIRECTION_SCRATCH.with(|cell| {
            let mut u = cell.borrow_mut();
            self.token_direction_into(tokens, &mut u);
            f(&u)
        })
    }

    /// `L(theta, T)` for one direction `u_T` (see module docs).
    fn loss_for_direction(&self, theta: &[f32], u: &[f32]) -> f64 {
        let n = theta.len() as f64;
        let mut q = 0.0f64;
        for i in 0..theta.len() {
            let x = theta[i] as f64 - self.theta_star[i] as f64 - self.delta * u[i] as f64;
            q += x * x;
        }
        self.floor + self.qscale * q / n
    }

    /// One signed evaluation step `theta - step * sign(coeff)` in place,
    /// restricted to real (non-padding) coefficients.
    fn signed_step_in_place(theta: &mut [f32], coeff: &[f32], step: f32) {
        for (i, t) in theta.iter_mut().enumerate() {
            let c = coeff[i];
            if c > 0.0 {
                *t -= step;
            } else if c < 0.0 {
                *t += step;
            }
        }
    }
}

impl ExecBackend for SimExec {
    fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    fn init_params(&self) -> Result<Vec<f32>> {
        let mut rng =
            Rng::from_parts(&["sim-init", &self.meta.name, &self.seed.to_string()]);
        Ok((0..self.meta.param_count).map(|_| rng.normal_f32(0.0, 0.1)).collect())
    }

    fn loss(&self, theta: &[f32], tokens: &[i32]) -> Result<f32> {
        self.check_theta(theta)?;
        self.check_tokens(tokens)?;
        self.with_token_direction(tokens, |u| Ok(self.loss_for_direction(theta, u) as f32))
    }

    fn loss_per_seq(&self, theta: &[f32], tokens: &[i32]) -> Result<Vec<f32>> {
        self.check_theta(theta)?;
        self.check_tokens(tokens)?;
        let s1 = self.meta.seq + 1;
        Ok(tokens
            .chunks(s1)
            .map(|row| {
                self.with_token_direction(row, |u| self.loss_for_direction(theta, u) as f32)
            })
            .collect())
    }

    fn grad(&self, theta: &[f32], tokens: &[i32]) -> Result<(f32, Vec<f32>)> {
        let mut g = Vec::new();
        let loss = self.grad_into(theta, tokens, &mut g)?;
        Ok((loss, g))
    }

    fn grad_into(&self, theta: &[f32], tokens: &[i32], grad_out: &mut Vec<f32>) -> Result<f32> {
        self.check_theta(theta)?;
        self.check_tokens(tokens)?;
        self.with_token_direction(tokens, |u| {
            let n = theta.len() as f64;
            grad_out.clear();
            grad_out.reserve(theta.len());
            // Fused loss: `x` here is exactly the term `loss_for_direction`
            // sums, in the same index order, so accumulating it alongside
            // the gradient is bit-identical to a separate loss pass.
            let mut q = 0.0f64;
            for i in 0..theta.len() {
                let x = theta[i] as f64 - self.theta_star[i] as f64 - self.delta * u[i] as f64;
                grad_out.push((2.0 * self.qscale * x / n) as f32);
                q += x * x;
            }
            Ok((self.floor + self.qscale * q / n) as f32)
        })
    }

    fn demo_compress(
        &self,
        error: &[f32],
        grad: &[f32],
        decay: f32,
    ) -> Result<(Vec<f32>, Vec<i32>, Vec<f32>)> {
        self.check_theta(error)?;
        self.check_theta(grad)?;
        let m = self.meta.chunk * self.meta.chunk;
        // Error feedback: e <- decay * e + g. One buffer serves as both
        // the ranking source and the returned residual: a chunk is ranked
        // strictly before any of its entries are zeroed (and chunks cover
        // disjoint index ranges), so the values read are exactly the
        // pre-zeroing `e` values the old two-buffer version ranked.
        let mut residual: Vec<f32> =
            error.iter().zip(grad).map(|(ei, gi)| decay * ei + gi).collect();
        let mut vals = Vec::with_capacity(self.meta.coeff_count);
        let mut idx = Vec::with_capacity(self.meta.coeff_count);
        for chunk_id in 0..self.meta.n_chunks {
            let lo = chunk_id * m;
            let hi = ((chunk_id + 1) * m).min(self.meta.param_count);
            // Rank this chunk's (identity-transformed) coefficients by
            // magnitude; padding positions are zeros and rank last.
            let mut order: Vec<usize> = (lo..hi.max(lo)).collect();
            order.sort_by(|&a, &b| {
                residual[b]
                    .abs()
                    .partial_cmp(&residual[a].abs())
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            });
            for k in 0..self.meta.topk {
                match order.get(k) {
                    Some(&i) => {
                        vals.push(residual[i]);
                        idx.push(i as i32);
                        residual[i] = 0.0;
                    }
                    None => {
                        // Chunk entirely past param_count: emit padding
                        // coefficients so the wire shape stays fixed.
                        vals.push(0.0);
                        idx.push((lo + k) as i32);
                    }
                }
            }
        }
        Ok((vals, idx, residual))
    }

    fn apply_update(&self, theta: &[f32], coeff: &[f32], lr: f32) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.apply_update_into(theta, coeff, lr, &mut out)?;
        Ok(out)
    }

    fn apply_update_into(
        &self,
        theta: &[f32],
        coeff: &[f32],
        lr: f32,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        self.check_theta(theta)?;
        if coeff.len() != self.meta.padded_count {
            bail!("coeff has {} values, expected {}", coeff.len(), self.meta.padded_count);
        }
        out.clear();
        out.extend_from_slice(theta);
        Self::signed_step_in_place(out, coeff, lr);
        Ok(())
    }

    fn loss_delta(
        &self,
        theta: &[f32],
        coeff: &[f32],
        step: f32,
        tokens: &[i32],
    ) -> Result<(f32, f32)> {
        self.check_theta(theta)?;
        if coeff.len() != self.meta.padded_count {
            bail!("coeff has {} values, expected {}", coeff.len(), self.meta.padded_count);
        }
        self.check_tokens(tokens)?;
        // One fused pass, never materializing the stepped parameters.
        // Bit-compatibility with the default (apply_update + two losses):
        // the stepped value is computed with the same single f32 subtract
        // `signed_step_in_place` performs, and each quadratic term keeps
        // `loss_for_direction`'s exact `(theta - theta*) - delta*u`
        // association and index-order summation.
        self.with_token_direction(tokens, |u| {
            let n = theta.len() as f64;
            let (mut q0, mut q1) = (0.0f64, 0.0f64);
            for i in 0..theta.len() {
                let c = coeff[i];
                let stepped = if c > 0.0 {
                    theta[i] - step
                } else if c < 0.0 {
                    theta[i] + step
                } else {
                    theta[i]
                };
                let du = self.delta * u[i] as f64;
                let x0 = theta[i] as f64 - self.theta_star[i] as f64 - du;
                let x1 = stepped as f64 - self.theta_star[i] as f64 - du;
                q0 += x0 * x0;
                q1 += x1 * x1;
            }
            Ok((
                (self.floor + self.qscale * q0 / n) as f32,
                (self.floor + self.qscale * q1 / n) as f32,
            ))
        })
    }

    fn eval_peer(
        &self,
        theta: &[f32],
        coeff: &[f32],
        beta: f32,
        tok_assigned: &[i32],
        tok_rand: &[i32],
    ) -> Result<(f32, f32, f32, f32)> {
        let (la0, la1) = self.loss_delta(theta, coeff, beta, tok_assigned)?;
        let (lr0, lr1) = self.loss_delta(theta, coeff, beta, tok_rand)?;
        Ok((la0, la1, lr0, lr1))
    }

    fn as_shared(&self) -> Option<&(dyn ExecBackend + Sync)> {
        // Every method is a pure function over plain data: safe to call
        // from any worker directly, no owner-thread funnel required.
        Some(self)
    }

    fn adamw_step(
        &self,
        theta: &[f32],
        m: &[f32],
        v: &[f32],
        tokens: &[i32],
        lr: f32,
        t: f32,
    ) -> Result<(f32, Vec<f32>, Vec<f32>, Vec<f32>)> {
        self.check_theta(theta)?;
        self.check_theta(m)?;
        self.check_theta(v)?;
        let (loss, g) = self.grad(theta, tokens)?;
        // Same constants as `coordinator::baseline::AdamWParams::default`.
        let (b1, b2, eps, wd) = (0.9f32, 0.95f32, 1e-8f32, 0.1f32);
        let (bc1, bc2) = (1.0 - b1.powf(t), 1.0 - b2.powf(t));
        let mut theta2 = theta.to_vec();
        let mut m2 = m.to_vec();
        let mut v2 = v.to_vec();
        for i in 0..theta.len() {
            m2[i] = b1 * m2[i] + (1.0 - b1) * g[i];
            v2[i] = b2 * v2[i] + (1.0 - b2) * g[i] * g[i];
            let mhat = m2[i] / bc1;
            let vhat = v2[i] / bc2;
            theta2[i] -= lr * (mhat / (vhat.sqrt() + eps) + wd * theta2[i]);
        }
        Ok((loss, theta2, m2, v2))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim() -> SimExec {
        SimExec::new(&SimSpec::nano(), 7)
    }

    fn tokens(sim: &SimExec, tag: i32) -> Vec<i32> {
        let n = sim.meta.batch * (sim.meta.seq + 1);
        (0..n as i32).map(|i| (i * 31 + tag) % sim.meta.vocab as i32).collect()
    }

    #[test]
    fn meta_satisfies_abi_invariants() {
        for spec in [SimSpec::nano(), SimSpec::mid(), SimSpec::for_model_name("tiny")] {
            let m = spec.build_meta();
            assert_eq!(m.padded_count, m.n_chunks * m.chunk * m.chunk);
            assert_eq!(m.coeff_count, m.n_chunks * m.topk);
            let covered: usize = m.params.iter().map(|p| p.size).sum();
            assert_eq!(covered, m.param_count);
            let probe = m.sync_probe_indices();
            assert!(probe.iter().all(|&i| i < m.param_count));
        }
    }

    #[test]
    fn everything_is_deterministic() {
        let a = sim();
        let b = sim();
        let theta = a.init_params().unwrap();
        assert_eq!(theta, b.init_params().unwrap());
        let toks = tokens(&a, 1);
        assert_eq!(a.loss(&theta, &toks).unwrap(), b.loss(&theta, &toks).unwrap());
        let (la, ga) = a.grad(&theta, &toks).unwrap();
        let (lb, gb) = b.grad(&theta, &toks).unwrap();
        assert_eq!((la, ga), (lb, gb));
    }

    #[test]
    fn loss_is_near_log_vocab_and_falls_along_gradient() {
        let e = sim();
        let theta = e.init_params().unwrap();
        let toks = tokens(&e, 0);
        let (l0, g) = e.grad(&theta, &toks).unwrap();
        let expect = (e.meta.vocab as f32).ln();
        assert!((l0 - expect).abs() < 2.0, "init loss {l0} vs ln(V)={expect}");
        let stepped: Vec<f32> = theta.iter().zip(&g).map(|(t, gi)| t - 0.05 * gi).collect();
        let l1 = e.loss(&stepped, &toks).unwrap();
        assert!(l1 < l0, "gradient step must reduce loss: {l0} -> {l1}");
    }

    #[test]
    fn compress_emits_fixed_shape_and_strips_residual() {
        let e = sim();
        let theta = e.init_params().unwrap();
        let toks = tokens(&e, 2);
        let (_, g) = e.grad(&theta, &toks).unwrap();
        let err = vec![0.0f32; e.meta.param_count];
        let (vals, idx, e2) = e.demo_compress(&err, &g, 0.0).unwrap();
        assert_eq!(vals.len(), e.meta.coeff_count);
        assert_eq!(idx.len(), e.meta.coeff_count);
        let m = (e.meta.chunk * e.meta.chunk) as i32;
        for (j, &i) in idx.iter().enumerate() {
            let chunk = j / e.meta.topk;
            assert!(i >= chunk as i32 * m && i < (chunk as i32 + 1) * m, "idx stripe at {j}");
        }
        let gn: f64 = g.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
        let en: f64 = e2.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
        assert!(en < gn, "top-k must remove energy: {en} !< {gn}");
    }

    #[test]
    fn apply_update_is_exactly_one_signed_step() {
        let e = sim();
        let theta = e.init_params().unwrap();
        let mut coeff = vec![0.0f32; e.meta.padded_count];
        coeff[0] = 1.0;
        coeff[5] = -2.0;
        let lr = 0.02f32;
        let theta2 = e.apply_update(&theta, &coeff, lr).unwrap();
        for (i, (a, b)) in theta.iter().zip(&theta2).enumerate() {
            let d = (a - b).abs();
            assert!(d == 0.0 || (d - lr).abs() < 1e-7, "step at {i} must be 0 or ±lr, got {d}");
        }
        assert!((theta[0] - theta2[0] - lr).abs() < 1e-7);
        assert!((theta2[5] - theta[5] - lr).abs() < 1e-7);
    }

    #[test]
    fn assigned_data_scores_higher_than_random_for_real_training() {
        // The PoC signal (eq. 3): compress a gradient computed on T_a, step
        // with it, and the loss drop on T_a should (on average over many
        // shards) exceed the drop on unrelated data.
        let e = sim();
        let mut theta = e.init_params().unwrap();
        // Train until the quadratic term is small, so the per-shard
        // delta-alignment dominates coefficient selection.
        for r in 0..150 {
            let toks = tokens(&e, r);
            let (_, g) = e.grad(&theta, &toks).unwrap();
            theta = theta.iter().zip(&g).map(|(t, gi)| t - 0.02 * gi).collect();
        }
        let mut diff_sum = 0.0;
        let n_trials = 20;
        for r in 0..n_trials {
            let ta = tokens(&e, 100 + r);
            let tr = tokens(&e, 10_000 + r);
            let (_, g) = e.grad(&theta, &ta).unwrap();
            let err = vec![0.0f32; e.meta.param_count];
            let (vals, idx, _) = e.demo_compress(&err, &g, 0.999).unwrap();
            let mut coeff = vec![0.0f32; e.meta.padded_count];
            for (v, i) in vals.iter().zip(&idx) {
                coeff[*i as usize] += v;
            }
            let (la0, la1, lr0, lr1) = e.eval_peer(&theta, &coeff, 0.01, &ta, &tr).unwrap();
            diff_sum += (la0 - la1) as f64 - (lr0 - lr1) as f64;
        }
        assert!(
            diff_sum / n_trials as f64 > 0.0,
            "assigned-shard LossScore must exceed random-shard on average: {diff_sum}"
        );
    }

    #[test]
    fn shape_errors_are_loud() {
        let e = sim();
        let theta = e.init_params().unwrap();
        assert!(e.loss(&theta[1..], &tokens(&e, 0)).is_err());
        assert!(e.loss(&theta, &[1, 2, 3]).is_err());
        assert!(e.apply_update(&theta, &[0.0; 3], 0.1).is_err());
    }
}
