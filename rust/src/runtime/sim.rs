//! `SimExec` — a deterministic, pure-Rust [`ExecBackend`].
//!
//! The PJRT [`Executor`](super::Executor) needs compiled HLO artifacts and
//! native XLA. Neither is required to exercise the *incentive* mechanics,
//! which only assume the ABI's semantics:
//!
//! - losses fall along the negative gradient (so LossScores are
//!   informative),
//! - gradients computed on a data shard drop the loss on *that* shard a
//!   little more than on a fresh one (so proof-of-computation separates
//!   honest peers from freeloaders/copiers, eq. 3),
//! - `demo_compress` is error-feedback + per-chunk top-k in a coefficient
//!   space, and `apply_update` is exactly one signed step per parameter
//!   (so SyncScore units and checkpoint sign-replay hold).
//!
//! `SimExec` implements those semantics on a synthetic quadratic model:
//! for token batch `T`, `L(theta, T) = floor + qscale * mean((theta -
//! theta* - delta * u_T)^2)` where `theta*` is a seed-derived target and
//! `u_T` a direction hashed from the tokens. The `u_T` shift is what makes
//! training data *identifiable*: a step from a gradient computed on `T`
//! aligns with `u_T` and drops the loss on `T` slightly more than on an
//! unrelated batch — exactly the paper's LossScore-difference signal.
//!
//! Every method is a pure function of its inputs (no interior state), so
//! results are bit-identical regardless of call order or thread count —
//! the property the parallel-pipeline determinism tests pin down.
//!
//! **Fixed-lane summation contract.** Every quadratic reduction in this
//! file accumulates into [`LANES`] parallel f64 lanes — index `i`
//! always lands in lane `i % LANES`, in increasing `i` order — and the
//! lanes collapse through the fixed pairwise tree in [`lane_reduce`].
//! The summation order is therefore a pure function of index: identical
//! across thread counts, platforms, and between the single-call and
//! batched kernels (which is what lets `loss_delta_batch` share one
//! theta pass across a whole peer sweep while staying bit-identical to
//! per-call `loss_delta`). The chunked inner loops are written so LLVM
//! autovectorizes them; the lane count is part of the numeric contract,
//! so changing `LANES` is a re-baselining event for run fingerprints.
//!
//! The "DCT" is the identity chunking: coefficient `i` is parameter `i`
//! (indices past `param_count` are padding). That keeps compression,
//! scatter, and signed updates consistent with the validator's native-Rust
//! bookkeeping without a transform library.

use std::cell::RefCell;

use anyhow::{bail, Result};
use sha2::{Digest, Sha256};

use super::meta::{Hyper, ModelMeta, ParamSpec};
use super::{EvalPeerCase, ExecBackend};
use crate::util::Rng;

thread_local! {
    /// Per-worker scratch for the token direction `u_T`. Every loss /
    /// grad / eval call derives a fresh direction; before this scratch,
    /// each derivation allocated a theta-sized `Vec` — per peer, per
    /// microbatch, per validator eval, every round. The round pipeline's
    /// workers are persistent (`runtime::pool`), so one buffer per
    /// worker thread lives for the whole run.
    static DIRECTION_SCRATCH: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };

    /// Per-worker scratch for batched eval: all of a sweep's token
    /// directions, concatenated (`2 * cases * param_count` floats for
    /// `eval_peer_batch`). Separate from `DIRECTION_SCRATCH` so batched
    /// kernels never contend with a single-direction caller's borrow.
    static BATCH_DIRECTION_SCRATCH: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// Accumulator width of the fixed-lane reductions (see module docs).
/// Eight f64 lanes span one AVX-512 register / two AVX2 registers; the
/// value is part of the determinism contract, not just a tuning knob.
pub const LANES: usize = 8;

/// Collapse a lane accumulator through a fixed pairwise tree. Keeping
/// the tree shape constant (rather than a left fold) is what makes the
/// total independent of how the compiler schedules the adds.
#[inline(always)]
fn lane_reduce(acc: [f64; LANES]) -> f64 {
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

/// Shape of a synthetic model config (everything `ModelMeta` derives from).
#[derive(Clone, Debug)]
pub struct SimSpec {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
    /// DCT chunk side; a chunk holds `chunk * chunk` coefficients.
    pub chunk: usize,
    pub n_chunks: usize,
    /// Coefficients kept per chunk.
    pub topk: usize,
    pub param_count: usize,
}

impl SimSpec {
    /// Smallest config; mirrors the artifact `nano` in spirit.
    pub fn nano() -> SimSpec {
        SimSpec {
            name: "nano".into(),
            d_model: 8,
            n_layers: 1,
            vocab: 64,
            seq: 16,
            batch: 2,
            chunk: 8,
            n_chunks: 4,
            topk: 4,
            param_count: 200,
        }
    }

    /// Mid-size config for multi-threaded benchmarks: enough parameters
    /// that per-peer gradient/compression work dominates thread overhead.
    pub fn mid() -> SimSpec {
        SimSpec {
            name: "mid".into(),
            d_model: 64,
            n_layers: 4,
            vocab: 256,
            seq: 32,
            batch: 2,
            chunk: 32,
            n_chunks: 64,
            topk: 16,
            param_count: 60_000,
        }
    }

    /// Map an artifact config name onto a simulation spec of comparable
    /// intent (unknown names get `nano`).
    pub fn for_model_name(name: &str) -> SimSpec {
        match name {
            "mid" => SimSpec::mid(),
            "tiny" | "small" | "base" => SimSpec {
                name: name.into(),
                d_model: 16,
                n_layers: 2,
                vocab: 128,
                seq: 24,
                batch: 2,
                chunk: 16,
                n_chunks: 16,
                topk: 8,
                param_count: 3_500,
            },
            _ => SimSpec { name: name.into(), ..SimSpec::nano() },
        }
    }

    /// Materialize the ABI contract. Tensor boundaries are synthetic but
    /// satisfy every invariant `ModelMeta::parse` enforces, so SyncScore
    /// probes (first + last element per tensor) work unchanged.
    pub fn build_meta(&self) -> ModelMeta {
        assert!(self.param_count <= self.n_chunks * self.chunk * self.chunk);
        let sizes = [
            self.param_count / 2,
            self.param_count / 4,
            self.param_count / 8,
            self.param_count - self.param_count / 2 - self.param_count / 4
                - self.param_count / 8,
        ];
        let names = ["tok_embed", "blocks", "norm", "head"];
        let mut params = Vec::new();
        let mut offset = 0;
        for (name, &size) in names.iter().zip(&sizes) {
            if size == 0 {
                continue;
            }
            params.push(ParamSpec {
                name: (*name).to_string(),
                shape: vec![size],
                offset,
                size,
            });
            offset += size;
        }
        ModelMeta {
            name: self.name.clone(),
            d_model: self.d_model,
            n_layers: self.n_layers,
            vocab: self.vocab,
            seq: self.seq,
            batch: self.batch,
            chunk: self.chunk,
            topk: self.topk,
            param_count: self.param_count,
            padded_count: self.n_chunks * self.chunk * self.chunk,
            n_chunks: self.n_chunks,
            coeff_count: self.n_chunks * self.topk,
            hyper: Hyper { lr: 0.02, demo_decay: 0.999, adamw_lr: 3e-4 },
            params,
            artifacts: vec![],
        }
    }
}

/// Deterministic pure-Rust execution backend (see module docs).
#[derive(Clone)]
pub struct SimExec {
    meta: ModelMeta,
    seed: u64,
    /// The quadratic's optimum.
    theta_star: Vec<f32>,
    /// Curvature scale: init loss lands near `ln(vocab)`.
    qscale: f64,
    /// Data-alignment shift applied to the optimum per token batch.
    delta: f64,
    /// Irreducible loss floor (the corpus's switch-noise analogue).
    floor: f64,
}

impl SimExec {
    pub fn new(spec: &SimSpec, seed: u64) -> SimExec {
        let meta = spec.build_meta();
        let mut rng = Rng::from_parts(&["sim-target", &spec.name, &seed.to_string()]);
        let theta_star: Vec<f32> = (0..meta.param_count).map(|_| rng.normal_f32(0.0, 0.1)).collect();
        SimExec { meta, seed, theta_star, qscale: 150.0, delta: 0.05, floor: 1.0 }
    }

    /// Spec-by-model-name convenience used by the artifact-less fallbacks.
    pub fn from_model_name(name: &str, seed: u64) -> SimExec {
        SimExec::new(&SimSpec::for_model_name(name), seed)
    }

    fn check_theta(&self, theta: &[f32]) -> Result<()> {
        if theta.len() != self.meta.param_count {
            bail!("theta has {} values, expected {}", theta.len(), self.meta.param_count);
        }
        Ok(())
    }

    fn check_tokens(&self, tokens: &[i32]) -> Result<()> {
        let want = self.meta.batch * (self.meta.seq + 1);
        if tokens.len() != want {
            bail!("tokens has {} values, expected {}", tokens.len(), want);
        }
        Ok(())
    }

    /// Per-batch direction `u_T`: i.i.d. standard normals seeded by a hash
    /// of the tokens (and the run seed, so different runs see different
    /// data geometry), written into a reusable buffer (cleared first).
    fn token_direction_into(&self, tokens: &[i32], out: &mut Vec<f32>) {
        out.clear();
        self.token_direction_extend(tokens, out);
    }

    /// `token_direction_into` that *appends* — the batched kernels pack
    /// many directions into one flat scratch matrix with this.
    fn token_direction_extend(&self, tokens: &[i32], out: &mut Vec<f32>) {
        let mut h = Sha256::new();
        h.update(self.seed.to_le_bytes());
        for t in tokens {
            h.update(t.to_le_bytes());
        }
        let digest = h.finalize();
        let mut rng = Rng::new(u64::from_le_bytes(digest[..8].try_into().unwrap()));
        out.reserve(self.meta.param_count);
        out.extend((0..self.meta.param_count).map(|_| rng.normal_f32(0.0, 1.0)));
    }

    /// Derive `u_T` into this worker's thread-local scratch and hand it
    /// to `f`. Calls must not nest (each would need its own buffer) —
    /// every consumer below uses one direction at a time, sequentially.
    fn with_token_direction<R>(&self, tokens: &[i32], f: impl FnOnce(&[f32]) -> R) -> R {
        DIRECTION_SCRATCH.with(|cell| {
            let mut u = cell.borrow_mut();
            self.token_direction_into(tokens, &mut u);
            f(&u)
        })
    }

    /// `L(theta, T)` for one direction `u_T` (see module docs). Fixed-lane
    /// reduction: index `k` accumulates into lane `k % LANES`, collapsed
    /// by `lane_reduce` — every other quadratic sum in this file follows
    /// the same scheme so all paths agree bitwise.
    fn loss_for_direction(&self, theta: &[f32], u: &[f32]) -> f64 {
        let len = theta.len();
        let n = len as f64;
        let term = |k: usize| {
            let x = theta[k] as f64 - self.theta_star[k] as f64 - self.delta * u[k] as f64;
            x * x
        };
        let mut acc = [0.0f64; LANES];
        let mut i = 0;
        while i + LANES <= len {
            for j in 0..LANES {
                acc[j] += term(i + j);
            }
            i += LANES;
        }
        for j in 0..len - i {
            acc[j] += term(i + j);
        }
        self.floor + self.qscale * lane_reduce(acc) / n
    }

    /// One signed evaluation step `theta - step * sign(coeff)` in place,
    /// restricted to real (non-padding) coefficients. Branchless select
    /// form (autovectorizes to a masked subtract); subtracting a `0.0`
    /// step is bit-identical to not touching the value, signed zeros
    /// included, so this matches the old branchy loop exactly.
    fn signed_step_in_place(theta: &mut [f32], coeff: &[f32], step: f32) {
        for (t, &c) in theta.iter_mut().zip(coeff) {
            let d = if c > 0.0 {
                step
            } else if c < 0.0 {
                -step
            } else {
                0.0
            };
            *t -= d;
        }
    }

    /// The evaluation-stepped parameter `loss_delta` scores: the same
    /// single f32 subtract `signed_step_in_place` performs.
    #[inline(always)]
    fn stepped_at(theta: &[f32], coeff: &[f32], step: f32, k: usize) -> f32 {
        let c = coeff[k];
        let d = if c > 0.0 {
            step
        } else if c < 0.0 {
            -step
        } else {
            0.0
        };
        theta[k] - d
    }

    /// The pre-lane scalar `loss_delta`: one sequential f64 accumulator
    /// per loss, same math in index order. No production path calls this
    /// — it exists so `bench::suite` can report the lane kernels' speedup
    /// against the old scalar shape on the same machine, and so tests can
    /// bound the lane scheme's reassociation error.
    pub fn loss_delta_scalar_ref(
        &self,
        theta: &[f32],
        coeff: &[f32],
        step: f32,
        tokens: &[i32],
    ) -> Result<(f32, f32)> {
        self.check_theta(theta)?;
        if coeff.len() != self.meta.padded_count {
            bail!("coeff has {} values, expected {}", coeff.len(), self.meta.padded_count);
        }
        self.check_tokens(tokens)?;
        self.with_token_direction(tokens, |u| {
            let n = theta.len() as f64;
            let (mut q0, mut q1) = (0.0f64, 0.0f64);
            for k in 0..theta.len() {
                let stepped = Self::stepped_at(theta, coeff, step, k);
                let du = self.delta * u[k] as f64;
                let x0 = theta[k] as f64 - self.theta_star[k] as f64 - du;
                let x1 = stepped as f64 - self.theta_star[k] as f64 - du;
                q0 += x0 * x0;
                q1 += x1 * x1;
            }
            Ok((
                (self.floor + self.qscale * q0 / n) as f32,
                (self.floor + self.qscale * q1 / n) as f32,
            ))
        })
    }
}

impl ExecBackend for SimExec {
    fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    fn init_params(&self) -> Result<Vec<f32>> {
        let mut rng =
            Rng::from_parts(&["sim-init", &self.meta.name, &self.seed.to_string()]);
        Ok((0..self.meta.param_count).map(|_| rng.normal_f32(0.0, 0.1)).collect())
    }

    fn loss(&self, theta: &[f32], tokens: &[i32]) -> Result<f32> {
        self.check_theta(theta)?;
        self.check_tokens(tokens)?;
        self.with_token_direction(tokens, |u| Ok(self.loss_for_direction(theta, u) as f32))
    }

    fn loss_per_seq(&self, theta: &[f32], tokens: &[i32]) -> Result<Vec<f32>> {
        self.check_theta(theta)?;
        self.check_tokens(tokens)?;
        let s1 = self.meta.seq + 1;
        Ok(tokens
            .chunks(s1)
            .map(|row| {
                self.with_token_direction(row, |u| self.loss_for_direction(theta, u) as f32)
            })
            .collect())
    }

    fn grad(&self, theta: &[f32], tokens: &[i32]) -> Result<(f32, Vec<f32>)> {
        let mut g = Vec::new();
        let loss = self.grad_into(theta, tokens, &mut g)?;
        Ok((loss, g))
    }

    fn grad_into(&self, theta: &[f32], tokens: &[i32], grad_out: &mut Vec<f32>) -> Result<f32> {
        self.check_theta(theta)?;
        self.check_tokens(tokens)?;
        self.with_token_direction(tokens, |u| {
            let len = theta.len();
            let n = len as f64;
            grad_out.clear();
            grad_out.resize(len, 0.0);
            let g = grad_out.as_mut_slice();
            // Fused loss: `x` here is exactly the term `loss_for_direction`
            // sums, with the same lane-per-index accumulation, so fusing
            // the gradient write is bit-identical to a separate loss pass.
            let mut acc = [0.0f64; LANES];
            let mut i = 0;
            while i + LANES <= len {
                for j in 0..LANES {
                    let k = i + j;
                    let x =
                        theta[k] as f64 - self.theta_star[k] as f64 - self.delta * u[k] as f64;
                    g[k] = (2.0 * self.qscale * x / n) as f32;
                    acc[j] += x * x;
                }
                i += LANES;
            }
            for j in 0..len - i {
                let k = i + j;
                let x = theta[k] as f64 - self.theta_star[k] as f64 - self.delta * u[k] as f64;
                g[k] = (2.0 * self.qscale * x / n) as f32;
                acc[j] += x * x;
            }
            Ok((self.floor + self.qscale * lane_reduce(acc) / n) as f32)
        })
    }

    fn demo_compress(
        &self,
        error: &[f32],
        grad: &[f32],
        decay: f32,
    ) -> Result<(Vec<f32>, Vec<i32>, Vec<f32>)> {
        let mut residual = error.to_vec();
        let mut vals = Vec::new();
        let mut idx = Vec::new();
        self.demo_compress_into(&mut residual, grad, decay, &mut vals, &mut idx)?;
        Ok((vals, idx, residual))
    }

    fn demo_compress_into(
        &self,
        error: &mut [f32],
        grad: &[f32],
        decay: f32,
        vals_out: &mut Vec<f32>,
        idx_out: &mut Vec<i32>,
    ) -> Result<()> {
        self.check_theta(error)?;
        self.check_theta(grad)?;
        let m = self.meta.chunk * self.meta.chunk;
        // Error feedback: e <- decay * e + g, in place. One buffer serves
        // as both the ranking source and the residual left behind: a
        // chunk is ranked strictly before any of its entries are zeroed
        // (and chunks cover disjoint index ranges), so the values read
        // are exactly the post-feedback, pre-zeroing `e` values the old
        // two-buffer version ranked.
        for (e, &g) in error.iter_mut().zip(grad) {
            *e = decay * *e + g;
        }
        vals_out.clear();
        idx_out.clear();
        vals_out.reserve(self.meta.coeff_count);
        idx_out.reserve(self.meta.coeff_count);
        let mut order: Vec<usize> = Vec::with_capacity(m);
        for chunk_id in 0..self.meta.n_chunks {
            let lo = chunk_id * m;
            let hi = ((chunk_id + 1) * m).min(self.meta.param_count);
            // Rank this chunk's (identity-transformed) coefficients by
            // magnitude; padding positions are zeros and rank last.
            order.clear();
            order.extend(lo..hi.max(lo));
            order.sort_by(|&a, &b| {
                error[b]
                    .abs()
                    .partial_cmp(&error[a].abs())
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            });
            for k in 0..self.meta.topk {
                match order.get(k) {
                    Some(&i) => {
                        vals_out.push(error[i]);
                        idx_out.push(i as i32);
                        error[i] = 0.0;
                    }
                    None => {
                        // Chunk entirely past param_count: emit padding
                        // coefficients so the wire shape stays fixed.
                        vals_out.push(0.0);
                        idx_out.push((lo + k) as i32);
                    }
                }
            }
        }
        Ok(())
    }

    fn apply_update(&self, theta: &[f32], coeff: &[f32], lr: f32) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.apply_update_into(theta, coeff, lr, &mut out)?;
        Ok(out)
    }

    fn apply_update_into(
        &self,
        theta: &[f32],
        coeff: &[f32],
        lr: f32,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        self.check_theta(theta)?;
        if coeff.len() != self.meta.padded_count {
            bail!("coeff has {} values, expected {}", coeff.len(), self.meta.padded_count);
        }
        out.clear();
        out.extend_from_slice(theta);
        Self::signed_step_in_place(out, coeff, lr);
        Ok(())
    }

    fn loss_delta(
        &self,
        theta: &[f32],
        coeff: &[f32],
        step: f32,
        tokens: &[i32],
    ) -> Result<(f32, f32)> {
        self.check_theta(theta)?;
        if coeff.len() != self.meta.padded_count {
            bail!("coeff has {} values, expected {}", coeff.len(), self.meta.padded_count);
        }
        self.check_tokens(tokens)?;
        // One fused pass, never materializing the stepped parameters.
        // Bit-compatibility with the default (apply_update + two losses):
        // the stepped value is computed with the same single f32 subtract
        // `signed_step_in_place` performs, and each quadratic term keeps
        // `loss_for_direction`'s exact `(theta - theta*) - delta*u`
        // association and lane-per-index summation.
        self.with_token_direction(tokens, |u| {
            let len = theta.len();
            let n = len as f64;
            let term = |k: usize| {
                let stepped = Self::stepped_at(theta, coeff, step, k);
                let du = self.delta * u[k] as f64;
                let x0 = theta[k] as f64 - self.theta_star[k] as f64 - du;
                let x1 = stepped as f64 - self.theta_star[k] as f64 - du;
                (x0 * x0, x1 * x1)
            };
            let mut a0 = [0.0f64; LANES];
            let mut a1 = [0.0f64; LANES];
            let mut i = 0;
            while i + LANES <= len {
                for j in 0..LANES {
                    let (t0, t1) = term(i + j);
                    a0[j] += t0;
                    a1[j] += t1;
                }
                i += LANES;
            }
            for j in 0..len - i {
                let (t0, t1) = term(i + j);
                a0[j] += t0;
                a1[j] += t1;
            }
            Ok((
                (self.floor + self.qscale * lane_reduce(a0) / n) as f32,
                (self.floor + self.qscale * lane_reduce(a1) / n) as f32,
            ))
        })
    }

    fn eval_peer(
        &self,
        theta: &[f32],
        coeff: &[f32],
        beta: f32,
        tok_assigned: &[i32],
        tok_rand: &[i32],
    ) -> Result<(f32, f32, f32, f32)> {
        let (la0, la1) = self.loss_delta(theta, coeff, beta, tok_assigned)?;
        let (lr0, lr1) = self.loss_delta(theta, coeff, beta, tok_rand)?;
        Ok((la0, la1, lr0, lr1))
    }

    fn loss_delta_batch(
        &self,
        theta: &[f32],
        candidates: &[(&[f32], f32)],
        tokens: &[i32],
    ) -> Result<Vec<(f32, f32)>> {
        self.check_theta(theta)?;
        for (coeff, _) in candidates {
            if coeff.len() != self.meta.padded_count {
                bail!("coeff has {} values, expected {}", coeff.len(), self.meta.padded_count);
            }
        }
        self.check_tokens(tokens)?;
        // One direction derivation + one theta pass serve every candidate.
        // Bit-identity with per-call `loss_delta`: each candidate's `a1`
        // lanes receive exactly its own terms, in index order, through
        // the same expressions — the i-outer / candidate-inner loop never
        // mixes accumulators across candidates.
        self.with_token_direction(tokens, |u| {
            let len = theta.len();
            let n = len as f64;
            let mut a0 = [0.0f64; LANES];
            let mut a1: Vec<[f64; LANES]> = vec![[0.0f64; LANES]; candidates.len()];
            let mut i = 0;
            while i < len {
                let width = LANES.min(len - i);
                for j in 0..width {
                    let k = i + j;
                    let du = self.delta * u[k] as f64;
                    let x0 = theta[k] as f64 - self.theta_star[k] as f64 - du;
                    a0[j] += x0 * x0;
                    for (ci, &(coeff, step)) in candidates.iter().enumerate() {
                        let stepped = Self::stepped_at(theta, coeff, step, k);
                        let x1 = stepped as f64 - self.theta_star[k] as f64 - du;
                        a1[ci][j] += x1 * x1;
                    }
                }
                i += width;
            }
            let before = (self.floor + self.qscale * lane_reduce(a0) / n) as f32;
            Ok(a1
                .into_iter()
                .map(|acc| (before, (self.floor + self.qscale * lane_reduce(acc) / n) as f32))
                .collect())
        })
    }

    fn eval_peer_batch(
        &self,
        theta: &[f32],
        beta: f32,
        cases: &[EvalPeerCase<'_>],
    ) -> Result<Vec<(f32, f32, f32, f32)>> {
        self.check_theta(theta)?;
        for case in cases {
            if case.coeff.len() != self.meta.padded_count {
                bail!(
                    "coeff has {} values, expected {}",
                    case.coeff.len(),
                    self.meta.padded_count
                );
            }
            self.check_tokens(case.tok_assigned)?;
            self.check_tokens(case.tok_rand)?;
        }
        if cases.is_empty() {
            return Ok(Vec::new());
        }
        // Materialize all 2C directions once (the SHA-256 + normal-stream
        // derivation is itself a hot cost at validator fan-outs), then run
        // one fused theta pass for the whole sweep. Accumulator layout:
        // [assigned-before, assigned-after, rand-before, rand-after] lane
        // arrays per case, each receiving only its own terms in index
        // order — bit-identical to per-call `eval_peer`.
        BATCH_DIRECTION_SCRATCH.with(|cell| {
            let mut dirs = cell.borrow_mut();
            dirs.clear();
            dirs.reserve(2 * cases.len() * self.meta.param_count);
            for case in cases {
                self.token_direction_extend(case.tok_assigned, &mut dirs);
                self.token_direction_extend(case.tok_rand, &mut dirs);
            }
            let p = self.meta.param_count;
            let len = theta.len();
            let n = len as f64;
            let mut acc: Vec<[[f64; LANES]; 4]> = vec![[[0.0f64; LANES]; 4]; cases.len()];
            let mut i = 0;
            while i < len {
                let width = LANES.min(len - i);
                for j in 0..width {
                    let k = i + j;
                    let base = theta[k] as f64 - self.theta_star[k] as f64;
                    for (ci, case) in cases.iter().enumerate() {
                        let stepped =
                            Self::stepped_at(theta, case.coeff, beta, k) as f64
                                - self.theta_star[k] as f64;
                        let dua = self.delta * dirs[2 * ci * p + k] as f64;
                        let x0 = base - dua;
                        let x1 = stepped - dua;
                        let dur = self.delta * dirs[(2 * ci + 1) * p + k] as f64;
                        let y0 = base - dur;
                        let y1 = stepped - dur;
                        let a = &mut acc[ci];
                        a[0][j] += x0 * x0;
                        a[1][j] += x1 * x1;
                        a[2][j] += y0 * y0;
                        a[3][j] += y1 * y1;
                    }
                }
                i += width;
            }
            Ok(acc
                .into_iter()
                .map(|a| {
                    let l = |lanes| (self.floor + self.qscale * lane_reduce(lanes) / n) as f32;
                    (l(a[0]), l(a[1]), l(a[2]), l(a[3]))
                })
                .collect())
        })
    }

    fn as_shared(&self) -> Option<&(dyn ExecBackend + Sync)> {
        // Every method is a pure function over plain data: safe to call
        // from any worker directly, no owner-thread funnel required.
        Some(self)
    }

    fn adamw_step(
        &self,
        theta: &[f32],
        m: &[f32],
        v: &[f32],
        tokens: &[i32],
        lr: f32,
        t: f32,
    ) -> Result<(f32, Vec<f32>, Vec<f32>, Vec<f32>)> {
        self.check_theta(theta)?;
        self.check_theta(m)?;
        self.check_theta(v)?;
        let (loss, g) = self.grad(theta, tokens)?;
        // Same constants as `coordinator::baseline::AdamWParams::default`.
        let (b1, b2, eps, wd) = (0.9f32, 0.95f32, 1e-8f32, 0.1f32);
        let (bc1, bc2) = (1.0 - b1.powf(t), 1.0 - b2.powf(t));
        let mut theta2 = theta.to_vec();
        let mut m2 = m.to_vec();
        let mut v2 = v.to_vec();
        for i in 0..theta.len() {
            m2[i] = b1 * m2[i] + (1.0 - b1) * g[i];
            v2[i] = b2 * v2[i] + (1.0 - b2) * g[i] * g[i];
            let mhat = m2[i] / bc1;
            let vhat = v2[i] / bc2;
            theta2[i] -= lr * (mhat / (vhat.sqrt() + eps) + wd * theta2[i]);
        }
        Ok((loss, theta2, m2, v2))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim() -> SimExec {
        SimExec::new(&SimSpec::nano(), 7)
    }

    fn tokens(sim: &SimExec, tag: i32) -> Vec<i32> {
        let n = sim.meta.batch * (sim.meta.seq + 1);
        (0..n as i32).map(|i| (i * 31 + tag) % sim.meta.vocab as i32).collect()
    }

    #[test]
    fn meta_satisfies_abi_invariants() {
        for spec in [SimSpec::nano(), SimSpec::mid(), SimSpec::for_model_name("tiny")] {
            let m = spec.build_meta();
            assert_eq!(m.padded_count, m.n_chunks * m.chunk * m.chunk);
            assert_eq!(m.coeff_count, m.n_chunks * m.topk);
            let covered: usize = m.params.iter().map(|p| p.size).sum();
            assert_eq!(covered, m.param_count);
            let probe = m.sync_probe_indices();
            assert!(probe.iter().all(|&i| i < m.param_count));
        }
    }

    #[test]
    fn everything_is_deterministic() {
        let a = sim();
        let b = sim();
        let theta = a.init_params().unwrap();
        assert_eq!(theta, b.init_params().unwrap());
        let toks = tokens(&a, 1);
        assert_eq!(a.loss(&theta, &toks).unwrap(), b.loss(&theta, &toks).unwrap());
        let (la, ga) = a.grad(&theta, &toks).unwrap();
        let (lb, gb) = b.grad(&theta, &toks).unwrap();
        assert_eq!((la, ga), (lb, gb));
    }

    #[test]
    fn loss_is_near_log_vocab_and_falls_along_gradient() {
        let e = sim();
        let theta = e.init_params().unwrap();
        let toks = tokens(&e, 0);
        let (l0, g) = e.grad(&theta, &toks).unwrap();
        let expect = (e.meta.vocab as f32).ln();
        assert!((l0 - expect).abs() < 2.0, "init loss {l0} vs ln(V)={expect}");
        let stepped: Vec<f32> = theta.iter().zip(&g).map(|(t, gi)| t - 0.05 * gi).collect();
        let l1 = e.loss(&stepped, &toks).unwrap();
        assert!(l1 < l0, "gradient step must reduce loss: {l0} -> {l1}");
    }

    #[test]
    fn compress_emits_fixed_shape_and_strips_residual() {
        let e = sim();
        let theta = e.init_params().unwrap();
        let toks = tokens(&e, 2);
        let (_, g) = e.grad(&theta, &toks).unwrap();
        let err = vec![0.0f32; e.meta.param_count];
        let (vals, idx, e2) = e.demo_compress(&err, &g, 0.0).unwrap();
        assert_eq!(vals.len(), e.meta.coeff_count);
        assert_eq!(idx.len(), e.meta.coeff_count);
        let m = (e.meta.chunk * e.meta.chunk) as i32;
        for (j, &i) in idx.iter().enumerate() {
            let chunk = j / e.meta.topk;
            assert!(i >= chunk as i32 * m && i < (chunk as i32 + 1) * m, "idx stripe at {j}");
        }
        let gn: f64 = g.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
        let en: f64 = e2.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
        assert!(en < gn, "top-k must remove energy: {en} !< {gn}");
    }

    #[test]
    fn apply_update_is_exactly_one_signed_step() {
        let e = sim();
        let theta = e.init_params().unwrap();
        let mut coeff = vec![0.0f32; e.meta.padded_count];
        coeff[0] = 1.0;
        coeff[5] = -2.0;
        let lr = 0.02f32;
        let theta2 = e.apply_update(&theta, &coeff, lr).unwrap();
        for (i, (a, b)) in theta.iter().zip(&theta2).enumerate() {
            let d = (a - b).abs();
            assert!(d == 0.0 || (d - lr).abs() < 1e-7, "step at {i} must be 0 or ±lr, got {d}");
        }
        assert!((theta[0] - theta2[0] - lr).abs() < 1e-7);
        assert!((theta2[5] - theta[5] - lr).abs() < 1e-7);
    }

    #[test]
    fn assigned_data_scores_higher_than_random_for_real_training() {
        // The PoC signal (eq. 3): compress a gradient computed on T_a, step
        // with it, and the loss drop on T_a should (on average over many
        // shards) exceed the drop on unrelated data.
        let e = sim();
        let mut theta = e.init_params().unwrap();
        // Train until the quadratic term is small, so the per-shard
        // delta-alignment dominates coefficient selection.
        for r in 0..150 {
            let toks = tokens(&e, r);
            let (_, g) = e.grad(&theta, &toks).unwrap();
            theta = theta.iter().zip(&g).map(|(t, gi)| t - 0.02 * gi).collect();
        }
        let mut diff_sum = 0.0;
        let n_trials = 20;
        for r in 0..n_trials {
            let ta = tokens(&e, 100 + r);
            let tr = tokens(&e, 10_000 + r);
            let (_, g) = e.grad(&theta, &ta).unwrap();
            let err = vec![0.0f32; e.meta.param_count];
            let (vals, idx, _) = e.demo_compress(&err, &g, 0.999).unwrap();
            let mut coeff = vec![0.0f32; e.meta.padded_count];
            for (v, i) in vals.iter().zip(&idx) {
                coeff[*i as usize] += v;
            }
            let (la0, la1, lr0, lr1) = e.eval_peer(&theta, &coeff, 0.01, &ta, &tr).unwrap();
            diff_sum += (la0 - la1) as f64 - (lr0 - lr1) as f64;
        }
        assert!(
            diff_sum / n_trials as f64 > 0.0,
            "assigned-shard LossScore must exceed random-shard on average: {diff_sum}"
        );
    }

    #[test]
    fn shape_errors_are_loud() {
        let e = sim();
        let theta = e.init_params().unwrap();
        assert!(e.loss(&theta[1..], &tokens(&e, 0)).is_err());
        assert!(e.loss(&theta, &[1, 2, 3]).is_err());
        assert!(e.apply_update(&theta, &[0.0; 3], 0.1).is_err());
    }

    /// A spec with an arbitrary `param_count`, so the lane kernels can be
    /// pinned at every remainder `param_count % LANES`.
    fn spec_with(param_count: usize) -> SimSpec {
        SimSpec {
            name: format!("lane-{param_count}"),
            chunk: 8,
            n_chunks: param_count.div_ceil(64).max(1),
            topk: 4,
            param_count,
            ..SimSpec::nano()
        }
    }

    /// Lengths covering every residue mod LANES, both below and above one
    /// full lane block, plus the stock sizes.
    fn lane_width_sweep() -> Vec<usize> {
        let mut v: Vec<usize> = (1..=2 * LANES + 3).collect();
        v.extend([31, 64, 65, 200, 333]);
        v
    }

    #[test]
    fn lane_sum_matches_index_mod_lane_specification() {
        // The determinism contract in the module docs, executable: lane j
        // accumulates exactly the terms of indices i with i % LANES == j,
        // in increasing i, collapsed by the fixed pairwise tree. The
        // chunked kernel loops must be bit-identical to this naive spec.
        for len in lane_width_sweep() {
            let e = SimExec::new(&spec_with(len), 11);
            let theta = e.init_params().unwrap();
            let toks = tokens(&e, len as i32);
            let mut u = Vec::new();
            e.token_direction_into(&toks, &mut u);

            let mut acc = [0.0f64; LANES];
            for i in 0..len {
                let x = theta[i] as f64 - e.theta_star[i] as f64 - e.delta * u[i] as f64;
                acc[i % LANES] += x * x;
            }
            let spec_loss = e.floor + e.qscale * lane_reduce(acc) / len as f64;

            let kernel_loss = e.loss_for_direction(&theta, &u);
            assert_eq!(kernel_loss.to_bits(), spec_loss.to_bits(), "len {len}");

            // …and the plain sequential sum agrees to rounding error, so
            // the lane scheme is a reassociation, not a different formula.
            let mut q = 0.0f64;
            for i in 0..len {
                let x = theta[i] as f64 - e.theta_star[i] as f64 - e.delta * u[i] as f64;
                q += x * x;
            }
            let seq_loss = e.floor + e.qscale * q / len as f64;
            assert!(
                (kernel_loss - seq_loss).abs() <= 1e-9 * seq_loss.abs().max(1.0),
                "len {len}: lane {kernel_loss} vs sequential {seq_loss}"
            );
        }
    }

    #[test]
    fn fused_kernels_agree_with_composed_calls_at_every_lane_width() {
        // grad_into's fused loss == loss(); loss_delta == the allocating
        // default composition (loss + apply_update + loss) — bitwise, at
        // every remainder mod LANES.
        for len in lane_width_sweep() {
            let e = SimExec::new(&spec_with(len), 13);
            let theta = e.init_params().unwrap();
            let toks = tokens(&e, 7 * len as i32 + 1);
            let padded = e.meta.padded_count;
            let mut rng = Rng::new(len as u64);
            let coeff: Vec<f32> = (0..padded)
                .map(|_| match rng.below(3) {
                    0 => 1.0,
                    1 => -1.0,
                    _ => 0.0,
                })
                .collect();
            let step = 0.013f32;

            let mut g = Vec::new();
            let fused_loss = e.grad_into(&theta, &toks, &mut g).unwrap();
            assert_eq!(
                fused_loss.to_bits(),
                e.loss(&theta, &toks).unwrap().to_bits(),
                "len {len}: grad_into loss"
            );

            let (d0, d1) = e.loss_delta(&theta, &coeff, step, &toks).unwrap();
            let stepped = e.apply_update(&theta, &coeff, step).unwrap();
            let (c0, c1) =
                (e.loss(&theta, &toks).unwrap(), e.loss(&stepped, &toks).unwrap());
            assert_eq!((d0.to_bits(), d1.to_bits()), (c0.to_bits(), c1.to_bits()), "len {len}");
        }
    }
}
