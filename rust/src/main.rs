//! `gauntlet` — CLI launcher for the Templar/Gauntlet reproduction.
//!
//! Subcommands:
//!   run       permissionless Gauntlet training run (the paper's system)
//!   soak      adversary-zoo endurance harness: long runs with rolling
//!             invariant checks, scenario fuzzing, and seed repro
//!   bench     PerfLab benchmark suites with a baseline regression gate
//!   baseline  centralized AdamW DDP comparison run
//!   eval      downstream zero-shot suites on the initial model
//!   info      print a config's artifact/ABI summary
//!   lint      determinism & unsafety static analysis (in-tree detlint)
//!
//! Examples:
//!   gauntlet run --model nano --rounds 20 --peers 6 --topg 3
//!   gauntlet run --model tiny --rounds 100 --peers "honest,honest:2,desync,poisoner"
//!   gauntlet bench --suite hotpath --out BENCH_hotpath.json \
//!       --compare baseline/BENCH_hotpath.json --fail-over 1.25
//!   gauntlet baseline --model nano --rounds 20 --workers 4

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use gauntlet::bench::{human_duration, sparkline, suite, Table};
use gauntlet::coordinator::baseline::{AdamWParams, AdamWTrainer};
use gauntlet::coordinator::engine::{GauntletBuilder, GauntletEngine};
use gauntlet::coordinator::events::JsonlTraceObserver;
use gauntlet::coordinator::snapshot::RunSnapshot;
use gauntlet::data::Corpus;
use gauntlet::eval::{evaluate_suite, Suite};
use gauntlet::peers::Behavior;
use gauntlet::runtime::{artifact_dir, Executor};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let flags = parse_flags(&args[1..])?;
    match cmd.as_str() {
        "run" => cmd_run(&flags),
        "soak" => cmd_soak(&flags),
        "bench" => cmd_bench(&flags),
        "baseline" => cmd_baseline(&flags),
        "eval" => cmd_eval(&flags),
        "info" => cmd_info(&flags),
        "lint" => cmd_lint(&flags),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command {other:?}; try `gauntlet help`"),
    }
}

fn print_usage() {
    println!(
        "gauntlet — Incentivizing Permissionless Distributed Learning of LLMs\n\
         \n\
         USAGE: gauntlet <command> [--flag value ...]\n\
         \n\
         COMMANDS\n\
         \x20 run       Gauntlet permissionless training run\n\
         \x20           --model <cfg>      artifact config (default nano)\n\
         \x20           --rounds <n>       communication rounds (default 20)\n\
         \x20           --peers <spec>     count or comma list, e.g.\n\
         \x20                              \"honest,honest:2,desync,poisoner,copier:0\"\n\
         \x20           --topg <g>         aggregation size (default 4)\n\
         \x20           --eval-sample <s>  peers primary-evaluated per round\n\
         \x20           --seed <s>         run seed\n\
         \x20           --threads <n>      pipeline workers (0 = auto, 1 = sequential)\n\
         \x20           --scenario <f|s>   churn script, a file or inline, e.g.\n\
         \x20                              \"@3 join honest; @5 leave 4; @7 outage 0.5 2\"\n\
         \x20           --max-uids <n>     chain slot cap incl. validators (0 = unbounded;\n\
         \x20                              full table evicts the lowest-incentive peer)\n\
         \x20           --immunity <r>     rounds of post-registration eviction immunity\n\
         \x20           --lr <f> --schedule constant|cosine:<w>:<t>[:<min>]|halve:<n>\n\
         \x20           --no-normalize     disable encoded-domain normalization (§4 ablation)\n\
         \x20           --metrics-out <f>  write the RunMetrics JSON to a file (on\n\
         \x20                              --resume: the post-resume rounds only)\n\
         \x20           --trace-out <f>    stream the typed round-event JSONL trace to a file\n\
         \x20           --snapshot-out <f> write a resumable run snapshot at the end\n\
         \x20           --resume <f>       continue a snapshotted run (--rounds = new total;\n\
         \x20                              omit to finish the originally configured rounds)\n\
         \x20           (without compiled artifacts, `run` falls back to the\n\
         \x20            deterministic pure-Rust SimExec backend)\n\
         \x20 soak      adversary-zoo endurance harness (see README \"Adversary zoo\")\n\
         \x20           --rounds <n>       soak length (default 2000)\n\
         \x20           --peers <spec>     population (default: full mixed zoo)\n\
         \x20           --snapshot-every <n> snapshot/resume self-test cadence (0 = off)\n\
         \x20           --churn <rate>     production-rate registration churn:\n\
         \x20                              steady joins/round against a capped slot\n\
         \x20                              table (0 = off; evicts lowest incentive)\n\
         \x20           --chaos <p>        storage-fault profile: rolling read-path\n\
         \x20                              chaos windows (get-fail / corrupt) at\n\
         \x20                              probability p; with --fuzz/--repro, the\n\
         \x20                              generated scripts gain chaos directives\n\
         \x20                              capped at p (dominance waived when p > 0.3\n\
         \x20                              or an eclipse lands)\n\
         \x20           --fuzz <cases>     instead: run N random adversary scripts\n\
         \x20                              through full engine runs (prop::scenario)\n\
         \x20           --fuzz-seed <s>    base seed for --fuzz\n\
         \x20           --failures-out <f> write failing fuzz seeds as JSONL\n\
         \x20           --repro <seed>     instead: re-run one printed fuzz failure\n\
         \x20           --size <n>         size hint for --repro (from the report)\n\
         \x20           --model/--seed/--threads/--eval-every as for `run`\n\
         \x20 bench     PerfLab benchmark suites (see README \"Performance\")\n\
         \x20           --suite <name>     suite to run (default hotpath)\n\
         \x20           --quick            shrink iteration counts (PR gate)\n\
         \x20           --out <f>          write BENCH_<suite>.json schema to a file\n\
         \x20           --compare <f>      diff against a baseline BENCH_*.json;\n\
         \x20                              exits non-zero on regression\n\
         \x20           --fail-over <r>    regression threshold ratio (default 1.25)\n\
         \x20           --list             list registered suites and benches\n\
         \x20 baseline  AdamW DDP comparison\n\
         \x20           --model/--rounds/--workers/--seed\n\
         \x20 eval      downstream suites on the init model\n\
         \x20           --model/--items\n\
         \x20 info      print a config's ABI summary (--model)\n\
         \x20 lint      determinism & unsafety lint (see README \"Correctness tooling\")\n\
         \x20           --path <dir>       source tree to scan (default rust/src)\n"
    );
}

fn parse_flags(args: &[String]) -> Result<BTreeMap<String, String>> {
    let mut out = BTreeMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        let Some(name) = a.strip_prefix("--") else {
            bail!("expected --flag, got {a:?}");
        };
        // boolean flags
        const BOOL_FLAGS: &[&str] = &["no-normalize", "quick", "list"];
        if BOOL_FLAGS.contains(&name) {
            out.insert(name.to_string(), "true".to_string());
            i += 1;
            continue;
        }
        let v = args.get(i + 1).with_context(|| format!("--{name} needs a value"))?;
        out.insert(name.to_string(), v.clone());
        i += 2;
    }
    Ok(out)
}

fn flag<T: std::str::FromStr>(flags: &BTreeMap<String, String>, name: &str, default: T) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    match flags.get(name) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{name} {v:?}: {e}")),
    }
}

/// Parse a peer spec: either a count ("6" = that many honest peers) or a
/// comma list of behaviour tokens (the [`Behavior::parse_spec`] grammar,
/// shared with scenario `join` events):
///   honest | honest:<mult> | freeloader | desync[:<at>[:<pause>]] |
///   late[:<prob>] | silent[:<prob>] | format | rescaler[:<f>] |
///   poisoner[:<scale>] | copier[:<uid>] | duplicator[:<uid>] |
///   sybil[:<ring>[:<eps>]] | copycat[:<uid>[:<noise>]] |
///   briber[:<uid>] | slowloris | stale[:<lag>]
pub fn parse_peers(spec: &str) -> Result<Vec<Behavior>> {
    if let Ok(n) = spec.parse::<usize>() {
        return Ok(vec![Behavior::Honest { data_mult: 1.0 }; n]);
    }
    spec.split(',')
        .map(|part| Behavior::parse_spec(part).map_err(|e| anyhow::anyhow!("--peers: {e}")))
        .collect()
}

/// Resolve `--scenario`: a value that *looks* like a script (starts with
/// `@`, a JSON bracket, or a `#` comment) is parsed inline; anything else
/// is a file path and must exist — so a typo'd filename reports
/// file-not-found instead of a misleading script syntax error.
fn parse_scenario(value: &str) -> Result<gauntlet::scenario::Scenario> {
    let looks_inline = value.trim_start().starts_with(['@', '{', '[', '#']);
    let text = if looks_inline {
        value.to_string()
    } else {
        std::fs::read_to_string(value)
            .with_context(|| format!("--scenario: reading script file {value:?}"))?
    };
    gauntlet::scenario::Scenario::parse(&text)
        .map_err(|e| anyhow::anyhow!("--scenario {value:?}: {e}"))
}

fn cmd_run(flags: &BTreeMap<String, String>) -> Result<()> {
    // --resume rebuilds the whole run from a snapshot (which embeds its
    // config); otherwise the flags assemble a fresh config. Either way the
    // result is a GauntletEngine behind the auto backend (artifacts when
    // available, SimExec fallback otherwise).
    let mut builder = if let Some(path) = flags.get("resume") {
        // Only continuation-shaped flags apply on resume; everything that
        // shapes the run (population, scenario, seed, hyperparameters)
        // lives in the snapshot. Reject anything else loudly — silently
        // ignoring `--scenario` or `--seed` would run a different
        // experiment than the user asked for.
        const RESUME_FLAGS: &[&str] =
            &["resume", "rounds", "threads", "metrics-out", "trace-out", "snapshot-out"];
        for name in flags.keys() {
            if !RESUME_FLAGS.contains(&name.as_str()) {
                bail!(
                    "--{name} cannot be combined with --resume: the snapshot already \
                     fixes the run's configuration (allowed here: {})",
                    RESUME_FLAGS.iter().map(|f| format!("--{f}")).collect::<Vec<_>>().join(" ")
                );
            }
        }
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("--resume: reading snapshot {path:?}"))?;
        let snap = RunSnapshot::parse(&text)
            .with_context(|| format!("--resume: parsing snapshot {path:?}"))?;
        // `--rounds` is the run's *total*; a total at or below the
        // snapshot's round would "resume" zero rounds and still print a
        // plausible fingerprint — refuse instead of succeeding vacuously.
        let total = match flags.get("rounds") {
            Some(r) => r.parse().map_err(|e| anyhow::anyhow!("--rounds {r:?}: {e}"))?,
            None => snap.cfg.rounds,
        };
        if total <= snap.round {
            bail!(
                "--resume: snapshot is already at round {} and the run target is \
                 --rounds {total} (a total, not an increment); pass --rounds {} or more \
                 to continue",
                snap.round,
                snap.round + 1
            );
        }
        println!("resuming from {path:?} at round {} (target {total})", snap.round);
        let mut b = GauntletBuilder::auto().resume(snap).rounds(total);
        if let Some(t) = flags.get("threads") {
            b = b.threads(t.parse().map_err(|e| anyhow::anyhow!("--threads {t:?}: {e}"))?);
        }
        b
    } else {
        let model: String = flag(flags, "model", "nano".to_string())?;
        let rounds: u64 = flag(flags, "rounds", 20)?;
        let peers = parse_peers(&flag(flags, "peers", "6".to_string())?)?;
        let mut cfg = gauntlet::coordinator::run::RunConfig {
            model,
            rounds,
            peers,
            ..Default::default()
        };
        cfg.params.top_g = flag(flags, "topg", cfg.params.top_g)?;
        cfg.params.eval_sample = flag(flags, "eval-sample", cfg.params.eval_sample)?;
        cfg.params.lr = flag(flags, "lr", cfg.params.lr)?;
        if let Some(spec) = flags.get("schedule") {
            cfg.params.schedule = gauntlet::coordinator::schedule::LrSchedule::parse(spec)
                .map_err(|e| anyhow::anyhow!("--schedule: {e}"))?;
        }
        cfg.seed = flag(flags, "seed", 0)?;
        cfg.eval_every = flag(flags, "eval-every", 5)?;
        cfg.threads = flag(flags, "threads", 0)?;
        cfg.max_uids = flag(flags, "max-uids", 0)?;
        cfg.immunity_rounds = flag(flags, "immunity", cfg.immunity_rounds)?;
        if let Some(spec) = flags.get("scenario") {
            cfg.scenario = parse_scenario(spec)?;
        }
        if flags.contains_key("no-normalize") {
            cfg.agg.normalize = false;
        }
        GauntletBuilder::auto().config(cfg)
    };

    // Observers compose instead of being inlined: a JSONL trace file is
    // just one more subscriber to the round-event stream.
    let trace = match flags.get("trace-out") {
        Some(path) => {
            let obs = JsonlTraceObserver::create(path)?;
            builder = builder.observer(obs.clone());
            Some(obs)
        }
        None => None,
    };

    let mut engine = builder.build()?;
    let cfg = engine.cfg();
    println!(
        "Gauntlet run: model={} backend={} rounds={} peers={} topG={} S={} normalize={} threads={} scenario-events={}",
        cfg.model,
        engine.backend_name(),
        cfg.rounds,
        engine.peers().len(),
        cfg.params.top_g,
        cfg.params.eval_sample,
        cfg.agg.normalize,
        cfg.effective_threads(),
        cfg.scenario.len(),
    );

    drive(&mut engine)?;

    if let Some(stats) = engine.exec_stats() {
        print_exec_stats(&stats);
    }
    if let Some(obs) = &trace {
        obs.flush()?;
    }
    if let Some(path) = flags.get("metrics-out") {
        let metrics = engine.metrics_observer().metrics();
        let covered = match (metrics.rounds.first(), metrics.rounds.last()) {
            (Some(a), Some(b)) => format!("rounds {}..={}", a.round, b.round),
            _ => "no rounds".to_string(),
        };
        std::fs::write(path, metrics.to_json().write())
            .with_context(|| format!("--metrics-out: writing {path:?}"))?;
        // On a resumed run this covers only the post-resume rounds — the
        // metrics observer starts fresh with the resumed engine.
        println!("metrics written to {path} ({covered})");
    }
    if let Some(path) = flags.get("snapshot-out") {
        let json = engine.snapshot().to_json().write();
        std::fs::write(path, json)
            .with_context(|| format!("--snapshot-out: writing {path:?}"))?;
        println!("snapshot written to {path} (resume with --resume {path})");
    }
    // The CI resume-smoke job diffs this line between a straight run and a
    // snapshot-then-resume run — they must match bit-for-bit.
    println!("run fingerprint: {:016x}", engine.fingerprint());
    Ok(())
}

fn drive(engine: &mut GauntletEngine) -> Result<()> {
    let mut losses = Vec::new();
    while engine.round() < engine.cfg().rounds {
        let r = engine.round();
        let rec = engine.run_round()?;
        for e in &rec.events {
            println!("round {r:>4}  ** {e}");
        }
        if let Some(l) = rec.heldout_loss {
            losses.push(l);
            println!(
                "round {r:>4}  heldout={l:.4}  local={:.4}  valid={}  topG={:?}",
                rec.mean_local_loss, rec.n_valid_submissions, rec.top_g
            );
        }
    }
    println!("\nloss curve: {}", sparkline(&losses, 60));

    // final scoreboard
    let mut t = Table::new(
        "final peer standings",
        &["uid", "behaviour", "mu", "rating", "score", "balance"],
    );
    let book = &engine.validators()[0].book;
    for p in engine.peers() {
        let st = book.get(p.uid);
        t.row(&[
            p.uid.to_string(),
            p.behavior.label(),
            st.map(|s| format!("{:+.3}", s.mu.value)).unwrap_or_default(),
            st.map(|s| format!("{:.2}", s.rating.mu)).unwrap_or_default(),
            format!("{:.3}", book.peer_score(p.uid)),
            format!(
                "{:.3}",
                engine.chain().neuron(p.uid).map(|n| n.balance).unwrap_or(0.0)
            ),
        ]);
    }
    t.print();
    Ok(())
}

/// Parse a fuzzer seed: decimal or `0x`-prefixed hex, so the hex seeds the
/// failure reports print paste straight back into `--repro`.
fn parse_seed(s: &str) -> Result<u64> {
    let t = s.trim();
    match t.strip_prefix("0x").or_else(|| t.strip_prefix("0X")) {
        Some(h) => u64::from_str_radix(h, 16).map_err(|e| anyhow::anyhow!("seed {s:?}: {e}")),
        None => t.parse().map_err(|e| anyhow::anyhow!("seed {s:?}: {e}")),
    }
}

/// `gauntlet soak`: the adversary-zoo endurance harness (README "Adversary
/// zoo"). Three modes:
///
/// - default: a multi-thousand-round run of a mixed zoo population with
///   rolling invariant checks every round, periodic snapshot/resume
///   self-tests, and a final class-dominance report;
/// - `--fuzz <cases>`: random churn + adversary scripts through full
///   engine runs via `prop::scenario`, printing a standalone-reproducing
///   seed per failure (the CI nightly runs this at high case counts);
/// - `--repro <seed> --size <n>`: re-run exactly one reported failure.
fn cmd_soak(flags: &BTreeMap<String, String>) -> Result<()> {
    use gauntlet::prop::scenario::{check_class_dominance, check_seed_chaos, InvariantTracker};

    // Storage-fault intensity shared by every soak mode: fuzz/repro cap
    // their generated chaos directives at this probability, the default
    // endurance run schedules rolling chaos windows with it.
    let chaos: f64 = flag(flags, "chaos", 0.0)?;
    anyhow::ensure!(
        (0.0..=1.0).contains(&chaos),
        "--chaos must be a probability in [0, 1]"
    );

    if let Some(seed) = flags.get("repro") {
        let seed = parse_seed(seed)?;
        let size: usize = flag(flags, "size", 32)?;
        println!("repro: seed={seed:#x} size={size} chaos={chaos}");
        return match check_seed_chaos(seed, size, chaos) {
            Ok(()) => {
                println!("repro passed: all invariants hold at this seed");
                Ok(())
            }
            Err(e) => bail!("repro failed:\n{e}"),
        };
    }

    if let Some(cases) = flags.get("fuzz") {
        let cases: u64 = cases.parse().map_err(|e| anyhow::anyhow!("--fuzz {cases:?}: {e}"))?;
        let base = parse_seed(&flag(flags, "fuzz-seed", format!("{}", 0x9A0C_0000_0000_u64))?)?;
        let mut failures: Vec<(u64, usize, String)> = Vec::new();
        for case in 0..cases {
            // Same seed/size schedule as prop::check so in-tree and CLI
            // fuzzing explore the same family of cases.
            let seed = base.wrapping_add(case);
            let size = 1 + (case as usize * 7) % 64;
            if let Err(e) = check_seed_chaos(seed, size, chaos) {
                let chaos_arg =
                    if chaos > 0.0 { format!(" --chaos {chaos}") } else { String::new() };
                eprintln!(
                    "FAIL case={case} seed={seed:#x} size={size} chaos={chaos}\n{e}\n  \
                     repro: gauntlet soak --repro {seed:#x} --size {size}{chaos_arg}"
                );
                failures.push((seed, size, e));
            }
            if (case + 1) % 10 == 0 {
                println!("fuzz: {}/{cases} cases, {} failure(s)", case + 1, failures.len());
            }
        }
        if let Some(path) = flags.get("failures-out") {
            let lines: String = failures
                .iter()
                .map(|(seed, size, e)| {
                    format!(
                        "{{\"seed\":\"{seed:#x}\",\"size\":{size},\"chaos\":{chaos},\"error\":{}}}\n",
                        gauntlet::minjson::Value::Str(e.clone()).write()
                    )
                })
                .collect();
            std::fs::write(path, lines)
                .with_context(|| format!("--failures-out: writing {path:?}"))?;
        }
        if !failures.is_empty() {
            bail!("{}/{cases} fuzz case(s) failed (repro commands above)", failures.len());
        }
        println!("fuzz: all {cases} cases passed");
        return Ok(());
    }

    let model: String = flag(flags, "model", "nano".to_string())?;
    let rounds: u64 = flag(flags, "rounds", 2_000)?;
    let seed: u64 = flag(flags, "seed", 0)?;
    let snapshot_every: u64 = flag(flags, "snapshot-every", 500)?;
    // One of every adversary class against a honest majority-of-work
    // population; victim uids point at the honest block (validator is uid
    // 0, peers start at uid 1). The lone validator holds the stake
    // majority, so `briber:0` also soaks the successful-bribe regime.
    let default_zoo = "honest,honest,honest:2,honest,freeloader,late:0.3,silent:0.2,\
                       rescaler:10,poisoner:50,copier:2,duplicator:3,sybil:1:0.05,\
                       sybil:1:0.05,copycat:3:0.1,briber:0,slowloris,stale:3";
    let peers = parse_peers(&flag(flags, "peers", default_zoo.to_string())?)?;
    let n_peers = peers.len();

    // Production-rate registration churn (`--churn <joins/round>`):
    // newcomers arrive at a steady rate against a capped slot table, so
    // once the table fills every join displaces the lowest-incentive
    // peer. This soaks the chain's derived indexes (hotkey map, stake
    // order, paid set) at the registration rhythm a live subnet sees —
    // a long churny run cycles far more uids through the table than are
    // ever active, exactly the regime the sparse epoch is built for.
    let churn: f64 = flag(flags, "churn", 0.0)?;
    anyhow::ensure!(
        churn >= 0.0 && churn.is_finite(),
        "--churn must be a finite joins-per-round rate >= 0"
    );
    let scenario = if churn > 0.0 || chaos > 0.0 {
        let classes = ["honest", "freeloader", "late:0.3", "stale:3"];
        let mut script = String::new();
        let mut due = 0.0_f64;
        let mut k = 0usize;
        for r in 1..rounds {
            due += churn;
            while due >= 1.0 {
                due -= 1.0;
                script.push_str(&format!("@{r} join {}\n", classes[k % classes.len()]));
                k += 1;
            }
        }
        if chaos > 0.0 {
            // Rolling read-path fault windows at roughly a 1/3 duty
            // cycle, alternating GET failures with payload corruption
            // so the digest-verdict rejection path soaks alongside the
            // retry budget.
            let mut r = 5_u64;
            let mut w = 0_usize;
            while r + 3 < rounds {
                let kind = if w % 2 == 0 { "get-fail" } else { "corrupt" };
                script.push_str(&format!("@{r} chaos {kind} {chaos} 3\n"));
                w += 1;
                r += 9;
            }
        }
        gauntlet::scenario::Scenario::parse(&script)?
    } else {
        gauntlet::scenario::Scenario::default()
    };
    let churn_events = scenario.len();

    let mut engine = GauntletBuilder::sim()
        .model(&model)
        .rounds(rounds)
        .peers(peers)
        .scenario(scenario)
        // The cap (initial population + slack) is what turns the steady
        // join stream into production churn: join -> immunity -> evict.
        .max_uids(if churn > 0.0 { 1 + n_peers + 2 } else { 0 })
        .seed(seed)
        .threads(flag(flags, "threads", 0)?)
        .eval_every(flag(flags, "eval-every", 0)?)
        .eval_sample(n_peers.max(8))
        .build()?;
    println!(
        "soak: model={model} rounds={rounds} peers={n_peers} seed={seed} \
         snapshot-every={snapshot_every} churn={churn}/round chaos={chaos} \
         ({churn_events} scripted events)"
    );

    let mut tracker = InvariantTracker::default();
    let mut self_tests = 0_u64;
    while engine.round() < rounds {
        let r = engine.round();
        let snap = (snapshot_every > 0 && r > 0 && r % snapshot_every == 0)
            .then(|| engine.snapshot());
        let rec = engine.run_round()?;
        tracker
            .observe(&rec)
            .map_err(|e| anyhow::anyhow!("invariant violated at round {r} (--seed {seed}): {e}"))?;
        if let Some(snap) = snap {
            // The snapshot was taken before this round ran; a resumed
            // engine replaying just that round must land on the same
            // fingerprint bit-for-bit.
            let mut resumed = GauntletBuilder::sim().resume(snap).rounds(r + 1).build()?;
            resumed.run_round()?;
            anyhow::ensure!(
                resumed.fingerprint() == engine.fingerprint(),
                "snapshot/resume self-test diverged at round {r}: resumed {:016x} vs \
                 live {:016x} (--seed {seed})",
                resumed.fingerprint(),
                engine.fingerprint()
            );
            self_tests += 1;
        }
        if (r + 1) % 100 == 0 {
            println!("soak: round {}/{rounds} ok ({self_tests} snapshot self-tests)", r + 1);
        }
    }

    let mut honest = Vec::new();
    let mut groups: BTreeMap<&'static str, Vec<f64>> = BTreeMap::new();
    for p in engine.peers() {
        let bal = engine.chain().neuron(p.uid).map(|n| n.balance).unwrap_or(0.0);
        let class = p.behavior.class();
        if class == "honest" {
            honest.push(bal);
        } else {
            groups.entry(class).or_default().push(bal);
        }
    }
    let mut t = Table::new("soak class earnings", &["class", "members", "mean balance"]);
    let h_mean = honest.iter().sum::<f64>() / honest.len().max(1) as f64;
    t.row(&["honest".to_string(), honest.len().to_string(), format!("{h_mean:.3}")]);
    for (class, bals) in &groups {
        let mean = bals.iter().sum::<f64>() / bals.len() as f64;
        t.row(&[class.to_string(), bals.len().to_string(), format!("{mean:.3}")]);
    }
    t.print();
    if chaos <= 0.3 {
        // The honest-strictly-out-earn invariant is only promised up to
        // moderate fault rates; past that, enough honest submissions are
        // chance-eclipsed per round that strict dominance can flip.
        check_class_dominance(&honest, &groups)
            .map_err(|e| anyhow::anyhow!("final class dominance (--seed {seed}): {e}"))?;
    } else {
        println!("soak: chaos={chaos} > 0.3, class-dominance check waived");
    }
    println!(
        "soak OK: {rounds} rounds, {self_tests} snapshot/resume self-tests, \
         fingerprint {:016x}",
        engine.fingerprint()
    );
    Ok(())
}

/// `gauntlet bench`: run a PerfLab suite, optionally persist the
/// machine-readable result (`--out`) and gate against a baseline file
/// (`--compare` + `--fail-over`) — the CI regression gate exits non-zero
/// through the error path when any bench regressed beyond the threshold.
fn cmd_bench(flags: &BTreeMap<String, String>) -> Result<()> {
    if flags.contains_key("list") {
        for s in suite::registry() {
            println!("{} — {}", s.name, s.description);
            for b in &s.benches {
                println!("  {}", b.name);
            }
        }
        return Ok(());
    }
    let name: String = flag(flags, "suite", "hotpath".to_string())?;
    let spec = suite::find_suite(&name)
        .ok_or_else(|| anyhow::anyhow!("unknown suite {name:?}; try `gauntlet bench --list`"))?;
    let ctx = suite::BenchCtx { quick: flags.contains_key("quick") };
    let result = suite::run_suite(&spec, &ctx)?;
    println!(
        "suite {} (schema v{}): {} benches, commit {}, {} threads available",
        result.suite,
        result.schema_version,
        result.benches.len(),
        result.fingerprint.git_commit,
        result.fingerprint.threads,
    );
    if let Some(path) = flags.get("out") {
        std::fs::write(path, result.to_json().write())
            .with_context(|| format!("--out: writing {path:?}"))?;
        println!("results written to {path}");
    }
    if let Some(path) = flags.get("compare") {
        let fail_over: f64 = flag(flags, "fail-over", 1.25)?;
        anyhow::ensure!(
            fail_over.is_finite() && fail_over > 0.0,
            "--fail-over must be a positive ratio, got {fail_over}"
        );
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("--compare: reading baseline {path:?}"))?;
        let parsed = gauntlet::minjson::Value::parse(&text)
            .map_err(|e| anyhow::anyhow!("--compare: baseline {path:?}: {e}"))?;
        let baseline = gauntlet::bench::suite::SuiteResult::from_json(&parsed)
            .with_context(|| format!("--compare: baseline {path:?}"))?;
        // Quick mode shrinks iteration counts AND the round-pipeline
        // workload, so quick and full results are not comparable — refuse
        // rather than reporting spurious (non-)regressions.
        anyhow::ensure!(
            result.quick == baseline.quick,
            "--compare: this run is {} but baseline {path:?} was recorded {}; \
             regenerate the baseline in the same mode (see baseline/README.md)",
            if result.quick { "--quick" } else { "full" },
            if baseline.quick { "with --quick" } else { "in full mode" },
        );
        let cmp = suite::compare(&result, &baseline, fail_over);
        // One verdict source: rows are marked by membership in the
        // regression list compare() produced, never by re-deriving the
        // threshold rule here.
        let regressed: std::collections::BTreeSet<&str> =
            cmp.regressions.iter().map(|d| d.name.as_str()).collect();
        let mut t = Table::new(
            &format!("vs {path} (fail-over {fail_over:.2}x)"),
            &["bench", "baseline", "current", "ratio"],
        );
        for d in &cmp.deltas {
            let marker =
                if regressed.contains(d.name.as_str()) { "  ** REGRESSION" } else { "" };
            t.row(&[
                d.name.clone(),
                human_duration(d.baseline_mean_s),
                human_duration(d.current_mean_s),
                format!("{:.2}x{marker}", d.ratio),
            ]);
        }
        t.print();
        for n in &cmp.only_in_current {
            println!("note: {n} has no baseline entry yet (refresh baseline/ to gate it)");
        }
        for n in &cmp.only_in_baseline {
            println!("note: baseline entry {n} is no longer registered");
        }
        if !cmp.regressions.is_empty() {
            let names: Vec<String> = cmp
                .regressions
                .iter()
                .map(|d| format!("{} ({:.2}x)", d.name, d.ratio))
                .collect();
            bail!(
                "{} bench(es) regressed beyond {fail_over}x vs {path}: {}",
                cmp.regressions.len(),
                names.join(", ")
            );
        }
        println!("no regressions vs {path} (fail-over {fail_over:.2}x)");
    }
    Ok(())
}

fn cmd_baseline(flags: &BTreeMap<String, String>) -> Result<()> {
    let model: String = flag(flags, "model", "nano".to_string())?;
    let rounds: u64 = flag(flags, "rounds", 20)?;
    let workers: usize = flag(flags, "workers", 4)?;
    let seed: u64 = flag(flags, "seed", 0)?;
    let exec = Executor::load(artifact_dir(&model))?;
    let corpus = Corpus::new(exec.meta.vocab as u32, seed);
    let mut trainer = AdamWTrainer::new(exec.init_params()?, AdamWParams::default(), workers);
    println!("AdamW DDP baseline: model={model} rounds={rounds} workers={workers}");
    let mut losses = Vec::new();
    for r in 0..rounds {
        let loss = trainer.step(&exec, &corpus, r)?;
        losses.push(loss);
        if r % 5 == 0 {
            let toks = corpus.heldout(0, exec.meta.batch, exec.meta.seq + 1);
            let hl = exec.loss(&trainer.theta, &toks)?;
            println!("round {r:>4}  train={loss:.4}  heldout={hl:.4}");
        }
    }
    println!("\ntrain curve: {}", sparkline(&losses, 60));
    print_exec_stats(&exec.stats());
    Ok(())
}

fn cmd_eval(flags: &BTreeMap<String, String>) -> Result<()> {
    let model: String = flag(flags, "model", "nano".to_string())?;
    let items: usize = flag(flags, "items", 50)?;
    let exec = Executor::load(artifact_dir(&model))?;
    let corpus = Corpus::new(exec.meta.vocab as u32, 0);
    let theta = exec.init_params()?;
    let mut t = Table::new("downstream (init model)", &["suite", "items", "acc_norm", "chance"]);
    for suite in Suite::all() {
        let r = evaluate_suite(&exec, &theta, &corpus, suite, items)?;
        t.row(&[
            r.suite.name().to_string(),
            r.n_items.to_string(),
            format!("{:.3}", r.acc_norm),
            format!("{:.3}", r.chance),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_info(flags: &BTreeMap<String, String>) -> Result<()> {
    let model: String = flag(flags, "model", "nano".to_string())?;
    let exec = Executor::load(artifact_dir(&model))?;
    let m = &exec.meta;
    println!("config {}", m.name);
    println!(
        "  d_model={} layers={} vocab={} seq={} batch={}",
        m.d_model, m.n_layers, m.vocab, m.seq, m.batch
    );
    println!(
        "  params={} padded={} chunks={}x{}  topk={}  coeffs/pseudograd={}",
        m.param_count,
        m.padded_count,
        m.n_chunks,
        m.chunk * m.chunk,
        m.topk,
        m.coeff_count
    );
    println!(
        "  compression ratio: {:.0}x (dense f32 vs sparse val+idx)",
        (m.param_count as f64 * 4.0) / (m.coeff_count as f64 * 8.0)
    );
    println!("  artifacts: {}", m.artifacts.join(", "));
    println!("  tensors: {}", m.params.len());
    Ok(())
}

/// `gauntlet lint`: the in-tree determinism/unsafety scan, identical to
/// `cargo run -p detlint -- rust/src` (see README "Correctness tooling").
fn cmd_lint(flags: &BTreeMap<String, String>) -> Result<()> {
    let path = match flags.get("path") {
        Some(p) => std::path::PathBuf::from(p),
        None => {
            // From the workspace root `rust/src` exists; when invoked
            // from elsewhere, fall back to this crate's own source tree.
            let local = std::path::Path::new("rust/src");
            if local.is_dir() {
                local.to_path_buf()
            } else {
                std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src")
            }
        }
    };
    let report =
        detlint::scan_tree(&path).with_context(|| format!("scanning {}", path.display()))?;
    for f in &report.findings {
        println!("{f}");
    }
    println!(
        "detlint: {} file(s), {} finding(s), {} allow(s) in effect",
        report.files,
        report.findings.len(),
        report.allows_used
    );
    if !report.findings.is_empty() {
        bail!(
            "{} determinism/unsafety finding(s); fix the site or add a reasoned \
             `// detlint: allow(RULE, reason)`",
            report.findings.len()
        );
    }
    Ok(())
}

fn print_exec_stats(stats: &BTreeMap<String, gauntlet::runtime::ExecStats>) {
    if stats.is_empty() {
        return;
    }
    let mut t = Table::new("XLA executor stats", &["artifact", "calls", "total", "mean"]);
    for (name, s) in stats {
        let mean = if s.calls > 0 { s.total.as_secs_f64() / s.calls as f64 } else { 0.0 };
        t.row(&[
            name.clone(),
            s.calls.to_string(),
            format!("{:.2}s", s.total.as_secs_f64()),
            gauntlet::bench::human_duration(mean),
        ]);
    }
    t.print();
}
