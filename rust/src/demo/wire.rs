//! Wire format for pseudo-gradient submissions placed in cloud buckets.
//!
//! One object per (peer, round): a small header, the sparse DCT
//! coefficients, the SyncScore probe (2 sampled parameter values per
//! tensor, §3.2), and a SHA-256 integrity digest. The digest plus strict
//! structural validation is what lets the validator's *fast evaluation*
//! reject malformed submissions ("violating the format — e.g. tensors with
//! incorrect dimensions or data types") in microseconds, without touching
//! the model.
//!
//! Layout (little-endian):
//!   magic  u32 = 0x474E_544C ("GNTL")
//!   version u16 = 1, flags u16 = 0
//!   uid u32, round u64
//!   coeff_count u32, probe_count u32
//!   vals  f32 * coeff_count
//!   idx   i32 * coeff_count
//!   probe f32 * probe_count
//!   digest = sha256(everything above), 32 bytes

use sha2::{Digest, Sha256};

use super::SparseGrad;

pub const MAGIC: u32 = 0x474E_544C;
pub const VERSION: u16 = 1;

#[derive(Clone, Debug, PartialEq)]
pub struct Submission {
    pub uid: u32,
    pub round: u64,
    pub grad: SparseGrad,
    /// SyncScore probe: sampled parameter values (2 per tensor).
    pub probe: Vec<f32>,
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum WireError {
    #[error("object too short ({0} bytes)")]
    Truncated(usize),
    #[error("bad magic {0:#x}")]
    BadMagic(u32),
    #[error("unsupported version {0}")]
    BadVersion(u16),
    #[error("length mismatch: header says {expected} bytes, object has {actual}")]
    LengthMismatch { expected: usize, actual: usize },
    #[error("integrity digest mismatch")]
    BadDigest,
}

impl Submission {
    pub fn encode(&self) -> Vec<u8> {
        let c = self.grad.vals.len();
        let p = self.probe.len();
        let mut out = Vec::with_capacity(28 + 8 * c + 4 * p + 32);
        out.extend_from_slice(&MAGIC.to_le_bytes());
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&0u16.to_le_bytes());
        out.extend_from_slice(&self.uid.to_le_bytes());
        out.extend_from_slice(&self.round.to_le_bytes());
        out.extend_from_slice(&(c as u32).to_le_bytes());
        out.extend_from_slice(&(p as u32).to_le_bytes());
        // Bulk little-endian fast path for the three numeric sections
        // (the overwhelming bulk of the object): one memcpy each on LE
        // targets, byte-wise fallback elsewhere — identical bytes either
        // way (see `util::extend_f32_le` and its endianness test).
        crate::util::extend_f32_le(&mut out, &self.grad.vals);
        crate::util::extend_i32_le(&mut out, &self.grad.idx);
        crate::util::extend_f32_le(&mut out, &self.probe);
        let digest = Sha256::digest(&out);
        out.extend_from_slice(&digest);
        out
    }

    pub fn decode(bytes: &[u8]) -> Result<Submission, WireError> {
        let frame = Frame::parse(bytes)?;
        if !frame.digest_ok(bytes) {
            return Err(WireError::BadDigest);
        }
        Ok(frame.decode_sections(bytes))
    }

    /// Decode a stored object, memoizing the SHA-256 integrity check on
    /// the object itself. Validators share one `Arc<Object>` per (peer,
    /// round) submission, so the first reader pays the hash and every
    /// other validator (and every later probe of the same object) gets
    /// the verdict for free — encode-once, hash-once.
    ///
    /// Structural checks (magic/version/length) stay per-call: they are
    /// a few header reads, and keeping them out of the memo means the
    /// memo is purely the digest verdict the doc above promises.
    pub fn decode_object(obj: &crate::storage::Object) -> Result<Submission, WireError> {
        let bytes = &obj.bytes;
        let frame = Frame::parse(bytes)?;
        if !obj.integrity_memo(|b| match Frame::parse(b) {
            Ok(f) => f.digest_ok(b),
            Err(_) => false,
        }) {
            return Err(WireError::BadDigest);
        }
        Ok(frame.decode_sections(bytes))
    }

    /// The object key a submission is stored under in its peer's bucket.
    pub fn object_key(uid: u32, round: u64) -> String {
        let mut out = String::with_capacity(32);
        Self::write_object_key(&mut out, uid, round);
        out
    }

    /// Append the object key to a reusable buffer — the allocation-free
    /// form of [`Submission::object_key`] for the validator's fast-eval
    /// sweep, which derives one key per peer per round.
    pub fn write_object_key(out: &mut String, uid: u32, round: u64) {
        use std::fmt::Write as _;
        let _ = write!(out, "grad/round-{round:08}/uid-{uid}");
    }
}

/// Fixed-size wire header length (see the layout in the module docs).
const HEADER: usize = 4 + 2 + 2 + 4 + 8 + 4 + 4;

/// A structurally validated view of an encoded submission: header fields
/// plus section geometry. Splitting structural parsing from the digest
/// check lets [`Submission::decode_object`] memoize only the expensive
/// SHA-256 pass while re-running the cheap header checks per call.
struct Frame {
    uid: u32,
    round: u64,
    coeff_count: usize,
    probe_count: usize,
    /// Offset where the digest trailer starts (= body length).
    body_end: usize,
}

impl Frame {
    /// Magic / version / declared-length validation — everything `decode`
    /// checks except the integrity digest.
    fn parse(bytes: &[u8]) -> Result<Frame, WireError> {
        if bytes.len() < HEADER + 32 {
            return Err(WireError::Truncated(bytes.len()));
        }
        let rd_u32 = |o: usize| u32::from_le_bytes(bytes[o..o + 4].try_into().unwrap());
        let rd_u16 = |o: usize| u16::from_le_bytes(bytes[o..o + 2].try_into().unwrap());
        let magic = rd_u32(0);
        if magic != MAGIC {
            return Err(WireError::BadMagic(magic));
        }
        let version = rd_u16(4);
        if version != VERSION {
            return Err(WireError::BadVersion(version));
        }
        let uid = rd_u32(8);
        let round = u64::from_le_bytes(bytes[12..20].try_into().unwrap());
        let c = rd_u32(20) as usize;
        let p = rd_u32(24) as usize;
        let expected = HEADER + 8 * c + 4 * p + 32;
        if bytes.len() != expected {
            return Err(WireError::LengthMismatch { expected, actual: bytes.len() });
        }
        Ok(Frame { uid, round, coeff_count: c, probe_count: p, body_end: expected - 32 })
    }

    /// Recompute the body digest and compare against the trailer.
    fn digest_ok(&self, bytes: &[u8]) -> bool {
        Sha256::digest(&bytes[..self.body_end]).as_slice() == &bytes[self.body_end..]
    }

    /// Copy out the numeric sections (assumes `parse` validated lengths).
    /// Bulk, exactly-sized decode: each section is one slice copy on LE
    /// targets (byte-wise fallback elsewhere) — this runs once per peer
    /// per validator per round on the fast-eval path.
    fn decode_sections(&self, bytes: &[u8]) -> Submission {
        let (c, p) = (self.coeff_count, self.probe_count);
        let mut off = HEADER;
        let vals = crate::util::f32_from_le_bytes(&bytes[off..off + 4 * c]);
        off += 4 * c;
        let idx = crate::util::i32_from_le_bytes(&bytes[off..off + 4 * c]);
        off += 4 * c;
        let probe = crate::util::f32_from_le_bytes(&bytes[off..off + 4 * p]);
        Submission {
            uid: self.uid,
            round: self.round,
            grad: SparseGrad { vals, idx },
            probe,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use crate::prop_assert;

    fn sub() -> Submission {
        Submission {
            uid: 42,
            round: 1234,
            grad: SparseGrad { vals: vec![1.5, -2.25, 0.0], idx: vec![7, 0, 99] },
            probe: vec![0.5, -0.5],
        }
    }

    #[test]
    fn roundtrip() {
        let s = sub();
        assert_eq!(Submission::decode(&s.encode()).unwrap(), s);
    }

    #[test]
    fn empty_grad_roundtrips() {
        let s = Submission { uid: 0, round: 0, grad: SparseGrad { vals: vec![], idx: vec![] }, probe: vec![] };
        assert_eq!(Submission::decode(&s.encode()).unwrap(), s);
    }

    #[test]
    fn detects_truncation() {
        let b = sub().encode();
        assert!(matches!(Submission::decode(&b[..10]), Err(WireError::Truncated(10))));
        assert!(matches!(
            Submission::decode(&b[..b.len() - 1]),
            Err(WireError::LengthMismatch { .. })
        ));
    }

    #[test]
    fn detects_bitflip() {
        let mut b = sub().encode();
        let mid = b.len() / 2;
        b[mid] ^= 0x40;
        assert_eq!(Submission::decode(&b), Err(WireError::BadDigest));
    }

    #[test]
    fn detects_bad_magic_and_version() {
        let mut b = sub().encode();
        b[0] ^= 1;
        assert!(matches!(Submission::decode(&b), Err(WireError::BadMagic(_))));
        let mut b = sub().encode();
        b[4] = 99;
        assert!(matches!(Submission::decode(&b), Err(WireError::BadVersion(99))));
    }

    #[test]
    fn rejects_inflated_counts() {
        let mut b = sub().encode();
        // inflate coeff_count field
        b[20..24].copy_from_slice(&1000u32.to_le_bytes());
        assert!(matches!(Submission::decode(&b), Err(WireError::LengthMismatch { .. })));
    }

    #[test]
    fn decode_object_matches_decode_and_memoizes_the_digest() {
        use crate::storage::Object;
        let s = sub();
        let obj = Object::new("k".into(), s.encode(), 0);
        // First decode pays the hash; the second serves from the memo —
        // both must agree with the plain byte decode.
        assert_eq!(Submission::decode_object(&obj).unwrap(), s);
        assert_eq!(Submission::decode_object(&obj).unwrap(), s);
        assert_eq!(Submission::decode(&obj.bytes).unwrap(), s);
    }

    #[test]
    fn decode_object_rejects_corruption_and_structural_errors() {
        use crate::storage::Object;
        let mut b = sub().encode();
        let mid = b.len() / 2;
        b[mid] ^= 0x40;
        let corrupt = Object::new("k".into(), b, 0);
        assert_eq!(Submission::decode_object(&corrupt), Err(WireError::BadDigest));
        // The memo caches the *verdict*, not a success: still rejected.
        assert_eq!(Submission::decode_object(&corrupt), Err(WireError::BadDigest));

        let truncated = Object::new("k".into(), vec![1, 2, 3], 0);
        assert!(matches!(Submission::decode_object(&truncated), Err(WireError::Truncated(3))));
    }

    #[test]
    fn object_keys_sort_by_round() {
        let a = Submission::object_key(1, 9);
        let b = Submission::object_key(1, 10);
        assert!(a < b, "zero-padded rounds must sort lexicographically");
    }

    #[test]
    fn prop_roundtrip_arbitrary() {
        prop::check("wire-roundtrip", 40, |rng, size| {
            let c = size % 20;
            let p = size % 9;
            let s = Submission {
                uid: rng.below(u32::MAX as u64) as u32,
                round: rng.next_u64() % 1_000_000,
                grad: SparseGrad {
                    vals: (0..c).map(|_| rng.normal_f32(0.0, 10.0)).collect(),
                    idx: (0..c).map(|_| rng.below(1 << 20) as i32).collect(),
                },
                probe: (0..p).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
            };
            let d = Submission::decode(&s.encode()).map_err(|e| e.to_string())?;
            prop_assert!(d == s, "roundtrip mismatch");
            Ok(())
        });
    }

    /// The byte-wise reference encoder the bulk fast path must match
    /// exactly (this is the pre-fast-path implementation, kept as the
    /// format's executable specification).
    fn encode_bytewise(s: &Submission) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&MAGIC.to_le_bytes());
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&0u16.to_le_bytes());
        out.extend_from_slice(&s.uid.to_le_bytes());
        out.extend_from_slice(&s.round.to_le_bytes());
        out.extend_from_slice(&(s.grad.vals.len() as u32).to_le_bytes());
        out.extend_from_slice(&(s.probe.len() as u32).to_le_bytes());
        for v in &s.grad.vals {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for i in &s.grad.idx {
            out.extend_from_slice(&i.to_le_bytes());
        }
        for v in &s.probe {
            out.extend_from_slice(&v.to_le_bytes());
        }
        let digest = Sha256::digest(&out);
        out.extend_from_slice(&digest);
        out
    }

    #[test]
    fn prop_bulk_encode_matches_bytewise_reference() {
        // Random shapes — empty sections, odd sizes, large-ish payloads —
        // plus adversarial values (NaN, ±inf, -0.0) must produce the
        // byte-identical object under the bulk fast path, whatever the
        // target endianness. This is the endianness-safety pin for the
        // `util::extend_*_le` fast path on the wire format itself.
        // Shape schedule: prop::check's sizes are 1 + (case*7) % 64, so
        // `size % 5 == 0` (c = 0) and `size % 9 == 0` (p = 0) both occur
        // within 40 cases — the empty-section encodings (zero-length
        // bulk copies) really are exercised.
        prop::check("wire-bulk-vs-bytewise", 40, |rng, size| {
            let c = if size % 5 == 0 { 0 } else { (size * 37) % 700 };
            let p = size % 9;
            let special = [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -0.0, 0.0];
            let s = Submission {
                uid: rng.below(u32::MAX as u64) as u32,
                round: rng.next_u64() % 1_000_000,
                grad: SparseGrad {
                    vals: (0..c)
                        .map(|i| {
                            if i % 17 == 0 {
                                special[i % special.len()]
                            } else {
                                rng.normal_f32(0.0, 10.0)
                            }
                        })
                        .collect(),
                    idx: (0..c).map(|_| rng.below(1 << 24) as i32 - (1 << 23)).collect(),
                },
                probe: (0..p).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
            };
            let fast = s.encode();
            let reference = encode_bytewise(&s);
            prop_assert!(fast == reference, "bulk encoding diverged from byte-wise reference");
            let d = Submission::decode(&fast).map_err(|e| e.to_string())?;
            prop_assert!(d.uid == s.uid && d.round == s.round, "header mismatch");
            prop_assert!(
                d.grad.vals.len() == s.grad.vals.len()
                    && d.grad
                        .vals
                        .iter()
                        .zip(&s.grad.vals)
                        .all(|(a, b)| a.to_bits() == b.to_bits()),
                "vals bits must survive"
            );
            prop_assert!(d.grad.idx == s.grad.idx, "idx mismatch");
            prop_assert!(
                d.probe.iter().zip(&s.probe).all(|(a, b)| a.to_bits() == b.to_bits()),
                "probe bits must survive"
            );
            Ok(())
        });
    }
}
