//! Wire format for pseudo-gradient submissions placed in cloud buckets.
//!
//! One object per (peer, round): a small header, the sparse DCT
//! coefficients, the SyncScore probe (2 sampled parameter values per
//! tensor, §3.2), and a SHA-256 integrity digest. The digest plus strict
//! structural validation is what lets the validator's *fast evaluation*
//! reject malformed submissions ("violating the format — e.g. tensors with
//! incorrect dimensions or data types") in microseconds, without touching
//! the model.
//!
//! Layout (little-endian):
//!   magic  u32 = 0x474E_544C ("GNTL")
//!   version u16 = 1, flags u16 = 0
//!   uid u32, round u64
//!   coeff_count u32, probe_count u32
//!   vals  f32 * coeff_count
//!   idx   i32 * coeff_count
//!   probe f32 * probe_count
//!   digest = sha256(everything above), 32 bytes

use sha2::{Digest, Sha256};

use super::SparseGrad;

pub const MAGIC: u32 = 0x474E_544C;
pub const VERSION: u16 = 1;

#[derive(Clone, Debug, PartialEq)]
pub struct Submission {
    pub uid: u32,
    pub round: u64,
    pub grad: SparseGrad,
    /// SyncScore probe: sampled parameter values (2 per tensor).
    pub probe: Vec<f32>,
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum WireError {
    #[error("object too short ({0} bytes)")]
    Truncated(usize),
    #[error("bad magic {0:#x}")]
    BadMagic(u32),
    #[error("unsupported version {0}")]
    BadVersion(u16),
    #[error("length mismatch: header says {expected} bytes, object has {actual}")]
    LengthMismatch { expected: usize, actual: usize },
    #[error("integrity digest mismatch")]
    BadDigest,
}

impl Submission {
    pub fn encode(&self) -> Vec<u8> {
        let c = self.grad.vals.len();
        let p = self.probe.len();
        let mut out = Vec::with_capacity(28 + 8 * c + 4 * p + 32);
        out.extend_from_slice(&MAGIC.to_le_bytes());
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&0u16.to_le_bytes());
        out.extend_from_slice(&self.uid.to_le_bytes());
        out.extend_from_slice(&self.round.to_le_bytes());
        out.extend_from_slice(&(c as u32).to_le_bytes());
        out.extend_from_slice(&(p as u32).to_le_bytes());
        for v in &self.grad.vals {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for i in &self.grad.idx {
            out.extend_from_slice(&i.to_le_bytes());
        }
        for v in &self.probe {
            out.extend_from_slice(&v.to_le_bytes());
        }
        let digest = Sha256::digest(&out);
        out.extend_from_slice(&digest);
        out
    }

    pub fn decode(bytes: &[u8]) -> Result<Submission, WireError> {
        const HEADER: usize = 4 + 2 + 2 + 4 + 8 + 4 + 4;
        if bytes.len() < HEADER + 32 {
            return Err(WireError::Truncated(bytes.len()));
        }
        let rd_u32 = |o: usize| u32::from_le_bytes(bytes[o..o + 4].try_into().unwrap());
        let rd_u16 = |o: usize| u16::from_le_bytes(bytes[o..o + 2].try_into().unwrap());
        let magic = rd_u32(0);
        if magic != MAGIC {
            return Err(WireError::BadMagic(magic));
        }
        let version = rd_u16(4);
        if version != VERSION {
            return Err(WireError::BadVersion(version));
        }
        let uid = rd_u32(8);
        let round = u64::from_le_bytes(bytes[12..20].try_into().unwrap());
        let c = rd_u32(20) as usize;
        let p = rd_u32(24) as usize;
        let expected = HEADER + 8 * c + 4 * p + 32;
        if bytes.len() != expected {
            return Err(WireError::LengthMismatch { expected, actual: bytes.len() });
        }
        let body_end = expected - 32;
        let digest = Sha256::digest(&bytes[..body_end]);
        if digest.as_slice() != &bytes[body_end..] {
            return Err(WireError::BadDigest);
        }
        // Bulk, exactly-sized decode: `chunks_exact` over pre-sliced
        // regions collects through an exact-size iterator, so each buffer
        // is allocated once at its final capacity and the per-element
        // bounds checks of the old byte-offset loop disappear — this runs
        // once per peer per validator per round on the fast-eval path.
        let mut off = HEADER;
        let vals: Vec<f32> = bytes[off..off + 4 * c]
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect();
        off += 4 * c;
        let idx: Vec<i32> = bytes[off..off + 4 * c]
            .chunks_exact(4)
            .map(|b| i32::from_le_bytes(b.try_into().unwrap()))
            .collect();
        off += 4 * c;
        let probe: Vec<f32> = bytes[off..off + 4 * p]
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect();
        Ok(Submission { uid, round, grad: SparseGrad { vals, idx }, probe })
    }

    /// The object key a submission is stored under in its peer's bucket.
    pub fn object_key(uid: u32, round: u64) -> String {
        let mut out = String::with_capacity(32);
        Self::write_object_key(&mut out, uid, round);
        out
    }

    /// Append the object key to a reusable buffer — the allocation-free
    /// form of [`Submission::object_key`] for the validator's fast-eval
    /// sweep, which derives one key per peer per round.
    pub fn write_object_key(out: &mut String, uid: u32, round: u64) {
        use std::fmt::Write as _;
        let _ = write!(out, "grad/round-{round:08}/uid-{uid}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use crate::prop_assert;

    fn sub() -> Submission {
        Submission {
            uid: 42,
            round: 1234,
            grad: SparseGrad { vals: vec![1.5, -2.25, 0.0], idx: vec![7, 0, 99] },
            probe: vec![0.5, -0.5],
        }
    }

    #[test]
    fn roundtrip() {
        let s = sub();
        assert_eq!(Submission::decode(&s.encode()).unwrap(), s);
    }

    #[test]
    fn empty_grad_roundtrips() {
        let s = Submission { uid: 0, round: 0, grad: SparseGrad { vals: vec![], idx: vec![] }, probe: vec![] };
        assert_eq!(Submission::decode(&s.encode()).unwrap(), s);
    }

    #[test]
    fn detects_truncation() {
        let b = sub().encode();
        assert!(matches!(Submission::decode(&b[..10]), Err(WireError::Truncated(10))));
        assert!(matches!(
            Submission::decode(&b[..b.len() - 1]),
            Err(WireError::LengthMismatch { .. })
        ));
    }

    #[test]
    fn detects_bitflip() {
        let mut b = sub().encode();
        let mid = b.len() / 2;
        b[mid] ^= 0x40;
        assert_eq!(Submission::decode(&b), Err(WireError::BadDigest));
    }

    #[test]
    fn detects_bad_magic_and_version() {
        let mut b = sub().encode();
        b[0] ^= 1;
        assert!(matches!(Submission::decode(&b), Err(WireError::BadMagic(_))));
        let mut b = sub().encode();
        b[4] = 99;
        assert!(matches!(Submission::decode(&b), Err(WireError::BadVersion(99))));
    }

    #[test]
    fn rejects_inflated_counts() {
        let mut b = sub().encode();
        // inflate coeff_count field
        b[20..24].copy_from_slice(&1000u32.to_le_bytes());
        assert!(matches!(Submission::decode(&b), Err(WireError::LengthMismatch { .. })));
    }

    #[test]
    fn object_keys_sort_by_round() {
        let a = Submission::object_key(1, 9);
        let b = Submission::object_key(1, 10);
        assert!(a < b, "zero-padded rounds must sort lexicographically");
    }

    #[test]
    fn prop_roundtrip_arbitrary() {
        prop::check("wire-roundtrip", 40, |rng, size| {
            let c = size % 20;
            let p = size % 9;
            let s = Submission {
                uid: rng.below(u32::MAX as u64) as u32,
                round: rng.next_u64() % 1_000_000,
                grad: SparseGrad {
                    vals: (0..c).map(|_| rng.normal_f32(0.0, 10.0)).collect(),
                    idx: (0..c).map(|_| rng.below(1 << 20) as i32).collect(),
                },
                probe: (0..p).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
            };
            let d = Submission::decode(&s.encode()).map_err(|e| e.to_string())?;
            prop_assert!(d == s, "roundtrip mismatch");
            Ok(())
        });
    }
}
