//! DeMo compressed-domain plumbing on the coordinator side (Algorithm 2).
//!
//! Peers transmit pseudo-gradients as sparse top-k DCT coefficients
//! (values + global coefficient indices, produced by the `demo_compress`
//! artifact). The validator-side aggregation — per-peer L2 normalization in
//! the *encoded* domain (the §4 byzantine defense) followed by a weighted
//! sparse sum — is pure bookkeeping and runs natively in Rust on the hot
//! path; only the IDCT + sign + parameter step happens inside XLA
//! (`apply_update` artifact).

pub mod aggregate;
pub mod wire;

pub use aggregate::{aggregate, AggregateOpts};
pub use wire::{Submission, WireError};

/// A sparse pseudo-gradient in the DCT-encoded domain.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseGrad {
    /// Kept coefficient values (with sign), length C.
    pub vals: Vec<f32>,
    /// Global coefficient indices (chunk_id * chunk^2 + local), length C.
    pub idx: Vec<i32>,
}

impl SparseGrad {
    pub fn len(&self) -> usize {
        self.vals.len()
    }
    pub fn is_empty(&self) -> bool {
        self.vals.is_empty()
    }

    pub fn l2_norm(&self) -> f64 {
        crate::util::det_sum(self.vals.iter().map(|v| (*v as f64) * (*v as f64))).sqrt()
    }

    /// Structural validation against the config's expected dimensions —
    /// the §3.2 "basic checks (c)" format rule.
    pub fn validate(&self, coeff_count: usize, padded_count: usize) -> Result<(), String> {
        if self.vals.len() != coeff_count || self.idx.len() != coeff_count {
            return Err(format!(
                "bad length: {} vals / {} idx, expected {coeff_count}",
                self.vals.len(),
                self.idx.len()
            ));
        }
        if self.vals.iter().any(|v| !v.is_finite()) {
            return Err("non-finite coefficient value".into());
        }
        if self.idx.iter().any(|&i| i < 0 || i as usize >= padded_count) {
            return Err("coefficient index out of range".into());
        }
        Ok(())
    }

    /// Scatter into a dense coefficient vector of length `padded_count`,
    /// scaling values by `scale`. Duplicate indices accumulate.
    pub fn scatter_into(&self, dense: &mut [f32], scale: f32) {
        debug_assert_eq!(self.vals.len(), self.idx.len());
        for (&v, &i) in self.vals.iter().zip(&self.idx) {
            dense[i as usize] += v * scale;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sg(vals: Vec<f32>, idx: Vec<i32>) -> SparseGrad {
        SparseGrad { vals, idx }
    }

    #[test]
    fn l2_norm() {
        let g = sg(vec![3.0, 4.0], vec![0, 1]);
        assert!((g.l2_norm() - 5.0).abs() < 1e-12);
        assert_eq!(sg(vec![], vec![]).l2_norm(), 0.0);
    }

    #[test]
    fn validate_catches_format_violations() {
        let ok = sg(vec![1.0, 2.0], vec![0, 5]);
        assert!(ok.validate(2, 10).is_ok());
        assert!(ok.validate(3, 10).is_err(), "wrong count");
        assert!(sg(vec![f32::NAN, 1.0], vec![0, 1]).validate(2, 10).is_err(), "nan");
        assert!(sg(vec![1.0, 1.0], vec![0, 10]).validate(2, 10).is_err(), "idx overflow");
        assert!(sg(vec![1.0, 1.0], vec![-1, 0]).validate(2, 10).is_err(), "negative idx");
    }

    #[test]
    fn scatter_accumulates_duplicates() {
        let g = sg(vec![1.0, 2.0, 4.0], vec![1, 1, 3]);
        let mut dense = vec![0.0f32; 4];
        g.scatter_into(&mut dense, 0.5);
        assert_eq!(dense, vec![0.0, 1.5, 0.0, 2.0]);
    }
}
