//! Validator-side aggregation of compressed pseudo-gradients
//! (Algorithm 2, `DeMoAggregation`, lines 10–16 — minus the final IDCT +
//! sign, which run inside the `apply_update` XLA artifact).
//!
//! Per §4, each peer's encoded vector is L2-normalized before the weighted
//! sum so no single peer can dominate the aggregate by rescaling its
//! contribution — the paper's primary byzantine defense alongside the
//! post-aggregation sign.

use super::SparseGrad;

#[derive(Clone, Copy, Debug)]
pub struct AggregateOpts {
    /// Normalize each peer's encoded vector to unit L2 norm before summing
    /// (paper Algorithm 2 line 12). Exposed so the ablation bench can
    /// reproduce the §4 with/without-normalization comparison.
    pub normalize: bool,
    /// Norm floor: contributions with smaller L2 norm are dropped rather
    /// than amplified by a huge 1/norm factor.
    pub min_norm: f64,
}

impl Default for AggregateOpts {
    fn default() -> Self {
        AggregateOpts { normalize: true, min_norm: 1e-12 }
    }
}

/// Weighted aggregation into a dense DCT-coefficient vector f32[padded].
///
/// `contributions` pairs each peer's sparse gradient with its aggregation
/// weight w_p (eq. 6: 1/G for top-G peers). Weights are used as given;
/// zero-weight entries are skipped.
pub fn aggregate(
    contributions: &[(&SparseGrad, f64)],
    padded_count: usize,
    opts: &AggregateOpts,
) -> Vec<f32> {
    let mut dense = vec![0.0f32; padded_count];
    aggregate_into(contributions, &mut dense, opts);
    dense
}

/// Allocation-free variant for the hot loop: accumulates into `dense`
/// (which must be zeroed by the caller if a fresh aggregate is wanted).
pub fn aggregate_into(
    contributions: &[(&SparseGrad, f64)],
    dense: &mut [f32],
    opts: &AggregateOpts,
) {
    for (grad, w) in contributions {
        if *w == 0.0 || grad.is_empty() {
            continue;
        }
        let scale = if opts.normalize {
            let n = grad.l2_norm();
            if n < opts.min_norm {
                continue;
            }
            (*w / n) as f32
        } else {
            *w as f32
        };
        grad.scatter_into(dense, scale);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use crate::prop_assert;
    use crate::util::Rng;

    fn sg(vals: Vec<f32>, idx: Vec<i32>) -> SparseGrad {
        SparseGrad { vals, idx }
    }

    #[test]
    fn unweighted_sum_without_normalization() {
        let a = sg(vec![1.0, 2.0], vec![0, 2]);
        let b = sg(vec![4.0], vec![2]);
        let opts = AggregateOpts { normalize: false, ..Default::default() };
        let d = aggregate(&[(&a, 1.0), (&b, 1.0)], 4, &opts);
        assert_eq!(d, vec![1.0, 0.0, 6.0, 0.0]);
    }

    #[test]
    fn normalization_equalizes_scaled_copies() {
        // The §4 rescaling attack: a 1000x-scaled copy of the same gradient
        // must contribute identically to an honest one.
        let honest = sg(vec![0.6, 0.8], vec![1, 3]);
        let attacker = sg(vec![600.0, 800.0], vec![1, 3]);
        let opts = AggregateOpts::default();
        let d_h = aggregate(&[(&honest, 1.0)], 4, &opts);
        let d_a = aggregate(&[(&attacker, 1.0)], 4, &opts);
        for (x, y) in d_h.iter().zip(&d_a) {
            assert!((x - y).abs() < 1e-6, "{d_h:?} vs {d_a:?}");
        }
    }

    #[test]
    fn without_normalization_attacker_dominates() {
        let honest = sg(vec![0.6, 0.8], vec![0, 1]);
        let attacker = sg(vec![-600.0, 800.0], vec![0, 1]);
        let opts = AggregateOpts { normalize: false, ..Default::default() };
        let d = aggregate(&[(&honest, 0.5), (&attacker, 0.5)], 2, &opts);
        // attacker flipped the sign of coordinate 0 despite equal weight
        assert!(d[0] < 0.0);
    }

    #[test]
    fn zero_weight_and_empty_grads_skipped() {
        let a = sg(vec![1.0], vec![0]);
        let empty = sg(vec![], vec![]);
        let d = aggregate(&[(&a, 0.0), (&empty, 1.0)], 2, &AggregateOpts::default());
        assert_eq!(d, vec![0.0, 0.0]);
    }

    #[test]
    fn tiny_norm_contributions_dropped() {
        let eps = sg(vec![1e-20], vec![0]);
        let d = aggregate(&[(&eps, 1.0)], 1, &AggregateOpts::default());
        assert_eq!(d, vec![0.0], "should drop, not amplify by 1e20");
    }

    #[test]
    fn aggregate_into_accumulates_across_calls() {
        let a = sg(vec![2.0], vec![0]);
        let mut dense = vec![0.0f32; 1];
        let opts = AggregateOpts { normalize: false, ..Default::default() };
        aggregate_into(&[(&a, 1.0)], &mut dense, &opts);
        aggregate_into(&[(&a, 1.0)], &mut dense, &opts);
        assert_eq!(dense, vec![4.0]);
    }

    #[test]
    fn prop_linearity_and_norm_invariance() {
        prop::check("aggregate-invariants", 40, |rng, size| {
            let p_pad = 16 + size * 4;
            let c = 1 + size % 8;
            let mk = |rng: &mut Rng| {
                let idx: Vec<i32> =
                    (0..c).map(|_| rng.below(p_pad as u64) as i32).collect();
                let vals: Vec<f32> = (0..c).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                sg(vals, idx)
            };
            let g1 = mk(rng);
            let g2 = mk(rng);
            // (a) weighted sum is linear in weights (normalize=false)
            let opts = AggregateOpts { normalize: false, ..Default::default() };
            let d1 = aggregate(&[(&g1, 2.0), (&g2, 3.0)], p_pad, &opts);
            let a1 = aggregate(&[(&g1, 1.0)], p_pad, &opts);
            let a2 = aggregate(&[(&g2, 1.0)], p_pad, &opts);
            for i in 0..p_pad {
                let want = 2.0 * a1[i] + 3.0 * a2[i];
                prop_assert!((d1[i] - want).abs() < 1e-4, "linearity at {i}");
            }
            // (b) with normalization, scaling a contribution is a no-op
            let scaled = sg(g1.vals.iter().map(|v| v * 123.0).collect(), g1.idx.clone());
            let n1 = aggregate(&[(&g1, 1.0)], p_pad, &AggregateOpts::default());
            let n2 = aggregate(&[(&scaled, 1.0)], p_pad, &AggregateOpts::default());
            for i in 0..p_pad {
                prop_assert!((n1[i] - n2[i]).abs() < 1e-5, "norm invariance at {i}");
            }
            // (c) when indices don't collide, the scatter preserves the
            // normalized norm exactly (collisions may sum values, so the
            // check only applies to duplicate-free index sets)
            let mut uniq = g1.idx.clone();
            uniq.sort_unstable();
            uniq.dedup();
            if uniq.len() == g1.idx.len() && g1.l2_norm() > 1e-12 {
                let norm: f64 =
                    n1.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt();
                prop_assert!((norm - 1.0).abs() < 1e-5, "unit norm broken: {norm}");
            }
            Ok(())
        });
    }
}
