//! # Gauntlet — Incentivizing Permissionless Distributed Learning of LLMs
//!
//! A full reproduction of the Templar *Gauntlet* incentive system (Lidin et
//! al., 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! - **Layer 1/2 (build-time Python)**: a llama-style transformer and the
//!   DeMo compressor (chunked 2-D DCT + top-k Pallas kernels), AOT-lowered
//!   to HLO-text artifacts (`make artifacts`).
//! - **Layer 3 (this crate)**: everything the paper deploys — the Gauntlet
//!   validator (fast + primary evaluation, OpenSkill ratings,
//!   proof-of-computation, PEERSCORE, top-G aggregation), simulated
//!   S3-compatible cloud storage, a simulated Bittensor chain with Yuma
//!   consensus, honest and byzantine peer behaviours, and the PJRT runtime
//!   that executes the artifacts natively. Python is never on this path.
//!
//! Start with [`coordinator::run::TemplarRun`] (the end-to-end system) or
//! the `examples/` directory.

pub mod bench;
pub mod chain;
pub mod coordinator;
pub mod data;
pub mod demo;
pub mod eval;
pub mod minjson;
pub mod openskill;
pub mod peers;
pub mod prop;
pub mod runtime;
pub mod storage;
pub mod util;
