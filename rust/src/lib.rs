//! # Gauntlet — Incentivizing Permissionless Distributed Learning of LLMs
//!
//! A full reproduction of the Templar *Gauntlet* incentive system (Lidin et
//! al., 2025) as a three-layer Rust + JAX + Pallas stack (the repository
//! README's "Layer map" draws the picture):
//!
//! - **Layer 1/2 (build-time Python)**: a llama-style transformer and the
//!   DeMo compressor (chunked 2-D DCT + top-k Pallas kernels), AOT-lowered
//!   to HLO-text artifacts (`python -m compile.aot`).
//! - **Layer 3 (this crate)**: everything the paper deploys — the Gauntlet
//!   validator (fast + primary evaluation, OpenSkill ratings,
//!   proof-of-computation, PEERSCORE, top-G aggregation), simulated
//!   S3-compatible cloud storage, a simulated Bittensor chain with Yuma
//!   consensus, honest and byzantine peer behaviours, and the PJRT runtime
//!   that executes the artifacts natively. Python is never on this path.
//!
//! Model execution is abstracted behind [`runtime::ExecBackend`], with the
//! PJRT [`runtime::Executor`] for compiled artifacts and the pure-Rust
//! [`runtime::SimExec`] for artifact-less runs (README: "Runtime
//! backends"). The per-round evaluation pipeline is parallel by default
//! and bit-deterministic at any thread count (README: "Scaling the round
//! pipeline"); the thread knob is [`coordinator::run::RunConfig::threads`]
//! / the `GAUNTLET_THREADS` environment variable, and the non-`Send` PJRT
//! constraint is honored by the [`runtime::service`] request funnel.
//!
//! The peer population is **chain-driven and dynamic**: the simulated
//! subnet ([`chain`]) is a bounded neuron-slot table with deregistration,
//! Bittensor-style lowest-incentive replacement, and an immunity period,
//! and the coordinator resolves its peer set from the registry at the top
//! of every round. Mid-run churn — joins, leaves, stake moves, provider
//! outages — is scripted declaratively with a [`scenario::Scenario`]
//! (CLI: `gauntlet run --scenario <file|inline>`; demo:
//! `rust/examples/churn_gauntlet.rs`).
//!
//! The public surface is builder-first: assemble a
//! [`coordinator::engine::GauntletEngine`] with
//! [`coordinator::engine::GauntletBuilder`], subscribe
//! [`coordinator::events::Observer`]s to the typed round-event stream
//! (metrics and JSONL tracing are built-in observers, not inlined
//! plumbing), and pause/resume any run bit-identically through
//! [`coordinator::snapshot::RunSnapshot`] (CLI: `gauntlet run
//! --snapshot-out/--resume`; demo: `rust/examples/snapshot_resume.rs`).
//!
//! Start with [`coordinator::engine::GauntletBuilder`] or the
//! `rust/examples/` directory (each example documents which paper
//! figure it reproduces — see `rust/examples/README.md`).
//!
//! **Correctness tooling** (README: "Correctness tooling"): the round
//! path is statically audited by the in-tree `detlint` crate
//! (`gauntlet lint` / `cargo run -p detlint`), `unsafe` code must
//! discharge its obligations explicitly (`unsafe_op_in_unsafe_fn` is
//! deny-level, and detlint rule U001 requires a `// SAFETY:` comment on
//! every site), and the `WorkerPool`'s dispatch choreography is
//! loom-model-checked in `rust/tests/loom_pool.rs`.

// Inside an `unsafe fn`, each unsafe operation must sit in its own
// `unsafe {}` block with its own SAFETY justification — a fn-level
// unsafe blanket hides which line carries which obligation.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod bench;
pub mod chain;
pub mod coordinator;
pub mod data;
pub mod demo;
pub mod eval;
pub mod minjson;
pub mod openskill;
pub mod peers;
pub mod prop;
pub mod runtime;
pub mod scenario;
pub mod storage;
pub mod util;
