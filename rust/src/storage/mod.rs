//! Simulated S3-compatible cloud storage (paper §5, "Cloud-Based
//! Communication").
//!
//! In the deployed system every peer owns a bucket at an S3-compliant
//! provider (Cloudflare R2), posts its *read* credentials to the chain, and
//! broadcasts pseudo-gradients by writing into its own bucket; validators
//! read from peers' buckets and trust the provider's object timestamps
//! (anchored to blockchain time) to enforce the per-round put window.
//!
//! This module reproduces that API surface in-process:
//!   - buckets with owner-only writes and key-holder reads,
//!   - robust server-side timestamps (simulation clock, not wall clock),
//!   - configurable upload latency and fault injection (outages model the
//!     "reliability of the cloud provider" caveat in §5),
//!   - put-window enforcement as a *reader-side* filter, exactly like the
//!     validator ignores out-of-window objects in the live system.
//!
//! # Concurrency
//!
//! Like a real provider, the store is shared: every method takes `&self`,
//! buckets are partitioned across [`SHARDS`] independent `RwLock`s (keyed
//! by bucket-name hash), and objects are handed out as `Arc` clones. The
//! parallel round pipeline (`coordinator::run`) fans each validator's
//! fast-evaluation reads over a worker pool, so concurrent
//! [`ObjectStore::get_within_window`] calls on different peers' buckets
//! must not serialize on one map — per-bucket sharding gives readers of
//! distinct buckets disjoint locks, and `RwLock` lets readers of the same
//! bucket proceed together. The provider's latency/outage RNG sits behind
//! its own mutex; the coordinator applies PUTs in deterministic peer order
//! so draws are reproducible regardless of worker timing.

use std::collections::BTreeMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};

use crate::util::Rng;

/// Simulation time in milliseconds since run start.
pub type SimTime = u64;

/// Number of independent bucket shards (power of two).
pub const SHARDS: usize = 16;

/// A stored object with its server-assigned timestamp.
///
/// The store hands out `Arc<Object>` clones, so one stored submission is
/// shared by every validator that reads it in a round. That sharing is
/// what makes the integrity memo below worthwhile: the wire codec's
/// SHA-256 digest check is a function of `bytes` alone, so the first
/// reader's verdict can be cached on the object and served to every
/// later reader (`OnceLock` — thread-safe, computed at most once).
#[derive(Debug)]
pub struct Object {
    pub key: String,
    pub bytes: Vec<u8>,
    /// Server-side receive time — what the validator trusts.
    pub stored_at: SimTime,
    /// Memoized wire-integrity verdict (see [`Object::integrity_memo`]).
    integrity: OnceLock<bool>,
}

impl Object {
    pub fn new(key: String, bytes: Vec<u8>, stored_at: SimTime) -> Object {
        Object { key, bytes, stored_at, integrity: OnceLock::new() }
    }

    /// Whether `bytes` passes the caller's integrity check, computing
    /// `check` at most once for this object's lifetime. `check` must be
    /// a pure function of `self.bytes` (the wire codec's digest check
    /// is) — the verdict is shared across every holder of the `Arc`.
    pub fn integrity_memo(&self, check: impl FnOnce(&[u8]) -> bool) -> bool {
        *self.integrity.get_or_init(|| check(&self.bytes))
    }
}

// Manual impls: the memo is a cache, not state — a clone may carry the
// already-computed verdict, and equality ignores it entirely.
impl Clone for Object {
    fn clone(&self) -> Object {
        Object {
            key: self.key.clone(),
            bytes: self.bytes.clone(),
            stored_at: self.stored_at,
            integrity: self.integrity.clone(),
        }
    }
}

impl PartialEq for Object {
    fn eq(&self, other: &Object) -> bool {
        self.key == other.key && self.bytes == other.bytes && self.stored_at == other.stored_at
    }
}

/// Read credential a peer publishes on-chain (paper: read-access keys).
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct ReadKey(pub String);

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum StorageError {
    #[error("no such bucket {0:?}")]
    NoBucket(String),
    #[error("access denied to bucket {0:?}")]
    AccessDenied(String),
    #[error("provider outage")]
    Outage,
    #[error("object too large: {size} > {limit}")]
    TooLarge { size: usize, limit: usize },
}

struct Bucket {
    owner: String,
    read_key: ReadKey,
    objects: BTreeMap<String, Arc<Object>>,
}

/// Latency / reliability model for the simulated provider.
#[derive(Clone, Debug)]
pub struct ProviderModel {
    /// Mean upload latency (ms); actual draws are log-normal-ish around it.
    pub mean_upload_ms: f64,
    pub jitter_ms: f64,
    /// Probability an individual PUT is lost to a transient outage.
    pub outage_prob: f64,
    pub max_object_bytes: usize,
}

impl Default for ProviderModel {
    fn default() -> Self {
        ProviderModel {
            mean_upload_ms: 800.0,
            jitter_ms: 300.0,
            outage_prob: 0.0,
            max_object_bytes: 256 << 20,
        }
    }
}

/// The simulated S3 provider: all buckets, one global object namespace per
/// bucket, server-side clocks. Shareable across validator worker threads
/// (`&ObjectStore` is `Send + Sync`).
pub struct ObjectStore {
    shards: Vec<RwLock<BTreeMap<String, Bucket>>>,
    pub model: ProviderModel,
    /// Latency/outage draws; locked only on the (write-side) PUT path.
    rng: Mutex<Rng>,
    next_key_id: AtomicU64,
}

impl ObjectStore {
    pub fn new(model: ProviderModel, seed: u64) -> Self {
        ObjectStore {
            shards: (0..SHARDS).map(|_| RwLock::new(BTreeMap::new())).collect(),
            model,
            rng: Mutex::new(Rng::new(seed)),
            next_key_id: AtomicU64::new(0),
        }
    }

    fn shard(&self, bucket: &str) -> &RwLock<BTreeMap<String, Bucket>> {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        bucket.hash(&mut h);
        &self.shards[(h.finish() as usize) & (SHARDS - 1)]
    }

    /// Create a bucket owned by `owner`; returns the read key the owner
    /// would post on-chain.
    pub fn create_bucket(&self, name: &str, owner: &str) -> ReadKey {
        let id = self.next_key_id.fetch_add(1, Ordering::Relaxed) + 1;
        let key = ReadKey(format!("rk-{name}-{id:08x}"));
        self.shard(name).write().unwrap().insert(
            name.to_string(),
            Bucket { owner: owner.to_string(), read_key: key.clone(), objects: BTreeMap::new() },
        );
        key
    }

    pub fn bucket_exists(&self, name: &str) -> bool {
        self.shard(name).read().unwrap().contains_key(name)
    }

    /// Delete a bucket and every object in it (a deregistered peer's
    /// bucket is torn down; a recycled uid gets a brand-new bucket with a
    /// fresh read key). Returns whether the bucket existed.
    pub fn delete_bucket(&self, name: &str) -> bool {
        self.shard(name).write().unwrap().remove(name).is_some()
    }

    /// PUT an object. `now` is the client's send time; the stored timestamp
    /// is send time + simulated upload latency. Returns the server-side
    /// stored-at time, or an error on outage / size limit / ACL.
    pub fn put(
        &self,
        bucket: &str,
        writer: &str,
        key: &str,
        bytes: Vec<u8>,
        now: SimTime,
    ) -> Result<SimTime, StorageError> {
        if bytes.len() > self.model.max_object_bytes {
            return Err(StorageError::TooLarge {
                size: bytes.len(),
                limit: self.model.max_object_bytes,
            });
        }
        // One lock hold for both draws keeps the draw sequence identical to
        // the pre-sharding sequential store.
        let latency = {
            let mut rng = self.rng.lock().unwrap();
            if self.model.outage_prob > 0.0 && rng.chance(self.model.outage_prob) {
                return Err(StorageError::Outage);
            }
            (self.model.mean_upload_ms + rng.normal() * self.model.jitter_ms).max(1.0) as u64
        };
        let mut shard = self.shard(bucket).write().unwrap();
        let b = shard
            .get_mut(bucket)
            .ok_or_else(|| StorageError::NoBucket(bucket.to_string()))?;
        if b.owner != writer {
            return Err(StorageError::AccessDenied(bucket.to_string()));
        }
        let stored_at = now + latency;
        b.objects.insert(key.to_string(), Arc::new(Object::new(key.to_string(), bytes, stored_at)));
        Ok(stored_at)
    }

    /// GET with a read key (as validators do, using the on-chain key).
    pub fn get(
        &self,
        bucket: &str,
        rk: &ReadKey,
        key: &str,
    ) -> Result<Option<Arc<Object>>, StorageError> {
        let shard = self.shard(bucket).read().unwrap();
        let b = shard
            .get(bucket)
            .ok_or_else(|| StorageError::NoBucket(bucket.to_string()))?;
        if &b.read_key != rk {
            return Err(StorageError::AccessDenied(bucket.to_string()));
        }
        Ok(b.objects.get(key).cloned())
    }

    /// List all objects in a bucket (metadata view).
    pub fn list(&self, bucket: &str, rk: &ReadKey) -> Result<Vec<(String, SimTime)>, StorageError> {
        let shard = self.shard(bucket).read().unwrap();
        let b = shard
            .get(bucket)
            .ok_or_else(|| StorageError::NoBucket(bucket.to_string()))?;
        if &b.read_key != rk {
            return Err(StorageError::AccessDenied(bucket.to_string()));
        }
        Ok(b.objects.values().map(|o| (o.key.clone(), o.stored_at)).collect())
    }

    /// Reader-side put-window filter: fetch `key` only if its server
    /// timestamp falls inside `[window_start, window_end]` — the §3.2
    /// "basic checks (a)" rule. Both endpoints are inclusive: an object
    /// stored exactly on the window open or close is in-window. Returns:
    ///   `WindowedGet::InWindow(..)`   in-window object
    ///   `WindowedGet::Missing`        object absent (basic check (b) fails)
    ///   `WindowedGet::TooEarly/Late`  present but outside the window
    pub fn get_within_window(
        &self,
        bucket: &str,
        rk: &ReadKey,
        key: &str,
        window_start: SimTime,
        window_end: SimTime,
    ) -> Result<WindowedGet, StorageError> {
        match self.get(bucket, rk, key)? {
            None => Ok(WindowedGet::Missing),
            Some(o) if o.stored_at < window_start => Ok(WindowedGet::TooEarly(o.stored_at)),
            Some(o) if o.stored_at > window_end => Ok(WindowedGet::TooLate(o.stored_at)),
            Some(o) => Ok(WindowedGet::InWindow(o)),
        }
    }

    // ------------------- snapshot/resume support ------------------------
    //
    // The round pipeline only ever reads objects written in the *current*
    // round (fast-eval windowed GETs, copier second-pass reads), so a
    // round-boundary snapshot needs no object payloads — but it must
    // preserve the provider's RNG stream (latency/outage draws are taken
    // in deterministic PUT order), the read-key counter (future keys must
    // match), and every bucket's name/owner/read-key (the keys are already
    // published on-chain and must keep opening the recreated buckets).

    /// The provider RNG's raw state (see [`crate::util::Rng::state`]).
    pub fn rng_state(&self) -> u64 {
        self.rng.lock().unwrap().state()
    }

    /// Restore the provider RNG mid-stream.
    pub fn set_rng_state(&self, state: u64) {
        *self.rng.lock().unwrap() = Rng::from_state(state);
    }

    /// The read-key counter (next `create_bucket` uses this + 1).
    pub fn next_key_id(&self) -> u64 {
        self.next_key_id.load(Ordering::Relaxed)
    }

    pub fn set_next_key_id(&self, id: u64) {
        self.next_key_id.store(id, Ordering::Relaxed);
    }

    /// Every bucket's `(name, owner, read key)`, sorted by name.
    pub fn export_buckets(&self) -> Vec<(String, String, ReadKey)> {
        let mut out = Vec::new();
        for shard in &self.shards {
            for (name, b) in shard.read().unwrap().iter() {
                out.push((name.clone(), b.owner.clone(), b.read_key.clone()));
            }
        }
        out.sort();
        out
    }

    /// Recreate a bucket with a *given* read key (snapshot restore path;
    /// normal registration uses [`ObjectStore::create_bucket`], which mints
    /// a fresh key). The bucket starts empty.
    pub fn restore_bucket(&self, name: &str, owner: &str, key: ReadKey) {
        self.shard(name).write().unwrap().insert(
            name.to_string(),
            Bucket { owner: owner.to_string(), read_key: key, objects: BTreeMap::new() },
        );
    }

    /// Garbage-collect objects stored before `cutoff` (peers prune old
    /// rounds so buckets stay small).
    pub fn prune_before(&self, bucket: &str, writer: &str, cutoff: SimTime) -> usize {
        let mut shard = self.shard(bucket).write().unwrap();
        let Some(b) = shard.get_mut(bucket) else { return 0 };
        if b.owner != writer {
            return 0;
        }
        let before = b.objects.len();
        b.objects.retain(|_, o| o.stored_at >= cutoff);
        before - b.objects.len()
    }
}

/// Result of a windowed GET (see [`ObjectStore::get_within_window`]).
/// Owns its object handle so results can cross worker-thread boundaries.
#[derive(Clone, Debug)]
pub enum WindowedGet {
    InWindow(Arc<Object>),
    Missing,
    TooEarly(SimTime),
    TooLate(SimTime),
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> ObjectStore {
        let model = ProviderModel { mean_upload_ms: 100.0, jitter_ms: 0.0, ..Default::default() };
        ObjectStore::new(model, 42)
    }

    #[test]
    fn put_get_roundtrip_with_read_key() {
        let s = store();
        let rk = s.create_bucket("peer-0", "peer-0");
        let t = s.put("peer-0", "peer-0", "grad-17", vec![1, 2, 3], 1000).unwrap();
        assert!(t >= 1100, "latency applied");
        let o = s.get("peer-0", &rk, "grad-17").unwrap().unwrap();
        assert_eq!(o.bytes, vec![1, 2, 3]);
        assert_eq!(o.stored_at, t);
    }

    #[test]
    fn wrong_read_key_denied() {
        let s = store();
        let _rk = s.create_bucket("peer-0", "peer-0");
        let bad = ReadKey("rk-fake".into());
        assert_eq!(s.get("peer-0", &bad, "x"), Err(StorageError::AccessDenied("peer-0".into())));
    }

    #[test]
    fn only_owner_can_write() {
        let s = store();
        s.create_bucket("peer-0", "peer-0");
        let err = s.put("peer-0", "peer-1", "k", vec![], 0).unwrap_err();
        assert_eq!(err, StorageError::AccessDenied("peer-0".into()));
    }

    #[test]
    fn missing_bucket_errors() {
        let s = store();
        assert!(matches!(
            s.get("nope", &ReadKey("rk".into()), "k"),
            Err(StorageError::NoBucket(_))
        ));
    }

    #[test]
    fn window_filter_classifies_early_late_missing() {
        let s = store();
        let rk = s.create_bucket("b", "b");
        s.put("b", "b", "ontime", vec![1], 1000).unwrap(); // stored ~1100
        s.put("b", "b", "early", vec![2], 0).unwrap(); // stored ~100
        s.put("b", "b", "late", vec![3], 99_000).unwrap(); // stored ~99100
        let w = |k: &str| s.get_within_window("b", &rk, k, 500, 2000).unwrap();
        assert!(matches!(w("ontime"), WindowedGet::InWindow(_)));
        assert!(matches!(w("early"), WindowedGet::TooEarly(_)));
        assert!(matches!(w("late"), WindowedGet::TooLate(_)));
        assert!(matches!(w("absent"), WindowedGet::Missing));
    }

    #[test]
    fn window_boundaries_are_inclusive() {
        // jitter = 0 lands objects at exactly now + mean_upload_ms, so the
        // boundary semantics are testable: exactly-on-open and
        // exactly-on-close are both in-window; one ms outside is not.
        let s = store();
        let rk = s.create_bucket("b", "b");
        let on_open = s.put("b", "b", "on-open", vec![1], 400).unwrap();
        assert_eq!(on_open, 500);
        let on_close = s.put("b", "b", "on-close", vec![2], 1900).unwrap();
        assert_eq!(on_close, 2000);
        let before_open = s.put("b", "b", "before-open", vec![3], 399).unwrap();
        assert_eq!(before_open, 499);
        let after_close = s.put("b", "b", "after-close", vec![4], 1901).unwrap();
        assert_eq!(after_close, 2001);

        let w = |k: &str| s.get_within_window("b", &rk, k, 500, 2000).unwrap();
        assert!(matches!(w("on-open"), WindowedGet::InWindow(_)), "open edge is inclusive");
        assert!(matches!(w("on-close"), WindowedGet::InWindow(_)), "close edge is inclusive");
        assert!(matches!(w("before-open"), WindowedGet::TooEarly(499)));
        assert!(matches!(w("after-close"), WindowedGet::TooLate(2001)));
    }

    #[test]
    fn delete_bucket_tears_down_and_recreate_rotates_key() {
        let s = store();
        let rk_old = s.create_bucket("peer-3", "peer-3");
        s.put("peer-3", "peer-3", "grad", vec![1], 0).unwrap();
        assert!(s.delete_bucket("peer-3"));
        assert!(!s.bucket_exists("peer-3"));
        assert!(!s.delete_bucket("peer-3"), "second delete is a no-op");
        // A recycled uid recreates the bucket: old objects are gone and the
        // old read key no longer opens it.
        let rk_new = s.create_bucket("peer-3", "peer-3");
        assert_ne!(rk_old, rk_new);
        assert_eq!(
            s.get("peer-3", &rk_old, "grad"),
            Err(StorageError::AccessDenied("peer-3".into()))
        );
        assert_eq!(s.get("peer-3", &rk_new, "grad").unwrap(), None);
    }

    #[test]
    fn outage_injection_fails_puts() {
        let model = ProviderModel { outage_prob: 1.0, ..Default::default() };
        let s = ObjectStore::new(model, 1);
        s.create_bucket("b", "b");
        assert_eq!(s.put("b", "b", "k", vec![], 0), Err(StorageError::Outage));
    }

    #[test]
    fn size_limit_enforced() {
        let model = ProviderModel { max_object_bytes: 4, ..Default::default() };
        let s = ObjectStore::new(model, 1);
        s.create_bucket("b", "b");
        assert!(matches!(
            s.put("b", "b", "k", vec![0; 5], 0),
            Err(StorageError::TooLarge { size: 5, limit: 4 })
        ));
    }

    #[test]
    fn overwrite_updates_timestamp() {
        let s = store();
        let rk = s.create_bucket("b", "b");
        let t1 = s.put("b", "b", "k", vec![1], 0).unwrap();
        let t2 = s.put("b", "b", "k", vec![2], 5000).unwrap();
        assert!(t2 > t1);
        assert_eq!(s.get("b", &rk, "k").unwrap().unwrap().bytes, vec![2]);
    }

    #[test]
    fn prune_removes_old_objects_only_for_owner() {
        let s = store();
        let rk = s.create_bucket("b", "b");
        s.put("b", "b", "old", vec![1], 0).unwrap();
        s.put("b", "b", "new", vec![2], 10_000).unwrap();
        assert_eq!(s.prune_before("b", "intruder", 50_000), 0);
        assert_eq!(s.prune_before("b", "b", 5_000), 1);
        assert!(s.get("b", &rk, "old").unwrap().is_none());
        assert!(s.get("b", &rk, "new").unwrap().is_some());
    }

    #[test]
    fn list_returns_metadata() {
        let s = store();
        let rk = s.create_bucket("b", "b");
        s.put("b", "b", "a", vec![1], 0).unwrap();
        s.put("b", "b", "c", vec![2], 0).unwrap();
        let ls = s.list("b", &rk).unwrap();
        assert_eq!(ls.len(), 2);
        assert!(ls.iter().any(|(k, _)| k == "a"));
    }

    #[test]
    fn snapshot_accessors_rebuild_an_equivalent_store() {
        let s = store();
        let rk0 = s.create_bucket("peer-0", "peer-0");
        let rk1 = s.create_bucket("peer-1", "peer-1");
        s.put("peer-0", "peer-0", "g", vec![1], 100).unwrap(); // advances the rng

        let rebuilt = ObjectStore::new(s.model.clone(), 0);
        rebuilt.set_rng_state(s.rng_state());
        rebuilt.set_next_key_id(s.next_key_id());
        for (name, owner, key) in s.export_buckets() {
            rebuilt.restore_bucket(&name, &owner, key);
        }
        // Old keys still open the recreated buckets…
        assert_eq!(rebuilt.get("peer-0", &rk0, "g").unwrap(), None, "objects not carried");
        assert!(rebuilt.get("peer-1", &rk1, "x").unwrap().is_none());
        // …the key mint continues where it left off…
        assert_eq!(rebuilt.create_bucket("peer-2", "peer-2"), s.create_bucket("peer-2", "peer-2"));
        // …and the latency stream continues bit-identically.
        let ta = s.put("peer-0", "peer-0", "h", vec![2], 500).unwrap();
        let tb = rebuilt.put("peer-0", "peer-0", "h", vec![2], 500).unwrap();
        assert_eq!(ta, tb);
    }

    #[test]
    fn integrity_memo_computes_once_and_is_shared_across_arc_holders() {
        let s = store();
        let rk = s.create_bucket("b", "b");
        s.put("b", "b", "k", vec![9, 9, 9], 0).unwrap();
        let a = s.get("b", &rk, "k").unwrap().unwrap();
        let b = s.get("b", &rk, "k").unwrap().unwrap();
        let calls = std::cell::Cell::new(0u32);
        let verdict = a.integrity_memo(|bytes| {
            calls.set(calls.get() + 1);
            bytes == [9, 9, 9]
        });
        assert!(verdict);
        // Second holder of the same Arc sees the memo; its closure never runs.
        let again = b.integrity_memo(|_| {
            calls.set(calls.get() + 100);
            false
        });
        assert!(again, "memoized verdict wins over a later closure");
        assert_eq!(calls.get(), 1, "check ran exactly once across both readers");
        // Equality ignores the memo: a fresh equal object compares equal.
        let fresh = Object::new("k".into(), vec![9, 9, 9], a.stored_at);
        assert_eq!(*a, fresh);
    }

    #[test]
    fn concurrent_reads_and_owner_writes_do_not_poison() {
        // Smoke-test the sharded locking: 8 reader threads hammer windowed
        // GETs across 32 buckets while the owner keeps writing new rounds.
        let s = std::sync::Arc::new(store());
        let mut keys = Vec::new();
        for i in 0..32 {
            let b = format!("peer-{i}");
            keys.push(s.create_bucket(&b, &b));
            s.put(&b, &b, "r0", vec![i as u8], 1000).unwrap();
        }
        std::thread::scope(|scope| {
            for t in 0..8 {
                let s = &s;
                let keys = &keys;
                scope.spawn(move || {
                    for i in 0..32usize {
                        let b = format!("peer-{}", (i + t) % 32);
                        let rk = &keys[(i + t) % 32];
                        let got = s.get_within_window(&b, rk, "r0", 0, 10_000).unwrap();
                        assert!(matches!(got, WindowedGet::InWindow(_)));
                    }
                });
            }
            scope.spawn(|| {
                for i in 0..32 {
                    let b = format!("peer-{i}");
                    s.put(&b, &b, "r1", vec![0], 2000).unwrap();
                }
            });
        });
        for i in 0..32 {
            let b = format!("peer-{i}");
            assert_eq!(s.list(&b, &keys[i]).unwrap().len(), 2);
        }
    }
}
