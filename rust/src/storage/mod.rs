//! Simulated S3-compatible cloud storage (paper §5, "Cloud-Based
//! Communication").
//!
//! In the deployed system every peer owns a bucket at an S3-compliant
//! provider (Cloudflare R2), posts its *read* credentials to the chain, and
//! broadcasts pseudo-gradients by writing into its own bucket; validators
//! read from peers' buckets and trust the provider's object timestamps
//! (anchored to blockchain time) to enforce the per-round put window.
//!
//! This module reproduces that API surface in-process:
//!   - buckets with owner-only writes and key-holder reads,
//!   - robust server-side timestamps (simulation clock, not wall clock),
//!   - configurable upload latency and fault injection (outages model the
//!     "reliability of the cloud provider" caveat in §5),
//!   - put-window enforcement as a *reader-side* filter, exactly like the
//!     validator ignores out-of-window objects in the live system.
//!
//! # Concurrency
//!
//! Like a real provider, the store is shared: every method takes `&self`,
//! buckets are partitioned across [`SHARDS`] independent `RwLock`s (keyed
//! by bucket-name hash), and objects are handed out as `Arc` clones. The
//! parallel round pipeline (`coordinator::run`) fans each validator's
//! fast-evaluation reads over a worker pool, so concurrent
//! [`ObjectStore::get_within_window`] calls on different peers' buckets
//! must not serialize on one map — per-bucket sharding gives readers of
//! distinct buckets disjoint locks, and `RwLock` lets readers of the same
//! bucket proceed together.
//!
//! # Deterministic fault draw order
//!
//! Every fault draw in the store comes from seeded RNG state, in one
//! documented order, so run fingerprints pin bit-identically at any
//! thread count:
//!
//! - **Write path (sequential stream).** PUT-side draws — outage, upload
//!   latency, latency spike — come from one mutex-guarded [`Rng`] stream,
//!   advanced strictly in PUT order. The coordinator applies PUTs in
//!   deterministic peer order on one thread, so the stream is reproducible
//!   regardless of worker timing. Retried PUTs re-draw from the same
//!   stream (still on the coordinator, still in peer order).
//! - **Read path (keyed draws).** GET-side draws — transient get
//!   failure, corruption, truncation — cannot use a sequential stream:
//!   windowed GETs run concurrently across validators and pool workers,
//!   so draw *order* is nondeterministic. Instead each draw is a pure
//!   stateless function of `(fault seed, fault kind, bucket, key,
//!   reader, attempt)` hashed through [`Rng::from_parts`]. Any thread
//!   interleaving computes the same verdicts; a retry (higher `attempt`)
//!   is a fresh draw, while re-reading with the same attempt replays the
//!   same verdict.
//!
//! Targeted faults — per-reader eclipse and per-writer withholding — are
//! not probabilistic at all: they are explicit set-membership toggles
//! ([`ObjectStore::set_eclipse`], [`ObjectStore::set_withheld`]) driven
//! by the scenario engine on the coordinator thread.

use std::collections::{BTreeMap, BTreeSet};
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};

use crate::util::Rng;

/// Simulation time in milliseconds since run start.
pub type SimTime = u64;

/// Number of independent bucket shards (power of two).
pub const SHARDS: usize = 16;

/// A stored object with its server-assigned timestamp.
///
/// The store hands out `Arc<Object>` clones, so one stored submission is
/// shared by every validator that reads it in a round. That sharing is
/// what makes the integrity memo below worthwhile: the wire codec's
/// SHA-256 digest check is a function of `bytes` alone, so the first
/// reader's verdict can be cached on the object and served to every
/// later reader (`OnceLock` — thread-safe, computed at most once).
#[derive(Debug)]
pub struct Object {
    pub key: String,
    pub bytes: Vec<u8>,
    /// Server-side receive time — what the validator trusts.
    pub stored_at: SimTime,
    /// Memoized wire-integrity verdict (see [`Object::integrity_memo`]).
    integrity: OnceLock<bool>,
}

impl Object {
    pub fn new(key: String, bytes: Vec<u8>, stored_at: SimTime) -> Object {
        Object { key, bytes, stored_at, integrity: OnceLock::new() }
    }

    /// Whether `bytes` passes the caller's integrity check, computing
    /// `check` at most once for this object's lifetime. `check` must be
    /// a pure function of `self.bytes` (the wire codec's digest check
    /// is) — the verdict is shared across every holder of the `Arc`.
    pub fn integrity_memo(&self, check: impl FnOnce(&[u8]) -> bool) -> bool {
        *self.integrity.get_or_init(|| check(&self.bytes))
    }
}

// Manual impls: the memo is a cache, not state — a clone may carry the
// already-computed verdict, and equality ignores it entirely.
impl Clone for Object {
    fn clone(&self) -> Object {
        Object {
            key: self.key.clone(),
            bytes: self.bytes.clone(),
            stored_at: self.stored_at,
            integrity: self.integrity.clone(),
        }
    }
}

impl PartialEq for Object {
    fn eq(&self, other: &Object) -> bool {
        self.key == other.key && self.bytes == other.bytes && self.stored_at == other.stored_at
    }
}

/// Read credential a peer publishes on-chain (paper: read-access keys).
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct ReadKey(pub String);

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum StorageError {
    #[error("no such bucket {0:?}")]
    NoBucket(String),
    #[error("access denied to bucket {0:?}")]
    AccessDenied(String),
    #[error("provider outage")]
    Outage,
    #[error("object too large: {size} > {limit}")]
    TooLarge { size: usize, limit: usize },
    /// The object is definitively absent from the reader's view (e.g. the
    /// reader is eclipsed from the bucket). Unlike [`StorageError::Outage`]
    /// a retry cannot succeed — callers should degrade immediately.
    #[error("object not found: {0}")]
    NotFound(String),
}

impl StorageError {
    /// Whether a retry could plausibly succeed. Only [`StorageError::Outage`]
    /// is transient; every other variant is a definitive verdict (missing
    /// bucket, ACL failure, size limit, eclipsed view) and retrying it
    /// wastes the budget — callers should give up and degrade.
    pub fn is_transient(&self) -> bool {
        matches!(self, StorageError::Outage)
    }
}

/// Bounded-retry policy with exponential backoff on *simulation* time and
/// deterministic jitter. Used by peer PUTs and validator fast-eval GETs;
/// the jitter draw is a pure hash of `(salt, attempt)` — no wall clock,
/// no shared RNG stream — so retries are reproducible at any thread count.
#[derive(Clone, Debug, PartialEq)]
pub struct RetryPolicy {
    /// Total attempts (first try included). `1` disables retries.
    pub max_attempts: u32,
    /// Backoff before the second attempt; doubles per further attempt.
    pub base_backoff_ms: u64,
    /// Cap on the exponential term (jitter may exceed it by ≤ 25%).
    pub max_backoff_ms: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_attempts: 3, base_backoff_ms: 250, max_backoff_ms: 4000 }
    }
}

impl RetryPolicy {
    /// Sim-time to wait after the `attempt`-th try failed (1-based):
    /// `min(base · 2^(attempt-1), max)` plus a deterministic jitter in
    /// `[0, exp/4]` keyed on `(salt, attempt)`.
    pub fn backoff_ms(&self, salt: &str, attempt: u32) -> u64 {
        let shift = (attempt.saturating_sub(1)).min(16);
        let exp = self
            .base_backoff_ms
            .saturating_mul(1u64 << shift)
            .min(self.max_backoff_ms)
            .max(1);
        let jitter = Rng::from_parts(&["retry-jitter", salt, &attempt.to_string()])
            .below(exp / 4 + 1);
        exp + jitter
    }
}

struct Bucket {
    owner: String,
    read_key: ReadKey,
    objects: BTreeMap<String, Arc<Object>>,
}

/// Latency / reliability model for the simulated provider.
#[derive(Clone, Debug)]
pub struct ProviderModel {
    /// Mean upload latency (ms); actual draws are log-normal-ish around it.
    pub mean_upload_ms: f64,
    pub jitter_ms: f64,
    /// Probability an individual PUT is lost to a transient outage.
    pub outage_prob: f64,
    pub max_object_bytes: usize,
    /// Probability an individual GET fails transiently (retryable).
    pub get_fail_prob: f64,
    /// Probability a GET returns the payload with one bit flipped. The
    /// flip is deterministic per `(bucket, key, reader)` and always caught
    /// by the wire codec's digest verdict — never by a crash.
    pub corrupt_prob: f64,
    /// Probability a GET returns a deterministically truncated payload.
    pub truncate_prob: f64,
    /// Probability a PUT's upload latency takes an extra spike.
    pub spike_prob: f64,
    /// Size of the latency spike when one is drawn (ms).
    pub spike_ms: u64,
}

impl Default for ProviderModel {
    fn default() -> Self {
        ProviderModel {
            mean_upload_ms: 800.0,
            jitter_ms: 300.0,
            outage_prob: 0.0,
            max_object_bytes: 256 << 20,
            get_fail_prob: 0.0,
            corrupt_prob: 0.0,
            truncate_prob: 0.0,
            spike_prob: 0.0,
            spike_ms: 0,
        }
    }
}

/// The simulated S3 provider: all buckets, one global object namespace per
/// bucket, server-side clocks. Shareable across validator worker threads
/// (`&ObjectStore` is `Send + Sync`).
pub struct ObjectStore {
    shards: Vec<RwLock<BTreeMap<String, Bucket>>>,
    pub model: ProviderModel,
    /// Latency/outage draws; locked only on the (write-side) PUT path.
    rng: Mutex<Rng>,
    next_key_id: AtomicU64,
    /// Seed for the keyed (read-path) fault draws — see the module doc's
    /// "Deterministic fault draw order". Fixed at construction; never
    /// advanced, so no snapshot state beyond the constructor argument.
    fault_seed: u64,
    /// Targeted fault: `(reader, bucket)` pairs where the named reader's
    /// view of the bucket is blacked out (GETs return `NotFound`).
    eclipsed: RwLock<BTreeSet<(u64, String)>>,
    /// Targeted fault: writers whose PUTs succeed from their own point of
    /// view (latency drawn, stored-at returned) but are never persisted.
    withheld: RwLock<BTreeSet<String>>,
}

impl ObjectStore {
    pub fn new(model: ProviderModel, seed: u64) -> Self {
        ObjectStore {
            shards: (0..SHARDS).map(|_| RwLock::new(BTreeMap::new())).collect(),
            model,
            rng: Mutex::new(Rng::new(seed)),
            next_key_id: AtomicU64::new(0),
            fault_seed: seed,
            eclipsed: RwLock::new(BTreeSet::new()),
            withheld: RwLock::new(BTreeSet::new()),
        }
    }

    /// One keyed fault draw (read path). Pure function of the arguments —
    /// see the module doc for why the read path cannot share the write
    /// path's sequential stream.
    fn fault_rng(&self, kind: &str, bucket: &str, key: &str, reader: u64, attempt: u32) -> Rng {
        Rng::from_parts(&[
            "storage-fault",
            &self.fault_seed.to_string(),
            kind,
            bucket,
            key,
            &reader.to_string(),
            &attempt.to_string(),
        ])
    }

    fn shard(&self, bucket: &str) -> &RwLock<BTreeMap<String, Bucket>> {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        bucket.hash(&mut h);
        &self.shards[(h.finish() as usize) & (SHARDS - 1)]
    }

    /// Create a bucket owned by `owner`; returns the read key the owner
    /// would post on-chain.
    pub fn create_bucket(&self, name: &str, owner: &str) -> ReadKey {
        let id = self.next_key_id.fetch_add(1, Ordering::Relaxed) + 1;
        let key = ReadKey(format!("rk-{name}-{id:08x}"));
        self.shard(name).write().unwrap().insert(
            name.to_string(),
            Bucket { owner: owner.to_string(), read_key: key.clone(), objects: BTreeMap::new() },
        );
        key
    }

    pub fn bucket_exists(&self, name: &str) -> bool {
        self.shard(name).read().unwrap().contains_key(name)
    }

    /// Delete a bucket and every object in it (a deregistered peer's
    /// bucket is torn down; a recycled uid gets a brand-new bucket with a
    /// fresh read key). Returns whether the bucket existed.
    pub fn delete_bucket(&self, name: &str) -> bool {
        self.shard(name).write().unwrap().remove(name).is_some()
    }

    /// PUT an object. `now` is the client's send time; the stored timestamp
    /// is send time + simulated upload latency. Returns the server-side
    /// stored-at time, or an error on outage / size limit / ACL.
    pub fn put(
        &self,
        bucket: &str,
        writer: &str,
        key: &str,
        bytes: Vec<u8>,
        now: SimTime,
    ) -> Result<SimTime, StorageError> {
        self.check_size(&bytes)?;
        self.put_inner(bucket, writer, key, &mut Some(bytes), now)
    }

    /// PUT with bounded retries: transient failures back off on sim-time
    /// (each attempt's send time moves forward by [`RetryPolicy::backoff_ms`],
    /// so a rescued PUT can still land outside the put window — realistic
    /// degradation, not a free pass). Returns `(stored_at, attempts_used)`;
    /// definitive errors and an exhausted budget return the last error.
    pub fn put_with_retry(
        &self,
        bucket: &str,
        writer: &str,
        key: &str,
        bytes: Vec<u8>,
        now: SimTime,
        policy: &RetryPolicy,
    ) -> Result<(SimTime, u32), StorageError> {
        self.check_size(&bytes)?;
        let mut bytes = Some(bytes);
        let mut send = now;
        let mut attempt = 1u32;
        loop {
            match self.put_inner(bucket, writer, key, &mut bytes, send) {
                Ok(stored_at) => return Ok((stored_at, attempt)),
                Err(e) if e.is_transient() && attempt < policy.max_attempts.max(1) => {
                    send += policy.backoff_ms(key, attempt);
                    attempt += 1;
                }
                Err(e) => return Err(e),
            }
        }
    }

    fn check_size(&self, bytes: &[u8]) -> Result<(), StorageError> {
        if bytes.len() > self.model.max_object_bytes {
            return Err(StorageError::TooLarge {
                size: bytes.len(),
                limit: self.model.max_object_bytes,
            });
        }
        Ok(())
    }

    /// One PUT attempt. `bytes` is an `Option` so retries never clone the
    /// payload — it is only moved out on the attempt that actually stores.
    fn put_inner(
        &self,
        bucket: &str,
        writer: &str,
        key: &str,
        bytes: &mut Option<Vec<u8>>,
        now: SimTime,
    ) -> Result<SimTime, StorageError> {
        // One lock hold for all draws keeps the draw sequence identical to
        // the pre-sharding sequential store.
        let latency = {
            let mut rng = self.rng.lock().unwrap();
            if self.model.outage_prob > 0.0 && rng.chance(self.model.outage_prob) {
                return Err(StorageError::Outage);
            }
            let mut ms =
                (self.model.mean_upload_ms + rng.normal() * self.model.jitter_ms).max(1.0) as u64;
            if self.model.spike_prob > 0.0 && rng.chance(self.model.spike_prob) {
                ms += self.model.spike_ms;
            }
            ms
        };
        let mut shard = self.shard(bucket).write().unwrap();
        let b = shard
            .get_mut(bucket)
            .ok_or_else(|| StorageError::NoBucket(bucket.to_string()))?;
        if b.owner != writer {
            return Err(StorageError::AccessDenied(bucket.to_string()));
        }
        let stored_at = now + latency;
        if !self.is_withheld(writer) {
            let payload = bytes.take().expect("payload consumed by an earlier attempt");
            b.objects
                .insert(key.to_string(), Arc::new(Object::new(key.to_string(), payload, stored_at)));
        }
        Ok(stored_at)
    }

    /// GET with a read key (as validators do, using the on-chain key).
    pub fn get(
        &self,
        bucket: &str,
        rk: &ReadKey,
        key: &str,
    ) -> Result<Option<Arc<Object>>, StorageError> {
        let shard = self.shard(bucket).read().unwrap();
        let b = shard
            .get(bucket)
            .ok_or_else(|| StorageError::NoBucket(bucket.to_string()))?;
        if &b.read_key != rk {
            return Err(StorageError::AccessDenied(bucket.to_string()));
        }
        Ok(b.objects.get(key).cloned())
    }

    /// List all objects in a bucket (metadata view).
    pub fn list(&self, bucket: &str, rk: &ReadKey) -> Result<Vec<(String, SimTime)>, StorageError> {
        let shard = self.shard(bucket).read().unwrap();
        let b = shard
            .get(bucket)
            .ok_or_else(|| StorageError::NoBucket(bucket.to_string()))?;
        if &b.read_key != rk {
            return Err(StorageError::AccessDenied(bucket.to_string()));
        }
        Ok(b.objects.values().map(|o| (o.key.clone(), o.stored_at)).collect())
    }

    /// Reader-side put-window filter: fetch `key` only if its server
    /// timestamp falls inside `[window_start, window_end]` — the §3.2
    /// "basic checks (a)" rule. Both endpoints are inclusive: an object
    /// stored exactly on the window open or close is in-window. Returns:
    ///   `WindowedGet::InWindow(..)`   in-window object
    ///   `WindowedGet::Missing`        object absent (basic check (b) fails)
    ///   `WindowedGet::TooEarly/Late`  present but outside the window
    pub fn get_within_window(
        &self,
        bucket: &str,
        rk: &ReadKey,
        key: &str,
        window_start: SimTime,
        window_end: SimTime,
    ) -> Result<WindowedGet, StorageError> {
        match self.get(bucket, rk, key)? {
            None => Ok(WindowedGet::Missing),
            Some(o) if o.stored_at < window_start => Ok(WindowedGet::TooEarly(o.stored_at)),
            Some(o) if o.stored_at > window_end => Ok(WindowedGet::TooLate(o.stored_at)),
            Some(o) => Ok(WindowedGet::InWindow(o)),
        }
    }

    /// Windowed GET through the fault model, as a *named reader* — the
    /// fault-injecting counterpart of [`ObjectStore::get_within_window`].
    ///
    /// Fault order (read path, all keyed draws — see module doc):
    ///   1. eclipse check: an eclipsed `(reader, bucket)` pair gets a
    ///      definitive [`StorageError::NotFound`] (retrying cannot help);
    ///   2. transient get failure ([`ProviderModel::get_fail_prob`]) →
    ///      [`StorageError::Outage`]; a retry with a higher `attempt` is a
    ///      fresh draw;
    ///   3. payload damage on in-window objects: corruption (one bit
    ///      flipped) then truncation, keyed per `(bucket, key, reader)` so
    ///      the damage is stable across retries — retrying cannot launder a
    ///      corrupt replica; the digest verdict has to catch it.
    ///
    /// Damage is applied to a *fresh* `Arc<Object>` copy: the pristine
    /// stored object (and its shared integrity memo) is never touched, so
    /// other readers still see good bytes.
    pub fn get_within_window_as(
        &self,
        reader: u64,
        attempt: u32,
        bucket: &str,
        rk: &ReadKey,
        key: &str,
        window_start: SimTime,
        window_end: SimTime,
    ) -> Result<WindowedGet, StorageError> {
        if self.is_eclipsed(reader, bucket) {
            return Err(StorageError::NotFound(format!("{bucket}/{key}")));
        }
        if self.model.get_fail_prob > 0.0
            && self.fault_rng("get-fail", bucket, key, reader, attempt).next_f64()
                < self.model.get_fail_prob
        {
            return Err(StorageError::Outage);
        }
        match self.get_within_window(bucket, rk, key, window_start, window_end)? {
            WindowedGet::InWindow(o) => {
                Ok(WindowedGet::InWindow(self.maybe_damage(o, bucket, key, reader)))
            }
            other => Ok(other),
        }
    }

    /// Apply read-path payload damage (corruption, then truncation) per the
    /// model's probabilities. Returns the original `Arc` untouched when no
    /// damage is drawn.
    fn maybe_damage(&self, o: Arc<Object>, bucket: &str, key: &str, reader: u64) -> Arc<Object> {
        if self.model.corrupt_prob > 0.0 && !o.bytes.is_empty() {
            let mut rng = self.fault_rng("corrupt", bucket, key, reader, 0);
            if rng.next_f64() < self.model.corrupt_prob {
                let mut bytes = o.bytes.clone();
                let pos = rng.below(bytes.len() as u64) as usize;
                let bit = rng.below(8) as u32;
                // XOR always changes the byte, so any drawn flip is a real
                // corruption the digest check must reject.
                bytes[pos] ^= 1u8 << bit;
                return Arc::new(Object::new(o.key.clone(), bytes, o.stored_at));
            }
        }
        if self.model.truncate_prob > 0.0 && !o.bytes.is_empty() {
            let mut rng = self.fault_rng("truncate", bucket, key, reader, 0);
            if rng.next_f64() < self.model.truncate_prob {
                let keep = rng.below(o.bytes.len() as u64) as usize;
                let bytes = o.bytes[..keep].to_vec();
                return Arc::new(Object::new(o.key.clone(), bytes, o.stored_at));
            }
        }
        o
    }

    // ------------------- targeted faults (eclipse / withholding) ---------

    /// Black out `reader`'s view of `bucket`: its GETs via
    /// [`ObjectStore::get_within_window_as`] return
    /// [`StorageError::NotFound`] until cleared.
    pub fn set_eclipse(&self, reader: u64, bucket: &str) {
        self.eclipsed.write().unwrap().insert((reader, bucket.to_string()));
    }

    /// Lift an eclipse; returns whether it was active.
    pub fn clear_eclipse(&self, reader: u64, bucket: &str) -> bool {
        self.eclipsed.write().unwrap().remove(&(reader, bucket.to_string()))
    }

    pub fn is_eclipsed(&self, reader: u64, bucket: &str) -> bool {
        let set = self.eclipsed.read().unwrap();
        // Fast path: the common (no targeted faults) case takes only the
        // read lock — no per-GET key allocation.
        !set.is_empty() && set.contains(&(reader, bucket.to_string()))
    }

    /// Withhold `writer`'s PUTs: they succeed from the writer's view
    /// (latency drawn, stored-at returned) but nothing is persisted, so
    /// every reader sees the object as missing.
    pub fn set_withheld(&self, writer: &str) {
        self.withheld.write().unwrap().insert(writer.to_string());
    }

    /// Stop withholding; returns whether the writer was withheld.
    pub fn clear_withheld(&self, writer: &str) -> bool {
        self.withheld.write().unwrap().remove(writer)
    }

    pub fn is_withheld(&self, writer: &str) -> bool {
        let set = self.withheld.read().unwrap();
        !set.is_empty() && set.contains(writer)
    }

    // ------------------- snapshot/resume support ------------------------
    //
    // The round pipeline only ever reads objects written in the *current*
    // round (fast-eval windowed GETs, copier second-pass reads), so a
    // round-boundary snapshot needs no object payloads — but it must
    // preserve the provider's RNG stream (latency/outage draws are taken
    // in deterministic PUT order), the read-key counter (future keys must
    // match), and every bucket's name/owner/read-key (the keys are already
    // published on-chain and must keep opening the recreated buckets).

    /// The provider RNG's raw state (see [`crate::util::Rng::state`]).
    pub fn rng_state(&self) -> u64 {
        self.rng.lock().unwrap().state()
    }

    /// Restore the provider RNG mid-stream.
    pub fn set_rng_state(&self, state: u64) {
        *self.rng.lock().unwrap() = Rng::from_state(state);
    }

    /// The read-key counter (next `create_bucket` uses this + 1).
    pub fn next_key_id(&self) -> u64 {
        self.next_key_id.load(Ordering::Relaxed)
    }

    pub fn set_next_key_id(&self, id: u64) {
        self.next_key_id.store(id, Ordering::Relaxed);
    }

    /// Every bucket's `(name, owner, read key)`, sorted by name.
    pub fn export_buckets(&self) -> Vec<(String, String, ReadKey)> {
        let mut out = Vec::new();
        for shard in &self.shards {
            for (name, b) in shard.read().unwrap().iter() {
                out.push((name.clone(), b.owner.clone(), b.read_key.clone()));
            }
        }
        out.sort();
        out
    }

    /// Recreate a bucket with a *given* read key (snapshot restore path;
    /// normal registration uses [`ObjectStore::create_bucket`], which mints
    /// a fresh key). The bucket starts empty.
    pub fn restore_bucket(&self, name: &str, owner: &str, key: ReadKey) {
        self.shard(name).write().unwrap().insert(
            name.to_string(),
            Bucket { owner: owner.to_string(), read_key: key, objects: BTreeMap::new() },
        );
    }

    /// Garbage-collect objects stored before `cutoff` (peers prune old
    /// rounds so buckets stay small).
    pub fn prune_before(&self, bucket: &str, writer: &str, cutoff: SimTime) -> usize {
        let mut shard = self.shard(bucket).write().unwrap();
        let Some(b) = shard.get_mut(bucket) else { return 0 };
        if b.owner != writer {
            return 0;
        }
        let before = b.objects.len();
        b.objects.retain(|_, o| o.stored_at >= cutoff);
        before - b.objects.len()
    }
}

/// Result of a windowed GET (see [`ObjectStore::get_within_window`]).
/// Owns its object handle so results can cross worker-thread boundaries.
#[derive(Clone, Debug)]
pub enum WindowedGet {
    InWindow(Arc<Object>),
    Missing,
    TooEarly(SimTime),
    TooLate(SimTime),
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> ObjectStore {
        let model = ProviderModel { mean_upload_ms: 100.0, jitter_ms: 0.0, ..Default::default() };
        ObjectStore::new(model, 42)
    }

    #[test]
    fn put_get_roundtrip_with_read_key() {
        let s = store();
        let rk = s.create_bucket("peer-0", "peer-0");
        let t = s.put("peer-0", "peer-0", "grad-17", vec![1, 2, 3], 1000).unwrap();
        assert!(t >= 1100, "latency applied");
        let o = s.get("peer-0", &rk, "grad-17").unwrap().unwrap();
        assert_eq!(o.bytes, vec![1, 2, 3]);
        assert_eq!(o.stored_at, t);
    }

    #[test]
    fn wrong_read_key_denied() {
        let s = store();
        let _rk = s.create_bucket("peer-0", "peer-0");
        let bad = ReadKey("rk-fake".into());
        assert_eq!(s.get("peer-0", &bad, "x"), Err(StorageError::AccessDenied("peer-0".into())));
    }

    #[test]
    fn only_owner_can_write() {
        let s = store();
        s.create_bucket("peer-0", "peer-0");
        let err = s.put("peer-0", "peer-1", "k", vec![], 0).unwrap_err();
        assert_eq!(err, StorageError::AccessDenied("peer-0".into()));
    }

    #[test]
    fn missing_bucket_errors() {
        let s = store();
        assert!(matches!(
            s.get("nope", &ReadKey("rk".into()), "k"),
            Err(StorageError::NoBucket(_))
        ));
    }

    #[test]
    fn window_filter_classifies_early_late_missing() {
        let s = store();
        let rk = s.create_bucket("b", "b");
        s.put("b", "b", "ontime", vec![1], 1000).unwrap(); // stored ~1100
        s.put("b", "b", "early", vec![2], 0).unwrap(); // stored ~100
        s.put("b", "b", "late", vec![3], 99_000).unwrap(); // stored ~99100
        let w = |k: &str| s.get_within_window("b", &rk, k, 500, 2000).unwrap();
        assert!(matches!(w("ontime"), WindowedGet::InWindow(_)));
        assert!(matches!(w("early"), WindowedGet::TooEarly(_)));
        assert!(matches!(w("late"), WindowedGet::TooLate(_)));
        assert!(matches!(w("absent"), WindowedGet::Missing));
    }

    #[test]
    fn window_boundaries_are_inclusive() {
        // jitter = 0 lands objects at exactly now + mean_upload_ms, so the
        // boundary semantics are testable: exactly-on-open and
        // exactly-on-close are both in-window; one ms outside is not.
        let s = store();
        let rk = s.create_bucket("b", "b");
        let on_open = s.put("b", "b", "on-open", vec![1], 400).unwrap();
        assert_eq!(on_open, 500);
        let on_close = s.put("b", "b", "on-close", vec![2], 1900).unwrap();
        assert_eq!(on_close, 2000);
        let before_open = s.put("b", "b", "before-open", vec![3], 399).unwrap();
        assert_eq!(before_open, 499);
        let after_close = s.put("b", "b", "after-close", vec![4], 1901).unwrap();
        assert_eq!(after_close, 2001);

        let w = |k: &str| s.get_within_window("b", &rk, k, 500, 2000).unwrap();
        assert!(matches!(w("on-open"), WindowedGet::InWindow(_)), "open edge is inclusive");
        assert!(matches!(w("on-close"), WindowedGet::InWindow(_)), "close edge is inclusive");
        assert!(matches!(w("before-open"), WindowedGet::TooEarly(499)));
        assert!(matches!(w("after-close"), WindowedGet::TooLate(2001)));
    }

    #[test]
    fn delete_bucket_tears_down_and_recreate_rotates_key() {
        let s = store();
        let rk_old = s.create_bucket("peer-3", "peer-3");
        s.put("peer-3", "peer-3", "grad", vec![1], 0).unwrap();
        assert!(s.delete_bucket("peer-3"));
        assert!(!s.bucket_exists("peer-3"));
        assert!(!s.delete_bucket("peer-3"), "second delete is a no-op");
        // A recycled uid recreates the bucket: old objects are gone and the
        // old read key no longer opens it.
        let rk_new = s.create_bucket("peer-3", "peer-3");
        assert_ne!(rk_old, rk_new);
        assert_eq!(
            s.get("peer-3", &rk_old, "grad"),
            Err(StorageError::AccessDenied("peer-3".into()))
        );
        assert_eq!(s.get("peer-3", &rk_new, "grad").unwrap(), None);
    }

    #[test]
    fn outage_injection_fails_puts() {
        let model = ProviderModel { outage_prob: 1.0, ..Default::default() };
        let s = ObjectStore::new(model, 1);
        s.create_bucket("b", "b");
        assert_eq!(s.put("b", "b", "k", vec![], 0), Err(StorageError::Outage));
    }

    #[test]
    fn size_limit_enforced() {
        let model = ProviderModel { max_object_bytes: 4, ..Default::default() };
        let s = ObjectStore::new(model, 1);
        s.create_bucket("b", "b");
        assert!(matches!(
            s.put("b", "b", "k", vec![0; 5], 0),
            Err(StorageError::TooLarge { size: 5, limit: 4 })
        ));
    }

    #[test]
    fn overwrite_updates_timestamp() {
        let s = store();
        let rk = s.create_bucket("b", "b");
        let t1 = s.put("b", "b", "k", vec![1], 0).unwrap();
        let t2 = s.put("b", "b", "k", vec![2], 5000).unwrap();
        assert!(t2 > t1);
        assert_eq!(s.get("b", &rk, "k").unwrap().unwrap().bytes, vec![2]);
    }

    #[test]
    fn prune_removes_old_objects_only_for_owner() {
        let s = store();
        let rk = s.create_bucket("b", "b");
        s.put("b", "b", "old", vec![1], 0).unwrap();
        s.put("b", "b", "new", vec![2], 10_000).unwrap();
        assert_eq!(s.prune_before("b", "intruder", 50_000), 0);
        assert_eq!(s.prune_before("b", "b", 5_000), 1);
        assert!(s.get("b", &rk, "old").unwrap().is_none());
        assert!(s.get("b", &rk, "new").unwrap().is_some());
    }

    #[test]
    fn list_returns_metadata() {
        let s = store();
        let rk = s.create_bucket("b", "b");
        s.put("b", "b", "a", vec![1], 0).unwrap();
        s.put("b", "b", "c", vec![2], 0).unwrap();
        let ls = s.list("b", &rk).unwrap();
        assert_eq!(ls.len(), 2);
        assert!(ls.iter().any(|(k, _)| k == "a"));
    }

    #[test]
    fn snapshot_accessors_rebuild_an_equivalent_store() {
        let s = store();
        let rk0 = s.create_bucket("peer-0", "peer-0");
        let rk1 = s.create_bucket("peer-1", "peer-1");
        s.put("peer-0", "peer-0", "g", vec![1], 100).unwrap(); // advances the rng

        let rebuilt = ObjectStore::new(s.model.clone(), 0);
        rebuilt.set_rng_state(s.rng_state());
        rebuilt.set_next_key_id(s.next_key_id());
        for (name, owner, key) in s.export_buckets() {
            rebuilt.restore_bucket(&name, &owner, key);
        }
        // Old keys still open the recreated buckets…
        assert_eq!(rebuilt.get("peer-0", &rk0, "g").unwrap(), None, "objects not carried");
        assert!(rebuilt.get("peer-1", &rk1, "x").unwrap().is_none());
        // …the key mint continues where it left off…
        assert_eq!(rebuilt.create_bucket("peer-2", "peer-2"), s.create_bucket("peer-2", "peer-2"));
        // …and the latency stream continues bit-identically.
        let ta = s.put("peer-0", "peer-0", "h", vec![2], 500).unwrap();
        let tb = rebuilt.put("peer-0", "peer-0", "h", vec![2], 500).unwrap();
        assert_eq!(ta, tb);
    }

    #[test]
    fn integrity_memo_computes_once_and_is_shared_across_arc_holders() {
        let s = store();
        let rk = s.create_bucket("b", "b");
        s.put("b", "b", "k", vec![9, 9, 9], 0).unwrap();
        let a = s.get("b", &rk, "k").unwrap().unwrap();
        let b = s.get("b", &rk, "k").unwrap().unwrap();
        let calls = std::cell::Cell::new(0u32);
        let verdict = a.integrity_memo(|bytes| {
            calls.set(calls.get() + 1);
            bytes == [9, 9, 9]
        });
        assert!(verdict);
        // Second holder of the same Arc sees the memo; its closure never runs.
        let again = b.integrity_memo(|_| {
            calls.set(calls.get() + 100);
            false
        });
        assert!(again, "memoized verdict wins over a later closure");
        assert_eq!(calls.get(), 1, "check ran exactly once across both readers");
        // Equality ignores the memo: a fresh equal object compares equal.
        let fresh = Object::new("k".into(), vec![9, 9, 9], a.stored_at);
        assert_eq!(*a, fresh);
    }

    #[test]
    fn concurrent_reads_and_owner_writes_do_not_poison() {
        // Smoke-test the sharded locking: 8 reader threads hammer windowed
        // GETs across 32 buckets while the owner keeps writing new rounds.
        let s = std::sync::Arc::new(store());
        let mut keys = Vec::new();
        for i in 0..32 {
            let b = format!("peer-{i}");
            keys.push(s.create_bucket(&b, &b));
            s.put(&b, &b, "r0", vec![i as u8], 1000).unwrap();
        }
        std::thread::scope(|scope| {
            for t in 0..8 {
                let s = &s;
                let keys = &keys;
                scope.spawn(move || {
                    for i in 0..32usize {
                        let b = format!("peer-{}", (i + t) % 32);
                        let rk = &keys[(i + t) % 32];
                        let got = s.get_within_window(&b, rk, "r0", 0, 10_000).unwrap();
                        assert!(matches!(got, WindowedGet::InWindow(_)));
                    }
                });
            }
            scope.spawn(|| {
                for i in 0..32 {
                    let b = format!("peer-{i}");
                    s.put(&b, &b, "r1", vec![0], 2000).unwrap();
                }
            });
        });
        for i in 0..32 {
            let b = format!("peer-{i}");
            assert_eq!(s.list(&b, &keys[i]).unwrap().len(), 2);
        }
    }

    // ------------------- fault model -------------------------------------

    fn chaos_store(model: ProviderModel) -> (ObjectStore, ReadKey) {
        let s = ObjectStore::new(model, 42);
        let rk = s.create_bucket("peer-7", "peer-7");
        s.put("peer-7", "peer-7", "grad", vec![9, 9, 9], 400).unwrap(); // stored at 500
        (s, rk)
    }

    #[test]
    fn get_fail_is_transient_and_leaves_the_plain_path_alone() {
        let model = ProviderModel {
            mean_upload_ms: 100.0,
            jitter_ms: 0.0,
            get_fail_prob: 1.0,
            ..Default::default()
        };
        let (s, rk) = chaos_store(model);
        let err = s.get_within_window_as(1, 0, "peer-7", &rk, "grad", 0, 10_000).unwrap_err();
        assert_eq!(err, StorageError::Outage);
        assert!(err.is_transient(), "get-fail must look retryable");
        // The un-named (fault-free) read path is not touched by the model.
        let got = s.get_within_window("peer-7", &rk, "grad", 0, 10_000).unwrap();
        assert!(matches!(got, WindowedGet::InWindow(_)));
    }

    #[test]
    fn keyed_get_draws_are_reproducible_across_calls_and_threads() {
        let model = ProviderModel {
            mean_upload_ms: 100.0,
            jitter_ms: 0.0,
            get_fail_prob: 0.5,
            corrupt_prob: 0.5,
            ..Default::default()
        };
        let s = std::sync::Arc::new(ObjectStore::new(model, 42));
        let mut rks = Vec::new();
        for i in 0..8 {
            let b = format!("peer-{i}");
            rks.push(s.create_bucket(&b, &b));
            s.put(&b, &b, "grad", vec![i as u8; 16], 400).unwrap();
        }
        let read_all = |reader: u64| {
            (0..8usize)
                .map(|i| {
                    let b = format!("peer-{i}");
                    format!(
                        "{:?}",
                        s.get_within_window_as(reader, 0, &b, &rks[i], "grad", 0, 10_000)
                    )
                })
                .collect::<Vec<_>>()
        };
        let sequential = read_all(3);
        // The same reads done concurrently (any interleaving) must match
        // the sequential verdicts exactly — draws are keyed, not streamed.
        let concurrent = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..8)
                .map(|_| scope.spawn(|| read_all(3)))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect::<Vec<_>>()
        });
        for run in &concurrent {
            assert_eq!(*run, sequential);
        }
    }

    #[test]
    fn corruption_flips_bits_on_a_fresh_copy_only() {
        let model = ProviderModel {
            mean_upload_ms: 100.0,
            jitter_ms: 0.0,
            corrupt_prob: 1.0,
            ..Default::default()
        };
        let (s, rk) = chaos_store(model);
        let WindowedGet::InWindow(damaged) =
            s.get_within_window_as(1, 0, "peer-7", &rk, "grad", 0, 10_000).unwrap()
        else {
            panic!("expected in-window object")
        };
        assert_eq!(damaged.bytes.len(), 3, "corruption preserves length");
        assert_ne!(damaged.bytes, vec![9, 9, 9], "exactly one bit differs");
        let diff: u32 = damaged
            .bytes
            .iter()
            .zip([9u8, 9, 9])
            .map(|(a, b)| (a ^ b).count_ones())
            .sum();
        assert_eq!(diff, 1, "single bit flip");
        // Damage is stable across retries: same reader, same replica.
        let WindowedGet::InWindow(again) =
            s.get_within_window_as(1, 1, "peer-7", &rk, "grad", 0, 10_000).unwrap()
        else {
            panic!()
        };
        assert_eq!(again.bytes, damaged.bytes);
        // The stored object (and its integrity memo) stays pristine.
        let pristine = s.get("peer-7", &rk, "grad").unwrap().unwrap();
        assert_eq!(pristine.bytes, vec![9, 9, 9]);
        assert!(pristine.integrity_memo(|b| b == [9, 9, 9]));
        assert!(!damaged.integrity_memo(|b| b == [9, 9, 9]), "memo not shared with damage");
    }

    #[test]
    fn truncation_shortens_the_payload() {
        let model = ProviderModel {
            mean_upload_ms: 100.0,
            jitter_ms: 0.0,
            truncate_prob: 1.0,
            ..Default::default()
        };
        let (s, rk) = chaos_store(model);
        let WindowedGet::InWindow(o) =
            s.get_within_window_as(1, 0, "peer-7", &rk, "grad", 0, 10_000).unwrap()
        else {
            panic!()
        };
        assert!(o.bytes.len() < 3, "tail cut: {:?}", o.bytes);
        assert_eq!(o.bytes, vec![9u8; o.bytes.len()], "prefix preserved");
    }

    #[test]
    fn eclipse_blacks_out_one_reader_only_and_is_definitive() {
        let model =
            ProviderModel { mean_upload_ms: 100.0, jitter_ms: 0.0, ..Default::default() };
        let (s, rk) = chaos_store(model);
        s.set_eclipse(1, "peer-7");
        let err = s.get_within_window_as(1, 0, "peer-7", &rk, "grad", 0, 10_000).unwrap_err();
        assert!(matches!(err, StorageError::NotFound(_)));
        assert!(!err.is_transient(), "eclipse must not look retryable");
        // Another reader's view is untouched.
        let other = s.get_within_window_as(2, 0, "peer-7", &rk, "grad", 0, 10_000).unwrap();
        assert!(matches!(other, WindowedGet::InWindow(_)));
        assert!(s.clear_eclipse(1, "peer-7"));
        assert!(!s.clear_eclipse(1, "peer-7"), "second clear is a no-op");
        let back = s.get_within_window_as(1, 0, "peer-7", &rk, "grad", 0, 10_000).unwrap();
        assert!(matches!(back, WindowedGet::InWindow(_)));
    }

    #[test]
    fn withheld_writer_put_succeeds_but_stores_nothing() {
        let model =
            ProviderModel { mean_upload_ms: 100.0, jitter_ms: 0.0, ..Default::default() };
        let s = ObjectStore::new(model, 42);
        let rk = s.create_bucket("peer-3", "peer-3");
        s.set_withheld("peer-3");
        let t = s.put("peer-3", "peer-3", "grad", vec![1, 2], 400).unwrap();
        assert_eq!(t, 500, "writer sees a normal ack with latency");
        assert_eq!(s.get("peer-3", &rk, "grad").unwrap(), None, "readers see nothing");
        assert!(s.clear_withheld("peer-3"));
        s.put("peer-3", "peer-3", "grad", vec![1, 2], 600).unwrap();
        assert!(s.get("peer-3", &rk, "grad").unwrap().is_some());
    }

    #[test]
    fn latency_spike_extends_stored_at() {
        let model = ProviderModel {
            mean_upload_ms: 100.0,
            jitter_ms: 0.0,
            spike_prob: 1.0,
            spike_ms: 5_000,
            ..Default::default()
        };
        let s = ObjectStore::new(model, 42);
        s.create_bucket("b", "b");
        assert_eq!(s.put("b", "b", "k", vec![1], 400).unwrap(), 5_500);
    }

    #[test]
    fn retry_free_put_matches_put_with_retry_on_a_clean_provider() {
        // With no faults drawn, put and put_with_retry consume identical
        // draw sequences — the retry layer adds nothing on the happy path.
        let a = store();
        let b = store();
        a.create_bucket("p", "p");
        b.create_bucket("p", "p");
        let policy = RetryPolicy::default();
        for i in 0..4 {
            let t1 = a.put("p", "p", "k", vec![i], 100).unwrap();
            let (t2, attempts) = b.put_with_retry("p", "p", "k", vec![i], 100, &policy).unwrap();
            assert_eq!(t1, t2);
            assert_eq!(attempts, 1);
        }
        assert_eq!(a.rng_state(), b.rng_state(), "same stream position");
    }

    #[test]
    fn put_with_retry_exhausts_budget_on_hard_outage() {
        let model = ProviderModel { outage_prob: 1.0, ..Default::default() };
        let s = ObjectStore::new(model, 1);
        s.create_bucket("b", "b");
        let policy = RetryPolicy { max_attempts: 3, ..Default::default() };
        let before = s.rng_state();
        assert_eq!(
            s.put_with_retry("b", "b", "k", vec![1], 0, &policy),
            Err(StorageError::Outage)
        );
        assert_ne!(s.rng_state(), before, "attempts consumed outage draws");
    }

    #[test]
    fn put_with_retry_rescues_transient_outages() {
        let model = ProviderModel {
            mean_upload_ms: 100.0,
            jitter_ms: 0.0,
            outage_prob: 0.5,
            ..Default::default()
        };
        let s = ObjectStore::new(model, 7);
        let rk = s.create_bucket("b", "b");
        let policy = RetryPolicy { max_attempts: 50, ..Default::default() };
        let mut retried = false;
        for i in 0..32u8 {
            let key = format!("k{i}");
            let (stored_at, attempts) =
                s.put_with_retry("b", "b", &key, vec![i], 1_000, &policy).unwrap();
            if attempts > 1 {
                retried = true;
                assert!(stored_at > 1_100, "backoff pushed the send time forward");
            }
        }
        assert!(retried, "a p=0.5 outage must trip at least one retry in 32 puts");
        assert_eq!(s.list("b", &rk).unwrap().len(), 32, "every put eventually landed");
    }

    #[test]
    fn backoff_is_deterministic_exponential_and_capped() {
        let p = RetryPolicy { max_attempts: 5, base_backoff_ms: 250, max_backoff_ms: 4_000 };
        let b1 = p.backoff_ms("grad-3", 1);
        assert_eq!(b1, p.backoff_ms("grad-3", 1), "same salt+attempt, same jitter");
        // Exponential envelope: exp term doubles until the cap; jitter ≤ exp/4.
        for attempt in 1..=8u32 {
            let exp = (250u64 << (attempt - 1).min(16)).min(4_000);
            let b = p.backoff_ms("grad-3", attempt);
            assert!(b >= exp && b <= exp + exp / 4, "attempt {attempt}: {b} vs exp {exp}");
        }
    }
}
