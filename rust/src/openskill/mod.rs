//! OpenSkill rating system — Plackett–Luce model (Weng & Lin 2011, JMLR;
//! Joshy 2024 "OpenSkill" [paper ref 8]).
//!
//! The Gauntlet validator ranks the sampled peer subset S_t by LossScore
//! each round and feeds the ranking through this model; the resulting
//! `LossRating` (we use the conservative ordinal estimate, as openskill.py
//! does for leaderboards) is one of the two factors of PEERSCORE (eq. 4).
//!
//! This is a faithful port of the PlackettLuce update in openskill.py
//! (one-player teams, which is all Gauntlet needs): for each match the
//! sampled peers are a free-for-all ranked by score, with ties sharing a
//! rank.

/// A peer's rating: belief over skill as a Gaussian (mu, sigma).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Rating {
    pub mu: f64,
    pub sigma: f64,
}

impl Rating {
    /// Conservative point estimate used for ranking/leaderboards.
    pub fn ordinal(&self) -> f64 {
        self.mu - 3.0 * self.sigma
    }
}

/// Plackett–Luce model parameters (openskill.py defaults).
#[derive(Clone, Copy, Debug)]
pub struct PlackettLuce {
    pub mu0: f64,
    pub sigma0: f64,
    pub beta: f64,
    /// Additive dynamics variance (tau^2) applied before each update so
    /// sigma never collapses to zero and ratings stay adaptive.
    pub tau: f64,
    /// Numerical floor for the sigma update factor.
    pub kappa: f64,
}

impl Default for PlackettLuce {
    fn default() -> Self {
        let mu0 = 25.0;
        let sigma0 = mu0 / 3.0;
        PlackettLuce { mu0, sigma0, beta: sigma0 / 2.0, tau: mu0 / 300.0, kappa: 1e-4 }
    }
}

impl PlackettLuce {
    pub fn initial(&self) -> Rating {
        Rating { mu: self.mu0, sigma: self.sigma0 }
    }

    /// Update ratings for one match.
    ///
    /// `ranks[i]` is the rank of player i: **lower is better**, equal values
    /// are ties. Returns updated ratings in the same order.
    pub fn rate(&self, ratings: &[Rating], ranks: &[usize]) -> Vec<Rating> {
        assert_eq!(ratings.len(), ranks.len());
        let n = ratings.len();
        if n < 2 {
            return ratings.to_vec(); // no information in a 1-player match
        }

        // Dynamics: inflate sigma before the update (tau), as openskill.py
        // does, keeping long-lived ratings adaptive.
        let rs: Vec<Rating> = ratings
            .iter()
            .map(|r| Rating { mu: r.mu, sigma: (r.sigma * r.sigma + self.tau * self.tau).sqrt() })
            .collect();

        let beta_sq = self.beta * self.beta;
        // c = sqrt(sum_i (sigma_i^2 + beta^2))
        let c = crate::util::det_sum(rs.iter().map(|r| r.sigma * r.sigma + beta_sq)).sqrt();

        // sum_q[q] = sum over players i with rank_i >= rank_q of exp(mu_i/c)
        let exp_mu: Vec<f64> = rs.iter().map(|r| (r.mu / c).exp()).collect();
        let sum_q: Vec<f64> = (0..n)
            .map(|q| {
                crate::util::det_sum(
                    (0..n).filter(|&i| ranks[i] >= ranks[q]).map(|i| exp_mu[i]),
                )
            })
            .collect();
        // a[i] = number of players tied with player i (including itself)
        let a: Vec<f64> =
            (0..n).map(|i| ranks.iter().filter(|&&r| r == ranks[i]).count() as f64).collect();

        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let mut omega = 0.0;
            let mut delta = 0.0;
            for q in 0..n {
                if ranks[q] > ranks[i] {
                    continue; // only q with rank_q <= rank_i contribute
                }
                let quotient = exp_mu[i] / sum_q[q];
                omega += (if i == q { 1.0 - quotient } else { -quotient }) / a[q];
                delta += quotient * (1.0 - quotient) / a[q];
            }
            let sigma_sq = rs[i].sigma * rs[i].sigma;
            omega *= sigma_sq / c;
            delta *= sigma_sq / (c * c);
            // gamma regularizer (openskill.py default: sigma / c)
            let gamma = rs[i].sigma / c;
            let mu = rs[i].mu + omega;
            let sigma = (sigma_sq * (1.0 - gamma * delta).max(self.kappa)).sqrt();
            out.push(Rating { mu, sigma });
        }
        out
    }

    /// Convenience: rank players by a score (**higher score is better**),
    /// handling exact ties, then update.
    pub fn rate_by_scores(&self, ratings: &[Rating], scores: &[f64]) -> Vec<Rating> {
        let ranks = ranks_from_scores(scores);
        self.rate(ratings, &ranks)
    }
}

/// Dense ranks from scores: best score gets rank 0; exact ties share a rank.
pub fn ranks_from_scores(scores: &[f64]) -> Vec<usize> {
    let n = scores.len();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| scores[j].partial_cmp(&scores[i]).unwrap_or(std::cmp::Ordering::Equal));
    let mut ranks = vec![0usize; n];
    let mut rank = 0;
    for (pos, &i) in order.iter().enumerate() {
        if pos > 0 && scores[order[pos - 1]] > scores[i] {
            rank = pos;
        }
        ranks[i] = rank;
    }
    ranks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use crate::prop_assert;
    use crate::util::Rng;

    fn model() -> PlackettLuce {
        PlackettLuce::default()
    }

    #[test]
    fn winner_gains_loser_loses() {
        let m = model();
        let r = vec![m.initial(), m.initial()];
        let out = m.rate(&r, &[0, 1]);
        assert!(out[0].mu > r[0].mu, "winner mu should rise");
        assert!(out[1].mu < r[1].mu, "loser mu should fall");
        assert!(out[0].sigma < r[0].sigma * 1.001, "sigma should not blow up");
    }

    #[test]
    fn symmetric_two_player_update_is_antisymmetric() {
        let m = model();
        let r = vec![m.initial(), m.initial()];
        let out = m.rate(&r, &[0, 1]);
        let gain = out[0].mu - m.mu0;
        let loss = m.mu0 - out[1].mu;
        assert!((gain - loss).abs() < 1e-9, "equal-rating match should be zero-sum in mu");
    }

    #[test]
    fn ties_between_equals_leave_mu_unchanged() {
        let m = model();
        let r = vec![m.initial(), m.initial()];
        let out = m.rate(&r, &[0, 0]);
        assert!((out[0].mu - m.mu0).abs() < 1e-9);
        assert!((out[1].mu - m.mu0).abs() < 1e-9);
    }

    #[test]
    fn upset_moves_more_than_expected_win() {
        let m = model();
        let strong = Rating { mu: 30.0, sigma: 2.0 };
        let weak = Rating { mu: 20.0, sigma: 2.0 };
        let expected = m.rate(&[strong, weak], &[0, 1]); // strong wins
        let upset = m.rate(&[strong, weak], &[1, 0]); // weak wins
        let expected_gain = expected[0].mu - strong.mu;
        let upset_gain = upset[1].mu - weak.mu;
        assert!(upset_gain > expected_gain, "{upset_gain} <= {expected_gain}");
    }

    #[test]
    fn repeated_wins_separate_ratings() {
        let m = model();
        let mut rs = vec![m.initial(), m.initial(), m.initial()];
        for _ in 0..30 {
            rs = m.rate(&rs, &[0, 1, 2]);
        }
        assert!(rs[0].ordinal() > rs[1].ordinal());
        assert!(rs[1].ordinal() > rs[2].ordinal());
        assert!(rs[0].mu - rs[2].mu > 5.0, "spread should be substantial");
    }

    #[test]
    fn single_player_match_is_noop() {
        let m = model();
        let r = vec![Rating { mu: 27.0, sigma: 1.5 }];
        assert_eq!(m.rate(&r, &[0]), r);
    }

    #[test]
    fn ranks_from_scores_handles_ties_and_order() {
        assert_eq!(ranks_from_scores(&[3.0, 1.0, 2.0]), vec![0, 2, 1]);
        assert_eq!(ranks_from_scores(&[1.0, 1.0, 0.5]), vec![0, 0, 2]);
        assert_eq!(ranks_from_scores(&[]), Vec::<usize>::new());
    }

    #[test]
    fn prop_sigma_never_increases_much_and_mu_order_follows_ranks() {
        prop::check("openskill-invariants", 40, |rng, size| {
            let m = model();
            let n = 2 + size % 6;
            let ratings: Vec<Rating> = (0..n)
                .map(|_| Rating {
                    mu: rng.range_f64(10.0, 40.0),
                    sigma: rng.range_f64(0.5, 8.0),
                })
                .collect();
            let scores: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let out = m.rate_by_scores(&ratings, &scores);
            for (i, (b, a)) in ratings.iter().zip(&out).enumerate() {
                prop_assert!(a.sigma.is_finite() && a.mu.is_finite(), "non-finite at {i}");
                // sigma after tau-inflation can exceed input slightly, bound it
                let max_sigma = (b.sigma * b.sigma + m.tau * m.tau).sqrt() + 1e-12;
                prop_assert!(a.sigma <= max_sigma, "sigma grew: {} -> {}", b.sigma, a.sigma);
            }
            // The best-scoring among identical priors must end with max mu.
            let same: Vec<Rating> = (0..n).map(|_| m.initial()).collect();
            let out2 = m.rate_by_scores(&same, &scores);
            let best = scores
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            let max_mu = out2.iter().map(|r| r.mu).fold(f64::MIN, f64::max);
            prop_assert!(
                (out2[best].mu - max_mu).abs() < 1e-9,
                "best scorer should have max mu"
            );
            Ok(())
        });
    }

    #[test]
    fn prop_repeated_wins_are_monotone() {
        // tau = 0 isolates the measurement update: a constant winner's mu
        // must never decrease (it strictly beats someone every match) and
        // every player's sigma must be monotone non-increasing (each match
        // only adds information).
        prop::check("openskill-monotone", 20, |rng, size| {
            let m = PlackettLuce { tau: 0.0, ..PlackettLuce::default() };
            let n = 2 + size % 5;
            let mut rs: Vec<Rating> = (0..n)
                .map(|_| Rating {
                    mu: rng.range_f64(15.0, 35.0),
                    sigma: rng.range_f64(2.0, 8.0),
                })
                .collect();
            for round in 0..200 {
                // player 0 always wins; the rest land in random tiers
                let mut ranks: Vec<usize> =
                    (0..n).map(|_| 1 + rng.below(3) as usize).collect();
                ranks[0] = 0;
                let prev = rs.clone();
                rs = m.rate(&rs, &ranks);
                for (i, (b, a)) in prev.iter().zip(&rs).enumerate() {
                    prop_assert!(
                        a.mu.is_finite() && a.sigma.is_finite(),
                        "round {round}: non-finite rating at {i}"
                    );
                    prop_assert!(
                        a.sigma <= b.sigma + 1e-12,
                        "round {round}: sigma rose at {i}: {} -> {}",
                        b.sigma,
                        a.sigma
                    );
                }
                prop_assert!(
                    rs[0].mu + 1e-9 >= prev[0].mu,
                    "round {round}: constant winner's mu fell: {} -> {}",
                    prev[0].mu,
                    rs[0].mu
                );
            }
            Ok(())
        });
    }

    #[test]
    fn prop_ratings_stay_finite_over_thousands_of_matches() {
        // The validator feeds one match per round for the lifetime of a
        // run; with the default tau dynamics, ratings must neither blow up
        // nor collapse over thousands of random-outcome matches.
        prop::check("openskill-endurance", 8, |rng, size| {
            let m = model();
            let n = 3 + size % 5;
            let mut rs: Vec<Rating> = (0..n).map(|_| m.initial()).collect();
            for round in 0..2_000 {
                let scores: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
                rs = m.rate_by_scores(&rs, &scores);
                for (i, r) in rs.iter().enumerate() {
                    prop_assert!(
                        r.mu.is_finite() && r.sigma.is_finite(),
                        "round {round}: non-finite rating at {i}"
                    );
                    prop_assert!(
                        r.sigma > 0.0 && r.sigma <= m.sigma0 * 2.0,
                        "round {round}: sigma left its band at {i}: {}",
                        r.sigma
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_total_mu_roughly_conserved_for_identical_priors() {
        prop::check("openskill-mu-conservation", 30, |rng, size| {
            let m = model();
            let n = 2 + size % 5;
            let rs: Vec<Rating> = (0..n).map(|_| m.initial()).collect();
            let scores: Vec<f64> = (0..n).map(|_| rng.range_f64(0.0, 1.0)).collect();
            let out = m.rate_by_scores(&rs, &scores);
            let before: f64 = rs.iter().map(|r| r.mu).sum();
            let after: f64 = out.iter().map(|r| r.mu).sum();
            prop_assert!((before - after).abs() < 1e-6, "mu sum drifted {before} -> {after}");
            Ok(())
        });
    }
}
