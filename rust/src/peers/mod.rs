//! Peer behaviours: honest miners and the §3/§4 adversaries.
//!
//! The live network's peers are humans running (possibly modified) training
//! scripts; the paper's own controlled experiments (Fig. 2, §4) script them
//! instead. Each [`Behavior`] reproduces one participant archetype the
//! incentive mechanism must handle:
//!
//! | behaviour        | attack surface                    | caught by      |
//! |------------------|-----------------------------------|----------------|
//! | Honest{mult}     | — (mult>1: more data, more reward)| rewarded       |
//! | Freeloader       | trains on non-assigned data       | PoC mu (eq. 3) |
//! | Copier           | re-posts another peer's gradient  | PoC mu         |
//! | Duplicator       | sybil posting identical gradients | PoC mu         |
//! | Desync           | stale model (3 rounds behind)     | SyncScore + LossRating |
//! | Late / Silent    | misses the put window             | fast checks    |
//! | FormatViolator   | malformed tensors                 | fast checks    |
//! | Rescaler         | norm inflation of the aggregate   | encoded-domain normalization (§4) |
//! | Poisoner         | garbage coefficients              | LossScore + normalization |
//! | Sybil            | k uids share one gradient, perturbed per member | PoC mu (no assigned-shard work) |
//! | CopycatNoise     | steals a victim's gradient, adds noise to dodge dedup | PoC mu |
//! | Briber           | pays one validator to inflate its weight | Yuma stake-weighted clipping |
//! | SlowLoris        | honest work posted at the last moment of the put window | window check (only if it misses) |
//! | StaleReplayer    | re-posts its own gradient from r−k | LossScore (stale direction) |

pub mod runner;

pub use runner::{PeerCtx, PeerOutput, PeerRunner, PeerRunnerState};

use crate::chain::Uid;

/// What a peer does each round.
#[derive(Clone, Debug, PartialEq)]
pub enum Behavior {
    /// Follows the baseline script; `data_mult` scales how many assigned
    /// microbatches it trains on per round (the "peer processing more
    /// data" of Fig. 2 uses 2.0).
    Honest { data_mult: f64 },
    /// Computes real gradients but on self-chosen (non-assigned) data.
    Freeloader,
    /// Pauses for `pause` rounds starting at `at`, then continues from the
    /// stale model (the Fig. 2 "desynchronized" peer; pause = 3).
    Desync { at: u64, pause: u64 },
    /// Honest compute, but uploads after the put window with prob. `prob`.
    Late { prob: f64 },
    /// Skips submitting entirely with probability `prob`.
    Silent { prob: f64 },
    /// Posts structurally corrupt objects.
    FormatViolator,
    /// Honest gradient scaled by `factor` (§4 norm attack).
    Rescaler { factor: f32 },
    /// Posts random large coefficients (§4 poisoning).
    Poisoner { scale: f32 },
    /// Copies `victim`'s submission from its public bucket and re-posts it
    /// under its own uid before the window closes.
    Copier { victim: Uid },
    /// Second registration of the same operator as `original`: posts the
    /// identical pseudo-gradient under a different uid.
    Duplicator { original: Uid },
    /// Collusion-ring member: every peer with the same `ring` id derives
    /// its gradient from one shared (non-assigned) computation, then
    /// perturbs the transmitted values by relative noise `eps` so no two
    /// members post bit-identical submissions (dodging duplicate checks).
    Sybil { ring: u64, eps: f32 },
    /// Copier that perturbs the stolen coefficients with relative noise
    /// `noise` so the copy is not bit-identical to the victim's.
    CopycatNoise { victim: Uid, noise: f32 },
    /// Computes honestly but bribes `validator` to inflate the weight it
    /// commits for this peer — the stake-security attack Yuma consensus
    /// clips unless the bribed validator holds a stake majority. The
    /// inflation itself is applied by the coordinator at the weight-commit
    /// boundary (see `coordinator::run`).
    Briber { validator: Uid },
    /// Honest compute, but every upload lands at the last instant of the
    /// put window (probing the window-close boundary every round).
    SlowLoris,
    /// Replays its own submission from `lag` rounds ago under a current
    /// header and fresh probe (honest until its history is `lag` deep).
    StaleReplayer { lag: u64 },
}

impl Behavior {
    /// Parse one behaviour spec token, the shared grammar of the CLI
    /// `--peers` list and scenario `join` events:
    ///
    /// `honest | honest:<mult> | freeloader | desync[:<at>[:<pause>]] |
    /// late[:<prob>] | silent[:<prob>] | format | rescaler[:<factor>] |
    /// poisoner[:<scale>] | copier[:<uid>] | duplicator[:<uid>] |
    /// sybil[:<ring>[:<eps>]] | copycat[:<uid>[:<noise>]] |
    /// briber[:<uid>] | slowloris | stale[:<lag>]`
    ///
    /// ```
    /// use gauntlet::peers::Behavior;
    /// assert_eq!(Behavior::parse_spec("honest:2"), Ok(Behavior::Honest { data_mult: 2.0 }));
    /// assert_eq!(Behavior::parse_spec("copier:7"), Ok(Behavior::Copier { victim: 7 }));
    /// assert!(Behavior::parse_spec("gremlin").is_err());
    /// ```
    pub fn parse_spec(spec: &str) -> Result<Behavior, String> {
        let fields: Vec<&str> = spec.trim().split(':').collect();
        fn num<T: std::str::FromStr>(fields: &[&str], i: usize, default: T) -> Result<T, String>
        where
            T::Err: std::fmt::Display,
        {
            match fields.get(i) {
                None => Ok(default),
                Some(f) => f.parse().map_err(|e| format!("bad field {f:?}: {e}")),
            }
        }
        let b = match fields[0] {
            "honest" => Behavior::Honest { data_mult: num(&fields, 1, 1.0)? },
            "freeloader" => Behavior::Freeloader,
            "desync" => Behavior::Desync {
                at: num(&fields, 1, 3)?,
                pause: num(&fields, 2, 3)?,
            },
            "late" => Behavior::Late { prob: num(&fields, 1, 0.8)? },
            "silent" => Behavior::Silent { prob: num(&fields, 1, 0.8)? },
            "format" => Behavior::FormatViolator,
            "rescaler" => Behavior::Rescaler { factor: num(&fields, 1, 100.0)? },
            "poisoner" => Behavior::Poisoner { scale: num(&fields, 1, 100.0)? },
            "copier" => Behavior::Copier { victim: num(&fields, 1, 0)? },
            "duplicator" => Behavior::Duplicator { original: num(&fields, 1, 0)? },
            "sybil" => Behavior::Sybil {
                ring: num(&fields, 1, 0)?,
                eps: num(&fields, 2, 0.01)?,
            },
            "copycat" => Behavior::CopycatNoise {
                victim: num(&fields, 1, 0)?,
                noise: num(&fields, 2, 0.05)?,
            },
            "briber" => Behavior::Briber { validator: num(&fields, 1, 0)? },
            "slowloris" => Behavior::SlowLoris,
            "stale" => Behavior::StaleReplayer { lag: num(&fields, 1, 3)? },
            other => return Err(format!("unknown peer behaviour {other:?}")),
        };
        Ok(b)
    }

    /// Canonical spec string: the inverse of [`Behavior::parse_spec`], used
    /// to serialize behaviours into run snapshots and scenario JSON.
    ///
    /// ```
    /// use gauntlet::peers::Behavior;
    /// let b = Behavior::Desync { at: 5, pause: 2 };
    /// assert_eq!(Behavior::parse_spec(&b.spec()), Ok(b));
    /// ```
    pub fn spec(&self) -> String {
        match self {
            Behavior::Honest { data_mult } if *data_mult == 1.0 => "honest".into(),
            Behavior::Honest { data_mult } => format!("honest:{data_mult}"),
            Behavior::Freeloader => "freeloader".into(),
            Behavior::Desync { at, pause } => format!("desync:{at}:{pause}"),
            Behavior::Late { prob } => format!("late:{prob}"),
            Behavior::Silent { prob } => format!("silent:{prob}"),
            Behavior::FormatViolator => "format".into(),
            Behavior::Rescaler { factor } => format!("rescaler:{factor}"),
            Behavior::Poisoner { scale } => format!("poisoner:{scale}"),
            Behavior::Copier { victim } => format!("copier:{victim}"),
            Behavior::Duplicator { original } => format!("duplicator:{original}"),
            Behavior::Sybil { ring, eps } => format!("sybil:{ring}:{eps}"),
            Behavior::CopycatNoise { victim, noise } => format!("copycat:{victim}:{noise}"),
            Behavior::Briber { validator } => format!("briber:{validator}"),
            Behavior::SlowLoris => "slowloris".into(),
            Behavior::StaleReplayer { lag } => format!("stale:{lag}"),
        }
    }

    /// Behaviours that need another peer's submission first (evaluated in
    /// the second pass of the round loop).
    pub fn is_second_pass(&self) -> bool {
        matches!(
            self,
            Behavior::Copier { .. } | Behavior::Duplicator { .. } | Behavior::CopycatNoise { .. }
        )
    }

    /// The uid this behaviour sources its gradient from, if any.
    pub fn source_uid(&self) -> Option<Uid> {
        match self {
            Behavior::Copier { victim } => Some(*victim),
            Behavior::Duplicator { original } => Some(*original),
            Behavior::CopycatNoise { victim, .. } => Some(*victim),
            _ => None,
        }
    }

    /// Short label for metrics output.
    pub fn label(&self) -> String {
        match self {
            Behavior::Honest { data_mult } if *data_mult == 1.0 => "honest".into(),
            Behavior::Honest { data_mult } => format!("honest-x{data_mult}"),
            Behavior::Freeloader => "freeloader".into(),
            Behavior::Desync { .. } => "desync".into(),
            Behavior::Late { .. } => "late".into(),
            Behavior::Silent { .. } => "silent".into(),
            Behavior::FormatViolator => "format-violator".into(),
            Behavior::Rescaler { factor } => format!("rescaler-x{factor}"),
            Behavior::Poisoner { .. } => "poisoner".into(),
            Behavior::Copier { victim } => format!("copier-of-{victim}"),
            Behavior::Duplicator { original } => format!("duplicator-of-{original}"),
            Behavior::Sybil { ring, .. } => format!("sybil-ring-{ring}"),
            Behavior::CopycatNoise { victim, .. } => format!("copycat-of-{victim}"),
            Behavior::Briber { validator } => format!("briber-of-{validator}"),
            Behavior::SlowLoris => "slowloris".into(),
            Behavior::StaleReplayer { lag } => format!("stale-x{lag}"),
        }
    }

    /// A coarse class name grouping parameterizations of the same attack,
    /// used by the scenario fuzzer and soak harness to aggregate earnings
    /// per adversary family.
    pub fn class(&self) -> &'static str {
        match self {
            Behavior::Honest { .. } => "honest",
            Behavior::Freeloader => "freeloader",
            Behavior::Desync { .. } => "desync",
            Behavior::Late { .. } => "late",
            Behavior::Silent { .. } => "silent",
            Behavior::FormatViolator => "format",
            Behavior::Rescaler { .. } => "rescaler",
            Behavior::Poisoner { .. } => "poisoner",
            Behavior::Copier { .. } => "copier",
            Behavior::Duplicator { .. } => "duplicator",
            Behavior::Sybil { .. } => "sybil",
            Behavior::CopycatNoise { .. } => "copycat",
            Behavior::Briber { .. } => "briber",
            Behavior::SlowLoris => "slowloris",
            Behavior::StaleReplayer { .. } => "stale",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn second_pass_classification() {
        assert!(Behavior::Copier { victim: 1 }.is_second_pass());
        assert!(Behavior::Duplicator { original: 2 }.is_second_pass());
        assert!(Behavior::CopycatNoise { victim: 1, noise: 0.05 }.is_second_pass());
        assert!(!Behavior::Honest { data_mult: 1.0 }.is_second_pass());
        assert!(!Behavior::Poisoner { scale: 100.0 }.is_second_pass());
        assert!(!Behavior::Sybil { ring: 0, eps: 0.01 }.is_second_pass());
        assert!(!Behavior::SlowLoris.is_second_pass());
        assert!(!Behavior::StaleReplayer { lag: 3 }.is_second_pass());
        assert!(!Behavior::Briber { validator: 0 }.is_second_pass());
    }

    #[test]
    fn source_uid() {
        assert_eq!(Behavior::Copier { victim: 7 }.source_uid(), Some(7));
        assert_eq!(Behavior::Duplicator { original: 3 }.source_uid(), Some(3));
        assert_eq!(Behavior::CopycatNoise { victim: 5, noise: 0.1 }.source_uid(), Some(5));
        assert_eq!(Behavior::Freeloader.source_uid(), None);
        assert_eq!(Behavior::Sybil { ring: 2, eps: 0.01 }.source_uid(), None);
    }

    #[test]
    fn parse_spec_roundtrips_every_behaviour() {
        for (spec, want) in [
            ("honest", Behavior::Honest { data_mult: 1.0 }),
            ("honest:2.5", Behavior::Honest { data_mult: 2.5 }),
            ("freeloader", Behavior::Freeloader),
            ("desync", Behavior::Desync { at: 3, pause: 3 }),
            ("desync:5:2", Behavior::Desync { at: 5, pause: 2 }),
            ("late", Behavior::Late { prob: 0.8 }),
            ("late:0.3", Behavior::Late { prob: 0.3 }),
            ("silent:0.9", Behavior::Silent { prob: 0.9 }),
            ("format", Behavior::FormatViolator),
            ("rescaler:1000", Behavior::Rescaler { factor: 1000.0 }),
            ("poisoner", Behavior::Poisoner { scale: 100.0 }),
            ("copier:4", Behavior::Copier { victim: 4 }),
            ("duplicator:9", Behavior::Duplicator { original: 9 }),
            ("sybil", Behavior::Sybil { ring: 0, eps: 0.01 }),
            ("sybil:7:0.25", Behavior::Sybil { ring: 7, eps: 0.25 }),
            ("copycat:3", Behavior::CopycatNoise { victim: 3, noise: 0.05 }),
            ("copycat:3:0.5", Behavior::CopycatNoise { victim: 3, noise: 0.5 }),
            ("briber:1", Behavior::Briber { validator: 1 }),
            ("slowloris", Behavior::SlowLoris),
            ("stale", Behavior::StaleReplayer { lag: 3 }),
            ("stale:5", Behavior::StaleReplayer { lag: 5 }),
        ] {
            assert_eq!(Behavior::parse_spec(spec), Ok(want), "{spec}");
        }
        assert!(Behavior::parse_spec("nope").is_err());
        assert!(Behavior::parse_spec("honest:abc").is_err());
        assert!(Behavior::parse_spec("sybil:x").is_err());
        assert!(Behavior::parse_spec("stale:-1").is_err());
    }

    #[test]
    fn spec_roundtrips_through_parse() {
        let all = [
            Behavior::Honest { data_mult: 1.0 },
            Behavior::Honest { data_mult: 2.5 },
            Behavior::Freeloader,
            Behavior::Desync { at: 5, pause: 2 },
            Behavior::Late { prob: 0.3 },
            Behavior::Silent { prob: 0.9 },
            Behavior::FormatViolator,
            Behavior::Rescaler { factor: 1000.0 },
            Behavior::Poisoner { scale: 100.0 },
            Behavior::Copier { victim: 4 },
            Behavior::Duplicator { original: 9 },
            Behavior::Sybil { ring: 7, eps: 0.25 },
            Behavior::CopycatNoise { victim: 3, noise: 0.5 },
            Behavior::Briber { validator: 1 },
            Behavior::SlowLoris,
            Behavior::StaleReplayer { lag: 5 },
        ];
        for b in all {
            assert_eq!(Behavior::parse_spec(&b.spec()), Ok(b.clone()), "{}", b.spec());
        }
    }

    #[test]
    fn spec_roundtrips_over_random_params() {
        // Satellite: parse_spec(b.spec()) == Ok(b) for EVERY variant over
        // randomly generated parameters (float Display output is
        // shortest-roundtrip in Rust, so exact equality is required).
        crate::prop::check("behavior-spec-roundtrip", 64, |rng, _size| {
            let b = crate::prop::scenario::arbitrary_behavior(rng, 1000);
            let spec = b.spec();
            match Behavior::parse_spec(&spec) {
                Ok(back) => {
                    crate::prop_assert!(back == b, "{spec:?} parsed back as {back:?}, not {b:?}");
                }
                Err(e) => return Err(format!("{spec:?} failed to parse: {e}")),
            }
            Ok(())
        });
    }

    #[test]
    fn labels_are_distinct() {
        let labels: Vec<String> = [
            Behavior::Honest { data_mult: 1.0 },
            Behavior::Honest { data_mult: 2.0 },
            Behavior::Freeloader,
            Behavior::Desync { at: 5, pause: 3 },
            Behavior::Rescaler { factor: 100.0 },
            Behavior::Sybil { ring: 1, eps: 0.01 },
            Behavior::CopycatNoise { victim: 2, noise: 0.05 },
            Behavior::Copier { victim: 2 },
            Behavior::Briber { validator: 0 },
            Behavior::SlowLoris,
            Behavior::StaleReplayer { lag: 3 },
        ]
        .iter()
        .map(|b| b.label())
        .collect();
        let mut dedup = labels.clone();
        dedup.dedup();
        assert_eq!(labels, dedup);
    }

    #[test]
    fn classes_cover_every_variant_distinctly() {
        let classes: Vec<&str> = [
            Behavior::Honest { data_mult: 1.0 },
            Behavior::Freeloader,
            Behavior::Desync { at: 3, pause: 3 },
            Behavior::Late { prob: 0.8 },
            Behavior::Silent { prob: 0.8 },
            Behavior::FormatViolator,
            Behavior::Rescaler { factor: 100.0 },
            Behavior::Poisoner { scale: 100.0 },
            Behavior::Copier { victim: 0 },
            Behavior::Duplicator { original: 0 },
            Behavior::Sybil { ring: 0, eps: 0.01 },
            Behavior::CopycatNoise { victim: 0, noise: 0.05 },
            Behavior::Briber { validator: 0 },
            Behavior::SlowLoris,
            Behavior::StaleReplayer { lag: 3 },
        ]
        .iter()
        .map(|b| b.class())
        .collect();
        let mut dedup = classes.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), classes.len(), "class names must be unique");
    }
}
