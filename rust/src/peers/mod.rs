//! Peer behaviours: honest miners and the §3/§4 adversaries.
//!
//! The live network's peers are humans running (possibly modified) training
//! scripts; the paper's own controlled experiments (Fig. 2, §4) script them
//! instead. Each [`Behavior`] reproduces one participant archetype the
//! incentive mechanism must handle:
//!
//! | behaviour        | attack surface                    | caught by      |
//! |------------------|-----------------------------------|----------------|
//! | Honest{mult}     | — (mult>1: more data, more reward)| rewarded       |
//! | Freeloader       | trains on non-assigned data       | PoC mu (eq. 3) |
//! | Copier           | re-posts another peer's gradient  | PoC mu         |
//! | Duplicator       | sybil posting identical gradients | PoC mu         |
//! | Desync           | stale model (3 rounds behind)     | SyncScore + LossRating |
//! | Late / Silent    | misses the put window             | fast checks    |
//! | FormatViolator   | malformed tensors                 | fast checks    |
//! | Rescaler         | norm inflation of the aggregate   | encoded-domain normalization (§4) |
//! | Poisoner         | garbage coefficients              | LossScore + normalization |

pub mod runner;

pub use runner::{PeerCtx, PeerOutput, PeerRunner};

use crate::chain::Uid;

/// What a peer does each round.
#[derive(Clone, Debug, PartialEq)]
pub enum Behavior {
    /// Follows the baseline script; `data_mult` scales how many assigned
    /// microbatches it trains on per round (the "peer processing more
    /// data" of Fig. 2 uses 2.0).
    Honest { data_mult: f64 },
    /// Computes real gradients but on self-chosen (non-assigned) data.
    Freeloader,
    /// Pauses for `pause` rounds starting at `at`, then continues from the
    /// stale model (the Fig. 2 "desynchronized" peer; pause = 3).
    Desync { at: u64, pause: u64 },
    /// Honest compute, but uploads after the put window with prob. `prob`.
    Late { prob: f64 },
    /// Skips submitting entirely with probability `prob`.
    Silent { prob: f64 },
    /// Posts structurally corrupt objects.
    FormatViolator,
    /// Honest gradient scaled by `factor` (§4 norm attack).
    Rescaler { factor: f32 },
    /// Posts random large coefficients (§4 poisoning).
    Poisoner { scale: f32 },
    /// Copies `victim`'s submission from its public bucket and re-posts it
    /// under its own uid before the window closes.
    Copier { victim: Uid },
    /// Second registration of the same operator as `original`: posts the
    /// identical pseudo-gradient under a different uid.
    Duplicator { original: Uid },
}

impl Behavior {
    /// Behaviours that need another peer's submission first (evaluated in
    /// the second pass of the round loop).
    pub fn is_second_pass(&self) -> bool {
        matches!(self, Behavior::Copier { .. } | Behavior::Duplicator { .. })
    }

    /// The uid this behaviour sources its gradient from, if any.
    pub fn source_uid(&self) -> Option<Uid> {
        match self {
            Behavior::Copier { victim } => Some(*victim),
            Behavior::Duplicator { original } => Some(*original),
            _ => None,
        }
    }

    /// Short label for metrics output.
    pub fn label(&self) -> String {
        match self {
            Behavior::Honest { data_mult } if *data_mult == 1.0 => "honest".into(),
            Behavior::Honest { data_mult } => format!("honest-x{data_mult}"),
            Behavior::Freeloader => "freeloader".into(),
            Behavior::Desync { .. } => "desync".into(),
            Behavior::Late { .. } => "late".into(),
            Behavior::Silent { .. } => "silent".into(),
            Behavior::FormatViolator => "format-violator".into(),
            Behavior::Rescaler { factor } => format!("rescaler-x{factor}"),
            Behavior::Poisoner { .. } => "poisoner".into(),
            Behavior::Copier { victim } => format!("copier-of-{victim}"),
            Behavior::Duplicator { original } => format!("duplicator-of-{original}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn second_pass_classification() {
        assert!(Behavior::Copier { victim: 1 }.is_second_pass());
        assert!(Behavior::Duplicator { original: 2 }.is_second_pass());
        assert!(!Behavior::Honest { data_mult: 1.0 }.is_second_pass());
        assert!(!Behavior::Poisoner { scale: 100.0 }.is_second_pass());
    }

    #[test]
    fn source_uid() {
        assert_eq!(Behavior::Copier { victim: 7 }.source_uid(), Some(7));
        assert_eq!(Behavior::Duplicator { original: 3 }.source_uid(), Some(3));
        assert_eq!(Behavior::Freeloader.source_uid(), None);
    }

    #[test]
    fn labels_are_distinct() {
        let labels: Vec<String> = [
            Behavior::Honest { data_mult: 1.0 },
            Behavior::Honest { data_mult: 2.0 },
            Behavior::Freeloader,
            Behavior::Desync { at: 5, pause: 3 },
            Behavior::Rescaler { factor: 100.0 },
        ]
        .iter()
        .map(|b| b.label())
        .collect();
        let mut dedup = labels.clone();
        dedup.dedup();
        assert_eq!(labels, dedup);
    }
}
