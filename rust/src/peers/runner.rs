//! The peer-side training loop: local gradient work, DeMo compression and
//! bucket upload, parameterized by [`Behavior`].
//!
//! Honest flow per round (the paper's baseline miner script):
//!   1. derive the assigned shards `D_t^p` from public seeds,
//!   2. accumulate gradients over `n` microbatches via the `grad` artifact,
//!   3. fold into the DeMo error-feedback buffer and compress
//!      (`demo_compress` artifact: e <- decay*e + g, DCT, top-k),
//!   4. sample the SyncScore probe from the local model view,
//!   5. upload the wire-encoded submission inside the put window.
//!
//! Adversarial behaviours deviate at specific steps — see `peers/mod.rs`.

use anyhow::Result;

use super::Behavior;
use crate::coordinator::round::RoundClock;
use crate::coordinator::GauntletParams;
use crate::data::Corpus;
use crate::demo::wire::Submission;
use crate::demo::SparseGrad;
use crate::runtime::{ExecBackend, Executor};
use crate::storage::SimTime;
use crate::util::Rng;

/// Everything a peer sees when taking its turn in a round.
///
/// Generic over the execution backend so the same peer code runs against
/// the PJRT [`Executor`] on the owning thread, an
/// [`ExecClient`](crate::runtime::ExecClient) from a parallel worker, or
/// the pure-Rust [`SimExec`](crate::runtime::SimExec).
pub struct PeerCtx<'a, E: ExecBackend + ?Sized = Executor> {
    pub exec: &'a E,
    pub corpus: &'a Corpus,
    /// The globally agreed model at the start of the round (what a
    /// synchronized peer holds after applying the previous aggregation).
    pub global_theta: &'a [f32],
    pub round: u64,
    pub clock: &'a RoundClock,
    pub params: &'a GauntletParams,
}

/// What the peer does with the storage layer this round.
#[derive(Debug)]
pub enum PeerOutput {
    Submit { time: SimTime, bytes: Vec<u8> },
    Skip,
}

/// How long before the put-window close a SlowLoris peer sends its upload:
/// just enough headroom for the mean provider latency, so the object lands
/// in the last block of the window nearly every round (and occasionally
/// misses it when the latency draw runs long — that boundary probing is
/// the attack).
const SLOW_LORIS_MARGIN_MS: u64 = 2_000;

/// Per-peer persistent state across rounds.
pub struct PeerRunner {
    pub uid: u32,
    pub behavior: Behavior,
    /// DeMo error-feedback buffer (zeros at start, like the reference
    /// miner script).
    error: Vec<f32>,
    /// Divergent local model, if this peer is not tracking the global one
    /// (Desync after its pause).
    theta_local: Option<Vec<f32>>,
    rng: Rng,
    /// ms of compute per microbatch (speed heterogeneity).
    pub compute_ms_per_mb: u64,
    /// Diagnostics: microbatches processed in the last round.
    pub last_microbatches: usize,
    pub last_local_loss: f64,
    /// Gradient-accumulation scratch, reused across rounds (perf). Pure
    /// scratch: zero-filled before every use, so it is *not* part of
    /// [`PeerRunnerState`] and restarts empty after a snapshot resume.
    grad_accum: Vec<f32>,
    /// Per-microbatch kernel output scratch (the buffer
    /// [`ExecBackend::grad_into`] writes into, also reused as the
    /// `apply_update_into` target for divergent peers). Pure scratch,
    /// like `grad_accum`: every consumer overwrites it fully.
    grad_scratch: Vec<f32>,
    /// StaleReplayer's archive of its own recent gradients, keyed by the
    /// round they were computed in (bounded to the replay lag). Persistent
    /// state: a resume mid-lag must replay the same stale gradient the
    /// uninterrupted run would have.
    replay_log: Vec<(u64, SparseGrad)>,
}

/// Every persistent field of a [`PeerRunner`], exported as plain data for
/// run snapshots: the DeMo error-feedback buffer and the behaviour RNG are
/// mid-run state that the next round's draws depend on, so resume must
/// restore them bit-exactly rather than re-derive them from the seed.
#[derive(Clone, Debug)]
pub struct PeerRunnerState {
    pub uid: u32,
    pub behavior: Behavior,
    pub error: Vec<f32>,
    pub theta_local: Option<Vec<f32>>,
    pub rng_state: u64,
    pub compute_ms_per_mb: u64,
    pub last_microbatches: usize,
    pub last_local_loss: f64,
    pub replay_log: Vec<(u64, SparseGrad)>,
}

impl PeerRunner {
    pub fn new(uid: u32, behavior: Behavior, param_count: usize, seed: u64) -> Self {
        let mut rng = Rng::from_parts(&["peer", &uid.to_string(), &seed.to_string()]);
        let compute_ms_per_mb = 2_000 + rng.below(2_000);
        PeerRunner {
            uid,
            behavior,
            error: vec![0.0; param_count],
            theta_local: None,
            rng,
            compute_ms_per_mb,
            last_microbatches: 0,
            last_local_loss: f64::NAN,
            grad_accum: Vec::new(),
            grad_scratch: Vec::new(),
            replay_log: Vec::new(),
        }
    }

    /// Export this runner's persistent state (see [`PeerRunnerState`]).
    pub fn to_state(&self) -> PeerRunnerState {
        PeerRunnerState {
            uid: self.uid,
            behavior: self.behavior.clone(),
            error: self.error.clone(),
            theta_local: self.theta_local.clone(),
            rng_state: self.rng.state(),
            compute_ms_per_mb: self.compute_ms_per_mb,
            last_microbatches: self.last_microbatches,
            last_local_loss: self.last_local_loss,
            replay_log: self.replay_log.clone(),
        }
    }

    /// Rebuild a runner mid-run — the exact inverse of
    /// [`PeerRunner::to_state`].
    pub fn from_state(state: PeerRunnerState) -> PeerRunner {
        PeerRunner {
            uid: state.uid,
            behavior: state.behavior,
            error: state.error,
            theta_local: state.theta_local,
            rng: Rng::from_state(state.rng_state),
            compute_ms_per_mb: state.compute_ms_per_mb,
            last_microbatches: state.last_microbatches,
            last_local_loss: state.last_local_loss,
            grad_accum: Vec::new(),
            grad_scratch: Vec::new(),
            replay_log: state.replay_log,
        }
    }

    /// The model this peer trains on / probes from.
    fn theta_view<'a, E: ExecBackend + ?Sized>(&'a self, ctx: &'a PeerCtx<'_, E>) -> &'a [f32] {
        self.theta_local.as_deref().unwrap_or(ctx.global_theta)
    }

    /// Whether this peer is currently in its Desync pause.
    fn paused(&self, round: u64) -> bool {
        matches!(self.behavior, Behavior::Desync { at, pause } if (at..at + pause).contains(&round))
    }

    /// First-pass step (every behaviour except Copier/Duplicator).
    pub fn step<E: ExecBackend + ?Sized>(&mut self, ctx: &PeerCtx<'_, E>) -> Result<PeerOutput> {
        assert!(!self.behavior.is_second_pass(), "second-pass peer stepped in pass 1");
        match self.behavior.clone() {
            Behavior::Honest { data_mult } => self.honest_step(ctx, data_mult, 1.0),
            Behavior::Rescaler { factor } => self.honest_step(ctx, 1.0, factor),
            Behavior::Freeloader => self.freeload_step(ctx),
            Behavior::Desync { .. } => {
                if self.paused(ctx.round) {
                    Ok(PeerOutput::Skip)
                } else {
                    self.honest_step(ctx, 1.0, 1.0)
                }
            }
            Behavior::Late { prob } => {
                let out = self.honest_step(ctx, 1.0, 1.0)?;
                if let PeerOutput::Submit { bytes, .. } = out {
                    let (_, close) = ctx.clock.put_window(ctx.round);
                    let time = if self.rng.chance(prob) {
                        close + 1 + self.rng.below(5_000) // missed the window
                    } else {
                        self.upload_time(ctx, 1)
                    };
                    Ok(PeerOutput::Submit { time, bytes })
                } else {
                    Ok(out)
                }
            }
            Behavior::Silent { prob } => {
                if self.rng.chance(prob) {
                    Ok(PeerOutput::Skip)
                } else {
                    self.honest_step(ctx, 1.0, 1.0)
                }
            }
            Behavior::FormatViolator => {
                // Real-looking header, wrong payload dimensions: claims one
                // extra coefficient, breaking the meta.json contract.
                let c = ctx.exec.meta().coeff_count + 1;
                let grad = SparseGrad {
                    vals: vec![0.1; c],
                    idx: (0..c as i32).collect(),
                };
                let sub = Submission {
                    uid: self.uid,
                    round: ctx.round,
                    grad,
                    probe: ctx.exec.meta().sync_probe(self.theta_view(ctx)),
                };
                Ok(PeerOutput::Submit { time: self.upload_time(ctx, 1), bytes: sub.encode() })
            }
            Behavior::Poisoner { scale } => {
                let meta = ctx.exec.meta();
                let c = meta.coeff_count;
                let grad = SparseGrad {
                    vals: (0..c).map(|_| self.rng.normal_f32(0.0, scale)).collect(),
                    idx: (0..c).map(|_| self.rng.below(meta.padded_count as u64) as i32).collect(),
                };
                let sub = Submission {
                    uid: self.uid,
                    round: ctx.round,
                    grad,
                    probe: meta.sync_probe(ctx.global_theta),
                };
                Ok(PeerOutput::Submit { time: self.upload_time(ctx, 1), bytes: sub.encode() })
            }
            Behavior::Sybil { ring, eps } => self.sybil_step(ctx, ring, eps),
            // A briber's compute is honest — the attack happens at the
            // weight-commit boundary, applied by the coordinator.
            Behavior::Briber { .. } => self.honest_step(ctx, 1.0, 1.0),
            Behavior::SlowLoris => {
                let out = self.honest_step(ctx, 1.0, 1.0)?;
                if let PeerOutput::Submit { time, bytes } = out {
                    // Aim for the last block of the put window, never
                    // earlier than the honest compute-bound time.
                    let (_, close) = ctx.clock.put_window(ctx.round);
                    let t = time.max(close.saturating_sub(SLOW_LORIS_MARGIN_MS));
                    Ok(PeerOutput::Submit { time: t, bytes })
                } else {
                    Ok(out)
                }
            }
            Behavior::StaleReplayer { lag } => self.stale_step(ctx, lag),
            Behavior::Copier { .. }
            | Behavior::Duplicator { .. }
            | Behavior::CopycatNoise { .. } => unreachable!(),
        }
    }

    /// Second-pass step for Copier/Duplicator: given the source peer's
    /// published bytes (if any), re-post the gradient under this uid.
    pub fn step_copy<E: ExecBackend + ?Sized>(
        &mut self,
        ctx: &PeerCtx<'_, E>,
        source_bytes: Option<&[u8]>,
    ) -> Result<PeerOutput> {
        let Some(bytes) = source_bytes else { return Ok(PeerOutput::Skip) };
        let Ok(src) = Submission::decode(bytes) else { return Ok(PeerOutput::Skip) };
        let mut grad = src.grad;
        if let Behavior::CopycatNoise { noise, .. } = self.behavior {
            // Relative per-coefficient noise: not bit-identical to the
            // victim, so duplicate detection alone can't flag the theft.
            for v in &mut grad.vals {
                *v *= 1.0 + self.rng.normal_f32(0.0, noise);
            }
        }
        let sub = Submission {
            uid: self.uid,
            round: ctx.round,
            grad,
            // The copier is synchronized (it follows the public aggregate),
            // so its probe is honest — only PoC can catch it.
            probe: ctx.exec.meta().sync_probe(self.theta_view(ctx)),
        };
        // Copying is fast; it posts shortly after the source appears.
        let (open, close) = ctx.clock.put_window(ctx.round);
        let t = (open + self.rng.below(close - open)).min(close - 1);
        Ok(PeerOutput::Submit { time: t, bytes: sub.encode() })
    }

    fn upload_time<E: ExecBackend + ?Sized>(&mut self, ctx: &PeerCtx<'_, E>, n_mb: usize) -> SimTime {
        let compute = self.compute_ms_per_mb * n_mb as u64 + self.rng.below(500);
        ctx.clock.compliant_upload_time(ctx.round, compute)
    }

    /// The honest miner loop; `grad_scale` rescales the transmitted values
    /// (1.0 for honest peers, the attack factor for Rescaler).
    ///
    /// The local model view is *taken* out of `self` for the duration of
    /// the step instead of copied — training against a divergent
    /// `theta_local` previously cloned the full parameter vector every
    /// round. Synchronized peers already borrow the global model directly.
    fn honest_step<E: ExecBackend + ?Sized>(
        &mut self,
        ctx: &PeerCtx<'_, E>,
        data_mult: f64,
        grad_scale: f32,
    ) -> Result<PeerOutput> {
        let local = self.theta_local.take();
        let result =
            self.honest_core(ctx, local.as_deref().unwrap_or(ctx.global_theta), data_mult, grad_scale);
        self.theta_local = local;
        result
    }

    fn honest_core<E: ExecBackend + ?Sized>(
        &mut self,
        ctx: &PeerCtx<'_, E>,
        theta: &[f32],
        data_mult: f64,
        grad_scale: f32,
    ) -> Result<PeerOutput> {
        let meta = ctx.exec.meta();
        let (b, s1) = (meta.batch, meta.seq + 1);
        let n_mb = ((ctx.params.base_microbatches as f64 * data_mult).round() as usize).max(1);
        self.last_microbatches = n_mb;

        // Zero-fill the reusable accumulator instead of allocating one per
        // round; the per-microbatch gradient lands in the reusable
        // `grad_scratch` (`grad_into`), so the inner loop allocates
        // nothing theta-sized at all.
        self.grad_accum.clear();
        self.grad_accum.resize(meta.param_count, 0.0);
        let mut loss_sum = 0.0f64;
        for mb in 0..n_mb {
            let toks = ctx.corpus.assigned_shard(self.uid, ctx.round, mb as u32, b, s1);
            let loss = ctx.exec.grad_into(theta, &toks, &mut self.grad_scratch)?;
            loss_sum += loss as f64;
            for (a, gi) in self.grad_accum.iter_mut().zip(&self.grad_scratch) {
                *a += gi / n_mb as f32;
            }
        }
        self.last_local_loss = loss_sum / n_mb as f64;

        // In-place compression: the error-feedback buffer is folded and
        // re-ranked where it lives — the last theta-sized allocation on
        // the honest step path.
        let (mut vals, mut idx) = (Vec::new(), Vec::new());
        ctx.exec.demo_compress_into(
            &mut self.error,
            &self.grad_accum,
            ctx.params.demo_decay,
            &mut vals,
            &mut idx,
        )?;
        if grad_scale != 1.0 {
            for v in &mut vals {
                *v *= grad_scale;
            }
        }
        let sub = Submission {
            uid: self.uid,
            round: ctx.round,
            grad: SparseGrad { vals, idx },
            probe: meta.sync_probe(theta),
        };
        Ok(PeerOutput::Submit { time: self.upload_time(ctx, n_mb), bytes: sub.encode() })
    }

    /// Freeloader: real gradient work, wrong (self-chosen) data. Same
    /// take-don't-copy model view as [`PeerRunner::honest_step`].
    fn freeload_step<E: ExecBackend + ?Sized>(&mut self, ctx: &PeerCtx<'_, E>) -> Result<PeerOutput> {
        let local = self.theta_local.take();
        let result = self.freeload_core(ctx, local.as_deref().unwrap_or(ctx.global_theta));
        self.theta_local = local;
        result
    }

    fn freeload_core<E: ExecBackend + ?Sized>(
        &mut self,
        ctx: &PeerCtx<'_, E>,
        theta: &[f32],
    ) -> Result<PeerOutput> {
        let meta = ctx.exec.meta();
        let (b, s1) = (meta.batch, meta.seq + 1);
        // deliberately NOT the assigned shard
        let toks = ctx.corpus.batch(
            &["freeload", &self.uid.to_string(), &ctx.round.to_string()],
            b,
            s1,
        );
        let loss = ctx.exec.grad_into(theta, &toks, &mut self.grad_scratch)?;
        self.last_local_loss = loss as f64;
        self.last_microbatches = 1;
        let (mut vals, mut idx) = (Vec::new(), Vec::new());
        ctx.exec.demo_compress_into(
            &mut self.error,
            &self.grad_scratch,
            ctx.params.demo_decay,
            &mut vals,
            &mut idx,
        )?;
        let sub = Submission {
            uid: self.uid,
            round: ctx.round,
            grad: SparseGrad { vals, idx },
            probe: meta.sync_probe(theta),
        };
        Ok(PeerOutput::Submit { time: self.upload_time(ctx, 1), bytes: sub.encode() })
    }

    /// Sybil ring member: one shared gradient computation per ring per
    /// round (derived from the ring id, not the member's uid or assigned
    /// shard), perturbed per member so no two submissions are identical.
    fn sybil_step<E: ExecBackend + ?Sized>(
        &mut self,
        ctx: &PeerCtx<'_, E>,
        ring: u64,
        eps: f32,
    ) -> Result<PeerOutput> {
        let local = self.theta_local.take();
        let result = self.sybil_core(ctx, local.as_deref().unwrap_or(ctx.global_theta), ring, eps);
        self.theta_local = local;
        result
    }

    fn sybil_core<E: ExecBackend + ?Sized>(
        &mut self,
        ctx: &PeerCtx<'_, E>,
        theta: &[f32],
        ring: u64,
        eps: f32,
    ) -> Result<PeerOutput> {
        let meta = ctx.exec.meta();
        let (b, s1) = (meta.batch, meta.seq + 1);
        // The whole ring shares this batch — k registrations, one unit of
        // gradient work (and none of it on the assigned shards).
        let toks =
            ctx.corpus.batch(&["sybil", &ring.to_string(), &ctx.round.to_string()], b, s1);
        let loss = ctx.exec.grad_into(theta, &toks, &mut self.grad_scratch)?;
        self.last_local_loss = loss as f64;
        self.last_microbatches = 1;
        let (mut vals, mut idx) = (Vec::new(), Vec::new());
        ctx.exec.demo_compress_into(
            &mut self.error,
            &self.grad_scratch,
            ctx.params.demo_decay,
            &mut vals,
            &mut idx,
        )?;
        // Per-member perturbation (the member's own RNG) to dodge
        // bit-identical duplicate checks.
        for v in &mut vals {
            *v *= 1.0 + self.rng.normal_f32(0.0, eps);
        }
        let sub = Submission {
            uid: self.uid,
            round: ctx.round,
            grad: SparseGrad { vals, idx },
            probe: meta.sync_probe(theta),
        };
        Ok(PeerOutput::Submit { time: self.upload_time(ctx, 1), bytes: sub.encode() })
    }

    /// StaleReplayer: does the honest work every round (keeping its error
    /// buffer and timing legitimate) but archives the fresh gradient and
    /// posts the one from `lag` rounds ago under a current header and
    /// fresh probe. Honest until the archive is `lag` deep.
    fn stale_step<E: ExecBackend + ?Sized>(
        &mut self,
        ctx: &PeerCtx<'_, E>,
        lag: u64,
    ) -> Result<PeerOutput> {
        let out = self.honest_step(ctx, 1.0, 1.0)?;
        let PeerOutput::Submit { time, bytes } = out else { return Ok(out) };
        let Ok(mut sub) = Submission::decode(&bytes) else {
            return Ok(PeerOutput::Submit { time, bytes });
        };
        self.replay_log.push((ctx.round, sub.grad.clone()));
        let cutoff = ctx.round.saturating_sub(lag);
        self.replay_log.retain(|(r, _)| *r >= cutoff);
        if lag > 0 && ctx.round >= lag {
            let want = ctx.round - lag;
            if let Some((_, old)) = self.replay_log.iter().find(|(r, _)| *r == want) {
                sub.grad = old.clone();
            }
        }
        Ok(PeerOutput::Submit { time, bytes: sub.encode() })
    }

    /// End-of-round model maintenance: synchronized peers adopt the new
    /// global model; a Desync peer in/after its pause maintains its own
    /// divergent copy by applying the aggregate to the stale base.
    pub fn on_round_end<E: ExecBackend + ?Sized>(
        &mut self,
        round: u64,
        new_global: &[f32],
        exec: &E,
        agg_coeff: Option<&[f32]>,
        lr: f32,
    ) -> Result<()> {
        match self.behavior {
            Behavior::Desync { at, pause } => {
                if round + 1 == at {
                    // entering the pause: freeze the current global model
                    self.theta_local = Some(new_global.to_vec());
                } else if let Some(local) = &mut self.theta_local {
                    if round + 1 >= at + pause {
                        // resumed: keep applying aggregations to the stale
                        // base (permanently ~`pause` steps divergent).
                        // Applied into the reusable scratch and swapped in,
                        // so maintaining the divergent copy allocates
                        // nothing per round.
                        if let Some(coeff) = agg_coeff {
                            exec.apply_update_into(local, coeff, lr, &mut self.grad_scratch)?;
                            std::mem::swap(local, &mut self.grad_scratch);
                        }
                    }
                    // during the pause: do nothing (model frozen)
                }
            }
            _ => {
                // synchronized peers hold the global model by reference
                self.theta_local = None;
            }
        }
        Ok(())
    }

    /// Expose the error-feedback buffer length (tests).
    pub fn error_norm(&self) -> f64 {
        crate::util::det_sum(self.error.iter().map(|x| (*x as f64).powi(2))).sqrt()
    }

    pub fn is_divergent(&self) -> bool {
        self.theta_local.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paused_window_is_half_open() {
        let p = PeerRunner::new(0, Behavior::Desync { at: 5, pause: 3 }, 4, 0);
        assert!(!p.paused(4));
        assert!(p.paused(5));
        assert!(p.paused(7));
        assert!(!p.paused(8));
    }

    #[test]
    fn new_runner_has_zero_error_buffer() {
        let p = PeerRunner::new(3, Behavior::Honest { data_mult: 1.0 }, 128, 1);
        assert_eq!(p.error_norm(), 0.0);
        assert!(!p.is_divergent());
    }

    #[test]
    fn replay_log_survives_state_roundtrip() {
        let mut p = PeerRunner::new(2, Behavior::StaleReplayer { lag: 2 }, 8, 1);
        p.replay_log.push((4, SparseGrad { vals: vec![1.0, -2.0], idx: vec![0, 5] }));
        p.replay_log.push((5, SparseGrad { vals: vec![0.5, 0.25], idx: vec![3, 7] }));
        let q = PeerRunner::from_state(p.to_state());
        assert_eq!(q.replay_log, p.replay_log);
    }

    #[test]
    fn compute_speed_is_deterministic_per_uid_seed() {
        let a = PeerRunner::new(3, Behavior::Freeloader, 4, 9);
        let b = PeerRunner::new(3, Behavior::Freeloader, 4, 9);
        assert_eq!(a.compute_ms_per_mb, b.compute_ms_per_mb);
        let c = PeerRunner::new(4, Behavior::Freeloader, 4, 9);
        assert_ne!(a.compute_ms_per_mb, c.compute_ms_per_mb);
    }
}
